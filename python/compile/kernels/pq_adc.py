"""Layer-1 Pallas kernel: PQ asymmetric-distance computation (ADC).

Given a per-query lookup table ``lut (M, K)`` and per-vector codes
``codes (N, M)``, the approximate distance of vector n is
``sum_m lut[m, codes[n, m]]`` — the next-hop selection hot spot of
PageANN's on-page compressed neighbors (paper §4.2).

TPU mapping: the LUT (M x 256 f32 <= 16 KiB at M=16) stays resident in VMEM
across the grid; code tiles stream through. The gather is expressed as
``take_along_axis`` over the K axis, which Mosaic lowers to VMEM dynamic
gathers; on CPU (interpret=True) it executes as numpy fancy indexing.

Codes arrive as f32 (the rust boundary passes a single literal dtype) and
are converted in-kernel; values are exact integers <= 255 so the f32->s32
round-trip is lossless.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128


def _adc_kernel(lut_ref, codes_ref, o_ref):
    lut = lut_ref[...]  # (M, K)
    codes = codes_ref[...].astype(jnp.int32)  # (TR, M)
    m = lut.shape[0]
    # gathered[n, m] = lut[m, codes[n, m]]
    gathered = jnp.take_along_axis(lut.T[None, :, :],  # (1, K, M) -> broadcast
                                   codes[:, None, :], axis=1)[:, 0, :]
    del m
    o_ref[...] = jnp.sum(gathered, axis=-1)[None, :]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def pq_adc(lut, codes, *, block_rows=DEFAULT_BLOCK_ROWS, interpret=True):
    """ADC distances: lut (M, K) f32, codes (N, M) f32-of-ints -> (N,) f32."""
    n, m = codes.shape
    _, k = lut.shape
    assert n % block_rows == 0, f"rows {n} not a multiple of {block_rows}"
    out = pl.pallas_call(
        _adc_kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),  # LUT resident in VMEM
            pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(lut, codes)
    return out[0]


def vmem_bytes(block_rows, m, k):
    """Estimated VMEM footprint per grid step."""
    return 4 * (m * k + block_rows * m + block_rows)
