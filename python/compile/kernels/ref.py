"""Pure-jnp reference oracles for the Layer-1 Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
(`python/tests/`) asserts allclose between kernel and oracle across shape /
dtype sweeps (hypothesis). The rust layer additionally cross-checks the
compiled artifacts against its own native backend.
"""

import jax.numpy as jnp


def l2_batch_ref(query, block):
    """Squared L2 from `query` (D,) to each row of `block` (R, D) -> (R,)."""
    diff = block - query[None, :]
    return jnp.sum(diff * diff, axis=-1)


def pq_adc_ref(lut, codes):
    """Asymmetric distance computation.

    lut:   (M, K) f32 — per-subspace distance of the query to each centroid.
    codes: (N, M) int — centroid index per subspace for each of N vectors.
    returns (N,) f32 — sum over subspaces of lut[m, codes[n, m]].
    """
    m = lut.shape[0]
    gathered = lut[jnp.arange(m)[None, :], codes]  # (N, M)
    return jnp.sum(gathered, axis=-1)


def hash_encode_ref(query, planes):
    """Hyperplane sign bits: (planes @ query > 0) as f32 (H,)."""
    proj = planes @ query
    return (proj > 0).astype(jnp.float32)


def pq_lut_ref(query, codebooks):
    """Build the ADC lookup table.

    query:     (D,) f32
    codebooks: (M, K, D//M) f32
    returns    (M, K) f32 — squared L2 from the m-th query subvector to each
               centroid of subspace m.
    """
    m, _, dsub = codebooks.shape
    qsub = query.reshape(m, 1, dsub)
    diff = codebooks - qsub
    return jnp.sum(diff * diff, axis=-1)
