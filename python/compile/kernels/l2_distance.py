"""Layer-1 Pallas kernel: batched squared-L2 distance (the page-scan hot
spot).

TPU mapping (DESIGN.md §Hardware-Adaptation): the distance is computed as
``||x||^2 - 2 x.q + ||q||^2`` so the inner loop is a (TR, D) x (D,) matvec
that lowers onto the MXU; the row axis is tiled by BlockSpec so each tile
(TR x D f32 <= 64 KiB at TR=128, D=128) sits in VMEM with the query vector
resident across the whole grid.

CPU note: lowered with ``interpret=True`` — real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Structure (tiling, fused
matvec) is preserved either way.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 128 keeps the tile square-ish against D<=128 and is a
# multiple of the 8-lane f32 sublane tiling on TPU.
DEFAULT_BLOCK_ROWS = 128


def _l2_kernel(q_ref, x_ref, o_ref):
    # q_ref: (1, D) — kept 2-D so the matvec is a plain dot on the MXU.
    # x_ref: (TR, D) tile of the block.
    # o_ref: (1, TR) distances for this tile.
    q = q_ref[...]  # (1, D)
    x = x_ref[...]  # (TR, D)
    xsq = jnp.sum(x * x, axis=-1)  # (TR,)
    qsq = jnp.sum(q * q)  # scalar
    cross = jnp.dot(x, q[0, :])  # (TR,) — MXU matvec
    o_ref[...] = (xsq - 2.0 * cross + qsq)[None, :]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def l2_batch(query, block, *, block_rows=DEFAULT_BLOCK_ROWS, interpret=True):
    """Squared L2 from `query` (D,) to each row of `block` (R, D) -> (R,).

    R must be a multiple of `block_rows` (the AOT wrapper pads).
    """
    r, d = block.shape
    assert r % block_rows == 0, f"rows {r} not a multiple of {block_rows}"
    q2 = query[None, :]  # (1, D)
    out = pl.pallas_call(
        _l2_kernel,
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),  # query resident
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),  # row tiles
        ],
        out_specs=pl.BlockSpec((1, block_rows), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, r), jnp.float32),
        interpret=interpret,
    )(q2, block)
    return out[0]


def vmem_bytes(block_rows, d):
    """Estimated VMEM footprint per grid step (inputs + output tile)."""
    return 4 * (d + block_rows * d + block_rows)
