"""Layer-1 Pallas kernel: hyperplane-LSH hash encoding.

Projects the query onto H random hyperplanes and emits the sign bits
(as 0.0/1.0 f32; the rust side packs them into a u64 code). This is the
in-memory routing front-end of PageANN (paper §4.3): one matvec per query,
executed once per search.

TPU mapping: H x D f32 (<= 16 KiB at H=32, D=128) fits in a single VMEM
tile, so the grid is trivial — one step, one MXU matvec.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hash_kernel(q_ref, p_ref, o_ref):
    q = q_ref[...]  # (1, D)
    planes = p_ref[...]  # (H, D)
    proj = jnp.dot(planes, q[0, :])  # (H,) — MXU matvec
    o_ref[...] = (proj > 0).astype(jnp.float32)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def hash_encode(query, planes, *, interpret=True):
    """Sign bits of `planes @ query`: (D,), (H, D) -> (H,) of {0.0, 1.0}."""
    h, d = planes.shape
    out = pl.pallas_call(
        _hash_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((h, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, h), jnp.float32),
        interpret=interpret,
    )(query[None, :], planes)
    return out[0]


def vmem_bytes(h, d):
    return 4 * (d + h * d + h)
