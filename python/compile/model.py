"""Layer-2 JAX graphs: the compute-side of PageANN's query path.

These compose the Layer-1 Pallas kernels into the fixed-shape computations
the rust coordinator invokes through PJRT:

* ``l2_batch``      — exact distances query -> page-vector block (kernel).
* ``pq_adc``        — approx distances query -> compressed neighbors (kernel).
* ``hash_encode``   — LSH routing code (kernel).
* ``pq_lut``        — per-query ADC table build (plain jnp: one-shot per
                      query, not a hot loop; XLA fuses it into 3 ops).
* ``page_scan``     — fused: exact block distances + neighbor ADC in one
                      artifact, saving a PJRT dispatch per hop.

Everything here runs at build time only (``make artifacts``); the rust
binary loads the lowered HLO text and never imports python.
"""

import jax.numpy as jnp

from .kernels import hash_encode as hk
from .kernels import l2_distance as l2k
from .kernels import pq_adc as adck


def l2_batch(query, block):
    return l2k.l2_batch(query, block)


def pq_adc(lut, codes):
    return adck.pq_adc(lut, codes)


def hash_encode(query, planes):
    return hk.hash_encode(query, planes)


def pq_lut(query, codebooks):
    """ADC table: (D,), (M, K, D//M) -> (M, K). Plain jnp (fused by XLA)."""
    m, _, dsub = codebooks.shape
    qsub = query.reshape(m, 1, dsub)
    diff = codebooks - qsub
    return jnp.sum(diff * diff, axis=-1)


def page_scan(query, block, lut, codes):
    """Fused per-hop computation (paper Alg. 2 lines 20-27).

    query: (D,) f32        — the query vector
    block: (R, D) f32      — vectors of the batch of pages just read
    lut:   (M, K) f32      — the query's ADC table
    codes: (N, M) f32-int  — compressed codes of the pages' neighbors

    Returns (exact (R,), approx (N,)).
    """
    exact = l2k.l2_batch(query, block)
    approx = adck.pq_adc(lut, codes)
    return exact, approx
