"""Layer-2 model graph tests: composition, shapes, and AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


def test_pq_lut_matches_ref():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal(128, ), jnp.float32)
    cb = jnp.asarray(rng.standard_normal((16, 256, 8)), jnp.float32)
    got = model.pq_lut(q, cb)
    want = ref.pq_lut_ref(q, cb)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_page_scan_outputs_match_components():
    rng = np.random.default_rng(8)
    d, r, m, k = 96, 256, 8, 256
    q = jnp.asarray(rng.standard_normal(d), jnp.float32)
    block = jnp.asarray(rng.standard_normal((r, d)), jnp.float32)
    lut = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, k, size=(r, m)), jnp.float32)
    exact, approx = model.page_scan(q, block, lut, codes)
    np.testing.assert_allclose(exact, ref.l2_batch_ref(q, block), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(
        approx, ref.pq_adc_ref(lut, codes.astype(jnp.int32)), rtol=1e-5, atol=1e-4
    )


def test_adc_ranking_consistency():
    """PQ ADC distance through the model must rank exact reconstructions
    identically to direct distance on reconstructed vectors."""
    rng = np.random.default_rng(9)
    d, m, k = 32, 8, 16
    dsub = d // m
    cb = jnp.asarray(rng.standard_normal((m, k, dsub)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(d), jnp.float32)
    lut = model.pq_lut(q, cb)
    codes = rng.integers(0, k, size=(16, m))
    # Reconstruct vectors from codes.
    recon = np.stack([
        np.concatenate([np.asarray(cb[mm, codes[n, mm]]) for mm in range(m)])
        for n in range(16)
    ])
    exact = np.sum((recon - np.asarray(q)[None, :]) ** 2, axis=-1)
    approx = np.asarray(ref.pq_adc_ref(lut, jnp.asarray(codes, jnp.int32)))
    np.testing.assert_allclose(approx, exact, rtol=1e-3, atol=1e-3)


def test_aot_lowering_produces_hlo_text():
    """Every artifact lowers to parseable HLO text with ENTRY."""
    count = 0
    for name, lowered, meta in aot.build_artifacts():
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        assert len(text) > 200, name
        count += 1
        if count >= 4:  # lowering all ~18 is slow; spot-check the first few
            break
    assert count == 4


def test_aot_manifest_covers_required_names():
    names = [name for name, _, _ in _artifact_names()]
    for d in aot.DIMS:
        assert f"l2_batch_d{d}" in names
        assert f"hash_encode_d{d}_h{aot.HASH_BITS}" in names
        # Every dim must have at least one page_scan variant (PQ-compatible M).
        assert any(n.startswith(f"page_scan_d{d}_m") for n in names), d
    for m in aot.PQ_M:
        assert f"pq_adc_m{m}" in names


def _artifact_names():
    """Enumerate artifact metadata without lowering (fast)."""
    out = []
    for d in aot.DIMS:
        out.append((f"l2_batch_d{d}", None, None))
        out.append((f"hash_encode_d{d}_h{aot.HASH_BITS}", None, None))
        for m in aot.pq_ms(d):
            out.append((f"pq_lut_d{d}_m{m}", None, None))
            out.append((f"page_scan_d{d}_m{m}", None, None))
    for m in aot.PQ_M:
        out.append((f"pq_adc_m{m}", None, None))
    return out
