"""Layer-1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracle.

Hypothesis sweeps shapes and value distributions; fixed cases pin the exact
AOT shapes the artifacts are lowered with.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import hash_encode as hk
from compile.kernels import l2_distance as l2k
from compile.kernels import pq_adc as adck
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

SETTINGS = hypothesis.settings(
    max_examples=25, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


# ---------------------------------------------------------------- l2_batch

@hypothesis.given(
    d=st.sampled_from([8, 32, 96, 100, 128]),
    tiles=st.integers(1, 4),
    block_rows=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
@SETTINGS
def test_l2_batch_matches_ref(d, tiles, block_rows, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, d)
    block = rand(rng, tiles * block_rows, d)
    got = l2k.l2_batch(q, block, block_rows=block_rows)
    want = ref.l2_batch_ref(q, block)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_l2_batch_aot_shape():
    """Exact AOT lowering shape: D=128, R=256."""
    rng = np.random.default_rng(0)
    q = rand(rng, 128, scale=100.0)  # SIFT-scale magnitudes
    block = rand(rng, 256, 128, scale=100.0)
    got = l2k.l2_batch(q, block)
    want = ref.l2_batch_ref(q, block)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-1)


def test_l2_batch_zero_query():
    block = jnp.ones((128, 16), jnp.float32)
    got = l2k.l2_batch(jnp.zeros(16, jnp.float32), block)
    np.testing.assert_allclose(got, jnp.full((128,), 16.0), rtol=1e-6)


def test_l2_batch_identical_rows_zero_distance():
    rng = np.random.default_rng(3)
    q = rand(rng, 32)
    block = jnp.tile(q[None, :], (128, 1))
    got = l2k.l2_batch(q, block)
    np.testing.assert_allclose(got, jnp.zeros(128), atol=1e-3)


def test_l2_batch_rejects_ragged_rows():
    with pytest.raises(AssertionError):
        l2k.l2_batch(jnp.zeros(8), jnp.zeros((100, 8)))  # 100 % 128 != 0


# ------------------------------------------------------------------ pq_adc

@hypothesis.given(
    m=st.sampled_from([4, 8, 16]),
    k=st.sampled_from([16, 256]),
    tiles=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
@SETTINGS
def test_pq_adc_matches_ref(m, k, tiles, seed):
    rng = np.random.default_rng(seed)
    n = tiles * adck.DEFAULT_BLOCK_ROWS
    lut = rand(rng, m, k, scale=10.0)
    codes_i = rng.integers(0, k, size=(n, m))
    got = adck.pq_adc(lut, jnp.asarray(codes_i, jnp.float32))
    want = ref.pq_adc_ref(lut, jnp.asarray(codes_i, jnp.int32))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_pq_adc_aot_shape():
    rng = np.random.default_rng(1)
    lut = rand(rng, 16, 256, scale=100.0)
    codes = rng.integers(0, 256, size=(256, 16))
    got = adck.pq_adc(lut, jnp.asarray(codes, jnp.float32))
    want = ref.pq_adc_ref(lut, jnp.asarray(codes, jnp.int32))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-2)


def test_pq_adc_uniform_lut_gives_m_times_value():
    m, k, n = 8, 256, 128
    lut = jnp.full((m, k), 2.5, jnp.float32)
    codes = jnp.zeros((n, m), jnp.float32)
    got = adck.pq_adc(lut, codes)
    np.testing.assert_allclose(got, jnp.full((n,), 20.0), rtol=1e-6)


# ------------------------------------------------------------- hash_encode

@hypothesis.given(
    d=st.sampled_from([8, 96, 128]),
    h=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
@SETTINGS
def test_hash_encode_matches_ref(d, h, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, d)
    planes = rand(rng, h, d)
    got = hk.hash_encode(q, planes)
    want = ref.hash_encode_ref(q, planes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hash_encode_bits_are_binary():
    rng = np.random.default_rng(2)
    got = hk.hash_encode(rand(rng, 128), rand(rng, 32, 128))
    vals = set(np.asarray(got).tolist())
    assert vals <= {0.0, 1.0}


def test_hash_encode_antipodal_queries_flip_all_bits():
    rng = np.random.default_rng(4)
    q = rand(rng, 64)
    planes = rand(rng, 32, 64)
    a = np.asarray(hk.hash_encode(q, planes))
    b = np.asarray(hk.hash_encode(-q, planes))
    # proj != 0 almost surely, so bits must be complementary.
    np.testing.assert_array_equal(a + b, np.ones(32, np.float32))
