//! Recall/IO regression gate (ISSUE 9): pinned floors that fail the build
//! if navigation quality quietly degrades.
//!
//! The parity suites (`batch_search.rs`) prove batching/scheduling changes
//! are bit-identical to the sequential path — but bit-identity tests can't
//! catch a regression that changes the sequential path itself (a PQ
//! training slip, a selection-order bug, a grouping change that strands
//! neighborhoods across pages). This suite pins absolute floors instead:
//! the synthetic SiftLike workload has recall@10 ≈ 0.9 at `l = 80`
//! (`index_end_to_end.rs` asserts ≥ 0.85), so floors of 0.80 (PQ8) and
//! 0.70 (PQ4, coarser routing) leave slack for noise across I/O backends
//! while still catching any real drop. Mean I/Os per query is the latency
//! proxy — it is deterministic for a given index + params, where wall
//! clock is not.
//!
//! The floors run under both the classic per-query loop and the batched
//! pipeline (`PAGEANN_BATCH` ∈ {1, 8} equivalents), on every I/O backend
//! preference, and the final test proves the gate *can* fail by injecting
//! a result drop and requiring recall to fall below the floor.

use pageann::dataset::{DatasetKind, SynthSpec, Workload};
use pageann::engine::{
    run_workload_batched, AnnSystem, FaultSpec, OpenOptions, PageAnnIndex, WorkloadReport,
};
use pageann::layout::{BuildConfig, CvPlacement, IndexBuilder};
use pageann::metrics::QueryStats;
use pageann::vamana::VamanaParams;
use pageann::Result;
use std::path::PathBuf;

const K: usize = 10;
const L: usize = 80;
const PQ8_RECALL_FLOOR: f64 = 0.80;
const PQ4_RECALL_FLOOR: f64 = 0.70;
/// `index_end_to_end.rs` pins `mean_ios < 80` on this workload; the
/// regression gate allows headroom but still catches a blow-up.
const MEAN_IOS_CEILING: f64 = 100.0;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pageann-recall-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn workload() -> Workload {
    let spec = SynthSpec::new(DatasetKind::SiftLike, 3000).with_dim(32).with_clusters(16);
    Workload::synthesize(&spec, 40, K, 77)
}

/// Build with the e2e suite's config; `pq_k = 16` selects the nibble-packed
/// PQ4 fast-scan mode, `pq_k = 256` the byte-coded PQ8 default.
fn build_index(dir: &PathBuf, w: &Workload, pq_k: usize) {
    let cfg = BuildConfig {
        pq_m: 8,
        pq_k,
        cv_placement: CvPlacement::OnPage,
        routing_sample_frac: 0.03,
        vamana: VamanaParams { r: 16, l_build: 40, alpha: 1.2, seed: 5, nthreads: 4 },
        ..Default::default()
    };
    IndexBuilder::new(&w.base, cfg).build(dir).unwrap();
}

fn run(idx: &PageAnnIndex, w: &Workload, batch: usize) -> WorkloadReport {
    run_workload_batched(idx, &w.queries, Some(&w.gt), K, L, 4, batch)
}

fn check_floor(rep: &WorkloadReport, floor: f64, tag: &str) {
    assert_eq!(rep.summary.errors, 0, "{tag}: queries failed");
    assert!(
        rep.summary.recall >= floor,
        "{tag}: recall@{K} regressed to {:.4} (floor {floor})",
        rep.summary.recall
    );
    let ios = rep.summary.mean_ios();
    assert!(
        ios < MEAN_IOS_CEILING,
        "{tag}: mean I/Os per query regressed to {ios:.1} (ceiling {MEAN_IOS_CEILING})"
    );
}

#[test]
fn pq8_recall_floor_across_backends_and_batch_sizes() {
    let dir = tmpdir("pq8");
    let w = workload();
    build_index(&dir, &w, 256);
    // Backend preferences never fail the open (unavailable ones fall
    // back), so every row runs everywhere; the CI matrix additionally
    // pins `PAGEANN_IO` per leg, which `None` (= probe order) honors.
    for backend in [None, Some("pread"), Some("aio"), Some("uring")] {
        let idx = PageAnnIndex::open(
            &dir,
            OpenOptions {
                io_backend: backend.map(str::to_string),
                faults: FaultSpec::Off,
                ..Default::default()
            },
        )
        .unwrap();
        let tag_base = format!("pq8 pref={} backend={}", backend.unwrap_or("auto"), idx.io_backend());
        let seq = run(&idx, &w, 1);
        check_floor(&seq, PQ8_RECALL_FLOOR, &format!("{tag_base} batch=1"));
        let batched = run(&idx, &w, 8);
        check_floor(&batched, PQ8_RECALL_FLOOR, &format!("{tag_base} batch=8"));
        // Batching is bit-identical to sequential, so recall and total
        // I/Os must agree exactly — a cheap end-to-end parity pin on top
        // of the absolute floor.
        assert_eq!(
            seq.summary.recall, batched.summary.recall,
            "{tag_base}: batched recall diverged from sequential"
        );
        assert_eq!(
            seq.summary.totals.ios, batched.summary.totals.ios,
            "{tag_base}: batched total I/Os diverged from sequential"
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pq4_recall_floor_with_and_without_batching() {
    let dir = tmpdir("pq4");
    let w = workload();
    build_index(&dir, &w, 16);
    let idx = PageAnnIndex::open(
        &dir,
        OpenOptions { faults: FaultSpec::Off, ..Default::default() },
    )
    .unwrap();
    let seq = run(&idx, &w, 1);
    check_floor(&seq, PQ4_RECALL_FLOOR, "pq4 batch=1");
    let batched = run(&idx, &w, 8);
    check_floor(&batched, PQ4_RECALL_FLOOR, "pq4 batch=8");
    assert_eq!(seq.summary.recall, batched.summary.recall, "pq4: batched recall diverged");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Result-dropping wrapper: keeps only the first `keep` of every answer.
/// Simulates the class of regression the floors exist to catch (navigation
/// finding fewer of the true neighbors) without touching the index.
struct Truncating {
    inner: PageAnnIndex,
    keep: usize,
}

impl AnnSystem for Truncating {
    fn name(&self) -> String {
        "truncating".into()
    }
    fn search_one(
        &self,
        query: &[f32],
        k: usize,
        l: usize,
        stats: &mut QueryStats,
    ) -> Result<Vec<u32>> {
        let mut ids = self.inner.search_one(query, k, l, stats)?;
        ids.truncate(self.keep);
        Ok(ids)
    }
    fn memory_bytes(&self) -> usize {
        self.inner.memory_bytes()
    }
}

#[test]
fn gate_detects_injected_recall_drop() {
    // Sensitivity check: the floor must actually be able to fail. Dropping
    // half of every answer caps recall at 0.5 < 0.80, so a gate that still
    // passes here is asserting nothing.
    let dir = tmpdir("inject");
    let w = workload();
    build_index(&dir, &w, 256);
    let idx = PageAnnIndex::open(
        &dir,
        OpenOptions { faults: FaultSpec::Off, ..Default::default() },
    )
    .unwrap();
    let broken = Truncating { inner: idx, keep: K / 2 };
    for batch in [1usize, 8] {
        let rep = run_workload_batched(&broken, &w.queries, Some(&w.gt), K, L, 4, batch);
        assert_eq!(rep.summary.errors, 0);
        assert!(
            rep.summary.recall < PQ8_RECALL_FLOOR,
            "batch={batch}: injected half-result drop not detected (recall {:.4})",
            rep.summary.recall
        );
        assert!(rep.summary.recall <= 0.5 + 1e-9, "batch={batch}: truncation cap violated");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}
