//! Navigability regression: the synthetic workloads must support greedy
//! graph search from a single entry point — the property every scheme in
//! the paper depends on. Guards the dataset generator against regressions
//! toward non-navigable (isolated-blob) structure.

use pageann::dataset::{recall_at_k, DatasetKind, SynthSpec, Workload};
use pageann::vamana::{greedy_search, SearchScratch, VamanaGraph, VamanaParams};

fn greedy_recall(w: &Workload, g: &VamanaGraph, l: usize) -> f64 {
    let mut results = Vec::new();
    for qi in 0..w.queries.len() {
        let q = w.queries.get_f32(qi);
        let mut s = SearchScratch::default();
        let found = greedy_search(&w.base, &g.adj, g.medoid, &q, l, 10, &mut s);
        results.push(found.into_iter().map(|(_, id)| id).collect::<Vec<_>>());
    }
    recall_at_k(&results, &w.gt, 10)
}

#[test]
fn default_specs_are_navigable_at_full_dim() {
    let vp = VamanaParams { r: 24, l_build: 48, alpha: 1.2, seed: 0xBEEF, nthreads: 8 };
    for kind in [DatasetKind::SiftLike, DatasetKind::SpacevLike, DatasetKind::DeepLike] {
        let spec = SynthSpec::new(kind, 6_000);
        let w = Workload::synthesize(&spec, 32, 10, 0xDA7A);
        let g = VamanaGraph::build(&w.base, &vp);
        let r = greedy_recall(&w, &g, 100);
        assert!(r >= 0.9, "{}: greedy-from-medoid recall {r}", kind.name());
    }
}
