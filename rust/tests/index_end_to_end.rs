//! End-to-end: synthesize a workload, build a PageANN index on disk, open
//! it, and verify recall/IO/latency behaviour across the three §4.3
//! memory regimes.

use pageann::dataset::{DatasetKind, SynthSpec, Workload};
use pageann::engine::{run_workload, AnnSystem, OpenOptions, PageAnnIndex};
use pageann::layout::{BuildConfig, CvPlacement, IndexBuilder};
use pageann::metrics::QueryStats;
use pageann::search::{SearchParams, SearchScratch};
use pageann::vamana::VamanaParams;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("pageann-e2e-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn small_workload() -> Workload {
    let spec = SynthSpec::new(DatasetKind::SiftLike, 3000).with_dim(32).with_clusters(16);
    Workload::synthesize(&spec, 40, 10, 77)
}

fn build_cfg(cv: CvPlacement) -> BuildConfig {
    BuildConfig {
        pq_m: 8,
        cv_placement: cv,
        routing_sample_frac: 0.03,
        vamana: VamanaParams { r: 16, l_build: 40, alpha: 1.2, seed: 5, nthreads: 4 },
        ..Default::default()
    }
}

fn check_regime(tag: &str, cv: CvPlacement, min_recall: f64) {
    let w = small_workload();
    let dir = tmpdir(tag);
    let report = IndexBuilder::new(&w.base, build_cfg(cv)).build(&dir).unwrap();
    assert!(report.n_pages > 0);

    let idx = PageAnnIndex::open(&dir, OpenOptions::default()).unwrap();
    let rep = run_workload(&idx, &w.queries, Some(&w.gt), 10, 80, 4);
    assert!(
        rep.summary.recall >= min_recall,
        "{tag}: recall {} < {min_recall}",
        rep.summary.recall
    );
    // One hop = one page: mean IOs must be far below the vector-graph hop
    // count a DiskANN-style search would need (~L).
    assert!(rep.summary.mean_ios() < 80.0, "{tag}: {} IOs", rep.summary.mean_ios());
    assert!(rep.summary.mean_latency_ms() > 0.0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn recall_on_page_regime() {
    check_regime("onpage", CvPlacement::OnPage, 0.85);
}

#[test]
fn recall_hybrid_regime() {
    check_regime("hybrid", CvPlacement::Hybrid { mem_frac: 0.5 }, 0.85);
}

#[test]
fn recall_in_memory_regime() {
    check_regime("inmem", CvPlacement::InMemory, 0.85);
}

#[test]
fn in_memory_placement_shrinks_page_count() {
    let w = small_workload();
    let d1 = tmpdir("shrink-a");
    let d2 = tmpdir("shrink-b");
    let r_onpage = IndexBuilder::new(&w.base, build_cfg(CvPlacement::OnPage)).build(&d1).unwrap();
    let r_inmem = IndexBuilder::new(&w.base, build_cfg(CvPlacement::InMemory)).build(&d2).unwrap();
    // §4.3: freeing page space for vectors shrinks the page-node graph.
    assert!(
        r_inmem.n_pages < r_onpage.n_pages,
        "inmem {} !< onpage {}",
        r_inmem.n_pages,
        r_onpage.n_pages
    );
    assert!(r_inmem.capacity > r_onpage.capacity);
    std::fs::remove_dir_all(&d1).unwrap();
    std::fs::remove_dir_all(&d2).unwrap();
}

#[test]
fn warmup_cache_reduces_ios() {
    let w = small_workload();
    let dir = tmpdir("warm");
    IndexBuilder::new(&w.base, build_cfg(CvPlacement::OnPage)).build(&dir).unwrap();

    let mut idx = PageAnnIndex::open(&dir, OpenOptions::default()).unwrap();
    let before = run_workload(&idx, &w.queries, Some(&w.gt), 10, 60, 2);
    // Cache half the pages' worth of budget.
    let budget = idx.meta.n_pages * idx.meta.page_size / 2;
    idx.warmup(&w.queries, budget).unwrap();
    assert!(idx.cache_pages() > 0);
    let after = run_workload(&idx, &w.queries, Some(&w.gt), 10, 60, 2);
    assert!(
        after.summary.mean_ios() < before.summary.mean_ios() * 0.8,
        "cache didn't cut IOs: {} -> {}",
        before.summary.mean_ios(),
        after.summary.mean_ios()
    );
    assert!(after.summary.totals.cache_hits > 0);
    // Recall unchanged by caching.
    assert!((after.summary.recall - before.summary.recall).abs() < 0.05);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn larger_l_improves_recall_and_costs_more_io() {
    let w = small_workload();
    let dir = tmpdir("ltrade");
    IndexBuilder::new(&w.base, build_cfg(CvPlacement::OnPage)).build(&dir).unwrap();
    let idx = PageAnnIndex::open(&dir, OpenOptions::default()).unwrap();
    let lo = run_workload(&idx, &w.queries, Some(&w.gt), 10, 12, 2);
    let hi = run_workload(&idx, &w.queries, Some(&w.gt), 10, 150, 2);
    assert!(hi.summary.recall >= lo.summary.recall, "{} vs {}", hi.summary.recall, lo.summary.recall);
    assert!(hi.summary.mean_ios() > lo.summary.mean_ios());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn direct_search_api_reports_stats() {
    let w = small_workload();
    let dir = tmpdir("direct");
    IndexBuilder::new(&w.base, build_cfg(CvPlacement::OnPage)).build(&dir).unwrap();
    let idx = PageAnnIndex::open(&dir, OpenOptions::default()).unwrap();
    let mut scratch = SearchScratch::new();
    let mut stats = QueryStats::default();
    let q = w.queries.get_f32(0);
    let out = idx
        .search(&q, &SearchParams { k: 10, l: 64, ..Default::default() }, &mut scratch, &mut stats)
        .unwrap();
    assert_eq!(out.len(), 10);
    // Distances ascending, ids valid.
    for win in out.windows(2) {
        assert!(win[0].0 <= win[1].0);
    }
    assert!(out.iter().all(|&(_, id)| (id as usize) < w.base.len()));
    assert!(stats.ios > 0);
    assert!(stats.hops > 0);
    assert!(stats.exact_dists > 0);
    assert!(stats.approx_dists > 0);
    assert!(stats.bytes_read >= stats.ios * 4096);
    // Read amplification should be low (most of each page useful).
    assert!(stats.read_amplification() < 3.0, "{}", stats.read_amplification());
    assert_eq!(idx.name(), "PageANN");
    assert!(idx.memory_bytes() > 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn speculation_is_result_invariant() {
    // ISSUE 3 acceptance: the two-deep speculative pipeline may change
    // only WHERE page bytes come from — never the results nor the
    // algorithmic I/O count. A sim-SSD store has max_inflight_batches > 1
    // on every kernel (the 4.4 CI kernel has neither io_uring nor usable
    // AIO), so this exercises the speculation branch even where tier-1
    // otherwise runs pread-only.
    use pageann::io::SsdModel;
    use std::time::Duration;
    let w = small_workload();
    let dir = tmpdir("spec");
    IndexBuilder::new(&w.base, build_cfg(CvPlacement::OnPage)).build(&dir).unwrap();
    // Fast device model: the modeled latency is irrelevant here, only the
    // multi-batch capability that arms the speculation gate.
    let fast = SsdModel {
        base_latency: Duration::from_micros(5),
        bandwidth_bps: 1e10,
        queue_depth: 64,
    };
    let idx = PageAnnIndex::open(
        &dir,
        OpenOptions { sim_ssd: Some(fast), ..Default::default() },
    )
    .unwrap();
    let params_on = SearchParams { k: 10, l: 60, speculate: true, ..Default::default() };
    let params_off = SearchParams { speculate: false, ..params_on.clone() };
    let mut scratch = SearchScratch::new();
    let mut spec_reads = 0u64;
    for qi in 0..w.queries.len() {
        let q = w.queries.get_f32(qi);
        let mut st_on = QueryStats::default();
        let mut st_off = QueryStats::default();
        let r_on = idx.search(&q, &params_on, &mut scratch, &mut st_on).unwrap();
        let r_off = idx.search(&q, &params_off, &mut scratch, &mut st_off).unwrap();
        assert_eq!(r_on, r_off, "query {qi}: speculation changed the results");
        assert_eq!(
            st_on.ios, st_off.ios,
            "query {qi}: speculation changed the algorithmic I/O count"
        );
        assert_eq!(st_on.hops, st_off.hops, "query {qi}: speculation changed the hop count");
        assert_eq!(st_off.spec_hits + st_off.spec_wasted, 0, "speculate=false still speculated");
        spec_reads += st_on.spec_hits + st_on.spec_wasted;
    }
    assert!(spec_reads > 0, "speculation never engaged — the two-deep branch went untested");
    std::fs::remove_dir_all(&dir).unwrap();
}
