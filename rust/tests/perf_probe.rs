//! §Perf probes (run with --ignored): before/after measurements for the
//! optimization log in EXPERIMENTS.md.

use pageann::dataset::{DatasetKind, SynthSpec, Workload};
use pageann::engine::{run_workload, OpenOptions, PageAnnIndex};
use pageann::io::SsdModel;
use pageann::layout::{BuildConfig, IndexBuilder};
use pageann::search::SearchParams;
use pageann::vamana::VamanaParams;

#[test]
#[ignore]
fn perf_pipeline_on_off() {
    let spec = SynthSpec::new(DatasetKind::SiftLike, 20_000);
    let w = Workload::synthesize(&spec, 128, 10, 0xDA7A);
    let dir = std::env::temp_dir().join("pageann-perf-pipe");
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = BuildConfig {
        vamana: VamanaParams { r: 24, l_build: 48, alpha: 1.2, seed: 1, nthreads: 16 },
        ..Default::default()
    };
    IndexBuilder::new(&w.base, cfg).build(&dir).unwrap();
    for pipeline in [false, true] {
        let params = SearchParams { pipeline, ..Default::default() };
        let idx = PageAnnIndex::open(
            &dir,
            OpenOptions { sim_ssd: Some(SsdModel::default()), params, ..Default::default() },
        )
        .unwrap();
        // 3 repetitions, take the best (noise robustness).
        let mut best_ms = f64::INFINITY;
        let mut rep_keep = None;
        for _ in 0..3 {
            let rep = run_workload(&idx, &w.queries, Some(&w.gt), 10, 64, 1);
            if rep.summary.mean_latency_ms() < best_ms {
                best_ms = rep.summary.mean_latency_ms();
                rep_keep = Some(rep);
            }
        }
        let rep = rep_keep.unwrap();
        eprintln!(
            "pipeline={pipeline}: mean={:.3}ms io={:.3}ms compute={:.3}ms ios={:.1} recall={:.4}",
            best_ms,
            rep.summary.totals.io_time.as_secs_f64() * 1e3 / rep.summary.queries as f64,
            rep.summary.totals.compute_time.as_secs_f64() * 1e3 / rep.summary.queries as f64,
            rep.summary.mean_ios(),
            rep.summary.recall
        );
    }
}
