//! Cross-backend page-store conformance + stress suite (ISSUE 3).
//!
//! Runs every available backend — uring, aio, pread, sim-ssd — through
//! random out-of-order batches with multiple in-flight `PendingRead`s on
//! several threads, asserting byte-exact contents, zero slot leakage, and
//! graceful *skip* (not failure) on kernels without io_uring or AIO.
//!
//! The tier-1 CI matrix re-runs this binary once per `PAGEANN_IO` value
//! (see `ci/tier1.sh`), set **before the process starts** — no test in
//! this binary ever calls `set_var` (concurrent getenv/setenv is UB on
//! glibc, and libtest's parallel tests do hidden getenv calls, e.g.
//! `temp_dir()`); the env override is honor-checked read-only against
//! whatever the current matrix leg exported.

use pageann::io::{
    open_auto, open_with, AioPageStore, PageStore, PreadPageStore, SimSsdStore, SsdModel,
    UringPageStore,
};
use pageann::util::XorShift;
use std::path::PathBuf;
use std::time::Duration;

const PAGE: usize = 2048;
const N_PAGES: usize = 64;

fn tmpfile(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pageann-iostores-{}-{name}", std::process::id()))
}

/// Same deterministic page fill the in-crate tests use.
fn write_pages(path: &PathBuf) {
    let mut data = vec![0u8; PAGE * N_PAGES];
    for p in 0..N_PAGES {
        for (i, b) in data[p * PAGE..(p + 1) * PAGE].iter_mut().enumerate() {
            *b = ((p * 131 + i) % 251) as u8;
        }
    }
    std::fs::write(path, &data).unwrap();
}

fn expect_byte(page: u32, i: usize) -> u8 {
    ((page as usize * 131 + i) % 251) as u8
}

fn verify(ids: &[u32], bufs: &[Vec<u8>], tag: &str) {
    for (k, &p) in ids.iter().enumerate() {
        // Spot-check a few offsets per page (full scans × stress rounds
        // would dominate the suite's runtime without adding coverage).
        for i in [0usize, 1, 7, PAGE / 2, PAGE - 1] {
            assert_eq!(bufs[k][i], expect_byte(p, i), "{tag}: page {p} byte {i}");
        }
    }
}

fn mk_bufs(n: usize) -> Vec<Vec<u8>> {
    (0..n).map(|_| vec![0u8; PAGE]).collect()
}

/// Every backend that opens in this environment. Unavailable backends are
/// skipped with a note — never a failure (the CI kernel is 4.4, which has
/// neither io_uring nor necessarily AIO).
fn backends(path: &PathBuf) -> Vec<(String, Box<dyn PageStore>)> {
    let mut out: Vec<(String, Box<dyn PageStore>)> = Vec::new();
    match UringPageStore::open(path, PAGE) {
        Ok(s) => out.push(("uring".into(), Box::new(s))),
        Err(e) => eprintln!("skip uring: {e}"),
    }
    match AioPageStore::open(path, PAGE) {
        Ok(s) => out.push(("aio".into(), Box::new(s))),
        Err(e) => eprintln!("skip aio: {e}"),
    }
    out.push(("pread".into(), Box::new(PreadPageStore::open(path, PAGE).unwrap())));
    let fast = SsdModel {
        base_latency: Duration::from_micros(20),
        bandwidth_bps: 1e10,
        queue_depth: 8,
    };
    let inner = Box::new(PreadPageStore::open(path, PAGE).unwrap());
    out.push(("sim-ssd".into(), Box::new(SimSsdStore::new(inner, fast))));
    out
}

fn random_ids(rng: &mut XorShift, max_len: usize) -> Vec<u32> {
    let n = 1 + rng.next_below(max_len) as usize;
    // Duplicate-free random page set (stores may submit per-page reads
    // into distinct buffers, but unique ids keep verification simple).
    let mut ids: Vec<u32> = Vec::with_capacity(n);
    while ids.len() < n {
        let p = rng.next_below(N_PAGES) as u32;
        if !ids.contains(&p) {
            ids.push(p);
        }
    }
    ids
}

#[test]
fn conformance_random_out_of_order_batches() {
    let path = tmpfile("conf");
    write_pages(&path);
    for (name, store) in backends(&path) {
        assert_eq!(store.n_pages(), N_PAGES, "{name}");
        assert_eq!(store.page_size(), PAGE, "{name}");
        let mut rng = XorShift::new(0xC0FFEE);
        // Synchronous batches.
        for _ in 0..20 {
            let ids = random_ids(&mut rng, 8);
            let mut bufs = mk_bufs(ids.len());
            store.read_pages(&ids, &mut bufs).unwrap();
            verify(&ids, &bufs, &name);
        }
        // Three overlapping async batches, waited in rotating order.
        for round in 0..10 {
            let batches: Vec<Vec<u32>> = (0..3).map(|_| random_ids(&mut rng, 6)).collect();
            let mut pending: Vec<(usize, _)> = batches
                .iter()
                .enumerate()
                .map(|(bi, ids)| (bi, store.begin_read(ids, mk_bufs(ids.len()))))
                .collect();
            // Rotate which batch is waited first.
            while !pending.is_empty() {
                let idx = round % pending.len();
                let (bi, p) = pending.remove(idx);
                let (bufs, r) = p.wait();
                r.unwrap_or_else(|e| panic!("{name}: {e}"));
                verify(&batches[bi], &bufs, &name);
            }
        }
        // Error contract: invalid page id fails from wait() WITH buffers.
        let (back, r) = store.begin_read(&[N_PAGES as u32 + 5], mk_bufs(1)).wait();
        assert!(r.is_err(), "{name}: out-of-range read must fail");
        assert_eq!(back.len(), 1, "{name}: buffers must survive the error");
        // Empty batch is a no-op.
        let (back, r) = store.begin_read(&[], Vec::new()).wait();
        r.unwrap();
        assert!(back.is_empty());
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn stress_multi_thread_multi_inflight() {
    let path = tmpfile("stress");
    write_pages(&path);
    for (name, store) in backends(&path) {
        let store: &dyn PageStore = store.as_ref();
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let name = name.clone();
                s.spawn(move || {
                    let mut rng = XorShift::new(0x9E3779B9 ^ (t + 1));
                    for round in 0..15 {
                        // Hold several pending batches at once, then wait
                        // newest-first (fully out of submission order).
                        let batches: Vec<Vec<u32>> =
                            (0..3).map(|_| random_ids(&mut rng, 5)).collect();
                        let mut pending: Vec<_> = batches
                            .iter()
                            .map(|ids| store.begin_read(ids, mk_bufs(ids.len())))
                            .collect();
                        while let Some(p) = pending.pop() {
                            let ids = &batches[pending.len()];
                            let (bufs, r) = p.wait();
                            r.unwrap_or_else(|e| {
                                panic!("{name} t{t} round {round}: {e}")
                            });
                            verify(ids, &bufs, &name);
                        }
                        // Occasionally drop a batch without waiting — the
                        // store must complete it and stay healthy.
                        if round % 5 == 0 {
                            let ids = random_ids(&mut rng, 3);
                            let p = store.begin_read(&ids, mk_bufs(ids.len()));
                            drop(p);
                        }
                    }
                });
            }
        });
        // The store still serves correct reads after the stress.
        let ids = vec![3u32, 1, 9];
        let mut bufs = mk_bufs(3);
        store.read_pages(&ids, &mut bufs).unwrap();
        verify(&ids, &bufs, &name);
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn sim_ssd_slot_accounting_is_leak_free_under_stress() {
    let path = tmpfile("simslots");
    write_pages(&path);
    // Queue depth deliberately smaller than the combined in-flight demand:
    // the virtual-time channel model must schedule all of it (later
    // deadlines, never blocked threads) and the in-flight tracking must
    // come back to zero on every path (waits, drops without wait).
    let model = SsdModel {
        base_latency: Duration::from_micros(10),
        bandwidth_bps: 1e10,
        queue_depth: 4,
    };
    let sim = SimSsdStore::new(Box::new(PreadPageStore::open(&path, PAGE).unwrap()), model);
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let sim = &sim;
            s.spawn(move || {
                let mut rng = XorShift::new(0xABCD ^ t);
                for round in 0..12 {
                    let a = random_ids(&mut rng, 3);
                    let b = random_ids(&mut rng, 3);
                    // Two batches in flight per thread × 4 threads ≫ QD 4.
                    let pa = sim.begin_read(&a, mk_bufs(a.len()));
                    let pb = sim.begin_read(&b, mk_bufs(b.len()));
                    let (bufs_b, rb) = pb.wait();
                    rb.unwrap();
                    verify(&b, &bufs_b, "sim-b");
                    if round % 3 == 0 {
                        drop(pa); // completed by Drop, buffers discarded
                    } else {
                        let (bufs_a, ra) = pa.wait();
                        ra.unwrap();
                        verify(&a, &bufs_a, "sim-a");
                    }
                }
            });
        }
    });
    assert_eq!(sim.in_flight(), 0, "queue slots leaked under multi-batch stress");
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn backend_preferences_and_env_override() {
    let path = tmpfile("prefs");
    write_pages(&path);

    // The acceptance contract: open_with never fails for any preference on
    // any kernel — it falls down the uring → aio → pread ladder.
    for pref in [Some("uring"), Some("aio"), Some("pread"), Some("bogus"), None] {
        let store = open_with(&path, PAGE, pref)
            .unwrap_or_else(|e| panic!("open_with({pref:?}) must not fail: {e}"));
        let ids = vec![6u32, 0, 11];
        let mut bufs = mk_bufs(3);
        store.read_pages(&ids, &mut bufs).unwrap();
        verify(&ids, &bufs, &format!("pref={pref:?} ({})", store.name()));
    }

    // Env override, READ-ONLY: the CI matrix leg exported PAGEANN_IO
    // before this process started (never set_var in-process — see the
    // module docs). open_auto must honor it and still never fail.
    let env_pref = std::env::var("PAGEANN_IO").ok();
    let store = open_auto(&path, PAGE).unwrap_or_else(|e| {
        panic!("open_auto with PAGEANN_IO={env_pref:?} must not fail: {e}")
    });
    assert!(
        ["io-uring", "linux-aio", "pread"].contains(&store.name()),
        "unexpected backend {}",
        store.name()
    );
    if env_pref.as_deref() == Some("pread") {
        assert_eq!(store.name(), "pread", "explicit pread must be honored");
    }
    let mut bufs = mk_bufs(2);
    store.read_pages(&[1, 13], &mut bufs).unwrap();
    verify(&[1, 13], &bufs, "env");
    std::fs::remove_file(&path).unwrap();
}
