//! Batched-search parity suite (ISSUE 8): `search_batch` must be
//! bit-identical to the sequential path for every batch size, on every
//! I/O backend, and under permanent page loss — batching may change only
//! WHERE bytes come from (one deduplicated read per round) and how LUTs
//! are built (one subspace-major pass, aliased for duplicates), never the
//! answers.
//!
//! Everything here pins `FaultSpec::Config`/`FaultSpec::Off` explicitly,
//! so the suite is deterministic regardless of any `PAGEANN_FAULTS` the
//! CI matrix leg exports. (Transient-fault schedules depend on read
//! order, which batching legitimately changes; permanent `dead` pages
//! fail every read regardless of order, so they ARE parity-testable.)

use pageann::dataset::{DatasetKind, SynthSpec, Workload};
use pageann::engine::{AnnSystem, FaultSpec, OpenOptions, PageAnnIndex};
use pageann::io::FaultConfig;
use pageann::layout::{BuildConfig, CvPlacement, IndexBuilder};
use pageann::metrics::QueryStats;
use pageann::search::{BatchScratch, SearchParams, SearchScratch};
use pageann::vamana::VamanaParams;
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pageann-batch-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn small_workload() -> Workload {
    let spec = SynthSpec::new(DatasetKind::SiftLike, 3000).with_dim(32).with_clusters(16);
    Workload::synthesize(&spec, 24, 10, 77)
}

fn build_index(dir: &PathBuf) -> Workload {
    let w = small_workload();
    let cfg = BuildConfig {
        pq_m: 8,
        cv_placement: CvPlacement::OnPage,
        routing_sample_frac: 0.03,
        vamana: VamanaParams { r: 16, l_build: 40, alpha: 1.2, seed: 5, nthreads: 4 },
        ..Default::default()
    };
    IndexBuilder::new(&w.base, cfg).build(dir).unwrap();
    w
}

fn assert_bitwise_eq(got: &[(f32, u32)], want: &[(f32, u32)], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: result count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.1, w.1, "{tag}: id mismatch at rank {i}");
        assert_eq!(
            g.0.to_bits(),
            w.0.to_bits(),
            "{tag}: distance at rank {i} not bit-identical ({} vs {})",
            g.0,
            w.0
        );
    }
}

/// Sequential reference: one `search` per query on a fresh scratch.
fn sequential_reference(
    idx: &PageAnnIndex,
    w: &Workload,
    params: &SearchParams,
) -> (Vec<Vec<(f32, u32)>>, Vec<QueryStats>) {
    let mut scratch = SearchScratch::new();
    let mut results = Vec::new();
    let mut stats = Vec::new();
    for qi in 0..w.queries.len() {
        let q = w.queries.get_f32(qi);
        let mut st = QueryStats::default();
        results.push(idx.search(&q, params, &mut scratch, &mut st).unwrap());
        stats.push(st);
    }
    (results, stats)
}

#[test]
fn batch_is_bit_identical_to_sequential_across_backends_and_sizes() {
    let dir = tmpdir("parity");
    let w = build_index(&dir);
    let params = SearchParams { k: 10, l: 60, ..Default::default() };

    // `io_backend` preference never fails the open: unavailable backends
    // fall back, so every row runs everywhere (possibly on pread).
    for backend in [None, Some("pread"), Some("aio"), Some("uring")] {
        let idx = PageAnnIndex::open(
            &dir,
            OpenOptions {
                io_backend: backend.map(str::to_string),
                faults: FaultSpec::Off,
                ..Default::default()
            },
        )
        .unwrap();
        let tag = format!("pref={} backend={}", backend.unwrap_or("auto"), idx.io_backend());
        let (seq, seq_stats) = sequential_reference(&idx, &w, &params);

        let mut batch = BatchScratch::new();
        for bs in [1usize, 3, 8] {
            let mut qi = 0;
            while qi < w.queries.len() {
                let hi = (qi + bs).min(w.queries.len());
                let qvecs: Vec<Vec<f32>> = (qi..hi).map(|i| w.queries.get_f32(i)).collect();
                let qrefs: Vec<&[f32]> = qvecs.iter().map(|v| v.as_slice()).collect();
                let mut stats = vec![QueryStats::default(); qrefs.len()];
                let outs = idx.search_batch(&qrefs, &params, &mut batch, &mut stats);
                assert_eq!(outs.len(), qrefs.len());
                for (j, out) in outs.into_iter().enumerate() {
                    let q = qi + j;
                    let t = format!("{tag} bs={bs} q={q}");
                    let out = out.unwrap_or_else(|e| panic!("{t}: query failed: {e}"));
                    assert_bitwise_eq(&out, &seq[q], &t);
                    // Stats invariants: `ios`/`hops`/`cache_hits` keep
                    // their sequential-parity meaning; the coalescing
                    // shows up only in `batch_shared_ios`.
                    let st = &stats[j];
                    let ss = &seq_stats[q];
                    assert_eq!(st.ios, ss.ios, "{t}: ios");
                    assert_eq!(st.hops, ss.hops, "{t}: hops");
                    assert_eq!(st.cache_hits, ss.cache_hits, "{t}: cache_hits");
                    assert_eq!(st.approx_dists, ss.approx_dists, "{t}: approx_dists");
                    assert_eq!(st.exact_dists, ss.exact_dists, "{t}: exact_dists");
                    assert!(st.batch_shared_ios <= st.ios, "{t}: shared > ios");
                    assert_eq!(st.retries + st.failed_ios + st.crc_failures, 0, "{t}");
                    assert!(!st.degraded, "{t}");
                    // Phase-taxonomy invariants (ISSUE 10): the phases
                    // are disjoint sub-spans of the query's wall time,
                    // the coarse io_time is exactly the submit+wait
                    // split, and gather_wait belongs to the server
                    // executor — direct calls never charge it.
                    assert!(
                        st.phases.sum() <= st.total_time,
                        "{t}: phases ({:?}) exceed total ({:?})",
                        st.phases.sum(),
                        st.total_time
                    );
                    assert_eq!(
                        st.io_time,
                        st.phases.io_submit + st.phases.io_wait,
                        "{t}: io_time is not the io_submit+io_wait split"
                    );
                    assert_eq!(
                        st.phases.gather_wait,
                        std::time::Duration::ZERO,
                        "{t}: direct search_batch charged gather_wait"
                    );
                }
                qi = hi;
            }
        }
        // The batch scratch pools its round buffers: repeated use must
        // reach a steady pool size, like the sequential scratch.
        let sizes: Vec<usize> = (0..4)
            .map(|_| {
                let q0 = w.queries.get_f32(0);
                let q1 = w.queries.get_f32(1);
                let qrefs: Vec<&[f32]> = vec![&q0, &q1];
                let mut stats = vec![QueryStats::default(); 2];
                let _ = idx.search_batch(&qrefs, &params, &mut batch, &mut stats);
                batch.pooled_buffers()
            })
            .collect();
        assert!(
            sizes.windows(2).skip(1).all(|s| s[0] == s[1]),
            "{tag}: batch buffer pool never stabilized: {sizes:?}"
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn duplicate_heavy_batch_shares_luts_and_page_reads() {
    let dir = tmpdir("dup");
    let w = build_index(&dir);
    let idx = PageAnnIndex::open(
        &dir,
        OpenOptions { faults: FaultSpec::Off, ..Default::default() },
    )
    .unwrap();
    let params = SearchParams { k: 10, l: 60, ..Default::default() };

    let q0 = w.queries.get_f32(0);
    let q1 = w.queries.get_f32(1);
    let q2 = w.queries.get_f32(2);
    // Sequential reference per distinct query.
    let mut scratch = SearchScratch::new();
    let mut refs: Vec<Vec<(f32, u32)>> = Vec::new();
    for q in [&q0, &q1, &q2] {
        let mut st = QueryStats::default();
        refs.push(idx.search(q, &params, &mut scratch, &mut st).unwrap());
    }

    // Duplicate-heavy batch: 8 queries over 3 distinct vectors.
    let pattern: [usize; 8] = [0, 1, 0, 0, 1, 2, 2, 0];
    let distinct: [&[f32]; 3] = [q0.as_slice(), q1.as_slice(), q2.as_slice()];
    let qrefs: Vec<&[f32]> = pattern.iter().map(|&i| distinct[i]).collect();
    let mut stats = vec![QueryStats::default(); qrefs.len()];
    let mut batch = BatchScratch::new();
    let outs = idx.search_batch(&qrefs, &params, &mut batch, &mut stats);
    let (mut shared, mut reused) = (0u64, 0u64);
    for (j, out) in outs.into_iter().enumerate() {
        let out = out.unwrap();
        assert_bitwise_eq(&out, &refs[pattern[j]], &format!("dup q={j}"));
        shared += stats[j].batch_shared_ios;
        reused += stats[j].lut_reused;
    }
    assert!(shared > 0, "identical batchmates never coalesced a page read");
    assert_eq!(reused, 5, "8 queries over 3 distinct vectors must alias exactly 5 LUTs");

    // Opting out of LUT sharing must not change answers either.
    let off = SearchParams { lut_share: false, ..params.clone() };
    let mut stats = vec![QueryStats::default(); qrefs.len()];
    let outs = idx.search_batch(&qrefs, &off, &mut batch, &mut stats);
    for (j, out) in outs.into_iter().enumerate() {
        assert_bitwise_eq(&out.unwrap(), &refs[pattern[j]], &format!("dup/off q={j}"));
        assert_eq!(stats[j].lut_reused, 0, "share=off still aliased a LUT");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn trait_search_batch_matches_search_one() {
    // The engine-level API: `AnnSystem::search_batch` (id-only) must agree
    // with `search_one` for every batch size, including the batch=1 bypass
    // that routes through today's single-query path.
    let dir = tmpdir("trait");
    let w = build_index(&dir);
    let idx = PageAnnIndex::open(
        &dir,
        OpenOptions { faults: FaultSpec::Off, ..Default::default() },
    )
    .unwrap();
    let (k, l) = (10usize, 60usize);

    let mut seq: Vec<Vec<u32>> = Vec::new();
    for qi in 0..w.queries.len() {
        let q = w.queries.get_f32(qi);
        let mut st = QueryStats::default();
        seq.push(idx.search_one(&q, k, l, &mut st).unwrap());
    }
    for bs in [1usize, 3, 8] {
        let mut qi = 0;
        while qi < w.queries.len() {
            let hi = (qi + bs).min(w.queries.len());
            let qvecs: Vec<Vec<f32>> = (qi..hi).map(|i| w.queries.get_f32(i)).collect();
            let qrefs: Vec<&[f32]> = qvecs.iter().map(|v| v.as_slice()).collect();
            let mut stats = vec![QueryStats::default(); qrefs.len()];
            let outs = AnnSystem::search_batch(&idx, &qrefs, k, l, &mut stats);
            for (j, out) in outs.into_iter().enumerate() {
                assert_eq!(out.unwrap(), seq[qi + j], "bs={bs} q={}", qi + j);
            }
            qi = hi;
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn dead_pages_degrade_batchmates_independently() {
    // Permanent loss is order-independent (a dead page fails EVERY read),
    // so even under faults the batch must be bit-identical to sequential:
    // same answers, same per-query degraded flags. A degraded query must
    // never poison its batchmates.
    let dir = tmpdir("dead");
    let w = build_index(&dir);
    let probe = PageAnnIndex::open(
        &dir,
        OpenOptions { faults: FaultSpec::Off, ..Default::default() },
    )
    .unwrap();
    let n_pages = probe.meta.n_pages;
    assert!(n_pages >= 8, "workload too small to lose pages meaningfully");
    drop(probe);
    let dead: Vec<u32> = (0..n_pages as u32).step_by(4).collect();
    let faulty = PageAnnIndex::open(
        &dir,
        OpenOptions {
            faults: FaultSpec::Config(FaultConfig { dead: dead.clone(), ..Default::default() }),
            ..Default::default()
        },
    )
    .unwrap();
    let params = SearchParams { k: 10, l: 60, ..Default::default() };
    let (seq, seq_stats) = {
        let mut scratch = SearchScratch::new();
        let mut results = Vec::new();
        let mut stats = Vec::new();
        for qi in 0..w.queries.len() {
            let q = w.queries.get_f32(qi);
            let mut st = QueryStats::default();
            results.push(faulty.search(&q, &params, &mut scratch, &mut st).unwrap());
            stats.push(st);
        }
        (results, stats)
    };
    assert!(seq_stats.iter().any(|s| s.degraded), "no query ever touched a dead page");
    assert!(seq_stats.iter().any(|s| !s.degraded), "every query degraded — batchmate isolation untestable");

    let mut batch = BatchScratch::new();
    let mut total = QueryStats::default();
    let mut qi = 0;
    while qi < w.queries.len() {
        let hi = (qi + 8).min(w.queries.len());
        let qvecs: Vec<Vec<f32>> = (qi..hi).map(|i| w.queries.get_f32(i)).collect();
        let qrefs: Vec<&[f32]> = qvecs.iter().map(|v| v.as_slice()).collect();
        let mut stats = vec![QueryStats::default(); qrefs.len()];
        let outs = faulty.search_batch(&qrefs, &params, &mut batch, &mut stats);
        for (j, out) in outs.into_iter().enumerate() {
            let q = qi + j;
            let out =
                out.unwrap_or_else(|e| panic!("query {q} failed under permanent loss: {e}"));
            assert_bitwise_eq(&out, &seq[q], &format!("dead q={q}"));
            assert_eq!(
                stats[j].degraded, seq_stats[q].degraded,
                "q {q}: degraded flag diverged from sequential"
            );
            if stats[j].degraded {
                assert!(stats[j].failed_ios > 0, "q {q}: degraded without failed_ios");
            }
            total.merge(&stats[j]);
        }
        qi = hi;
    }
    assert!(total.failed_ios > 0);
    assert!(total.retries > 0, "dead pages must be retried before being dropped");
    // The per-page fault records (aggregated server-side into the
    // top-offenders table) name actual dead pages as permanent failures.
    assert!(
        total.page_faults.iter().any(|r| r.failed && dead.contains(&r.page)),
        "no page-fault record names a dead page"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
