//! SIMD kernel subsystem tests: the dispatched kernels must agree with the
//! scalar oracle (≤1e-4 relative) across dims, dtypes, and unaligned slice
//! offsets; the batched ADC must match per-code ADC; and swapping the
//! scalar scanner for the SIMD scanner must not change search results.

use pageann::dataset::{DatasetKind, Dtype, SynthSpec, VectorSet, Workload};
use pageann::distance::simd::scalar_adc4_batch;
use pageann::distance::{kernels, scalar_kernels, BatchScanner, NativeBatch, ScalarBatch};
use pageann::engine::{run_workload, OpenOptions, PageAnnIndex};
use pageann::layout::{BuildConfig, IndexBuilder};
use pageann::pq::{pack_nibbles, unpack_nibbles, AdcLut, PqCodebook};
use pageann::proptest::forall;
use pageann::util::XorShift;
use pageann::vamana::VamanaParams;

/// The dims the kernels must handle: everything below one SIMD register,
/// the three paper dims, and a large one that stresses the unrolled loops.
const DIMS: [usize; 12] = [1, 2, 3, 4, 5, 6, 7, 8, 96, 100, 128, 960];

fn assert_close(got: f32, want: f32, what: &str) {
    let tol = 1e-4 * want.abs().max(1.0);
    assert!((got - want).abs() <= tol, "{what}: dispatched {got} vs scalar {want}");
}

#[test]
fn kernels_match_scalar_all_dims_f32() {
    let ks = kernels();
    let sc = scalar_kernels();
    forall(
        "simd-f32-agreement",
        48,
        |rng| {
            let dim = DIMS[rng.next_below(DIMS.len())];
            let a: Vec<f32> = (0..dim).map(|_| rng.next_gaussian() * 20.0).collect();
            let b: Vec<f32> = (0..dim).map(|_| rng.next_gaussian() * 20.0).collect();
            (dim, a, b)
        },
        |(dim, a, b)| {
            assert_close((ks.l2sq_f32)(&a, &b), (sc.l2sq_f32)(&a, &b), &format!("l2 f32 d={dim}"));
            assert_close((ks.norm_sq_f32)(&a), (sc.norm_sq_f32)(&a), &format!("norm d={dim}"));
        },
    );
}

#[test]
fn kernels_match_scalar_all_dims_u8_i8() {
    let ks = kernels();
    let sc = scalar_kernels();
    forall(
        "simd-int-agreement",
        48,
        |rng| {
            let dim = DIMS[rng.next_below(DIMS.len())];
            let q: Vec<f32> = (0..dim).map(|_| rng.next_f32() * 255.0).collect();
            let v: Vec<u8> = (0..dim).map(|_| rng.next_below(256) as u8).collect();
            (dim, q, v)
        },
        |(dim, q, v)| {
            assert_close((ks.l2sq_f32_u8)(&q, &v), (sc.l2sq_f32_u8)(&q, &v), &format!("u8 d={dim}"));
            let vi: Vec<i8> = v.iter().map(|&x| x as i8).collect();
            assert_close((ks.l2sq_f32_i8)(&q, &vi), (sc.l2sq_f32_i8)(&q, &vi), &format!("i8 d={dim}"));
        },
    );
}

#[test]
fn kernels_handle_unaligned_slices() {
    // Page buffers hand out vector bytes at arbitrary offsets (5-byte
    // header + id table), so every kernel must accept slices that are not
    // SIMD-aligned — and the f32-bytes kernel slices that are not even
    // element-aligned.
    let ks = kernels();
    let sc = scalar_kernels();
    let mut rng = XorShift::new(0xA11);
    for &dim in &DIMS {
        let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian() * 10.0).collect();
        let v: Vec<f32> = (0..dim).map(|_| rng.next_gaussian() * 10.0).collect();
        let v_bytes: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
        for offset in 0..4usize {
            // Byte-offset f32 view (odd offsets are element-misaligned).
            let mut buf = vec![0u8; offset + v_bytes.len()];
            buf[offset..].copy_from_slice(&v_bytes);
            let got = (ks.l2sq_f32_bytes)(&q, &buf[offset..]);
            let want = (sc.l2sq_f32_bytes)(&q, &buf[offset..]);
            assert_close(got, want, &format!("f32-bytes d={dim} off={offset}"));
            let exact = (sc.l2sq_f32)(&q, &v);
            assert_close(got, exact, &format!("f32-bytes-vs-slices d={dim} off={offset}"));

            // Offset u8 view.
            let raw: Vec<u8> = (0..offset + dim).map(|_| rng.next_below(256) as u8).collect();
            let qu: Vec<f32> = (0..dim).map(|_| rng.next_f32() * 255.0).collect();
            assert_close(
                (ks.l2sq_f32_u8)(&qu, &raw[offset..]),
                (sc.l2sq_f32_u8)(&qu, &raw[offset..]),
                &format!("u8 d={dim} off={offset}"),
            );
        }
        // f32 slices offset by one element (4-byte aligned, not 32-byte).
        if dim > 1 {
            let big: Vec<f32> = (0..dim + 1).map(|_| rng.next_gaussian()).collect();
            assert_close(
                (ks.l2sq_f32)(&q[1..], &big[1..dim]),
                (sc.l2sq_f32)(&q[1..], &big[1..dim]),
                &format!("f32-shifted d={dim}"),
            );
        }
    }
}

#[test]
fn batch_scanners_agree_across_dtypes() {
    forall(
        "scanner-agreement",
        32,
        |rng| {
            let dim = DIMS[rng.next_below(DIMS.len())];
            let n = 1 + rng.next_below(40);
            let dtype = [Dtype::U8, Dtype::I8, Dtype::F32][rng.next_below(3)];
            let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian() * 30.0).collect();
            let mut set = VectorSet::new(dtype, dim, n);
            for i in 0..n {
                let v: Vec<f32> = (0..dim).map(|_| rng.next_gaussian() * 30.0).collect();
                set.set_from_f32(i, &v);
            }
            (q, set)
        },
        |(q, set)| {
            let n = set.len();
            let mut simd = vec![0f32; n];
            let mut scalar = vec![0f32; n];
            NativeBatch.scan(&q, set.as_bytes(), set.dtype(), n, &mut simd);
            ScalarBatch.scan(&q, set.as_bytes(), set.dtype(), n, &mut scalar);
            for i in 0..n {
                assert_close(simd[i], scalar[i], &format!("{:?} row {i}", set.dtype()));
            }
        },
    );
}

#[test]
fn adc_batch_matches_per_code_distance() {
    forall(
        "adc-batch-vs-single",
        32,
        |rng| {
            let m = [4usize, 8, 16, 20][rng.next_below(4)];
            let k = [16usize, 64, 256][rng.next_below(3)];
            let n = [0usize, 1, 7, 8, 9, 63, 200][rng.next_below(7)];
            let dim = m * 4;
            // Train a real codebook so the table has realistic values.
            let spec = SynthSpec::new(DatasetKind::DeepLike, 260.max(k + 4))
                .with_dim(dim)
                .with_clusters(4);
            let base = spec.generate(rng.next_u64());
            let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
            let codes: Vec<u8> = (0..n * m).map(|_| rng.next_below(k) as u8).collect();
            (base, m, q, codes, n)
        },
        |(base, m, q, codes, n)| {
            let cb = PqCodebook::train(&base, m, 4, 7);
            let mut lut = AdcLut::empty();
            cb.build_lut_into(&q, &mut lut);
            // Clamp generated code values to the trained k (k = min(256, n)).
            let codes: Vec<u8> =
                codes.iter().map(|&c| (c as usize % lut.k()) as u8).collect();
            let mut batch = vec![f32::NAN; n];
            lut.distance_batch(&codes, n, &mut batch);
            for i in 0..n {
                let single = lut.distance(&codes[i * m..(i + 1) * m]);
                assert_close(batch[i], single, &format!("adc row {i}/{n} m={m}"));
            }
        },
    );
}

/// The PQ4 fast-scan kernel's contract is *bit*-exactness against its
/// scalar oracle (integer nibble sums, shared unfused dequant), stronger
/// than the 1e-4 tolerance of the f32 kernels — so assert `to_bits`
/// equality across subspace counts (odd/even, above and below one
/// register), batch sizes (remainder tails) and arbitrary nibble values.
#[test]
fn adc4_kernel_matches_scalar_oracle_bit_for_bit() {
    let ks = kernels();
    forall(
        "adc4-bit-exact",
        64,
        |rng| {
            let m = [1usize, 2, 3, 4, 7, 8, 15, 16, 32, 64][rng.next_below(10)];
            let n = [0usize, 1, 5, 15, 16, 17, 33, 100][rng.next_below(8)];
            let cw = (m + 1) / 2;
            // Lead with a random pad so the code block starts at an
            // arbitrary (SIMD-unaligned) byte offset, as gathered scratch
            // slices do.
            let offset = rng.next_below(4);
            let qtable: Vec<u8> = (0..m * 16).map(|_| rng.next_below(256) as u8).collect();
            let codes: Vec<u8> =
                (0..offset + n * cw).map(|_| rng.next_below(256) as u8).collect();
            let scale = rng.next_f32() * 0.5 + 1e-3;
            let bias = rng.next_f32() * 100.0;
            (m, n, offset, qtable, codes, scale, bias)
        },
        |(m, n, offset, qtable, codes, scale, bias)| {
            let codes = &codes[offset..];
            let mut got = vec![f32::NAN; n];
            let mut want = vec![f32::NAN; n];
            (ks.adc4_batch)(&qtable, m, codes, n, scale, bias, &mut got);
            scalar_adc4_batch(&qtable, m, codes, n, scale, bias, &mut want);
            for i in 0..n {
                assert_eq!(
                    got[i].to_bits(),
                    want[i].to_bits(),
                    "row {i}/{n} m={m}: dispatched {} vs scalar {}",
                    got[i],
                    want[i]
                );
            }
        },
    );
}

#[test]
fn nibble_pack_unpack_roundtrip() {
    forall(
        "nibble-roundtrip",
        64,
        |rng| {
            let m = 1 + rng.next_below(64);
            let code: Vec<u8> = (0..m).map(|_| rng.next_below(16) as u8).collect();
            code
        },
        |code| {
            let m = code.len();
            let packed = pack_nibbles(&code);
            assert_eq!(packed.len(), (m + 1) / 2);
            assert_eq!(unpack_nibbles(&packed, m), code);
            // Odd m: the trailing high nibble is zero (deterministic
            // storage bytes, so page serialization is reproducible).
            if m % 2 == 1 {
                assert_eq!(packed[m / 2] >> 4, 0);
            }
        },
    );
}

/// PQ4 batched ADC equals per-code PQ4 ADC (the packed analogue of
/// `adc_batch_matches_per_code_distance`) — and both run the quantized
/// fast-scan table, so equality is exact.
#[test]
fn adc4_batch_matches_per_code_distance() {
    forall(
        "adc4-batch-vs-single",
        24,
        |rng| {
            let m = [2usize, 4, 8, 16][rng.next_below(4)];
            let n = [0usize, 1, 7, 16, 33, 100][rng.next_below(6)];
            let dim = m * 4;
            let spec = SynthSpec::new(DatasetKind::DeepLike, 300).with_dim(dim).with_clusters(4);
            let base = spec.generate(rng.next_u64());
            let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
            let codes: Vec<u8> =
                (0..n * ((m + 1) / 2)).map(|_| rng.next_below(256) as u8).collect();
            (base, m, q, codes, n)
        },
        |(base, m, q, codes, n)| {
            let cb = PqCodebook::train_with_k(&base, m, 16, 4, 7);
            assert!(cb.packed());
            let cw = cb.code_bytes();
            let mut lut = AdcLut::empty();
            cb.build_lut_into(&q, &mut lut);
            assert!(lut.is_packed());
            let mut batch = vec![f32::NAN; n];
            lut.distance_batch(&codes, n, &mut batch);
            for i in 0..n {
                let single = lut.distance(&codes[i * cw..(i + 1) * cw]);
                assert_eq!(
                    batch[i].to_bits(),
                    single.to_bits(),
                    "adc4 row {i}/{n} m={m}: batch {} vs single {single}",
                    batch[i]
                );
            }
        },
    );
}

#[test]
fn lut_reuse_is_equivalent_to_fresh_build() {
    // build_lut_into must fully overwrite previous contents (different m/k).
    let mut rng = XorShift::new(5);
    let mk_cb = |m: usize, dim: usize, seed: u64| {
        let spec = SynthSpec::new(DatasetKind::DeepLike, 300).with_dim(dim).with_clusters(4);
        PqCodebook::train(&spec.generate(seed), m, 4, seed)
    };
    let cb_big = mk_cb(16, 64, 1);
    let cb_small = mk_cb(4, 16, 2);
    let q64: Vec<f32> = (0..64).map(|_| rng.next_gaussian()).collect();
    let q16: Vec<f32> = (0..16).map(|_| rng.next_gaussian()).collect();
    let mut lut = AdcLut::empty();
    cb_big.build_lut_into(&q64, &mut lut);
    cb_small.build_lut_into(&q16, &mut lut); // shrink in place
    let fresh = cb_small.build_lut(&q16);
    assert_eq!(lut.m(), fresh.m());
    assert_eq!(lut.k(), fresh.k());
    assert_eq!(lut.table(), fresh.table());
}

/// Swapping the exact-distance scanner between the scalar oracle and the
/// dispatched SIMD kernels must leave recall identical on the synthetic
/// workload (the acceptance gate of the SIMD subsystem).
///
/// The exact-equality assert is deterministic, not flaky: the workload is
/// u8 (SIFT-like), so distances are exact integers < 2^24 and scalar/FMA
/// kernels agree bit-for-bit; and the traversal is shared (ADC runs on the
/// dispatched kernels in both configurations) so the scanned set is
/// identical by construction.
#[test]
fn scalar_and_simd_scanners_give_identical_recall() {
    let spec = SynthSpec::new(DatasetKind::SiftLike, 3000).with_dim(32).with_clusters(16);
    let w = Workload::synthesize(&spec, 40, 10, 0x51D);
    let dir = std::env::temp_dir().join(format!("pageann-simd-parity-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = BuildConfig {
        pq_m: 8,
        vamana: VamanaParams { r: 16, l_build: 40, alpha: 1.2, seed: 5, nthreads: 4 },
        ..Default::default()
    };
    IndexBuilder::new(&w.base, cfg).build(&dir).unwrap();

    let open = |scanner: Option<Box<dyn BatchScanner>>| {
        PageAnnIndex::open(&dir, OpenOptions { scanner, ..Default::default() }).unwrap()
    };
    let simd_idx = open(None); // default = dispatched kernels
    let scalar_idx = open(Some(Box::new(ScalarBatch)));

    let rep_simd = run_workload(&simd_idx, &w.queries, Some(&w.gt), 10, 48, 4);
    let rep_scalar = run_workload(&scalar_idx, &w.queries, Some(&w.gt), 10, 48, 4);
    assert!(
        (rep_simd.summary.recall - rep_scalar.summary.recall).abs() < 1e-9,
        "recall diverged: simd {} vs scalar {}",
        rep_simd.summary.recall,
        rep_scalar.summary.recall
    );
    // The traversal is driven by ADC estimates, which both configurations
    // share — so the I/O pattern must be identical too.
    assert_eq!(rep_simd.summary.totals.ios, rep_scalar.summary.totals.ios);
    assert!(rep_simd.summary.recall > 0.5, "sanity: search must actually work");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// End-to-end PQ4 acceptance gate: a nibble-packed index (k=16 codebooks,
/// half the inline-code bytes per page, fast-scan ADC) must hold recall@10
/// within 2 points of the PQ8 build on the synthetic benchmark. The exact
/// rescoring of scanned page vectors bounds how much ADC coarseness can
/// cost — PQ4 only steers traversal.
#[test]
fn pq4_recall_within_two_points_of_pq8() {
    let spec = SynthSpec::new(DatasetKind::SiftLike, 3000).with_dim(32).with_clusters(16);
    let w = Workload::synthesize(&spec, 40, 10, 0x9D4);
    let base_dir = std::env::temp_dir().join(format!("pageann-pq4-parity-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base_dir);
    let build = |pq_k: usize, sub: &str| {
        let dir = base_dir.join(sub);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = BuildConfig {
            pq_m: 8,
            pq_k,
            vamana: VamanaParams { r: 16, l_build: 40, alpha: 1.2, seed: 5, nthreads: 4 },
            ..Default::default()
        };
        IndexBuilder::new(&w.base, cfg).build(&dir).unwrap();
        PageAnnIndex::open(&dir, OpenOptions::default()).unwrap()
    };
    let idx8 = build(256, "pq8");
    let idx4 = build(16, "pq4");
    assert_eq!(idx8.meta.code_bytes(), 8);
    assert_eq!(idx4.meta.code_bytes(), 4, "PQ4 index must store nibble-packed codes");
    let rep8 = run_workload(&idx8, &w.queries, Some(&w.gt), 10, 64, 4);
    let rep4 = run_workload(&idx4, &w.queries, Some(&w.gt), 10, 64, 4);
    assert!(rep8.summary.recall > 0.5, "sanity: PQ8 search must work ({})", rep8.summary.recall);
    assert!(rep4.summary.recall > 0.5, "sanity: PQ4 search must work ({})", rep4.summary.recall);
    assert!(
        rep4.summary.recall >= rep8.summary.recall - 0.02,
        "PQ4 recall {} more than 2 points below PQ8 {}",
        rep4.summary.recall,
        rep8.summary.recall
    );
    std::fs::remove_dir_all(&base_dir).unwrap();
}
