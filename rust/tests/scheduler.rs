//! Adaptive batch-scheduler suite (ISSUE 9): deterministic, clock-driven
//! tests of the gather-window policy, plus end-to-end checks that the new
//! scheduling and caching knobs never change answers.
//!
//! The window logic is pure arithmetic over caller-supplied timestamps
//! ([`ArrivalTracker`] never reads a clock), so every trajectory here is
//! exact — no sleeps, no tolerance bands. The wire-parity and LUT-cache
//! tests then pin the end-to-end invariants: `--gather-us` (fixed mode)
//! produces the same deterministic frames as the adaptive default, and the
//! cross-tick LUT cache is invisible in results while visible in stats.

use pageann::dataset::{DatasetKind, SynthSpec, Workload};
use pageann::engine::{
    AnnSystem, ArrivalTracker, BatchConfig, FaultSpec, GatherPolicy, MonotonicClock, OpenOptions,
    PageAnnIndex, QueryClient, QueryServer, TickClock, STAT_HIST_NAMES,
};
use pageann::layout::{BuildConfig, CvPlacement, IndexBuilder};
use pageann::metrics::QueryStats;
use pageann::search::{BatchScratch, SearchParams};
use pageann::vamana::VamanaParams;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Hand-stepped [`TickClock`]: tests advance time explicitly, so EWMA
/// trajectories and window sizes are exact rather than timing-dependent.
struct ManualClock {
    now: AtomicU64,
}

impl ManualClock {
    fn new() -> Self {
        Self { now: AtomicU64::new(0) }
    }
    fn advance(&self, us: u64) {
        self.now.fetch_add(us, Ordering::SeqCst);
    }
}

impl TickClock for ManualClock {
    fn now_us(&self) -> u64 {
        self.now.load(Ordering::SeqCst)
    }
}

// ---------------------------------------------------------------------
// Deterministic window-policy tests
// ---------------------------------------------------------------------

#[test]
fn lone_query_waits_under_ten_micros() {
    // The acceptance bound: a lone query under the adaptive policy must
    // wait < 10µs for batchmates that are not coming. With no arrival
    // history the window is exactly zero.
    let clock = ManualClock::new();
    let mut arrivals = ArrivalTracker::new();
    arrivals.note_arrival(clock.now_us()); // first arrival only anchors
    let policy = GatherPolicy::Adaptive { max: Duration::from_micros(200) };
    let w = policy.window(&arrivals, 8);
    assert!(w < Duration::from_micros(10), "lone query would wait {w:?}");
    assert_eq!(w, Duration::ZERO);
}

#[test]
fn slow_arrivals_collapse_window_to_zero() {
    // Arrivals slower than the cap: waiting the whole cap buys at most one
    // batchmate, so the adaptive window collapses to zero.
    let clock = ManualClock::new();
    let mut arrivals = ArrivalTracker::new();
    for _ in 0..5 {
        arrivals.note_arrival(clock.now_us());
        clock.advance(1_000); // 1ms apart >> 200µs cap
    }
    let policy = GatherPolicy::Adaptive { max: Duration::from_micros(200) };
    assert_eq!(policy.window(&arrivals, 8), Duration::ZERO);
}

#[test]
fn burst_grows_window_toward_cap() {
    // A steady 10µs-apart burst: the EWMA converges to 10, so the window
    // asks for (batch_max − 1) × 10µs — under the cap, it is exact.
    let clock = ManualClock::new();
    let mut arrivals = ArrivalTracker::new();
    for _ in 0..50 {
        arrivals.note_arrival(clock.now_us());
        clock.advance(10);
    }
    let ewma = arrivals.ewma_us().expect("samples folded");
    assert!((ewma - 10.0).abs() < 1e-9, "steady stream must converge exactly, got {ewma}");
    let policy = GatherPolicy::Adaptive { max: Duration::from_micros(200) };
    assert_eq!(policy.window(&arrivals, 8), Duration::from_micros(70));
    // A tighter cap truncates the same demand.
    let capped = GatherPolicy::Adaptive { max: Duration::from_micros(50) };
    assert_eq!(capped.window(&arrivals, 8), Duration::from_micros(50));
    // batch_max = 1 never waits: there is no batchmate to gather.
    assert_eq!(policy.window(&arrivals, 1), Duration::ZERO);
}

#[test]
fn ewma_reacts_to_regime_change() {
    // 1ms-apart trickle (window 0), then a 5µs burst: the EWMA must move
    // below the cap within a handful of samples and the window reopen.
    let clock = ManualClock::new();
    let mut arrivals = ArrivalTracker::new();
    for _ in 0..10 {
        arrivals.note_arrival(clock.now_us());
        clock.advance(1_000);
    }
    let policy = GatherPolicy::Adaptive { max: Duration::from_micros(200) };
    assert_eq!(policy.window(&arrivals, 8), Duration::ZERO);
    for _ in 0..30 {
        arrivals.note_arrival(clock.now_us());
        clock.advance(5);
    }
    let w = policy.window(&arrivals, 8);
    assert!(w > Duration::ZERO, "window never reopened after burst began");
    assert!(w <= Duration::from_micros(200), "window exceeded its cap: {w:?}");
}

#[test]
fn fixed_policy_ignores_arrival_history() {
    // `--gather-us` pins the historical behavior exactly: the constant
    // passes through untouched no matter what the tracker has seen.
    let fixed = GatherPolicy::Fixed(Duration::from_micros(200));
    let mut arrivals = ArrivalTracker::new();
    assert_eq!(fixed.window(&arrivals, 8), Duration::from_micros(200));
    let clock = ManualClock::new();
    for _ in 0..20 {
        arrivals.note_arrival(clock.now_us());
        clock.advance(3);
    }
    assert_eq!(fixed.window(&arrivals, 8), Duration::from_micros(200));
    assert_eq!(fixed.window(&arrivals, 1), Duration::from_micros(200));
}

#[test]
fn monotonic_clock_is_nondecreasing() {
    let clock = MonotonicClock::new();
    let mut last = clock.now_us();
    for _ in 0..1000 {
        let now = clock.now_us();
        assert!(now >= last, "clock went backwards: {now} < {last}");
        last = now;
    }
}

// ---------------------------------------------------------------------
// End-to-end: wire parity and the cross-tick LUT cache
// ---------------------------------------------------------------------

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pageann-sched-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn build_index(dir: &PathBuf) -> Workload {
    let spec = SynthSpec::new(DatasetKind::SiftLike, 3000).with_dim(32).with_clusters(16);
    let w = Workload::synthesize(&spec, 16, 10, 77);
    let cfg = BuildConfig {
        pq_m: 8,
        cv_placement: CvPlacement::OnPage,
        routing_sample_frac: 0.03,
        vamana: VamanaParams { r: 16, l_build: 40, alpha: 1.2, seed: 5, nthreads: 4 },
        ..Default::default()
    };
    IndexBuilder::new(&w.base, cfg).build(dir).unwrap();
    w
}

fn open_index(dir: &PathBuf, lut_cache_entries: usize) -> PageAnnIndex {
    PageAnnIndex::open(
        dir,
        OpenOptions { faults: FaultSpec::Off, lut_cache_entries, ..Default::default() },
    )
    .unwrap()
}

#[test]
fn fixed_mode_wire_parity_with_adaptive_default() {
    // `--gather-us` (fixed) vs the adaptive default: scheduling may change
    // only *when* a tick runs, never what it answers. Every deterministic
    // field of every frame — result ids, per-query ios, and the
    // deterministic stats counters — must agree between the two servers.
    let dir = tmpdir("parity");
    let w = build_index(&dir);
    let spawn = |gather: GatherPolicy| {
        let idx = open_index(&dir, 0);
        let dim = idx.meta.dim;
        let sys: Arc<dyn AnnSystem> = Arc::new(idx);
        QueryServer::bind("127.0.0.1:0", sys, dim)
            .unwrap()
            .with_batching(BatchConfig { batch_max: 4, gather, executors: 1 })
            .spawn()
            .unwrap()
    };
    let fixed = spawn(GatherPolicy::Fixed(Duration::from_micros(200)));
    let adaptive = spawn(GatherPolicy::Adaptive { max: Duration::from_micros(200) });

    let mut cf = QueryClient::connect(&fixed.addr).unwrap();
    let mut ca = QueryClient::connect(&adaptive.addr).unwrap();
    for qi in 0..w.queries.len() {
        let q = w.queries.get_f32(qi);
        let rf = cf.query(&q, 10, 60).unwrap();
        let ra = ca.query(&q, 10, 60).unwrap();
        assert_eq!(rf.ids, ra.ids, "q {qi}: ids diverged between fixed and adaptive");
        assert_eq!(rf.ios, ra.ios, "q {qi}: ios diverged between fixed and adaptive");
    }
    let sf = cf.stats(8).unwrap();
    let sa = ca.stats(8).unwrap();
    for (name, f, a) in [
        ("queries", sf.queries, sa.queries),
        ("errors", sf.errors, sa.errors),
        ("total_ios", sf.total_ios, sa.total_ios),
        ("retries", sf.retries, sa.retries),
        ("failed_ios", sf.failed_ios, sa.failed_ios),
        ("crc_failures", sf.crc_failures, sa.crc_failures),
        ("degraded", sf.degraded, sa.degraded),
        ("lut_cache_hits", sf.lut_cache_hits, sa.lut_cache_hits),
    ] {
        assert_eq!(f, a, "stats field {name} diverged between fixed and adaptive");
    }
    assert_eq!(sf.queries, w.queries.len() as u64);
    fixed.stop();
    adaptive.stop();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn lut_cache_is_invisible_in_results_and_visible_in_stats() {
    // Cross-tick recurrence: the same duplicate-heavy batch submitted
    // twice. Cache off: every tick rebuilds (in-batch aliasing only).
    // Cache on: tick 1 misses and publishes, tick 2 hits for every query
    // whose bits recur — with bit-identical results throughout.
    let dir = tmpdir("lutcache");
    let w = build_index(&dir);
    let params = SearchParams { k: 10, l: 60, ..Default::default() };
    let q0 = w.queries.get_f32(0);
    let q1 = w.queries.get_f32(1);
    let q2 = w.queries.get_f32(2);
    let pattern: [&[f32]; 6] = [&q0, &q1, &q0, &q2, &q1, &q0];

    let run_tick = |idx: &PageAnnIndex, batch: &mut BatchScratch| {
        let mut stats = vec![QueryStats::default(); pattern.len()];
        let outs = idx.search_batch(&pattern, &params, batch, &mut stats);
        let results: Vec<Vec<(f32, u32)>> = outs.into_iter().map(|o| o.unwrap()).collect();
        (results, stats)
    };

    let off = open_index(&dir, 0);
    assert!(off.lut_cache_stats().is_none(), "entries=0 must not construct a cache");
    let mut batch_off = BatchScratch::new();
    let (ref1, st_off1) = run_tick(&off, &mut batch_off);
    let (ref2, st_off2) = run_tick(&off, &mut batch_off);
    assert_eq!(ref1.len(), ref2.len());
    for (a, b) in ref1.iter().zip(ref2.iter()) {
        assert_eq!(a, b, "cache-off ticks disagree with themselves");
    }
    let off_hits: u64 = st_off1.iter().chain(st_off2.iter()).map(|s| s.lut_cache_hits).sum();
    assert_eq!(off_hits, 0, "cache off must never report hits");

    let on = open_index(&dir, 8);
    let mut batch_on = BatchScratch::new();
    let (tick1, st1) = run_tick(&on, &mut batch_on);
    let (tick2, st2) = run_tick(&on, &mut batch_on);
    for (j, (got, want)) in tick1.iter().chain(tick2.iter()).zip(ref1.iter().cycle()).enumerate() {
        assert_eq!(got.len(), want.len(), "q {j}: result count");
        for (rank, ((gd, gi), (wd, wi))) in got.iter().zip(want.iter()).enumerate() {
            assert_eq!(gi, wi, "q {j} rank {rank}: id changed by the LUT cache");
            assert_eq!(
                gd.to_bits(),
                wd.to_bits(),
                "q {j} rank {rank}: distance not bit-identical under the LUT cache"
            );
        }
    }
    // Tick 1: all 6 queries miss (3 distinct builds + 3 in-arena aliases).
    let hits1: u64 = st1.iter().map(|s| s.lut_cache_hits).sum();
    assert_eq!(hits1, 0, "first tick cannot hit an empty cache");
    assert_eq!(st1.iter().map(|s| s.lut_reused).sum::<u64>(), 3);
    // Tick 2: every query's bits recur → all 6 hit; nothing is rebuilt or
    // aliased because nothing is built at all.
    let hits2: u64 = st2.iter().map(|s| s.lut_cache_hits).sum();
    assert_eq!(hits2, 6, "second tick must be served entirely from the cache");
    assert_eq!(st2.iter().map(|s| s.lut_reused).sum::<u64>(), 0);
    let cs = on.lut_cache_stats().expect("cache constructed");
    assert_eq!(cs.entries, 3, "3 distinct bit patterns resident");
    assert_eq!(cs.hits, 6);
    assert_eq!(cs.misses, 6, "6 lookups on the cold tick missed");
    assert_eq!(cs.evictions, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn stats_frame_carries_full_histogram_taxonomy() {
    // ISSUE 10: the PANT stats frame must carry every histogram named in
    // STAT_HIST_NAMES, in wire order — arrival gaps, gather occupancy,
    // total latency, and one histogram per search phase. Sequential
    // queries over one connection make every count deterministic, and the
    // per-phase means must sum to no more than the total-latency mean
    // (each phase is a sub-interval of the query's wall time).
    let dir = tmpdir("hists");
    let w = build_index(&dir);
    let idx = open_index(&dir, 0);
    let dim = idx.meta.dim;
    let sys: Arc<dyn AnnSystem> = Arc::new(idx);
    let handle = QueryServer::bind("127.0.0.1:0", sys, dim)
        .unwrap()
        .with_batching(BatchConfig {
            batch_max: 4,
            gather: GatherPolicy::Fixed(Duration::ZERO),
            executors: 1,
        })
        .spawn()
        .unwrap();
    let mut c = QueryClient::connect(&handle.addr).unwrap();
    let n = 6usize;
    for qi in 0..n {
        let q = w.queries.get_f32(qi);
        let resp = c.query(&q, 10, 60).unwrap();
        assert!(!resp.ids.is_empty(), "q {qi}: empty result");
    }
    let snap = c.stats(8).unwrap();
    assert_eq!(snap.queries, n as u64);
    assert_eq!(snap.errors, 0);
    assert_eq!(
        snap.hists.iter().map(|(name, _)| name.as_str()).collect::<Vec<_>>(),
        STAT_HIST_NAMES.to_vec(),
        "stats frame must carry every histogram in wire order"
    );
    let total = *snap.hist("total_us").expect("total_us histogram");
    assert_eq!(total.count, n as u64);
    assert!(total.max > 0.0, "queries took nonzero wall time");
    assert!(total.p50 <= total.p90 && total.p90 <= total.p99 && total.p99 <= total.p999);
    // Sequential queries drain one per tick: one occupancy sample per
    // tick, one inter-arrival gap per adjacent enqueue pair (the first
    // arrival only anchors the tracker).
    let occ = snap.hist("gather_occupancy").expect("gather_occupancy histogram");
    assert_eq!(occ.count, n as u64);
    assert!(occ.max >= 1.0, "occupancy max below one query per tick");
    assert_eq!(snap.hist("arrival_us").expect("arrival_us histogram").count, (n - 1) as u64);
    // Every phase histogram saw every query; zero-duration phases still
    // land in bucket 0, so counts stay equal across the taxonomy.
    let mut phase_mean_sum = 0.0;
    for &name in &STAT_HIST_NAMES[3..] {
        let ph = snap.hist(name).unwrap_or_else(|| panic!("missing phase histogram {name}"));
        assert_eq!(ph.count, n as u64, "phase {name} missed a query");
        phase_mean_sum += ph.mean;
    }
    assert!(
        phase_mean_sum <= total.mean * 1.001 + 1.0,
        "phase means ({phase_mean_sum:.1}us) exceed total mean ({:.1}us)",
        total.mean
    );
    handle.stop();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn lut_cache_hits_flow_through_server_stats_frame() {
    // Full wire path: two concurrent identical queries per round so the
    // executor forms a real batch (the batch=1 bypass routes through the
    // sequential path, which never consults the cache). Round 1 populates;
    // round 2 must report cross-tick hits in the PANT frame.
    let dir = tmpdir("lutwire");
    let w = build_index(&dir);
    let idx = open_index(&dir, 8);
    let dim = idx.meta.dim;
    let sys: Arc<dyn AnnSystem> = Arc::new(idx);
    let handle = QueryServer::bind("127.0.0.1:0", sys, dim)
        .unwrap()
        .with_batching(BatchConfig {
            batch_max: 2,
            gather: GatherPolicy::Fixed(Duration::from_secs(2)),
            executors: 1,
        })
        .spawn()
        .unwrap();
    let addr = handle.addr;
    let q = w.queries.get_f32(0);
    let round = |tag: &str| {
        std::thread::scope(|s| {
            for _ in 0..2 {
                let qv = q.clone();
                s.spawn(move || {
                    let mut c = QueryClient::connect(&addr).unwrap();
                    let resp = c.query(&qv, 10, 60).unwrap();
                    assert!(!resp.ids.is_empty(), "{tag}: empty result");
                });
            }
        });
    };
    round("round1");
    round("round2");
    let mut c = QueryClient::connect(&addr).unwrap();
    let snap = c.stats(8).unwrap();
    assert_eq!(snap.queries, 4);
    assert_eq!(snap.errors, 0);
    // Round 1's pair shares in-batch (1 alias); round 2's pair hits the
    // cross-tick cache (2 hits) — but if the two clients of a round ever
    // land in separate ticks the split shifts, so assert the invariant
    // that must hold either way: the recurring query was served from the
    // cache at least once, and no query both hit and aliased.
    assert!(
        snap.lut_cache_hits >= 2,
        "identical queries across ticks never hit the cache (hits={})",
        snap.lut_cache_hits
    );
    assert!(
        snap.lut_cache_hits + snap.lut_reused <= 3,
        "hits ({}) + aliases ({}) exceed the 3 non-building queries",
        snap.lut_cache_hits,
        snap.lut_reused
    );
    handle.stop();
    std::fs::remove_dir_all(&dir).unwrap();
}
