//! Property-based tests over the core invariants: page serde roundtrip,
//! remap bijectivity, grouping partition, candidate-set ordering, PQ/LUT
//! consistency, routing probe correctness, distance-kernel agreement.

use pageann::dataset::{DatasetKind, Dtype, SynthSpec, VectorSet};
use pageann::distance::{l2sq_f32, l2sq_query, BatchScanner, NativeBatch};
use pageann::layout::{IdRemap, PageRef, PageWriter};
use pageann::pagegraph::{group_into_pages, GroupingParams};
use pageann::pq::{unpack_nibbles, LutArena, PqCodebook, PqEncoder};
use pageann::proptest::{default_cases, forall, gen_dim, gen_near_duplicates, gen_vec};
use pageann::routing::RoutingIndex;
use pageann::search::CandidateSet;
use pageann::util::XorShift;
use pageann::vamana::{VamanaGraph, VamanaParams};

#[test]
fn prop_distance_kernels_agree_across_dtypes() {
    forall(
        "distance-dtype-agreement",
        default_cases(),
        |rng| {
            let dim = gen_dim(rng);
            let q = gen_vec(rng, dim, 50.0);
            let v = gen_vec(rng, dim, 50.0);
            (dim, q, v)
        },
        |(dim, q, v)| {
            // Quantize v into each dtype and compare the dispatcher against
            // direct f32 math on the quantized values.
            for dtype in [Dtype::U8, Dtype::I8, Dtype::F32] {
                let mut set = VectorSet::new(dtype, dim, 1);
                set.set_from_f32(0, &v);
                let got = l2sq_query(&q, set.view(0));
                let want = l2sq_f32(&q, &set.get_f32(0));
                let tol = 1e-3 * want.max(1.0);
                assert!((got - want).abs() <= tol, "{dtype:?}: {got} vs {want}");
            }
        },
    );
}

#[test]
fn prop_batch_scanner_matches_pointwise() {
    forall(
        "batch-scan-pointwise",
        default_cases(),
        |rng| {
            let dim = gen_dim(rng);
            let n = 1 + rng.next_below(40);
            let q = gen_vec(rng, dim, 10.0);
            let mut set = VectorSet::new(Dtype::F32, dim, n);
            for i in 0..n {
                let v = gen_vec(rng, dim, 10.0);
                set.set_from_f32(i, &v);
            }
            (q, set)
        },
        |(q, set)| {
            let n = set.len();
            let mut out = vec![0f32; n];
            NativeBatch.scan(&q, set.as_bytes(), set.dtype(), n, &mut out);
            for i in 0..n {
                let want = l2sq_query(&q, set.view(i));
                assert!((out[i] - want).abs() <= 1e-3 * want.max(1.0));
            }
        },
    );
}

#[test]
fn prop_page_serde_roundtrip() {
    forall(
        "page-roundtrip",
        default_cases(),
        |rng| {
            let stride = [8usize, 32, 96, 128][rng.next_below(4)];
            // Code *storage* widths, including the odd nibble-packed
            // strides a PQ4 build produces (⌈m/2⌉ for odd m).
            let m = [3usize, 4, 5, 8, 16][rng.next_below(5)];
            let page_size = [2048usize, 4096][rng.next_below(2)];
            let n_vecs = 1 + rng.next_below(12);
            let n_nbrs = rng.next_below(30);
            let vectors: Vec<(u32, Vec<u8>)> = (0..n_vecs)
                .map(|_| {
                    (rng.next_u64() as u32, (0..stride).map(|_| rng.next_below(256) as u8).collect())
                })
                .collect();
            let neighbors: Vec<(u32, Option<Vec<u8>>)> = (0..n_nbrs)
                .map(|_| {
                    let id = rng.next_u64() as u32;
                    let code = if rng.next_f32() < 0.6 {
                        Some((0..m).map(|_| rng.next_below(256) as u8).collect())
                    } else {
                        None
                    };
                    (id, code)
                })
                .collect();
            (stride, m, page_size, vectors, neighbors)
        },
        |(stride, m, page_size, vectors, neighbors)| {
            let mut w = PageWriter {
                page_size,
                vec_stride: stride,
                code_bytes: m,
                checksum: true,
                vectors: vectors.iter().map(|(id, v)| (*id, v.as_slice())).collect(),
                neighbors: neighbors.iter().map(|(id, c)| (*id, c.as_deref())).collect(),
            };
            w.truncate_to_fit();
            if !w.fits() {
                return; // vectors alone exceed the page; builder never does this
            }
            let kept = w.neighbors.len();
            let mut buf = vec![0u8; page_size];
            w.serialize_into(&mut buf).unwrap();
            assert!(PageRef::verify_checksum(&buf));
            let p = PageRef::parse_verified(&buf, stride, m).unwrap();
            assert_eq!(p.n_vecs(), vectors.len());
            assert_eq!(p.n_nbrs(), kept);
            for (i, (oid, v)) in vectors.iter().enumerate() {
                assert_eq!(p.orig_id(i), *oid);
                assert_eq!(p.vector(i), v.as_slice());
            }
            for (j, (nid, code)) in neighbors.iter().take(kept).enumerate() {
                assert_eq!(p.nbr_id(j), *nid);
                assert_eq!(p.nbr_code(j), code.as_deref());
            }
            assert!(p.used_bytes() <= page_size);
        },
    );
}

#[test]
fn prop_remap_bijective_and_page_stable() {
    forall(
        "remap-bijection",
        default_cases(),
        |rng| {
            let n = 5 + rng.next_below(200);
            let cap = 1 + rng.next_below(8);
            // Random partition into pages of ≤ cap.
            let mut ids: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut ids);
            let mut pages = Vec::new();
            let mut it = ids.into_iter().peekable();
            while it.peek().is_some() {
                let take = 1 + rng.next_below(cap);
                pages.push(it.by_ref().take(take).collect::<Vec<u32>>());
            }
            (n, cap, pages)
        },
        |(n, cap, pages)| {
            let r = IdRemap::from_pages(&pages, cap, n);
            for orig in 0..n as u32 {
                let new = r.to_new(orig);
                assert_eq!(r.to_orig(new), orig);
                let page = r.page_of(new) as usize;
                assert!(pages[page].contains(&orig));
            }
        },
    );
}

#[test]
fn prop_candidate_set_total_order() {
    forall(
        "candidate-order",
        default_cases(),
        |rng| {
            let cap = 1 + rng.next_below(32);
            let n = rng.next_below(200);
            let items: Vec<(f32, u32)> =
                (0..n).map(|i| (rng.next_f32(), i as u32)).collect();
            (cap, items)
        },
        |(cap, items)| {
            let mut c = CandidateSet::new(cap);
            for &(d, id) in &items {
                c.push(d, id);
            }
            // Pops come out in non-decreasing distance order and are the
            // cap smallest distances seen.
            let mut popped = Vec::new();
            while let Some(id) = c.pop_closest_unvisited() {
                popped.push(id);
            }
            assert!(popped.len() <= cap);
            let dist_of = |id: u32| items[id as usize].0;
            for w in popped.windows(2) {
                assert!(dist_of(w[0]) <= dist_of(w[1]));
            }
            if !items.is_empty() && !popped.is_empty() {
                let mut sorted: Vec<f32> = items.iter().map(|&(d, _)| d).collect();
                sorted.sort_by(|a, b| a.total_cmp(b));
                // The closest item overall must have been popped first.
                assert_eq!(dist_of(popped[0]), sorted[0]);
            }
        },
    );
}

#[test]
fn prop_pq_adc_equals_decoded_distance() {
    forall(
        "pq-adc-consistency",
        24, // training is expensive; fewer cases
        |rng| {
            let dim = [16usize, 32][rng.next_below(2)];
            let m = [4usize, 8][rng.next_below(2)];
            let n = 300;
            let spec = SynthSpec::new(DatasetKind::DeepLike, n).with_dim(dim).with_clusters(5);
            let base = spec.generate(rng.next_u64());
            let q = gen_vec(rng, dim, 1.0);
            (base, m, q)
        },
        |(base, m, q)| {
            let cb = PqCodebook::train(&base, m, 6, 9);
            let enc = PqEncoder::new(&cb);
            let lut = cb.build_lut(&q);
            for i in [0usize, 7, 150, 299] {
                let code = enc.encode(&base.get_f32(i));
                let adc = lut.distance(&code);
                let decoded = cb.decode(&code);
                let exact = l2sq_f32(&q, &decoded);
                assert!(
                    (adc - exact).abs() <= 1e-2 * exact.max(1.0),
                    "vector {i}: adc {adc} vs decoded-exact {exact}"
                );
            }
        },
    );
}

#[test]
fn prop_pq4_adc_tracks_decoded_distance_within_quant_step() {
    // The PQ4 fast-scan path quantizes the per-query LUT to u8, so its ADC
    // may differ from the exact table sum by at most m rounding steps of
    // scale/2 — on top of the PQ approximation itself. Also pins the
    // pack → store → unpack identity against the unpacked encoder output.
    forall(
        "pq4-adc-consistency",
        16, // training is expensive; fewer cases
        |rng| {
            let dim = [16usize, 32][rng.next_below(2)];
            let m = [4usize, 8][rng.next_below(2)];
            let n = 300;
            let spec = SynthSpec::new(DatasetKind::DeepLike, n).with_dim(dim).with_clusters(5);
            let base = spec.generate(rng.next_u64());
            let q = gen_vec(rng, dim, 1.0);
            (base, m, q)
        },
        |(base, m, q)| {
            let cb = PqCodebook::train_with_k(&base, m, 16, 6, 9);
            assert!(cb.packed());
            assert_eq!(cb.code_bytes(), (m + 1) / 2);
            let enc = PqEncoder::new(&cb);
            let lut = cb.build_lut(&q);
            for i in [0usize, 7, 150, 299] {
                let v = base.get_f32(i);
                let code = enc.encode(&v);
                let stored = enc.encode_packed(&v);
                assert_eq!(unpack_nibbles(&stored, m), code);
                let adc = lut.distance(&stored);
                let decoded = cb.decode(&code);
                let exact = l2sq_f32(&q, &decoded);
                let bound = 0.5 * lut.q4_scale() * m as f32 + 2e-2 * exact.max(1.0);
                assert!(
                    (adc - exact).abs() <= bound,
                    "vector {i}: adc4 {adc} vs decoded-exact {exact} (bound {bound})"
                );
            }
        },
    );
}

#[test]
fn prop_lossy_lut_sharing_stays_within_adc_bound() {
    // `lut_share_threshold < 1.0` (the explicitly lossy opt-in) lets a
    // near-duplicate query score through an earlier batchmate's ADC table.
    // The substitution error is analytically bounded: for queries a, b and
    // any reconstruction x,
    //   |d_a(x) − d_b(x)| = |⟨a−b, a+b−2x⟩| ≤ ‖a−b‖ · (‖a‖ + ‖b‖ + 2‖x‖).
    // Every aliased lookup must land inside that bound (plus the PQ4 u8
    // table-quantization step when packed, and f32 accumulation slack) on
    // randomized jittered batches — replayable via PAGEANN_PROP_SEED.
    forall(
        "lossy-lut-share-bound",
        12, // training is expensive; fewer cases
        |rng| {
            let dim = [16usize, 32][rng.next_below(2)];
            let m = [4usize, 8][rng.next_below(2)];
            let pq4 = rng.next_below(2) == 1;
            let spec = SynthSpec::new(DatasetKind::DeepLike, 300).with_dim(dim).with_clusters(5);
            let base = spec.generate(rng.next_u64());
            let batch = gen_near_duplicates(rng, dim, 6, 1.0, 1e-4);
            (base, m, pq4, batch)
        },
        |(base, m, pq4, batch)| {
            let cb = if pq4 {
                PqCodebook::train_with_k(&base, m, 16, 6, 9)
            } else {
                PqCodebook::train(&base, m, 6, 9)
            };
            let enc = PqEncoder::new(&cb);
            let refs: Vec<&[f32]> = batch.iter().map(|v| v.as_slice()).collect();
            let mut arena = LutArena::new();
            arena.set_share(true, 0.999);
            cb.build_luts_into(&refs, &mut arena);
            // A 1e-4 relative jitter clears a 0.999 cosine screen by
            // orders of magnitude: the batch must collapse onto one table.
            assert!(
                (1..batch.len()).all(|i| arena.reused(i)),
                "near-duplicates failed to alias under the lossy policy"
            );

            let norm = |v: &[f32]| {
                v.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt() as f32
            };
            let dist = |a: &[f32], b: &[f32]| {
                a.iter()
                    .zip(b)
                    .map(|(&x, &y)| (x as f64 - y as f64).powi(2))
                    .sum::<f64>()
                    .sqrt() as f32
            };
            let max_norm = batch.iter().map(|q| norm(q)).fold(0f32, f32::max);
            let dmax = batch.iter().map(|q| dist(&batch[0], q)).fold(0f32, f32::max);
            for qi in 1..batch.len() {
                let own = cb.build_lut(&batch[qi]);
                // Whichever batchmate owns the shared table, it is within
                // dmax of the base, so ‖owner − q_qi‖ ≤ dmax + ‖q_0 − q_qi‖.
                let delta = dmax + dist(&batch[0], &batch[qi]);
                for i in [0usize, 7, 150, 299] {
                    let v = base.get_f32(i);
                    let unpacked = enc.encode(&v);
                    let code = if cb.packed() { enc.encode_packed(&v) } else { unpacked.clone() };
                    let shared_d = arena.lut(qi).distance(&code);
                    let own_d = own.distance(&code);
                    let x_norm = norm(&cb.decode(&unpacked));
                    let quant = if cb.packed() {
                        0.5 * (arena.lut(qi).q4_scale() + own.q4_scale()) * m as f32
                    } else {
                        0.0
                    };
                    let bound = delta * (2.0 * max_norm + 2.0 * x_norm)
                        + quant
                        + 1e-3 * own_d.abs().max(1.0);
                    assert!(
                        (shared_d - own_d).abs() <= bound,
                        "q {qi} vec {i}: shared-table ADC {shared_d} vs own {own_d} \
                         exceeds bound {bound}"
                    );
                }
            }
            // The exact (default) policy must keep jittered queries apart:
            // bit keying, so nothing lossy happens unless asked for.
            let mut exact = LutArena::new();
            exact.set_share(true, 1.0);
            cb.build_luts_into(&refs, &mut exact);
            assert!(exact.built() >= 2, "distinct bit patterns aliased under the exact policy");
        },
    );
}

#[test]
fn prop_grouping_partitions_any_graph() {
    forall(
        "grouping-partition",
        12,
        |rng| {
            let n = 100 + rng.next_below(400);
            let cap = 1 + rng.next_below(10);
            let hops = 1 + rng.next_below(3);
            let spec = SynthSpec::new(DatasetKind::SiftLike, n).with_dim(16).with_clusters(4);
            let base = spec.generate(rng.next_u64());
            (base, cap, hops, rng.next_u64())
        },
        |(base, cap, hops, seed)| {
            let g = VamanaGraph::build(
                &base,
                &VamanaParams { r: 8, l_build: 16, alpha: 1.2, seed: 1, nthreads: 2 },
            );
            let pages =
                group_into_pages(&base, &g, &GroupingParams { capacity: cap, hops, seed });
            let mut seen = vec![false; base.len()];
            for p in &pages {
                assert!(!p.is_empty() && p.len() <= cap);
                for &v in p {
                    assert!(!seen[v as usize], "duplicate {v}");
                    seen[v as usize] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "not a partition");
        },
    );
}

#[test]
fn prop_routing_probe_returns_sampled_ids_only() {
    forall(
        "routing-membership",
        24,
        |rng| {
            let n = 200 + rng.next_below(500);
            let bits = 4 + rng.next_below(28);
            let frac = 0.05 + rng.next_f64() * 0.4;
            let spec = SynthSpec::new(DatasetKind::DeepLike, n).with_dim(12).with_clusters(4);
            (spec.generate(rng.next_u64()), bits, frac, rng.next_u64())
        },
        |(base, bits, frac, seed)| {
            let idx = RoutingIndex::build(&base, frac, bits, seed);
            let sampled: std::collections::HashSet<u32> =
                idx.buckets.values().flatten().copied().collect();
            assert_eq!(sampled.len(), idx.n_sampled);
            let mut rng = XorShift::new(seed ^ 1);
            for _ in 0..10 {
                let q = base.get_f32(rng.next_below(base.len()));
                for id in idx.entry_points(&q, 2, 16) {
                    assert!(sampled.contains(&id), "non-sampled id {id} returned");
                }
            }
            // Radius-0 self probe: a sampled vector must find its own
            // bucket (its code is its bucket key).
            let &any = sampled.iter().next().unwrap();
            let q = base.get_f32(any as usize);
            let hits = idx.entry_points(&q, 0, usize::MAX);
            assert!(hits.contains(&any));
        },
    );
}
