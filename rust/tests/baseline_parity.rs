//! Cross-scheme comparisons on one shared workload: the architectural
//! ordering claims of the paper must hold at test scale.

use pageann::baselines::{DiskAnnIndex, DiskAnnLike, SpannLike, StarlingLike};
use pageann::dataset::{DatasetKind, SynthSpec, Workload};
use pageann::engine::{run_workload, tune_to_recall, AnnSystem, OpenOptions, PageAnnIndex};
use pageann::io::SsdModel;
use pageann::layout::{BuildConfig, IndexBuilder};
use pageann::vamana::VamanaParams;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("pageann-parity-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn vamana() -> VamanaParams {
    VamanaParams { r: 16, l_build: 40, alpha: 1.2, seed: 5, nthreads: 4 }
}

fn workload() -> Workload {
    let spec = SynthSpec::new(DatasetKind::SiftLike, 4000).with_dim(32).with_clusters(16);
    Workload::synthesize(&spec, 40, 10, 99)
}

/// PageANN needs fewer I/Os than DiskANN at the same recall — the paper's
/// central claim (Table 3's Mean I/Os column).
#[test]
fn pageann_beats_diskann_on_ios_at_equal_recall() {
    let w = workload();
    let d1 = tmpdir("pa");
    let d2 = tmpdir("da");

    let cfg = BuildConfig { pq_m: 8, vamana: vamana(), ..Default::default() };
    IndexBuilder::new(&w.base, cfg).build(&d1).unwrap();
    let pa = PageAnnIndex::open(&d1, OpenOptions::default()).unwrap();

    let da_idx = DiskAnnIndex::build(&w.base, &vamana(), 8, 4096, &d2).unwrap();
    let da = DiskAnnLike::open(da_idx, 5).unwrap();

    let (_, rep_pa) = tune_to_recall(&pa, &w.queries, &w.gt, 10, 0.9, 4);
    let (_, rep_da) = tune_to_recall(&da, &w.queries, &w.gt, 10, 0.9, 4);
    assert!(rep_pa.summary.recall >= 0.88, "pageann recall {}", rep_pa.summary.recall);
    assert!(rep_da.summary.recall >= 0.88, "diskann recall {}", rep_da.summary.recall);
    assert!(
        rep_pa.summary.mean_ios() < rep_da.summary.mean_ios(),
        "pageann {} IOs !< diskann {} IOs",
        rep_pa.summary.mean_ios(),
        rep_da.summary.mean_ios()
    );
    // And read amplification must be near 1 vs well above 1 (Table 1).
    let amp_pa = rep_pa.summary.totals.read_amplification();
    let amp_da = rep_da.summary.totals.read_amplification();
    assert!(amp_pa < 1.5, "pageann amp {amp_pa}");
    assert!(amp_da > amp_pa * 1.5, "diskann amp {amp_da} vs pageann {amp_pa}");

    std::fs::remove_dir_all(&d1).unwrap();
    std::fs::remove_dir_all(&d2).unwrap();
}

/// Under the NVMe timing model, fewer I/Os must translate to lower
/// latency (Fig. 7's ordering), not just fewer syscalls.
#[test]
fn pageann_latency_beats_diskann_under_ssd_model() {
    let w = workload();
    let d1 = tmpdir("pa-sim");
    let d2 = tmpdir("da-sim");
    let model = SsdModel::default();

    let cfg = BuildConfig { pq_m: 8, vamana: vamana(), ..Default::default() };
    IndexBuilder::new(&w.base, cfg).build(&d1).unwrap();
    let pa = PageAnnIndex::open(
        &d1,
        OpenOptions { sim_ssd: Some(model.clone()), ..Default::default() },
    )
    .unwrap();
    let da_idx = DiskAnnIndex::build(&w.base, &vamana(), 8, 4096, &d2).unwrap();
    let da = DiskAnnLike::open(da_idx, 5).unwrap().with_sim_ssd(model);

    let (_, rep_pa) = tune_to_recall(&pa, &w.queries, &w.gt, 10, 0.9, 4);
    let (_, rep_da) = tune_to_recall(&da, &w.queries, &w.gt, 10, 0.9, 4);
    assert!(
        rep_pa.summary.mean_latency_ms() < rep_da.summary.mean_latency_ms(),
        "pageann {}ms !< diskann {}ms",
        rep_pa.summary.mean_latency_ms(),
        rep_da.summary.mean_latency_ms()
    );
    // And I/O must dominate both (Fig. 2's >90% claim holds loosely here).
    assert!(rep_pa.summary.io_fraction() > 0.5, "{}", rep_pa.summary.io_fraction());
    assert!(rep_da.summary.io_fraction() > 0.5, "{}", rep_da.summary.io_fraction());

    std::fs::remove_dir_all(&d1).unwrap();
    std::fs::remove_dir_all(&d2).unwrap();
}

/// All five schemes return *correct* neighbors — same ground truth, high
/// recall, valid original ids.
#[test]
fn all_schemes_agree_on_easy_queries() {
    let w = workload();
    let base_dir = tmpdir("agree");

    let mut systems: Vec<Box<dyn AnnSystem>> = Vec::new();
    {
        let d = base_dir.join("pa");
        IndexBuilder::new(&w.base, BuildConfig { pq_m: 8, vamana: vamana(), ..Default::default() })
            .build(&d)
            .unwrap();
        systems.push(Box::new(PageAnnIndex::open(&d, OpenOptions::default()).unwrap()));
    }
    {
        let d = base_dir.join("da");
        let idx = DiskAnnIndex::build(&w.base, &vamana(), 8, 4096, &d).unwrap();
        systems.push(Box::new(DiskAnnLike::open(idx, 5).unwrap()));
    }
    {
        let d = base_dir.join("st");
        systems.push(Box::new(
            StarlingLike::build(&w.base, &vamana(), 8, 4096, &d, 5).unwrap(),
        ));
    }
    {
        let d = base_dir.join("sp");
        systems.push(Box::new(SpannLike::build(&w.base, 64, 1.5, 4096, &d, 4).unwrap()));
    }

    for sys in &systems {
        let rep = run_workload(sys.as_ref(), &w.queries, Some(&w.gt), 10, 120, 4);
        assert!(
            rep.summary.recall >= 0.85,
            "{} recall {}",
            sys.name(),
            rep.summary.recall
        );
        for ids in &rep.results {
            assert!(ids.iter().all(|&id| (id as usize) < w.base.len()), "{}", sys.name());
            let set: std::collections::HashSet<_> = ids.iter().collect();
            assert_eq!(set.len(), ids.len(), "{} returned duplicates", sys.name());
        }
    }
    std::fs::remove_dir_all(&base_dir).unwrap();
}
