//! Integration: AOT artifacts (python/jax/pallas → HLO text) load, compile
//! and execute through the PJRT runtime, and agree numerically with the
//! native rust distance backend.
//!
//! Requires `make artifacts` to have run; tests skip (with a loud message)
//! when the artifacts directory is absent so `cargo test` stays runnable in
//! a fresh checkout.

use pageann::dataset::Dtype;
use pageann::distance::{BatchScanner, NativeBatch, XlaBatch};
use pageann::runtime::{execute_f32, execute_f32_multi, ArtifactSet, XlaRuntime};
use pageann::util::XorShift;
use std::path::Path;

fn artifacts() -> Option<(ArtifactSet, XlaRuntime)> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let arts = match ArtifactSet::load(&dir) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("SKIP: {e}");
            return None;
        }
    };
    // Without the `xla` feature the runtime is a stub whose constructor
    // errors; skip rather than fail even when artifacts are present.
    match XlaRuntime::cpu() {
        Ok(rt) => Some((arts, rt)),
        Err(e) => {
            eprintln!("SKIP: {e}");
            None
        }
    }
}

#[test]
fn l2_batch_artifact_matches_native() {
    let Some((arts, rt)) = artifacts() else { return };
    assert!(rt.device_count() >= 1);

    for &dim in &[96usize, 100, 128] {
        let xla = XlaBatch::load(&rt, &arts, dim, 1).unwrap();
        let rows = xla.rows();
        let mut rng = XorShift::new(dim as u64);
        let query: Vec<f32> = (0..dim).map(|_| rng.next_gaussian() * 10.0).collect();
        // Raw u8 block (SIFT-like) — exercises dtype decode in the backend.
        let n = rows + rows / 2; // force a split across two artifact calls
        let block: Vec<u8> = (0..n * dim).map(|_| rng.next_below(256) as u8).collect();

        let mut got = vec![0f32; n];
        xla.scan(&query, &block, Dtype::U8, n, &mut got);
        let mut want = vec![0f32; n];
        NativeBatch.scan(&query, &block, Dtype::U8, n, &mut want);
        for i in 0..n {
            let tol = 1e-3 * want[i].max(1.0);
            assert!(
                (got[i] - want[i]).abs() <= tol,
                "dim={dim} row {i}: xla {} vs native {}",
                got[i],
                want[i]
            );
        }
    }
}

#[test]
fn pq_adc_artifact_matches_reference() {
    let Some((arts, rt)) = artifacts() else { return };
    let art = arts.get("pq_adc_m16").unwrap();
    let m = art.meta_usize("m").unwrap();
    let k = art.meta_usize("k").unwrap();
    let rows = art.meta_usize("rows").unwrap();
    let exe = rt.load_hlo_text(&art.file).unwrap();

    let mut rng = XorShift::new(7);
    let lut: Vec<f32> = (0..m * k).map(|_| rng.next_f32() * 100.0).collect();
    let codes_int: Vec<usize> = (0..rows * m).map(|_| rng.next_below(k)).collect();
    let codes_f: Vec<f32> = codes_int.iter().map(|&c| c as f32).collect();

    let got = execute_f32(
        &exe,
        &[(&lut, &[m as i64, k as i64]), (&codes_f, &[rows as i64, m as i64])],
    )
    .unwrap();
    assert_eq!(got.len(), rows);
    for r in 0..rows {
        let want: f32 = (0..m).map(|s| lut[s * k + codes_int[r * m + s]]).sum();
        assert!((got[r] - want).abs() <= 1e-2 * want.max(1.0), "row {r}: {} vs {want}", got[r]);
    }
}

#[test]
fn hash_encode_artifact_matches_native_signs() {
    let Some((arts, rt)) = artifacts() else { return };
    let art = arts.get("hash_encode_d128_h32").unwrap();
    let dim = art.meta_usize("dim").unwrap();
    let bits = art.meta_usize("bits").unwrap();
    let exe = rt.load_hlo_text(&art.file).unwrap();

    let mut rng = XorShift::new(17);
    let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
    let planes: Vec<f32> = (0..bits * dim).map(|_| rng.next_gaussian()).collect();
    let got = execute_f32(&exe, &[(&q, &[dim as i64]), (&planes, &[bits as i64, dim as i64])])
        .unwrap();
    assert_eq!(got.len(), bits);
    for b in 0..bits {
        let dot: f32 = planes[b * dim..(b + 1) * dim].iter().zip(&q).map(|(p, x)| p * x).sum();
        let want = if dot > 0.0 { 1.0 } else { 0.0 };
        assert_eq!(got[b], want, "bit {b} (dot={dot})");
    }
}

#[test]
fn page_scan_fused_artifact_returns_both_outputs() {
    let Some((arts, rt)) = artifacts() else { return };
    let art = arts.get("page_scan_d128_m16").unwrap();
    let (dim, rows, m, k) = (
        art.meta_usize("dim").unwrap(),
        art.meta_usize("rows").unwrap(),
        art.meta_usize("m").unwrap(),
        art.meta_usize("k").unwrap(),
    );
    let exe = rt.load_hlo_text(&art.file).unwrap();

    let mut rng = XorShift::new(23);
    let q: Vec<f32> = (0..dim).map(|_| rng.next_f32()).collect();
    let block: Vec<f32> = (0..rows * dim).map(|_| rng.next_f32()).collect();
    let lut: Vec<f32> = (0..m * k).map(|_| rng.next_f32()).collect();
    let codes_int: Vec<usize> = (0..rows * m).map(|_| rng.next_below(k)).collect();
    let codes: Vec<f32> = codes_int.iter().map(|&c| c as f32).collect();

    let outs = execute_f32_multi(
        &exe,
        &[
            (&q, &[dim as i64]),
            (&block, &[rows as i64, dim as i64]),
            (&lut, &[m as i64, k as i64]),
            (&codes, &[rows as i64, m as i64]),
        ],
        2,
    )
    .unwrap();
    assert_eq!(outs[0].len(), rows);
    assert_eq!(outs[1].len(), rows);
    // Spot-check both outputs against scalar math.
    for r in [0usize, rows / 2, rows - 1] {
        let exact: f32 = (0..dim).map(|j| {
            let d = block[r * dim + j] - q[j];
            d * d
        }).sum();
        assert!((outs[0][r] - exact).abs() <= 1e-3 * exact.max(1.0));
        let adc: f32 = (0..m).map(|s| lut[s * k + codes_int[r * m + s]]).sum();
        assert!((outs[1][r] - adc).abs() <= 1e-3 * adc.max(1.0));
    }
}
