//! Fault-matrix suite (ISSUE 6): every page-store backend against the
//! deterministic fault injector, then the full engine against transient
//! and permanent faults.
//!
//! The contract under test: transient faults (EIO-then-recover, bit flips
//! the CRC tail catches) must be invisible in the *results* — only the
//! fault accounting in `QueryStats` may change — while permanent faults
//! (dead pages) degrade the traversal gracefully: queries complete, the
//! damage is reported via `failed_ios`/`degraded`, and no buffer leaks
//! from the scratch pool on any path.
//!
//! Everything here pins `FaultSpec::Config`/`FaultSpec::Off` explicitly,
//! so the suite is deterministic regardless of any `PAGEANN_FAULTS` the
//! CI matrix leg exports for the *other* test binaries.

use pageann::dataset::{DatasetKind, SynthSpec, Workload};
use pageann::engine::{FaultSpec, OpenOptions, PageAnnIndex};
use pageann::io::{
    AioPageStore, FaultConfig, FaultStore, PageStore, PreadPageStore, SimSsdStore, SsdModel,
    UringPageStore,
};
use pageann::layout::{BuildConfig, CvPlacement, IndexBuilder};
use pageann::metrics::QueryStats;
use pageann::search::{SearchParams, SearchScratch};
use pageann::vamana::VamanaParams;
use std::path::PathBuf;
use std::time::Duration;

const PAGE: usize = 2048;
const N_PAGES: usize = 32;

fn tmppath(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pageann-faultmx-{tag}-{}", std::process::id()))
}

fn write_pages(path: &PathBuf) {
    let mut data = vec![0u8; PAGE * N_PAGES];
    for p in 0..N_PAGES {
        for (i, b) in data[p * PAGE..(p + 1) * PAGE].iter_mut().enumerate() {
            *b = ((p * 131 + i) % 251) as u8;
        }
    }
    std::fs::write(path, &data).unwrap();
}

fn expect_byte(page: u32, i: usize) -> u8 {
    ((page as usize * 131 + i) % 251) as u8
}

fn mk_bufs(n: usize) -> Vec<Vec<u8>> {
    (0..n).map(|_| vec![0u8; PAGE]).collect()
}

/// Every backend that opens in this environment (unavailable ones skip
/// with a note, as in the io_stores conformance suite).
fn backends(path: &PathBuf) -> Vec<(String, Box<dyn PageStore>)> {
    let mut out: Vec<(String, Box<dyn PageStore>)> = Vec::new();
    match UringPageStore::open(path, PAGE) {
        Ok(s) => out.push(("uring".into(), Box::new(s))),
        Err(e) => eprintln!("skip uring: {e}"),
    }
    match AioPageStore::open(path, PAGE) {
        Ok(s) => out.push(("aio".into(), Box::new(s))),
        Err(e) => eprintln!("skip aio: {e}"),
    }
    out.push(("pread".into(), Box::new(PreadPageStore::open(path, PAGE).unwrap())));
    let fast = SsdModel {
        base_latency: Duration::from_micros(10),
        bandwidth_bps: 1e10,
        queue_depth: 8,
    };
    let inner = Box::new(PreadPageStore::open(path, PAGE).unwrap());
    out.push(("sim-ssd".into(), Box::new(SimSsdStore::new(inner, fast))));
    out
}

#[test]
fn injected_faults_conform_on_every_backend() {
    let path = tmppath("conf");
    write_pages(&path);

    // fail-first: the first read of every page errors, the second
    // succeeds byte-exact — on the sync and the async path.
    for (name, inner) in backends(&path) {
        let s = FaultStore::new(inner, FaultConfig { fail_first: 1, ..Default::default() });
        let ids = vec![3u32, 1, 7];
        let mut bufs = mk_bufs(3);
        assert!(s.read_pages(&ids, &mut bufs).is_err(), "{name}: first reads must fail");
        s.read_pages(&ids, &mut bufs).unwrap_or_else(|e| panic!("{name}: retry failed: {e}"));
        for (k, &p) in ids.iter().enumerate() {
            for i in [0usize, 7, PAGE - 1] {
                assert_eq!(bufs[k][i], expect_byte(p, i), "{name}: page {p} byte {i}");
            }
        }
        // Owned-buffer contract on the injected-error async path.
        let (back, r) = s.begin_read(&[9, 4], mk_bufs(2)).wait();
        assert!(r.is_err(), "{name}: fresh pages must fail their first async read");
        assert_eq!(back.len(), 2, "{name}: buffers lost on the injected-error path");
        let (back, r) = s.begin_read(&[9, 4], mk_bufs(2)).wait();
        r.unwrap_or_else(|e| panic!("{name}: async retry failed: {e}"));
        assert_eq!(back[0][1], expect_byte(9, 1), "{name}");
        assert_eq!(back[1][1], expect_byte(4, 1), "{name}");
    }

    // Dead pages fail every attempt; healthy neighbors keep working.
    for (name, inner) in backends(&path) {
        let s = FaultStore::new(inner, FaultConfig { dead: vec![5], ..Default::default() });
        for _ in 0..3 {
            assert!(s.read_pages(&[5], &mut mk_bufs(1)).is_err(), "{name}: dead page read ok");
            let mut bufs = mk_bufs(1);
            s.read_pages(&[6], &mut bufs).unwrap();
            assert_eq!(bufs[0][0], expect_byte(6, 0), "{name}");
        }
    }

    // Corruption faults succeed quietly: exactly one flipped bit, or a
    // zeroed tail half, with the head intact.
    for (name, inner) in backends(&path) {
        let s = FaultStore::new(inner, FaultConfig { flip_every: 1, ..Default::default() });
        let mut bufs = mk_bufs(1);
        s.read_pages(&[2], &mut bufs).unwrap();
        let wrong: u32 = bufs[0]
            .iter()
            .enumerate()
            .map(|(i, &b)| (b ^ expect_byte(2, i)).count_ones())
            .sum();
        assert_eq!(wrong, 1, "{name}: flip_every=1 must flip exactly one bit");
    }
    for (name, inner) in backends(&path) {
        let s = FaultStore::new(inner, FaultConfig { torn_every: 1, ..Default::default() });
        let mut bufs = mk_bufs(1);
        s.read_pages(&[2], &mut bufs).unwrap();
        assert!(bufs[0][PAGE / 2..].iter().all(|&b| b == 0), "{name}: tail must be torn");
        assert_eq!(bufs[0][3], expect_byte(2, 3), "{name}: head must be intact");
    }

    std::fs::remove_file(&path).unwrap();
}

fn small_workload() -> Workload {
    let spec = SynthSpec::new(DatasetKind::SiftLike, 2500).with_dim(24).with_clusters(12);
    Workload::synthesize(&spec, 25, 10, 99)
}

fn build_index(dir: &PathBuf) {
    let w = small_workload();
    let cfg = BuildConfig {
        pq_m: 8,
        cv_placement: CvPlacement::OnPage,
        routing_sample_frac: 0.03,
        vamana: VamanaParams { r: 16, l_build: 40, alpha: 1.2, seed: 5, nthreads: 4 },
        ..Default::default()
    };
    IndexBuilder::new(&w.base, cfg).build(dir).unwrap();
}

/// Fast sim-SSD so `max_inflight_batches > 1` arms the two-deep pipeline:
/// the fault paths must be exercised on the speculative branch too, even
/// where tier-1 CI otherwise runs pread-only.
fn fast_ssd() -> SsdModel {
    SsdModel {
        base_latency: Duration::from_micros(5),
        bandwidth_bps: 1e10,
        queue_depth: 64,
    }
}

fn open_with_faults(dir: &PathBuf, faults: FaultSpec) -> PageAnnIndex {
    PageAnnIndex::open(
        dir,
        OpenOptions { sim_ssd: Some(fast_ssd()), faults, ..Default::default() },
    )
    .unwrap()
}

#[test]
fn transient_faults_leave_results_identical_and_are_counted() {
    // ISSUE 6 acceptance: with transient EIO and periodic bit flips the
    // run completes with no panics, every corruption is detected, retries
    // land in QueryStats::retries, and the results match the fault-free
    // run whenever no page is permanently lost.
    let w = small_workload();
    let dir = tmppath("transient");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    build_index(&dir);

    let clean = open_with_faults(&dir, FaultSpec::Off);
    // fail_first=1 fails the FIRST read of every page then recovers —
    // a deterministic full-coverage transient-EIO schedule; flip_every
    // corrupts periodically, which only the CRC tail can catch. Both are
    // always recoverable, so no query may degrade.
    let faulty = open_with_faults(
        &dir,
        FaultSpec::Config(FaultConfig {
            seed: 11,
            fail_first: 1,
            flip_every: 53,
            ..Default::default()
        }),
    );

    let params = SearchParams { k: 10, l: 60, ..Default::default() };
    let mut scratch_c = SearchScratch::new();
    let mut scratch_f = SearchScratch::new();
    let mut total = QueryStats::default();
    for qi in 0..w.queries.len() {
        let q = w.queries.get_f32(qi);
        let mut st_c = QueryStats::default();
        let mut st_f = QueryStats::default();
        let r_c = clean.search(&q, &params, &mut scratch_c, &mut st_c).unwrap();
        let r_f = faulty.search(&q, &params, &mut scratch_f, &mut st_f).unwrap();
        assert_eq!(r_c, r_f, "query {qi}: recovered faults changed the results");
        assert!(!st_f.degraded, "query {qi}: recoverable faults must not degrade");
        assert_eq!(st_f.failed_ios, 0, "query {qi}");
        assert_eq!(st_c.retries + st_c.crc_failures, 0, "clean run saw faults");
        // Phase taxonomy holds under injected faults (ISSUE 10): recovery
        // work lands inside the same disjoint spans, so the sum stays
        // bounded by wall time and the coarse io_time stays exactly the
        // submit+wait split. gather_wait belongs to the server executor.
        assert!(
            st_f.phases.sum() <= st_f.total_time,
            "query {qi}: phases ({:?}) exceed total ({:?})",
            st_f.phases.sum(),
            st_f.total_time
        );
        assert_eq!(
            st_f.io_time,
            st_f.phases.io_submit + st_f.phases.io_wait,
            "query {qi}: io_time split broken under transient faults"
        );
        assert_eq!(st_f.phases.gather_wait, Duration::ZERO, "query {qi}: direct call gathered");
        total.merge(&st_f);
    }
    assert!(total.retries > 0, "fail-first EIOs never triggered a retry");
    assert!(total.crc_failures > 0, "bit flips were never detected by the CRC");

    // Pool-leak check: repeating one query must reach a steady pool size —
    // the retry/recovery paths may not strand or duplicate buffers.
    let q = w.queries.get_f32(0);
    let mut sizes = Vec::new();
    for _ in 0..6 {
        let mut st = QueryStats::default();
        faulty.search(&q, &params, &mut scratch_f, &mut st).unwrap();
        sizes.push(scratch_f.pooled_buffers());
    }
    assert!(
        sizes.windows(2).skip(1).all(|w| w[0] == w[1]),
        "pool size never stabilized: {sizes:?}"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn dead_pages_degrade_traversal_without_panic() {
    let w = small_workload();
    let dir = tmppath("dead");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    build_index(&dir);

    let probe = open_with_faults(&dir, FaultSpec::Off);
    let n_pages = probe.meta.n_pages;
    assert!(n_pages >= 8, "workload too small to lose pages meaningfully");
    // Permanently kill every 4th page: enough loss that searches must hit
    // it, not so much that traversal collapses.
    let dead: Vec<u32> = (0..n_pages as u32).step_by(4).collect();
    let faulty = open_with_faults(
        &dir,
        FaultSpec::Config(FaultConfig { dead, ..Default::default() }),
    );

    let params = SearchParams { k: 10, l: 60, ..Default::default() };
    let mut scratch = SearchScratch::new();
    let mut total = QueryStats::default();
    let mut degraded_queries = 0u32;
    for qi in 0..w.queries.len() {
        let q = w.queries.get_f32(qi);
        let mut st = QueryStats::default();
        // Must complete Ok: unreadable pages are skipped, not fatal.
        let out = faulty
            .search(&q, &params, &mut scratch, &mut st)
            .unwrap_or_else(|e| panic!("query {qi} failed under permanent loss: {e}"));
        assert!(out.len() <= params.k);
        for win in out.windows(2) {
            assert!(win[0].0 <= win[1].0, "query {qi}: results out of order");
        }
        if st.degraded {
            degraded_queries += 1;
            assert!(st.failed_ios > 0, "query {qi}: degraded without failed_ios");
        }
        // Phase invariants survive permanent loss too: degraded rounds
        // still charge their I/O inside the submit+wait split.
        assert!(
            st.phases.sum() <= st.total_time,
            "query {qi}: phases ({:?}) exceed total ({:?})",
            st.phases.sum(),
            st.total_time
        );
        assert_eq!(
            st.io_time,
            st.phases.io_submit + st.phases.io_wait,
            "query {qi}: io_time split broken under permanent loss"
        );
        total.merge(&st);
    }
    assert!(degraded_queries > 0, "no query ever touched a dead page");
    assert!(total.failed_ios > 0);
    assert!(total.retries > 0, "dead pages must be retried before being dropped");

    // The degraded path must return failed buffers to the pool too.
    let q = w.queries.get_f32(0);
    let mut sizes = Vec::new();
    for _ in 0..6 {
        let mut st = QueryStats::default();
        faulty.search(&q, &params, &mut scratch, &mut st).unwrap();
        sizes.push(scratch.pooled_buffers());
    }
    assert!(
        sizes.windows(2).skip(1).all(|w| w[0] == w[1]),
        "pool size never stabilized under degraded reads: {sizes:?}"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn fault_spec_off_ignores_environment() {
    // FaultSpec::Off must yield a clean store even when PAGEANN_FAULTS is
    // exported (the CI fault leg relies on this to keep baselines clean).
    // Read-only env check — never set_var in-process.
    let dir = tmppath("specoff");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    build_index(&dir);
    let idx = PageAnnIndex::open(
        &dir,
        OpenOptions { faults: FaultSpec::Off, ..Default::default() },
    )
    .unwrap();
    let w = small_workload();
    let q = w.queries.get_f32(0);
    let mut scratch = SearchScratch::new();
    let mut st = QueryStats::default();
    let out = idx
        .search(&q, &SearchParams { k: 10, l: 60, ..Default::default() }, &mut scratch, &mut st)
        .unwrap();
    assert_eq!(out.len(), 10);
    assert_eq!(st.retries + st.failed_ios + st.crc_failures, 0);
    assert!(!st.degraded);
    std::fs::remove_dir_all(&dir).unwrap();
}
