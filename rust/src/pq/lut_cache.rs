//! Cross-tick ADC LUT cache for recurring queries.
//!
//! The batch pipeline already aliases duplicate queries *within* one batch
//! ([`LutArena`](super::LutArena) sharing), but a serving workload's
//! duplicates mostly recur *across* server ticks — the same query resent
//! seconds apart lands in a different batch and rebuilds its `m × k` table
//! from scratch. [`LutCache`] is a small bounded LRU map from a query's
//! exact f32 **bit pattern** plus the codebook's `(m, k)` identity to a
//! deep-copied [`AdcLut`], shared behind an `Arc` so a hit costs one clone
//! of a pointer instead of a full `build_luts_into` pass.
//!
//! Keying on bits (not values) keeps the cache loss-free by construction:
//! a hit returns byte-for-byte the table a rebuild would produce, so cache
//! on vs. off can never change any result (the scheduler test suite pins
//! this). The `(m, k)` component guards against an index reopen with a
//! different codebook shape sharing a process-wide cache.
//!
//! Default **off** (`--lut-cache 0`); the engine only constructs one when
//! the operator opts in. A capacity of 0 disables the cache entirely
//! (`get` always misses and `insert` is a no-op), so callers can hold an
//! unconditional handle without branching.
//!
//! Concurrency: one `Mutex` (poison-tolerant via [`crate::util::sync::
//! lock`]) around the whole map. Executor threads touch it once per query
//! per tick — orders of magnitude colder than the page read path — so a
//! single lock is the right simplicity/contention trade.

use super::AdcLut;
use crate::util::sync::lock;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Aggregate counters, for the stats frame and tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LutCacheStats {
    /// `get` calls that returned a cached table.
    pub hits: u64,
    /// `get` calls that found nothing (including all calls at capacity 0).
    pub misses: u64,
    /// Entries displaced by LRU eviction (not counting no-op inserts).
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: usize,
}

/// Key: the query's exact f32 bit pattern + the codebook identity it was
/// built against. Bit keying makes `-0.0 != 0.0` and NaN payloads distinct
/// — exactly the equivalence classes under which two LUT builds are
/// guaranteed bitwise identical.
type Key = (Vec<u32>, usize, usize);

struct Entry {
    lut: Arc<AdcLut>,
    last_used: u64,
}

struct Inner {
    map: HashMap<Key, Entry>,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Bounded cross-tick LRU cache of built ADC tables. See the module docs.
pub struct LutCache {
    capacity: usize,
    inner: Mutex<Inner>,
}

impl LutCache {
    /// A cache holding at most `capacity` tables. Capacity 0 disables it
    /// (always-miss, insert is a no-op).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                tick: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
        }
    }

    fn key(query: &[f32], m: usize, k: usize) -> Key {
        (query.iter().map(|v| v.to_bits()).collect(), m, k)
    }

    /// Maximum resident entries.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Look up the table for `query` under codebook identity `(m, k)`.
    /// A hit refreshes the entry's LRU position.
    pub fn get(&self, query: &[f32], m: usize, k: usize) -> Option<Arc<AdcLut>> {
        if self.capacity == 0 {
            let mut g = lock(&self.inner);
            g.misses += 1;
            return None;
        }
        let key = Self::key(query, m, k);
        let mut g = lock(&self.inner);
        g.tick += 1;
        let tick = g.tick;
        match g.map.get_mut(&key) {
            Some(e) => {
                e.last_used = tick;
                let lut = Arc::clone(&e.lut);
                g.hits += 1;
                Some(lut)
            }
            None => {
                g.misses += 1;
                None
            }
        }
    }

    /// Insert a freshly built table for `query`. Evicts the least recently
    /// used entry when at capacity; replaces in place on key collision
    /// (idempotent for concurrent builders of the same query).
    pub fn insert(&self, query: &[f32], m: usize, k: usize, lut: Arc<AdcLut>) {
        if self.capacity == 0 {
            return;
        }
        let key = Self::key(query, m, k);
        let mut g = lock(&self.inner);
        g.tick += 1;
        let tick = g.tick;
        if let Some(e) = g.map.get_mut(&key) {
            e.lut = lut;
            e.last_used = tick;
            return;
        }
        if g.map.len() >= self.capacity {
            // O(n) LRU scan: the cache is small and bounded by design.
            if let Some(victim) =
                g.map.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| k.clone())
            {
                g.map.remove(&victim);
                g.evictions += 1;
            }
        }
        g.map.insert(key, Entry { lut, last_used: tick });
    }

    /// Aggregate hit/miss/eviction counters and current occupancy.
    pub fn stats(&self) -> LutCacheStats {
        let g = lock(&self.inner);
        LutCacheStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            entries: g.map.len(),
        }
    }

    /// Currently resident entries.
    pub fn len(&self) -> usize {
        lock(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, SynthSpec};
    use crate::pq::PqCodebook;

    fn codebook() -> PqCodebook {
        let data =
            SynthSpec::new(DatasetKind::DeepLike, 300).with_dim(16).with_clusters(4).generate(5);
        PqCodebook::train(&data, 4, 6, 7)
    }

    #[test]
    fn hit_returns_bitwise_identical_table() {
        let cb = codebook();
        let q: Vec<f32> = (0..16).map(|i| i as f32 * 0.25 - 1.0).collect();
        let cache = LutCache::new(4);
        assert!(cache.get(&q, cb.m, cb.k).is_none());
        let built = Arc::new(cb.build_lut(&q));
        cache.insert(&q, cb.m, cb.k, Arc::clone(&built));
        let hit = cache.get(&q, cb.m, cb.k).expect("inserted entry must hit");
        let fresh = cb.build_lut(&q);
        assert_eq!(
            hit.table().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            fresh.table().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions, s.entries), (1, 1, 0, 1));
    }

    #[test]
    fn bit_pattern_and_identity_are_the_key() {
        let cb = codebook();
        let q: Vec<f32> = vec![0.5; 16];
        let cache = LutCache::new(4);
        cache.insert(&q, cb.m, cb.k, Arc::new(cb.build_lut(&q)));
        // A 1-ulp jitter is a different query: bit keying, not value keying.
        let mut jitter = q.clone();
        jitter[3] = f32::from_bits(jitter[3].to_bits() + 1);
        assert!(cache.get(&jitter, cb.m, cb.k).is_none());
        // Same bits under a different codebook identity: miss.
        assert!(cache.get(&q, cb.m, cb.k + 1).is_none());
        assert!(cache.get(&q, cb.m + 1, cb.k).is_none());
        // -0.0 and 0.0 are distinct keys (a rebuild could differ bitwise
        // only if the inputs differ bitwise — keep the classes aligned).
        let zp = vec![0.0f32; 16];
        let mut zn = zp.clone();
        zn[0] = -0.0;
        cache.insert(&zp, cb.m, cb.k, Arc::new(cb.build_lut(&zp)));
        assert!(cache.get(&zn, cb.m, cb.k).is_none());
        assert!(cache.get(&zp, cb.m, cb.k).is_some());
    }

    #[test]
    fn lru_eviction_displaces_least_recent() {
        let cb = codebook();
        let qs: Vec<Vec<f32>> = (0..3).map(|i| vec![i as f32; 16]).collect();
        let cache = LutCache::new(2);
        cache.insert(&qs[0], cb.m, cb.k, Arc::new(cb.build_lut(&qs[0])));
        cache.insert(&qs[1], cb.m, cb.k, Arc::new(cb.build_lut(&qs[1])));
        // Touch q0 so q1 becomes the LRU victim.
        assert!(cache.get(&qs[0], cb.m, cb.k).is_some());
        cache.insert(&qs[2], cb.m, cb.k, Arc::new(cb.build_lut(&qs[2])));
        assert!(cache.get(&qs[1], cb.m, cb.k).is_none(), "LRU entry must be evicted");
        assert!(cache.get(&qs[0], cb.m, cb.k).is_some());
        assert!(cache.get(&qs[2], cb.m, cb.k).is_some());
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
    }

    #[test]
    fn reinsert_same_key_replaces_without_eviction() {
        let cb = codebook();
        let q = vec![1.5f32; 16];
        let cache = LutCache::new(1);
        cache.insert(&q, cb.m, cb.k, Arc::new(cb.build_lut(&q)));
        cache.insert(&q, cb.m, cb.k, Arc::new(cb.build_lut(&q)));
        let s = cache.stats();
        assert_eq!(s.evictions, 0);
        assert_eq!(s.entries, 1);
    }

    #[test]
    fn zero_capacity_disables_cache() {
        let cb = codebook();
        let q = vec![2.0f32; 16];
        let cache = LutCache::new(0);
        cache.insert(&q, cb.m, cb.k, Arc::new(cb.build_lut(&q)));
        assert!(cache.get(&q, cb.m, cb.k).is_none());
        assert!(cache.is_empty());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 1, 0));
    }
}
