//! PQ codebooks, encoding, and ADC lookup tables.
//!
//! Two code widths share every type here:
//!
//! * **PQ8** (`k ≤ 256`): one byte per subspace, `m` bytes per stored code.
//! * **PQ4** (`k ≤ 16`): two subspace codes per byte (subspace `s` in byte
//!   `s/2`, even `s` in the low nibble), `⌈m/2⌉` bytes per stored code.
//!   Selected automatically whenever the trained `k` fits a nibble — see
//!   [`PqCodebook::packed`] — and scored by the in-register shuffle
//!   fast-scan kernel over a u8-quantized LUT ([`AdcLut`] builds the
//!   quantized companion table per query).
//!
//! [`PqCodebook::code_bytes`] is the storage stride everywhere (pages,
//! memcodes, baselines); callers never branch on the width themselves.

use super::kmeans::kmeans;
use crate::dataset::VectorSet;
use crate::distance::l2sq_f32;
use crate::util::{parallel_for, ReadExt, WriteExt, XorShift};
use crate::Result;
use std::io::{Read, Write};

/// A compressed vector: one centroid index per subspace (unpacked), or the
/// nibble-packed storage form (see [`pack_nibbles`]).
pub type PqCode = Vec<u8>;

/// Largest `k` for which codes are nibble-packed (PQ4 fast-scan mode).
pub const PQ4_MAX_K: usize = 16;

/// Bytes one stored code of `m` subspaces with `k` centroids occupies:
/// `⌈m/2⌉` nibble-packed for PQ4 (`k ≤ 16`), `m` otherwise. **The single
/// source of the packing rule** — [`PqCodebook::code_bytes`] and
/// `IndexMeta::code_bytes` both delegate here, so the predicate and the
/// formula can never drift between the codebook and the on-disk metadata.
pub fn storage_bytes(m: usize, k: usize) -> usize {
    if k > 0 && k <= PQ4_MAX_K {
        (m + 1) / 2
    } else {
        m
    }
}

/// Pack one-byte-per-subspace PQ4 codes (values `< 16`) into nibbles:
/// subspace `s` lands in byte `s/2`, even `s` in the low nibble.
pub fn pack_nibbles(code: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; (code.len() + 1) / 2];
    for (s, &c) in code.iter().enumerate() {
        debug_assert!(c < 16, "PQ4 code {c} does not fit a nibble");
        out[s / 2] |= if s % 2 == 0 { c & 0x0f } else { (c & 0x0f) << 4 };
    }
    out
}

/// Inverse of [`pack_nibbles`]: expand `⌈m/2⌉` packed bytes back to `m`
/// one-byte-per-subspace codes.
pub fn unpack_nibbles(packed: &[u8], m: usize) -> Vec<u8> {
    debug_assert!(packed.len() >= (m + 1) / 2);
    (0..m)
        .map(|s| {
            let b = packed[s / 2];
            if s % 2 == 0 {
                b & 0x0f
            } else {
                b >> 4
            }
        })
        .collect()
}

/// Trained PQ codebooks: `m` subspaces × `k ≤ 256` centroids × `dsub` dims.
#[derive(Debug, Clone)]
pub struct PqCodebook {
    pub dim: usize,
    pub m: usize,
    pub k: usize,
    pub dsub: usize,
    /// m × k × dsub, row-major.
    pub centroids: Vec<f32>,
}

impl PqCodebook {
    /// Train on (a sample of) `data` with the default `k = 256` (PQ8).
    /// `m` must divide the dimension.
    pub fn train(data: &VectorSet, m: usize, iters: usize, seed: u64) -> Self {
        Self::train_with_k(data, m, 256, iters, seed)
    }

    /// Train with an explicit centroid budget `k_max ≤ 256`. `k_max ≤ 16`
    /// selects the nibble-packed PQ4 layout (half the stored bytes per
    /// code, fast-scan shuffle ADC). The storage width follows the
    /// *requested* budget: a PQ8 request never drops into the PQ4 class
    /// just because the training set is tiny (see the clamp below).
    pub fn train_with_k(data: &VectorSet, m: usize, k_max: usize, iters: usize, seed: u64) -> Self {
        let dim = data.dim();
        assert!(m > 0 && dim % m == 0, "m={m} must divide dim={dim}");
        assert!((2..=256).contains(&k_max), "k_max={k_max} out of range");
        let dsub = dim / m;
        // Clamp the budget to the data size so k-means is well-posed — but
        // never across the PQ4/PQ8 width boundary: `k ≤ 16` flips every
        // code artifact to nibble-packed storage and lossy u8-quantized
        // ADC, and that format choice must be the caller's, not a side
        // effect of a degenerate (≤ 16 vector) training set. On such sets a
        // PQ8 request keeps `k = 17` and the extra centroid rows are
        // duplicates (harmless: the encoder picks the first-best row).
        let k = if k_max > PQ4_MAX_K {
            k_max.min(data.len().max(PQ4_MAX_K + 1))
        } else {
            k_max.min(data.len().max(1))
        };
        // Sample up to 64k training vectors.
        let mut rng = XorShift::new(seed);
        let n_train = data.len().min(65_536);
        let idx = rng.sample_indices(data.len(), n_train);
        // Decode the sample once.
        let mut sample = vec![0f32; n_train * dim];
        for (r, &i) in idx.iter().enumerate() {
            data.decode_into(i, &mut sample[r * dim..(r + 1) * dim]);
        }
        let mut centroids = vec![0f32; m * k * dsub];
        for sub in 0..m {
            // Slice out the subspace columns.
            let mut subdata = vec![0f32; n_train * dsub];
            for r in 0..n_train {
                subdata[r * dsub..(r + 1) * dsub]
                    .copy_from_slice(&sample[r * dim + sub * dsub..r * dim + (sub + 1) * dsub]);
            }
            let km = kmeans(&subdata, dsub, k, iters, seed.wrapping_add(sub as u64));
            // k-means clamps to the point count internally; on degenerate
            // sets (fewer points than the PQ8 floor above) duplicate the
            // last centroid so every index < k stays a valid row.
            let rows = km.k.min(k).max(1);
            let dst = &mut centroids[sub * k * dsub..(sub + 1) * k * dsub];
            dst[..rows * dsub].copy_from_slice(&km.centroids[..rows * dsub]);
            for c in rows..k {
                let (head, tail) = dst.split_at_mut(c * dsub);
                tail[..dsub].copy_from_slice(&head[(rows - 1) * dsub..rows * dsub]);
            }
        }
        Self { dim, m, k, dsub, centroids }
    }

    #[inline]
    pub fn centroid(&self, sub: usize, c: usize) -> &[f32] {
        let base = (sub * self.k + c) * self.dsub;
        &self.centroids[base..base + self.dsub]
    }

    /// True when codes are nibble-packed (PQ4: every centroid index fits a
    /// nibble).
    #[inline]
    pub fn packed(&self) -> bool {
        self.k <= PQ4_MAX_K
    }

    /// Bytes per *stored* compressed vector ([`storage_bytes`]) — the code
    /// stride on pages, in memcodes and in the baselines' resident tables.
    pub fn code_bytes(&self) -> usize {
        storage_bytes(self.m, self.k)
    }

    /// Build the per-query ADC lookup table (m × k squared distances).
    pub fn build_lut(&self, query: &[f32]) -> AdcLut {
        let mut lut = AdcLut::empty();
        self.build_lut_into(query, &mut lut);
        lut
    }

    /// Size an [`AdcLut`]'s header and table for this codebook without
    /// filling any slot. The fill pass writes every slot, so only the
    /// length matters — this skips the zeroing memset on the steady-state
    /// (same-size) path.
    fn prepare_lut(&self, lut: &mut AdcLut) {
        lut.m = self.m;
        lut.k = self.k;
        lut.code_bytes = self.code_bytes();
        if lut.table.len() != self.m * self.k {
            lut.table.resize(self.m * self.k, 0.0);
        }
    }

    /// Fill one subspace row of `lut` (the k distances from the query's
    /// `sub` slice to that subspace's centroid block). Both the single- and
    /// the batched build go through here, so their numerics are identical
    /// slot for slot.
    #[inline]
    fn fill_lut_row(&self, query: &[f32], sub: usize, lut: &mut AdcLut) {
        let l2 = crate::distance::simd::kernels().l2sq_f32;
        let qsub = &query[sub * self.dsub..(sub + 1) * self.dsub];
        let row = &mut lut.table[sub * self.k..(sub + 1) * self.k];
        let centroids = &self.centroids[sub * self.k * self.dsub..(sub + 1) * self.k * self.dsub];
        for (c, slot) in row.iter_mut().enumerate() {
            *slot = l2(qsub, &centroids[c * self.dsub..(c + 1) * self.dsub]);
        }
    }

    /// Finish a filled table: quantize the PQ4 fast-scan companion, or
    /// fully reset it so a reused scratch LUT never exposes a previous PQ4
    /// query's dequant constants.
    fn finish_lut(&self, lut: &mut AdcLut) {
        if self.packed() {
            lut.quantize_q4();
        } else {
            lut.q4.clear();
            lut.q4_scale = 1.0;
            lut.q4_bias = 0.0;
        }
    }

    /// Build the ADC table into a caller-owned [`AdcLut`], reusing its
    /// allocation. This is the hot-path entry: the search scratch owns one
    /// `AdcLut` per thread, so steady-state queries allocate nothing here.
    /// It is the batch build ([`Self::build_luts_into`]) at batch = 1 —
    /// same prepare/fill-row/finish steps, so single-query callers see
    /// bit-identical tables.
    pub fn build_lut_into(&self, query: &[f32], lut: &mut AdcLut) {
        assert_eq!(query.len(), self.dim);
        self.prepare_lut(lut);
        for sub in 0..self.m {
            self.fill_lut_row(query, sub, lut);
        }
        self.finish_lut(lut);
    }

    /// Build the ADC tables for a whole query batch in **one pass over the
    /// codebook**: the fill loop runs subspace-major, so each subspace's
    /// centroid block is loaded once and stays hot in cache while every
    /// query's row is computed — instead of `batch` cold sweeps over the
    /// full `m × k × dsub` centroid array.
    ///
    /// Near-duplicate queries (see [`LutArena::set_share`]) alias a
    /// previously built LUT instead of rebuilding: `arena.lut(i)` maps
    /// query `i` to its table either way, and `arena.reused(i)` reports
    /// whether it was aliased. With the default exact share policy an
    /// aliased table is bit-identical to the rebuild it replaced, so
    /// sharing never changes results.
    pub fn build_luts_into(&self, queries: &[&[f32]], arena: &mut LutArena) {
        arena.assign.clear();
        arena.reused.clear();
        arena.owners.clear();
        for (i, q) in queries.iter().enumerate() {
            assert_eq!(q.len(), self.dim, "query {i} dim");
            let alias = if arena.share {
                arena.owners.iter().position(|&o| arena.matches(queries[o], q))
            } else {
                None
            };
            match alias {
                Some(li) => {
                    arena.assign.push(li);
                    arena.reused.push(true);
                }
                None => {
                    arena.assign.push(arena.owners.len());
                    arena.owners.push(i);
                    arena.reused.push(false);
                }
            }
        }
        let n_uniq = arena.owners.len();
        while arena.luts.len() < n_uniq {
            arena.luts.push(AdcLut::empty());
        }
        for li in 0..n_uniq {
            self.prepare_lut(&mut arena.luts[li]);
        }
        // The one pass over the codebook: subspace-major, all queries per
        // centroid block.
        for sub in 0..self.m {
            for li in 0..n_uniq {
                self.fill_lut_row(queries[arena.owners[li]], sub, &mut arena.luts[li]);
            }
        }
        for li in 0..n_uniq {
            self.finish_lut(&mut arena.luts[li]);
        }
    }

    /// Decode a code back to the (approximate) vector. Accepts either the
    /// unpacked (`m`-byte) or the stored (`code_bytes`) form.
    pub fn decode(&self, code: &[u8]) -> Vec<f32> {
        let unpacked;
        let code = if self.packed() && code.len() == self.code_bytes() && self.code_bytes() < self.m
        {
            unpacked = unpack_nibbles(code, self.m);
            &unpacked[..]
        } else {
            code
        };
        let mut out = vec![0f32; self.dim];
        for sub in 0..self.m {
            out[sub * self.dsub..(sub + 1) * self.dsub]
                .copy_from_slice(self.centroid(sub, code[sub] as usize));
        }
        out
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_u32(PQ_MAGIC)?;
        w.write_u32(PQ_VERSION)?;
        w.write_u32(self.dim as u32)?;
        w.write_u32(self.m as u32)?;
        w.write_u32(self.k as u32)?;
        w.write_f32_slice(&self.centroids)?;
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        // v1 files (pre-PQ4) start directly with `dim`; versioned files
        // start with a magic word that no plausible dimension collides
        // with. Accept both so seed-era artifacts keep loading.
        let first = r.read_u32v()?;
        let dim = if first == PQ_MAGIC {
            let v = r.read_u32v()?;
            anyhow::ensure!(v == PQ_VERSION, "pq codebook version {v} != supported {PQ_VERSION}");
            r.read_u32v()? as usize
        } else {
            first as usize
        };
        let m = r.read_u32v()? as usize;
        let k = r.read_u32v()? as usize;
        anyhow::ensure!(m > 0 && dim % m == 0 && k > 0 && k <= 256, "corrupt codebook header");
        let dsub = dim / m;
        let centroids = r.read_f32_vec(m * k * dsub)?;
        Ok(Self { dim, m, k, dsub, centroids })
    }
}

/// Magic prefix of versioned `pq.bin` headers ("PQCB"); absent in legacy
/// (seed) files, which begin directly with `dim`.
const PQ_MAGIC: u32 = 0x5051_4342;
/// Current `pq.bin` format version. v2 = explicit versioning + PQ4-aware
/// readers (`k ≤ 16` ⇒ nibble-packed code artifacts).
const PQ_VERSION: u32 = 2;

/// Per-query lookup table for asymmetric distance computation.
///
/// Layout: a flat `m × k` f32 table, subspace-major (row stride `k`), which
/// is exactly the shape the SIMD `adc_batch` kernel gathers from — one
/// contiguous table row per subspace. For PQ4 codebooks (`k ≤ 16`) the
/// build also quantizes a `m × 16` u8 companion table (`q4`) for the
/// fast-scan shuffle kernel: per-subspace row minima folded into `q4_bias`,
/// one shared `q4_scale = max row range / 255`. Fields are private so the
/// layout contract between this type and `distance::simd` stays in one
/// file. `Clone` exists for the cross-tick [`LutCache`](super::LutCache),
/// which keeps deep copies of built tables so cached entries stay valid
/// after the arena that built them is reused.
#[derive(Clone)]
pub struct AdcLut {
    m: usize,
    k: usize,
    /// Bytes per stored code this table scores (`⌈m/2⌉` packed, else `m`).
    code_bytes: usize,
    /// m × k squared subspace distances, row stride `k`.
    table: Vec<f32>,
    /// u8-quantized `m × 16` fast-scan rows; empty unless PQ4.
    q4: Vec<u8>,
    /// Per-row minima scratch for the quantization pass (reused allocation,
    /// like `table` — `build_lut_into` runs per query).
    q4_lo: Vec<f32>,
    q4_scale: f32,
    q4_bias: f32,
}

impl Default for AdcLut {
    fn default() -> Self {
        Self::empty()
    }
}

impl AdcLut {
    /// An empty table; fill with [`PqCodebook::build_lut_into`].
    pub fn empty() -> Self {
        Self {
            m: 0,
            k: 0,
            code_bytes: 0,
            table: Vec::new(),
            q4: Vec::new(),
            q4_lo: Vec::new(),
            q4_scale: 1.0,
            q4_bias: 0.0,
        }
    }

    /// Quantize the f32 table into the PQ4 fast-scan companion: row minima
    /// sum into the bias, the widest row range sets the shared scale, and
    /// unused row slots (`k < 16`) saturate to 255 so a corrupt nibble
    /// reads as "far" rather than out of bounds.
    fn quantize_q4(&mut self) {
        debug_assert!(self.k <= PQ4_MAX_K && self.k > 0);
        if self.q4.len() != self.m * 16 {
            self.q4.resize(self.m * 16, 0);
        }
        if self.q4_lo.len() != self.m {
            self.q4_lo.resize(self.m, 0.0);
        }
        // One reduction pass: row minima (kept for the quantize loop) plus
        // the widest row range, which fixes the shared scale.
        let mut bias = 0f32;
        let mut max_range = 0f32;
        for s in 0..self.m {
            let row = &self.table[s * self.k..(s + 1) * self.k];
            let lo = row.iter().fold(f32::INFINITY, |a, &v| a.min(v));
            let hi = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            self.q4_lo[s] = lo;
            bias += lo;
            max_range = max_range.max(hi - lo);
        }
        let scale = if max_range > 0.0 { max_range / 255.0 } else { 1.0 };
        for s in 0..self.m {
            let row = &self.table[s * self.k..(s + 1) * self.k];
            let lo = self.q4_lo[s];
            let out = &mut self.q4[s * 16..(s + 1) * 16];
            for (c, slot) in out.iter_mut().enumerate() {
                *slot = if c < self.k {
                    ((row[c] - lo) / scale).round().min(255.0) as u8
                } else {
                    255
                };
            }
        }
        self.q4_scale = scale;
        self.q4_bias = bias;
    }

    /// Subspace count of the codes this table scores.
    #[inline]
    pub fn m(&self) -> usize {
        self.m
    }

    /// Centroids per subspace.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Bytes per stored code this table scores (`⌈m/2⌉` for PQ4, else `m`).
    #[inline]
    pub fn code_bytes(&self) -> usize {
        self.code_bytes
    }

    /// True when this table scores nibble-packed PQ4 codes.
    #[inline]
    pub fn is_packed(&self) -> bool {
        !self.q4.is_empty()
    }

    /// The raw `m × k` table (benches, artifact interop).
    pub fn table(&self) -> &[f32] {
        &self.table
    }

    /// The PQ4 fast-scan companion (`m × 16` u8 rows; empty unless PQ4).
    pub fn q4_table(&self) -> &[u8] {
        &self.q4
    }

    /// Dequant scale of the PQ4 companion table (quantization step size).
    pub fn q4_scale(&self) -> f32 {
        self.q4_scale
    }

    /// Dequant bias of the PQ4 companion table (summed row minima).
    pub fn q4_bias(&self) -> f32 {
        self.q4_bias
    }

    /// Approximate squared distance to the vector with `code` in its stored
    /// width (delegates to the scalar ADC kernel of the matching width —
    /// one source of truth for the table walk).
    #[inline]
    pub fn distance(&self, code: &[u8]) -> f32 {
        debug_assert_eq!(code.len(), self.code_bytes);
        let mut out = [0f32; 1];
        if self.is_packed() {
            crate::distance::simd::scalar_adc4_batch(
                &self.q4,
                self.m,
                code,
                1,
                self.q4_scale,
                self.q4_bias,
                &mut out,
            );
        } else {
            crate::distance::simd::scalar_adc_batch(&self.table, self.m, self.k, code, 1, &mut out);
        }
        out[0]
    }

    /// Batched ADC: score `n` codes packed row-major (`n × code_bytes`)
    /// into `out[..n]` with the dispatched SIMD kernel of the matching code
    /// width. Equivalent to `n` calls to [`Self::distance`] (asserted by
    /// the property suite).
    #[inline]
    pub fn distance_batch(&self, codes: &[u8], n: usize, out: &mut [f32]) {
        debug_assert!(codes.len() >= n * self.code_bytes);
        debug_assert!(out.len() >= n);
        if self.is_packed() {
            (crate::distance::simd::kernels().adc4_batch)(
                &self.q4,
                self.m,
                codes,
                n,
                self.q4_scale,
                self.q4_bias,
                out,
            );
        } else {
            (crate::distance::simd::kernels().adc_batch)(&self.table, self.m, self.k, codes, n, out);
        }
    }

    /// [`Self::distance_batch`] into a scratch-owned `Vec`, growing it as
    /// needed. The shared entry point for the gather-then-batch topology
    /// phases (PageANN search and the beam-search baselines).
    #[inline]
    pub fn score_into(&self, codes: &[u8], n: usize, out: &mut Vec<f32>) {
        if out.len() < n {
            out.resize(n, 0.0);
        }
        self.distance_batch(codes, n, out);
    }
}

/// A pool of per-query ADC tables for one query batch, filled by
/// [`PqCodebook::build_luts_into`]. Allocations (the tables themselves and
/// the assignment vectors) are reused across batches, so steady-state
/// batch queries allocate nothing here.
///
/// # LUT sharing
///
/// Queries that near-duplicate an earlier query in the same batch can
/// *alias* that query's table instead of rebuilding it. The screen is a
/// normalized-dot-product threshold (cosine similarity over f64
/// accumulators). Two policies:
///
/// * `threshold >= 1.0` (default): only **bit-identical** queries share a
///   table. The dot screen is skipped for an exact `memcmp`-style bit
///   compare, so an aliased LUT is guaranteed identical to the rebuild it
///   replaced and sharing can never change any result.
/// * `threshold < 1.0`: queries whose cosine similarity and squared-norm
///   ratio both clear the threshold share the first query's table. This is
///   a lossy, explicitly opt-in approximation for duplicate-heavy serving
///   workloads (resent queries with jittered floats).
pub struct LutArena {
    /// Built tables, one per *unique* query (index space of `assign`).
    luts: Vec<AdcLut>,
    /// Query index -> index into `luts`.
    assign: Vec<usize>,
    /// Whether query `i` aliased a previously built table.
    reused: Vec<bool>,
    /// For each built lut, the query index that owns (built) it.
    owners: Vec<usize>,
    share: bool,
    threshold: f32,
}

impl Default for LutArena {
    fn default() -> Self {
        Self::new()
    }
}

impl LutArena {
    pub fn new() -> Self {
        Self {
            luts: Vec::new(),
            assign: Vec::new(),
            reused: Vec::new(),
            owners: Vec::new(),
            share: true,
            threshold: 1.0,
        }
    }

    /// Enable/disable near-duplicate LUT sharing (default on), and set the
    /// normalized-dot threshold (default 1.0 = exact matches only).
    pub fn set_share(&mut self, share: bool, threshold: f32) {
        self.share = share;
        self.threshold = threshold;
    }

    /// Number of queries in the last batch.
    pub fn len(&self) -> usize {
        self.assign.len()
    }

    pub fn is_empty(&self) -> bool {
        self.assign.is_empty()
    }

    /// Number of tables actually built for the last batch (≤ `len`).
    pub fn built(&self) -> usize {
        self.owners.len()
    }

    /// The ADC table assigned to query `qi` of the last batch.
    #[inline]
    pub fn lut(&self, qi: usize) -> &AdcLut {
        &self.luts[self.assign[qi]]
    }

    /// Whether query `qi` aliased an earlier query's table.
    #[inline]
    pub fn reused(&self, qi: usize) -> bool {
        self.reused[qi]
    }

    /// The near-duplicate check: exact bit equality when `threshold >=
    /// 1.0`, else a cosine + norm-ratio screen over f64 accumulators.
    fn matches(&self, a: &[f32], b: &[f32]) -> bool {
        if a.len() != b.len() {
            return false;
        }
        if self.threshold >= 1.0 {
            // Bitwise compare: NaN-safe and distinguishes -0.0 from 0.0,
            // so an aliased table is exactly what a rebuild would produce.
            return a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits());
        }
        let (mut dot, mut na2, mut nb2) = (0f64, 0f64, 0f64);
        for (&x, &y) in a.iter().zip(b) {
            dot += x as f64 * y as f64;
            na2 += x as f64 * x as f64;
            nb2 += y as f64 * y as f64;
        }
        let t2 = (self.threshold as f64) * (self.threshold as f64);
        if na2 == 0.0 || nb2 == 0.0 {
            return na2 == nb2;
        }
        // Cosine screen + norm-ratio guard (colinear-but-scaled queries
        // have cosine 1 but different tables).
        dot > 0.0 && dot * dot >= t2 * na2 * nb2 && na2.min(nb2) >= t2 * na2.max(nb2)
    }
}

/// Encoder: assigns each subvector to its nearest centroid.
pub struct PqEncoder<'a> {
    cb: &'a PqCodebook,
}

impl<'a> PqEncoder<'a> {
    pub fn new(cb: &'a PqCodebook) -> Self {
        Self { cb }
    }

    /// One centroid index per subspace (unpacked, `m` bytes).
    pub fn encode(&self, v: &[f32]) -> PqCode {
        let cb = self.cb;
        let mut code = vec![0u8; cb.m];
        for sub in 0..cb.m {
            let vsub = &v[sub * cb.dsub..(sub + 1) * cb.dsub];
            let mut best = 0usize;
            let mut bestd = f32::INFINITY;
            for c in 0..cb.k {
                let d = l2sq_f32(vsub, cb.centroid(sub, c));
                if d < bestd {
                    bestd = d;
                    best = c;
                }
            }
            code[sub] = best as u8;
        }
        code
    }

    /// Encode to the *storage* width ([`PqCodebook::code_bytes`]):
    /// nibble-packed for PQ4 codebooks, identical to [`Self::encode`]
    /// otherwise.
    pub fn encode_packed(&self, v: &[f32]) -> PqCode {
        let code = self.encode(v);
        if self.cb.packed() {
            pack_nibbles(&code)
        } else {
            code
        }
    }

    /// Encode a whole set in parallel into a dense `n × code_bytes` matrix
    /// (storage width — nibble-packed for PQ4).
    pub fn encode_all(&self, data: &VectorSet, nthreads: usize) -> Vec<u8> {
        let cw = self.cb.code_bytes();
        let rows = parallel_for(data.len(), nthreads, |i| self.encode_packed(&data.get_f32(i)));
        let mut out = vec![0u8; data.len() * cw];
        for (i, code) in rows.into_iter().enumerate() {
            out[i * cw..(i + 1) * cw].copy_from_slice(&code);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, SynthSpec};

    fn small_set() -> VectorSet {
        SynthSpec::new(DatasetKind::DeepLike, 400).with_dim(16).with_clusters(4).generate(8)
    }

    #[test]
    fn encode_decode_reduces_error_vs_random_code() {
        let data = small_set();
        let cb = PqCodebook::train(&data, 4, 12, 7);
        let enc = PqEncoder::new(&cb);
        let mut err_enc = 0f64;
        let mut err_rand = 0f64;
        let mut rng = XorShift::new(3);
        for i in 0..100 {
            let v = data.get_f32(i);
            let code = enc.encode(&v);
            let rand_code: Vec<u8> = (0..cb.m).map(|_| rng.next_below(cb.k) as u8).collect();
            err_enc += l2sq_f32(&v, &cb.decode(&code)) as f64;
            err_rand += l2sq_f32(&v, &cb.decode(&rand_code)) as f64;
        }
        assert!(err_enc * 3.0 < err_rand, "enc {err_enc} rand {err_rand}");
    }

    #[test]
    fn lut_distance_equals_decode_distance_per_subspace() {
        // ADC(lut, code) must equal the exact sum of subspace distances to
        // the code's centroids (that's its definition).
        let data = small_set();
        let cb = PqCodebook::train(&data, 4, 8, 11);
        let enc = PqEncoder::new(&cb);
        let q = data.get_f32(0);
        let lut = cb.build_lut(&q);
        for i in [1usize, 17, 200] {
            let code = enc.encode(&data.get_f32(i));
            let adc = lut.distance(&code);
            let mut manual = 0f32;
            for sub in 0..cb.m {
                manual += l2sq_f32(
                    &q[sub * cb.dsub..(sub + 1) * cb.dsub],
                    cb.centroid(sub, code[sub] as usize),
                );
            }
            assert!((adc - manual).abs() < 1e-4, "{adc} vs {manual}");
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let data = small_set();
        let cb = PqCodebook::train(&data, 4, 5, 13);
        let mut buf = Vec::new();
        cb.write_to(&mut buf).unwrap();
        let back = PqCodebook::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.dim, cb.dim);
        assert_eq!(back.m, cb.m);
        assert_eq!(back.k, cb.k);
        assert_eq!(back.centroids, cb.centroids);
    }

    #[test]
    fn corrupt_header_rejected() {
        let mut buf = Vec::new();
        buf.write_u32(16).unwrap();
        buf.write_u32(3).unwrap(); // 3 does not divide 16
        buf.write_u32(256).unwrap();
        assert!(PqCodebook::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn encode_all_matches_single() {
        let data = small_set();
        let cb = PqCodebook::train(&data, 4, 5, 17);
        let enc = PqEncoder::new(&cb);
        let packed = enc.encode_all(&data, 4);
        for i in [0usize, 5, 399] {
            assert_eq!(&packed[i * 4..(i + 1) * 4], enc.encode(&data.get_f32(i)).as_slice());
        }
    }

    #[test]
    fn pq4_codebook_packs_two_codes_per_byte() {
        let data = small_set();
        let cb = PqCodebook::train_with_k(&data, 4, 16, 8, 21);
        assert!(cb.packed());
        assert_eq!(cb.k, 16);
        assert_eq!(cb.code_bytes(), 2);
        let enc = PqEncoder::new(&cb);
        let v = data.get_f32(3);
        let code = enc.encode(&v);
        assert!(code.iter().all(|&c| c < 16));
        let stored = enc.encode_packed(&v);
        assert_eq!(stored.len(), 2);
        assert_eq!(unpack_nibbles(&stored, 4), code);
        // encode_all writes the storage width.
        let all = enc.encode_all(&data, 2);
        assert_eq!(all.len(), data.len() * 2);
        assert_eq!(&all[3 * 2..4 * 2], stored.as_slice());
        // decode accepts both widths and agrees.
        assert_eq!(cb.decode(&stored), cb.decode(&code));
    }

    #[test]
    fn pq4_adc_matches_f32_table_within_quantization_step() {
        // The fast-scan path quantizes the LUT to u8; its error per code is
        // bounded by m rounding errors of at most scale/2 each.
        let data = small_set();
        let cb = PqCodebook::train_with_k(&data, 4, 16, 10, 31);
        let enc = PqEncoder::new(&cb);
        let q = data.get_f32(0);
        let lut = cb.build_lut(&q);
        assert!(lut.is_packed());
        for i in [1usize, 17, 200, 399] {
            let code = enc.encode(&data.get_f32(i));
            let exact: f32 =
                (0..cb.m).map(|s| lut.table()[s * cb.k + code[s] as usize]).sum();
            let got = lut.distance(&pack_nibbles(&code));
            let bound = 0.5 * lut.q4_scale() * cb.m as f32 + 1e-3 * exact.abs().max(1.0);
            assert!(
                (got - exact).abs() <= bound,
                "vector {i}: adc4 {got} vs table-sum {exact} (bound {bound})"
            );
        }
    }

    #[test]
    fn tiny_dataset_does_not_flip_pq8_into_packed_mode() {
        // A PQ8 request on a degenerate (≤ 16 vector) set must keep the
        // one-byte-per-subspace width: the storage format follows the
        // requested budget, never the data size.
        let data = SynthSpec::new(DatasetKind::DeepLike, 10).with_dim(8).with_clusters(2).generate(3);
        let cb = PqCodebook::train(&data, 2, 4, 1);
        assert!(cb.k > PQ4_MAX_K, "trained k {} fell into the PQ4 class", cb.k);
        assert!(!cb.packed());
        assert_eq!(cb.code_bytes(), 2);
        // Every centroid row is a valid slice (duplicates fill the tail)
        // and encoding stays in range.
        for sub in 0..cb.m {
            for c in 0..cb.k {
                assert_eq!(cb.centroid(sub, c).len(), cb.dsub);
            }
        }
        let code = PqEncoder::new(&cb).encode(&data.get_f32(0));
        assert!(code.iter().all(|&c| (c as usize) < cb.k));
        // An explicit PQ4 request on the same tiny set still packs.
        let cb4 = PqCodebook::train_with_k(&data, 2, 16, 4, 1);
        assert!(cb4.packed());
        assert_eq!(cb4.code_bytes(), 1);
    }

    #[test]
    fn pq4_serialization_roundtrip_preserves_width() {
        let data = small_set();
        let cb = PqCodebook::train_with_k(&data, 4, 16, 5, 13);
        let mut buf = Vec::new();
        cb.write_to(&mut buf).unwrap();
        let back = PqCodebook::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.k, cb.k);
        assert!(back.packed());
        assert_eq!(back.code_bytes(), cb.code_bytes());
        assert_eq!(back.centroids, cb.centroids);
    }

    #[test]
    fn batch_lut_build_matches_single_build_bitwise() {
        // The subspace-major batch pass must produce the same table, slot
        // for slot, as the per-query build — for both PQ8 and PQ4.
        let data = small_set();
        for k in [256usize, 16] {
            let cb = PqCodebook::train_with_k(&data, 4, k, 8, 9);
            let queries: Vec<Vec<f32>> = (0..5).map(|i| data.get_f32(i * 7)).collect();
            let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            let mut arena = LutArena::new();
            cb.build_luts_into(&refs, &mut arena);
            assert_eq!(arena.len(), 5);
            assert_eq!(arena.built(), 5);
            for (i, q) in refs.iter().enumerate() {
                assert!(!arena.reused(i));
                let single = cb.build_lut(q);
                assert_eq!(
                    arena.lut(i).table().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    single.table().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "k={k} query {i}"
                );
                assert_eq!(arena.lut(i).q4_table(), single.q4_table());
                assert_eq!(arena.lut(i).code_bytes(), single.code_bytes());
            }
        }
    }

    #[test]
    fn duplicate_queries_alias_one_lut() {
        let data = small_set();
        let cb = PqCodebook::train(&data, 4, 8, 9);
        let a = data.get_f32(0);
        let b = data.get_f32(1);
        let refs: Vec<&[f32]> = vec![&a, &b, &a, &a, &b];
        let mut arena = LutArena::new();
        cb.build_luts_into(&refs, &mut arena);
        assert_eq!(arena.len(), 5);
        assert_eq!(arena.built(), 2, "only two unique queries");
        assert_eq!(
            (0..5).map(|i| arena.reused(i)).collect::<Vec<_>>(),
            vec![false, false, true, true, true]
        );
        // Aliased tables are the same table.
        assert!(std::ptr::eq(arena.lut(0), arena.lut(2)));
        assert_eq!(arena.lut(1).table(), cb.build_lut(&b).table());
        // Sharing off: every query builds.
        arena.set_share(false, 1.0);
        cb.build_luts_into(&refs, &mut arena);
        assert_eq!(arena.built(), 5);
        assert!((0..5).all(|i| !arena.reused(i)));
    }

    #[test]
    fn near_duplicate_threshold_aliases_jittered_query_only_when_lossy() {
        let data = small_set();
        let cb = PqCodebook::train(&data, 4, 8, 9);
        let a = data.get_f32(0);
        let mut jitter = a.clone();
        for v in jitter.iter_mut() {
            *v *= 1.0 + 1e-6;
        }
        let refs: Vec<&[f32]> = vec![&a, &jitter];
        // Exact policy: a 1e-6 jitter is a different query.
        let mut arena = LutArena::new();
        cb.build_luts_into(&refs, &mut arena);
        assert_eq!(arena.built(), 2);
        // Lossy opt-in policy: it aliases.
        arena.set_share(true, 0.999);
        cb.build_luts_into(&refs, &mut arena);
        assert_eq!(arena.built(), 1);
        assert!(arena.reused(1));
        // But a genuinely different query never does (negated: cosine -1).
        let c: Vec<f32> = a.iter().map(|v| -v).collect();
        let refs2: Vec<&[f32]> = vec![&a, &c];
        cb.build_luts_into(&refs2, &mut arena);
        assert_eq!(arena.built(), 2);
        // Scaled-colinear queries have cosine 1 but different tables: the
        // norm-ratio guard must keep them separate.
        let scaled: Vec<f32> = a.iter().map(|v| v * 2.0).collect();
        let refs3: Vec<&[f32]> = vec![&a, &scaled];
        cb.build_luts_into(&refs3, &mut arena);
        assert_eq!(arena.built(), 2, "scaled query must not alias");
    }

    #[test]
    fn legacy_unversioned_header_still_loads() {
        // Seed-era pq.bin files start directly with `dim`.
        let data = small_set();
        let cb = PqCodebook::train(&data, 4, 5, 13);
        let mut buf = Vec::new();
        buf.write_u32(cb.dim as u32).unwrap();
        buf.write_u32(cb.m as u32).unwrap();
        buf.write_u32(cb.k as u32).unwrap();
        buf.write_f32_slice(&cb.centroids).unwrap();
        let back = PqCodebook::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.dim, cb.dim);
        assert_eq!(back.centroids, cb.centroids);
    }

    use crate::util::XorShift;
}
