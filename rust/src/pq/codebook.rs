//! PQ codebooks, encoding, and ADC lookup tables.

use super::kmeans::kmeans;
use crate::dataset::VectorSet;
use crate::distance::l2sq_f32;
use crate::util::{parallel_for, ReadExt, WriteExt, XorShift};
use crate::Result;
use std::io::{Read, Write};

/// A compressed vector: one centroid index per subspace.
pub type PqCode = Vec<u8>;

/// Trained PQ codebooks: `m` subspaces × `k ≤ 256` centroids × `dsub` dims.
#[derive(Debug, Clone)]
pub struct PqCodebook {
    pub dim: usize,
    pub m: usize,
    pub k: usize,
    pub dsub: usize,
    /// m × k × dsub, row-major.
    pub centroids: Vec<f32>,
}

impl PqCodebook {
    /// Train on (a sample of) `data`. `m` must divide the dimension.
    pub fn train(data: &VectorSet, m: usize, iters: usize, seed: u64) -> Self {
        let dim = data.dim();
        assert!(m > 0 && dim % m == 0, "m={m} must divide dim={dim}");
        let dsub = dim / m;
        let k = 256usize.min(data.len().max(1));
        // Sample up to 64k training vectors.
        let mut rng = XorShift::new(seed);
        let n_train = data.len().min(65_536);
        let idx = rng.sample_indices(data.len(), n_train);
        // Decode the sample once.
        let mut sample = vec![0f32; n_train * dim];
        for (r, &i) in idx.iter().enumerate() {
            data.decode_into(i, &mut sample[r * dim..(r + 1) * dim]);
        }
        let mut centroids = vec![0f32; m * k * dsub];
        for sub in 0..m {
            // Slice out the subspace columns.
            let mut subdata = vec![0f32; n_train * dsub];
            for r in 0..n_train {
                subdata[r * dsub..(r + 1) * dsub]
                    .copy_from_slice(&sample[r * dim + sub * dsub..r * dim + (sub + 1) * dsub]);
            }
            let km = kmeans(&subdata, dsub, k, iters, seed.wrapping_add(sub as u64));
            centroids[sub * k * dsub..(sub + 1) * k * dsub].copy_from_slice(&km.centroids);
        }
        Self { dim, m, k, dsub, centroids }
    }

    #[inline]
    pub fn centroid(&self, sub: usize, c: usize) -> &[f32] {
        let base = (sub * self.k + c) * self.dsub;
        &self.centroids[base..base + self.dsub]
    }

    /// Bytes per compressed vector.
    pub fn code_bytes(&self) -> usize {
        self.m
    }

    /// Build the per-query ADC lookup table (m × k squared distances).
    pub fn build_lut(&self, query: &[f32]) -> AdcLut {
        let mut lut = AdcLut::empty();
        self.build_lut_into(query, &mut lut);
        lut
    }

    /// Build the ADC table into a caller-owned [`AdcLut`], reusing its
    /// allocation. This is the hot-path entry: the search scratch owns one
    /// `AdcLut` per thread, so steady-state queries allocate nothing here.
    pub fn build_lut_into(&self, query: &[f32], lut: &mut AdcLut) {
        assert_eq!(query.len(), self.dim);
        lut.m = self.m;
        lut.k = self.k;
        // The fill loop writes every slot, so only the length matters —
        // avoid the zeroing memset on the steady-state (same-size) path.
        if lut.table.len() != self.m * self.k {
            lut.table.resize(self.m * self.k, 0.0);
        }
        let l2 = crate::distance::simd::kernels().l2sq_f32;
        for sub in 0..self.m {
            let qsub = &query[sub * self.dsub..(sub + 1) * self.dsub];
            let row = &mut lut.table[sub * self.k..(sub + 1) * self.k];
            let centroids = &self.centroids[sub * self.k * self.dsub..(sub + 1) * self.k * self.dsub];
            for (c, slot) in row.iter_mut().enumerate() {
                *slot = l2(qsub, &centroids[c * self.dsub..(c + 1) * self.dsub]);
            }
        }
    }

    /// Decode a code back to the (approximate) vector.
    pub fn decode(&self, code: &[u8]) -> Vec<f32> {
        let mut out = vec![0f32; self.dim];
        for sub in 0..self.m {
            out[sub * self.dsub..(sub + 1) * self.dsub]
                .copy_from_slice(self.centroid(sub, code[sub] as usize));
        }
        out
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_u32(self.dim as u32)?;
        w.write_u32(self.m as u32)?;
        w.write_u32(self.k as u32)?;
        w.write_f32_slice(&self.centroids)?;
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        let dim = r.read_u32v()? as usize;
        let m = r.read_u32v()? as usize;
        let k = r.read_u32v()? as usize;
        anyhow::ensure!(m > 0 && dim % m == 0 && k > 0 && k <= 256, "corrupt codebook header");
        let dsub = dim / m;
        let centroids = r.read_f32_vec(m * k * dsub)?;
        Ok(Self { dim, m, k, dsub, centroids })
    }
}

/// Per-query lookup table for asymmetric distance computation.
///
/// Layout: a flat `m × k` f32 table, subspace-major (row stride `k`), which
/// is exactly the shape the SIMD `adc_batch` kernel gathers from — one
/// contiguous table row per subspace. Fields are private so the layout
/// contract between this type and `distance::simd` stays in one file.
pub struct AdcLut {
    m: usize,
    k: usize,
    /// m × k squared subspace distances, row stride `k`.
    table: Vec<f32>,
}

impl Default for AdcLut {
    fn default() -> Self {
        Self::empty()
    }
}

impl AdcLut {
    /// An empty table; fill with [`PqCodebook::build_lut_into`].
    pub fn empty() -> Self {
        Self { m: 0, k: 0, table: Vec::new() }
    }

    /// Subspace count of the codes this table scores.
    #[inline]
    pub fn m(&self) -> usize {
        self.m
    }

    /// Centroids per subspace.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// The raw `m × k` table (benches, artifact interop).
    pub fn table(&self) -> &[f32] {
        &self.table
    }

    /// Approximate squared distance to the vector with `code` (delegates to
    /// the scalar ADC kernel — one source of truth for the table walk).
    #[inline]
    pub fn distance(&self, code: &[u8]) -> f32 {
        debug_assert_eq!(code.len(), self.m);
        let mut out = [0f32; 1];
        crate::distance::simd::scalar_adc_batch(&self.table, self.m, self.k, code, 1, &mut out);
        out[0]
    }

    /// Batched ADC: score `n` codes packed row-major (`n × m`) into
    /// `out[..n]` with the dispatched SIMD kernel. Equivalent to `n` calls
    /// to [`Self::distance`] (asserted by the property suite).
    #[inline]
    pub fn distance_batch(&self, codes: &[u8], n: usize, out: &mut [f32]) {
        debug_assert!(codes.len() >= n * self.m);
        debug_assert!(out.len() >= n);
        (crate::distance::simd::kernels().adc_batch)(&self.table, self.m, self.k, codes, n, out);
    }

    /// [`Self::distance_batch`] into a scratch-owned `Vec`, growing it as
    /// needed. The shared entry point for the gather-then-batch topology
    /// phases (PageANN search and the beam-search baselines).
    #[inline]
    pub fn score_into(&self, codes: &[u8], n: usize, out: &mut Vec<f32>) {
        if out.len() < n {
            out.resize(n, 0.0);
        }
        self.distance_batch(codes, n, out);
    }
}

/// Encoder: assigns each subvector to its nearest centroid.
pub struct PqEncoder<'a> {
    cb: &'a PqCodebook,
}

impl<'a> PqEncoder<'a> {
    pub fn new(cb: &'a PqCodebook) -> Self {
        Self { cb }
    }

    pub fn encode(&self, v: &[f32]) -> PqCode {
        let cb = self.cb;
        let mut code = vec![0u8; cb.m];
        for sub in 0..cb.m {
            let vsub = &v[sub * cb.dsub..(sub + 1) * cb.dsub];
            let mut best = 0usize;
            let mut bestd = f32::INFINITY;
            for c in 0..cb.k {
                let d = l2sq_f32(vsub, cb.centroid(sub, c));
                if d < bestd {
                    bestd = d;
                    best = c;
                }
            }
            code[sub] = best as u8;
        }
        code
    }

    /// Encode a whole set in parallel into a packed n × m byte matrix.
    pub fn encode_all(&self, data: &VectorSet, nthreads: usize) -> Vec<u8> {
        let m = self.cb.m;
        let rows = parallel_for(data.len(), nthreads, |i| self.encode(&data.get_f32(i)));
        let mut out = vec![0u8; data.len() * m];
        for (i, code) in rows.into_iter().enumerate() {
            out[i * m..(i + 1) * m].copy_from_slice(&code);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, SynthSpec};

    fn small_set() -> VectorSet {
        SynthSpec::new(DatasetKind::DeepLike, 400).with_dim(16).with_clusters(4).generate(8)
    }

    #[test]
    fn encode_decode_reduces_error_vs_random_code() {
        let data = small_set();
        let cb = PqCodebook::train(&data, 4, 12, 7);
        let enc = PqEncoder::new(&cb);
        let mut err_enc = 0f64;
        let mut err_rand = 0f64;
        let mut rng = XorShift::new(3);
        for i in 0..100 {
            let v = data.get_f32(i);
            let code = enc.encode(&v);
            let rand_code: Vec<u8> = (0..cb.m).map(|_| rng.next_below(cb.k) as u8).collect();
            err_enc += l2sq_f32(&v, &cb.decode(&code)) as f64;
            err_rand += l2sq_f32(&v, &cb.decode(&rand_code)) as f64;
        }
        assert!(err_enc * 3.0 < err_rand, "enc {err_enc} rand {err_rand}");
    }

    #[test]
    fn lut_distance_equals_decode_distance_per_subspace() {
        // ADC(lut, code) must equal the exact sum of subspace distances to
        // the code's centroids (that's its definition).
        let data = small_set();
        let cb = PqCodebook::train(&data, 4, 8, 11);
        let enc = PqEncoder::new(&cb);
        let q = data.get_f32(0);
        let lut = cb.build_lut(&q);
        for i in [1usize, 17, 200] {
            let code = enc.encode(&data.get_f32(i));
            let adc = lut.distance(&code);
            let mut manual = 0f32;
            for sub in 0..cb.m {
                manual += l2sq_f32(
                    &q[sub * cb.dsub..(sub + 1) * cb.dsub],
                    cb.centroid(sub, code[sub] as usize),
                );
            }
            assert!((adc - manual).abs() < 1e-4, "{adc} vs {manual}");
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let data = small_set();
        let cb = PqCodebook::train(&data, 4, 5, 13);
        let mut buf = Vec::new();
        cb.write_to(&mut buf).unwrap();
        let back = PqCodebook::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.dim, cb.dim);
        assert_eq!(back.m, cb.m);
        assert_eq!(back.k, cb.k);
        assert_eq!(back.centroids, cb.centroids);
    }

    #[test]
    fn corrupt_header_rejected() {
        let mut buf = Vec::new();
        buf.write_u32(16).unwrap();
        buf.write_u32(3).unwrap(); // 3 does not divide 16
        buf.write_u32(256).unwrap();
        assert!(PqCodebook::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn encode_all_matches_single() {
        let data = small_set();
        let cb = PqCodebook::train(&data, 4, 5, 17);
        let enc = PqEncoder::new(&cb);
        let packed = enc.encode_all(&data, 4);
        for i in [0usize, 5, 399] {
            assert_eq!(&packed[i * 4..(i + 1) * 4], enc.encode(&data.get_f32(i)).as_slice());
        }
    }

    use crate::util::XorShift;
}
