//! Product quantization (PQ): the lossy vector compression all the
//! disk-based baselines and PageANN's on-page/in-memory compressed neighbor
//! vectors use (paper §4.2–4.3).
//!
//! A vector of dimension `D` is split into `M` subspaces of `D/M` dims; each
//! subspace has a `K`-entry codebook trained by k-means (`K = 256` by
//! default, `K = 16` in the nibble-packed PQ4 fast-scan mode, which halves
//! the stored bytes per code). Query-time distance is *asymmetric* (ADC): a
//! per-query `M×K` lookup table of exact subspace distances, summed over the
//! code bytes — via an 8-wide gather for PQ8 and an in-register shuffle
//! over a u8-quantized table for PQ4 (see `distance::simd`).

mod codebook;
mod kmeans;
mod lut_cache;

pub use codebook::{
    pack_nibbles, storage_bytes, unpack_nibbles, AdcLut, LutArena, PqCode, PqCodebook, PqEncoder,
    PQ4_MAX_K,
};
pub use kmeans::kmeans;
pub use lut_cache::{LutCache, LutCacheStats};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, SynthSpec};
    use crate::util::XorShift;

    #[test]
    fn adc_preserves_neighbor_ranking_statistically() {
        // Train PQ on a clustered set; verify that the ADC-nearest of two
        // points at very different true distances agrees with the true
        // ordering in the vast majority of cases.
        let spec = SynthSpec::new(DatasetKind::DeepLike, 2000).with_dim(32).with_clusters(8);
        let base = spec.generate(5);
        let cb = PqCodebook::train(&base, 8, 16, 123);
        let enc = PqEncoder::new(&cb);
        let codes: Vec<PqCode> = (0..base.len()).map(|i| enc.encode(&base.get_f32(i))).collect();

        let mut rng = XorShift::new(99);
        let mut agree = 0usize;
        let trials = 300;
        for _ in 0..trials {
            let q = base.get_f32(rng.next_below(base.len()));
            let lut = cb.build_lut(&q);
            let a = rng.next_below(base.len());
            let b = rng.next_below(base.len());
            let ta = crate::distance::l2sq_f32(&q, &base.get_f32(a));
            let tb = crate::distance::l2sq_f32(&q, &base.get_f32(b));
            // Only count clearly-separated pairs (2x ratio).
            if ta.max(tb) < 2.0 * ta.min(tb) {
                agree += 1; // don't penalize ambiguous pairs
                continue;
            }
            let ea = lut.distance(&codes[a]);
            let eb = lut.distance(&codes[b]);
            if (ta < tb) == (ea < eb) {
                agree += 1;
            }
        }
        assert!(agree * 10 >= trials * 9, "ADC ranking agreement too low: {agree}/{trials}");
    }
}
