//! Lloyd's k-means with k-means++ seeding — the codebook trainer for PQ and
//! the cluster-head selector for the SPANN-like baseline.
#![deny(unsafe_op_in_unsafe_fn)]

use crate::distance::l2sq_f32;
use crate::util::{parallel_chunks, XorShift};

/// Result of a k-means run over row-major `data` (n × dim).
pub struct KmeansResult {
    /// k × dim centroids, row-major.
    pub centroids: Vec<f32>,
    /// Assignment of each input row to a centroid.
    pub assignment: Vec<u32>,
    pub k: usize,
    pub dim: usize,
}

impl KmeansResult {
    pub fn centroid(&self, c: usize) -> &[f32] {
        &self.centroids[c * self.dim..(c + 1) * self.dim]
    }

    /// Index of the centroid nearest to `v`.
    pub fn nearest(&self, v: &[f32]) -> usize {
        let mut best = 0usize;
        let mut bestd = f32::INFINITY;
        for c in 0..self.k {
            let d = l2sq_f32(v, self.centroid(c));
            if d < bestd {
                bestd = d;
                best = c;
            }
        }
        best
    }
}

/// Run k-means. `data` is row-major n×dim. Deterministic per seed.
///
/// Empty clusters are re-seeded from the point farthest from its centroid,
/// so exactly `k` non-degenerate centroids come back even for adversarial
/// inputs (k > #distinct points degrades gracefully to duplicated
/// centroids).
pub fn kmeans(data: &[f32], dim: usize, k: usize, iters: usize, seed: u64) -> KmeansResult {
    assert!(dim > 0 && data.len() % dim == 0);
    let n = data.len() / dim;
    assert!(n > 0, "kmeans on empty data");
    let k = k.min(n.max(1));
    let mut rng = XorShift::new(seed);
    let row = |i: usize| &data[i * dim..(i + 1) * dim];

    // k-means++ seeding on a bounded sample for cost control.
    let sample: Vec<usize> = if n > 16_384 {
        rng.sample_indices(n, 16_384)
    } else {
        (0..n).collect()
    };
    let mut centroids = Vec::with_capacity(k * dim);
    centroids.extend_from_slice(row(sample[rng.next_below(sample.len())]));
    let mut d2: Vec<f32> = sample.iter().map(|&i| l2sq_f32(row(i), &centroids[..dim])).collect();
    while centroids.len() < k * dim {
        let total: f64 = d2.iter().map(|&d| d as f64).sum();
        let pick = if total <= 0.0 {
            sample[rng.next_below(sample.len())]
        } else {
            let mut target = rng.next_f64() * total;
            let mut chosen = sample[sample.len() - 1];
            for (j, &i) in sample.iter().enumerate() {
                target -= d2[j] as f64;
                if target <= 0.0 {
                    chosen = i;
                    break;
                }
            }
            chosen
        };
        let start = centroids.len();
        centroids.extend_from_slice(row(pick));
        let newc = centroids[start..].to_vec();
        for (j, &i) in sample.iter().enumerate() {
            d2[j] = d2[j].min(l2sq_f32(row(i), &newc));
        }
    }

    let mut assignment = vec![0u32; n];
    let nthreads = crate::util::num_threads();
    for _ in 0..iters {
        // Assign (parallel over rows).
        {
            let centroids = &centroids;
            let assign_ptr = AssignPtr(assignment.as_mut_ptr());
            parallel_chunks(n, nthreads, |s, e| {
                let p = assign_ptr;
                for i in s..e {
                    let v = row(i);
                    let mut best = 0u32;
                    let mut bestd = f32::INFINITY;
                    for c in 0..k {
                        let d = l2sq_f32(v, &centroids[c * dim..(c + 1) * dim]);
                        if d < bestd {
                            bestd = d;
                            best = c as u32;
                        }
                    }
                    // SAFETY: i < n = assignment.len(), and parallel_chunks
                    // hands each worker a disjoint [s, e) range, so no two
                    // threads write the same slot.
                    unsafe { *p.0.add(i) = best };
                }
            });
        }
        // Update.
        let mut sums = vec![0f64; k * dim];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = assignment[i] as usize;
            counts[c] += 1;
            for (s, &x) in sums[c * dim..(c + 1) * dim].iter_mut().zip(row(i)) {
                *s += x as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed from the point farthest from its centroid.
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da = l2sq_f32(row(a), &centroids[assignment[a] as usize * dim..][..dim]);
                        let db = l2sq_f32(row(b), &centroids[assignment[b] as usize * dim..][..dim]);
                        da.total_cmp(&db)
                    })
                    .unwrap();
                centroids[c * dim..(c + 1) * dim].copy_from_slice(row(far));
            } else {
                for j in 0..dim {
                    centroids[c * dim + j] = (sums[c * dim + j] / counts[c] as f64) as f32;
                }
            }
        }
    }

    // Final assignment pass so assignment matches returned centroids.
    {
        let centroids = &centroids;
        let assign_ptr = AssignPtr(assignment.as_mut_ptr());
        parallel_chunks(n, nthreads, |s, e| {
            let p = assign_ptr;
            for i in s..e {
                let v = row(i);
                let mut best = 0u32;
                let mut bestd = f32::INFINITY;
                for c in 0..k {
                    let d = l2sq_f32(v, &centroids[c * dim..(c + 1) * dim]);
                    if d < bestd {
                        bestd = d;
                        best = c as u32;
                    }
                }
                // SAFETY: i < n = assignment.len(), and parallel_chunks
                // hands each worker a disjoint [s, e) range, so no two
                // threads write the same slot.
                unsafe { *p.0.add(i) = best };
            }
        });
    }

    KmeansResult { centroids, assignment, k, dim }
}

#[derive(Clone, Copy)]
struct AssignPtr(*mut u32);
// SAFETY: shipped across parallel_chunks workers that write disjoint index
// ranges of the underlying `assignment` vec, which outlives every worker
// (parallel_chunks joins before returning).
unsafe impl Send for AssignPtr {}
// SAFETY: as above — the pointer is only used for disjoint-range writes,
// so shared references between workers cannot race.
unsafe impl Sync for AssignPtr {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separates_two_obvious_clusters() {
        // Points at 0 and at 100.
        let mut data = Vec::new();
        for i in 0..20 {
            let base = if i < 10 { 0.0 } else { 100.0 };
            data.extend_from_slice(&[base + (i % 10) as f32 * 0.1, base]);
        }
        let r = kmeans(&data, 2, 2, 10, 1);
        assert_eq!(r.k, 2);
        // All first-10 same cluster, all last-10 the other.
        let a = r.assignment[0];
        assert!(r.assignment[..10].iter().all(|&c| c == a));
        assert!(r.assignment[10..].iter().all(|&c| c != a));
        // Centroids near 0 and 100.
        let c0 = r.centroid(r.assignment[0] as usize)[1];
        let c1 = r.centroid(r.assignment[10] as usize)[1];
        assert!(c0.abs() < 5.0 && (c1 - 100.0).abs() < 5.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let data: Vec<f32> = (0..200).map(|i| (i * 7 % 31) as f32).collect();
        let a = kmeans(&data, 4, 5, 8, 9);
        let b = kmeans(&data, 4, 5, 8, 9);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn k_clamped_to_n() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0];
        let r = kmeans(&data, 2, 10, 3, 0);
        assert_eq!(r.k, 2);
        assert_eq!(r.assignment.len(), 2);
    }

    #[test]
    fn assignment_is_nearest_centroid() {
        let data: Vec<f32> = (0..300).map(|i| ((i * 13) % 97) as f32 / 10.0).collect();
        let r = kmeans(&data, 3, 6, 10, 2);
        for i in 0..100 {
            let v = &data[i * 3..(i + 1) * 3];
            assert_eq!(r.assignment[i] as usize, r.nearest(v), "row {i}");
        }
    }

    #[test]
    fn duplicate_points_do_not_crash() {
        let data = vec![5.0f32; 50 * 2];
        let r = kmeans(&data, 2, 8, 5, 3);
        assert_eq!(r.assignment.len(), 50);
    }
}
