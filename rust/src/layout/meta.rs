//! Index metadata header (meta.bin).

use crate::dataset::Dtype;
use crate::util::checked::{to_u32, to_usize, Ix};
use crate::util::{ReadExt, WriteExt};
use crate::Result;
use std::io::{Read, Write};
use std::path::Path;

pub const MAGIC: u32 = 0x50414E4E; // "PANN"
/// On-disk format version. v4: PQ4 support — when `pq_k ≤ 16`, every code
/// artifact (inline page codes, memcodes.bin) stores nibble-packed
/// `⌈pq_m/2⌉`-byte codes instead of `pq_m` bytes; readers derive the stride
/// from [`IndexMeta::code_bytes`]. v3 indexes with `pq_k > 16` are
/// byte-identical, but the version gate forces a rebuild rather than risk a
/// silent stride mismatch on small-k indexes. v5: per-page CRC32C in the
/// last 4 bytes of every page ([`IndexMeta::page_crc`]); v4 indexes load
/// unchanged with `page_crc = false`, since the payload offsets are
/// identical — only the tail reservation differs.
pub const VERSION: u32 = 5;

/// Last version whose pages carry no checksum tail.
pub const LEGACY_UNCHECKSUMMED_VERSION: u32 = 4;

/// Where compressed neighbor vectors live (paper §4.3 memory-disk
/// coordination).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CvPlacement {
    /// All codes inline on their referencing pages (severe memory pressure).
    OnPage,
    /// Codes of the hottest `frac` of vectors in memory, rest on page.
    Hybrid { mem_frac: f64 },
    /// All codes in memory; pages carry none and fit more vectors.
    InMemory,
}

impl CvPlacement {
    pub fn mem_frac(&self) -> f64 {
        match self {
            CvPlacement::OnPage => 0.0,
            CvPlacement::Hybrid { mem_frac } => *mem_frac,
            CvPlacement::InMemory => 1.0,
        }
    }

    fn tag(&self) -> u8 {
        match self {
            CvPlacement::OnPage => 0,
            CvPlacement::Hybrid { .. } => 1,
            CvPlacement::InMemory => 2,
        }
    }
}

/// Everything the query engine needs to interpret the index files.
#[derive(Debug, Clone)]
pub struct IndexMeta {
    pub dtype: Dtype,
    pub dim: usize,
    /// Original vector count.
    pub n_vectors: usize,
    pub n_pages: usize,
    pub page_size: usize,
    /// Max vectors per page node; `page(id) = id / capacity` in new-id space.
    pub capacity: usize,
    /// Neighbor-entry budget used when sizing pages.
    pub max_nbrs: usize,
    pub pq_m: usize,
    pub pq_k: usize,
    pub cv_placement: CvPlacement,
    /// Entry point (new-id space) when routing returns nothing.
    pub medoid_new_id: u32,
    /// LSH routing bits (0 = no routing index on disk).
    pub routing_bits: usize,
    /// Pages carry a CRC32C in their last 4 bytes (v5+ builds). Legacy v4
    /// indexes load with this false and skip verification.
    pub page_crc: bool,
}

impl IndexMeta {
    pub fn vec_stride(&self) -> usize {
        self.dim * self.dtype.size_bytes()
    }

    /// Bytes per stored PQ code: nibble-packed `⌈pq_m/2⌉` when the index
    /// was built with a PQ4 codebook (`pq_k ≤ 16`), `pq_m` otherwise.
    /// Delegates to [`crate::pq::storage_bytes`] — one packing rule shared
    /// with the codebook — and is the stride readers use for page parsing
    /// and memcodes.
    pub fn code_bytes(&self) -> usize {
        crate::pq::storage_bytes(self.pq_m, self.pq_k)
    }

    /// Total new-id slots (some unused on partially-filled pages).
    pub fn n_slots(&self) -> usize {
        self.n_pages * self.capacity
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_u32(MAGIC)?;
        w.write_u32(if self.page_crc { VERSION } else { LEGACY_UNCHECKSUMMED_VERSION })?;
        w.write_u8(self.dtype.tag())?;
        w.write_u32(to_u32(self.dim)?)?;
        w.write_u64(self.n_vectors as u64)?;
        w.write_u64(self.n_pages as u64)?;
        w.write_u32(to_u32(self.page_size)?)?;
        w.write_u32(to_u32(self.capacity)?)?;
        w.write_u32(to_u32(self.max_nbrs)?)?;
        w.write_u32(to_u32(self.pq_m)?)?;
        w.write_u32(to_u32(self.pq_k)?)?;
        w.write_u8(self.cv_placement.tag())?;
        w.write_f32(self.cv_placement.mem_frac() as f32)?;
        w.write_u32(self.medoid_new_id)?;
        w.write_u32(to_u32(self.routing_bits)?)?;
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        anyhow::ensure!(r.read_u32v()? == MAGIC, "bad magic (not a PageANN index)");
        let v = r.read_u32v()?;
        anyhow::ensure!(
            v == LEGACY_UNCHECKSUMMED_VERSION || v == VERSION,
            "index version {v} not in supported range {LEGACY_UNCHECKSUMMED_VERSION}..={VERSION}"
        );
        let page_crc = v >= VERSION;
        let dtype = Dtype::from_tag(r.read_u8v()?)?;
        let dim = r.read_u32v()?.ix();
        let n_vectors = to_usize(r.read_u64v()?)?;
        let n_pages = to_usize(r.read_u64v()?)?;
        let page_size = r.read_u32v()?.ix();
        let capacity = r.read_u32v()?.ix();
        let max_nbrs = r.read_u32v()?.ix();
        let pq_m = r.read_u32v()?.ix();
        let pq_k = r.read_u32v()?.ix();
        let tag = r.read_u8v()?;
        let frac = r.read_f32v()? as f64;
        let cv_placement = match tag {
            0 => CvPlacement::OnPage,
            1 => CvPlacement::Hybrid { mem_frac: frac },
            2 => CvPlacement::InMemory,
            _ => anyhow::bail!("unknown cv placement tag {tag}"),
        };
        let medoid_new_id = r.read_u32v()?;
        let routing_bits = r.read_u32v()?.ix();
        anyhow::ensure!(dim > 0 && capacity > 0 && page_size >= 512, "corrupt meta");
        Ok(Self {
            dtype,
            dim,
            n_vectors,
            n_pages,
            page_size,
            capacity,
            max_nbrs,
            pq_m,
            pq_k,
            cv_placement,
            medoid_new_id,
            routing_bits,
            page_crc,
        })
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(dir.join("meta.bin"))?);
        self.write_to(&mut f)
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(std::fs::File::open(dir.join("meta.bin"))?);
        Self::read_from(&mut f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> IndexMeta {
        IndexMeta {
            dtype: Dtype::U8,
            dim: 128,
            n_vectors: 100_000,
            n_pages: 4000,
            page_size: 4096,
            capacity: 25,
            max_nbrs: 48,
            pq_m: 16,
            pq_k: 256,
            cv_placement: CvPlacement::Hybrid { mem_frac: 0.5 },
            medoid_new_id: 17,
            routing_bits: 32,
            page_crc: true,
        }
    }

    #[test]
    fn roundtrip() {
        let m = meta();
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        let back = IndexMeta::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.dim, 128);
        assert_eq!(back.n_pages, 4000);
        assert_eq!(back.capacity, 25);
        assert!(matches!(back.cv_placement, CvPlacement::Hybrid { mem_frac } if (mem_frac - 0.5).abs() < 1e-6));
        assert_eq!(back.medoid_new_id, 17);
        assert_eq!(back.n_slots(), 100_000);
        assert!(back.page_crc);
    }

    #[test]
    fn legacy_v4_loads_without_crc() {
        // An un-checksummed index writes the legacy version word and reads
        // back with `page_crc = false` — old indexes keep loading.
        let mut m = meta();
        m.page_crc = false;
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        assert_eq!(
            u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]),
            LEGACY_UNCHECKSUMMED_VERSION
        );
        let back = IndexMeta::read_from(&mut buf.as_slice()).unwrap();
        assert!(!back.page_crc);
        assert_eq!(back.dim, 128);
    }

    #[test]
    fn code_bytes_tracks_pq_k() {
        let mut m = meta();
        assert_eq!(m.code_bytes(), 16); // pq_k = 256 → one byte per subspace
        m.pq_k = 16;
        assert_eq!(m.code_bytes(), 8); // PQ4 → nibble-packed
        m.pq_m = 5;
        assert_eq!(m.code_bytes(), 3); // odd m rounds up
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = Vec::new();
        buf.write_u32(0xDEAD).unwrap();
        buf.write_u32(VERSION).unwrap();
        assert!(IndexMeta::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn wrong_version_rejected() {
        let m = meta();
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        buf[4] = 99;
        assert!(IndexMeta::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("pageann-meta-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        meta().save(&dir).unwrap();
        let back = IndexMeta::load(&dir).unwrap();
        assert_eq!(back.n_vectors, 100_000);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
