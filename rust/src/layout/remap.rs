//! Vector-id reassignment tables (paper §5: "Vector ID reassignment").
//!
//! After page grouping, vectors get new ids `page_idx * capacity + offset`
//! so the searcher recovers the page of any id with one division. Pages may
//! be partially filled, so the new-id space has holes (`INVALID`).

use crate::util::checked::{to_u32, to_usize, Ix};
use crate::util::{ReadExt, WriteExt};
use crate::Result;
use std::io::{Read, Write};
use std::path::Path;

pub const INVALID: u32 = u32::MAX;

#[derive(Debug, Clone)]
pub struct IdRemap {
    /// new-id (slot) → original id, `INVALID` for unused slots.
    pub new_to_orig: Vec<u32>,
    /// original id → new id.
    pub orig_to_new: Vec<u32>,
    pub capacity: usize,
}

impl IdRemap {
    /// Build from the page grouping: `pages[p]` = original ids in page `p`.
    pub fn from_pages(pages: &[Vec<u32>], capacity: usize, n_vectors: usize) -> Self {
        let mut new_to_orig = vec![INVALID; pages.len() * capacity];
        let mut orig_to_new = vec![INVALID; n_vectors];
        for (p, members) in pages.iter().enumerate() {
            assert!(members.len() <= capacity, "page {p} overfull");
            for (off, &orig) in members.iter().enumerate() {
                let new_id = u32::try_from(p * capacity + off).expect("slot id fits u32");
                new_to_orig[new_id.ix()] = orig;
                debug_assert_eq!(orig_to_new[orig.ix()], INVALID, "vector {orig} grouped twice");
                orig_to_new[orig.ix()] = new_id;
            }
        }
        Self { new_to_orig, orig_to_new, capacity }
    }

    #[inline]
    pub fn page_of(&self, new_id: u32) -> u32 {
        // lint:allow(truncating-cast): capacity is vectors-per-page (tens),
        // checked > 0 at load; it always fits u32, and this division is on
        // the per-hop hot path.
        new_id / self.capacity as u32
    }

    #[inline]
    pub fn to_orig(&self, new_id: u32) -> u32 {
        self.new_to_orig[new_id.ix()]
    }

    #[inline]
    pub fn to_new(&self, orig_id: u32) -> u32 {
        self.orig_to_new[orig_id.ix()]
    }

    pub fn n_slots(&self) -> usize {
        self.new_to_orig.len()
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_u32(to_u32(self.capacity)?)?;
        w.write_u64(self.new_to_orig.len() as u64)?;
        w.write_u64(self.orig_to_new.len() as u64)?;
        w.write_u32_slice(&self.new_to_orig)?;
        w.write_u32_slice(&self.orig_to_new)?;
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        let capacity = r.read_u32v()?.ix();
        anyhow::ensure!(capacity > 0, "corrupt remap");
        let n_new = to_usize(r.read_u64v()?)?;
        let n_orig = to_usize(r.read_u64v()?)?;
        let new_to_orig = r.read_u32_vec(n_new)?;
        let orig_to_new = r.read_u32_vec(n_orig)?;
        Ok(Self { new_to_orig, orig_to_new, capacity })
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(dir.join("remap.bin"))?);
        self.write_to(&mut f)
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(std::fs::File::open(dir.join("remap.bin"))?);
        Self::read_from(&mut f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bijection_over_valid_slots() {
        let pages = vec![vec![5u32, 2], vec![0u32, 1, 3], vec![4u32]];
        let r = IdRemap::from_pages(&pages, 3, 6);
        assert_eq!(r.n_slots(), 9);
        for orig in 0..6u32 {
            let n = r.to_new(orig);
            assert_ne!(n, INVALID);
            assert_eq!(r.to_orig(n), orig);
        }
        // Page lookup.
        assert_eq!(r.page_of(r.to_new(5)), 0);
        assert_eq!(r.page_of(r.to_new(3)), 1);
        assert_eq!(r.page_of(r.to_new(4)), 2);
        // Holes are INVALID.
        assert_eq!(r.to_orig(2), INVALID); // page0 slot 2 unused
    }

    #[test]
    #[should_panic(expected = "overfull")]
    fn overfull_page_panics() {
        let pages = vec![vec![0u32, 1, 2, 3]];
        let _ = IdRemap::from_pages(&pages, 3, 4);
    }

    #[test]
    fn serialization_roundtrip() {
        let pages = vec![vec![1u32, 0], vec![2u32]];
        let r = IdRemap::from_pages(&pages, 2, 3);
        let mut buf = Vec::new();
        r.write_to(&mut buf).unwrap();
        let back = IdRemap::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.new_to_orig, r.new_to_orig);
        assert_eq!(back.orig_to_new, r.orig_to_new);
        assert_eq!(back.capacity, 2);
    }
}
