//! Index build pipeline: Vamana → page-node graph → on-disk layout.
//!
//! This is the pre-processing stage of Fig. 3: it owns every build-time
//! decision (page capacity from the §4.2 equation, compressed-vector
//! placement from the §4.3 memory budget, representative selection) and
//! writes the final file set.

use crate::dataset::VectorSet;
use crate::layout::{page_capacity, CvPlacement, IdRemap, IndexMeta, PageWriter};
use crate::pagegraph::{build_page_graph, GroupingParams, PageGraph};
use crate::pq::{PqCodebook, PqEncoder};
use crate::routing::RoutingIndex;
use crate::util::checked::{to_u32, Ix};
use crate::util::{Stopwatch, WriteExt};
use crate::vamana::{VamanaGraph, VamanaParams};
use crate::Result;
use std::io::Write;
use std::path::{Path, PathBuf};

/// All build-time knobs. Defaults mirror the paper's SIFT configuration.
#[derive(Debug, Clone)]
pub struct BuildConfig {
    pub page_size: usize,
    /// Neighbor-entry budget per page (NB in DESIGN.md).
    pub max_nbrs: usize,
    /// Representatives per neighboring page.
    pub reps_per_page: usize,
    /// Hop bound `h` for grouping.
    pub hops: usize,
    /// PQ subspaces (must divide dim).
    pub pq_m: usize,
    /// Centroids per subspace (2..=256). `≤ 16` selects the nibble-packed
    /// PQ4 layout: half the inline-code bytes per page and the fast-scan
    /// shuffle ADC at query time.
    pub pq_k: usize,
    pub pq_train_iters: usize,
    /// Compressed-vector placement (§4.3). Drives page capacity.
    pub cv_placement: CvPlacement,
    /// LSH routing: #hyperplanes (0 disables) and sample fraction.
    pub routing_bits: usize,
    pub routing_sample_frac: f64,
    pub vamana: VamanaParams,
    pub seed: u64,
}

impl Default for BuildConfig {
    fn default() -> Self {
        Self {
            page_size: super::DEFAULT_PAGE_SIZE,
            max_nbrs: 48,
            reps_per_page: 2,
            hops: 2,
            pq_m: 16,
            pq_k: 256,
            pq_train_iters: 12,
            cv_placement: CvPlacement::OnPage,
            routing_bits: 32,
            routing_sample_frac: 0.01,
            vamana: VamanaParams::default(),
            seed: 42,
        }
    }
}

/// Paths of a built index.
#[derive(Debug, Clone)]
pub struct IndexFiles {
    pub dir: PathBuf,
}

impl IndexFiles {
    pub fn new(dir: &Path) -> Self {
        Self { dir: dir.to_path_buf() }
    }
    pub fn pages(&self) -> PathBuf {
        self.dir.join("pages.bin")
    }
    pub fn pq(&self) -> PathBuf {
        self.dir.join("pq.bin")
    }
    pub fn memcodes(&self) -> PathBuf {
        self.dir.join("memcodes.bin")
    }
    pub fn routing(&self) -> PathBuf {
        self.dir.join("routing.bin")
    }
}

/// Timings of the build phases (Table 5's construction column).
#[derive(Debug, Clone, Default)]
pub struct BuildReport {
    pub vamana_secs: f64,
    pub pq_secs: f64,
    pub grouping_secs: f64,
    pub write_secs: f64,
    pub n_pages: usize,
    pub capacity: usize,
    pub avg_page_degree: f64,
    /// Neighbor entries whose codes were dropped to fit pages.
    pub truncated_nbrs: usize,
}

impl BuildReport {
    pub fn total_secs(&self) -> f64 {
        self.vamana_secs + self.pq_secs + self.grouping_secs + self.write_secs
    }
}

pub struct IndexBuilder<'a> {
    pub base: &'a VectorSet,
    pub config: BuildConfig,
}

impl<'a> IndexBuilder<'a> {
    pub fn new(base: &'a VectorSet, config: BuildConfig) -> Self {
        Self { base, config }
    }

    /// Build everything and write the index into `dir`.
    pub fn build(&self, dir: &Path) -> Result<BuildReport> {
        std::fs::create_dir_all(dir)?;
        let cfg = &self.config;
        let base = self.base;
        anyhow::ensure!(base.dim() % cfg.pq_m == 0, "pq_m {} must divide dim {}", cfg.pq_m, base.dim());
        anyhow::ensure!((2..=256).contains(&cfg.pq_k), "pq_k {} out of range", cfg.pq_k);
        let mut report = BuildReport::default();
        let mut sw = Stopwatch::new();

        // 1. Vector-level Vamana graph.
        sw.start();
        let graph = VamanaGraph::build(base, &cfg.vamana);
        sw.stop();
        report.vamana_secs = sw.total().as_secs_f64();
        sw.reset();

        // 2. PQ codebooks + all codes (stored width: nibble-packed for PQ4).
        sw.start();
        let cb = PqCodebook::train_with_k(base, cfg.pq_m, cfg.pq_k, cfg.pq_train_iters, cfg.seed ^ 0xC0DE);
        let encoder = PqEncoder::new(&cb);
        let codes = encoder.encode_all(base, cfg.vamana.nthreads);
        let code_w = cb.code_bytes();
        sw.stop();
        report.pq_secs = sw.total().as_secs_f64();
        sw.reset();

        // 3. Page capacity from the §4.2 equation (with the *stored* code
        //    width — PQ4 pages fit more), then grouping + page graph
        //    derivation.
        sw.start();
        let capacity = page_capacity(
            cfg.page_size,
            base.dim() * base.dtype().size_bytes(),
            cfg.max_nbrs,
            code_w,
            cfg.cv_placement.mem_frac(),
        );
        let grouping = GroupingParams { capacity, hops: cfg.hops, seed: cfg.seed };
        let pg = build_page_graph(base, &graph, &grouping, cfg.max_nbrs, cfg.reps_per_page);
        sw.stop();
        report.grouping_secs = sw.total().as_secs_f64();
        sw.reset();
        report.n_pages = pg.n_pages();
        report.capacity = capacity;
        report.avg_page_degree = pg.avg_page_degree();

        // 4. Compressed-vector placement: the most-referenced neighbors go
        //    to memory (§4.3 — one copy total, memory preferred for the
        //    hottest codes since they save the most page space).
        let mem_code_ids = self.select_mem_codes(&pg);

        // 5. Write files.
        sw.start();
        report.truncated_nbrs = self.write_pages(dir, &pg, &codes, code_w, &mem_code_ids)?;
        self.write_memcodes(dir, &pg.remap, &codes, code_w, &mem_code_ids)?;
        {
            let mut f = std::io::BufWriter::new(std::fs::File::create(IndexFiles::new(dir).pq())?);
            cb.write_to(&mut f)?;
        }
        pg.remap.save(dir)?;
        let routing = self.build_routing(&pg.remap)?;
        if let Some(r) = &routing {
            let mut f =
                std::io::BufWriter::new(std::fs::File::create(IndexFiles::new(dir).routing())?);
            r.write_to(&mut f)?;
        }
        let meta = IndexMeta {
            dtype: base.dtype(),
            dim: base.dim(),
            n_vectors: base.len(),
            n_pages: pg.n_pages(),
            page_size: cfg.page_size,
            capacity,
            max_nbrs: cfg.max_nbrs,
            pq_m: cb.m,
            pq_k: cb.k,
            cv_placement: cfg.cv_placement,
            medoid_new_id: pg.remap.to_new(graph.medoid),
            routing_bits: routing.as_ref().map(|r| r.bits).unwrap_or(0),
            page_crc: true,
        };
        meta.save(dir)?;
        sw.stop();
        report.write_secs = sw.total().as_secs_f64();
        Ok(report)
    }

    /// Pick which vectors' codes live in memory: rank by how often they are
    /// referenced as page neighbors; routing samples are added by
    /// `write_memcodes` unconditionally.
    fn select_mem_codes(&self, pg: &PageGraph) -> Vec<bool> {
        let frac = self.config.cv_placement.mem_frac();
        let n_slots = pg.remap.n_slots();
        let mut in_mem = vec![false; n_slots];
        if frac <= 0.0 {
            return in_mem;
        }
        if frac >= 1.0 {
            for s in 0..n_slots {
                // lint:allow(truncating-cast): slot ids fit u32 by
                // construction — the remap stores them as u32.
                if pg.remap.to_orig(s as u32) != super::remap::INVALID {
                    in_mem[s] = true;
                }
            }
            return in_mem;
        }
        let mut refcount = vec![0u32; n_slots];
        for nbrs in &pg.nbrs {
            for &nb in nbrs {
                refcount[nb.ix()] += 1;
            }
        }
        // lint:allow(truncating-cast): frac < 1 here, so the f64 product is
        // strictly below base.len() (a usize) — the cast cannot truncate.
        let budget = ((self.base.len() as f64) * frac) as usize;
        // lint:allow(truncating-cast): slot ids fit u32 by construction —
        // the remap stores them as u32.
        let mut ranked: Vec<u32> = (0..n_slots as u32)
            .filter(|&s| refcount[s.ix()] > 0)
            .collect();
        ranked.sort_by(|&a, &b| {
            refcount[b.ix()]
                .cmp(&refcount[a.ix()])
                .then(a.cmp(&b))
        });
        for &s in ranked.iter().take(budget) {
            in_mem[s.ix()] = true;
        }
        in_mem
    }

    fn write_pages(
        &self,
        dir: &Path,
        pg: &PageGraph,
        codes: &[u8],
        code_w: usize,
        mem_code_ids: &[bool],
    ) -> Result<usize> {
        let cfg = &self.config;
        let base = self.base;
        let files = IndexFiles::new(dir);
        let mut f = std::io::BufWriter::new(std::fs::File::create(files.pages())?);
        let mut buf = vec![0u8; cfg.page_size];
        let mut truncated = 0usize;
        for (p, members) in pg.pages.iter().enumerate() {
            let vectors: Vec<(u32, &[u8])> =
                members.iter().map(|&orig| (orig, base.raw(orig.ix()))).collect();
            let neighbors: Vec<(u32, Option<&[u8]>)> = pg.nbrs[p]
                .iter()
                .map(|&nb| {
                    let orig = pg.remap.to_orig(nb).ix();
                    let code = if mem_code_ids[nb.ix()] {
                        None
                    } else {
                        Some(&codes[orig * code_w..(orig + 1) * code_w])
                    };
                    (nb, code)
                })
                .collect();
            let mut w = PageWriter {
                page_size: cfg.page_size,
                vec_stride: base.dim() * base.dtype().size_bytes(),
                code_bytes: code_w,
                checksum: true,
                vectors,
                neighbors,
            };
            let before = w.neighbors.len();
            w.truncate_to_fit();
            truncated += before - w.neighbors.len();
            w.serialize_into(&mut buf)?;
            f.write_all(&buf)?;
        }
        f.flush()?;
        Ok(truncated)
    }

    fn write_memcodes(
        &self,
        dir: &Path,
        remap: &IdRemap,
        codes: &[u8],
        code_w: usize,
        mem_code_ids: &[bool],
    ) -> Result<()> {
        // Routing-sampled vectors must have in-memory codes for entry-point
        // distance estimation; include them too.
        let routing_ids = self.routing_sample_ids(remap);
        let mut ids: Vec<u32> = mem_code_ids
            .iter()
            .enumerate()
            .filter(|&(_, &b)| b)
            // lint:allow(truncating-cast): slot ids fit u32 by construction —
            // the remap stores them as u32.
            .map(|(s, _)| s as u32)
            .collect();
        ids.extend(routing_ids);
        ids.sort();
        ids.dedup();

        let files = IndexFiles::new(dir);
        let mut f = std::io::BufWriter::new(std::fs::File::create(files.memcodes())?);
        // Header stores the *storage* stride (nibble-packed for PQ4).
        f.write_u32(to_u32(code_w)?)?;
        f.write_u64(ids.len() as u64)?;
        for &new_id in &ids {
            let orig = remap.to_orig(new_id).ix();
            f.write_u32(new_id)?;
            f.write_all(&codes[orig * code_w..(orig + 1) * code_w])?;
        }
        f.flush()?;
        Ok(())
    }

    /// The deterministic sample the routing index will contain (new ids).
    fn routing_sample_ids(&self, remap: &IdRemap) -> Vec<u32> {
        if self.config.routing_bits == 0 {
            return Vec::new();
        }
        RoutingIndex::sample_ids(
            self.base.len(),
            self.config.routing_sample_frac,
            self.config.seed ^ 0x40C7,
        )
        .into_iter()
        .map(|orig| remap.to_new(orig))
        .collect()
    }

    fn build_routing(&self, remap: &IdRemap) -> Result<Option<RoutingIndex>> {
        if self.config.routing_bits == 0 {
            return Ok(None);
        }
        // Build over original vectors, then remap bucket ids into new-id
        // space (the search operates entirely on new ids). The sample is
        // exactly `routing_sample_ids`, whose codes write_memcodes pinned
        // in memory.
        let sample = RoutingIndex::sample_ids(
            self.base.len(),
            self.config.routing_sample_frac,
            self.config.seed ^ 0x40C7,
        );
        let mut idx = RoutingIndex::build_with_sample(
            self.base,
            &sample,
            self.config.routing_bits,
            self.config.seed ^ 0x40C7,
        );
        for ids in idx.buckets.values_mut() {
            for id in ids.iter_mut() {
                *id = remap.to_new(*id);
            }
        }
        Ok(Some(idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, SynthSpec};

    #[test]
    fn build_writes_consistent_files() {
        let spec = SynthSpec::new(DatasetKind::SiftLike, 400).with_dim(32).with_clusters(4);
        let base = spec.generate(19);
        let dir = std::env::temp_dir().join(format!("pageann-build-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = BuildConfig {
            pq_m: 8,
            vamana: VamanaParams { r: 10, l_build: 20, alpha: 1.2, seed: 3, nthreads: 2 },
            ..Default::default()
        };
        let report = IndexBuilder::new(&base, cfg.clone()).build(&dir).unwrap();
        assert!(report.n_pages > 0);
        assert!(report.capacity > 1);
        assert!(report.total_secs() > 0.0);

        // Files exist and are consistent.
        let meta = IndexMeta::load(&dir).unwrap();
        assert_eq!(meta.n_vectors, 400);
        assert_eq!(meta.n_pages, report.n_pages);
        let pages_len = std::fs::metadata(dir.join("pages.bin")).unwrap().len() as usize;
        assert_eq!(pages_len, meta.n_pages * meta.page_size);
        let remap = IdRemap::load(&dir).unwrap();
        assert_eq!(remap.capacity, meta.capacity);
        // Every page parses.
        let bytes = std::fs::read(dir.join("pages.bin")).unwrap();
        let mut total_vecs = 0usize;
        for p in 0..meta.n_pages {
            let pr = crate::layout::PageRef::parse(
                &bytes[p * meta.page_size..(p + 1) * meta.page_size],
                meta.vec_stride(),
                meta.code_bytes(),
            )
            .unwrap();
            total_vecs += pr.n_vecs();
            for j in 0..pr.n_nbrs() {
                let nb = pr.nbr_id(j);
                assert!((nb as usize) < remap.n_slots());
                assert_ne!(remap.page_of(nb) as usize, p);
            }
        }
        assert_eq!(total_vecs, 400);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pq4_build_packs_nibble_codes_and_fits_more() {
        let spec = SynthSpec::new(DatasetKind::SiftLike, 400).with_dim(32).with_clusters(4);
        let base = spec.generate(23);
        let dir = std::env::temp_dir().join(format!("pageann-build4-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = BuildConfig {
            pq_m: 8,
            pq_k: 16,
            vamana: VamanaParams { r: 10, l_build: 20, alpha: 1.2, seed: 3, nthreads: 2 },
            ..Default::default()
        };
        let report = IndexBuilder::new(&base, cfg).build(&dir).unwrap();
        let meta = IndexMeta::load(&dir).unwrap();
        assert_eq!(meta.pq_k, 16);
        assert_eq!(meta.code_bytes(), 4); // m=8 nibble-packed
        // PQ4 halves inline-code bytes, so capacity must be ≥ the PQ8 run
        // with otherwise identical geometry.
        let pq8_capacity = crate::layout::page_capacity(
            meta.page_size,
            meta.vec_stride(),
            meta.max_nbrs,
            8,
            0.0,
        );
        assert!(report.capacity >= pq8_capacity, "{} < {pq8_capacity}", report.capacity);
        // Every page parses with the packed stride and codes are in range.
        let bytes = std::fs::read(dir.join("pages.bin")).unwrap();
        for p in 0..meta.n_pages {
            let pr = crate::layout::PageRef::parse(
                &bytes[p * meta.page_size..(p + 1) * meta.page_size],
                meta.vec_stride(),
                meta.code_bytes(),
            )
            .unwrap();
            for j in 0..pr.n_nbrs() {
                if let Some(code) = pr.nbr_code(j) {
                    assert_eq!(code.len(), meta.code_bytes());
                }
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
