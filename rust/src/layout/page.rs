//! Page serialization: the exact byte layout of one SSD page (Fig. 5).
//!
//! ```text
//! [u16 n_vecs][u16 n_nbrs][u8 flags]          5-byte header
//! [orig ids:  u32 × n_vecs]                   result reporting
//! [vectors:   n_vecs × stride]                exact distances
//! [nbr ids:   u32 × n_nbrs]                   topology (new-id space)
//! [bitmap:    ceil(n_nbrs/8)]                 iff flags&1: bit=code inline
//! [codes:     code_bytes × (#inline)]         ADC next-hop selection
//! ```
//!
//! `code_bytes` is the *storage* width of one PQ code: `M` bytes for PQ8,
//! `⌈M/2⌉` nibble-packed bytes for PQ4 (`meta.pq_k ≤ 16`) — so a PQ4 index
//! spends half the inline-code bytes and packs more neighbors (or vectors)
//! per page. This module is width-agnostic: it only moves opaque
//! `code_bytes`-sized blobs; `IndexMeta::code_bytes()` is the single source
//! of the stride at parse time.
//!
//! `PageRef` is a zero-copy view over a page buffer; the searcher never
//! materializes an owned page.
//!
//! # Page integrity (ISSUE 6)
//!
//! Checksummed pages (meta v5, `IndexMeta::page_crc`) reserve their **last
//! 4 bytes** for a CRC32C over the rest of the page, written by
//! [`PageWriter::serialize_into`] when `checksum` is set. The tail position
//! keeps every payload offset identical to the legacy layout, so v4 indexes
//! parse with the same code and readers opt into verification via
//! [`PageRef::verify_checksum`] / [`PageRef::parse_verified`]. Corruption
//! anywhere in the page — a flipped bit, a torn write zeroing the tail, a
//! misdirected read returning the wrong page image — fails verification
//! instead of being silently scored.

use crate::util::checked::{to_u16, Ix};
use crate::util::crc32c;
use crate::Result;

pub const PAGE_HEADER_BYTES: usize = 5;
pub const OVERHEAD_PER_NBR_ID: usize = 4;
/// Tail bytes reserved for the page CRC32C (checksummed layouts only).
pub const PAGE_CRC_BYTES: usize = 4;

const FLAG_BITMAP: u8 = 1;

/// Serializer for one page.
pub struct PageWriter<'a> {
    pub page_size: usize,
    pub vec_stride: usize,
    pub code_bytes: usize,
    /// Write a CRC32C into the page's last 4 bytes (meta v5 layout); those
    /// bytes are then off-limits to payload.
    pub checksum: bool,
    /// (orig_id, raw vector bytes) of the page node's members.
    pub vectors: Vec<(u32, &'a [u8])>,
    /// (new_id, Option<code>) neighbor entries; `None` = code lives in
    /// memory at query time.
    pub neighbors: Vec<(u32, Option<&'a [u8]>)>,
}

impl<'a> PageWriter<'a> {
    /// Exact serialized size for the current contents.
    pub fn serialized_size(&self) -> usize {
        let inline = self.neighbors.iter().filter(|(_, c)| c.is_some()).count();
        let any_memory = self.neighbors.iter().any(|(_, c)| c.is_none());
        let bitmap = if any_memory && inline > 0 {
            crate::util::div_ceil(self.neighbors.len(), 8)
        } else if any_memory {
            // all-memory: bitmap still written (all zeros) when mixed mode
            // is possible; we omit it and clear the flag instead.
            0
        } else {
            0
        };
        PAGE_HEADER_BYTES
            + self.vectors.len() * (4 + self.vec_stride)
            + self.neighbors.len() * 4
            + bitmap
            + inline * self.code_bytes
            + if self.checksum { PAGE_CRC_BYTES } else { 0 }
    }

    /// True if the contents fit the page.
    pub fn fits(&self) -> bool {
        self.serialized_size() <= self.page_size
    }

    /// Drop lowest-priority neighbors (the tail — callers pre-sort by
    /// priority) until the page fits.
    pub fn truncate_to_fit(&mut self) {
        while !self.fits() && !self.neighbors.is_empty() {
            self.neighbors.pop();
        }
    }

    /// Serialize into `out` (must be exactly `page_size`; tail is zeroed).
    pub fn serialize_into(&self, out: &mut [u8]) -> Result<()> {
        anyhow::ensure!(out.len() == self.page_size, "bad page buffer size");
        anyhow::ensure!(self.fits(), "page overflow: {} > {}", self.serialized_size(), self.page_size);
        anyhow::ensure!(self.vectors.len() < u16::MAX.ix(), "too many vectors");
        anyhow::ensure!(self.neighbors.len() < u16::MAX.ix(), "too many neighbors");
        out.fill(0);

        let inline = self.neighbors.iter().filter(|(_, c)| c.is_some()).count();
        let mixed = inline > 0 && inline < self.neighbors.len();
        let all_inline = inline == self.neighbors.len() && !self.neighbors.is_empty();
        let flags = if mixed { FLAG_BITMAP } else { 0 };

        out[0..2].copy_from_slice(&to_u16(self.vectors.len())?.to_le_bytes());
        out[2..4].copy_from_slice(&to_u16(self.neighbors.len())?.to_le_bytes());
        out[4] = flags
            | if all_inline { 2 } else { 0 };

        let mut off = PAGE_HEADER_BYTES;
        for (oid, _) in &self.vectors {
            out[off..off + 4].copy_from_slice(&oid.to_le_bytes());
            off += 4;
        }
        for (_, bytes) in &self.vectors {
            anyhow::ensure!(bytes.len() == self.vec_stride, "vector stride mismatch");
            out[off..off + self.vec_stride].copy_from_slice(bytes);
            off += self.vec_stride;
        }
        for (nid, _) in &self.neighbors {
            out[off..off + 4].copy_from_slice(&nid.to_le_bytes());
            off += 4;
        }
        if mixed {
            let bitmap_off = off;
            off += crate::util::div_ceil(self.neighbors.len(), 8);
            for (i, (_, code)) in self.neighbors.iter().enumerate() {
                if code.is_some() {
                    out[bitmap_off + i / 8] |= 1 << (i % 8);
                }
            }
        }
        for (_, code) in &self.neighbors {
            if let Some(c) = code {
                anyhow::ensure!(c.len() == self.code_bytes, "code length mismatch");
                out[off..off + self.code_bytes].copy_from_slice(c);
                off += self.code_bytes;
            }
        }
        if self.checksum {
            let crc = crc32c(&out[..self.page_size - PAGE_CRC_BYTES]);
            out[self.page_size - PAGE_CRC_BYTES..].copy_from_slice(&crc.to_le_bytes());
        }
        Ok(())
    }
}

/// Zero-copy reader over one serialized page.
#[derive(Clone, Copy)]
pub struct PageRef<'a> {
    buf: &'a [u8],
    vec_stride: usize,
    code_bytes: usize,
    n_vecs: usize,
    n_nbrs: usize,
    flags: u8,
}

impl<'a> PageRef<'a> {
    /// True when `buf`'s trailing CRC32C matches its contents. Only
    /// meaningful for checksummed layouts (`IndexMeta::page_crc`); a legacy
    /// page's tail bytes are payload or zero padding, not a checksum.
    pub fn verify_checksum(buf: &[u8]) -> bool {
        if buf.len() < PAGE_HEADER_BYTES + PAGE_CRC_BYTES {
            return false;
        }
        let body = &buf[..buf.len() - PAGE_CRC_BYTES];
        let tail = &buf[buf.len() - PAGE_CRC_BYTES..];
        crc32c(body) == u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]])
    }

    /// [`PageRef::parse`] preceded by checksum verification — the entry
    /// point for bytes fresh off the device on a checksummed index. A
    /// mismatch is reported before any structural field is trusted.
    pub fn parse_verified(buf: &'a [u8], vec_stride: usize, code_bytes: usize) -> Result<Self> {
        anyhow::ensure!(Self::verify_checksum(buf), "page checksum mismatch");
        Self::parse(buf, vec_stride, code_bytes)
    }

    pub fn parse(buf: &'a [u8], vec_stride: usize, code_bytes: usize) -> Result<Self> {
        anyhow::ensure!(buf.len() >= PAGE_HEADER_BYTES, "page too small");
        let n_vecs = u16::from_le_bytes([buf[0], buf[1]]).ix();
        let n_nbrs = u16::from_le_bytes([buf[2], buf[3]]).ix();
        let flags = buf[4];
        let p = Self { buf, vec_stride, code_bytes, n_vecs, n_nbrs, flags };
        anyhow::ensure!(p.codes_end() <= buf.len(), "corrupt page: overruns buffer");
        Ok(p)
    }

    #[inline]
    pub fn n_vecs(&self) -> usize {
        self.n_vecs
    }

    #[inline]
    pub fn n_nbrs(&self) -> usize {
        self.n_nbrs
    }

    #[inline]
    fn orig_ids_off(&self) -> usize {
        PAGE_HEADER_BYTES
    }

    #[inline]
    fn vectors_off(&self) -> usize {
        self.orig_ids_off() + self.n_vecs * 4
    }

    #[inline]
    fn nbr_ids_off(&self) -> usize {
        self.vectors_off() + self.n_vecs * self.vec_stride
    }

    #[inline]
    fn bitmap_off(&self) -> usize {
        self.nbr_ids_off() + self.n_nbrs * 4
    }

    #[inline]
    fn has_bitmap(&self) -> bool {
        self.flags & FLAG_BITMAP != 0
    }

    #[inline]
    fn all_inline(&self) -> bool {
        self.flags & 2 != 0
    }

    #[inline]
    fn bitmap_len(&self) -> usize {
        if self.has_bitmap() {
            crate::util::div_ceil(self.n_nbrs, 8)
        } else {
            0
        }
    }

    #[inline]
    fn codes_off(&self) -> usize {
        self.bitmap_off() + self.bitmap_len()
    }

    fn inline_count(&self) -> usize {
        if self.all_inline() {
            self.n_nbrs
        } else if self.has_bitmap() {
            let bm = &self.buf[self.bitmap_off()..self.bitmap_off() + self.bitmap_len()];
            bm.iter().map(|b| b.count_ones().ix()).sum()
        } else {
            0
        }
    }

    fn codes_end(&self) -> usize {
        self.codes_off() + self.inline_count() * self.code_bytes
    }

    /// Original id of member vector `i`.
    #[inline]
    pub fn orig_id(&self, i: usize) -> u32 {
        let o = self.orig_ids_off() + i * 4;
        u32::from_le_bytes([self.buf[o], self.buf[o + 1], self.buf[o + 2], self.buf[o + 3]])
    }

    /// Raw bytes of member vector `i`.
    #[inline]
    pub fn vector(&self, i: usize) -> &'a [u8] {
        let o = self.vectors_off() + i * self.vec_stride;
        &self.buf[o..o + self.vec_stride]
    }

    /// The contiguous block of all member vectors (batch scans).
    #[inline]
    pub fn vectors_block(&self) -> &'a [u8] {
        let o = self.vectors_off();
        &self.buf[o..o + self.n_vecs * self.vec_stride]
    }

    /// New-id of neighbor `j`.
    #[inline]
    pub fn nbr_id(&self, j: usize) -> u32 {
        let o = self.nbr_ids_off() + j * 4;
        u32::from_le_bytes([self.buf[o], self.buf[o + 1], self.buf[o + 2], self.buf[o + 3]])
    }

    /// Inline PQ code of neighbor `j`, or `None` if its code lives in
    /// memory.
    pub fn nbr_code(&self, j: usize) -> Option<&'a [u8]> {
        if self.all_inline() {
            let o = self.codes_off() + j * self.code_bytes;
            return Some(&self.buf[o..o + self.code_bytes]);
        }
        if !self.has_bitmap() {
            return None;
        }
        let bm_off = self.bitmap_off();
        if self.buf[bm_off + j / 8] & (1 << (j % 8)) == 0 {
            return None;
        }
        // Rank: number of set bits before j.
        let mut rank = 0usize;
        for b in 0..j / 8 {
            rank += self.buf[bm_off + b].count_ones().ix();
        }
        let partial = self.buf[bm_off + j / 8] & (1u8 << (j % 8)).wrapping_sub(1);
        rank += partial.count_ones().ix();
        let o = self.codes_off() + rank * self.code_bytes;
        Some(&self.buf[o..o + self.code_bytes])
    }

    /// Bytes of this page that carry payload (for read-amplification).
    pub fn used_bytes(&self) -> usize {
        self.codes_end()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_vectors(n: usize, stride: usize) -> Vec<(u32, Vec<u8>)> {
        (0..n).map(|i| (100 + i as u32, vec![i as u8; stride])).collect()
    }

    #[test]
    fn roundtrip_all_inline() {
        let stride = 16;
        let m = 4;
        let vecs = mk_vectors(3, stride);
        let codes: Vec<Vec<u8>> = (0..5).map(|j| vec![j as u8; m]).collect();
        let w = PageWriter {
            page_size: 512,
            vec_stride: stride,
            code_bytes: m,
            checksum: false,
            vectors: vecs.iter().map(|(id, v)| (*id, v.as_slice())).collect(),
            neighbors: (0..5).map(|j| (j as u32 * 7, Some(codes[j].as_slice()))).collect(),
        };
        let mut buf = vec![0u8; 512];
        w.serialize_into(&mut buf).unwrap();
        let p = PageRef::parse(&buf, stride, m).unwrap();
        assert_eq!(p.n_vecs(), 3);
        assert_eq!(p.n_nbrs(), 5);
        assert_eq!(p.orig_id(1), 101);
        assert_eq!(p.vector(2), &vec![2u8; stride][..]);
        assert_eq!(p.nbr_id(3), 21);
        assert_eq!(p.nbr_code(4).unwrap(), &vec![4u8; m][..]);
        assert_eq!(p.vectors_block().len(), 3 * stride);
    }

    #[test]
    fn roundtrip_no_codes() {
        let w = PageWriter {
            page_size: 256,
            vec_stride: 8,
            code_bytes: 4,
            checksum: false,
            vectors: vec![(7, &[1u8; 8])],
            neighbors: vec![(11, None), (12, None)],
        };
        let mut buf = vec![0u8; 256];
        w.serialize_into(&mut buf).unwrap();
        let p = PageRef::parse(&buf, 8, 4).unwrap();
        assert_eq!(p.nbr_code(0), None);
        assert_eq!(p.nbr_code(1), None);
        assert_eq!(p.nbr_id(1), 12);
    }

    #[test]
    fn roundtrip_mixed_codes_bitmap_rank() {
        let m = 3;
        let c1 = vec![9u8; m];
        let c2 = vec![17u8; m];
        // inline at positions 1 and 9 (crosses a byte boundary in bitmap).
        let mut neighbors: Vec<(u32, Option<&[u8]>)> = (0..12).map(|j| (j, None)).collect();
        neighbors[1].1 = Some(c1.as_slice());
        neighbors[9].1 = Some(c2.as_slice());
        let w = PageWriter { page_size: 256, vec_stride: 4, code_bytes: m, checksum: false, vectors: vec![(0, &[0u8; 4])], neighbors };
        let mut buf = vec![0u8; 256];
        w.serialize_into(&mut buf).unwrap();
        let p = PageRef::parse(&buf, 4, m).unwrap();
        assert_eq!(p.nbr_code(0), None);
        assert_eq!(p.nbr_code(1).unwrap(), &c1[..]);
        assert_eq!(p.nbr_code(5), None);
        assert_eq!(p.nbr_code(9).unwrap(), &c2[..]);
        assert_eq!(p.nbr_code(11), None);
        assert!(p.used_bytes() < 256);
    }

    #[test]
    fn overflow_rejected_and_truncate_fixes() {
        let stride = 64;
        let vecs = mk_vectors(3, stride);
        let code = vec![0u8; 8];
        let mut w = PageWriter {
            page_size: 256,
            vec_stride: stride,
            code_bytes: 8,
            checksum: false,
            vectors: vecs.iter().map(|(id, v)| (*id, v.as_slice())).collect(),
            neighbors: (0..20).map(|j| (j, Some(code.as_slice()))).collect(),
        };
        let mut buf = vec![0u8; 256];
        assert!(w.serialize_into(&mut buf).is_err());
        w.truncate_to_fit();
        assert!(w.fits());
        w.serialize_into(&mut buf).unwrap();
        let p = PageRef::parse(&buf, stride, 8).unwrap();
        assert_eq!(p.n_vecs(), 3);
        assert!(p.n_nbrs() < 20);
    }

    #[test]
    fn corrupt_header_detected() {
        let mut buf = vec![0u8; 64];
        buf[0..2].copy_from_slice(&100u16.to_le_bytes()); // 100 vecs can't fit
        buf[2..4].copy_from_slice(&0u16.to_le_bytes());
        assert!(PageRef::parse(&buf, 32, 4).is_err());
    }

    #[test]
    fn checksummed_roundtrip_and_detection() {
        let stride = 16;
        let m = 4;
        let vecs = mk_vectors(3, stride);
        let codes: Vec<Vec<u8>> = (0..5).map(|j| vec![j as u8; m]).collect();
        let w = PageWriter {
            page_size: 512,
            vec_stride: stride,
            code_bytes: m,
            checksum: true,
            vectors: vecs.iter().map(|(id, v)| (*id, v.as_slice())).collect(),
            neighbors: (0..5).map(|j| (j as u32 * 7, Some(codes[j].as_slice()))).collect(),
        };
        let mut buf = vec![0u8; 512];
        w.serialize_into(&mut buf).unwrap();
        assert!(PageRef::verify_checksum(&buf));
        let p = PageRef::parse_verified(&buf, stride, m).unwrap();
        assert_eq!(p.n_vecs(), 3);
        assert_eq!(p.nbr_code(4).unwrap(), &vec![4u8; m][..]);
        // Any single flipped bit — payload, zero padding, or the stored CRC
        // itself — must fail verification.
        for bit in [0usize, 6 * 8 + 1, 300 * 8, 511 * 8 + 7] {
            buf[bit / 8] ^= 1 << (bit % 8);
            assert!(!PageRef::verify_checksum(&buf), "bit {bit} undetected");
            assert!(PageRef::parse_verified(&buf, stride, m).is_err());
            buf[bit / 8] ^= 1 << (bit % 8);
        }
        // A torn page (tail half zeroed, as a partial write leaves it) is
        // detected too.
        let mut torn = buf.clone();
        for b in torn[256..].iter_mut() {
            *b = 0;
        }
        assert!(!PageRef::verify_checksum(&torn));
    }

    #[test]
    fn checksum_reserves_tail_bytes() {
        // With checksum on, contents that would exactly fill the page must
        // be rejected / truncated — the CRC tail is not payload space.
        let stride = 8;
        let vecs = mk_vectors(2, stride);
        let mut w = PageWriter {
            page_size: PAGE_HEADER_BYTES + 2 * (4 + stride) + 3 * 4 + 2, // 2 short of CRC space
            vec_stride: stride,
            code_bytes: 4,
            checksum: true,
            vectors: vecs.iter().map(|(id, v)| (*id, v.as_slice())).collect(),
            neighbors: (0..3).map(|j| (j, None)).collect(),
        };
        assert!(!w.fits());
        w.truncate_to_fit();
        assert!(w.fits());
        assert!(w.neighbors.len() < 3);
        let mut buf = vec![0u8; w.page_size];
        w.serialize_into(&mut buf).unwrap();
        assert!(PageRef::verify_checksum(&buf));
    }
}
