//! On-disk index layout (paper §4.2, Fig. 5).
//!
//! ```text
//! index-dir/
//!   meta.bin      header: magic, version, geometry, PQ params, CV placement
//!   pages.bin     page i at byte offset i * page_size (see `page`)
//!   pq.bin        PQ codebooks
//!   memcodes.bin  compressed vectors resident in memory at query time
//!   routing.bin   LSH routing index (planes + buckets over new-id space)
//!   remap.bin     new-id ↔ original-id tables
//! ```
//!
//! Each SSD page stores: the page node's full vectors (+ their original
//! ids), the ids of neighbor *vectors* in other pages (new-id space, so
//! `page = id / capacity` is one shift), and — depending on the CV placement
//! mode — the PQ codes of those neighbors inline, so next-hop selection
//! needs no extra I/O.

mod builder;
mod meta;
mod page;
mod remap;

pub use builder::{BuildConfig, BuildReport, IndexBuilder, IndexFiles};
pub use meta::{CvPlacement, IndexMeta, LEGACY_UNCHECKSUMMED_VERSION, MAGIC, VERSION};
pub use page::{PageRef, PageWriter, OVERHEAD_PER_NBR_ID, PAGE_CRC_BYTES, PAGE_HEADER_BYTES};
pub use remap::IdRemap;

/// Default SSD page size (bytes). 4 KiB mirrors the paper's main setup;
/// benches also exercise 8 KiB.
pub const DEFAULT_PAGE_SIZE: usize = 4096;

/// Compute the page-node capacity (vectors per page) from the layout
/// equation in §4.2:
///
/// `n = (P - header - NB·(id + flag? + (1-ρ)·code_bytes)) / (stride + orig_id)`
///
/// where `ρ` is the fraction of neighbor codes placed in memory and
/// `code_bytes` is the *storage* width of one PQ code (`M` for PQ8,
/// `⌈M/2⌉` for nibble-packed PQ4 — halving the inline-code bytes is what
/// lets a PQ4 build pack more vectors per 4 KB page).
pub fn page_capacity(
    page_size: usize,
    vec_stride: usize,
    max_nbrs: usize,
    code_bytes: usize,
    mem_code_frac: f64,
) -> usize {
    let flag_bytes = if mem_code_frac > 0.0 && mem_code_frac < 1.0 {
        crate::util::div_ceil(max_nbrs, 8)
    } else {
        0
    };
    // lint:allow(truncating-cast): frac ∈ [0,1], so the product is ≤ max_nbrs
    // (already a usize) and non-negative — the f64→usize cast cannot truncate.
    let on_page_codes = ((1.0 - mem_code_frac) * max_nbrs as f64).ceil() as usize;
    let nbr_bytes = max_nbrs * 4 + flag_bytes + on_page_codes * code_bytes;
    // New builds always reserve the CRC32C tail (v5 format); only legacy
    // v4 indexes go without, and those are never built anymore.
    let avail = page_size.saturating_sub(PAGE_HEADER_BYTES + nbr_bytes + PAGE_CRC_BYTES);
    (avail / (vec_stride + 4)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_matches_paper_shape() {
        // SIFT-like: 128-d u8 → stride 128; 4K page, 48 nbrs, M=16.
        let on_page = page_capacity(4096, 128, 48, 16, 0.0);
        let in_mem = page_capacity(4096, 128, 48, 16, 1.0);
        // All codes in memory → strictly more vectors per page (paper §4.3:
        // freed disk space is reallocated to vectors).
        assert!(in_mem > on_page, "{in_mem} vs {on_page}");
        // Sanity: a 4K page of 132-byte slots holds ~20-30 vectors.
        assert!((10..32).contains(&on_page), "{on_page}");
        assert!((20..32).contains(&in_mem), "{in_mem}");
    }

    #[test]
    fn pq4_half_width_codes_fit_more() {
        // Nibble-packed codes (m=16 → 8 bytes) free inline-code space that
        // goes to vectors — the PQ4 capacity sits between PQ8-on-page and
        // all-codes-in-memory.
        let pq8 = page_capacity(4096, 128, 48, 16, 0.0);
        let pq4 = page_capacity(4096, 128, 48, 8, 0.0);
        let in_mem = page_capacity(4096, 128, 48, 16, 1.0);
        assert!(pq4 > pq8, "{pq4} vs {pq8}");
        assert!(pq4 <= in_mem, "{pq4} vs {in_mem}");
    }

    #[test]
    fn capacity_monotone_in_mem_frac() {
        let mut prev = 0;
        for f in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let c = page_capacity(4096, 384, 48, 12, f);
            assert!(c >= prev, "frac {f}: {c} < {prev}");
            prev = c;
        }
    }

    #[test]
    fn capacity_never_zero() {
        // Degenerate: tiny page, huge vectors — still at least 1 (the page
        // then spans logically; the builder asserts real fit separately).
        assert_eq!(page_capacity(512, 4096, 64, 16, 0.0), 1);
    }
}
