//! PageANN — scalable disk-based ANN search with a page-aligned graph.
//! See DESIGN.md for the system inventory and experiment index.
pub mod baselines;
pub mod bench;
pub mod cache;
pub mod dataset;
pub mod distance;
pub mod engine;
pub mod io;
pub mod layout;
pub mod memplan;
pub mod metrics;
pub mod pagegraph;
pub mod pq;
pub mod proptest;
pub mod routing;
pub mod runtime;
pub mod search;
pub mod util;
pub mod vamana;

pub type Result<T> = anyhow::Result<T>;
