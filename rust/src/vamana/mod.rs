//! Vamana graph construction (Subramanya et al., DiskANN NeurIPS'19) — the
//! vector-level proximity graph PageANN derives its page-node graph from
//! (paper §4.1), and the graph the DiskANN/PipeANN/Starling baselines
//! traverse directly.
//!
//! Construction: random-regular init, then for each point a greedy beam
//! search from the medoid collects a visited set, which `robust_prune`
//! filters with the α-dominance rule; surviving edges are inserted
//! bidirectionally (neighbors re-pruned on overflow). Two passes (α = 1.0
//! then α = target) as in the reference implementation.

mod build;
mod greedy;

pub use build::{VamanaGraph, VamanaParams};
pub use greedy::{greedy_search, SearchScratch};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{ground_truth, recall_at_k, DatasetKind, SynthSpec};

    #[test]
    fn vamana_reaches_high_recall_in_memory() {
        // End-to-end sanity: in-memory greedy search on the built graph must
        // reach ≥0.9 recall@10 on an easy clustered set.
        let spec = SynthSpec::new(DatasetKind::DeepLike, 2000).with_dim(24).with_clusters(12);
        let base = spec.generate(31);
        let queries = spec.generate_queries(30, 31, 99);
        let gt = ground_truth(&base, &queries, 10, 4);

        let g = VamanaGraph::build(&base, &VamanaParams { r: 24, l_build: 48, alpha: 1.2, seed: 7, nthreads: 4 });
        let mut results = Vec::new();
        for qi in 0..queries.len() {
            let q = queries.get_f32(qi);
            let mut scratch = SearchScratch::default();
            let found = greedy_search(&base, &g.adj, g.medoid, &q, 40, 10, &mut scratch);
            results.push(found.into_iter().map(|(_, id)| id).collect::<Vec<_>>());
        }
        let r = recall_at_k(&results, &gt, 10);
        assert!(r >= 0.9, "in-memory vamana recall too low: {r}");
    }

    #[test]
    fn degree_bound_respected() {
        let spec = SynthSpec::new(DatasetKind::SiftLike, 500).with_dim(16);
        let base = spec.generate(1);
        let params = VamanaParams { r: 12, l_build: 24, alpha: 1.2, seed: 3, nthreads: 2 };
        let g = VamanaGraph::build(&base, &params);
        assert_eq!(g.adj.len(), 500);
        for (i, nbrs) in g.adj.iter().enumerate() {
            assert!(nbrs.len() <= 12, "node {i} degree {}", nbrs.len());
            assert!(nbrs.iter().all(|&n| (n as usize) < 500 && n as usize != i));
            // No duplicate edges.
            let set: std::collections::HashSet<_> = nbrs.iter().collect();
            assert_eq!(set.len(), nbrs.len());
        }
    }

    #[test]
    fn graph_is_connected_enough() {
        // BFS from medoid should reach ~everything (Vamana guarantees
        // navigability; allow a small number of stragglers).
        let spec = SynthSpec::new(DatasetKind::DeepLike, 800).with_dim(16).with_clusters(6);
        let base = spec.generate(17);
        let g = VamanaGraph::build(&base, &VamanaParams { r: 16, l_build: 32, alpha: 1.2, seed: 5, nthreads: 4 });
        let mut seen = vec![false; 800];
        let mut stack = vec![g.medoid];
        seen[g.medoid as usize] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &n in &g.adj[v as usize] {
                if !seen[n as usize] {
                    seen[n as usize] = true;
                    count += 1;
                    stack.push(n);
                }
            }
        }
        assert!(count >= 790, "only {count}/800 reachable from medoid");
    }
}
