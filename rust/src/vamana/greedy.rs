//! Greedy (best-first) beam search over an in-memory adjacency list — used
//! during Vamana construction and by the in-memory half of the baselines.

use crate::dataset::VectorSet;
use crate::distance::l2sq_query;

/// Reusable scratch buffers for greedy search (zero-alloc on the hot path).
#[derive(Default)]
pub struct SearchScratch {
    /// (distance, id, expanded) beam, kept sorted ascending by distance.
    beam: Vec<(f32, u32, bool)>,
    visited: std::collections::HashSet<u32>,
}

/// Best-first search: returns the `k` closest (distance, id) found, and
/// records every expanded node in `scratch.visited` (the candidate set
/// robust_prune consumes during construction).
///
/// `l` is the beam width (search list size); `k ≤ l`.
pub fn greedy_search(
    base: &VectorSet,
    adj: &[Vec<u32>],
    entry: u32,
    query: &[f32],
    l: usize,
    k: usize,
    scratch: &mut SearchScratch,
) -> Vec<(f32, u32)> {
    greedy_search_multi(base, adj, &[entry], query, l, k, scratch)
}

/// Like [`greedy_search`] but seeded with several entry points.
pub fn greedy_search_multi(
    base: &VectorSet,
    adj: &[Vec<u32>],
    entries: &[u32],
    query: &[f32],
    l: usize,
    k: usize,
    scratch: &mut SearchScratch,
) -> Vec<(f32, u32)> {
    let l = l.max(k).max(1);
    let beam = &mut scratch.beam;
    let visited = &mut scratch.visited;
    beam.clear();
    visited.clear();

    for &e in entries {
        if visited.insert(e) {
            let d = l2sq_query(query, base.view(e as usize));
            beam.push((d, e, false));
        }
    }
    beam.sort_by(|a, b| a.0.total_cmp(&b.0));
    beam.truncate(l);

    loop {
        // Closest unexpanded beam entry.
        let Some(pos) = beam.iter().position(|&(_, _, expanded)| !expanded) else {
            break;
        };
        beam[pos].2 = true;
        let v = beam[pos].1;

        for &n in &adj[v as usize] {
            if !visited.insert(n) {
                continue;
            }
            let d = l2sq_query(query, base.view(n as usize));
            // Insert into the sorted beam if it beats the current worst (or
            // the beam has room).
            if beam.len() < l {
                let at = beam.partition_point(|&(bd, _, _)| bd <= d);
                beam.insert(at, (d, n, false));
            } else if d < beam[l - 1].0 {
                let at = beam.partition_point(|&(bd, _, _)| bd <= d);
                beam.insert(at, (d, n, false));
                beam.truncate(l);
            }
        }
    }

    beam.iter().take(k).map(|&(d, id, _)| (d, id)).collect()
}

impl SearchScratch {
    /// Nodes expanded/visited during the last search (construction uses
    /// these as prune candidates).
    pub fn visited_ids(&self) -> impl Iterator<Item = u32> + '_ {
        self.visited.iter().copied()
    }

    /// Direct access to the visited set (construction-time reuse).
    pub fn visited_mut(&mut self) -> &mut std::collections::HashSet<u32> {
        &mut self.visited
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::VectorSet;

    /// Line graph over points on a number line: 0-1-2-…-9.
    fn line_world() -> (VectorSet, Vec<Vec<u32>>) {
        let rows: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let base = VectorSet::from_f32(1, &rows);
        let adj: Vec<Vec<u32>> = (0..10)
            .map(|i: u32| {
                let mut v = Vec::new();
                if i > 0 {
                    v.push(i - 1);
                }
                if i < 9 {
                    v.push(i + 1);
                }
                v
            })
            .collect();
        (base, adj)
    }

    #[test]
    fn walks_the_line_to_the_target() {
        let (base, adj) = line_world();
        let mut s = SearchScratch::default();
        let out = greedy_search(&base, &adj, 0, &[8.7], 4, 2, &mut s);
        assert_eq!(out[0].1, 9);
        assert_eq!(out[1].1, 8);
    }

    #[test]
    fn k_results_sorted_by_distance() {
        let (base, adj) = line_world();
        let mut s = SearchScratch::default();
        let out = greedy_search(&base, &adj, 5, &[3.2], 6, 4, &mut s);
        assert_eq!(out.len(), 4);
        for w in out.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
        assert_eq!(out[0].1, 3);
    }

    #[test]
    fn beam_width_one_is_pure_greedy() {
        let (base, adj) = line_world();
        let mut s = SearchScratch::default();
        let out = greedy_search(&base, &adj, 0, &[9.0], 1, 1, &mut s);
        assert_eq!(out[0].1, 9);
    }

    #[test]
    fn visited_contains_path() {
        let (base, adj) = line_world();
        let mut s = SearchScratch::default();
        let _ = greedy_search(&base, &adj, 0, &[9.0], 2, 1, &mut s);
        let visited: std::collections::HashSet<u32> = s.visited_ids().collect();
        for i in 0..10 {
            assert!(visited.contains(&i), "node {i} not visited");
        }
    }

    #[test]
    fn multi_entry_dedups() {
        let (base, adj) = line_world();
        let mut s = SearchScratch::default();
        let out = greedy_search_multi(&base, &adj, &[0, 0, 9], &[4.5], 10, 10, &mut s);
        // All 10 nodes reachable; no duplicates in results.
        let ids: std::collections::HashSet<u32> = out.iter().map(|&(_, id)| id).collect();
        assert_eq!(ids.len(), out.len());
    }
}
