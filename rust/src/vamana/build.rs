//! Vamana construction: robust prune + bidirectional insertion.

use super::greedy::SearchScratch;
use crate::dataset::VectorSet;
use crate::distance::{l2sq_f32, l2sq_query};
use crate::util::{parallel_chunks, XorShift};
use std::sync::Mutex;

/// Construction parameters (paper notation: R = degree bound, L = build
/// beam width, α = prune slack).
#[derive(Debug, Clone)]
pub struct VamanaParams {
    pub r: usize,
    pub l_build: usize,
    pub alpha: f32,
    pub seed: u64,
    pub nthreads: usize,
}

impl Default for VamanaParams {
    fn default() -> Self {
        Self { r: 24, l_build: 64, alpha: 1.2, seed: 42, nthreads: crate::util::num_threads() }
    }
}

/// The built graph: bounded-degree adjacency plus the medoid entry point.
pub struct VamanaGraph {
    pub adj: Vec<Vec<u32>>,
    pub medoid: u32,
    pub params_r: usize,
}

impl VamanaGraph {
    /// Build over `base`. Deterministic for fixed (params, base) modulo
    /// insertion-order races between threads; we process points in batches
    /// with per-node locks, like the reference implementation.
    pub fn build(base: &VectorSet, params: &VamanaParams) -> Self {
        let n = base.len();
        assert!(n > 0);
        let r = params.r.max(2);
        let mut rng = XorShift::new(params.seed);

        // --- medoid: point closest to the dataset mean (sampled mean for
        // large sets).
        let medoid = find_medoid(base, &mut rng);

        // --- random R-regular init.
        let adj: Vec<Mutex<Vec<u32>>> = (0..n)
            .map(|i| {
                let mut nbrs = Vec::with_capacity(r);
                while nbrs.len() < r.min(n - 1) {
                    let c = rng.next_below(n) as u32;
                    if c as usize != i && !nbrs.contains(&c) {
                        nbrs.push(c);
                    }
                }
                Mutex::new(nbrs)
            })
            .collect();

        // --- two passes: α=1.0 then α=params.alpha.
        for &alpha in &[1.0f32, params.alpha] {
            // Randomized order each pass.
            let mut order: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut order);
            let order = &order;
            let adj_ref = &adj;

            parallel_chunks(n, params.nthreads, |s, e| {
                let mut scratch = SearchScratch::default();
                for &p in &order[s..e] {
                    let q = base.get_f32(p as usize);
                    // Greedy search over the *current* graph (lock-snapshot
                    // adjacency reads).
                    let _ = greedy_search_locked(
                        base,
                        adj_ref,
                        medoid,
                        &q,
                        params.l_build,
                        1,
                        &mut scratch,
                    );
                    // Candidate pool: visited nodes + current neighbors.
                    let mut cands: Vec<(f32, u32)> = scratch
                        .visited_ids()
                        .filter(|&v| v != p)
                        .map(|v| (l2sq_query(&q, base.view(v as usize)), v))
                        .collect();
                    {
                        let cur = adj_ref[p as usize].lock().unwrap();
                        for &v in cur.iter() {
                            if v != p && !cands.iter().any(|&(_, c)| c == v) {
                                cands.push((l2sq_query(&q, base.view(v as usize)), v));
                            }
                        }
                    }
                    let pruned = robust_prune(base, p, cands, alpha, r);
                    {
                        let mut cur = adj_ref[p as usize].lock().unwrap();
                        *cur = pruned.clone();
                    }
                    // Reverse edges with overflow re-prune.
                    for &nb in &pruned {
                        let mut nbadj = adj_ref[nb as usize].lock().unwrap();
                        if !nbadj.contains(&p) {
                            nbadj.push(p);
                            if nbadj.len() > r {
                                let nbq = base.get_f32(nb as usize);
                                let cands: Vec<(f32, u32)> = nbadj
                                    .iter()
                                    .map(|&v| (l2sq_query(&nbq, base.view(v as usize)), v))
                                    .collect();
                                *nbadj = robust_prune(base, nb, cands, alpha, r);
                            }
                        }
                    }
                }
            });
        }

        let adj: Vec<Vec<u32>> = adj.into_iter().map(|m| m.into_inner().unwrap()).collect();
        Self { adj, medoid, params_r: r }
    }

    /// Average out-degree (reported in Table 1 context).
    pub fn avg_degree(&self) -> f64 {
        let total: usize = self.adj.iter().map(|a| a.len()).sum();
        total as f64 / self.adj.len().max(1) as f64
    }
}

/// Robust prune (DiskANN Alg. 2): repeatedly take the closest candidate,
/// then drop every candidate that is α-dominated by it.
fn robust_prune(
    base: &VectorSet,
    p: u32,
    mut cands: Vec<(f32, u32)>,
    alpha: f32,
    r: usize,
) -> Vec<u32> {
    cands.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    cands.dedup_by_key(|&mut (_, id)| id);
    let mut out: Vec<u32> = Vec::with_capacity(r);
    let mut out_vecs: Vec<Vec<f32>> = Vec::with_capacity(r);
    'next: for &(d_pc, c) in &cands {
        if c == p {
            continue;
        }
        for ov in &out_vecs {
            let d_oc = l2sq_f32(ov, &base.get_f32(c as usize));
            // Squared distances: α-rule applies to α²·d² vs d².
            if alpha * alpha * d_oc <= d_pc {
                continue 'next;
            }
        }
        out.push(c);
        out_vecs.push(base.get_f32(c as usize));
        if out.len() >= r {
            break;
        }
    }
    out
}

/// Medoid: the point nearest the (sampled) dataset mean.
fn find_medoid(base: &VectorSet, rng: &mut XorShift) -> u32 {
    let n = base.len();
    let dim = base.dim();
    let sample = rng.sample_indices(n, n.min(10_000));
    let mut mean = vec![0f64; dim];
    let mut buf = vec![0f32; dim];
    for &i in &sample {
        base.decode_into(i, &mut buf);
        for (m, &x) in mean.iter_mut().zip(&buf) {
            *m += x as f64;
        }
    }
    let meanf: Vec<f32> = mean.iter().map(|&m| (m / sample.len() as f64) as f32).collect();
    let mut best = 0u32;
    let mut bestd = f32::INFINITY;
    for &i in &sample {
        let d = l2sq_query(&meanf, base.view(i));
        if d < bestd {
            bestd = d;
            best = i as u32;
        }
    }
    best
}

/// Greedy search reading adjacency through per-node locks (construction
/// time only; the query path uses the immutable graph).
fn greedy_search_locked(
    base: &VectorSet,
    adj: &[Mutex<Vec<u32>>],
    entry: u32,
    query: &[f32],
    l: usize,
    k: usize,
    scratch: &mut SearchScratch,
) -> Vec<(f32, u32)> {
    // Inlined best-first loop (mirrors greedy.rs, but neighbor lists are
    // cloned under their lock).
    let l = l.max(k).max(1);
    let mut beam: Vec<(f32, u32, bool)> = Vec::with_capacity(l + 1);
    let mut visited = scratchhack(scratch);
    visited.clear();
    visited.insert(entry);
    beam.push((l2sq_query(query, base.view(entry as usize)), entry, false));

    loop {
        let Some(pos) = beam.iter().position(|&(_, _, x)| !x) else { break };
        beam[pos].2 = true;
        let v = beam[pos].1;
        let nbrs = adj[v as usize].lock().unwrap().clone();
        for n in nbrs {
            if !visited.insert(n) {
                continue;
            }
            let d = l2sq_query(query, base.view(n as usize));
            if beam.len() < l {
                let at = beam.partition_point(|&(bd, _, _)| bd <= d);
                beam.insert(at, (d, n, false));
            } else if d < beam[l - 1].0 {
                let at = beam.partition_point(|&(bd, _, _)| bd <= d);
                beam.insert(at, (d, n, false));
                beam.truncate(l);
            }
        }
    }
    let out = beam.iter().take(k).map(|&(d, id, _)| (d, id)).collect();
    putback(scratch, visited);
    out
}

// Scratch plumbing: reuse the visited set allocation across points.
fn scratchhack(s: &mut SearchScratch) -> std::collections::HashSet<u32> {
    std::mem::take(s.visited_mut())
}
fn putback(s: &mut SearchScratch, v: std::collections::HashSet<u32>) {
    *s.visited_mut() = v;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, SynthSpec};

    #[test]
    fn robust_prune_drops_dominated() {
        // p at origin; candidates at 1.0 and 1.1 in the same direction:
        // the second is dominated (d(c1,c2) small, α·d small vs d(p,c2)).
        let base = VectorSet::from_f32(1, &[0.0, 1.0, 1.1, -5.0]);
        let cands = vec![(1.0f32, 1u32), (1.21f32, 2u32), (25.0f32, 3u32)];
        let out = robust_prune(&base, 0, cands, 1.2, 4);
        assert!(out.contains(&1));
        assert!(!out.contains(&2), "1.1 should be dominated by 1.0");
        assert!(out.contains(&3), "opposite direction survives");
    }

    #[test]
    fn robust_prune_respects_degree_bound() {
        let rows: Vec<f32> = (0..50).map(|i| i as f32).collect();
        let base = VectorSet::from_f32(1, &rows);
        let cands: Vec<(f32, u32)> =
            (1..50).map(|i| ((i * i) as f32, i as u32)).collect();
        let out = robust_prune(&base, 0, cands, 100.0, 8); // huge α disables domination
        assert_eq!(out.len(), 8);
    }

    #[test]
    fn medoid_is_central() {
        let spec = SynthSpec::new(DatasetKind::DeepLike, 300).with_dim(8).with_clusters(1);
        let base = spec.generate(2);
        let mut rng = XorShift::new(1);
        let m = find_medoid(&base, &mut rng) as usize;
        // Medoid distance to mean must be at most the median point's.
        let dim = base.dim();
        let mut mean = vec![0f32; dim];
        for i in 0..base.len() {
            for (s, x) in mean.iter_mut().zip(base.get_f32(i)) {
                *s += x / base.len() as f32;
            }
        }
        let dm = l2sq_f32(&mean, &base.get_f32(m));
        let mut better = 0;
        for i in 0..base.len() {
            if l2sq_f32(&mean, &base.get_f32(i)) < dm {
                better += 1;
            }
        }
        assert!(better < base.len() / 10, "medoid not central: {better} closer");
    }
}
