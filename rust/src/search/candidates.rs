//! The candidate set of Algorithm 2: a capacity-bounded pool of
//! (estimated distance, vector id) pairs ordered by distance, tracking
//! which entries have been expanded.
//!
//! Implemented as a sorted vector with binary-search insertion — for the
//! pool sizes the paper uses (L ≤ a few hundred) this beats heap-based
//! structures on constant factors and gives O(1) `pop_closest_unvisited`
//! via a moving cursor.

pub struct CandidateSet {
    /// Sorted ascending by (distance, id).
    entries: Vec<Entry>,
    capacity: usize,
    /// Index of the first possibly-unvisited entry.
    cursor: usize,
}

#[derive(Clone, Copy)]
struct Entry {
    dist: f32,
    id: u32,
    visited: bool,
}

impl CandidateSet {
    pub fn new(capacity: usize) -> Self {
        Self { entries: Vec::with_capacity(capacity + 1), capacity: capacity.max(1), cursor: 0 }
    }

    pub fn reset(&mut self, capacity: usize) {
        self.entries.clear();
        self.capacity = capacity.max(1);
        self.cursor = 0;
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Insert if it beats the current worst (or there is room). Returns
    /// whether the candidate was accepted.
    pub fn push(&mut self, dist: f32, id: u32) -> bool {
        if self.entries.len() >= self.capacity {
            let worst = self.entries[self.entries.len() - 1];
            if dist > worst.dist || (dist == worst.dist && id >= worst.id) {
                return false;
            }
        }
        let at = self
            .entries
            .partition_point(|e| (e.dist, e.id) <= (dist, id));
        self.entries.insert(at, Entry { dist, id, visited: false });
        if at < self.cursor {
            self.cursor = at;
        }
        if self.entries.len() > self.capacity {
            self.entries.pop();
        }
        true
    }

    /// Closest entry not yet expanded, marking it expanded.
    pub fn pop_closest_unvisited(&mut self) -> Option<u32> {
        while self.cursor < self.entries.len() {
            if !self.entries[self.cursor].visited {
                self.entries[self.cursor].visited = true;
                let id = self.entries[self.cursor].id;
                self.cursor += 1;
                return Some(id);
            }
            self.cursor += 1;
        }
        None
    }

    pub fn has_unvisited(&self) -> bool {
        self.entries[self.cursor.min(self.entries.len())..]
            .iter()
            .any(|e| !e.visited)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_distance_order() {
        let mut c = CandidateSet::new(8);
        for (d, id) in [(5.0, 1), (1.0, 2), (3.0, 3)] {
            assert!(c.push(d, id));
        }
        assert_eq!(c.pop_closest_unvisited(), Some(2));
        assert_eq!(c.pop_closest_unvisited(), Some(3));
        assert_eq!(c.pop_closest_unvisited(), Some(1));
        assert_eq!(c.pop_closest_unvisited(), None);
        assert!(!c.has_unvisited());
    }

    #[test]
    fn capacity_evicts_worst() {
        let mut c = CandidateSet::new(2);
        assert!(c.push(1.0, 1));
        assert!(c.push(2.0, 2));
        assert!(!c.push(3.0, 3), "worse than worst must be rejected");
        assert!(c.push(0.5, 4));
        assert_eq!(c.len(), 2);
        assert_eq!(c.pop_closest_unvisited(), Some(4));
        assert_eq!(c.pop_closest_unvisited(), Some(1));
        assert_eq!(c.pop_closest_unvisited(), None);
    }

    #[test]
    fn closer_arrival_after_pops_is_seen() {
        let mut c = CandidateSet::new(4);
        c.push(5.0, 1);
        assert_eq!(c.pop_closest_unvisited(), Some(1));
        // A closer candidate arrives after the cursor moved past index 0.
        assert!(c.push(1.0, 2));
        assert!(c.has_unvisited());
        assert_eq!(c.pop_closest_unvisited(), Some(2));
    }

    #[test]
    fn duplicate_distances_handled() {
        let mut c = CandidateSet::new(4);
        c.push(1.0, 10);
        c.push(1.0, 11);
        c.push(1.0, 9);
        let a = c.pop_closest_unvisited().unwrap();
        let b = c.pop_closest_unvisited().unwrap();
        let d = c.pop_closest_unvisited().unwrap();
        assert_eq!(vec![a, b, d], vec![9, 10, 11]); // id tie-break
    }

    #[test]
    fn reset_clears_state() {
        let mut c = CandidateSet::new(2);
        c.push(1.0, 1);
        c.pop_closest_unvisited();
        c.reset(3);
        assert!(c.is_empty());
        assert!(!c.has_unvisited());
        c.push(2.0, 5);
        assert_eq!(c.pop_closest_unvisited(), Some(5));
    }
}
