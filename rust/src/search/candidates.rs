//! The candidate set of Algorithm 2: a capacity-bounded pool of
//! (estimated distance, vector id) pairs ordered by distance, tracking
//! which entries have been expanded.
//!
//! Implemented as a sorted vector with binary-search insertion — for the
//! pool sizes the paper uses (L ≤ a few hundred) this beats heap-based
//! structures on constant factors and gives O(1) `pop_closest_unvisited`
//! via a moving cursor.

pub struct CandidateSet {
    /// Sorted ascending by (distance, id).
    entries: Vec<Entry>,
    capacity: usize,
    /// Index of the first possibly-unvisited entry.
    cursor: usize,
}

#[derive(Clone, Copy)]
struct Entry {
    dist: f32,
    id: u32,
    visited: bool,
}

impl CandidateSet {
    pub fn new(capacity: usize) -> Self {
        Self { entries: Vec::with_capacity(capacity + 1), capacity: capacity.max(1), cursor: 0 }
    }

    pub fn reset(&mut self, capacity: usize) {
        self.entries.clear();
        self.capacity = capacity.max(1);
        self.cursor = 0;
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Insert if it beats the current worst (or there is room). Returns
    /// whether the candidate was accepted.
    pub fn push(&mut self, dist: f32, id: u32) -> bool {
        if self.entries.len() >= self.capacity {
            let worst = self.entries[self.entries.len() - 1];
            if dist > worst.dist || (dist == worst.dist && id >= worst.id) {
                return false;
            }
        }
        let at = self
            .entries
            .partition_point(|e| (e.dist, e.id) <= (dist, id));
        self.entries.insert(at, Entry { dist, id, visited: false });
        if at < self.cursor {
            self.cursor = at;
        }
        if self.entries.len() > self.capacity {
            self.entries.pop();
        }
        true
    }

    /// Closest entry not yet expanded, marking it expanded.
    pub fn pop_closest_unvisited(&mut self) -> Option<u32> {
        while self.cursor < self.entries.len() {
            if !self.entries[self.cursor].visited {
                self.entries[self.cursor].visited = true;
                let id = self.entries[self.cursor].id;
                self.cursor += 1;
                return Some(id);
            }
            self.cursor += 1;
        }
        None
    }

    pub fn has_unvisited(&self) -> bool {
        self.entries[self.cursor.min(self.entries.len())..]
            .iter()
            .any(|e| !e.visited)
    }

    /// Visit the unexpanded candidates in distance order **without**
    /// marking them expanded — the speculative page predictor's view of
    /// what `pop_closest_unvisited` would return next. `f` returns whether
    /// to keep iterating.
    pub fn peek_unvisited(&self, mut f: impl FnMut(u32) -> bool) {
        for e in self.entries[self.cursor.min(self.entries.len())..].iter() {
            if !e.visited && !f(e.id) {
                break;
            }
        }
    }
}

/// Bounded top-L result reservoir: keeps the `cap` smallest `(dist, id)`
/// pairs seen, as a binary max-heap ordered by `(dist, id)`.
///
/// Replaces the old push-everything-then-sort-then-dedup results vector:
/// a search scanning P pages × V vectors/page now does O(P·V·log L) heap
/// work on a cache-resident L-sized buffer instead of growing an unbounded
/// vector and sorting it at the end. Because the ordering includes the id
/// tiebreak, the retained set — and therefore the final top-k — is
/// identical to what the full sort produced.
pub struct TopReservoir {
    cap: usize,
    /// Max-heap by (dist, id): `heap[0]` is the current worst survivor.
    heap: Vec<(f32, u32)>,
}

#[inline]
fn res_gt(a: (f32, u32), b: (f32, u32)) -> bool {
    // Total order (distances are finite; total_cmp for safety), id tiebreak.
    a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)) == std::cmp::Ordering::Greater
}

impl Default for TopReservoir {
    /// Placeholder capacity; every search calls [`TopReservoir::reset`]
    /// with the real bound before pushing.
    fn default() -> Self {
        Self::new(64)
    }
}

impl TopReservoir {
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), heap: Vec::with_capacity(cap.max(1)) }
    }

    /// Clear and re-bound the reservoir (per-query reset; keeps the
    /// allocation).
    pub fn reset(&mut self, cap: usize) {
        self.cap = cap.max(1);
        self.heap.clear();
        self.heap.reserve(self.cap);
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Offer one result. O(1) when it loses to the current worst (the
    /// common case once the reservoir is warm).
    #[inline]
    pub fn push(&mut self, dist: f32, id: u32) {
        if self.heap.len() < self.cap {
            self.heap.push((dist, id));
            self.sift_up(self.heap.len() - 1);
        } else if res_gt(self.heap[0], (dist, id)) {
            self.heap[0] = (dist, id);
            self.sift_down(0);
        }
    }

    /// Contents sorted ascending by (dist, id), deduplicated by id.
    pub fn sorted(&self) -> Vec<(f32, u32)> {
        let mut v = self.heap.clone();
        v.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        v.dedup_by_key(|r| r.1);
        v
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if res_gt(self.heap[i], self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut largest = i;
            if l < n && res_gt(self.heap[l], self.heap[largest]) {
                largest = l;
            }
            if r < n && res_gt(self.heap[r], self.heap[largest]) {
                largest = r;
            }
            if largest == i {
                return;
            }
            self.heap.swap(i, largest);
            i = largest;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_keeps_smallest() {
        let mut r = TopReservoir::new(3);
        for (d, id) in [(5.0, 1), (1.0, 2), (3.0, 3), (0.5, 4), (9.0, 5)] {
            r.push(d, id);
        }
        assert_eq!(r.sorted(), vec![(0.5, 4), (1.0, 2), (3.0, 3)]);
        assert_eq!(r.len(), 3);
    }

    #[test]
    fn reservoir_matches_full_sort() {
        let mut rng = crate::util::XorShift::new(31);
        for cap in [1usize, 4, 17, 64] {
            let items: Vec<(f32, u32)> =
                (0..300u32).map(|i| (rng.next_f32() * 10.0, i)).collect();
            let mut r = TopReservoir::new(cap);
            for &(d, id) in &items {
                r.push(d, id);
            }
            let mut want = items.clone();
            want.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            want.truncate(cap);
            assert_eq!(r.sorted(), want, "cap={cap}");
        }
    }

    #[test]
    fn reservoir_reset_rebounds() {
        let mut r = TopReservoir::new(2);
        r.push(1.0, 1);
        r.push(2.0, 2);
        r.reset(1);
        assert!(r.is_empty());
        r.push(4.0, 9);
        r.push(3.0, 8);
        assert_eq!(r.sorted(), vec![(3.0, 8)]);
    }

    #[test]
    fn reservoir_id_tiebreak_matches_sort() {
        let mut r = TopReservoir::new(2);
        for id in [7u32, 3, 5, 1] {
            r.push(2.0, id);
        }
        assert_eq!(r.sorted(), vec![(2.0, 1), (2.0, 3)]);
    }

    #[test]
    fn pops_in_distance_order() {
        let mut c = CandidateSet::new(8);
        for (d, id) in [(5.0, 1), (1.0, 2), (3.0, 3)] {
            assert!(c.push(d, id));
        }
        assert_eq!(c.pop_closest_unvisited(), Some(2));
        assert_eq!(c.pop_closest_unvisited(), Some(3));
        assert_eq!(c.pop_closest_unvisited(), Some(1));
        assert_eq!(c.pop_closest_unvisited(), None);
        assert!(!c.has_unvisited());
    }

    #[test]
    fn capacity_evicts_worst() {
        let mut c = CandidateSet::new(2);
        assert!(c.push(1.0, 1));
        assert!(c.push(2.0, 2));
        assert!(!c.push(3.0, 3), "worse than worst must be rejected");
        assert!(c.push(0.5, 4));
        assert_eq!(c.len(), 2);
        assert_eq!(c.pop_closest_unvisited(), Some(4));
        assert_eq!(c.pop_closest_unvisited(), Some(1));
        assert_eq!(c.pop_closest_unvisited(), None);
    }

    #[test]
    fn closer_arrival_after_pops_is_seen() {
        let mut c = CandidateSet::new(4);
        c.push(5.0, 1);
        assert_eq!(c.pop_closest_unvisited(), Some(1));
        // A closer candidate arrives after the cursor moved past index 0.
        assert!(c.push(1.0, 2));
        assert!(c.has_unvisited());
        assert_eq!(c.pop_closest_unvisited(), Some(2));
    }

    #[test]
    fn duplicate_distances_handled() {
        let mut c = CandidateSet::new(4);
        c.push(1.0, 10);
        c.push(1.0, 11);
        c.push(1.0, 9);
        let a = c.pop_closest_unvisited().unwrap();
        let b = c.pop_closest_unvisited().unwrap();
        let d = c.pop_closest_unvisited().unwrap();
        assert_eq!(vec![a, b, d], vec![9, 10, 11]); // id tie-break
    }

    #[test]
    fn reset_clears_state() {
        let mut c = CandidateSet::new(2);
        c.push(1.0, 1);
        c.pop_closest_unvisited();
        c.reset(3);
        assert!(c.is_empty());
        assert!(!c.has_unvisited());
        c.push(2.0, 5);
        assert_eq!(c.pop_closest_unvisited(), Some(5));
    }
}
