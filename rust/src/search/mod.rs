//! Page-granular best-first search (paper §4.4, Algorithm 2).
//!
//! The traversal works on *page nodes*: each expansion round pops up to `b`
//! closest unvisited candidate vectors, maps them to unvisited pages, reads
//! those pages in one batched I/O, scans every resident vector exactly
//! (result set), and pushes every neighbor entry with an ADC-estimated
//! distance (candidate set). One graph hop == one page read, which is the
//! paper's central I/O property.
//!
//! CPU-side structure (the §5 pipeline only overlaps work if these finish
//! inside an I/O wait):
//! * exact scans go through the dispatched SIMD scanner
//!   ([`crate::distance::NativeBatch`]);
//! * neighbor ADC estimation is **batched**: codes are gathered into a
//!   contiguous scratch block per hop and scored with one
//!   [`AdcLut::distance_batch`] call instead of per-neighbor table walks;
//! * the per-query LUT is built into a scratch-owned buffer and the result
//!   set is a bounded top-L reservoir — zero steady-state allocations.

mod candidates;

pub use candidates::{CandidateSet, TopReservoir};

use crate::cache::{MemCodes, PageCache};
use crate::dataset::Dtype;
use crate::distance::BatchScanner;
use crate::io::PageStore;
use crate::layout::{IndexMeta, PageRef};
use crate::metrics::QueryStats;
use crate::pq::{AdcLut, PqCodebook};
use crate::Result;
use std::time::Instant;

/// Tunables of one search (paper notation: L = pool, b = I/O batch).
#[derive(Debug, Clone)]
pub struct SearchParams {
    pub k: usize,
    /// Candidate-set capacity (search list size) — the recall knob.
    pub l: usize,
    /// Pages per batched I/O round.
    pub io_batch: usize,
    /// Hamming probe radius for routing entry.
    pub routing_radius: usize,
    /// Max entry points taken from the router.
    pub max_entries: usize,
    /// Overlap exact-distance computation with the next async read
    /// (paper §5 I/O-computation pipeline).
    pub pipeline: bool,
}

impl Default for SearchParams {
    fn default() -> Self {
        Self { k: 10, l: 64, io_batch: 5, routing_radius: 2, max_entries: 16, pipeline: true }
    }
}

/// Per-thread reusable search state (buffers sized on first use).
pub struct SearchScratch {
    candidates: CandidateSet,
    /// Visited marks, epoch-stamped so clearing is O(1).
    visited_vec: Vec<u32>,
    visited_page: Vec<u32>,
    epoch: u32,
    /// Bounded top-L result reservoir (exact distances).
    results: TopReservoir,
    page_bufs: Vec<Vec<u8>>,
    page_ids: Vec<u32>,
    /// Every page touched by the last search (warm-up frequency input).
    pages_touched: Vec<u32>,
    dist_buf: Vec<f32>,
    /// Per-query ADC table, rebuilt in place (no per-query allocation).
    lut: AdcLut,
    /// Gathered neighbor ids / codes / distances for the batched topology
    /// phase; cleared per hop, capacity retained.
    nbr_ids: Vec<u32>,
    nbr_codes: Vec<u8>,
    nbr_dists: Vec<f32>,
}

impl SearchScratch {
    pub fn new() -> Self {
        Self {
            candidates: CandidateSet::new(64),
            visited_vec: Vec::new(),
            visited_page: Vec::new(),
            epoch: 0,
            results: TopReservoir::new(64),
            page_bufs: Vec::new(),
            page_ids: Vec::new(),
            pages_touched: Vec::new(),
            dist_buf: Vec::new(),
            lut: AdcLut::empty(),
            nbr_ids: Vec::new(),
            nbr_codes: Vec::new(),
            nbr_dists: Vec::new(),
        }
    }

    /// Results of the last search (top-L scanned vectors, sorted).
    pub fn results_for_warmup(&self) -> Vec<(f32, u32)> {
        self.results.sorted()
    }

    /// Pages touched by the last search (borrowed; no per-call clone).
    pub fn visited_pages_for_warmup(&self) -> &[u32] {
        &self.pages_touched
    }

    fn reset(&mut self, n_slots: usize, n_pages: usize, l: usize, k: usize) {
        if self.visited_vec.len() < n_slots {
            self.visited_vec.resize(n_slots, 0);
        }
        if self.visited_page.len() < n_pages {
            self.visited_page.resize(n_pages, 0);
        }
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // Epoch wrapped: hard-clear.
            self.visited_vec.fill(0);
            self.visited_page.fill(0);
            self.epoch = 1;
        }
        self.candidates.reset(l);
        self.results.reset(l.max(k));
        self.pages_touched.clear();
    }
}

impl Default for SearchScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Everything a search needs to see of the opened index.
pub struct SearchContext<'a> {
    pub meta: &'a IndexMeta,
    pub store: &'a dyn PageStore,
    pub cache: &'a PageCache,
    pub memcodes: &'a MemCodes,
    pub scanner: &'a dyn BatchScanner,
    pub pq: &'a PqCodebook,
}

/// Run Algorithm 2. `entries` are entry-point vector ids (new-id space)
/// from the router (or the medoid fallback). The per-query ADC table is
/// built into `scratch` from `ctx.pq`. Returns the top-k
/// `(distance, original_id)` pairs.
pub fn search_pages(
    ctx: &SearchContext<'_>,
    query: &[f32],
    entries: &[u32],
    params: &SearchParams,
    scratch: &mut SearchScratch,
    stats: &mut QueryStats,
) -> Result<Vec<(f32, u32)>> {
    let meta = ctx.meta;
    let capacity = meta.capacity as u32;
    let dtype: Dtype = meta.dtype;
    let stride = meta.vec_stride();
    // Storage bytes per PQ code (nibble-packed for PQ4 indexes) — the
    // stride for page parsing, memcodes and the gathered-code scratch.
    let code_w = meta.code_bytes();
    scratch.reset(meta.n_slots(), meta.n_pages, params.l, params.k);
    let epoch = scratch.epoch;

    // Per-query ADC table into the scratch-owned buffer.
    let t_lut = Instant::now();
    ctx.pq.build_lut_into(query, &mut scratch.lut);
    stats.compute_time += t_lut.elapsed();
    debug_assert_eq!(scratch.lut.code_bytes(), code_w);

    // Seed candidates (Alg. 2 lines 4-7): estimated distance from resident
    // codes where available; entries without codes get pushed with d=0 so
    // they are expanded first. Like the topology phase below, a seed is
    // marked visited only when the pool accepts it — a rejected seed can
    // still re-enter later via a closer page.
    for &e in entries.iter().take(params.max_entries.max(1)) {
        if scratch.visited_vec[e as usize] == epoch {
            continue;
        }
        let d = ctx.memcodes.get(e).map(|c| scratch.lut.distance(c)).unwrap_or(0.0);
        if scratch.candidates.push(d, e) {
            scratch.visited_vec[e as usize] = epoch; // seeded (not yet expanded)
        }
        stats.approx_dists += 1;
    }

    // Exact scans deferred until the next I/O wait (paper §5 pipeline);
    // owned buffers cycle back into the scratch pool after scanning.
    enum Deferred<'c> {
        Owned(Vec<u8>),
        Cached(&'c [u8]),
    }
    let mut deferred: Vec<Deferred<'_>> = Vec::new();

    // Drains `deferred`: exact distances into the result reservoir.
    macro_rules! scan_deferred {
        () => {{
            let t_cpu = Instant::now();
            for item in deferred.drain(..) {
                let bytes: &[u8] = match &item {
                    Deferred::Owned(b) => b,
                    Deferred::Cached(b) => b,
                };
                let page = PageRef::parse(&bytes[..meta.page_size], stride, code_w)?;
                let nv = page.n_vecs();
                if scratch.dist_buf.len() < nv {
                    scratch.dist_buf.resize(nv, 0.0);
                }
                ctx.scanner
                    .scan(query, page.vectors_block(), dtype, nv, &mut scratch.dist_buf);
                stats.exact_dists += nv as u64;
                for i in 0..nv {
                    scratch.results.push(scratch.dist_buf[i], page.orig_id(i));
                }
                if let Deferred::Owned(buf) = item {
                    scratch.page_bufs.push(buf); // back to the pool
                }
            }
            stats.compute_time += t_cpu.elapsed();
        }};
    }

    // Main loop (lines 8-28).
    while scratch.candidates.has_unvisited() {
        // Collect up to `io_batch` unvisited pages (lines 10-18).
        scratch.page_ids.clear();
        while scratch.page_ids.len() < params.io_batch {
            let Some(v) = scratch.candidates.pop_closest_unvisited() else {
                break;
            };
            let p = v / capacity;
            if scratch.visited_page[p as usize] != epoch {
                scratch.visited_page[p as usize] = epoch;
                scratch.page_ids.push(p);
                scratch.pages_touched.push(p);
            }
        }
        if scratch.page_ids.is_empty() {
            // Popped candidates all mapped to already-visited pages — no
            // page read happened, so this round is not a hop.
            continue;
        }
        stats.hops += 1;

        // Partition into cached / disk (cache hits served from memory).
        let mut disk_ids: Vec<u32> = Vec::with_capacity(scratch.page_ids.len());
        let mut cached_bytes: Vec<&[u8]> = Vec::new();
        for &p in scratch.page_ids.iter() {
            if let Some(bytes) = ctx.cache.get(p) {
                cached_bytes.push(bytes);
                stats.cache_hits += 1;
            } else {
                disk_ids.push(p);
            }
        }

        // Take buffers from the pool for the disk reads.
        let mut disk_bufs: Vec<Vec<u8>> = Vec::with_capacity(disk_ids.len());
        for _ in 0..disk_ids.len() {
            disk_bufs.push(
                scratch
                    .page_bufs
                    .pop()
                    .unwrap_or_else(|| vec![0u8; meta.page_size]),
            );
        }

        // Submit the batch read (line 19). In pipelined mode the exact
        // scans deferred from the previous hop execute while the device
        // works — the §5 I/O-computation overlap.
        let t_submit = Instant::now();
        let pending = ctx.store.begin_read(&disk_ids, &mut disk_bufs)?;
        let submit_time = t_submit.elapsed();
        if params.pipeline {
            scan_deferred!();
        }
        let t_wait = Instant::now();
        pending.wait()?;
        stats.io_time += submit_time + t_wait.elapsed();
        stats.ios += disk_ids.len() as u64;
        stats.bytes_read += (disk_ids.len() * meta.page_size) as u64;

        // Topology phase (lines 24-26): neighbor entries → candidate set
        // with ADC estimates. Never deferred — the next hop's page
        // selection depends on it. Runs in two passes: gather all unvisited
        // neighbors' codes into one contiguous scratch block, score them
        // with a single batched ADC call, then push.
        let t_cpu = Instant::now();
        scratch.nbr_ids.clear();
        scratch.nbr_codes.clear();
        for (is_disk, bytes) in disk_bufs
            .iter()
            .map(|b| (true, b.as_slice()))
            .chain(cached_bytes.iter().map(|b| (false, *b)))
        {
            let page = PageRef::parse(&bytes[..meta.page_size], stride, code_w)?;
            if is_disk {
                stats.bytes_used += page.used_bytes() as u64;
            }
            for j in 0..page.n_nbrs() {
                let nb = page.nbr_id(j);
                if scratch.visited_vec[nb as usize] == epoch {
                    continue;
                }
                let code = page.nbr_code(j).or_else(|| ctx.memcodes.get(nb));
                let Some(code) = code else {
                    // Build guarantees one copy exists; treat miss as a
                    // corrupt index rather than silently skipping.
                    anyhow::bail!("no compressed vector for neighbor {nb}");
                };
                debug_assert_eq!(code.len(), code_w);
                scratch.nbr_ids.push(nb);
                scratch.nbr_codes.extend_from_slice(code);
            }
        }
        let n_gathered = scratch.nbr_ids.len();
        scratch
            .lut
            .score_into(&scratch.nbr_codes, n_gathered, &mut scratch.nbr_dists);
        stats.approx_dists += n_gathered as u64;
        for i in 0..n_gathered {
            let nb = scratch.nbr_ids[i];
            // A neighbor can be gathered twice in one round (shared by two
            // pages); the epoch re-check keeps the second copy from
            // double-entering the pool.
            if scratch.visited_vec[nb as usize] == epoch {
                continue;
            }
            // Only mark visited when accepted into the pool; rejected
            // candidates may re-enter later via a closer page.
            if scratch.candidates.push(scratch.nbr_dists[i], nb) {
                scratch.visited_vec[nb as usize] = epoch;
            }
        }
        stats.compute_time += t_cpu.elapsed();

        // Queue the exact scans (lines 21-23): deferred in pipelined mode,
        // immediate otherwise.
        for buf in disk_bufs {
            deferred.push(Deferred::Owned(buf));
        }
        for bytes in cached_bytes {
            deferred.push(Deferred::Cached(bytes));
        }
        if !params.pipeline {
            scan_deferred!();
        }
    }
    // Drain the tail of the pipeline.
    scan_deferred!();

    // Final ranking (lines 29-30): the reservoir already holds the top-L
    // by (dist, id); sort it and cut to k.
    let t_cpu = Instant::now();
    let mut out = scratch.results.sorted();
    out.truncate(params.k);
    stats.compute_time += t_cpu.elapsed();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_default_match_paper() {
        let p = SearchParams::default();
        assert_eq!(p.io_batch, 5); // paper §6.1: batch size fixed at 5
        assert_eq!(p.k, 10); // recall@10
    }
}
