//! Page-granular best-first search (paper §4.4, Algorithm 2).
//!
//! The traversal works on *page nodes*: each expansion round pops up to `b`
//! closest unvisited candidate vectors, maps them to unvisited pages, reads
//! those pages in one batched I/O, scans every resident vector exactly
//! (result set), and pushes every neighbor entry with an ADC-estimated
//! distance (candidate set). One graph hop == one page read, which is the
//! paper's central I/O property.
//!
//! CPU-side structure (the §5 pipeline only overlaps work if these finish
//! inside an I/O wait):
//! * exact scans go through the dispatched SIMD scanner
//!   ([`crate::distance::NativeBatch`]);
//! * neighbor ADC estimation is **batched**: codes are gathered into a
//!   contiguous scratch block per hop and scored with one
//!   [`AdcLut::distance_batch`] call instead of per-neighbor table walks;
//! * the per-query LUT is built into a scratch-owned buffer and the result
//!   set is a bounded top-L reservoir — zero steady-state allocations.
//!
//! # Two-deep I/O pipeline (speculative prefetch)
//!
//! On stores that keep more than one batch in flight
//! ([`PageStore::max_inflight_batches`] > 1 — io_uring, AIO, sim-SSD), the
//! searcher runs a *two-deep* pipeline: right after this hop's read is
//! waited, it predicts the next hop's page batch from the **pre-topology**
//! candidate pool ([`CandidateSet::peek_unvisited`], which mirrors what
//! `pop_closest_unvisited` would return) and submits that batch
//! speculatively, so the device reads it while the topology phase runs on
//! the CPU. The next hop's real selection then consumes matching
//! speculative pages and discards the rest — the speculation is thrown
//! away whenever the candidate frontier changed. Selection, scoring and
//! result ranking are completely untouched by speculation (it only changes
//! *where bytes come from*), so results are bit-identical across backends
//! and with `speculate` off; `ios` counts only consumed reads (see
//! [`QueryStats::spec_hits`]/[`spec_wasted`]).
//!
//! # Fault tolerance (degraded reads)
//!
//! Disk-sourced pages are integrity-checked against the page CRC tail when
//! the index carries one (`IndexMeta::page_crc`). A batch read error or a
//! checksum mismatch does **not** fail the query: the affected pages are
//! demoted to bounded per-page re-reads with exponential backoff
//! ([`SearchParams::max_io_retries`]), and pages that stay unreadable are
//! dropped from the hop while the traversal continues on the surviving
//! frontier. The damage is reported, never hidden:
//! [`QueryStats::retries`], [`QueryStats::crc_failures`],
//! [`QueryStats::failed_ios`] and [`QueryStats::degraded`].
//!
//! # Batched execution ([`search_batch`])
//!
//! A batch of queries runs all hop loops in **lockstep**: every round,
//! each live query does its normal page selection, the per-query frontier
//! reads are merged into one deduplicated `begin_read` (a page wanted by
//! several queries is read **once** and scored once per wanting query
//! through that query's own LUT), and each query's topology phase and
//! exact scans then run against the shared bytes. The per-query ADC LUTs
//! are built together in one subspace-major pass over the codebook
//! ([`crate::pq::PqCodebook::build_luts_into`]), with bit-identical
//! near-duplicate queries aliasing a batchmate's table
//! ([`crate::pq::LutArena`]).
//!
//! **Identity argument** — why batch results are bit-identical to running
//! [`search_pages`] per query:
//! * Each query's cursor (candidate pool, visited marks, reservoir) is
//!   private and evolves through exactly the sequential state machine; a
//!   selection pass only ends early when `pop_closest_unvisited` runs dry,
//!   so "empty selection ⇒ query done" matches the sequential loop's exit.
//! * Sharing only changes *where bytes come from*, never which bytes: a
//!   deduplicated page read returns the same page image every wanting
//!   query would have read itself, and each query scores it in its own
//!   selection order (disk pages first, then cache hits — the sequential
//!   gather order).
//! * Aliased LUTs are bit-identical to the rebuild they replace (the
//!   default share policy only aliases bit-identical queries), and the
//!   result reservoir's retained set is order-independent, so moving the
//!   exact scans out of the deferred pipeline changes timing only.
//! * **Cross-tick LUT cache** ([`SearchContext::lut_cache`], default off):
//!   a cache hit returns byte-for-byte the table a rebuild would produce —
//!   the cache keys on the query's exact f32 bit pattern plus the
//!   codebook's `(m, k)` identity (see [`crate::pq::LutCache`]) — so
//!   resolving a LUT from the cache instead of building it can never
//!   change a result. [`QueryStats::lut_cache_hits`] counts the skipped
//!   builds.
//! * **I/O-overlapped rerank**: while a round's deduplicated `begin_read`
//!   is in flight, the topology + exact-scan phase already runs for every
//!   batchmate whose selected pages were all satisfied from the page cache
//!   (cached pages never enter the round's read list, so these queries
//!   need none of the in-flight bytes). Each query mutates only its own
//!   cursor and stats plus shared scratch that is cleared per query, so
//!   overlapping cache-only batchmates with the wait reorders work
//!   *across* queries without reordering any single query's state machine
//!   — every per-query candidate order, and therefore every result, is
//!   untouched.
//!
//! Speculation is sequential-only; it also never changes results, so the
//! parity holds against the speculating one-query path. Stats keep their
//! sequential meaning per query (`ios` counts a shared page for every
//! wanting query); [`QueryStats::batch_shared_ios`] counts the duplicate
//! wants that were *not* physically re-read, so a round's physical reads
//! are `Σ ios − Σ batch_shared_ios`, and [`QueryStats::lut_reused`] marks
//! queries whose LUT was aliased.
//!
//! [`spec_wasted`]: crate::metrics::QueryStats::spec_wasted
//! [`QueryStats::spec_hits`]: crate::metrics::QueryStats::spec_hits
//! [`QueryStats::batch_shared_ios`]: crate::metrics::QueryStats::batch_shared_ios
//! [`QueryStats::lut_reused`]: crate::metrics::QueryStats::lut_reused
//! [`QueryStats::lut_cache_hits`]: crate::metrics::QueryStats::lut_cache_hits

mod candidates;

pub use candidates::{CandidateSet, TopReservoir};

use crate::cache::{MemCodes, PageCache};
use crate::dataset::Dtype;
use crate::distance::BatchScanner;
use crate::io::{PageStore, PendingRead};
use crate::layout::{IndexMeta, PageRef};
use crate::metrics::trace::{HopSpan, TraceSink};
use crate::metrics::{PageFaultRecord, QueryStats};
use crate::pq::{AdcLut, LutArena, LutCache, PqCodebook};
use crate::Result;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Tunables of one search (paper notation: L = pool, b = I/O batch).
#[derive(Debug, Clone)]
pub struct SearchParams {
    pub k: usize,
    /// Candidate-set capacity (search list size) — the recall knob.
    pub l: usize,
    /// Pages per batched I/O round.
    pub io_batch: usize,
    /// Hamming probe radius for routing entry.
    pub routing_radius: usize,
    /// Max entry points taken from the router.
    pub max_entries: usize,
    /// Overlap exact-distance computation with the next async read
    /// (paper §5 I/O-computation pipeline).
    pub pipeline: bool,
    /// Two-deep pipeline: speculatively submit the predicted next-hop page
    /// batch while the topology phase runs (needs `pipeline` and a store
    /// with `max_inflight_batches() > 1`; results are bit-identical either
    /// way).
    pub speculate: bool,
    /// Bounded per-page re-reads after a transient I/O error or checksum
    /// mismatch before the page is skipped and the traversal degrades.
    pub max_io_retries: usize,
    /// Batch mode only: alias the ADC LUT of a near-duplicate batchmate
    /// instead of rebuilding it (see [`crate::pq::LutArena`]).
    pub lut_share: bool,
    /// Near-duplicate threshold for `lut_share`. The default `1.0` shares
    /// only bit-identical queries (sharing can never change results);
    /// values `< 1.0` opt into lossy cosine-screened sharing.
    pub lut_share_threshold: f32,
}

impl Default for SearchParams {
    fn default() -> Self {
        Self {
            k: 10,
            l: 64,
            io_batch: 5,
            routing_radius: 2,
            max_entries: 16,
            pipeline: true,
            speculate: true,
            max_io_retries: 3,
            lut_share: true,
            lut_share_threshold: 1.0,
        }
    }
}

/// Per-thread reusable search state (buffers sized on first use).
pub struct SearchScratch {
    candidates: CandidateSet,
    /// Visited marks, epoch-stamped so clearing is O(1).
    visited_vec: Vec<u32>,
    visited_page: Vec<u32>,
    epoch: u32,
    /// Bounded top-L result reservoir (exact distances).
    results: TopReservoir,
    page_bufs: Vec<Vec<u8>>,
    page_ids: Vec<u32>,
    /// Every page touched by the last search (warm-up frequency input).
    pages_touched: Vec<u32>,
    dist_buf: Vec<f32>,
    /// Per-query ADC table, rebuilt in place (no per-query allocation).
    lut: AdcLut,
    /// Gathered neighbor ids / codes / distances for the batched topology
    /// phase; cleared per hop, capacity retained.
    nbr_ids: Vec<u32>,
    nbr_codes: Vec<u8>,
    nbr_dists: Vec<f32>,
}

impl SearchScratch {
    pub fn new() -> Self {
        Self {
            candidates: CandidateSet::new(64),
            visited_vec: Vec::new(),
            visited_page: Vec::new(),
            epoch: 0,
            results: TopReservoir::new(64),
            page_bufs: Vec::new(),
            page_ids: Vec::new(),
            pages_touched: Vec::new(),
            dist_buf: Vec::new(),
            lut: AdcLut::empty(),
            nbr_ids: Vec::new(),
            nbr_codes: Vec::new(),
            nbr_dists: Vec::new(),
        }
    }

    /// Results of the last search (top-L scanned vectors, sorted).
    pub fn results_for_warmup(&self) -> Vec<(f32, u32)> {
        self.results.sorted()
    }

    /// Pages touched by the last search (borrowed; no per-call clone).
    pub fn visited_pages_for_warmup(&self) -> &[u32] {
        &self.pages_touched
    }

    /// Buffers currently parked in the page pool (leak diagnostics).
    pub fn pooled_buffers(&self) -> usize {
        self.page_bufs.len()
    }

    fn reset(&mut self, n_slots: usize, n_pages: usize, l: usize, k: usize) {
        if self.visited_vec.len() < n_slots {
            self.visited_vec.resize(n_slots, 0);
        }
        if self.visited_page.len() < n_pages {
            self.visited_page.resize(n_pages, 0);
        }
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // Epoch wrapped: hard-clear.
            self.visited_vec.fill(0);
            self.visited_page.fill(0);
            self.epoch = 1;
        }
        self.candidates.reset(l);
        self.results.reset(l.max(k));
        self.pages_touched.clear();
    }
}

impl Default for SearchScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Everything a search needs to see of the opened index.
pub struct SearchContext<'a> {
    pub meta: &'a IndexMeta,
    pub store: &'a dyn PageStore,
    pub cache: &'a PageCache,
    pub memcodes: &'a MemCodes,
    pub scanner: &'a dyn BatchScanner,
    pub pq: &'a PqCodebook,
    /// Cross-tick LUT cache (`None` = off, the default). Consulted only by
    /// [`search_batch`]: recurring bit-identical queries skip their LUT
    /// build entirely across server ticks, loss-free by construction.
    pub lut_cache: Option<&'a LutCache>,
    /// Opt-in hop tracing (`None` = off, the default — one pointer check
    /// per hop is the entire happy-path cost). When set, every hop emits a
    /// JSONL span to the sink; see `OBSERVABILITY.md`.
    pub trace: Option<&'a TraceSink>,
}

/// Counter snapshot taken at hop start so a trace span can report per-hop
/// deltas without any always-on bookkeeping (only built when tracing).
#[derive(Clone, Copy)]
struct HopSnap {
    cache_hits: u64,
    spec_hits: u64,
    spec_wasted: u64,
    retries: u64,
    failed_ios: u64,
    lut_build: Duration,
    io_submit: Duration,
    io_wait: Duration,
    topology: Duration,
    rerank: Duration,
}

impl HopSnap {
    fn of(st: &QueryStats) -> Self {
        Self {
            cache_hits: st.cache_hits,
            spec_hits: st.spec_hits,
            spec_wasted: st.spec_wasted,
            retries: st.retries,
            failed_ios: st.failed_ios,
            lut_build: st.phases.lut_build,
            io_submit: st.phases.io_submit,
            io_wait: st.phases.io_wait,
            topology: st.phases.topology,
            rerank: st.phases.rerank,
        }
    }

    /// Build the span for one finished hop from the deltas since `self`.
    fn span<'p>(&self, st: &QueryStats, qid: u64, batch: u64, pages: &'p [u32]) -> HopSpan<'p> {
        HopSpan {
            query: qid,
            hop: st.hops.saturating_sub(1),
            batch,
            pages,
            cache_hits: st.cache_hits - self.cache_hits,
            spec_hits: st.spec_hits - self.spec_hits,
            spec_wasted: st.spec_wasted - self.spec_wasted,
            retries: st.retries - self.retries,
            failed_ios: st.failed_ios - self.failed_ios,
            lut_build_us: dur_us(st.phases.lut_build.saturating_sub(self.lut_build)),
            io_submit_us: dur_us(st.phases.io_submit.saturating_sub(self.io_submit)),
            io_wait_us: dur_us(st.phases.io_wait.saturating_sub(self.io_wait)),
            topology_us: dur_us(st.phases.topology.saturating_sub(self.topology)),
            rerank_us: dur_us(st.phases.rerank.saturating_sub(self.rerank)),
        }
    }
}

fn dur_us(d: Duration) -> f64 {
    d.as_secs_f64() * 1e6
}

/// Exact scans deferred until the next I/O wait (paper §5 pipeline);
/// owned buffers cycle back into the scratch pool after scanning.
enum Deferred<'c> {
    Owned(Vec<u8>),
    Cached(&'c [u8]),
}

/// Every owned page buffer that is mid-flight through one search hop. It
/// lives *outside* the fallible hop loop so that `search_pages` can sweep
/// everything back into `scratch.page_bufs` on **any** exit path — a `?`
/// after buffers left the pool must not shrink it (ISSUE 3 satellite: a
/// recovered error used to permanently reintroduce per-query allocation).
struct HopState<'c> {
    deferred: Vec<Deferred<'c>>,
    disk_bufs: Vec<Vec<u8>>,
    /// Speculative pages consumed by the current hop: `(page_id, bytes)`.
    prefetched: Vec<(u32, Vec<u8>)>,
    /// The in-flight speculative batch and its page ids.
    spec: Option<(PendingRead<'c>, Vec<u32>)>,
}

/// Pop `n` page buffers from the pool, allocating only on cold start —
/// the one place that knows how search buffers are made.
fn take_bufs(pool: &mut Vec<Vec<u8>>, n: usize, page_size: usize) -> Vec<Vec<u8>> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(pool.pop().unwrap_or_else(|| vec![0u8; page_size]));
    }
    out
}

/// True when `buf` can be trusted as a faithful copy of its page: the CRC
/// tail on checksummed (v5+) indexes, vacuously true on legacy indexes
/// that carry no checksum.
fn page_bytes_ok(meta: &IndexMeta, buf: &[u8]) -> bool {
    !meta.page_crc || PageRef::verify_checksum(&buf[..meta.page_size])
}

/// Bounded synchronous re-read of one page with exponential backoff
/// (50µs·2ⁿ, capped) — the retry policy for transient device errors and
/// checksum mismatches. Every attempt counts in `stats.retries`; returns
/// whether `buf` ended up holding a verified copy.
fn reread_with_retries(
    ctx: &SearchContext<'_>,
    pid: u32,
    buf: &mut Vec<u8>,
    max_retries: usize,
    stats: &mut QueryStats,
) -> bool {
    for attempt in 0..max_retries {
        stats.retries += 1;
        std::thread::sleep(Duration::from_micros(50u64 << attempt.min(6)));
        match ctx.store.read_pages(std::slice::from_ref(&pid), std::slice::from_mut(buf)) {
            Ok(()) => {
                if page_bytes_ok(ctx.meta, buf) {
                    return true;
                }
                stats.crc_failures += 1;
            }
            Err(_) => {}
        }
    }
    false
}

/// Append a [`PageFaultRecord`] for `pid` when its recovery left any trace
/// — retries attempted, CRC mismatches observed, or a permanent failure —
/// given the pre-recovery counter snapshot `(r0, c0)`. The happy path
/// (clean first read) records nothing and allocates nothing.
fn record_page_fault(stats: &mut QueryStats, pid: u32, r0: u64, c0: u64, good: bool) {
    if stats.retries > r0 || stats.crc_failures > c0 || !good {
        stats.page_faults.push(PageFaultRecord {
            page: pid,
            retries: (stats.retries - r0) as u32,
            crc_failures: (stats.crc_failures - c0) as u32,
            failed: !good,
        });
    }
}

/// Run Algorithm 2. `entries` are entry-point vector ids (new-id space)
/// from the router (or the medoid fallback). The per-query ADC table is
/// built into `scratch` from `ctx.pq`. Returns the top-k
/// `(distance, original_id)` pairs.
pub fn search_pages(
    ctx: &SearchContext<'_>,
    query: &[f32],
    entries: &[u32],
    params: &SearchParams,
    scratch: &mut SearchScratch,
    stats: &mut QueryStats,
) -> Result<Vec<(f32, u32)>> {
    let meta = ctx.meta;
    let code_w = meta.code_bytes();
    scratch.reset(meta.n_slots(), meta.n_pages, params.l, params.k);
    let epoch = scratch.epoch;

    // Per-query ADC table into the scratch-owned buffer.
    let t_lut = Instant::now();
    ctx.pq.build_lut_into(query, &mut scratch.lut);
    let lut_dt = t_lut.elapsed();
    stats.compute_time += lut_dt;
    stats.phases.lut_build += lut_dt;
    debug_assert_eq!(scratch.lut.code_bytes(), code_w);

    // Seed candidates (Alg. 2 lines 4-7): estimated distance from resident
    // codes where available; entries without codes get pushed with d=0 so
    // they are expanded first. Like the topology phase, a seed is marked
    // visited only when the pool accepts it — a rejected seed can still
    // re-enter later via a closer page.
    for &e in entries.iter().take(params.max_entries.max(1)) {
        if scratch.visited_vec[e as usize] == epoch {
            continue;
        }
        let d = ctx.memcodes.get(e).map(|c| scratch.lut.distance(c)).unwrap_or(0.0);
        if scratch.candidates.push(d, e) {
            scratch.visited_vec[e as usize] = epoch; // seeded (not yet expanded)
        }
        stats.approx_dists += 1;
    }

    let mut hop = HopState {
        deferred: Vec::new(),
        disk_bufs: Vec::new(),
        prefetched: Vec::new(),
        spec: None,
    };
    let result = run_hops(ctx, query, params, scratch, stats, &mut hop);

    // Pool-leak sweep: every owned buffer still mid-flight — a pending
    // speculation, unscanned deferred pages, this hop's read buffers —
    // returns to the pool whether `result` is Ok or Err.
    if let Some((sp, _ids)) = hop.spec.take() {
        let (sbufs, _sres) = sp.wait();
        stats.spec_wasted += sbufs.len() as u64;
        scratch.page_bufs.extend(sbufs);
    }
    for item in hop.deferred.drain(..) {
        if let Deferred::Owned(b) = item {
            scratch.page_bufs.push(b);
        }
    }
    scratch.page_bufs.append(&mut hop.disk_bufs);
    for (_, b) in hop.prefetched.drain(..) {
        scratch.page_bufs.push(b);
    }
    result?;

    // Final ranking (lines 29-30): the reservoir already holds the top-L
    // by (dist, id); sort it and cut to k.
    let t_cpu = Instant::now();
    let mut out = scratch.results.sorted();
    out.truncate(params.k);
    let fin_dt = t_cpu.elapsed();
    stats.compute_time += fin_dt;
    stats.phases.rerank += fin_dt;
    Ok(out)
}

/// The hop loop (Alg. 2 lines 8-28) plus the §5 pipeline. All owned page
/// buffers flow through `hop` so the caller can recover them on error.
fn run_hops<'c>(
    ctx: &SearchContext<'c>,
    query: &[f32],
    params: &SearchParams,
    scratch: &mut SearchScratch,
    stats: &mut QueryStats,
    hop: &mut HopState<'c>,
) -> Result<()> {
    let meta = ctx.meta;
    let capacity = meta.capacity as u32;
    let dtype: Dtype = meta.dtype;
    let stride = meta.vec_stride();
    // Storage bytes per PQ code (nibble-packed for PQ4 indexes) — the
    // stride for page parsing, memcodes and the gathered-code scratch.
    let code_w = meta.code_bytes();
    let epoch = scratch.epoch;
    // The two-deep pipeline only pays off on stores that genuinely keep
    // more than one batch in flight; on synchronous stores a speculative
    // read would serialize in front of real work. The static gate is
    // refined at runtime: if a speculative submission ever completes
    // synchronously (e.g. the AIO ctx pool is exhausted under
    // oversubscription and begin_read degraded to a blocking read),
    // speculation is switched off for the rest of this query.
    let mut speculate =
        params.pipeline && params.speculate && ctx.store.max_inflight_batches() > 1;

    let HopState { deferred, disk_bufs, prefetched, spec } = hop;

    // Pages dropped this hop after exhausting retries (degraded traversal)
    // — cleared per hop, capacity retained.
    let mut failed_pages: Vec<u32> = Vec::new();

    // Drains `deferred`: exact distances into the result reservoir;
    // evaluates to a `Result` so call sites with a read still in flight
    // can reclaim its buffers before propagating. The reservoir's
    // retained set is order-independent, so draining LIFO is
    // result-identical to FIFO — and lets a parse failure hand its buffer
    // (and, via the caller's sweep, all remaining ones) back to the pool.
    macro_rules! scan_deferred {
        () => {{
            let t_cpu = Instant::now();
            let mut scan_result: Result<()> = Ok(());
            while let Some(item) = deferred.pop() {
                let bytes: &[u8] = match &item {
                    Deferred::Owned(b) => b,
                    Deferred::Cached(b) => b,
                };
                let page = match PageRef::parse(&bytes[..meta.page_size], stride, code_w) {
                    Ok(p) => p,
                    Err(e) => {
                        if let Deferred::Owned(buf) = item {
                            scratch.page_bufs.push(buf); // back to the pool
                        }
                        scan_result = Err(e);
                        break;
                    }
                };
                let nv = page.n_vecs();
                if scratch.dist_buf.len() < nv {
                    scratch.dist_buf.resize(nv, 0.0);
                }
                ctx.scanner
                    .scan(query, page.vectors_block(), dtype, nv, &mut scratch.dist_buf);
                stats.exact_dists += nv as u64;
                for i in 0..nv {
                    scratch.results.push(scratch.dist_buf[i], page.orig_id(i));
                }
                if let Deferred::Owned(buf) = item {
                    scratch.page_bufs.push(buf); // back to the pool
                }
            }
            let scan_dt = t_cpu.elapsed();
            stats.compute_time += scan_dt;
            stats.phases.rerank += scan_dt;
            scan_result
        }};
    }

    // Hop tracing state: a query id and a per-hop counter snapshot, both
    // built only when the sink is on.
    let qid = ctx.trace.map(|t| t.next_query_id()).unwrap_or(0);
    let mut hop_snap: Option<HopSnap> = None;

    while scratch.candidates.has_unvisited() {
        // Collect up to `io_batch` unvisited pages (lines 10-18).
        scratch.page_ids.clear();
        while scratch.page_ids.len() < params.io_batch {
            let Some(v) = scratch.candidates.pop_closest_unvisited() else {
                break;
            };
            let p = v / capacity;
            if scratch.visited_page[p as usize] != epoch {
                scratch.visited_page[p as usize] = epoch;
                scratch.page_ids.push(p);
                scratch.pages_touched.push(p);
            }
        }
        if scratch.page_ids.is_empty() {
            // Popped candidates all mapped to already-visited pages — no
            // page read happened, so this round is not a hop.
            continue;
        }
        stats.hops += 1;
        failed_pages.clear();
        if ctx.trace.is_some() {
            let mut snap = HopSnap::of(stats);
            if stats.hops == 1 {
                // Charge the pre-loop LUT build to the first hop's span.
                snap.lut_build = Duration::ZERO;
            }
            hop_snap = Some(snap);
        }

        // Partition into speculation-covered / cached / disk. Pages the
        // in-flight speculative batch already covers need no new read.
        let spec_pages: &[u32] =
            spec.as_ref().map(|(_, ids)| ids.as_slice()).unwrap_or(&[]);
        let mut disk_ids: Vec<u32> = Vec::with_capacity(scratch.page_ids.len());
        let mut cached_bytes: Vec<&'c [u8]> = Vec::new();
        let mut want_spec: Vec<u32> = Vec::new();
        for &p in scratch.page_ids.iter() {
            if spec_pages.contains(&p) {
                want_spec.push(p);
            } else if let Some(bytes) = ctx.cache.get(p) {
                cached_bytes.push(bytes);
                stats.cache_hits += 1;
            } else {
                disk_ids.push(p);
            }
        }

        // Submit the non-speculated reads (line 19), buffers from the
        // pool. This batch and the speculation are now in flight together.
        debug_assert!(disk_bufs.is_empty());
        let rbufs = take_bufs(&mut scratch.page_bufs, disk_ids.len(), meta.page_size);
        let t_submit = Instant::now();
        let pending = ctx.store.begin_read(&disk_ids, rbufs);
        let submit_time = t_submit.elapsed();
        stats.ios += disk_ids.len() as u64;
        stats.bytes_read += (disk_ids.len() * meta.page_size) as u64;

        // In pipelined mode the exact scans deferred from the previous hop
        // execute while the device works — the §5 I/O-computation overlap.
        // A scan failure here must reclaim the in-flight read's buffers
        // (they live inside `pending`, out of the caller's sweep) before
        // surfacing; the speculation, if any, is still parked in
        // `hop.spec` and is recovered by the caller.
        if params.pipeline {
            if let Err(e) = scan_deferred!() {
                let (b, _) = pending.wait();
                scratch.page_bufs.extend(b);
                return Err(e);
            }
        }

        // Resolve last hop's speculation (it has had a full topology phase
        // plus this hop's selection to complete — the wait is usually
        // free). Matching pages become this hop's prefetched bytes and are
        // counted as ordinary reads; the rest were mispredictions.
        debug_assert!(prefetched.is_empty());
        if let Some((sp, sids)) = spec.take() {
            let t_spec = Instant::now();
            let (mut sbufs, sres) = sp.wait();
            let spec_dt = t_spec.elapsed();
            stats.io_time += spec_dt;
            stats.phases.io_wait += spec_dt;
            let spec_ok = sres.is_ok();
            for (&pid, mut buf) in sids.iter().zip(sbufs.drain(..)) {
                let wanted = want_spec.contains(&pid);
                if !wanted {
                    // `spec_wasted` measures *prediction* quality: a page
                    // the frontier never asked for. A correctly-predicted
                    // page lost to a device error is not the predictor's
                    // fault.
                    stats.spec_wasted += 1;
                    scratch.page_bufs.push(buf);
                    continue;
                }
                // A wanted page is consumed as an ordinary read — but only
                // once its bytes check out. A batch error taints every
                // buffer: a failed read can leave a stale-but-valid page
                // from the pool behind, which a checksum cannot tell from
                // the real thing (the CRC doesn't bind page identity), so
                // nothing from a failed batch is ever consumed directly.
                let (r0, c0) = (stats.retries, stats.crc_failures);
                let mut good = spec_ok && {
                    let ok = page_bytes_ok(meta, &buf);
                    if !ok {
                        stats.crc_failures += 1;
                    }
                    ok
                };
                if good {
                    stats.spec_hits += 1;
                } else {
                    good =
                        reread_with_retries(ctx, pid, &mut buf, params.max_io_retries, stats);
                }
                record_page_fault(stats, pid, r0, c0, good);
                stats.ios += 1;
                stats.bytes_read += meta.page_size as u64;
                if good {
                    prefetched.push((pid, buf));
                } else {
                    // Truly unreadable: drop the page, keep traversing.
                    failed_pages.push(pid);
                    scratch.page_bufs.push(buf);
                }
            }
        }

        // Wait for this hop's read (line 20). The buffers come back even
        // on error, parked in `hop.disk_bufs` for the caller's sweep.
        let t_wait = Instant::now();
        let (rbufs_back, read_result) = pending.wait();
        *disk_bufs = rbufs_back;
        let wait_dt = t_wait.elapsed();
        stats.io_time += submit_time + wait_dt;
        stats.phases.io_submit += submit_time;
        stats.phases.io_wait += wait_dt;

        // Recovery: a batch error or a checksum mismatch demotes the
        // affected pages to bounded per-page re-reads; pages that stay
        // unreadable are dropped from the hop and the traversal continues
        // degraded rather than failing the query.
        let batch_ok = read_result.is_ok();
        if !batch_ok || meta.page_crc {
            let mut keep = 0usize;
            for i in 0..disk_ids.len() {
                let pid = disk_ids[i];
                // Batch errors don't say which page failed, and a failed
                // read can leave a stale-but-valid pool page behind that a
                // checksum cannot tell from the real thing — so every page
                // of a failed batch is re-read rather than salvaged.
                let (r0, c0) = (stats.retries, stats.crc_failures);
                let mut good = batch_ok && {
                    let ok = page_bytes_ok(meta, &disk_bufs[i]);
                    if !ok {
                        stats.crc_failures += 1;
                    }
                    ok
                };
                if !good {
                    good = reread_with_retries(
                        ctx,
                        pid,
                        &mut disk_bufs[i],
                        params.max_io_retries,
                        stats,
                    );
                }
                record_page_fault(stats, pid, r0, c0, good);
                if good {
                    // Stable compaction: kept pages preserve selection
                    // order, so the topology phase's in-order matching
                    // below still works.
                    disk_ids.swap(keep, i);
                    disk_bufs.swap(keep, i);
                    keep += 1;
                } else {
                    failed_pages.push(pid);
                }
            }
            for buf in disk_bufs.drain(keep..) {
                scratch.page_bufs.push(buf);
            }
            disk_ids.truncate(keep);
        }
        if !failed_pages.is_empty() {
            stats.failed_ios += failed_pages.len() as u64;
            stats.degraded = true;
        }

        // Two-deep pipeline: predict the next hop's batch from the
        // pre-topology pool and put it on the device now, so it reads
        // while the topology phase below runs on the CPU. If the topology
        // phase changes the frontier, the next hop discards the guess.
        if speculate {
            debug_assert!(spec.is_none());
            let mut sids: Vec<u32> = Vec::with_capacity(params.io_batch);
            {
                let visited_page = &scratch.visited_page;
                let cache = ctx.cache;
                let io_batch = params.io_batch;
                scratch.candidates.peek_unvisited(|v| {
                    let p = v / capacity;
                    if visited_page[p as usize] != epoch
                        && !sids.contains(&p)
                        && cache.get(p).is_none()
                    {
                        sids.push(p);
                    }
                    sids.len() < io_batch
                });
            }
            if !sids.is_empty() {
                let sbufs = take_bufs(&mut scratch.page_bufs, sids.len(), meta.page_size);
                let t_spec = Instant::now();
                let sp = ctx.store.begin_read(&sids, sbufs);
                let spec_submit_dt = t_spec.elapsed();
                stats.io_time += spec_submit_dt;
                stats.phases.io_submit += spec_submit_dt;
                if !sp.is_async() {
                    // The store degraded to a synchronous submission (e.g.
                    // AIO ctx pool exhausted): this speculation already
                    // cost blocking I/O, so use its data but stop
                    // speculating for the rest of the query.
                    speculate = false;
                }
                *spec = Some((sp, sids));
            }
        }

        // Topology phase (lines 24-26): neighbor entries → candidate set
        // with ADC estimates. Never deferred — the next hop's page
        // selection depends on it. Runs in two passes: gather all unvisited
        // neighbors' codes into one contiguous scratch block, score them
        // with a single batched ADC call, then push.
        let t_cpu = Instant::now();
        scratch.nbr_ids.clear();
        scratch.nbr_codes.clear();
        {
            // Split the scratch borrows explicitly so the closure and the
            // page-id iteration below borrow disjoint fields.
            let visited_vec = &scratch.visited_vec;
            let nbr_ids = &mut scratch.nbr_ids;
            let nbr_codes = &mut scratch.nbr_codes;
            let mut gather = |bytes: &[u8], is_disk: bool| -> Result<()> {
                let page = PageRef::parse(&bytes[..meta.page_size], stride, code_w)?;
                if is_disk {
                    stats.bytes_used += page.used_bytes() as u64;
                }
                for j in 0..page.n_nbrs() {
                    let nb = page.nbr_id(j);
                    if visited_vec[nb as usize] == epoch {
                        continue;
                    }
                    let code = page.nbr_code(j).or_else(|| ctx.memcodes.get(nb));
                    let Some(code) = code else {
                        // Build guarantees one copy exists; treat miss as a
                        // corrupt index rather than silently skipping.
                        anyhow::bail!("no compressed vector for neighbor {nb}");
                    };
                    debug_assert_eq!(code.len(), code_w);
                    nbr_ids.push(nb);
                    nbr_codes.extend_from_slice(code);
                }
                Ok(())
            };
            // Disk-sourced pages in selection order (fresh reads + spec
            // hits), then cache hits — the exact order the one-deep path
            // used, so results stay bit-identical with speculation on.
            let mut processed = 0usize;
            let mut di = 0usize;
            for &p in scratch.page_ids.iter() {
                let bytes: &[u8] = if di < disk_ids.len() && disk_ids[di] == p {
                    di += 1;
                    disk_bufs[di - 1].as_slice()
                } else if let Some((_, b)) = prefetched.iter().find(|(id, _)| *id == p) {
                    b.as_slice()
                } else {
                    // Cache hit (second pass) or a page dropped as
                    // unreadable this hop.
                    continue;
                };
                gather(bytes, true)?;
                processed += 1;
            }
            for &bytes in cached_bytes.iter() {
                gather(bytes, false)?;
                processed += 1;
            }
            anyhow::ensure!(
                processed + failed_pages.len() == scratch.page_ids.len(),
                "internal: a selected page lost its byte source"
            );
        }
        let n_gathered = scratch.nbr_ids.len();
        scratch
            .lut
            .score_into(&scratch.nbr_codes, n_gathered, &mut scratch.nbr_dists);
        stats.approx_dists += n_gathered as u64;
        for i in 0..n_gathered {
            let nb = scratch.nbr_ids[i];
            // A neighbor can be gathered twice in one round (shared by two
            // pages); the epoch re-check keeps the second copy from
            // double-entering the pool.
            if scratch.visited_vec[nb as usize] == epoch {
                continue;
            }
            // Only mark visited when accepted into the pool; rejected
            // candidates may re-enter later via a closer page.
            if scratch.candidates.push(scratch.nbr_dists[i], nb) {
                scratch.visited_vec[nb as usize] = epoch;
            }
        }
        let topo_dt = t_cpu.elapsed();
        stats.compute_time += topo_dt;
        stats.phases.topology += topo_dt;

        // Queue the exact scans (lines 21-23): deferred in pipelined mode,
        // immediate otherwise.
        for buf in disk_bufs.drain(..) {
            deferred.push(Deferred::Owned(buf));
        }
        for (_, buf) in prefetched.drain(..) {
            deferred.push(Deferred::Owned(buf));
        }
        for bytes in cached_bytes {
            deferred.push(Deferred::Cached(bytes));
        }
        if !params.pipeline {
            // Nothing is in flight here except a speculation parked in
            // `hop.spec` (caller-recovered), so the error can propagate.
            scan_deferred!()?;
        }

        if let (Some(tr), Some(snap)) = (ctx.trace, hop_snap.take()) {
            tr.emit_hop(&snap.span(stats, qid, 1, &scratch.page_ids));
        }
    }
    // Drain the tail of the pipeline.
    scan_deferred!()?;
    Ok(())
}

/// Per-query traversal state inside a batched search: exactly the mutable
/// state [`search_pages`] keeps per query, minus the buffers that are
/// shared across the batch (the page pool, gather scratch and LUTs, which
/// live in [`BatchScratch`]).
struct QueryCursor {
    candidates: CandidateSet,
    results: TopReservoir,
    visited_vec: Vec<u32>,
    visited_page: Vec<u32>,
    epoch: u32,
    /// This round's page selection, in selection order — the order the
    /// topology phase scores disk pages in.
    page_ids: Vec<u32>,
    /// Candidate pool exhausted — this query takes no further rounds.
    done: bool,
    /// A per-query failure (corrupt page, missing code). The query stops;
    /// its batchmates keep running.
    error: Option<anyhow::Error>,
}

impl QueryCursor {
    fn new() -> Self {
        Self {
            candidates: CandidateSet::new(64),
            results: TopReservoir::new(64),
            visited_vec: Vec::new(),
            visited_page: Vec::new(),
            epoch: 0,
            page_ids: Vec::new(),
            done: false,
            error: None,
        }
    }

    fn reset(&mut self, n_slots: usize, n_pages: usize, l: usize, k: usize) {
        if self.visited_vec.len() < n_slots {
            self.visited_vec.resize(n_slots, 0);
        }
        if self.visited_page.len() < n_pages {
            self.visited_page.resize(n_pages, 0);
        }
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // Epoch wrapped: hard-clear.
            self.visited_vec.fill(0);
            self.visited_page.fill(0);
            self.epoch = 1;
        }
        self.candidates.reset(l);
        self.results.reset(l.max(k));
        self.page_ids.clear();
        self.done = false;
        self.error = None;
    }
}

/// Per-batch reusable search state: the LUT arena, the shared page-buffer
/// pool and the gather/scan scratch, plus one [`QueryCursor`] per query.
/// Like [`SearchScratch`], allocations are sized on first use and reused —
/// steady-state batches allocate nothing.
pub struct BatchScratch {
    arena: LutArena,
    cursors: Vec<QueryCursor>,
    /// Shared pool of page-sized buffers (one copy of each deduplicated
    /// round read, not one per wanting query).
    page_bufs: Vec<Vec<u8>>,
    dist_buf: Vec<f32>,
    nbr_ids: Vec<u32>,
    nbr_codes: Vec<u8>,
    nbr_dists: Vec<f32>,
    /// This round's deduplicated disk page ids, in first-wanting order.
    round_ids: Vec<u32>,
    /// For each `round_ids` entry, the query that first wanted it — the
    /// query charged for the physical recovery work (CRC checks, retries).
    round_owner: Vec<usize>,
    /// Per-round flags: queries whose topology + scan phase already ran
    /// during the I/O overlap window (selection fully cache-satisfied).
    round_done: Vec<bool>,
}

impl BatchScratch {
    pub fn new() -> Self {
        Self {
            arena: LutArena::new(),
            cursors: Vec::new(),
            page_bufs: Vec::new(),
            dist_buf: Vec::new(),
            nbr_ids: Vec::new(),
            nbr_codes: Vec::new(),
            nbr_dists: Vec::new(),
            round_ids: Vec::new(),
            round_owner: Vec::new(),
            round_done: Vec::new(),
        }
    }

    /// Buffers currently parked in the shared page pool (leak diagnostics).
    pub fn pooled_buffers(&self) -> usize {
        self.page_bufs.len()
    }
}

impl Default for BatchScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// One page's topology gather for one query of a batch: parse, count the
/// consumed bytes (disk-sourced pages only, matching the sequential path),
/// and append every unvisited neighbor's id + code to the gather scratch.
#[allow(clippy::too_many_arguments)]
fn gather_page(
    ctx: &SearchContext<'_>,
    bytes: &[u8],
    is_disk: bool,
    visited_vec: &[u32],
    epoch: u32,
    nbr_ids: &mut Vec<u32>,
    nbr_codes: &mut Vec<u8>,
    stats: &mut QueryStats,
) -> Result<()> {
    let meta = ctx.meta;
    let code_w = meta.code_bytes();
    let page = PageRef::parse(&bytes[..meta.page_size], meta.vec_stride(), code_w)?;
    if is_disk {
        stats.bytes_used += page.used_bytes() as u64;
    }
    for j in 0..page.n_nbrs() {
        let nb = page.nbr_id(j);
        if visited_vec[nb as usize] == epoch {
            continue;
        }
        let code = page.nbr_code(j).or_else(|| ctx.memcodes.get(nb));
        let Some(code) = code else {
            // Build guarantees one copy exists; treat miss as a corrupt
            // index rather than silently skipping.
            anyhow::bail!("no compressed vector for neighbor {nb}");
        };
        debug_assert_eq!(code.len(), code_w);
        nbr_ids.push(nb);
        nbr_codes.extend_from_slice(code);
    }
    Ok(())
}

/// One query's topology phase (gather + ADC scoring + candidate pushes)
/// and exact scans for one batch round, against the round's shared disk
/// bytes and the page cache. Factored out of the round loop so queries
/// whose selection was fully cache-satisfied can run while the round's
/// deduplicated read is still in flight — for those calls `round_bufs` is
/// empty and never indexed, because none of their pages appear in
/// `round_ids`. The call mutates only `cur`/`st` plus shared scratch that
/// is cleared on entry, so the relative order of batchmates can never
/// change any query's result (module docs, "I/O-overlapped rerank").
#[allow(clippy::too_many_arguments)]
fn process_query_round(
    ctx: &SearchContext<'_>,
    query: &[f32],
    lut: &AdcLut,
    cur: &mut QueryCursor,
    round_ids: &[u32],
    round_bufs: &[Vec<u8>],
    failed: &[u32],
    nbr_ids: &mut Vec<u32>,
    nbr_codes: &mut Vec<u8>,
    nbr_dists: &mut Vec<f32>,
    dist_buf: &mut Vec<f32>,
    st: &mut QueryStats,
) {
    let meta = ctx.meta;
    let stride = meta.vec_stride();
    let code_w = meta.code_bytes();
    let dtype: Dtype = meta.dtype;
    let t_cpu = Instant::now();
    let QueryCursor {
        candidates,
        results,
        visited_vec,
        visited_page: _,
        epoch,
        page_ids,
        done: _,
        error,
    } = cur;
    let epoch = *epoch;
    nbr_ids.clear();
    nbr_codes.clear();
    let mut qerr: Option<anyhow::Error> = None;
    // Gather order: disk-sourced pages in selection order, then cache
    // hits — the sequential order, so the candidate-pool evolution is
    // bit-identical.
    'gather: for pass in 0..2 {
        for &p in page_ids.iter() {
            let from_disk = round_ids.iter().position(|&r| r == p);
            let bytes: &[u8] = match (pass, from_disk) {
                (0, Some(i)) => {
                    if failed.contains(&p) {
                        continue; // dropped this round (degraded)
                    }
                    round_bufs[i].as_slice()
                }
                (1, None) => match ctx.cache.get(p) {
                    Some(b) => b,
                    None => continue,
                },
                _ => continue,
            };
            if let Err(e) =
                gather_page(ctx, bytes, pass == 0, visited_vec, epoch, nbr_ids, nbr_codes, st)
            {
                qerr = Some(e);
                break 'gather;
            }
        }
    }
    if let Some(e) = qerr.take() {
        let dt = t_cpu.elapsed();
        st.compute_time += dt;
        st.phases.topology += dt;
        *error = Some(e);
        return;
    }
    let n_g = nbr_ids.len();
    lut.score_into(&nbr_codes[..], n_g, nbr_dists);
    st.approx_dists += n_g as u64;
    for i in 0..n_g {
        let nb = nbr_ids[i];
        // A neighbor can be gathered twice in one round; the epoch
        // re-check keeps the second copy out.
        if visited_vec[nb as usize] == epoch {
            continue;
        }
        if candidates.push(nbr_dists[i], nb) {
            visited_vec[nb as usize] = epoch;
        }
    }
    // Exact scans (lines 21-23). The reservoir's retained set is
    // order-independent, so scanning here instead of deferred into the
    // next I/O wait changes timing only, never results.
    let t_rerank = Instant::now();
    for &p in page_ids.iter() {
        let bytes: &[u8] = if let Some(i) = round_ids.iter().position(|&r| r == p) {
            if failed.contains(&p) {
                continue;
            }
            round_bufs[i].as_slice()
        } else if let Some(b) = ctx.cache.get(p) {
            b
        } else {
            continue;
        };
        let page = match PageRef::parse(&bytes[..meta.page_size], stride, code_w) {
            Ok(pg) => pg,
            Err(e) => {
                qerr = Some(e);
                break;
            }
        };
        let nv = page.n_vecs();
        if dist_buf.len() < nv {
            dist_buf.resize(nv, 0.0);
        }
        ctx.scanner.scan(query, page.vectors_block(), dtype, nv, dist_buf);
        st.exact_dists += nv as u64;
        for i in 0..nv {
            results.push(dist_buf[i], page.orig_id(i));
        }
    }
    // Split the round's CPU span at the scan boundary: gather + scoring +
    // pushes are topology, the exact scans are rerank; their sum is the
    // exact coarse `compute_time` this block always charged.
    let topo_dt = t_rerank.duration_since(t_cpu);
    let rerank_dt = t_rerank.elapsed();
    st.compute_time += topo_dt + rerank_dt;
    st.phases.topology += topo_dt;
    st.phases.rerank += rerank_dt;
    *error = qerr;
}

/// Run Algorithm 2 for a whole query batch in lockstep: all LUTs are built
/// in one pass over the codebook (near-duplicates alias, see
/// [`crate::pq::LutArena`]), and each round merges every query's frontier
/// page reads into **one deduplicated `begin_read`** — a page wanted by
/// two queries is read once and scored twice.
///
/// Per-query results are bit-identical to sequential [`search_pages`] (the
/// module docs give the identity argument). Errors are per-query: a query
/// that hits a corrupt page stops with its own `Err` while its batchmates
/// keep running, so the return value is one `Result` per input query, in
/// order.
///
/// Stats semantics: a shared page counts in `ios`/`bytes_read` for *every*
/// wanting query (exactly what the sequential run would report), and in
/// `batch_shared_ios` for every wanting query after the first — so the
/// round's physical reads are `Σ ios − Σ batch_shared_ios`. Physical
/// recovery work (CRC verification, retries) is charged to the page's
/// first-wanting query.
pub fn search_batch(
    ctx: &SearchContext<'_>,
    queries: &[&[f32]],
    entries: &[&[u32]],
    params: &SearchParams,
    batch: &mut BatchScratch,
    stats: &mut [QueryStats],
) -> Vec<Result<Vec<(f32, u32)>>> {
    let n = queries.len();
    debug_assert_eq!(entries.len(), n);
    debug_assert_eq!(stats.len(), n);
    if n == 0 {
        return Vec::new();
    }
    let meta = ctx.meta;
    let capacity = meta.capacity as u32;
    let code_w = meta.code_bytes();

    let BatchScratch {
        arena,
        cursors,
        page_bufs,
        dist_buf,
        nbr_ids,
        nbr_codes,
        nbr_dists,
        round_ids,
        round_owner,
        round_done,
    } = batch;

    // LUT resolution. Without a cross-tick cache, every LUT is built in
    // one subspace-major pass (near-duplicates alias inside the arena);
    // with `ctx.lut_cache` on, recurring bit-identical queries take their
    // table straight from the cache and only the misses go through the
    // build pass, each unique fresh build published back. Either way the
    // resolved tables are byte-identical to a per-query rebuild (module
    // docs), and the (approximate) per-query share of the resolution cost
    // goes into each query's compute time.
    arena.set_share(params.lut_share, params.lut_share_threshold);
    let t_lut = Instant::now();
    let mut cached_luts: Vec<Option<Arc<AdcLut>>> = Vec::new();
    // Maps a cache-missed query to its arena build slot; empty when the
    // cache is off (then arena slot == query index).
    let mut miss_pos: Vec<usize> = Vec::new();
    match ctx.lut_cache {
        None => ctx.pq.build_luts_into(queries, arena),
        Some(cache) => {
            let (m, k) = (ctx.pq.m, ctx.pq.k);
            cached_luts.reserve(n);
            for &q in queries.iter() {
                cached_luts.push(cache.get(q, m, k));
            }
            miss_pos = vec![usize::MAX; n];
            let mut miss_queries: Vec<&[f32]> = Vec::new();
            for qi in 0..n {
                if cached_luts[qi].is_none() {
                    miss_pos[qi] = miss_queries.len();
                    miss_queries.push(queries[qi]);
                }
            }
            ctx.pq.build_luts_into(&miss_queries, arena);
            for qi in 0..n {
                let mi = miss_pos[qi];
                // Publish each unique fresh build; aliased slots share an
                // owner slot that gets published itself.
                if mi != usize::MAX && !arena.reused(mi) {
                    cache.insert(queries[qi], m, k, Arc::new(arena.lut(mi).clone()));
                }
            }
        }
    }
    // Per-query table handles: cache hit → the cached copy, otherwise the
    // query's arena slot.
    let lut_refs: Vec<&AdcLut> = (0..n)
        .map(|qi| match cached_luts.get(qi).and_then(|c| c.as_deref()) {
            Some(l) => l,
            None if miss_pos.is_empty() => arena.lut(qi),
            None => arena.lut(miss_pos[qi]),
        })
        .collect();
    let lut_dt = t_lut.elapsed() / n as u32;
    for (qi, st) in stats.iter_mut().enumerate() {
        st.compute_time += lut_dt;
        st.phases.lut_build += lut_dt;
        if matches!(cached_luts.get(qi), Some(Some(_))) {
            st.lut_cache_hits += 1;
        } else if arena.reused(if miss_pos.is_empty() { qi } else { miss_pos[qi] }) {
            st.lut_reused += 1;
        }
        debug_assert_eq!(lut_refs[qi].code_bytes(), code_w);
    }

    // Seed every cursor exactly like the sequential path (Alg. 2 lines
    // 4-7): estimated distance from resident codes where available,
    // visited only when the pool accepts.
    while cursors.len() < n {
        cursors.push(QueryCursor::new());
    }
    for qi in 0..n {
        let cur = &mut cursors[qi];
        cur.reset(meta.n_slots(), meta.n_pages, params.l, params.k);
        let st = &mut stats[qi];
        for &e in entries[qi].iter().take(params.max_entries.max(1)) {
            if cur.visited_vec[e as usize] == cur.epoch {
                continue;
            }
            let d = ctx.memcodes.get(e).map(|c| lut_refs[qi].distance(c)).unwrap_or(0.0);
            if cur.candidates.push(d, e) {
                cur.visited_vec[e as usize] = cur.epoch; // seeded (not yet expanded)
            }
            st.approx_dists += 1;
        }
    }

    // Pages dropped this round after exhausting retries — cleared per
    // round, capacity retained.
    let mut failed: Vec<u32> = Vec::new();

    // Hop tracing (off by default): per-query span ids plus a per-round
    // counter snapshot so each emitted span reports that round's deltas.
    let qids: Vec<u64> = match ctx.trace {
        Some(tr) => (0..n).map(|_| tr.next_query_id()).collect(),
        None => Vec::new(),
    };
    let mut snaps: Vec<HopSnap> = Vec::new();
    let mut round_no: u64 = 0;

    loop {
        if ctx.trace.is_some() {
            snaps.clear();
            snaps.extend(stats.iter().map(HopSnap::of));
            if round_no == 0 {
                // Charge the pre-loop LUT resolution to the first round.
                for s in snaps.iter_mut() {
                    s.lut_build = Duration::ZERO;
                }
            }
        }
        // Selection: one pass per live query, identical to the sequential
        // lines 10-18. A pass that finds no page proves the pool was
        // exhausted (it only ends early when `pop_closest_unvisited` runs
        // dry), so that query is done — see the module docs.
        round_ids.clear();
        round_owner.clear();
        let mut any = false;
        for qi in 0..n {
            let cur = &mut cursors[qi];
            cur.page_ids.clear();
            if cur.done || cur.error.is_some() {
                continue;
            }
            while cur.page_ids.len() < params.io_batch {
                let Some(v) = cur.candidates.pop_closest_unvisited() else {
                    break;
                };
                let p = v / capacity;
                if cur.visited_page[p as usize] != cur.epoch {
                    cur.visited_page[p as usize] = cur.epoch;
                    cur.page_ids.push(p);
                }
            }
            if cur.page_ids.is_empty() {
                cur.done = true;
                continue;
            }
            any = true;
            let st = &mut stats[qi];
            st.hops += 1;
            for &p in cur.page_ids.iter() {
                if ctx.cache.get(p).is_some() {
                    st.cache_hits += 1;
                    continue;
                }
                // Every wanting query counts the read (sequential-parity
                // `ios`); non-first wanters also count the share.
                st.ios += 1;
                st.bytes_read += meta.page_size as u64;
                if round_ids.contains(&p) {
                    st.batch_shared_ios += 1;
                } else {
                    round_ids.push(p);
                    round_owner.push(qi);
                }
            }
        }
        if !any {
            break;
        }

        // One deduplicated read for the whole round (line 19) — with the
        // topology + scan phase of every *cache-only* query (no selected
        // page in `round_ids`, so none of the in-flight bytes are needed)
        // overlapped into the wait. Cached pages never enter `round_ids`,
        // and each query touches only its own cursor plus per-call-cleared
        // scratch, so the overlap is invisible to the remaining queries —
        // see the module docs ("I/O-overlapped rerank").
        failed.clear();
        round_done.clear();
        round_done.resize(n, false);
        let mut round_bufs: Vec<Vec<u8>> = Vec::new();
        if !round_ids.is_empty() {
            let rbufs = take_bufs(page_bufs, round_ids.len(), meta.page_size);
            let t_submit = Instant::now();
            let pending = ctx.store.begin_read(&round_ids[..], rbufs);
            let submit_dt = t_submit.elapsed();
            for qi in 0..n {
                if cursors[qi].page_ids.is_empty()
                    || cursors[qi].error.is_some()
                    || cursors[qi].page_ids.iter().any(|p| round_ids.contains(p))
                {
                    continue;
                }
                process_query_round(
                    ctx,
                    queries[qi],
                    lut_refs[qi],
                    &mut cursors[qi],
                    round_ids,
                    &round_bufs,
                    &failed,
                    nbr_ids,
                    nbr_codes,
                    nbr_dists,
                    dist_buf,
                    &mut stats[qi],
                );
                round_done[qi] = true;
            }
            let t_wait = Instant::now();
            let (bufs, read_result) = pending.wait();
            // Charged I/O time excludes the overlapped CPU work: the
            // submit cost plus the residual wait, not the batchmates'
            // scoring that hid inside it.
            let wait_dt = t_wait.elapsed();
            let io_dt = submit_dt + wait_dt;
            round_bufs = bufs;
            for qi in 0..n {
                if cursors[qi].page_ids.iter().any(|p| round_ids.contains(p)) {
                    stats[qi].io_time += io_dt;
                    stats[qi].phases.io_submit += submit_dt;
                    stats[qi].phases.io_wait += wait_dt;
                }
            }

            // Recovery: the same per-page policy as the sequential path;
            // physical work is charged to the page's first-wanting query.
            let batch_ok = read_result.is_ok();
            if !batch_ok || meta.page_crc {
                for i in 0..round_ids.len() {
                    let pid = round_ids[i];
                    let st = &mut stats[round_owner[i]];
                    let (r0, c0) = (st.retries, st.crc_failures);
                    let mut good = batch_ok && {
                        let ok = page_bytes_ok(meta, &round_bufs[i]);
                        if !ok {
                            st.crc_failures += 1;
                        }
                        ok
                    };
                    if !good {
                        good = reread_with_retries(
                            ctx,
                            pid,
                            &mut round_bufs[i],
                            params.max_io_retries,
                            st,
                        );
                    }
                    record_page_fault(st, pid, r0, c0, good);
                    if !good {
                        failed.push(pid);
                    }
                }
            }
            if !failed.is_empty() {
                // Every query that wanted a dropped page degrades; its
                // batchmates are untouched.
                for qi in 0..n {
                    let nf =
                        cursors[qi].page_ids.iter().filter(|p| failed.contains(p)).count() as u64;
                    if nf > 0 {
                        stats[qi].failed_ios += nf;
                        stats[qi].degraded = true;
                    }
                }
            }
        }

        // Per-query topology phase + exact scans for every query not
        // already handled in the overlap window, in batch order. Each
        // query scores the one shared copy of a page's bytes through its
        // own LUT and cursor — read once, scored per wanting query.
        for qi in 0..n {
            if round_done[qi] || cursors[qi].page_ids.is_empty() || cursors[qi].error.is_some() {
                continue;
            }
            process_query_round(
                ctx,
                queries[qi],
                lut_refs[qi],
                &mut cursors[qi],
                round_ids,
                &round_bufs,
                &failed,
                nbr_ids,
                nbr_codes,
                nbr_dists,
                dist_buf,
                &mut stats[qi],
            );
        }

        // One span per live query per round (its hop) when tracing.
        if let Some(tr) = ctx.trace {
            let live = cursors.iter().take(n).filter(|c| !c.page_ids.is_empty()).count() as u64;
            for qi in 0..n {
                if cursors[qi].page_ids.is_empty() {
                    continue;
                }
                tr.emit_hop(&snaps[qi].span(&stats[qi], qids[qi], live, &cursors[qi].page_ids));
            }
        }
        round_no += 1;

        // The round's buffers — one per deduplicated page — back to the
        // shared pool.
        page_bufs.append(&mut round_bufs);
    }

    // Final ranking per query (lines 29-30).
    let t_fin = Instant::now();
    let mut out: Vec<Result<Vec<(f32, u32)>>> = Vec::with_capacity(n);
    for qi in 0..n {
        let cur = &mut cursors[qi];
        match cur.error.take() {
            Some(e) => out.push(Err(e)),
            None => {
                let mut r = cur.results.sorted();
                r.truncate(params.k);
                out.push(Ok(r));
            }
        }
    }
    let fin_dt = t_fin.elapsed() / n as u32;
    for st in stats.iter_mut() {
        st.compute_time += fin_dt;
        st.phases.rerank += fin_dt;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_default_match_paper() {
        let p = SearchParams::default();
        assert_eq!(p.io_batch, 5); // paper §6.1: batch size fixed at 5
        assert_eq!(p.k, 10); // recall@10
        assert!(p.speculate); // two-deep pipeline on by default
        assert_eq!(p.max_io_retries, 3); // bounded degraded-read retries
    }
}
