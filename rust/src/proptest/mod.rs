//! Minimal property-testing harness (the offline vendor set has no
//! proptest crate): deterministic generators over a seeded [`XorShift`]
//! stream plus a `forall` runner that reports the failing seed so any
//! counterexample is reproducible with `PAGEANN_PROP_SEED=<seed>`.

use crate::util::XorShift;

/// Number of cases per property (override with PAGEANN_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("PAGEANN_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(64)
}

/// Run `prop` on `cases` generated inputs. On panic, re-raises with the
/// offending case index and seed in the message.
pub fn forall<G, T, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut XorShift) -> T,
    T: std::fmt::Debug,
    P: FnMut(T),
{
    let base_seed: u64 = std::env::var("PAGEANN_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x9A0B5EED);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64 * 0x9E3779B97F4A7C15);
        let mut rng = XorShift::new(seed);
        let input = gen(&mut rng);
        let desc = format!("{input:?}");
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(input)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic".into());
            panic!(
                "property `{name}` failed on case {case} (PAGEANN_PROP_SEED={seed}):\n  input: {}\n  cause: {msg}",
                truncate(&desc, 400)
            );
        }
    }
}

fn truncate(s: &str, n: usize) -> &str {
    if s.len() <= n {
        s
    } else {
        &s[..s.char_indices().take_while(|&(i, _)| i < n).count()]
    }
}

// ---- common generators -------------------------------------------------

/// Random f32 vector with entries in roughly [-scale, scale].
pub fn gen_vec(rng: &mut XorShift, dim: usize, scale: f32) -> Vec<f32> {
    (0..dim).map(|_| rng.next_gaussian() * scale).collect()
}

/// Random dimension from a menu of awkward sizes.
pub fn gen_dim(rng: &mut XorShift) -> usize {
    const DIMS: [usize; 7] = [1, 3, 4, 8, 31, 96, 128];
    DIMS[rng.next_below(DIMS.len())]
}

/// A batch of `n` near-duplicate queries: the first is drawn fresh, every
/// later one is the first with per-coordinate multiplicative jitter of
/// relative size ≲ `rel_jitter` (the shape of a resent serving query whose
/// floats got re-rounded). Models the duplicate-heavy batches the lossy
/// LUT-sharing policy (`lut_share_threshold < 1.0`) exists for.
pub fn gen_near_duplicates(
    rng: &mut XorShift,
    dim: usize,
    n: usize,
    scale: f32,
    rel_jitter: f32,
) -> Vec<Vec<f32>> {
    let base = gen_vec(rng, dim, scale);
    let mut out = Vec::with_capacity(n);
    out.push(base.clone());
    for _ in 1..n {
        out.push(
            base.iter().map(|&v| v * (1.0 + rel_jitter * rng.next_gaussian())).collect(),
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("trivial", 16, |rng| rng.next_below(100), |x| assert!(x < 100));
    }

    #[test]
    #[should_panic(expected = "property `bad` failed")]
    fn forall_reports_failures_with_seed() {
        forall("bad", 16, |rng| rng.next_below(100), |x| assert!(x < 1, "x={x}"));
    }

    #[test]
    fn generators_shape() {
        let mut rng = XorShift::new(1);
        assert_eq!(gen_vec(&mut rng, 8, 2.0).len(), 8);
        let d = gen_dim(&mut rng);
        assert!(d >= 1 && d <= 128);
    }
}
