//! Concurrent workload runner: N query threads pulling from a shared
//! queue — the paper's §6.1 measurement setup (16 threads, QPS + mean
//! latency + mean I/Os at a recall operating point).

use super::AnnSystem;
use crate::dataset::{recall_at_k, VectorSet};
use crate::metrics::{CpuMeter, LatencyHistogram, QueryStats, RunSummary};
use crate::util::sync::{into_inner, lock};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Results + summary of one workload run.
pub struct WorkloadReport {
    pub summary: RunSummary,
    pub results: Vec<Vec<u32>>,
    pub cpu_pct: f64,
}

/// Run every query in `queries` through `sys` on `nthreads` concurrent
/// threads; compute recall against `gt` if provided. Batch size comes
/// from the `PAGEANN_BATCH` env var (default 1 — the classic per-query
/// loop); see [`run_workload_batched`].
pub fn run_workload(
    sys: &dyn AnnSystem,
    queries: &VectorSet,
    gt: Option<&[Vec<u32>]>,
    k: usize,
    l: usize,
    nthreads: usize,
) -> WorkloadReport {
    let batch = std::env::var("PAGEANN_BATCH")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&b| b >= 1)
        .unwrap_or(1);
    run_workload_batched(sys, queries, gt, k, l, nthreads, batch)
}

/// [`run_workload`] with an explicit batch size: worker threads claim
/// `batch`-sized chunks of the query stream and feed them to
/// [`AnnSystem::search_batch`] (shared LUT builds + coalesced page reads
/// on batch-native schemes). Each query in a chunk reports the chunk's
/// wall time as its latency — the latency a batched tick imposes on every
/// member. `batch = 1` is exactly the old per-query loop.
pub fn run_workload_batched(
    sys: &dyn AnnSystem,
    queries: &VectorSet,
    gt: Option<&[Vec<u32>]>,
    k: usize,
    l: usize,
    nthreads: usize,
    batch: usize,
) -> WorkloadReport {
    let n = queries.len();
    let nthreads = nthreads.max(1);
    let batch = batch.max(1);
    let next = AtomicUsize::new(0);
    let errors = AtomicUsize::new(0);
    let agg: Mutex<(QueryStats, LatencyHistogram)> =
        Mutex::new((QueryStats::default(), LatencyHistogram::new()));
    // Per-thread result buffers, merged once at the end — no per-query
    // mutex traffic on the hot loop.
    let done: Mutex<Vec<Vec<(usize, Vec<u32>)>>> = Mutex::new(Vec::with_capacity(nthreads));

    let cpu = CpuMeter::start();
    let wall_start = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..nthreads {
            s.spawn(|| {
                let mut local = QueryStats::default();
                let mut hist = LatencyHistogram::new();
                let mut mine: Vec<(usize, Vec<u32>)> = Vec::with_capacity(n / nthreads + 1);
                let mut stats: Vec<QueryStats> = Vec::with_capacity(batch);
                loop {
                    // Claim the next chunk of the query stream.
                    let lo = next.fetch_add(batch, Ordering::Relaxed);
                    if lo >= n {
                        break;
                    }
                    let hi = (lo + batch).min(n);
                    let qvecs: Vec<Vec<f32>> = (lo..hi).map(|qi| queries.get_f32(qi)).collect();
                    let qrefs: Vec<&[f32]> = qvecs.iter().map(|v| v.as_slice()).collect();
                    stats.clear();
                    stats.resize(hi - lo, QueryStats::default());
                    let t = Instant::now();
                    let outs = sys.search_batch(&qrefs, k, l, &mut stats);
                    let dt = t.elapsed();
                    for (j, res) in outs.into_iter().enumerate() {
                        // A failed query contributes an empty result
                        // (recall charges the miss) and an error count —
                        // one bad page must not abort the whole workload,
                        // nor its batchmates.
                        let ids = match res {
                            Ok(ids) => ids,
                            Err(e) => {
                                errors.fetch_add(1, Ordering::Relaxed);
                                eprintln!("runner: query {} failed: {e}", lo + j);
                                Vec::new()
                            }
                        };
                        let mut st = std::mem::take(&mut stats[j]);
                        st.total_time = dt;
                        hist.record(dt);
                        local.merge(&st);
                        mine.push((lo + j, ids));
                    }
                }
                let mut g = lock(&agg);
                g.0.merge(&local);
                g.1.merge(&hist);
                drop(g);
                lock(&done).push(mine);
            });
        }
    });
    let wall = wall_start.elapsed();
    let cpu_pct = cpu.utilization_pct();

    let (totals, latency) = into_inner(agg);
    let mut results: Vec<Vec<u32>> = vec![Vec::new(); n];
    for batch in into_inner(done) {
        for (qi, ids) in batch {
            results[qi] = ids;
        }
    }
    let recall = match gt {
        Some(gt) => recall_at_k(&results, gt, k),
        None => f64::NAN,
    };
    WorkloadReport {
        summary: RunSummary {
            queries: n as u64,
            errors: errors.load(Ordering::Relaxed) as u64,
            wall,
            totals,
            latency,
            recall,
        },
        results,
        cpu_pct,
    }
}

/// Sweep the search-list size until the target recall is reached; returns
/// `(l, report)` for the smallest `l` that clears `target_recall`, or the
/// best found. This is how the paper fixes "Recall@10 = 0.9" operating
/// points across schemes.
pub fn tune_to_recall(
    sys: &dyn AnnSystem,
    queries: &VectorSet,
    gt: &[Vec<u32>],
    k: usize,
    target_recall: f64,
    nthreads: usize,
) -> (usize, WorkloadReport) {
    let mut l = k.max(10);
    // The first run seeds `best` unconditionally, so the rest of the sweep
    // never deals in `Option` (and the loop only runs while `best` is a
    // miss — any hit both replaces it and ends the sweep).
    let mut best = (l, run_workload(sys, queries, Some(gt), k, l, nthreads));
    let mut hit = best.1.summary.recall >= target_recall;
    let mut tries = 1;
    while !hit && tries < 10 {
        let grown = (l as f64 * 1.7).ceil() as usize;
        if grown > 4096 {
            break;
        }
        l = grown;
        let rep = run_workload(sys, queries, Some(gt), k, l, nthreads);
        hit = rep.summary.recall >= target_recall;
        if hit || rep.summary.recall > best.1.summary.recall {
            best = (l, rep);
        }
        tries += 1;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::Dtype;

    /// Trivial brute-force AnnSystem for runner tests.
    struct BruteForce {
        base: VectorSet,
    }

    impl AnnSystem for BruteForce {
        fn name(&self) -> String {
            "brute".into()
        }
        fn search_one(
            &self,
            q: &[f32],
            k: usize,
            _l: usize,
            stats: &mut QueryStats,
        ) -> crate::Result<Vec<u32>> {
            stats.exact_dists += self.base.len() as u64;
            let mut all: Vec<(f32, u32)> = (0..self.base.len())
                .map(|i| (crate::distance::l2sq_query(q, self.base.view(i)), i as u32))
                .collect();
            all.sort_by(|a, b| a.0.total_cmp(&b.0));
            Ok(all.into_iter().take(k).map(|(_, i)| i).collect())
        }
        fn memory_bytes(&self) -> usize {
            self.base.payload_bytes()
        }
    }

    #[test]
    fn runner_counts_and_recall() {
        let mut base = VectorSet::new(Dtype::F32, 4, 50);
        for i in 0..50 {
            base.set_from_f32(i, &[i as f32, 0.0, 0.0, 0.0]);
        }
        let mut queries = VectorSet::new(Dtype::F32, 4, 8);
        for i in 0..8 {
            queries.set_from_f32(i, &[i as f32 * 5.0 + 0.1, 0.0, 0.0, 0.0]);
        }
        let gt = crate::dataset::ground_truth(&base, &queries, 5, 2);
        let sys = BruteForce { base };
        let rep = run_workload(&sys, &queries, Some(&gt), 5, 10, 4);
        assert_eq!(rep.summary.queries, 8);
        assert!((rep.summary.recall - 1.0).abs() < 1e-9, "{}", rep.summary.recall);
        assert!(rep.summary.qps() > 0.0);
        assert_eq!(rep.summary.totals.exact_dists, 8 * 50);
        assert_eq!(rep.results.len(), 8);
        assert!(rep.results.iter().all(|r| r.len() == 5));
        assert_eq!(rep.summary.errors, 0);
    }

    /// System that errors on some queries — the runner must keep going.
    struct Flaky {
        inner: BruteForce,
    }

    impl AnnSystem for Flaky {
        fn name(&self) -> String {
            "flaky".into()
        }
        fn search_one(
            &self,
            q: &[f32],
            k: usize,
            l: usize,
            stats: &mut QueryStats,
        ) -> crate::Result<Vec<u32>> {
            anyhow::ensure!(q[0] < 20.0, "injected search failure");
            self.inner.search_one(q, k, l, stats)
        }
        fn memory_bytes(&self) -> usize {
            0
        }
    }

    #[test]
    fn runner_survives_failing_queries() {
        let mut base = VectorSet::new(Dtype::F32, 4, 50);
        for i in 0..50 {
            base.set_from_f32(i, &[i as f32, 0.0, 0.0, 0.0]);
        }
        let mut queries = VectorSet::new(Dtype::F32, 4, 8);
        for i in 0..8 {
            queries.set_from_f32(i, &[i as f32 * 5.0 + 0.1, 0.0, 0.0, 0.0]);
        }
        let sys = Flaky { inner: BruteForce { base } };
        // Queries 4..8 have q[0] ≥ 20 → fail; 0..4 succeed.
        let rep = run_workload(&sys, &queries, None, 5, 10, 4);
        assert_eq!(rep.summary.queries, 8);
        assert_eq!(rep.summary.errors, 4);
        let nonempty = rep.results.iter().filter(|r| !r.is_empty()).count();
        assert_eq!(nonempty, 4, "failed queries yield empty results, others survive");
    }

    #[test]
    fn batched_chunks_cover_every_query_identically() {
        let mut base = VectorSet::new(Dtype::F32, 4, 50);
        for i in 0..50 {
            base.set_from_f32(i, &[i as f32, 0.0, 0.0, 0.0]);
        }
        let mut queries = VectorSet::new(Dtype::F32, 4, 10);
        for i in 0..10 {
            queries.set_from_f32(i, &[i as f32 * 4.0 + 0.1, 0.0, 0.0, 0.0]);
        }
        let sys = BruteForce { base };
        let seq = run_workload_batched(&sys, &queries, None, 5, 10, 2, 1);
        // Batch sizes that divide the stream unevenly must still cover
        // every query exactly once, with identical results.
        for batch in [3usize, 4, 16] {
            let rep = run_workload_batched(&sys, &queries, None, 5, 10, 2, batch);
            assert_eq!(rep.summary.queries, 10);
            assert_eq!(rep.summary.errors, 0);
            assert_eq!(rep.results, seq.results, "batch={batch}");
            assert_eq!(rep.summary.totals.exact_dists, seq.summary.totals.exact_dists);
        }
    }

    #[test]
    fn batched_runner_counts_errors_per_query() {
        let mut base = VectorSet::new(Dtype::F32, 4, 50);
        for i in 0..50 {
            base.set_from_f32(i, &[i as f32, 0.0, 0.0, 0.0]);
        }
        let mut queries = VectorSet::new(Dtype::F32, 4, 8);
        for i in 0..8 {
            queries.set_from_f32(i, &[i as f32 * 5.0 + 0.1, 0.0, 0.0, 0.0]);
        }
        let sys = Flaky { inner: BruteForce { base } };
        // Queries 4..8 fail; a failing query must not take down the rest
        // of its chunk.
        let rep = run_workload_batched(&sys, &queries, None, 5, 10, 2, 3);
        assert_eq!(rep.summary.queries, 8);
        assert_eq!(rep.summary.errors, 4);
        let nonempty = rep.results.iter().filter(|r| !r.is_empty()).count();
        assert_eq!(nonempty, 4);
    }

    #[test]
    fn tune_finds_recall_immediately_for_exact_system() {
        let mut base = VectorSet::new(Dtype::F32, 2, 30);
        for i in 0..30 {
            base.set_from_f32(i, &[i as f32, i as f32]);
        }
        let queries = {
            let mut q = VectorSet::new(Dtype::F32, 2, 4);
            for i in 0..4 {
                q.set_from_f32(i, &[i as f32 * 3.0, i as f32 * 3.0]);
            }
            q
        };
        let gt = crate::dataset::ground_truth(&base, &queries, 3, 1);
        let sys = BruteForce { base };
        let (l, rep) = tune_to_recall(&sys, &queries, &gt, 3, 0.9, 2);
        assert!(rep.summary.recall >= 0.9);
        assert_eq!(l, 10); // first try suffices
    }
}
