//! TCP query server: the network front-end of the L3 coordinator.
//!
//! Wire protocol (little-endian, one request per frame):
//!
//! ```text
//! request:   [u32 magic 0x50414E51 "PANQ"] [u32 k] [u32 l] [u32 dim] [f32 × dim]
//! response:  [u32 magic 0x50414E52 "PANR"] [u32 n] [u32 id × n]
//!            [f32 latency_ms] [u32 ios]
//! error:     [u32 magic 0x50414E45 "PANE"] [u32 len] [len bytes utf-8]
//! stats req: [u32 magic 0x50414E53 "PANS"] [u32 top_n]
//! stats rep: [u32 magic 0x50414E54 "PANT"] [u64 queries] [u64 errors]
//!            [u64 total_ios] [u64 retries] [u64 failed_ios]
//!            [u64 crc_failures] [u64 degraded] [u64 batch_shared_ios]
//!            [u64 lut_reused] [u64 lut_cache_hits]
//!            [u32 n_hists] ([u8 name_len] [name_len bytes utf-8]
//!             [u64 count] [f64 mean] [f64 p50] [f64 p90] [f64 p99]
//!             [f64 p999] [f64 max]) × n_hists
//!            [u32 n]
//!            ([u32 page] [u64 retries] [u64 crc_failures] [u64 failed_ios]) × n
//! ```
//!
//! One OS thread per connection parses frames. With `batch_max == 1`
//! (ISSUE 8's compatibility mode) the connection thread also runs the
//! search inline — exactly the pre-batching behavior. With
//! `batch_max > 1` (the default), parsed requests flow through a
//! tick-based admission queue: a small executor pool drains up to
//! `batch_max` requests per tick, waiting at most the gather window for
//! batchmates, groups them by `(k, l)`, and answers each request over its
//! own reply channel so the connection thread writes the response. The
//! batched tick calls [`AnnSystem::search_batch`], which shares ADC LUT
//! builds and coalesces duplicate page reads across the gathered queries
//! (see `search::search_batch`); results are bit-identical to the inline
//! path, so batching is purely a throughput knob.
//!
//! # Gather-window policy (ISSUE 9)
//!
//! The wait-for-batchmates budget is a [`GatherPolicy`]. The default is
//! **adaptive**: an [`ArrivalTracker`] EWMA of request inter-arrival times
//! (sampled on every enqueue, through the injected [`TickClock`]) sizes
//! each tick's window as `(batch_max − 1) × ewma`, capped at
//! `--gather-us-max`. Under light load — no arrival history yet, or
//! arrivals slower than the cap — the window collapses to zero, so a lone
//! query never pays the full window waiting for batchmates that are not
//! coming; under bursts it grows toward the cap and batches fill.
//! `GatherPolicy::Fixed` (`--gather-us`) pins the pre-adaptive behavior
//! exactly: every tick waits the same bounded window.
//!
//! # Server knobs
//!
//! | flag | env | default | meaning |
//! |---|---|---|---|
//! | `--batch-max N` | `PAGEANN_BATCH` | 8 | requests per executor tick; 1 = inline path |
//! | `--gather-us U` | `PAGEANN_GATHER_US` | unset | **fixed** gather window of `U` µs (disables adaptivity) |
//! | `--gather-us-max U` | `PAGEANN_GATHER_US_MAX` | 200 | cap on the adaptive window |
//! | `--lut-cache N` | `PAGEANN_LUT_CACHE` | 0 (off) | cross-tick LUT cache entries (`pq::LutCache`) |
//! | `--trace <path>` | `PAGEANN_TRACE` | off | per-hop JSONL trace spans (`metrics::trace`) |
//!
//! # Telemetry
//!
//! Beyond the raw counters, the `PANT` frame carries a self-describing
//! histogram section ([`STAT_HIST_NAMES`]): request inter-arrival gaps,
//! gather-window occupancy (queries per executor tick), end-to-end query
//! latency, and one histogram per search phase
//! (`metrics::PhaseTimes` — gather_wait / lut_build / io_submit /
//! io_wait / topology / rerank). The phase taxonomy, frame layout, and
//! histogram semantics are documented in `OBSERVABILITY.md` at the repo
//! root; [`QueryClient::stats`] decodes the frame into a
//! [`StatsSnapshot`].
//!
//! Failure semantics (ISSUE 6): a failed search answers with a `PANE`
//! error frame and the connection survives; a malformed request is
//! answered and the payload fully drained (when bounded) so the stream
//! stays in sync, or the connection is closed (when it can't be); each
//! connection carries a read timeout so a stalled client can't pin its
//! thread forever; and persistent `accept` errors (e.g. EMFILE) back off
//! exponentially instead of busy-spinning. [`ServerStats`] additionally
//! aggregates per-page fault totals (retries / CRC failures / permanent
//! failures, keyed by page id) so monitoring can spot a dying flash
//! region via the `PANS` stats frame.

use super::AnnSystem;
use crate::metrics::{HistSummary, LatencyHistogram, LogHistogram, QueryStats, N_PHASES};
use crate::util::sync::{cond_wait, cond_wait_timeout, lock};
use crate::Result;
use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

pub const REQ_MAGIC: u32 = 0x50414E51;
pub const RESP_MAGIC: u32 = 0x50414E52;
pub const ERR_MAGIC: u32 = 0x50414E45;
pub const STAT_MAGIC: u32 = 0x50414E53;
pub const STAT_RESP_MAGIC: u32 = 0x50414E54;

/// Hard cap on the query dimension a request may declare. Below it, a bad
/// request's payload is drained so the connection stays usable; above it,
/// draining is unbounded work for garbage, so the connection closes.
pub const MAX_QDIM: usize = 1 << 16;

/// Default per-connection read timeout (covers idle keep-alive too).
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Largest top-offenders table a `PANS` stats reply will carry.
pub const STAT_TOP_N_CAP: usize = 256;

/// Default admission-queue batch size when `PAGEANN_BATCH` is unset.
pub const DEFAULT_BATCH_MAX: usize = 8;

/// The historical fixed gather window (ISSUE 8): how long an executor held
/// a partial batch waiting for batchmates before running the tick anyway.
/// Still the value `--gather-us` documentation points at, and the default
/// **cap** of the adaptive policy ([`DEFAULT_GATHER_WINDOW_MAX`]).
pub const DEFAULT_GATHER_WINDOW: Duration = Duration::from_micros(200);

/// Default cap on the adaptive gather window (`--gather-us-max`).
pub const DEFAULT_GATHER_WINDOW_MAX: Duration = DEFAULT_GATHER_WINDOW;

/// EWMA smoothing factor for [`ArrivalTracker`]: weight of the newest
/// inter-arrival sample. 0.2 reacts to a burst within ~5 requests while a
/// single straggler barely moves the estimate.
pub const ARRIVAL_EWMA_ALPHA: f64 = 0.2;

/// How long a connection thread waits for its batched reply before
/// answering with an error frame (guards the executor-shutdown race; in
/// normal operation replies arrive in query-latency time).
const EXECUTOR_REPLY_TIMEOUT: Duration = Duration::from_secs(60);

/// Injected time source for the admission queue's arrival tracking.
///
/// Production uses [`MonotonicClock`]; the deterministic scheduler tests
/// substitute a hand-stepped clock so EWMA trajectories and window sizes
/// are exact, not timing-dependent.
pub trait TickClock: Send + Sync {
    /// Microseconds since an arbitrary fixed origin. Must be monotonic
    /// non-decreasing within one clock instance.
    fn now_us(&self) -> u64;
}

/// Production [`TickClock`]: microseconds since the clock was created,
/// anchored to a monotonic [`std::time::Instant`].
#[derive(Debug)]
pub struct MonotonicClock {
    origin: std::time::Instant,
}

impl MonotonicClock {
    pub fn new() -> Self {
        Self { origin: std::time::Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl TickClock for MonotonicClock {
    fn now_us(&self) -> u64 {
        // Saturating: u64 µs overflows after ~584k years of uptime.
        u64::try_from(self.origin.elapsed().as_micros()).unwrap_or(u64::MAX)
    }
}

/// EWMA of request inter-arrival times, fed by every enqueue (under the
/// admission-queue lock) and read by the executor when it sizes a tick's
/// gather window. Pure arithmetic over caller-supplied timestamps — no
/// clock inside — so tests drive it deterministically.
#[derive(Debug, Clone, Copy, Default)]
pub struct ArrivalTracker {
    ewma_us: f64,
    /// Inter-arrival samples folded so far (0 = no estimate yet).
    samples: u64,
    last_us: Option<u64>,
}

impl ArrivalTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one arrival at `now_us`. The first arrival only anchors the
    /// stream; each later one folds its inter-arrival delta into the EWMA
    /// (the first delta seeds it directly). Returns that delta in µs —
    /// `None` on the anchoring arrival — so the caller can feed an
    /// arrival-rate histogram from the same sample the EWMA saw.
    pub fn note_arrival(&mut self, now_us: u64) -> Option<u64> {
        let delta = if let Some(last) = self.last_us {
            let d = now_us.saturating_sub(last);
            let df = d as f64;
            self.ewma_us = if self.samples == 0 {
                df
            } else {
                ARRIVAL_EWMA_ALPHA * df + (1.0 - ARRIVAL_EWMA_ALPHA) * self.ewma_us
            };
            self.samples += 1;
            Some(d)
        } else {
            None
        };
        self.last_us = Some(now_us);
        delta
    }

    /// Current inter-arrival estimate in µs, or `None` before the second
    /// arrival.
    pub fn ewma_us(&self) -> Option<f64> {
        if self.samples > 0 {
            Some(self.ewma_us)
        } else {
            None
        }
    }

    /// The adaptive gather window in µs for a tick that just accepted its
    /// first request: expected time for the *rest* of a `batch_max` batch
    /// to arrive (`(batch_max − 1) × ewma`), capped at `max_us`. Zero when
    /// there is no estimate yet, or when arrivals run slower than the cap
    /// itself — waiting the cap would buy at most one batchmate, so a lone
    /// query under light load departs immediately.
    pub fn window_us(&self, max_us: u64, batch_max: usize) -> u64 {
        let ewma = match self.ewma_us() {
            Some(e) => e,
            None => return 0,
        };
        if ewma >= max_us as f64 {
            return 0;
        }
        let want = (batch_max.saturating_sub(1) as f64) * ewma;
        (want.ceil() as u64).min(max_us)
    }
}

/// How long an executor tick waits for batchmates after its first request.
#[derive(Debug, Clone, Copy)]
pub enum GatherPolicy {
    /// Always wait up to the given window — the pre-adaptive (ISSUE 8)
    /// behavior, pinned exactly (`--gather-us`).
    Fixed(Duration),
    /// Arrival-rate-adaptive window ([`ArrivalTracker::window_us`]),
    /// capped at `max` (`--gather-us-max`). The default.
    Adaptive { max: Duration },
}

impl GatherPolicy {
    /// The wait budget for one tick, given the queue's arrival history.
    pub fn window(&self, arrivals: &ArrivalTracker, batch_max: usize) -> Duration {
        match *self {
            GatherPolicy::Fixed(d) => d,
            GatherPolicy::Adaptive { max } => {
                let max_us = u64::try_from(max.as_micros()).unwrap_or(u64::MAX);
                Duration::from_micros(arrivals.window_us(max_us, batch_max))
            }
        }
    }
}

/// Admission-queue configuration for [`QueryServer`].
///
/// `batch_max == 1` bypasses the queue entirely: connection threads run
/// searches inline, reproducing the pre-batching server exactly.
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Most requests one executor tick may gather (≥ 1).
    pub batch_max: usize,
    /// Gather-window policy: how long a tick waits for batchmates after
    /// its first request (see the module docs).
    pub gather: GatherPolicy,
    /// Executor threads draining the queue (≥ 1; only used when
    /// `batch_max > 1`).
    pub executors: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        let batch_max = std::env::var("PAGEANN_BATCH")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&b| b >= 1)
            .unwrap_or(DEFAULT_BATCH_MAX);
        let gather = match std::env::var("PAGEANN_GATHER_US")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
        {
            Some(us) => GatherPolicy::Fixed(Duration::from_micros(us)),
            None => {
                let max = std::env::var("PAGEANN_GATHER_US_MAX")
                    .ok()
                    .and_then(|v| v.parse::<u64>().ok())
                    .map(Duration::from_micros)
                    .unwrap_or(DEFAULT_GATHER_WINDOW_MAX);
                GatherPolicy::Adaptive { max }
            }
        };
        Self { batch_max, gather, executors: 2 }
    }
}

/// Aggregated fault totals for one page across every query the server has
/// answered.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PageFaultTotals {
    /// Successful-after-retry read attempts charged to this page.
    pub retries: u64,
    /// CRC32C tail verification failures observed on this page.
    pub crc_failures: u64,
    /// Times this page stayed unreadable after the retry budget.
    pub failed_ios: u64,
}

/// Histogram names carried in the `PANT` stats frame, in wire order.
/// `*_us` histograms are µs-domain; `gather_occupancy` counts queries
/// gathered per executor tick. The six phase histograms follow
/// `metrics::PhaseTimes::NAMES` order with a `_us` suffix. See
/// `OBSERVABILITY.md` ("Stats frame").
pub const STAT_HIST_NAMES: [&str; 3 + N_PHASES] = [
    "arrival_us",
    "gather_occupancy",
    "total_us",
    "gather_wait_us",
    "lut_build_us",
    "io_submit_us",
    "io_wait_us",
    "topology_us",
    "rerank_us",
];

/// Sanity bound a client places on the stats frame's histogram count.
pub const STAT_HIST_CAP: usize = 64;

/// Histogram state behind one lock: written per answered query (total +
/// phases), per enqueue (arrival gap), and per executor tick (occupancy).
/// Fixed memory — a few hundred u64 buckets per histogram, regardless of
/// query volume.
#[derive(Debug)]
struct ServerHists {
    /// Inter-arrival gaps between admission-queue enqueues, µs.
    arrival_us: LogHistogram,
    /// Queries gathered per executor tick (batch fill, 1 … batch_max).
    gather_occupancy: LogHistogram,
    /// End-to-end per-query latency (including gather wait), µs.
    total_us: LatencyHistogram,
    /// Per-phase latency, µs, indexed like `PhaseTimes::as_array`.
    phase_us: [LatencyHistogram; N_PHASES],
}

impl Default for ServerHists {
    fn default() -> Self {
        Self {
            arrival_us: LogHistogram::new(1.0, 1e7, 200),
            gather_occupancy: LogHistogram::new(1.0, 4096.0, 64),
            total_us: LatencyHistogram::new(),
            phase_us: Default::default(),
        }
    }
}

impl ServerHists {
    /// Named summaries in [`STAT_HIST_NAMES`] order.
    fn summaries(&self) -> Vec<(String, HistSummary)> {
        let mut v = Vec::with_capacity(STAT_HIST_NAMES.len());
        v.push((STAT_HIST_NAMES[0].to_string(), self.arrival_us.summary()));
        v.push((STAT_HIST_NAMES[1].to_string(), self.gather_occupancy.summary()));
        v.push((STAT_HIST_NAMES[2].to_string(), self.total_us.summary()));
        for (i, h) in self.phase_us.iter().enumerate() {
            v.push((STAT_HIST_NAMES[3 + i].to_string(), h.summary()));
        }
        v
    }
}

/// Server statistics (scraped by monitoring / tests, exported over the
/// `PANS` stats frame).
#[derive(Debug, Default)]
pub struct ServerStats {
    pub queries: AtomicU64,
    pub errors: AtomicU64,
    pub total_ios: AtomicU64,
    /// Read attempts retried inside the search path (sum of
    /// `QueryStats::retries`).
    pub retries: AtomicU64,
    /// Pages permanently skipped inside the search path.
    pub failed_ios: AtomicU64,
    /// Queries answered from a degraded traversal (some page skipped).
    pub degraded: AtomicU64,
    /// CRC32C verification failures observed inside the search path.
    pub crc_failures: AtomicU64,
    /// Page reads coalesced away by batched execution (sum of
    /// `QueryStats::batch_shared_ios`).
    pub batch_shared_ios: AtomicU64,
    /// Queries whose ADC LUT aliased a batchmate's instead of being built.
    pub lut_reused: AtomicU64,
    /// Queries whose ADC LUT came from the cross-tick `pq::LutCache`
    /// (sum of `QueryStats::lut_cache_hits`).
    pub lut_cache_hits: AtomicU64,
    /// Per-page fault aggregation, keyed by page id. Fed from each query's
    /// `QueryStats::page_faults`; read via [`ServerStats::top_offenders`].
    page_faults: Mutex<HashMap<u32, PageFaultTotals>>,
    /// Arrival / occupancy / total / per-phase latency histograms,
    /// exported as the `PANT` frame's histogram section.
    hists: Mutex<ServerHists>,
}

impl ServerStats {
    /// Fold one answered query's stats into the server counters. `ok`
    /// mirrors the reply actually sent: `true` for a result frame, `false`
    /// for an error frame.
    pub fn note_query(&self, ok: bool, q: &QueryStats) {
        self.retries.fetch_add(q.retries, Ordering::Relaxed);
        self.failed_ios.fetch_add(q.failed_ios, Ordering::Relaxed);
        self.crc_failures.fetch_add(q.crc_failures, Ordering::Relaxed);
        self.batch_shared_ios.fetch_add(q.batch_shared_ios, Ordering::Relaxed);
        self.lut_reused.fetch_add(q.lut_reused, Ordering::Relaxed);
        self.lut_cache_hits.fetch_add(q.lut_cache_hits, Ordering::Relaxed);
        if ok {
            self.queries.fetch_add(1, Ordering::Relaxed);
            self.total_ios.fetch_add(q.ios, Ordering::Relaxed);
            if q.degraded {
                self.degraded.fetch_add(1, Ordering::Relaxed);
            }
        } else {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        if !q.page_faults.is_empty() {
            let mut map = lock(&self.page_faults);
            for r in &q.page_faults {
                let t = map.entry(r.page).or_default();
                t.retries += r.retries as u64;
                t.crc_failures += r.crc_failures as u64;
                if r.failed {
                    t.failed_ios += 1;
                }
            }
        }
        // Latency histograms: every answered query contributes one sample
        // to the total and to each phase (zero-duration phases land in
        // bucket 0, so counts stay comparable across histograms).
        let mut h = lock(&self.hists);
        h.total_us.record(q.total_time);
        let phases = q.phases.as_array();
        for i in 0..N_PHASES {
            h.phase_us[i].record(phases[i]);
        }
    }

    /// Record one inter-arrival gap (µs) into the arrival-rate histogram.
    /// Fed by the connection threads from [`ArrivalTracker::note_arrival`].
    pub fn note_arrival_delta(&self, delta_us: u64) {
        lock(&self.hists).arrival_us.record(delta_us as f64);
    }

    /// Record one executor tick's batch fill (queries gathered).
    pub fn note_gather_occupancy(&self, n: usize) {
        lock(&self.hists).gather_occupancy.record(n as f64);
    }

    /// Named histogram summaries in wire order ([`STAT_HIST_NAMES`]).
    pub fn hist_summaries(&self) -> Vec<(String, HistSummary)> {
        lock(&self.hists).summaries()
    }

    /// The `n` worst pages, ranked by permanent failures, then CRC
    /// failures, then retries (page id breaks ties deterministically).
    pub fn top_offenders(&self, n: usize) -> Vec<(u32, PageFaultTotals)> {
        let map = lock(&self.page_faults);
        let mut v: Vec<(u32, PageFaultTotals)> = map.iter().map(|(&p, &t)| (p, t)).collect();
        drop(map);
        v.sort_by(|a, b| {
            (b.1.failed_ios, b.1.crc_failures, b.1.retries, a.0)
                .cmp(&(a.1.failed_ios, a.1.crc_failures, a.1.retries, b.0))
        });
        v.truncate(n);
        v
    }
}

/// One parsed request waiting in the admission queue. The reply channel
/// routes the answer back to the connection thread that parsed it.
struct PendingQuery {
    query: Vec<f32>,
    k: usize,
    l: usize,
    /// When the request entered the admission queue. The executor charges
    /// `dispatch − enqueued_at` to the query's `gather_wait` phase.
    enqueued_at: std::time::Instant,
    reply: mpsc::Sender<(Result<Vec<u32>>, QueryStats)>,
}

/// The queue proper plus its arrival history, together under one lock:
/// every enqueue stamps the tracker with the same ordering the executor
/// later reads it in, so EWMA updates never race the window computation.
struct QueueState {
    q: VecDeque<PendingQuery>,
    arrivals: ArrivalTracker,
}

/// Tick-based admission queue shared by connection threads (producers)
/// and the executor pool (consumers).
struct AdmissionQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
    shutdown: AtomicBool,
    clock: Arc<dyn TickClock>,
}

impl AdmissionQueue {
    fn new(clock: Arc<dyn TickClock>) -> Self {
        Self {
            state: Mutex::new(QueueState {
                q: VecDeque::new(),
                arrivals: ArrivalTracker::new(),
            }),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            clock,
        }
    }
}

/// Executor tick loop: block for one request, gather batchmates within the
/// bounded window, group by `(k, l)`, run [`AnnSystem::search_batch`], and
/// route every reply back to its connection. Exits when the queue is both
/// shut down and fully drained, so no pending request loses its reply.
fn executor_loop(
    queue: Arc<AdmissionQueue>,
    system: Arc<dyn AnnSystem>,
    cfg: BatchConfig,
    stats: Arc<ServerStats>,
) {
    loop {
        let mut batch: Vec<PendingQuery> = Vec::new();
        {
            let mut g = lock(&queue.state);
            loop {
                if let Some(p) = g.q.pop_front() {
                    batch.push(p);
                    break;
                }
                if queue.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                g = cond_wait(&queue.cv, g);
            }
            // Bounded gather window, sized by the policy from the arrival
            // history (fixed mode passes its constant through untouched):
            // a lone query pays at most `window` of extra latency waiting
            // for batchmates; a full batch departs immediately. A zero
            // window still drains whatever is already queued.
            let window = cfg.gather.window(&g.arrivals, cfg.batch_max);
            let deadline = std::time::Instant::now() + window;
            while batch.len() < cfg.batch_max {
                if let Some(p) = g.q.pop_front() {
                    batch.push(p);
                    continue;
                }
                // Spurious-wakeup safety: the deadline and the queue are
                // re-checked on EVERY wake — `cond_wait_timeout`'s timed-out
                // flag is deliberately ignored, so a spurious wake can
                // neither end the gather early nor extend it past the
                // deadline (see util::sync and tests/scheduler.rs).
                let now = std::time::Instant::now();
                if now >= deadline {
                    break;
                }
                let (g2, _timed_out) = cond_wait_timeout(&queue.cv, g, deadline - now);
                g = g2;
            }
        }
        // Gather-window occupancy: how full this tick's batch got (always
        // ≥ 1 — a tick only starts once it holds a request).
        stats.note_gather_occupancy(batch.len());
        // search_batch takes one (k, l) per call, so group the gathered
        // requests; mixed ticks become one call per distinct pair.
        let mut pending = batch;
        while let Some(first) = pending.first() {
            let (k, l) = (first.k, first.l);
            let mut group = Vec::with_capacity(pending.len());
            let mut rest = Vec::new();
            for p in pending {
                if p.k == k && p.l == l {
                    group.push(p);
                } else {
                    rest.push(p);
                }
            }
            pending = rest;
            let qrefs: Vec<&[f32]> = group.iter().map(|p| p.query.as_slice()).collect();
            let mut qstats = vec![QueryStats::default(); group.len()];
            // The admission-queue wait ends here: everything before this
            // instant is gather_wait, everything after is the search
            // proper (whose phases search_batch accounts itself).
            let dispatched = std::time::Instant::now();
            let results = system.search_batch(&qrefs, k, l.max(k), &mut qstats);
            drop(qrefs);
            for ((p, res), mut st) in group.into_iter().zip(results).zip(qstats) {
                let gw = dispatched.saturating_duration_since(p.enqueued_at);
                st.phases.gather_wait = gw;
                st.total_time += gw;
                // A closed receiver only means the connection died while
                // waiting; nothing to do.
                let _ = p.reply.send((res, st));
            }
        }
    }
}

pub struct QueryServer {
    listener: TcpListener,
    system: Arc<dyn AnnSystem>,
    dim: usize,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
    read_timeout: Option<Duration>,
    batch: BatchConfig,
    clock: Arc<dyn TickClock>,
}

/// Handle returned by [`QueryServer::spawn`]: stop + join the serve loop.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    pub stats: Arc<ServerStats>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Nudge the accept loop with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl QueryServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port). Batching
    /// defaults to [`BatchConfig::default`] (`PAGEANN_BATCH` or 8).
    pub fn bind(addr: &str, system: Arc<dyn AnnSystem>, dim: usize) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Ok(Self {
            listener,
            system,
            dim,
            stats: Arc::new(ServerStats::default()),
            shutdown: Arc::new(AtomicBool::new(false)),
            read_timeout: Some(DEFAULT_READ_TIMEOUT),
            batch: BatchConfig::default(),
            clock: Arc::new(MonotonicClock::new()),
        })
    }

    /// Override the per-connection read timeout (`None` = never time out).
    pub fn with_read_timeout(mut self, timeout: Option<Duration>) -> Self {
        self.read_timeout = timeout;
        self
    }

    /// Override the admission-queue configuration. `batch_max == 1`
    /// disables the queue and restores the inline (pre-batching) path.
    pub fn with_batching(mut self, cfg: BatchConfig) -> Self {
        self.batch = cfg;
        self
    }

    /// Override the arrival-tracking clock (tests inject a deterministic
    /// one; production keeps the default [`MonotonicClock`]).
    pub fn with_clock(mut self, clock: Arc<dyn TickClock>) -> Self {
        self.clock = clock;
        self
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Run the accept loop on a background thread.
    pub fn spawn(self) -> Result<ServerHandle> {
        let addr = self.local_addr()?;
        let shutdown = self.shutdown.clone();
        let stats = self.stats.clone();
        let join = std::thread::spawn(move || self.serve_loop());
        Ok(ServerHandle { addr, shutdown, join: Some(join), stats })
    }

    fn serve_loop(self) {
        // Batched mode: spin up the executor pool before accepting.
        let queue = if self.batch.batch_max > 1 {
            let q = Arc::new(AdmissionQueue::new(self.clock.clone()));
            for _ in 0..self.batch.executors.max(1) {
                let qx = Arc::clone(&q);
                let system = self.system.clone();
                let cfg = self.batch;
                let stats = self.stats.clone();
                std::thread::spawn(move || executor_loop(qx, system, cfg, stats));
            }
            Some(q)
        } else {
            None
        };
        // Exponential backoff for persistent accept() failures (EMFILE,
        // ENFILE): busy-spinning on a failing accept would peg a core and
        // starve the very connections holding the descriptors we need.
        let mut backoff = Duration::from_millis(10);
        const MAX_BACKOFF: Duration = Duration::from_secs(1);
        loop {
            let (stream, _) = match self.listener.accept() {
                Ok(s) => {
                    backoff = Duration::from_millis(10);
                    s
                }
                Err(e) => {
                    if self.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    eprintln!("server: accept failed ({e}); backing off {backoff:?}");
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(MAX_BACKOFF);
                    continue;
                }
            };
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let _ = stream.set_read_timeout(self.read_timeout);
            let system = self.system.clone();
            let stats = self.stats.clone();
            let dim = self.dim;
            let shutdown = self.shutdown.clone();
            let conn_queue = queue.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(stream, system, dim, stats, shutdown, conn_queue);
            });
        }
        // Wake the executors; they drain any queued requests (every
        // pending connection still gets its reply) and then exit.
        if let Some(q) = queue {
            q.shutdown.store(true, Ordering::SeqCst);
            q.cv.notify_all();
        }
    }
}

fn read_u32(s: &mut TcpStream) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    s.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(s: &mut TcpStream) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    s.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u8(s: &mut TcpStream) -> std::io::Result<u8> {
    let mut b = [0u8; 1];
    s.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_f64(s: &mut TcpStream) -> std::io::Result<f64> {
    let mut b = [0u8; 8];
    s.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

/// Read and discard exactly `n` bytes — keeps the stream frame-aligned
/// after a rejected request without allocating the full payload.
fn drain_exact(s: &mut TcpStream, mut n: usize) -> std::io::Result<()> {
    let mut sink = [0u8; 4096];
    while n > 0 {
        let take = n.min(sink.len());
        s.read_exact(&mut sink[..take])?;
        n -= take;
    }
    Ok(())
}

/// Serialize a `PANT` stats reply into `out` and send it.
fn write_stats_reply(
    stream: &mut TcpStream,
    stats: &ServerStats,
    top_n: usize,
    out: &mut Vec<u8>,
) -> Result<()> {
    let offenders = stats.top_offenders(top_n);
    out.clear();
    out.extend_from_slice(&STAT_RESP_MAGIC.to_le_bytes());
    for v in [
        stats.queries.load(Ordering::Relaxed),
        stats.errors.load(Ordering::Relaxed),
        stats.total_ios.load(Ordering::Relaxed),
        stats.retries.load(Ordering::Relaxed),
        stats.failed_ios.load(Ordering::Relaxed),
        stats.crc_failures.load(Ordering::Relaxed),
        stats.degraded.load(Ordering::Relaxed),
        stats.batch_shared_ios.load(Ordering::Relaxed),
        stats.lut_reused.load(Ordering::Relaxed),
        stats.lut_cache_hits.load(Ordering::Relaxed),
    ] {
        out.extend_from_slice(&v.to_le_bytes());
    }
    // Self-describing histogram section: clients match by name, so the
    // server can add histograms without a wire-version bump.
    let hists = stats.hist_summaries();
    out.extend_from_slice(&(hists.len() as u32).to_le_bytes());
    for (name, s) in &hists {
        debug_assert!(name.len() <= u8::MAX as usize, "histogram name too long");
        out.push(name.len() as u8);
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&s.count.to_le_bytes());
        for v in [s.mean, s.p50, s.p90, s.p99, s.p999, s.max] {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out.extend_from_slice(&(offenders.len() as u32).to_le_bytes());
    for (page, t) in &offenders {
        out.extend_from_slice(&page.to_le_bytes());
        out.extend_from_slice(&t.retries.to_le_bytes());
        out.extend_from_slice(&t.crc_failures.to_le_bytes());
        out.extend_from_slice(&t.failed_ios.to_le_bytes());
    }
    stream.write_all(out)?;
    Ok(())
}

fn handle_connection(
    mut stream: TcpStream,
    system: Arc<dyn AnnSystem>,
    dim: usize,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
    queue: Option<Arc<AdmissionQueue>>,
) -> Result<()> {
    stream.set_nodelay(true)?;
    // One set of buffers per connection, reused across requests: raw query
    // bytes, the decoded query, and the outgoing frame.
    let mut qbytes = vec![0u8; dim * 4];
    let mut query: Vec<f32> = Vec::with_capacity(dim);
    let mut out: Vec<u8> = Vec::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        let magic = match read_u32(&mut stream) {
            Ok(m) => m,
            Err(_) => return Ok(()), // connection closed
        };
        if magic == STAT_MAGIC {
            let top_n = read_u32(&mut stream)? as usize;
            write_stats_reply(&mut stream, &stats, top_n.min(STAT_TOP_N_CAP), &mut out)?;
            continue;
        }
        if magic != REQ_MAGIC {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            send_error(&mut stream, "bad request magic")?;
            return Ok(());
        }
        let k = read_u32(&mut stream)? as usize;
        let l = read_u32(&mut stream)? as usize;
        let qdim = read_u32(&mut stream)? as usize;
        if qdim > MAX_QDIM {
            // Declared payload too large to drain in good faith — answer
            // and close; there is no way to re-align the stream.
            stats.errors.fetch_add(1, Ordering::Relaxed);
            let _ = send_error(&mut stream, &format!("query dim {qdim} exceeds {MAX_QDIM}"));
            return Ok(());
        }
        if qdim != dim || k == 0 || k > 1000 || l > 100_000 {
            // Drain the FULL payload — exactly qdim f32s — so the next
            // frame's magic lands where the parser looks for it. A partial
            // drain would desync the connection and misparse payload bytes
            // as magic words.
            drain_exact(&mut stream, qdim * 4)?;
            stats.errors.fetch_add(1, Ordering::Relaxed);
            send_error(&mut stream, &format!("bad request: dim {qdim} (want {dim}), k {k}"))?;
            continue;
        }
        stream.read_exact(&mut qbytes)?;
        query.clear();
        query.extend(
            qbytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );

        let t = std::time::Instant::now();
        let (res, qstats) = match &queue {
            Some(q) => {
                // Batched path: enqueue and wait for the executor tick's
                // reply. The query buffer moves into the request; the next
                // frame re-fills a fresh one.
                let (tx, rx) = mpsc::channel();
                let delta = {
                    let mut g = lock(&q.state);
                    // Stamp the arrival under the queue lock so the EWMA
                    // sees enqueues in the same order the executor drains
                    // them.
                    let now = q.clock.now_us();
                    let delta = g.arrivals.note_arrival(now);
                    g.q.push_back(PendingQuery {
                        query: std::mem::take(&mut query),
                        k,
                        l,
                        enqueued_at: std::time::Instant::now(),
                        reply: tx,
                    });
                    delta
                };
                // Histogram write happens outside the queue lock.
                if let Some(d) = delta {
                    stats.note_arrival_delta(d);
                }
                q.cv.notify_one();
                match rx.recv_timeout(EXECUTOR_REPLY_TIMEOUT) {
                    Ok(r) => r,
                    Err(_) => {
                        (Err(anyhow::anyhow!("batch executor unavailable")), QueryStats::default())
                    }
                }
            }
            None => {
                // Inline path (batch_max == 1): identical to the
                // pre-batching server.
                let mut st = QueryStats::default();
                let r = system.search_one(&query, k, l.max(k), &mut st);
                (r, st)
            }
        };
        let ids = match res {
            Ok(ids) => ids,
            Err(e) => {
                // A failed search answers with an error frame; the
                // connection (and its serving thread) survives.
                stats.note_query(false, &qstats);
                send_error(&mut stream, &format!("search failed: {e}"))?;
                continue;
            }
        };
        let ms = t.elapsed().as_secs_f64() * 1e3;
        stats.note_query(true, &qstats);

        out.clear();
        out.extend_from_slice(&RESP_MAGIC.to_le_bytes());
        out.extend_from_slice(&(ids.len() as u32).to_le_bytes());
        for id in &ids {
            out.extend_from_slice(&id.to_le_bytes());
        }
        out.extend_from_slice(&(ms as f32).to_le_bytes());
        out.extend_from_slice(&(qstats.ios as u32).to_le_bytes());
        stream.write_all(&out)?;
    }
}

fn send_error(stream: &mut TcpStream, msg: &str) -> Result<()> {
    let mut out = Vec::with_capacity(8 + msg.len());
    out.extend_from_slice(&ERR_MAGIC.to_le_bytes());
    out.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    out.extend_from_slice(msg.as_bytes());
    stream.write_all(&out)?;
    Ok(())
}

/// Blocking client for the wire protocol above.
pub struct QueryClient {
    stream: TcpStream,
}

/// One answered query.
#[derive(Debug)]
pub struct ClientResponse {
    pub ids: Vec<u32>,
    pub server_ms: f32,
    pub ios: u32,
}

/// Decoded `PANT` stats reply.
#[derive(Debug, Clone, Default)]
pub struct StatsSnapshot {
    pub queries: u64,
    pub errors: u64,
    pub total_ios: u64,
    pub retries: u64,
    pub failed_ios: u64,
    pub crc_failures: u64,
    pub degraded: u64,
    pub batch_shared_ios: u64,
    pub lut_reused: u64,
    pub lut_cache_hits: u64,
    /// Named histogram summaries in wire order — see [`STAT_HIST_NAMES`]
    /// and `OBSERVABILITY.md` ("Stats frame"). µs domains except
    /// `gather_occupancy` (queries per tick).
    pub hists: Vec<(String, HistSummary)>,
    /// Worst pages by (permanent failures, CRC failures, retries).
    pub top_offenders: Vec<(u32, PageFaultTotals)>,
}

impl StatsSnapshot {
    /// Look up one histogram summary by its wire name (e.g. `"arrival_us"`).
    pub fn hist(&self, name: &str) -> Option<&HistSummary> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, s)| s)
    }
}

impl QueryClient {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Self { stream })
    }

    pub fn query(&mut self, q: &[f32], k: usize, l: usize) -> Result<ClientResponse> {
        let mut out = Vec::with_capacity(16 + q.len() * 4);
        out.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        out.extend_from_slice(&(k as u32).to_le_bytes());
        out.extend_from_slice(&(l as u32).to_le_bytes());
        out.extend_from_slice(&(q.len() as u32).to_le_bytes());
        for &x in q {
            out.extend_from_slice(&x.to_le_bytes());
        }
        self.stream.write_all(&out)?;

        let magic = read_u32(&mut self.stream)?;
        if magic == ERR_MAGIC {
            let len = read_u32(&mut self.stream)? as usize;
            let mut msg = vec![0u8; len.min(4096)];
            self.stream.read_exact(&mut msg)?;
            anyhow::bail!("server error: {}", String::from_utf8_lossy(&msg));
        }
        anyhow::ensure!(magic == RESP_MAGIC, "bad response magic {magic:#x}");
        let n = read_u32(&mut self.stream)? as usize;
        anyhow::ensure!(n <= 1000, "absurd result count {n}");
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            ids.push(read_u32(&mut self.stream)?);
        }
        let mut b = [0u8; 4];
        self.stream.read_exact(&mut b)?;
        let server_ms = f32::from_le_bytes(b);
        let ios = read_u32(&mut self.stream)?;
        Ok(ClientResponse { ids, server_ms, ios })
    }

    /// Fetch server counters and the `top_n` worst pages (`PANS`/`PANT`).
    pub fn stats(&mut self, top_n: usize) -> Result<StatsSnapshot> {
        let mut out = Vec::with_capacity(8);
        out.extend_from_slice(&STAT_MAGIC.to_le_bytes());
        out.extend_from_slice(&(top_n as u32).to_le_bytes());
        self.stream.write_all(&out)?;

        let magic = read_u32(&mut self.stream)?;
        if magic == ERR_MAGIC {
            let len = read_u32(&mut self.stream)? as usize;
            let mut msg = vec![0u8; len.min(4096)];
            self.stream.read_exact(&mut msg)?;
            anyhow::bail!("server error: {}", String::from_utf8_lossy(&msg));
        }
        anyhow::ensure!(magic == STAT_RESP_MAGIC, "bad stats magic {magic:#x}");
        let mut snap = StatsSnapshot {
            queries: read_u64(&mut self.stream)?,
            errors: read_u64(&mut self.stream)?,
            total_ios: read_u64(&mut self.stream)?,
            retries: read_u64(&mut self.stream)?,
            failed_ios: read_u64(&mut self.stream)?,
            crc_failures: read_u64(&mut self.stream)?,
            degraded: read_u64(&mut self.stream)?,
            batch_shared_ios: read_u64(&mut self.stream)?,
            lut_reused: read_u64(&mut self.stream)?,
            lut_cache_hits: read_u64(&mut self.stream)?,
            hists: Vec::new(),
            top_offenders: Vec::new(),
        };
        let n_hists = read_u32(&mut self.stream)? as usize;
        anyhow::ensure!(n_hists <= STAT_HIST_CAP, "absurd histogram count {n_hists}");
        for _ in 0..n_hists {
            let name_len = read_u8(&mut self.stream)? as usize;
            let mut name = vec![0u8; name_len];
            self.stream.read_exact(&mut name)?;
            let name = String::from_utf8_lossy(&name).into_owned();
            let count = read_u64(&mut self.stream)?;
            let mean = read_f64(&mut self.stream)?;
            let p50 = read_f64(&mut self.stream)?;
            let p90 = read_f64(&mut self.stream)?;
            let p99 = read_f64(&mut self.stream)?;
            let p999 = read_f64(&mut self.stream)?;
            let max = read_f64(&mut self.stream)?;
            snap.hists.push((name, HistSummary { count, mean, p50, p90, p99, p999, max }));
        }
        let n = read_u32(&mut self.stream)? as usize;
        anyhow::ensure!(n <= STAT_TOP_N_CAP, "absurd offender count {n}");
        for _ in 0..n {
            let page = read_u32(&mut self.stream)?;
            let retries = read_u64(&mut self.stream)?;
            let crc_failures = read_u64(&mut self.stream)?;
            let failed_ios = read_u64(&mut self.stream)?;
            snap.top_offenders.push((page, PageFaultTotals { retries, crc_failures, failed_ios }));
        }
        Ok(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{Dtype, VectorSet};
    use crate::metrics::PageFaultRecord;
    use std::sync::atomic::AtomicUsize;

    /// Brute-force system for protocol tests.
    struct Brute {
        base: VectorSet,
    }
    impl AnnSystem for Brute {
        fn name(&self) -> String {
            "brute".into()
        }
        fn search_one(
            &self,
            q: &[f32],
            k: usize,
            _l: usize,
            stats: &mut QueryStats,
        ) -> Result<Vec<u32>> {
            // Sentinel query → injected failure (exercises the PANE path).
            anyhow::ensure!(q[0].is_finite(), "injected search failure");
            stats.ios = 3;
            stats.retries = 1;
            let mut all: Vec<(f32, u32)> = (0..self.base.len())
                .map(|i| (crate::distance::l2sq_query(q, self.base.view(i)), i as u32))
                .collect();
            all.sort_by(|a, b| a.0.total_cmp(&b.0));
            Ok(all.into_iter().take(k).map(|(_, i)| i).collect())
        }
        fn memory_bytes(&self) -> usize {
            0
        }
    }

    /// Brute wrapper that records the largest batch `search_batch` saw.
    struct Batchy {
        inner: Brute,
        max_batch: AtomicUsize,
    }
    impl AnnSystem for Batchy {
        fn name(&self) -> String {
            "batchy".into()
        }
        fn search_one(
            &self,
            q: &[f32],
            k: usize,
            l: usize,
            stats: &mut QueryStats,
        ) -> Result<Vec<u32>> {
            self.inner.search_one(q, k, l, stats)
        }
        fn search_batch(
            &self,
            queries: &[&[f32]],
            k: usize,
            l: usize,
            stats: &mut [QueryStats],
        ) -> Vec<Result<Vec<u32>>> {
            self.max_batch.fetch_max(queries.len(), Ordering::Relaxed);
            queries
                .iter()
                .zip(stats.iter_mut())
                .map(|(q, st)| self.search_one(q, k, l, st))
                .collect()
        }
        fn memory_bytes(&self) -> usize {
            0
        }
    }

    fn spawn_server_with(cfg: BatchConfig) -> (ServerHandle, usize) {
        let dim = 4;
        let mut base = VectorSet::new(Dtype::F32, dim, 20);
        for i in 0..20 {
            base.set_from_f32(i, &[i as f32, 0.0, 0.0, 0.0]);
        }
        let sys: Arc<dyn AnnSystem> = Arc::new(Brute { base });
        let server = QueryServer::bind("127.0.0.1:0", sys, dim).unwrap().with_batching(cfg);
        (server.spawn().unwrap(), dim)
    }

    fn spawn_server() -> (ServerHandle, usize) {
        spawn_server_with(BatchConfig::default())
    }

    #[test]
    fn roundtrip_query_over_tcp() {
        let (handle, _) = spawn_server();
        let mut client = QueryClient::connect(&handle.addr).unwrap();
        let resp = client.query(&[5.2, 0.0, 0.0, 0.0], 3, 10).unwrap();
        assert_eq!(resp.ids, vec![5, 6, 4]);
        assert_eq!(resp.ios, 3);
        assert!(resp.server_ms >= 0.0);
        // Second query on the same connection.
        let resp2 = client.query(&[0.0, 0.0, 0.0, 0.0], 1, 10).unwrap();
        assert_eq!(resp2.ids, vec![0]);
        assert_eq!(handle.stats.queries.load(Ordering::Relaxed), 2);
        handle.stop();
    }

    #[test]
    fn batch_max_one_uses_inline_path_and_matches() {
        // The compatibility mode: no executors, connection threads search
        // inline — answers and stats identical to the batched default.
        let cfg = BatchConfig { batch_max: 1, ..BatchConfig::default() };
        let (handle, _) = spawn_server_with(cfg);
        let mut client = QueryClient::connect(&handle.addr).unwrap();
        let resp = client.query(&[5.2, 0.0, 0.0, 0.0], 3, 10).unwrap();
        assert_eq!(resp.ids, vec![5, 6, 4]);
        assert_eq!(resp.ios, 3);
        assert_eq!(handle.stats.queries.load(Ordering::Relaxed), 1);
        assert_eq!(handle.stats.retries.load(Ordering::Relaxed), 1);
        handle.stop();
    }

    #[test]
    fn batched_executor_coalesces_concurrent_queries() {
        // One executor, batch_max 3, generous gather window: three
        // concurrent clients must land in a single search_batch call.
        let dim = 4;
        let mut base = VectorSet::new(Dtype::F32, dim, 20);
        for i in 0..20 {
            base.set_from_f32(i, &[i as f32, 0.0, 0.0, 0.0]);
        }
        let sys = Arc::new(Batchy { inner: Brute { base }, max_batch: AtomicUsize::new(0) });
        let dynsys: Arc<dyn AnnSystem> = sys.clone();
        let server = QueryServer::bind("127.0.0.1:0", dynsys, dim).unwrap().with_batching(
            BatchConfig {
                batch_max: 3,
                gather: GatherPolicy::Fixed(Duration::from_secs(2)),
                executors: 1,
            },
        );
        let handle = server.spawn().unwrap();
        let addr = handle.addr;
        std::thread::scope(|s| {
            for t in 0u32..3 {
                s.spawn(move || {
                    let mut c = QueryClient::connect(&addr).unwrap();
                    let x = (t * 5) as f32;
                    let resp = c.query(&[x, 0.0, 0.0, 0.0], 1, 5).unwrap();
                    assert_eq!(resp.ids, vec![t * 5]);
                });
            }
        });
        assert_eq!(handle.stats.queries.load(Ordering::Relaxed), 3);
        assert_eq!(sys.max_batch.load(Ordering::Relaxed), 3);
        handle.stop();
    }

    #[test]
    fn stat_frame_reports_server_counters() {
        let (handle, _) = spawn_server();
        let mut client = QueryClient::connect(&handle.addr).unwrap();
        client.query(&[5.2, 0.0, 0.0, 0.0], 3, 10).unwrap();
        client.query(&[1.0, 0.0, 0.0, 0.0], 1, 10).unwrap();
        let snap = client.stats(8).unwrap();
        assert_eq!(snap.queries, 2);
        assert_eq!(snap.errors, 0);
        assert_eq!(snap.total_ios, 6);
        assert_eq!(snap.retries, 2);
        assert!(snap.top_offenders.is_empty());
        // Queries keep working on the same connection after a STAT frame.
        let resp = client.query(&[0.0, 0.0, 0.0, 0.0], 1, 10).unwrap();
        assert_eq!(resp.ids, vec![0]);
        handle.stop();
    }

    #[test]
    fn stats_frame_carries_arrival_and_phase_hists() {
        // Deterministic batched setup: one executor, zero gather window —
        // each tick drains exactly the queries already queued.
        let cfg = BatchConfig {
            batch_max: 4,
            gather: GatherPolicy::Fixed(Duration::ZERO),
            executors: 1,
        };
        let (handle, _) = spawn_server_with(cfg);
        let mut client = QueryClient::connect(&handle.addr).unwrap();
        client.query(&[5.2, 0.0, 0.0, 0.0], 3, 10).unwrap();
        client.query(&[1.0, 0.0, 0.0, 0.0], 1, 10).unwrap();
        let snap = client.stats(0).unwrap();
        assert_eq!(snap.hists.len(), STAT_HIST_NAMES.len());
        for (i, (name, _)) in snap.hists.iter().enumerate() {
            assert_eq!(name, STAT_HIST_NAMES[i]);
        }
        // Two answered queries → two samples in total + every phase hist.
        assert_eq!(snap.hist("total_us").unwrap().count, 2);
        for name in &STAT_HIST_NAMES[3..] {
            assert_eq!(snap.hist(name).unwrap().count, 2, "{name}");
        }
        // Sequential queries on one connection: exactly one inter-arrival
        // gap, and each tick gathered exactly one query.
        assert_eq!(snap.hist("arrival_us").unwrap().count, 1);
        let occ = snap.hist("gather_occupancy").unwrap();
        assert_eq!(occ.count, 2);
        assert!(occ.max >= 1.0, "occupancy max {}", occ.max);
        // Summaries are ordered.
        let t = snap.hist("total_us").unwrap();
        assert!(t.p50 <= t.p90 && t.p90 <= t.p99 && t.p99 <= t.p999);
        handle.stop();
    }

    #[test]
    fn stat_hist_names_follow_phase_taxonomy() {
        use crate::metrics::PhaseTimes;
        for (i, phase) in PhaseTimes::NAMES.iter().enumerate() {
            assert_eq!(STAT_HIST_NAMES[3 + i], format!("{phase}_us"));
        }
    }

    #[test]
    fn note_arrival_returns_inter_arrival_delta() {
        let mut t = ArrivalTracker::new();
        assert_eq!(t.note_arrival(100), None); // anchor only
        assert_eq!(t.note_arrival(150), Some(50));
        assert_eq!(t.note_arrival(150), Some(0));
        assert_eq!(t.note_arrival(250), Some(100));
    }

    #[test]
    fn per_page_fault_aggregation_and_top_offenders() {
        let stats = ServerStats::default();
        let mut q = QueryStats::default();
        q.page_faults.push(PageFaultRecord { page: 3, retries: 2, crc_failures: 1, failed: false });
        q.page_faults.push(PageFaultRecord { page: 9, retries: 0, crc_failures: 0, failed: true });
        stats.note_query(true, &q);
        let mut q2 = QueryStats::default();
        q2.page_faults.push(PageFaultRecord {
            page: 3,
            retries: 1,
            crc_failures: 0,
            failed: false,
        });
        stats.note_query(false, &q2);
        let top = stats.top_offenders(10);
        // Page 9 failed permanently → ranks first; page 3 aggregated
        // 3 retries + 1 CRC failure across two queries.
        assert_eq!(top.len(), 2);
        assert_eq!(top[0], (9, PageFaultTotals { retries: 0, crc_failures: 0, failed_ios: 1 }));
        assert_eq!(top[1], (3, PageFaultTotals { retries: 3, crc_failures: 1, failed_ios: 0 }));
        assert_eq!(stats.top_offenders(1).len(), 1);
        assert_eq!(stats.queries.load(Ordering::Relaxed), 1);
        assert_eq!(stats.errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn dim_mismatch_reports_error() {
        let (handle, _) = spawn_server();
        let mut client = QueryClient::connect(&handle.addr).unwrap();
        let err = client.query(&[1.0, 2.0], 3, 10).unwrap_err();
        assert!(err.to_string().contains("dim"), "{err}");
        assert_eq!(handle.stats.errors.load(Ordering::Relaxed), 1);
        handle.stop();
    }

    #[test]
    fn concurrent_connections() {
        let (handle, _) = spawn_server();
        let addr = handle.addr;
        std::thread::scope(|s| {
            for t in 0..4 {
                s.spawn(move || {
                    let mut c = QueryClient::connect(&addr).unwrap();
                    for i in 0..10 {
                        let x = ((t * 10 + i) % 20) as f32;
                        let resp = c.query(&[x, 0.0, 0.0, 0.0], 1, 5).unwrap();
                        assert_eq!(resp.ids, vec![x as u32]);
                    }
                });
            }
        });
        assert_eq!(handle.stats.queries.load(Ordering::Relaxed), 40);
        handle.stop();
    }

    #[test]
    fn bad_magic_closes_connection() {
        let (handle, _) = spawn_server();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        s.write_all(&0xDEADBEEFu32.to_le_bytes()).unwrap();
        let mut buf = [0u8; 4];
        s.read_exact(&mut buf).unwrap();
        assert_eq!(u32::from_le_bytes(buf), ERR_MAGIC);
        handle.stop();
    }

    #[test]
    fn search_error_answers_pane_and_connection_survives() {
        let (handle, _) = spawn_server();
        let mut client = QueryClient::connect(&handle.addr).unwrap();
        // NaN query hits Brute's injected failure → PANE frame.
        let err = client.query(&[f32::NAN, 0.0, 0.0, 0.0], 3, 10).unwrap_err();
        assert!(err.to_string().contains("search failed"), "{err}");
        assert_eq!(handle.stats.errors.load(Ordering::Relaxed), 1);
        // Same connection keeps answering.
        let resp = client.query(&[5.2, 0.0, 0.0, 0.0], 3, 10).unwrap();
        assert_eq!(resp.ids, vec![5, 6, 4]);
        assert_eq!(handle.stats.queries.load(Ordering::Relaxed), 1);
        assert_eq!(handle.stats.retries.load(Ordering::Relaxed), 1);
        handle.stop();
    }

    #[test]
    fn oversized_dim_drains_and_resyncs() {
        // A request with the wrong (but bounded) dim must leave the stream
        // frame-aligned: the full payload is drained, an error frame comes
        // back, and a subsequent valid query on the SAME connection works.
        let (handle, dim) = spawn_server();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        let qdim = 1000usize; // != dim, ≤ MAX_QDIM
        let mut req = Vec::new();
        req.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        req.extend_from_slice(&3u32.to_le_bytes()); // k
        req.extend_from_slice(&10u32.to_le_bytes()); // l
        req.extend_from_slice(&(qdim as u32).to_le_bytes());
        req.extend_from_slice(&vec![0u8; qdim * 4]); // payload
        s.write_all(&req).unwrap();
        let mut b = [0u8; 4];
        s.read_exact(&mut b).unwrap();
        assert_eq!(u32::from_le_bytes(b), ERR_MAGIC);
        s.read_exact(&mut b).unwrap();
        let len = u32::from_le_bytes(b) as usize;
        let mut msg = vec![0u8; len];
        s.read_exact(&mut msg).unwrap();
        // Now a valid query over the raw stream.
        let mut req = Vec::new();
        req.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        req.extend_from_slice(&1u32.to_le_bytes());
        req.extend_from_slice(&5u32.to_le_bytes());
        req.extend_from_slice(&(dim as u32).to_le_bytes());
        for x in [7.1f32, 0.0, 0.0, 0.0] {
            req.extend_from_slice(&x.to_le_bytes());
        }
        s.write_all(&req).unwrap();
        s.read_exact(&mut b).unwrap();
        assert_eq!(u32::from_le_bytes(b), RESP_MAGIC, "stream desynced after drained request");
        s.read_exact(&mut b).unwrap();
        assert_eq!(u32::from_le_bytes(b), 1); // n results
        s.read_exact(&mut b).unwrap();
        assert_eq!(u32::from_le_bytes(b), 7); // nearest id
        handle.stop();
    }

    #[test]
    fn absurd_dim_errors_and_closes() {
        // Beyond MAX_QDIM the server cannot drain in good faith: it must
        // answer with an error frame and close the connection instead of
        // reading gigabytes of garbage.
        let (handle, _) = spawn_server();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        let mut req = Vec::new();
        req.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        req.extend_from_slice(&3u32.to_le_bytes());
        req.extend_from_slice(&10u32.to_le_bytes());
        req.extend_from_slice(&((MAX_QDIM as u32) + 1).to_le_bytes());
        s.write_all(&req).unwrap();
        let mut b = [0u8; 4];
        s.read_exact(&mut b).unwrap();
        assert_eq!(u32::from_le_bytes(b), ERR_MAGIC);
        s.read_exact(&mut b).unwrap();
        let len = u32::from_le_bytes(b) as usize;
        let mut msg = vec![0u8; len];
        s.read_exact(&mut msg).unwrap();
        // Connection is closed: the next read hits EOF.
        let n = s.read(&mut b).unwrap();
        assert_eq!(n, 0, "connection must be closed after an undrainable request");
        handle.stop();
    }

    #[test]
    fn truncated_frame_times_out_instead_of_pinning_thread() {
        // A client that sends half a header and stalls must not hold its
        // serving thread forever — the read timeout reclaims it.
        let dim = 4;
        let mut base = VectorSet::new(Dtype::F32, dim, 4);
        for i in 0..4 {
            base.set_from_f32(i, &[i as f32, 0.0, 0.0, 0.0]);
        }
        let sys: Arc<dyn AnnSystem> = Arc::new(Brute { base });
        let server = QueryServer::bind("127.0.0.1:0", sys, dim)
            .unwrap()
            .with_read_timeout(Some(Duration::from_millis(100)));
        let handle = server.spawn().unwrap();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        s.write_all(&REQ_MAGIC.to_le_bytes()).unwrap(); // ...and stall
        // After the timeout the server abandons the connection: our next
        // read returns EOF (or a reset) rather than hanging.
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut b = [0u8; 4];
        match s.read(&mut b) {
            Ok(0) => {}        // clean close
            Ok(_) => panic!("server answered a truncated frame"),
            Err(_) => {}       // reset — also fine
        }
        handle.stop();
    }
}
