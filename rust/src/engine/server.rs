//! TCP query server: the network front-end of the L3 coordinator.
//!
//! Wire protocol (little-endian, one request per frame):
//!
//! ```text
//! request:  [u32 magic 0x50414E51 "PANQ"] [u32 k] [u32 l] [u32 dim] [f32 × dim]
//! response: [u32 magic 0x50414E52 "PANR"] [u32 n] [u32 id × n]
//!           [f32 latency_ms] [u32 ios]
//! error:    [u32 magic 0x50414E45 "PANE"] [u32 len] [len bytes utf-8]
//! ```
//!
//! One OS thread per connection (queries within a connection are
//! sequential; concurrency comes from multiple connections, matching the
//! paper's 1–16 query-thread setup). A shared [`AnnSystem`] serves all
//! connections; per-thread scratch lives in the system's thread-locals.

use super::AnnSystem;
use crate::metrics::QueryStats;
use crate::Result;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

pub const REQ_MAGIC: u32 = 0x50414E51;
pub const RESP_MAGIC: u32 = 0x50414E52;
pub const ERR_MAGIC: u32 = 0x50414E45;

/// Server statistics (scraped by monitoring / tests).
#[derive(Debug, Default)]
pub struct ServerStats {
    pub queries: AtomicU64,
    pub errors: AtomicU64,
    pub total_ios: AtomicU64,
}

pub struct QueryServer {
    listener: TcpListener,
    system: Arc<dyn AnnSystem>,
    dim: usize,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
}

/// Handle returned by [`QueryServer::spawn`]: stop + join the serve loop.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    pub stats: Arc<ServerStats>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Nudge the accept loop with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl QueryServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str, system: Arc<dyn AnnSystem>, dim: usize) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Ok(Self {
            listener,
            system,
            dim,
            stats: Arc::new(ServerStats::default()),
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Run the accept loop on a background thread.
    pub fn spawn(self) -> Result<ServerHandle> {
        let addr = self.local_addr()?;
        let shutdown = self.shutdown.clone();
        let stats = self.stats.clone();
        let join = std::thread::spawn(move || self.serve_loop());
        Ok(ServerHandle { addr, shutdown, join: Some(join), stats })
    }

    fn serve_loop(self) {
        loop {
            let (stream, _) = match self.listener.accept() {
                Ok(s) => s,
                Err(_) => continue,
            };
            if self.shutdown.load(Ordering::SeqCst) {
                return;
            }
            let system = self.system.clone();
            let stats = self.stats.clone();
            let dim = self.dim;
            let shutdown = self.shutdown.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(stream, system, dim, stats, shutdown);
            });
        }
    }
}

fn read_u32(s: &mut TcpStream) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    s.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn handle_connection(
    mut stream: TcpStream,
    system: Arc<dyn AnnSystem>,
    dim: usize,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true)?;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        let magic = match read_u32(&mut stream) {
            Ok(m) => m,
            Err(_) => return Ok(()), // connection closed
        };
        if magic != REQ_MAGIC {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            send_error(&mut stream, "bad request magic")?;
            return Ok(());
        }
        let k = read_u32(&mut stream)? as usize;
        let l = read_u32(&mut stream)? as usize;
        let qdim = read_u32(&mut stream)? as usize;
        if qdim != dim || k == 0 || k > 1000 || l > 100_000 {
            // Drain the (bounded) payload then report.
            let mut sink = vec![0u8; qdim.min(1 << 16) * 4];
            let _ = stream.read_exact(&mut sink);
            stats.errors.fetch_add(1, Ordering::Relaxed);
            send_error(&mut stream, &format!("bad request: dim {qdim} (want {dim}), k {k}"))?;
            continue;
        }
        let mut buf = vec![0u8; dim * 4];
        stream.read_exact(&mut buf)?;
        let query: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut qstats = QueryStats::default();
        let t = std::time::Instant::now();
        let ids = system.search_one(&query, k, l.max(k), &mut qstats);
        let ms = t.elapsed().as_secs_f64() * 1e3;
        stats.queries.fetch_add(1, Ordering::Relaxed);
        stats.total_ios.fetch_add(qstats.ios, Ordering::Relaxed);

        let mut out = Vec::with_capacity(16 + ids.len() * 4);
        out.extend_from_slice(&RESP_MAGIC.to_le_bytes());
        out.extend_from_slice(&(ids.len() as u32).to_le_bytes());
        for id in &ids {
            out.extend_from_slice(&id.to_le_bytes());
        }
        out.extend_from_slice(&(ms as f32).to_le_bytes());
        out.extend_from_slice(&(qstats.ios as u32).to_le_bytes());
        stream.write_all(&out)?;
    }
}

fn send_error(stream: &mut TcpStream, msg: &str) -> Result<()> {
    let mut out = Vec::with_capacity(8 + msg.len());
    out.extend_from_slice(&ERR_MAGIC.to_le_bytes());
    out.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    out.extend_from_slice(msg.as_bytes());
    stream.write_all(&out)?;
    Ok(())
}

/// Blocking client for the wire protocol above.
pub struct QueryClient {
    stream: TcpStream,
}

/// One answered query.
#[derive(Debug)]
pub struct ClientResponse {
    pub ids: Vec<u32>,
    pub server_ms: f32,
    pub ios: u32,
}

impl QueryClient {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Self { stream })
    }

    pub fn query(&mut self, q: &[f32], k: usize, l: usize) -> Result<ClientResponse> {
        let mut out = Vec::with_capacity(16 + q.len() * 4);
        out.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        out.extend_from_slice(&(k as u32).to_le_bytes());
        out.extend_from_slice(&(l as u32).to_le_bytes());
        out.extend_from_slice(&(q.len() as u32).to_le_bytes());
        for &x in q {
            out.extend_from_slice(&x.to_le_bytes());
        }
        self.stream.write_all(&out)?;

        let magic = read_u32(&mut self.stream)?;
        if magic == ERR_MAGIC {
            let len = read_u32(&mut self.stream)? as usize;
            let mut msg = vec![0u8; len.min(4096)];
            self.stream.read_exact(&mut msg)?;
            anyhow::bail!("server error: {}", String::from_utf8_lossy(&msg));
        }
        anyhow::ensure!(magic == RESP_MAGIC, "bad response magic {magic:#x}");
        let n = read_u32(&mut self.stream)? as usize;
        anyhow::ensure!(n <= 1000, "absurd result count {n}");
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            ids.push(read_u32(&mut self.stream)?);
        }
        let mut b = [0u8; 4];
        self.stream.read_exact(&mut b)?;
        let server_ms = f32::from_le_bytes(b);
        let ios = read_u32(&mut self.stream)?;
        Ok(ClientResponse { ids, server_ms, ios })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{Dtype, VectorSet};

    /// Brute-force system for protocol tests.
    struct Brute {
        base: VectorSet,
    }
    impl AnnSystem for Brute {
        fn name(&self) -> String {
            "brute".into()
        }
        fn search_one(&self, q: &[f32], k: usize, _l: usize, stats: &mut QueryStats) -> Vec<u32> {
            stats.ios = 3;
            let mut all: Vec<(f32, u32)> = (0..self.base.len())
                .map(|i| (crate::distance::l2sq_query(q, self.base.view(i)), i as u32))
                .collect();
            all.sort_by(|a, b| a.0.total_cmp(&b.0));
            all.into_iter().take(k).map(|(_, i)| i).collect()
        }
        fn memory_bytes(&self) -> usize {
            0
        }
    }

    fn spawn_server() -> (ServerHandle, usize) {
        let dim = 4;
        let mut base = VectorSet::new(Dtype::F32, dim, 20);
        for i in 0..20 {
            base.set_from_f32(i, &[i as f32, 0.0, 0.0, 0.0]);
        }
        let sys: Arc<dyn AnnSystem> = Arc::new(Brute { base });
        let server = QueryServer::bind("127.0.0.1:0", sys, dim).unwrap();
        (server.spawn().unwrap(), dim)
    }

    #[test]
    fn roundtrip_query_over_tcp() {
        let (handle, _) = spawn_server();
        let mut client = QueryClient::connect(&handle.addr).unwrap();
        let resp = client.query(&[5.2, 0.0, 0.0, 0.0], 3, 10).unwrap();
        assert_eq!(resp.ids, vec![5, 6, 4]);
        assert_eq!(resp.ios, 3);
        assert!(resp.server_ms >= 0.0);
        // Second query on the same connection.
        let resp2 = client.query(&[0.0, 0.0, 0.0, 0.0], 1, 10).unwrap();
        assert_eq!(resp2.ids, vec![0]);
        assert_eq!(handle.stats.queries.load(Ordering::Relaxed), 2);
        handle.stop();
    }

    #[test]
    fn dim_mismatch_reports_error() {
        let (handle, _) = spawn_server();
        let mut client = QueryClient::connect(&handle.addr).unwrap();
        let err = client.query(&[1.0, 2.0], 3, 10).unwrap_err();
        assert!(err.to_string().contains("dim"), "{err}");
        assert_eq!(handle.stats.errors.load(Ordering::Relaxed), 1);
        handle.stop();
    }

    #[test]
    fn concurrent_connections() {
        let (handle, _) = spawn_server();
        let addr = handle.addr;
        std::thread::scope(|s| {
            for t in 0..4 {
                s.spawn(move || {
                    let mut c = QueryClient::connect(&addr).unwrap();
                    for i in 0..10 {
                        let x = ((t * 10 + i) % 20) as f32;
                        let resp = c.query(&[x, 0.0, 0.0, 0.0], 1, 5).unwrap();
                        assert_eq!(resp.ids, vec![x as u32]);
                    }
                });
            }
        });
        assert_eq!(handle.stats.queries.load(Ordering::Relaxed), 40);
        handle.stop();
    }

    #[test]
    fn bad_magic_closes_connection() {
        let (handle, _) = spawn_server();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        s.write_all(&0xDEADBEEFu32.to_le_bytes()).unwrap();
        let mut buf = [0u8; 4];
        s.read_exact(&mut buf).unwrap();
        assert_eq!(u32::from_le_bytes(buf), ERR_MAGIC);
        handle.stop();
    }
}
