//! TCP query server: the network front-end of the L3 coordinator.
//!
//! Wire protocol (little-endian, one request per frame):
//!
//! ```text
//! request:  [u32 magic 0x50414E51 "PANQ"] [u32 k] [u32 l] [u32 dim] [f32 × dim]
//! response: [u32 magic 0x50414E52 "PANR"] [u32 n] [u32 id × n]
//!           [f32 latency_ms] [u32 ios]
//! error:    [u32 magic 0x50414E45 "PANE"] [u32 len] [len bytes utf-8]
//! ```
//!
//! One OS thread per connection (queries within a connection are
//! sequential; concurrency comes from multiple connections, matching the
//! paper's 1–16 query-thread setup). A shared [`AnnSystem`] serves all
//! connections; per-thread scratch lives in the system's thread-locals.
//!
//! Failure semantics (ISSUE 6): a failed search answers with a `PANE`
//! error frame and the connection survives; a malformed request is
//! answered and the payload fully drained (when bounded) so the stream
//! stays in sync, or the connection is closed (when it can't be); each
//! connection carries a read timeout so a stalled client can't pin its
//! thread forever; and persistent `accept` errors (e.g. EMFILE) back off
//! exponentially instead of busy-spinning.

use super::AnnSystem;
use crate::metrics::QueryStats;
use crate::Result;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

pub const REQ_MAGIC: u32 = 0x50414E51;
pub const RESP_MAGIC: u32 = 0x50414E52;
pub const ERR_MAGIC: u32 = 0x50414E45;

/// Hard cap on the query dimension a request may declare. Below it, a bad
/// request's payload is drained so the connection stays usable; above it,
/// draining is unbounded work for garbage, so the connection closes.
pub const MAX_QDIM: usize = 1 << 16;

/// Default per-connection read timeout (covers idle keep-alive too).
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Server statistics (scraped by monitoring / tests).
#[derive(Debug, Default)]
pub struct ServerStats {
    pub queries: AtomicU64,
    pub errors: AtomicU64,
    pub total_ios: AtomicU64,
    /// Read attempts retried inside the search path (sum of
    /// `QueryStats::retries`).
    pub retries: AtomicU64,
    /// Pages permanently skipped inside the search path.
    pub failed_ios: AtomicU64,
    /// Queries answered from a degraded traversal (some page skipped).
    pub degraded: AtomicU64,
}

pub struct QueryServer {
    listener: TcpListener,
    system: Arc<dyn AnnSystem>,
    dim: usize,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
    read_timeout: Option<Duration>,
}

/// Handle returned by [`QueryServer::spawn`]: stop + join the serve loop.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    pub stats: Arc<ServerStats>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Nudge the accept loop with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl QueryServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str, system: Arc<dyn AnnSystem>, dim: usize) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Ok(Self {
            listener,
            system,
            dim,
            stats: Arc::new(ServerStats::default()),
            shutdown: Arc::new(AtomicBool::new(false)),
            read_timeout: Some(DEFAULT_READ_TIMEOUT),
        })
    }

    /// Override the per-connection read timeout (`None` = never time out).
    pub fn with_read_timeout(mut self, timeout: Option<Duration>) -> Self {
        self.read_timeout = timeout;
        self
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Run the accept loop on a background thread.
    pub fn spawn(self) -> Result<ServerHandle> {
        let addr = self.local_addr()?;
        let shutdown = self.shutdown.clone();
        let stats = self.stats.clone();
        let join = std::thread::spawn(move || self.serve_loop());
        Ok(ServerHandle { addr, shutdown, join: Some(join), stats })
    }

    fn serve_loop(self) {
        // Exponential backoff for persistent accept() failures (EMFILE,
        // ENFILE): busy-spinning on a failing accept would peg a core and
        // starve the very connections holding the descriptors we need.
        let mut backoff = Duration::from_millis(10);
        const MAX_BACKOFF: Duration = Duration::from_secs(1);
        loop {
            let (stream, _) = match self.listener.accept() {
                Ok(s) => {
                    backoff = Duration::from_millis(10);
                    s
                }
                Err(e) => {
                    if self.shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    eprintln!("server: accept failed ({e}); backing off {backoff:?}");
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(MAX_BACKOFF);
                    continue;
                }
            };
            if self.shutdown.load(Ordering::SeqCst) {
                return;
            }
            let _ = stream.set_read_timeout(self.read_timeout);
            let system = self.system.clone();
            let stats = self.stats.clone();
            let dim = self.dim;
            let shutdown = self.shutdown.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(stream, system, dim, stats, shutdown);
            });
        }
    }
}

fn read_u32(s: &mut TcpStream) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    s.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Read and discard exactly `n` bytes — keeps the stream frame-aligned
/// after a rejected request without allocating the full payload.
fn drain_exact(s: &mut TcpStream, mut n: usize) -> std::io::Result<()> {
    let mut sink = [0u8; 4096];
    while n > 0 {
        let take = n.min(sink.len());
        s.read_exact(&mut sink[..take])?;
        n -= take;
    }
    Ok(())
}

fn handle_connection(
    mut stream: TcpStream,
    system: Arc<dyn AnnSystem>,
    dim: usize,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true)?;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        let magic = match read_u32(&mut stream) {
            Ok(m) => m,
            Err(_) => return Ok(()), // connection closed
        };
        if magic != REQ_MAGIC {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            send_error(&mut stream, "bad request magic")?;
            return Ok(());
        }
        let k = read_u32(&mut stream)? as usize;
        let l = read_u32(&mut stream)? as usize;
        let qdim = read_u32(&mut stream)? as usize;
        if qdim > MAX_QDIM {
            // Declared payload too large to drain in good faith — answer
            // and close; there is no way to re-align the stream.
            stats.errors.fetch_add(1, Ordering::Relaxed);
            let _ = send_error(&mut stream, &format!("query dim {qdim} exceeds {MAX_QDIM}"));
            return Ok(());
        }
        if qdim != dim || k == 0 || k > 1000 || l > 100_000 {
            // Drain the FULL payload — exactly qdim f32s — so the next
            // frame's magic lands where the parser looks for it. A partial
            // drain would desync the connection and misparse payload bytes
            // as magic words.
            drain_exact(&mut stream, qdim * 4)?;
            stats.errors.fetch_add(1, Ordering::Relaxed);
            send_error(&mut stream, &format!("bad request: dim {qdim} (want {dim}), k {k}"))?;
            continue;
        }
        let mut buf = vec![0u8; dim * 4];
        stream.read_exact(&mut buf)?;
        let query: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut qstats = QueryStats::default();
        let t = std::time::Instant::now();
        let ids = match system.search_one(&query, k, l.max(k), &mut qstats) {
            Ok(ids) => ids,
            Err(e) => {
                // A failed search answers with an error frame; the
                // connection (and its serving thread) survives.
                stats.errors.fetch_add(1, Ordering::Relaxed);
                stats.retries.fetch_add(qstats.retries, Ordering::Relaxed);
                stats.failed_ios.fetch_add(qstats.failed_ios, Ordering::Relaxed);
                send_error(&mut stream, &format!("search failed: {e}"))?;
                continue;
            }
        };
        let ms = t.elapsed().as_secs_f64() * 1e3;
        stats.queries.fetch_add(1, Ordering::Relaxed);
        stats.total_ios.fetch_add(qstats.ios, Ordering::Relaxed);
        stats.retries.fetch_add(qstats.retries, Ordering::Relaxed);
        stats.failed_ios.fetch_add(qstats.failed_ios, Ordering::Relaxed);
        if qstats.degraded {
            stats.degraded.fetch_add(1, Ordering::Relaxed);
        }

        let mut out = Vec::with_capacity(16 + ids.len() * 4);
        out.extend_from_slice(&RESP_MAGIC.to_le_bytes());
        out.extend_from_slice(&(ids.len() as u32).to_le_bytes());
        for id in &ids {
            out.extend_from_slice(&id.to_le_bytes());
        }
        out.extend_from_slice(&(ms as f32).to_le_bytes());
        out.extend_from_slice(&(qstats.ios as u32).to_le_bytes());
        stream.write_all(&out)?;
    }
}

fn send_error(stream: &mut TcpStream, msg: &str) -> Result<()> {
    let mut out = Vec::with_capacity(8 + msg.len());
    out.extend_from_slice(&ERR_MAGIC.to_le_bytes());
    out.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    out.extend_from_slice(msg.as_bytes());
    stream.write_all(&out)?;
    Ok(())
}

/// Blocking client for the wire protocol above.
pub struct QueryClient {
    stream: TcpStream,
}

/// One answered query.
#[derive(Debug)]
pub struct ClientResponse {
    pub ids: Vec<u32>,
    pub server_ms: f32,
    pub ios: u32,
}

impl QueryClient {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Self { stream })
    }

    pub fn query(&mut self, q: &[f32], k: usize, l: usize) -> Result<ClientResponse> {
        let mut out = Vec::with_capacity(16 + q.len() * 4);
        out.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        out.extend_from_slice(&(k as u32).to_le_bytes());
        out.extend_from_slice(&(l as u32).to_le_bytes());
        out.extend_from_slice(&(q.len() as u32).to_le_bytes());
        for &x in q {
            out.extend_from_slice(&x.to_le_bytes());
        }
        self.stream.write_all(&out)?;

        let magic = read_u32(&mut self.stream)?;
        if magic == ERR_MAGIC {
            let len = read_u32(&mut self.stream)? as usize;
            let mut msg = vec![0u8; len.min(4096)];
            self.stream.read_exact(&mut msg)?;
            anyhow::bail!("server error: {}", String::from_utf8_lossy(&msg));
        }
        anyhow::ensure!(magic == RESP_MAGIC, "bad response magic {magic:#x}");
        let n = read_u32(&mut self.stream)? as usize;
        anyhow::ensure!(n <= 1000, "absurd result count {n}");
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            ids.push(read_u32(&mut self.stream)?);
        }
        let mut b = [0u8; 4];
        self.stream.read_exact(&mut b)?;
        let server_ms = f32::from_le_bytes(b);
        let ios = read_u32(&mut self.stream)?;
        Ok(ClientResponse { ids, server_ms, ios })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{Dtype, VectorSet};

    /// Brute-force system for protocol tests.
    struct Brute {
        base: VectorSet,
    }
    impl AnnSystem for Brute {
        fn name(&self) -> String {
            "brute".into()
        }
        fn search_one(
            &self,
            q: &[f32],
            k: usize,
            _l: usize,
            stats: &mut QueryStats,
        ) -> Result<Vec<u32>> {
            // Sentinel query → injected failure (exercises the PANE path).
            anyhow::ensure!(q[0].is_finite(), "injected search failure");
            stats.ios = 3;
            stats.retries = 1;
            let mut all: Vec<(f32, u32)> = (0..self.base.len())
                .map(|i| (crate::distance::l2sq_query(q, self.base.view(i)), i as u32))
                .collect();
            all.sort_by(|a, b| a.0.total_cmp(&b.0));
            Ok(all.into_iter().take(k).map(|(_, i)| i).collect())
        }
        fn memory_bytes(&self) -> usize {
            0
        }
    }

    fn spawn_server() -> (ServerHandle, usize) {
        let dim = 4;
        let mut base = VectorSet::new(Dtype::F32, dim, 20);
        for i in 0..20 {
            base.set_from_f32(i, &[i as f32, 0.0, 0.0, 0.0]);
        }
        let sys: Arc<dyn AnnSystem> = Arc::new(Brute { base });
        let server = QueryServer::bind("127.0.0.1:0", sys, dim).unwrap();
        (server.spawn().unwrap(), dim)
    }

    #[test]
    fn roundtrip_query_over_tcp() {
        let (handle, _) = spawn_server();
        let mut client = QueryClient::connect(&handle.addr).unwrap();
        let resp = client.query(&[5.2, 0.0, 0.0, 0.0], 3, 10).unwrap();
        assert_eq!(resp.ids, vec![5, 6, 4]);
        assert_eq!(resp.ios, 3);
        assert!(resp.server_ms >= 0.0);
        // Second query on the same connection.
        let resp2 = client.query(&[0.0, 0.0, 0.0, 0.0], 1, 10).unwrap();
        assert_eq!(resp2.ids, vec![0]);
        assert_eq!(handle.stats.queries.load(Ordering::Relaxed), 2);
        handle.stop();
    }

    #[test]
    fn dim_mismatch_reports_error() {
        let (handle, _) = spawn_server();
        let mut client = QueryClient::connect(&handle.addr).unwrap();
        let err = client.query(&[1.0, 2.0], 3, 10).unwrap_err();
        assert!(err.to_string().contains("dim"), "{err}");
        assert_eq!(handle.stats.errors.load(Ordering::Relaxed), 1);
        handle.stop();
    }

    #[test]
    fn concurrent_connections() {
        let (handle, _) = spawn_server();
        let addr = handle.addr;
        std::thread::scope(|s| {
            for t in 0..4 {
                s.spawn(move || {
                    let mut c = QueryClient::connect(&addr).unwrap();
                    for i in 0..10 {
                        let x = ((t * 10 + i) % 20) as f32;
                        let resp = c.query(&[x, 0.0, 0.0, 0.0], 1, 5).unwrap();
                        assert_eq!(resp.ids, vec![x as u32]);
                    }
                });
            }
        });
        assert_eq!(handle.stats.queries.load(Ordering::Relaxed), 40);
        handle.stop();
    }

    #[test]
    fn bad_magic_closes_connection() {
        let (handle, _) = spawn_server();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        s.write_all(&0xDEADBEEFu32.to_le_bytes()).unwrap();
        let mut buf = [0u8; 4];
        s.read_exact(&mut buf).unwrap();
        assert_eq!(u32::from_le_bytes(buf), ERR_MAGIC);
        handle.stop();
    }

    #[test]
    fn search_error_answers_pane_and_connection_survives() {
        let (handle, _) = spawn_server();
        let mut client = QueryClient::connect(&handle.addr).unwrap();
        // NaN query hits Brute's injected failure → PANE frame.
        let err = client.query(&[f32::NAN, 0.0, 0.0, 0.0], 3, 10).unwrap_err();
        assert!(err.to_string().contains("search failed"), "{err}");
        assert_eq!(handle.stats.errors.load(Ordering::Relaxed), 1);
        // Same connection keeps answering.
        let resp = client.query(&[5.2, 0.0, 0.0, 0.0], 3, 10).unwrap();
        assert_eq!(resp.ids, vec![5, 6, 4]);
        assert_eq!(handle.stats.queries.load(Ordering::Relaxed), 1);
        assert_eq!(handle.stats.retries.load(Ordering::Relaxed), 1);
        handle.stop();
    }

    #[test]
    fn oversized_dim_drains_and_resyncs() {
        // A request with the wrong (but bounded) dim must leave the stream
        // frame-aligned: the full payload is drained, an error frame comes
        // back, and a subsequent valid query on the SAME connection works.
        let (handle, dim) = spawn_server();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        let qdim = 1000usize; // != dim, ≤ MAX_QDIM
        let mut req = Vec::new();
        req.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        req.extend_from_slice(&3u32.to_le_bytes()); // k
        req.extend_from_slice(&10u32.to_le_bytes()); // l
        req.extend_from_slice(&(qdim as u32).to_le_bytes());
        req.extend_from_slice(&vec![0u8; qdim * 4]); // payload
        s.write_all(&req).unwrap();
        let mut b = [0u8; 4];
        s.read_exact(&mut b).unwrap();
        assert_eq!(u32::from_le_bytes(b), ERR_MAGIC);
        s.read_exact(&mut b).unwrap();
        let len = u32::from_le_bytes(b) as usize;
        let mut msg = vec![0u8; len];
        s.read_exact(&mut msg).unwrap();
        // Now a valid query over the raw stream.
        let mut req = Vec::new();
        req.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        req.extend_from_slice(&1u32.to_le_bytes());
        req.extend_from_slice(&5u32.to_le_bytes());
        req.extend_from_slice(&(dim as u32).to_le_bytes());
        for x in [7.1f32, 0.0, 0.0, 0.0] {
            req.extend_from_slice(&x.to_le_bytes());
        }
        s.write_all(&req).unwrap();
        s.read_exact(&mut b).unwrap();
        assert_eq!(u32::from_le_bytes(b), RESP_MAGIC, "stream desynced after drained request");
        s.read_exact(&mut b).unwrap();
        assert_eq!(u32::from_le_bytes(b), 1); // n results
        s.read_exact(&mut b).unwrap();
        assert_eq!(u32::from_le_bytes(b), 7); // nearest id
        handle.stop();
    }

    #[test]
    fn absurd_dim_errors_and_closes() {
        // Beyond MAX_QDIM the server cannot drain in good faith: it must
        // answer with an error frame and close the connection instead of
        // reading gigabytes of garbage.
        let (handle, _) = spawn_server();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        let mut req = Vec::new();
        req.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        req.extend_from_slice(&3u32.to_le_bytes());
        req.extend_from_slice(&10u32.to_le_bytes());
        req.extend_from_slice(&((MAX_QDIM as u32) + 1).to_le_bytes());
        s.write_all(&req).unwrap();
        let mut b = [0u8; 4];
        s.read_exact(&mut b).unwrap();
        assert_eq!(u32::from_le_bytes(b), ERR_MAGIC);
        s.read_exact(&mut b).unwrap();
        let len = u32::from_le_bytes(b) as usize;
        let mut msg = vec![0u8; len];
        s.read_exact(&mut msg).unwrap();
        // Connection is closed: the next read hits EOF.
        let n = s.read(&mut b).unwrap();
        assert_eq!(n, 0, "connection must be closed after an undrainable request");
        handle.stop();
    }

    #[test]
    fn truncated_frame_times_out_instead_of_pinning_thread() {
        // A client that sends half a header and stalls must not hold its
        // serving thread forever — the read timeout reclaims it.
        let dim = 4;
        let mut base = VectorSet::new(Dtype::F32, dim, 4);
        for i in 0..4 {
            base.set_from_f32(i, &[i as f32, 0.0, 0.0, 0.0]);
        }
        let sys: Arc<dyn AnnSystem> = Arc::new(Brute { base });
        let server = QueryServer::bind("127.0.0.1:0", sys, dim)
            .unwrap()
            .with_read_timeout(Some(Duration::from_millis(100)));
        let handle = server.spawn().unwrap();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        s.write_all(&REQ_MAGIC.to_le_bytes()).unwrap(); // ...and stall
        // After the timeout the server abandons the connection: our next
        // read returns EOF (or a reset) rather than hanging.
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut b = [0u8; 4];
        match s.read(&mut b) {
            Ok(0) => {}        // clean close
            Ok(_) => panic!("server answered a truncated frame"),
            Err(_) => {}       // reset — also fine
        }
        handle.stop();
    }
}
