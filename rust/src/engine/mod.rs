//! Query engine: opens a built index, owns the memory-resident state
//! (routing, codes, cache, PJRT executables), and serves concurrent
//! queries.
//!
//! [`AnnSystem`] is the interface every scheme implements — PageANN here,
//! the four baselines in `crate::baselines` — so the experiment harness
//! drives them identically.

mod runner;
pub mod server;

pub use runner::{run_workload, run_workload_batched, tune_to_recall, WorkloadReport};
pub use server::{
    ArrivalTracker, BatchConfig, GatherPolicy, MonotonicClock, PageFaultTotals, QueryClient,
    QueryServer, ServerHandle, ServerStats, StatsSnapshot, TickClock, STAT_HIST_NAMES,
};

use crate::cache::{MemCodes, PageCache};
use crate::dataset::VectorSet;
use crate::distance::{BatchScanner, NativeBatch};
use crate::io::{open_with, FaultConfig, FaultStore, PageStore, SimSsdStore, SsdModel};
use crate::layout::{IndexFiles, IndexMeta, PageRef};
use crate::metrics::{QueryStats, TraceSink};
use crate::pq::{LutCache, PqCodebook};
use crate::routing::RoutingIndex;
use crate::search::{
    search_batch, search_pages, BatchScratch, SearchContext, SearchParams, SearchScratch,
};
use crate::Result;
use std::cell::RefCell;
use std::path::Path;

/// Common interface over all ANN schemes in this repo.
pub trait AnnSystem: Send + Sync {
    fn name(&self) -> String;
    /// Top-k original ids for one query. `l` is the search-list size (the
    /// recall knob every scheme shares). Errors (I/O that stays failed
    /// after retries with nothing found, corrupt index data) propagate —
    /// callers decide whether to drop the query (runner) or answer with an
    /// error frame (server); no implementation may panic on them.
    fn search_one(
        &self,
        query: &[f32],
        k: usize,
        l: usize,
        stats: &mut QueryStats,
    ) -> Result<Vec<u32>>;
    /// Top-k for a batch of queries, one `Result` (and one `stats` slot)
    /// per query in order. The default implementation loops
    /// [`Self::search_one`]; batch-native schemes (PageANN) override it to
    /// share LUT builds and coalesce page reads across the batch. Results
    /// must be identical to the sequential loop for every batch size.
    fn search_batch(
        &self,
        queries: &[&[f32]],
        k: usize,
        l: usize,
        stats: &mut [QueryStats],
    ) -> Vec<Result<Vec<u32>>> {
        debug_assert_eq!(queries.len(), stats.len());
        queries
            .iter()
            .zip(stats.iter_mut())
            .map(|(q, st)| self.search_one(q, k, l, st))
            .collect()
    }
    /// Resident memory this scheme needs at query time.
    fn memory_bytes(&self) -> usize;
}

/// Fault-injection policy for [`OpenOptions`].
#[derive(Debug, Clone, Default)]
pub enum FaultSpec {
    /// Honor the `PAGEANN_FAULTS` environment variable (no wrap when it is
    /// unset or a no-op).
    #[default]
    Env,
    /// Never inject, even when `PAGEANN_FAULTS` is set — lets tests build
    /// a clean-run baseline inside a faulted CI leg.
    Off,
    /// Explicit config, ignoring the environment.
    Config(FaultConfig),
}

impl FaultSpec {
    fn resolve(&self) -> Result<Option<FaultConfig>> {
        match self {
            FaultSpec::Env => FaultConfig::from_env(),
            FaultSpec::Off => Ok(None),
            FaultSpec::Config(c) => Ok(if c.is_noop() { None } else { Some(c.clone()) }),
        }
    }
}

/// Options for opening an index.
pub struct OpenOptions {
    /// Enforce the NVMe timing model (None = raw host I/O).
    pub sim_ssd: Option<SsdModel>,
    /// Budget for the warm-up page cache.
    pub cache_budget_bytes: usize,
    /// Distance backend. `None` = native scalar.
    pub scanner: Option<Box<dyn BatchScanner>>,
    /// Base search params (io_batch, routing probe) used by `search_one`.
    pub params: SearchParams,
    /// I/O backend preference (`uring`/`aio`/`pread`). `None` = honor the
    /// `PAGEANN_IO` env override, then probe uring → aio → pread. A
    /// preference redirects the probe but can never fail the open.
    pub io_backend: Option<String>,
    /// Fault injection (ISSUE 6). The fault wrapper goes outermost — over
    /// the sim-SSD model when both are on — so injected faults hit the
    /// same surface real device errors would.
    pub faults: FaultSpec,
    /// Cross-tick ADC LUT cache entries (`--lut-cache` /
    /// `PAGEANN_LUT_CACHE`). 0 (the default) disables the cache; > 0 lets
    /// `search_batch` skip LUT builds for queries that recur bit-identically
    /// across server ticks (see `pq::LutCache` — loss-free by
    /// construction).
    pub lut_cache_entries: usize,
    /// Per-hop JSONL trace target (`--trace` / `PAGEANN_TRACE`). `None`
    /// (the default) keeps tracing off at one pointer-check per hop; see
    /// `metrics::trace` and `OBSERVABILITY.md`.
    pub trace_path: Option<std::path::PathBuf>,
}

impl Default for OpenOptions {
    fn default() -> Self {
        let lut_cache_entries = std::env::var("PAGEANN_LUT_CACHE")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(0);
        Self {
            sim_ssd: None,
            cache_budget_bytes: 0,
            scanner: None,
            params: SearchParams::default(),
            io_backend: None,
            faults: FaultSpec::default(),
            lut_cache_entries,
            trace_path: None,
        }
    }
}

pub struct PageAnnIndex {
    pub meta: IndexMeta,
    store: Box<dyn PageStore>,
    /// Raw backend selected by the open probe (`io-uring`/`linux-aio`/
    /// `pread`) — the store itself may be wrapped in the sim-SSD model.
    io_backend: &'static str,
    cache: PageCache,
    memcodes: MemCodes,
    routing: Option<RoutingIndex>,
    pq: PqCodebook,
    scanner: Box<dyn BatchScanner>,
    params: SearchParams,
    /// Cross-tick LUT cache (`OpenOptions::lut_cache_entries` > 0); `None`
    /// keeps the zero-overhead build path.
    lut_cache: Option<LutCache>,
    /// Per-hop trace sink (`OpenOptions::trace_path` / `PAGEANN_TRACE`);
    /// `None` keeps the zero-overhead untraced path.
    trace: Option<std::sync::Arc<TraceSink>>,
}

thread_local! {
    static SCRATCH: RefCell<SearchScratch> = RefCell::new(SearchScratch::new());
    static BATCH: RefCell<BatchScratch> = RefCell::new(BatchScratch::new());
}

impl PageAnnIndex {
    /// Open a built index directory.
    pub fn open(dir: &Path, opts: OpenOptions) -> Result<Self> {
        let meta = IndexMeta::load(dir)?;
        let files = IndexFiles::new(dir);
        let raw = open_with(&files.pages(), meta.page_size, opts.io_backend.as_deref())?;
        anyhow::ensure!(raw.n_pages() == meta.n_pages, "pages.bin size mismatch");
        let io_backend = raw.name();
        let store: Box<dyn PageStore> = match opts.sim_ssd {
            Some(model) => Box::new(SimSsdStore::new(raw, model)),
            None => raw,
        };
        let store: Box<dyn PageStore> = match opts.faults.resolve()? {
            Some(cfg) => {
                eprintln!("engine: fault injection active: {cfg:?}");
                Box::new(FaultStore::new(store, cfg))
            }
            None => store,
        };
        let memcodes = MemCodes::load(dir, meta.n_slots())?;
        let pq = {
            let mut f = std::io::BufReader::new(std::fs::File::open(files.pq())?);
            PqCodebook::read_from(&mut f)?
        };
        anyhow::ensure!(
            pq.m == meta.pq_m && pq.k == meta.pq_k && pq.dim == meta.dim,
            "pq/meta mismatch"
        );
        // The stored code stride is width-dependent (PQ4 nibble-packs);
        // refuse an index whose memcodes were written at the other width.
        anyhow::ensure!(
            memcodes.code_bytes() == meta.code_bytes(),
            "memcodes stride {} != meta code width {}",
            memcodes.code_bytes(),
            meta.code_bytes()
        );
        let routing = if meta.routing_bits > 0 {
            let mut f = std::io::BufReader::new(std::fs::File::open(files.routing())?);
            Some(RoutingIndex::read_from(&mut f)?)
        } else {
            None
        };
        Ok(Self {
            cache: PageCache::empty(meta.page_size),
            scanner: opts.scanner.unwrap_or_else(|| Box::new(NativeBatch)),
            params: opts.params,
            lut_cache: if opts.lut_cache_entries > 0 {
                Some(LutCache::new(opts.lut_cache_entries))
            } else {
                None
            },
            trace: TraceSink::from_env_or(opts.trace_path.as_deref())?,
            meta,
            store,
            io_backend,
            memcodes,
            routing,
            pq,
        })
    }

    /// Raw I/O backend the open probe selected (before any sim-SSD wrap).
    pub fn io_backend(&self) -> &'static str {
        self.io_backend
    }

    /// Entry points for a query: routing probe, medoid fallback.
    fn entries(&self, query: &[f32]) -> Vec<u32> {
        if let Some(r) = &self.routing {
            let e = r.entry_points(query, self.params.routing_radius, self.params.max_entries);
            if !e.is_empty() {
                return e;
            }
        }
        vec![self.meta.medoid_new_id]
    }

    /// Full-control search (explicit params/scratch/stats).
    pub fn search(
        &self,
        query: &[f32],
        params: &SearchParams,
        scratch: &mut SearchScratch,
        stats: &mut QueryStats,
    ) -> Result<Vec<(f32, u32)>> {
        let t0 = std::time::Instant::now();
        let entries = self.entries(query);
        let ctx = SearchContext {
            meta: &self.meta,
            store: self.store.as_ref(),
            cache: &self.cache,
            memcodes: &self.memcodes,
            scanner: self.scanner.as_ref(),
            pq: &self.pq,
            lut_cache: self.lut_cache.as_ref(),
            trace: self.trace.as_deref(),
        };
        let out = search_pages(&ctx, query, &entries, params, scratch, stats)?;
        stats.total_time += t0.elapsed();
        Ok(out)
    }

    /// Full-control batched search: one [`Result`] per query, bit-identical
    /// to calling [`Self::search`] per query (see
    /// [`crate::search::search_batch`] for the identity argument). Each
    /// query's `total_time` is the batch's wall time — the latency a
    /// batched server tick actually imposes on every member.
    pub fn search_batch(
        &self,
        queries: &[&[f32]],
        params: &SearchParams,
        batch: &mut BatchScratch,
        stats: &mut [QueryStats],
    ) -> Vec<Result<Vec<(f32, u32)>>> {
        let t0 = std::time::Instant::now();
        let entries: Vec<Vec<u32>> = queries.iter().map(|q| self.entries(q)).collect();
        let entry_refs: Vec<&[u32]> = entries.iter().map(|e| e.as_slice()).collect();
        let ctx = SearchContext {
            meta: &self.meta,
            store: self.store.as_ref(),
            cache: &self.cache,
            memcodes: &self.memcodes,
            scanner: self.scanner.as_ref(),
            pq: &self.pq,
            lut_cache: self.lut_cache.as_ref(),
            trace: self.trace.as_deref(),
        };
        let out = search_batch(&ctx, queries, &entry_refs, params, batch, stats);
        let dt = t0.elapsed();
        for st in stats.iter_mut() {
            st.total_time += dt;
        }
        out
    }

    /// Warm-up (paper §4.3): run `queries` once, count page-visit
    /// frequencies, pin the hottest pages within `budget_bytes`.
    pub fn warmup(&mut self, queries: &VectorSet, budget_bytes: usize) -> Result<()> {
        if budget_bytes < self.meta.page_size {
            return Ok(());
        }
        let mut freq: std::collections::HashMap<u32, u64> = std::collections::HashMap::new();
        let mut scratch = SearchScratch::new();
        let params = self.params.clone();
        for qi in 0..queries.len() {
            let q = queries.get_f32(qi);
            let mut stats = QueryStats::default();
            self.search(&q, &params, &mut scratch, &mut stats)?;
            for &p in scratch.visited_pages_for_warmup() {
                *freq.entry(p).or_default() += 1;
            }
        }
        let freqs: Vec<(u32, u64)> = freq.into_iter().collect();
        let store = &*self.store;
        let meta = &self.meta;
        self.cache = PageCache::build(&freqs, meta.page_size, budget_bytes, |ids, out| {
            // Warm-up is best-effort: a page that won't read cleanly (or
            // fails checksum verification) after a few attempts is simply
            // not pinned — the query path re-reads it with its own retry
            // policy. Caching a corrupt page would poison every query.
            let batch_ok = store.read_pages(ids, out).is_ok();
            let mut keep = vec![true; ids.len()];
            for (k, &p) in ids.iter().enumerate() {
                let verify =
                    |buf: &Vec<u8>| !meta.page_crc || PageRef::verify_checksum(buf);
                let mut ok = batch_ok && verify(&out[k]);
                for _ in 0..3 {
                    if ok {
                        break;
                    }
                    ok = store
                        .read_pages(&[p], std::slice::from_mut(&mut out[k]))
                        .is_ok()
                        && verify(&out[k]);
                }
                keep[k] = ok;
            }
            Ok(keep)
        })?;
        Ok(())
    }

    pub fn routing_memory_bytes(&self) -> usize {
        self.routing.as_ref().map(|r| r.memory_bytes()).unwrap_or(0)
    }

    pub fn cache_pages(&self) -> usize {
        self.cache.n_pages()
    }

    /// Counters of the cross-tick LUT cache, or `None` when it is off.
    pub fn lut_cache_stats(&self) -> Option<crate::pq::LutCacheStats> {
        self.lut_cache.as_ref().map(|c| c.stats())
    }

    /// The per-hop trace sink, when tracing is on (`--trace` /
    /// `PAGEANN_TRACE`).
    pub fn trace_sink(&self) -> Option<&TraceSink> {
        self.trace.as_deref()
    }
}

impl AnnSystem for PageAnnIndex {
    fn name(&self) -> String {
        "PageANN".to_string()
    }

    fn search_one(
        &self,
        query: &[f32],
        k: usize,
        l: usize,
        stats: &mut QueryStats,
    ) -> Result<Vec<u32>> {
        let params = SearchParams { k, l, ..self.params.clone() };
        SCRATCH.with(|s| {
            let mut scratch = s.borrow_mut();
            let out = self.search(query, &params, &mut scratch, stats)?;
            Ok(out.into_iter().map(|(_, id)| id).collect())
        })
    }

    fn search_batch(
        &self,
        queries: &[&[f32]],
        k: usize,
        l: usize,
        stats: &mut [QueryStats],
    ) -> Vec<Result<Vec<u32>>> {
        // Batch size 1 gains nothing from lockstep (and the sequential
        // path additionally speculates), so route it through `search_one`
        // — this is literally today's single-query code path.
        if queries.len() == 1 {
            return vec![self.search_one(queries[0], k, l, &mut stats[0])];
        }
        let params = SearchParams { k, l, ..self.params.clone() };
        BATCH.with(|b| {
            let mut batch = b.borrow_mut();
            PageAnnIndex::search_batch(self, queries, &params, &mut batch, stats)
                .into_iter()
                .map(|r| r.map(|v| v.into_iter().map(|(_, id)| id).collect()))
                .collect()
        })
    }

    fn memory_bytes(&self) -> usize {
        self.memcodes.memory_bytes() + self.routing_memory_bytes() + self.cache.memory_bytes()
    }
}
