//! Hyperplane-LSH routing: planes, buckets, Hamming-radius probing.

use crate::dataset::VectorSet;
use crate::util::{ReadExt, WriteExt, XorShift};
use crate::Result;
use std::collections::HashMap;
use std::io::{Read, Write};

/// In-memory routing index: `bits ≤ 64` hyperplanes + hash buckets over a
/// sample of the base vectors.
pub struct RoutingIndex {
    pub dim: usize,
    pub bits: usize,
    /// bits × dim hyperplane normals, row-major.
    pub planes: Vec<f32>,
    /// code → sampled vector ids.
    pub buckets: HashMap<u64, Vec<u32>>,
    /// Number of sampled vectors (for memory accounting).
    pub n_sampled: usize,
}

impl RoutingIndex {
    /// Build from a `sample_frac` fraction of `base` using `bits`
    /// hyperplanes. Deterministic per seed.
    pub fn build(base: &VectorSet, sample_frac: f64, bits: usize, seed: u64) -> Self {
        let ids = Self::sample_ids(base.len(), sample_frac, seed);
        Self::build_with_sample(base, &ids, bits, seed)
    }

    /// The deterministic sample `build` would draw — exposed so callers
    /// (the index builder) can guarantee side tables cover exactly the
    /// sampled ids.
    pub fn sample_ids(n: usize, sample_frac: f64, seed: u64) -> Vec<u32> {
        let mut rng = XorShift::new(seed ^ 0x5A4D);
        let n_sample = ((n as f64 * sample_frac).round() as usize).clamp(n.min(64), n);
        rng.sample_indices(n, n_sample).into_iter().map(|i| i as u32).collect()
    }

    /// Build from an explicit sample id list.
    pub fn build_with_sample(base: &VectorSet, ids: &[u32], bits: usize, seed: u64) -> Self {
        assert!(bits > 0 && bits <= 64);
        let dim = base.dim();
        let mut rng = XorShift::new(seed);
        let planes: Vec<f32> = (0..bits * dim).map(|_| rng.next_gaussian()).collect();
        let mut buckets: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut buf = vec![0f32; dim];
        for &id in ids {
            base.decode_into(id as usize, &mut buf);
            let code = encode(&planes, bits, &buf);
            buckets.entry(code).or_default().push(id);
        }
        Self { dim, bits, planes, buckets, n_sampled: ids.len() }
    }

    /// Hash a query vector to its code.
    pub fn encode_query(&self, q: &[f32]) -> u64 {
        encode(&self.planes, self.bits, q)
    }

    /// Pack kernel-produced sign bits (0.0/1.0 per plane) into a code —
    /// used when the XLA `hash_encode` artifact does the projection.
    pub fn pack_bits(&self, bits: &[f32]) -> u64 {
        debug_assert_eq!(bits.len(), self.bits);
        let mut code = 0u64;
        for (i, &b) in bits.iter().enumerate() {
            if b > 0.5 {
                code |= 1 << i;
            }
        }
        code
    }

    /// All sampled ids in buckets within Hamming distance `radius` of the
    /// query's code, capped at `max_entries` (closest buckets first).
    pub fn entry_points(&self, q: &[f32], radius: usize, max_entries: usize) -> Vec<u32> {
        self.entry_points_for_code(self.encode_query(q), radius, max_entries)
    }

    /// Probe by precomputed code (the XLA-kernel path).
    pub fn entry_points_for_code(&self, code: u64, radius: usize, max_entries: usize) -> Vec<u32> {
        let mut out = Vec::new();
        // Radius-ordered probe: exact bucket, then Hamming-1, then Hamming-2…
        for r in 0..=radius.min(self.bits) {
            probe_at_radius(code, self.bits, r, &mut |c| {
                if let Some(ids) = self.buckets.get(&c) {
                    for &id in ids {
                        if out.len() < max_entries {
                            out.push(id);
                        }
                    }
                }
                out.len() < max_entries
            });
            if out.len() >= max_entries {
                break;
            }
        }
        out
    }

    /// Approximate resident bytes (planes + bucket table) for memory plans.
    pub fn memory_bytes(&self) -> usize {
        let planes = self.planes.len() * 4;
        let ids: usize = self.buckets.values().map(|v| v.len() * 4 + 16).sum();
        planes + ids + self.buckets.len() * 8
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_u32(self.dim as u32)?;
        w.write_u32(self.bits as u32)?;
        w.write_u32(self.n_sampled as u32)?;
        w.write_f32_slice(&self.planes)?;
        w.write_u32(self.buckets.len() as u32)?;
        let mut keys: Vec<u64> = self.buckets.keys().copied().collect();
        keys.sort();
        for k in keys {
            let ids = &self.buckets[&k];
            w.write_u64(k)?;
            w.write_u32(ids.len() as u32)?;
            w.write_u32_slice(ids)?;
        }
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        let dim = r.read_u32v()? as usize;
        let bits = r.read_u32v()? as usize;
        anyhow::ensure!(bits > 0 && bits <= 64 && dim > 0, "corrupt routing header");
        let n_sampled = r.read_u32v()? as usize;
        let planes = r.read_f32_vec(bits * dim)?;
        let n_buckets = r.read_u32v()? as usize;
        let mut buckets = HashMap::with_capacity(n_buckets);
        for _ in 0..n_buckets {
            let k = r.read_u64v()?;
            let n = r.read_u32v()? as usize;
            buckets.insert(k, r.read_u32_vec(n)?);
        }
        Ok(Self { dim, bits, planes, buckets, n_sampled })
    }
}

#[inline]
fn encode(planes: &[f32], bits: usize, v: &[f32]) -> u64 {
    let dim = v.len();
    let mut code = 0u64;
    for b in 0..bits {
        let row = &planes[b * dim..(b + 1) * dim];
        let mut dot = 0f32;
        for (p, x) in row.iter().zip(v) {
            dot += p * x;
        }
        if dot > 0.0 {
            code |= 1 << b;
        }
    }
    code
}

/// Visit every code at exactly Hamming distance `r` from `code` (over `bits`
/// bit positions). `f` returns false to stop early.
fn probe_at_radius(code: u64, bits: usize, r: usize, f: &mut impl FnMut(u64) -> bool) {
    if r == 0 {
        f(code);
        return;
    }
    // Enumerate r-subsets of bit positions (bounded: r ≤ 2 in practice).
    let mut positions = vec![0usize; r];
    fn rec(
        code: u64,
        bits: usize,
        r: usize,
        start: usize,
        depth: usize,
        positions: &mut [usize],
        f: &mut impl FnMut(u64) -> bool,
    ) -> bool {
        if depth == r {
            let mut c = code;
            for &p in positions.iter() {
                c ^= 1 << p;
            }
            return f(c);
        }
        for p in start..bits {
            positions[depth] = p;
            if !rec(code, bits, r, p + 1, depth + 1, positions, f) {
                return false;
            }
        }
        true
    }
    rec(code, bits, r, 0, 0, &mut positions, f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, SynthSpec};

    fn base() -> VectorSet {
        SynthSpec::new(DatasetKind::SiftLike, 1000).with_dim(32).with_clusters(8).generate(2)
    }

    #[test]
    fn codes_are_stable_and_bucketed() {
        let b = base();
        let idx = RoutingIndex::build(&b, 0.5, 16, 3);
        let total: usize = idx.buckets.values().map(|v| v.len()).sum();
        assert_eq!(total, idx.n_sampled);
        // Same vector → same code.
        let v = b.get_f32(10);
        assert_eq!(idx.encode_query(&v), idx.encode_query(&v));
    }

    #[test]
    fn pack_bits_matches_encode() {
        let b = base();
        let idx = RoutingIndex::build(&b, 0.1, 16, 3);
        let q = b.get_f32(0);
        // Simulate kernel output.
        let dim = idx.dim;
        let bits: Vec<f32> = (0..idx.bits)
            .map(|bi| {
                let row = &idx.planes[bi * dim..(bi + 1) * dim];
                let dot: f32 = row.iter().zip(&q).map(|(p, x)| p * x).sum();
                if dot > 0.0 {
                    1.0
                } else {
                    0.0
                }
            })
            .collect();
        assert_eq!(idx.pack_bits(&bits), idx.encode_query(&q));
    }

    #[test]
    fn probe_radius_enumerates_correct_counts() {
        let mut count0 = 0;
        probe_at_radius(0b1010, 8, 0, &mut |_| {
            count0 += 1;
            true
        });
        assert_eq!(count0, 1);
        let mut count1 = 0;
        probe_at_radius(0b1010, 8, 1, &mut |c| {
            assert_eq!((c ^ 0b1010).count_ones(), 1);
            count1 += 1;
            true
        });
        assert_eq!(count1, 8);
        let mut count2 = 0;
        probe_at_radius(0, 8, 2, &mut |c| {
            assert_eq!(c.count_ones(), 2);
            count2 += 1;
            true
        });
        assert_eq!(count2, 28); // C(8,2)
    }

    #[test]
    fn max_entries_respected() {
        let b = base();
        let idx = RoutingIndex::build(&b, 1.0, 8, 3);
        let q = b.get_f32(1);
        let e = idx.entry_points(&q, 2, 5);
        assert!(e.len() <= 5);
        assert!(!e.is_empty());
    }

    #[test]
    fn serialization_roundtrip() {
        let b = base();
        let idx = RoutingIndex::build(&b, 0.3, 12, 9);
        let mut buf = Vec::new();
        idx.write_to(&mut buf).unwrap();
        let back = RoutingIndex::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.dim, idx.dim);
        assert_eq!(back.bits, idx.bits);
        assert_eq!(back.planes, idx.planes);
        assert_eq!(back.buckets.len(), idx.buckets.len());
        let q = b.get_f32(7);
        assert_eq!(
            back.entry_points(&q, 1, 10),
            idx.entry_points(&q, 1, 10)
        );
    }

    #[test]
    fn memory_accounting_positive_and_monotone() {
        let b = base();
        let small = RoutingIndex::build(&b, 0.1, 8, 1);
        let big = RoutingIndex::build(&b, 0.9, 8, 1);
        assert!(small.memory_bytes() > 0);
        assert!(big.memory_bytes() > small.memory_bytes());
    }
}
