//! Lightweight in-memory routing index (paper §4.3, "caching for fast
//! lightweight indexing").
//!
//! A sample of base vectors is projected onto `H` random hyperplanes; the
//! sign pattern forms an `H`-bit binary code, and sampled vector ids are
//! bucketed by code. A query is encoded the same way and all buckets within
//! a small Hamming radius `r` are probed; the hits become entry points for
//! the on-disk page-graph traversal, cutting the search-path length.
//!
//! The hyperplane projection itself is the Layer-1 `hash_encode` kernel at
//! query time when the XLA backend is active; this module owns the planes,
//! buckets, serialization, and a native projection fallback.

mod hyperplane;

pub use hyperplane::RoutingIndex;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, SynthSpec};

    #[test]
    fn routing_entry_points_are_close_on_average() {
        // Entry points produced by the router should be much closer to the
        // query than random vectors are — that's its whole job.
        let spec = SynthSpec::new(DatasetKind::DeepLike, 3000).with_dim(32).with_clusters(16);
        let base = spec.generate(4);
        let queries = spec.generate_queries(20, 4, 77);
        let idx = RoutingIndex::build(&base, 0.2, 16, 21);

        let mut rng = crate::util::XorShift::new(5);
        let mut closer = 0usize;
        let mut total = 0usize;
        for qi in 0..queries.len() {
            let q = queries.get_f32(qi);
            let entries = idx.entry_points(&q, 2, 8);
            if entries.is_empty() {
                continue;
            }
            let de: f32 = entries
                .iter()
                .map(|&id| crate::distance::l2sq_query(&q, base.view(id as usize)))
                .fold(f32::INFINITY, f32::min);
            let dr: f32 = (0..entries.len())
                .map(|_| {
                    crate::distance::l2sq_query(
                        &q,
                        base.view(rng.next_below(base.len())),
                    )
                })
                .fold(f32::INFINITY, f32::min);
            total += 1;
            if de <= dr {
                closer += 1;
            }
        }
        assert!(total >= 15, "router returned entries for too few queries: {total}");
        assert!(closer * 10 >= total * 7, "router not better than random: {closer}/{total}");
    }
}
