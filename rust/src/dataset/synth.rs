//! Synthetic workload generation.
//!
//! Real embedding corpora (SIFT/SPACEV/DEEP) are mixtures of many local
//! clusters — that is what makes proximity graphs navigable and what page
//! clustering (Alg. 1) exploits. We synthesize the same structure: `C`
//! Gaussian cluster centers drawn uniformly in the dtype's dynamic range,
//! points drawn around a random center with per-cluster spread, quantized to
//! the target dtype. Queries are drawn from the same mixture (plus a small
//! out-of-distribution fraction, mirroring real query logs).

use super::types::{Dtype, VectorSet};
use crate::util::XorShift;

/// Fraction of base points interpolated between two cluster centers
/// (inter-cluster continuum density — see `SynthSpec::generate`).
const BRIDGE_FRAC: f32 = 0.15;

/// Which paper dataset this synthetic set stands in for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// SIFT-like: 128-d u8, range [0,255].
    SiftLike,
    /// SPACEV-like: 100-d i8, range [-128,127].
    SpacevLike,
    /// DEEP-like: 96-d f32, roughly unit-scale.
    DeepLike,
}

impl DatasetKind {
    pub fn default_dim(self) -> usize {
        match self {
            DatasetKind::SiftLike => 128,
            DatasetKind::SpacevLike => 100,
            DatasetKind::DeepLike => 96,
        }
    }

    pub fn dtype(self) -> Dtype {
        match self {
            DatasetKind::SiftLike => Dtype::U8,
            DatasetKind::SpacevLike => Dtype::I8,
            DatasetKind::DeepLike => Dtype::F32,
        }
    }

    /// (center_mid, center_sd, spread) in f32 space before quantization.
    ///
    /// Real embedding corpora are *overlapping* mixtures: cluster centers
    /// sit ~1.5 within-cluster spreads apart (squared inter/intra ratio
    /// ≈ 2–3), not isolated islands. Wildly separated centers make greedy
    /// graph search degenerate (every scheme gets trapped in the entry
    /// cluster) and make PQ trivially coarse — neither matches SIFT/DEEP
    /// behaviour.
    fn range(self) -> (f32, f32, f32) {
        match self {
            DatasetKind::SiftLike => (128.0, 22.0, 20.0),
            DatasetKind::SpacevLike => (0.0, 20.0, 18.0),
            DatasetKind::DeepLike => (0.0, 0.22, 0.2),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DatasetKind::SiftLike => "sift-like",
            DatasetKind::SpacevLike => "spacev-like",
            DatasetKind::DeepLike => "deep-like",
        }
    }
}

/// Parameters of a synthetic dataset.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub kind: DatasetKind,
    pub n: usize,
    pub dim: usize,
    pub clusters: usize,
    /// Fraction of queries drawn uniformly (out-of-distribution).
    pub ood_query_frac: f32,
}

impl SynthSpec {
    pub fn new(kind: DatasetKind, n: usize) -> Self {
        Self {
            kind,
            n,
            dim: kind.default_dim(),
            clusters: (n / 1000).clamp(8, 1024),
            ood_query_frac: 0.05,
        }
    }

    pub fn with_dim(mut self, dim: usize) -> Self {
        self.dim = dim;
        self
    }

    pub fn with_clusters(mut self, c: usize) -> Self {
        self.clusters = c.max(1);
        self
    }

    pub fn name(&self) -> String {
        format!("{}-{}", self.kind.name(), human_count(self.n))
    }

    /// Cluster centers on a low-intrinsic-dimension manifold.
    ///
    /// Drawing centers i.i.d. in R^D makes every cluster pair equidistant
    /// (concentration of measure) — greedy graph search then has no
    /// between-cluster gradient and *no* scheme can navigate, which is not
    /// how SIFT/DEEP behave (their intrinsic dimension is ~10–15). We draw
    /// center coefficients in a rank-8 random subspace instead: pairwise
    /// center distances vary, nearest-cluster chains exist, and proximity
    /// graphs stay navigable.
    fn centers(&self, rng: &mut XorShift) -> Vec<Vec<f32>> {
        let (mid, center_sd, _) = self.kind.range();
        let rank = 8.min(self.dim);
        // Random basis: rank × dim, rows ~ N(0, 1/rank) so composed
        // centers have per-dim variance ≈ center_sd².
        let basis: Vec<f32> = (0..rank * self.dim)
            .map(|_| rng.next_gaussian() / (rank as f32).sqrt())
            .collect();
        (0..self.clusters)
            .map(|_| {
                let z: Vec<f32> = (0..rank).map(|_| rng.next_gaussian() * center_sd).collect();
                (0..self.dim)
                    .map(|j| {
                        let mut x = mid;
                        for r in 0..rank {
                            x += z[r] * basis[r * self.dim + j];
                        }
                        x
                    })
                    .collect()
            })
            .collect()
    }

    /// Generate the base set. A given `(spec, seed)` is fully deterministic.
    pub fn generate(&self, seed: u64) -> VectorSet {
        let mut rng = XorShift::new(seed);
        let centers = self.centers(&mut rng);
        let (_, _, spread) = self.kind.range();
        let mut set = VectorSet::new(self.kind.dtype(), self.dim, self.n);
        let mut row = vec![0f32; self.dim];
        for i in 0..self.n {
            if self.clusters > 1 && rng.next_f32() < BRIDGE_FRAC {
                // Bridge point: an interpolation between two cluster
                // centers. Real corpora are continuous-density mixtures,
                // not isolated blobs; without inter-cluster density no
                // proximity graph is navigable (and none of the paper's
                // systems would work on such data either).
                let a = &centers[rng.next_below(self.clusters)];
                let b = &centers[rng.next_below(self.clusters)];
                let t = rng.next_f32();
                for (j, r) in row.iter_mut().enumerate() {
                    *r = t * a[j] + (1.0 - t) * b[j] + rng.next_gaussian() * spread;
                }
            } else {
                let c = &centers[rng.next_below(self.clusters)];
                // Per-cluster anisotropy: a handful of dims get 3x spread,
                // which keeps intra-cluster kNN non-trivial.
                for (j, r) in row.iter_mut().enumerate() {
                    let mult = if (j + i) % 17 == 0 { 3.0 } else { 1.0 };
                    *r = c[j] + rng.next_gaussian() * spread * mult;
                }
            }
            set.set_from_f32(i, &row);
        }
        set
    }

    /// Generate queries from the same mixture as `generate(base_seed)`:
    /// cluster centers are re-derived from `base_seed` so queries actually
    /// land near base-set clusters; the query draw itself uses `query_seed`.
    pub fn generate_queries(&self, n_queries: usize, base_seed: u64, query_seed: u64) -> VectorSet {
        let mut base_rng = XorShift::new(base_seed);
        let centers = self.centers(&mut base_rng);
        let (mid, center_sd, spread) = self.kind.range();
        let mut rng = XorShift::new(query_seed);
        let mut set = VectorSet::new(self.kind.dtype(), self.dim, n_queries);
        let mut row = vec![0f32; self.dim];
        for i in 0..n_queries {
            if rng.next_f32() < self.ood_query_frac {
                for r in row.iter_mut() {
                    *r = mid + rng.next_gaussian() * center_sd * 1.5;
                }
            } else {
                let c = &centers[rng.next_below(self.clusters)];
                for (j, r) in row.iter_mut().enumerate() {
                    *r = c[j] + rng.next_gaussian() * spread * 1.2;
                }
            }
            set.set_from_f32(i, &row);
        }
        set
    }
}

fn human_count(n: usize) -> String {
    if n >= 1_000_000 {
        format!("{}m", n / 1_000_000)
    } else if n >= 1_000 {
        format!("{}k", n / 1_000)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let spec = SynthSpec::new(DatasetKind::SiftLike, 200).with_dim(32);
        let a = spec.generate(7);
        let b = spec.generate(7);
        assert_eq!(a.as_bytes(), b.as_bytes());
        let c = spec.generate(8);
        assert_ne!(a.as_bytes(), c.as_bytes());
    }

    #[test]
    fn dtype_and_shape_per_kind() {
        for kind in [DatasetKind::SiftLike, DatasetKind::SpacevLike, DatasetKind::DeepLike] {
            let spec = SynthSpec::new(kind, 100);
            let s = spec.generate(1);
            assert_eq!(s.dtype(), kind.dtype());
            assert_eq!(s.dim(), kind.default_dim());
            assert_eq!(s.len(), 100);
        }
    }

    #[test]
    fn clusters_are_tighter_than_global() {
        // Mean distance to nearest of 2 same-cluster points should be far
        // below distance between random points: verify clustering exists by
        // comparing average pairwise distance of consecutive (likely
        // different-cluster) points vs global spread.
        let spec = SynthSpec::new(DatasetKind::DeepLike, 1000).with_dim(16).with_clusters(4);
        let s = spec.generate(3);
        // Compute distance distribution; with only 4 clusters at spread
        // 0.12 over range [-1,1], the histogram must be strongly bimodal:
        // some pairs ~cluster-internal (small), most pairs large.
        let mut small = 0usize;
        let mut large = 0usize;
        for i in 0..200 {
            for j in (i + 1)..200 {
                let d = crate::distance::l2sq_f32(&s.get_f32(i), &s.get_f32(j));
                if d < 1.0 {
                    small += 1;
                } else {
                    large += 1;
                }
            }
        }
        assert!(small > 100, "expected same-cluster pairs, got {small}");
        assert!(large > 1000, "expected cross-cluster pairs, got {large}");
    }
}
