//! fvecs / bvecs / ivecs readers and writers — the interchange formats of
//! the TEXMEX/BIGANN benchmark suites the paper evaluates on.
//!
//! Format: each vector is `[d: i32 little-endian][d elements]`, where
//! elements are f32 (fvecs), u8 (bvecs) or i32 (ivecs).

use super::types::{Dtype, VectorSet};
use crate::util::{ReadExt, WriteExt};
use crate::Result;
use std::io::{BufReader, BufWriter};
use std::path::Path;

/// Read an `.fvecs` file into an f32 [`VectorSet`].
pub fn read_fvecs(path: &Path) -> Result<VectorSet> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut rows: Vec<f32> = Vec::new();
    let mut dim: Option<usize> = None;
    loop {
        let d = match r.read_u32v() {
            Ok(d) => d as usize,
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e.into()),
        };
        anyhow::ensure!(d > 0 && d < 1 << 20, "implausible fvecs dim {d}");
        match dim {
            None => dim = Some(d),
            Some(prev) => anyhow::ensure!(prev == d, "ragged fvecs: {prev} vs {d}"),
        }
        rows.extend(r.read_f32_vec(d)?);
    }
    let dim = dim.ok_or_else(|| anyhow::anyhow!("empty fvecs file"))?;
    Ok(VectorSet::from_f32(dim, &rows))
}

/// Write an f32 [`VectorSet`] as `.fvecs`.
pub fn write_fvecs(path: &Path, set: &VectorSet) -> Result<()> {
    anyhow::ensure!(set.dtype() == Dtype::F32, "write_fvecs requires f32 set");
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for i in 0..set.len() {
        w.write_u32(set.dim() as u32)?;
        w.write_f32_slice(&set.get_f32(i))?;
    }
    Ok(())
}

/// Read a `.bvecs` file into a u8 [`VectorSet`].
pub fn read_bvecs(path: &Path) -> Result<VectorSet> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut data: Vec<u8> = Vec::new();
    let mut dim: Option<usize> = None;
    loop {
        let d = match r.read_u32v() {
            Ok(d) => d as usize,
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e.into()),
        };
        anyhow::ensure!(d > 0 && d < 1 << 20, "implausible bvecs dim {d}");
        match dim {
            None => dim = Some(d),
            Some(prev) => anyhow::ensure!(prev == d, "ragged bvecs: {prev} vs {d}"),
        }
        let start = data.len();
        data.resize(start + d, 0);
        std::io::Read::read_exact(&mut r, &mut data[start..])?;
    }
    let dim = dim.ok_or_else(|| anyhow::anyhow!("empty bvecs file"))?;
    VectorSet::from_raw(Dtype::U8, dim, data)
}

/// Read an `.ivecs` file (ground-truth id lists).
pub fn read_ivecs(path: &Path) -> Result<Vec<Vec<u32>>> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut out = Vec::new();
    loop {
        let d = match r.read_u32v() {
            Ok(d) => d as usize,
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e.into()),
        };
        anyhow::ensure!(d < 1 << 20, "implausible ivecs dim {d}");
        out.push(r.read_u32_vec(d)?);
    }
    Ok(out)
}

/// Write ground-truth id lists as `.ivecs`.
pub fn write_ivecs(path: &Path, rows: &[Vec<u32>]) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for row in rows {
        w.write_u32(row.len() as u32)?;
        w.write_u32_slice(row)?;
    }
    Ok(())
}

/// Dispatch on file extension: `.fvecs` → f32, `.bvecs` → u8.
pub fn read_vecs_auto(path: &Path) -> Result<VectorSet> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("fvecs") => read_fvecs(path),
        Some("bvecs") => read_bvecs(path),
        other => anyhow::bail!("unsupported vector file extension {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "pageann-fileio-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fvecs_roundtrip() {
        let dir = tmpdir();
        let set = VectorSet::from_f32(3, &[1.0, 2.0, 3.0, -4.0, 5.5, 0.0]);
        let p = dir.join("a.fvecs");
        write_fvecs(&p, &set).unwrap();
        let back = read_fvecs(&p).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.dim(), 3);
        assert_eq!(back.get_f32(1), vec![-4.0, 5.5, 0.0]);
        // auto dispatch
        let auto = read_vecs_auto(&p).unwrap();
        assert_eq!(auto.as_bytes(), back.as_bytes());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bvecs_roundtrip_manual() {
        let dir = tmpdir();
        let p = dir.join("b.bvecs");
        // Hand-encode two 4-d u8 vectors.
        let mut bytes = Vec::new();
        for v in [[1u8, 2, 3, 4], [250, 0, 9, 8]] {
            bytes.extend_from_slice(&4u32.to_le_bytes());
            bytes.extend_from_slice(&v);
        }
        std::fs::write(&p, &bytes).unwrap();
        let set = read_bvecs(&p).unwrap();
        assert_eq!(set.len(), 2);
        assert_eq!(set.dtype(), Dtype::U8);
        assert_eq!(set.get_f32(1), vec![250.0, 0.0, 9.0, 8.0]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ivecs_roundtrip() {
        let dir = tmpdir();
        let p = dir.join("gt.ivecs");
        let rows = vec![vec![5u32, 2, 9], vec![1u32]];
        write_ivecs(&p, &rows).unwrap();
        assert_eq!(read_ivecs(&p).unwrap(), rows);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ragged_fvecs_rejected() {
        let dir = tmpdir();
        let p = dir.join("ragged.fvecs");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&1.0f32.to_le_bytes());
        bytes.extend_from_slice(&2.0f32.to_le_bytes());
        bytes.extend_from_slice(&3u32.to_le_bytes()); // different dim
        bytes.extend_from_slice(&[0u8; 12]);
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_fvecs(&p).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unknown_extension_rejected() {
        assert!(read_vecs_auto(Path::new("/tmp/x.weird")).is_err());
    }
}
