//! Datasets: raw vector storage, synthetic workload generation, the
//! fvecs/bvecs/ivecs interchange formats, and exact ground-truth
//! computation.
//!
//! The paper evaluates on SIFT (128-d u8), SPACEV (100-d i8) and DEEP
//! (96-d f32). Those corpora are not redistributable here, so
//! [`synth::SynthSpec`] generates clustered datasets with identical
//! dimensionality/dtype and a controllable cluster structure — the property
//! graph-navigability and page-locality depend on (see DESIGN.md §3).

mod fileio;
mod groundtruth;
mod synth;
mod types;

pub use fileio::{read_fvecs, read_ivecs, read_vecs_auto, write_fvecs, write_ivecs};
pub use groundtruth::{ground_truth, recall_at_k};
pub use synth::{SynthSpec, DatasetKind};
pub use types::{Dtype, VectorSet, VectorView};

/// A complete benchmark workload: base vectors, query vectors, and the exact
/// top-k ground truth for each query.
pub struct Workload {
    pub name: String,
    pub base: VectorSet,
    pub queries: VectorSet,
    /// `gt[q]` = ids of the exact `k` nearest base vectors for query `q`.
    pub gt: Vec<Vec<u32>>,
    pub gt_k: usize,
}

impl Workload {
    /// Generate a synthetic workload (base + queries + ground truth).
    pub fn synthesize(spec: &SynthSpec, n_queries: usize, gt_k: usize, seed: u64) -> Self {
        let base = spec.generate(seed);
        let queries = spec.generate_queries(n_queries, seed, seed ^ 0x9E3779B97F4A7C15);
        let gt = ground_truth(&base, &queries, gt_k, crate::util::num_threads());
        Self { name: spec.name(), base, queries, gt, gt_k }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_end_to_end_tiny() {
        let spec = SynthSpec::new(DatasetKind::DeepLike, 500).with_dim(16).with_clusters(4);
        let w = Workload::synthesize(&spec, 10, 5, 42);
        assert_eq!(w.base.len(), 500);
        assert_eq!(w.queries.len(), 10);
        assert_eq!(w.gt.len(), 10);
        assert!(w.gt.iter().all(|g| g.len() == 5));
        // Ground truth ids must be valid and distinct.
        for g in &w.gt {
            let set: std::collections::HashSet<_> = g.iter().collect();
            assert_eq!(set.len(), g.len());
            assert!(g.iter().all(|&id| (id as usize) < 500));
        }
    }
}
