//! Exact k-nearest-neighbor ground truth (multithreaded brute force) and
//! the recall@k metric the paper reports.

use super::types::VectorSet;
use crate::distance::l2sq_query;
use crate::util::parallel_for;

/// A bounded max-heap over (distance, id): keeps the k smallest distances.
struct TopK {
    k: usize,
    /// Max-heap by distance (f32 total-ordered via bits).
    heap: std::collections::BinaryHeap<HeapItem>,
}

#[derive(PartialEq)]
struct HeapItem(f32, u32);
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Ties broken by id for determinism.
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

impl TopK {
    fn new(k: usize) -> Self {
        Self { k, heap: std::collections::BinaryHeap::with_capacity(k + 1) }
    }

    #[inline]
    fn push(&mut self, dist: f32, id: u32) {
        if self.heap.len() < self.k {
            self.heap.push(HeapItem(dist, id));
        } else if let Some(top) = self.heap.peek() {
            if HeapItem(dist, id) < *top {
                self.heap.pop();
                self.heap.push(HeapItem(dist, id));
            }
        }
    }

    /// Ids sorted ascending by distance.
    fn into_sorted_ids(self) -> Vec<u32> {
        let mut v: Vec<HeapItem> = self.heap.into_vec();
        v.sort_by(|a, b| a.cmp(b));
        v.into_iter().map(|HeapItem(_, id)| id).collect()
    }
}

/// Exact top-k ids for every query, by brute force over the base set.
pub fn ground_truth(
    base: &VectorSet,
    queries: &VectorSet,
    k: usize,
    nthreads: usize,
) -> Vec<Vec<u32>> {
    assert_eq!(base.dim(), queries.dim());
    let k = k.min(base.len());
    parallel_for(queries.len(), nthreads, |qi| {
        let q = queries.get_f32(qi);
        let mut top = TopK::new(k);
        for i in 0..base.len() {
            top.push(l2sq_query(&q, base.view(i)), i as u32);
        }
        top.into_sorted_ids()
    })
}

/// recall@k: |returned ∩ true top-k| / k, averaged over queries.
pub fn recall_at_k(results: &[Vec<u32>], gt: &[Vec<u32>], k: usize) -> f64 {
    assert_eq!(results.len(), gt.len());
    if results.is_empty() {
        return 0.0;
    }
    let mut total = 0.0f64;
    for (r, g) in results.iter().zip(gt) {
        let truth: std::collections::HashSet<u32> = g.iter().take(k).copied().collect();
        let hit = r.iter().take(k).filter(|id| truth.contains(id)).count();
        total += hit as f64 / k.min(truth.len().max(1)) as f64;
    }
    total / results.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{Dtype, VectorSet};
    use crate::util::XorShift;

    #[test]
    fn topk_keeps_smallest() {
        let mut t = TopK::new(3);
        for (d, id) in [(5.0, 0), (1.0, 1), (4.0, 2), (2.0, 3), (9.0, 4)] {
            t.push(d, id);
        }
        assert_eq!(t.into_sorted_ids(), vec![1, 3, 2]);
    }

    #[test]
    fn ground_truth_matches_naive_sort() {
        let mut rng = XorShift::new(21);
        let n = 300;
        let dim = 8;
        let rows: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian()).collect();
        let base = VectorSet::from_f32(dim, &rows);
        let qrows: Vec<f32> = (0..5 * dim).map(|_| rng.next_gaussian()).collect();
        let queries = VectorSet::from_f32(dim, &qrows);

        let gt = ground_truth(&base, &queries, 10, 4);
        for (qi, ids) in gt.iter().enumerate() {
            let q = queries.get_f32(qi);
            let mut all: Vec<(f32, u32)> = (0..n)
                .map(|i| (crate::distance::l2sq_f32(&q, &base.get_f32(i)), i as u32))
                .collect();
            all.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            let want: Vec<u32> = all.iter().take(10).map(|&(_, id)| id).collect();
            assert_eq!(ids, &want, "query {qi}");
        }
    }

    #[test]
    fn ground_truth_u8_dtype() {
        let mut base = VectorSet::new(Dtype::U8, 2, 4);
        for (i, v) in [[0.0, 0.0], [10.0, 0.0], [0.0, 10.0], [10.0, 10.0]].iter().enumerate() {
            base.set_from_f32(i, v);
        }
        let mut q = VectorSet::new(Dtype::U8, 2, 1);
        q.set_from_f32(0, &[1.0, 1.0]);
        let gt = ground_truth(&base, &q, 2, 1);
        assert_eq!(gt[0], vec![0, 1]); // (0,0) then (10,0) [tie with (0,10) broken by id]
    }

    #[test]
    fn recall_computation() {
        let gt = vec![vec![1u32, 2, 3], vec![4u32, 5, 6]];
        let perfect = vec![vec![3u32, 2, 1], vec![4u32, 5, 6]];
        assert!((recall_at_k(&perfect, &gt, 3) - 1.0).abs() < 1e-12);
        let half = vec![vec![1u32, 9, 8], vec![4u32, 5, 9]];
        let r = recall_at_k(&half, &gt, 3);
        assert!((r - 0.5).abs() < 1e-12, "{r}");
        let empty: Vec<Vec<u32>> = vec![];
        assert_eq!(recall_at_k(&empty, &[], 3), 0.0);
    }

    #[test]
    fn k_larger_than_base_is_clamped() {
        let base = VectorSet::from_f32(2, &[0.0, 0.0, 1.0, 1.0]);
        let q = VectorSet::from_f32(2, &[0.0, 0.0]);
        let gt = ground_truth(&base, &q, 10, 1);
        assert_eq!(gt[0].len(), 2);
    }
}
