//! Raw vector storage: a dtype-tagged, row-major byte matrix.
//!
//! Keeping vectors in their on-disk dtype (u8 for SIFT-like, i8 for
//! SPACEV-like, f32 for DEEP-like) is load-bearing for the paper: page-node
//! capacity is `page_bytes / (D * sizeof(dtype))`-ish, so a 128-d u8 vector
//! is 128 bytes, not 512.

use crate::Result;

/// Element type of a vector set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    U8,
    I8,
    F32,
}

impl Dtype {
    #[inline]
    pub fn size_bytes(self) -> usize {
        match self {
            Dtype::U8 | Dtype::I8 => 1,
            Dtype::F32 => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::U8 => "u8",
            Dtype::I8 => "i8",
            Dtype::F32 => "f32",
        }
    }

    pub fn from_tag(tag: u8) -> Result<Self> {
        Ok(match tag {
            0 => Dtype::U8,
            1 => Dtype::I8,
            2 => Dtype::F32,
            _ => anyhow::bail!("unknown dtype tag {tag}"),
        })
    }

    pub fn tag(self) -> u8 {
        match self {
            Dtype::U8 => 0,
            Dtype::I8 => 1,
            Dtype::F32 => 2,
        }
    }
}

/// Borrowed view of one raw vector.
#[derive(Debug, Clone, Copy)]
pub struct VectorView<'a> {
    pub bytes: &'a [u8],
    pub dtype: Dtype,
}

impl<'a> VectorView<'a> {
    /// Decode into an f32 buffer (must be `dim` long).
    pub fn decode_into(&self, out: &mut [f32]) {
        match self.dtype {
            Dtype::U8 => {
                for (o, &b) in out.iter_mut().zip(self.bytes) {
                    *o = b as f32;
                }
            }
            Dtype::I8 => {
                for (o, &b) in out.iter_mut().zip(self.bytes) {
                    *o = b as i8 as f32;
                }
            }
            Dtype::F32 => crate::util::binio::f32_from_le(self.bytes, out),
        }
    }

    pub fn dim(&self) -> usize {
        self.bytes.len() / self.dtype.size_bytes()
    }
}

/// An owned, row-major set of `n` vectors of dimension `dim` and a fixed
/// dtype, stored as raw bytes.
#[derive(Debug, Clone)]
pub struct VectorSet {
    dtype: Dtype,
    dim: usize,
    n: usize,
    data: Vec<u8>,
}

impl VectorSet {
    pub fn new(dtype: Dtype, dim: usize, n: usize) -> Self {
        Self { dtype, dim, n, data: vec![0u8; n * dim * dtype.size_bytes()] }
    }

    pub fn from_raw(dtype: Dtype, dim: usize, data: Vec<u8>) -> Result<Self> {
        let stride = dim * dtype.size_bytes();
        anyhow::ensure!(stride > 0 && data.len() % stride == 0, "ragged vector data");
        let n = data.len() / stride;
        Ok(Self { dtype, dim, n, data })
    }

    /// Build an f32 set from float rows.
    pub fn from_f32(dim: usize, rows: &[f32]) -> Self {
        assert_eq!(rows.len() % dim, 0);
        let mut data = Vec::with_capacity(rows.len() * 4);
        for &x in rows {
            data.extend_from_slice(&x.to_le_bytes());
        }
        Self { dtype: Dtype::F32, dim, n: rows.len() / dim, data }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    #[inline]
    pub fn stride(&self) -> usize {
        self.dim * self.dtype.size_bytes()
    }

    /// Raw bytes of vector `i`.
    #[inline]
    pub fn raw(&self, i: usize) -> &[u8] {
        let s = self.stride();
        &self.data[i * s..(i + 1) * s]
    }

    /// Borrowed typed view of vector `i`.
    #[inline]
    pub fn view(&self, i: usize) -> VectorView<'_> {
        VectorView { bytes: self.raw(i), dtype: self.dtype }
    }

    /// Mutable raw bytes of vector `i`.
    #[inline]
    pub fn raw_mut(&mut self, i: usize) -> &mut [u8] {
        let s = self.stride();
        &mut self.data[i * s..(i + 1) * s]
    }

    /// Decode vector `i` to f32.
    pub fn get_f32(&self, i: usize) -> Vec<f32> {
        let mut out = vec![0f32; self.dim];
        self.view(i).decode_into(&mut out);
        out
    }

    /// Decode vector `i` into a caller-provided buffer (hot path, no alloc).
    #[inline]
    pub fn decode_into(&self, i: usize, out: &mut [f32]) {
        self.view(i).decode_into(out);
    }

    /// Write an f32 row into slot `i`, quantizing to the set's dtype
    /// (clamping for integer dtypes).
    pub fn set_from_f32(&mut self, i: usize, row: &[f32]) {
        assert_eq!(row.len(), self.dim);
        let dtype = self.dtype;
        let raw = self.raw_mut(i);
        match dtype {
            Dtype::U8 => {
                for (b, &x) in raw.iter_mut().zip(row) {
                    *b = x.round().clamp(0.0, 255.0) as u8;
                }
            }
            Dtype::I8 => {
                for (b, &x) in raw.iter_mut().zip(row) {
                    *b = (x.round().clamp(-128.0, 127.0) as i8) as u8;
                }
            }
            Dtype::F32 => {
                for (c, &x) in raw.chunks_exact_mut(4).zip(row) {
                    c.copy_from_slice(&x.to_le_bytes());
                }
            }
        }
    }

    /// Total size of the raw vector payload in bytes (the paper's notion of
    /// "dataset size" against which memory ratios are computed).
    pub fn payload_bytes(&self) -> usize {
        self.data.len()
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip_all_dtypes() {
        for dtype in [Dtype::U8, Dtype::I8, Dtype::F32] {
            let mut s = VectorSet::new(dtype, 4, 3);
            s.set_from_f32(1, &[1.0, 2.0, 3.0, 4.0]);
            let got = s.get_f32(1);
            assert_eq!(got, vec![1.0, 2.0, 3.0, 4.0], "{dtype:?}");
            // Untouched rows are zero.
            assert_eq!(s.get_f32(0), vec![0.0; 4]);
        }
    }

    #[test]
    fn integer_dtypes_clamp() {
        let mut s = VectorSet::new(Dtype::U8, 2, 1);
        s.set_from_f32(0, &[-5.0, 300.0]);
        assert_eq!(s.get_f32(0), vec![0.0, 255.0]);

        let mut s = VectorSet::new(Dtype::I8, 2, 1);
        s.set_from_f32(0, &[-500.0, 500.0]);
        assert_eq!(s.get_f32(0), vec![-128.0, 127.0]);
    }

    #[test]
    fn stride_and_payload() {
        let s = VectorSet::new(Dtype::F32, 96, 10);
        assert_eq!(s.stride(), 384);
        assert_eq!(s.payload_bytes(), 3840);
        let s = VectorSet::new(Dtype::U8, 128, 10);
        assert_eq!(s.stride(), 128);
        assert_eq!(s.payload_bytes(), 1280);
    }

    #[test]
    fn from_raw_rejects_ragged() {
        assert!(VectorSet::from_raw(Dtype::F32, 3, vec![0u8; 10]).is_err());
        assert!(VectorSet::from_raw(Dtype::U8, 3, vec![0u8; 9]).is_ok());
    }

    #[test]
    fn dtype_tag_roundtrip() {
        for d in [Dtype::U8, Dtype::I8, Dtype::F32] {
            assert_eq!(Dtype::from_tag(d.tag()).unwrap(), d);
        }
        assert!(Dtype::from_tag(9).is_err());
    }
}
