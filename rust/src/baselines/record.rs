//! Vector-node disk records: the DiskANN-family on-disk format.
//!
//! One record per vector: `[vector bytes][u16 n_nbrs][u32 × R nbr ids]`,
//! fixed stride `record_size`, packed `nodes_per_page` to an SSD page.
//! DiskANN reads the page containing a node and uses only that record —
//! the read-amplification source PageANN eliminates.

use crate::dataset::VectorSet;
use crate::Result;

/// Geometry of a record file.
#[derive(Debug, Clone, Copy)]
pub struct RecordLayout {
    pub vec_stride: usize,
    pub max_degree: usize,
    pub page_size: usize,
}

impl RecordLayout {
    pub fn record_size(&self) -> usize {
        self.vec_stride + 2 + 4 * self.max_degree
    }

    pub fn nodes_per_page(&self) -> usize {
        (self.page_size / self.record_size()).max(1)
    }

    #[inline]
    pub fn page_of(&self, node: u32) -> u32 {
        node / self.nodes_per_page() as u32
    }

    #[inline]
    pub fn offset_in_page(&self, node: u32) -> usize {
        (node as usize % self.nodes_per_page()) * self.record_size()
    }

    pub fn n_pages(&self, n_nodes: usize) -> usize {
        crate::util::div_ceil(n_nodes, self.nodes_per_page())
    }

    /// Serialize the whole record file (node id = vector id, identity
    /// order; Starling passes a reordered adjacency+set instead).
    pub fn write_file(
        &self,
        path: &std::path::Path,
        base: &VectorSet,
        adj: &[Vec<u32>],
    ) -> Result<()> {
        use std::io::Write;
        anyhow::ensure!(base.len() == adj.len());
        anyhow::ensure!(self.record_size() * self.nodes_per_page() <= self.page_size || self.nodes_per_page() == 1);
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        let npp = self.nodes_per_page();
        let mut page = vec![0u8; self.page_size];
        let n_pages = self.n_pages(base.len());
        for p in 0..n_pages {
            page.fill(0);
            for s in 0..npp {
                let node = p * npp + s;
                if node >= base.len() {
                    break;
                }
                let off = s * self.record_size();
                let rec = &mut page[off..off + self.record_size()];
                rec[..self.vec_stride].copy_from_slice(base.raw(node));
                let nbrs = &adj[node];
                let n = nbrs.len().min(self.max_degree);
                rec[self.vec_stride..self.vec_stride + 2]
                    .copy_from_slice(&(n as u16).to_le_bytes());
                for (j, &nb) in nbrs.iter().take(n).enumerate() {
                    let o = self.vec_stride + 2 + j * 4;
                    rec[o..o + 4].copy_from_slice(&nb.to_le_bytes());
                }
            }
            f.write_all(&page)?;
        }
        f.flush()?;
        Ok(())
    }

    /// Parse the record of `node` out of its page buffer.
    pub fn parse<'a>(&self, page: &'a [u8], node: u32) -> NodeRecord<'a> {
        let off = self.offset_in_page(node);
        let rec = &page[off..off + self.record_size()];
        let n = u16::from_le_bytes([rec[self.vec_stride], rec[self.vec_stride + 1]]) as usize;
        NodeRecord { layout: *self, rec, n_nbrs: n.min(self.max_degree) }
    }

    /// Parse the record at slot `s` of a page (block scans).
    pub fn parse_slot<'a>(&self, page: &'a [u8], slot: usize) -> NodeRecord<'a> {
        let off = slot * self.record_size();
        let rec = &page[off..off + self.record_size()];
        let n = u16::from_le_bytes([rec[self.vec_stride], rec[self.vec_stride + 1]]) as usize;
        NodeRecord { layout: *self, rec, n_nbrs: n.min(self.max_degree) }
    }
}

/// Zero-copy view of one node record.
pub struct NodeRecord<'a> {
    layout: RecordLayout,
    rec: &'a [u8],
    n_nbrs: usize,
}

impl<'a> NodeRecord<'a> {
    pub fn vector(&self) -> &'a [u8] {
        &self.rec[..self.layout.vec_stride]
    }

    pub fn n_nbrs(&self) -> usize {
        self.n_nbrs
    }

    pub fn nbr(&self, j: usize) -> u32 {
        let o = self.layout.vec_stride + 2 + j * 4;
        u32::from_le_bytes([self.rec[o], self.rec[o + 1], self.rec[o + 2], self.rec[o + 3]])
    }

    /// Bytes of this record that are meaningful (read-amp accounting).
    pub fn used_bytes(&self) -> usize {
        self.layout.vec_stride + 2 + 4 * self.n_nbrs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::Dtype;

    #[test]
    fn geometry() {
        let l = RecordLayout { vec_stride: 128, max_degree: 24, page_size: 4096 };
        assert_eq!(l.record_size(), 128 + 2 + 96);
        assert_eq!(l.nodes_per_page(), 4096 / 226);
        assert_eq!(l.page_of(0), 0);
        assert_eq!(l.page_of(l.nodes_per_page() as u32), 1);
        assert_eq!(l.n_pages(100), crate::util::div_ceil(100, l.nodes_per_page()));
    }

    #[test]
    fn write_and_parse_roundtrip() {
        let mut base = VectorSet::new(Dtype::U8, 8, 10);
        for i in 0..10 {
            base.set_from_f32(i, &[i as f32; 8]);
        }
        let adj: Vec<Vec<u32>> = (0..10u32).map(|i| vec![(i + 1) % 10, (i + 2) % 10]).collect();
        let l = RecordLayout { vec_stride: 8, max_degree: 4, page_size: 128 };
        let path = std::env::temp_dir().join(format!("pageann-rec-{}", std::process::id()));
        l.write_file(&path, &base, &adj).unwrap();

        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes.len() % 128, 0);
        for node in [0u32, 3, 9] {
            let p = l.page_of(node) as usize;
            let page = &bytes[p * 128..(p + 1) * 128];
            let rec = l.parse(page, node);
            assert_eq!(rec.vector()[0], node as u8);
            assert_eq!(rec.n_nbrs(), 2);
            assert_eq!(rec.nbr(0), (node + 1) % 10);
            assert_eq!(rec.nbr(1), (node + 2) % 10);
            assert!(rec.used_bytes() <= l.record_size());
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn degree_overflow_truncated() {
        let mut base = VectorSet::new(Dtype::U8, 4, 2);
        base.set_from_f32(0, &[1.0; 4]);
        let adj = vec![vec![1u32; 10], vec![0u32]];
        let l = RecordLayout { vec_stride: 4, max_degree: 3, page_size: 64 };
        let path = std::env::temp_dir().join(format!("pageann-rec2-{}", std::process::id()));
        l.write_file(&path, &base, &adj).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let rec = l.parse(&bytes[..64], 0);
        assert_eq!(rec.n_nbrs(), 3);
        std::fs::remove_file(&path).unwrap();
    }
}
