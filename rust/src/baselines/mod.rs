//! Reimplementations of the four baselines the paper compares against
//! (§6.1), sharing PageANN's substrate (Vamana, PQ, page stores, metrics)
//! so the comparisons isolate exactly the architectural differences:
//!
//! | scheme            | disk granularity      | in-memory state        |
//! |-------------------|-----------------------|------------------------|
//! | [`DiskAnnLike`]   | vector node / sector  | all PQ codes           |
//! | [`PipeAnnLike`]   | vector node / sector  | all PQ codes           |
//! | [`StarlingLike`]  | packed page, block search | all PQ codes       |
//! | [`SpannLike`]     | posting lists         | cluster heads + graph  |
//!
//! DiskANN/PipeANN read a whole SSD page to use one node record → read
//! amplification ≫ 1 (Table 1). Starling packs neighbors into pages and
//! scans whole blocks → amplification ~1.3–2. SPANN trades memory for
//! sequential posting reads. PageANN's page-node graph makes the page the
//! *unit of traversal*, which none of these do.

mod diskann;
mod record;
mod spann;
mod starling;

pub use diskann::{DiskAnnIndex, DiskAnnLike, PipeAnnLike};
pub use record::{NodeRecord, RecordLayout};
pub use spann::SpannLike;
pub use starling::StarlingLike;

/// Placeholder store used only while swapping a store into the sim-SSD
/// wrapper (never read).
pub(crate) struct NullStore;

impl crate::io::PageStore for NullStore {
    fn page_size(&self) -> usize {
        0
    }
    fn n_pages(&self) -> usize {
        0
    }
    fn read_pages(&self, _: &[u32], _: &mut [Vec<u8>]) -> crate::Result<()> {
        anyhow::bail!("null store")
    }
    fn name(&self) -> &'static str {
        "null"
    }
}

pub(crate) fn diskann_null_store() -> NullStore {
    NullStore
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, SynthSpec, Workload};
    use crate::engine::{run_workload, AnnSystem};
    use crate::vamana::VamanaParams;

    fn workload() -> Workload {
        let spec = SynthSpec::new(DatasetKind::SiftLike, 2500).with_dim(32).with_clusters(12);
        Workload::synthesize(&spec, 30, 10, 55)
    }

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("pageann-bl-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn vamana() -> VamanaParams {
        VamanaParams { r: 16, l_build: 40, alpha: 1.2, seed: 5, nthreads: 4 }
    }

    #[test]
    fn diskann_like_reaches_recall() {
        let w = workload();
        let dir = tmpdir("da");
        let idx = DiskAnnIndex::build(&w.base, &vamana(), 8, 4096, &dir).unwrap();
        let sys = DiskAnnLike::open(idx, 4).unwrap();
        let rep = run_workload(&sys, &w.queries, Some(&w.gt), 10, 100, 4);
        assert!(rep.summary.recall >= 0.85, "{}", rep.summary.recall);
        // Vector-granularity reads: amplification must be well above 1
        // (Table 1's DiskANN row).
        let amp = rep.summary.totals.read_amplification();
        assert!(amp > 2.0, "diskann amp {amp}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn starling_like_cuts_read_amplification() {
        let w = workload();
        let d1 = tmpdir("st-a");
        let d2 = tmpdir("st-b");
        let da = DiskAnnLike::open(DiskAnnIndex::build(&w.base, &vamana(), 8, 4096, &d1).unwrap(), 4).unwrap();
        let st = StarlingLike::build(&w.base, &vamana(), 8, 4096, &d2, 4).unwrap();
        let rep_da = run_workload(&da, &w.queries, Some(&w.gt), 10, 100, 4);
        let rep_st = run_workload(&st, &w.queries, Some(&w.gt), 10, 100, 4);
        assert!(rep_st.summary.recall >= 0.85, "{}", rep_st.summary.recall);
        let amp_da = rep_da.summary.totals.read_amplification();
        let amp_st = rep_st.summary.totals.read_amplification();
        assert!(amp_st < amp_da, "starling {amp_st} !< diskann {amp_da}");
        std::fs::remove_dir_all(&d1).unwrap();
        std::fs::remove_dir_all(&d2).unwrap();
    }

    #[test]
    fn spann_like_reaches_recall_with_heavy_memory() {
        let w = workload();
        let dir = tmpdir("sp");
        let sys = SpannLike::build(&w.base, 64, 1.5, 4096, &dir, 4).unwrap();
        let rep = run_workload(&sys, &w.queries, Some(&w.gt), 10, 24, 4);
        assert!(rep.summary.recall >= 0.85, "{}", rep.summary.recall);
        // SPANN keeps heads + graph in memory: far more than PageANN's
        // routing table.
        assert!(sys.memory_bytes() > w.base.payload_bytes() / 20);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pipeann_like_matches_diskann_ios_with_lower_latency_model() {
        let w = workload();
        let dir = tmpdir("pa");
        let idx = DiskAnnIndex::build(&w.base, &vamana(), 8, 4096, &dir).unwrap();
        let pa = PipeAnnLike::open(idx, 4).unwrap();
        let rep = run_workload(&pa, &w.queries, Some(&w.gt), 10, 100, 4);
        assert!(rep.summary.recall >= 0.85, "{}", rep.summary.recall);
        assert!(rep.summary.mean_ios() > 0.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
