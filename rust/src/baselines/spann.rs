//! SPANN-like baseline (NeurIPS'21): memory-resident cluster heads +
//! disk-resident posting lists.
//!
//! Build: k-means picks `n/target_posting` heads; every vector joins the
//! posting lists of its `dup` closest heads (SPANN's duplication knob,
//! tuned in §6.1 to match disk overhead). Posting lists are page-aligned on
//! disk. Search: rank heads in memory, read the `nprobe = l` closest
//! postings (whole lists — all I/O issued *after* in-memory traversal,
//! SPANN's signature), scan exactly.
//!
//! Memory: full head vectors + head index — the ≥30%-memory-ratio floor of
//! Fig. 1/Table 4.

use crate::dataset::{VectorSet, VectorView};
use crate::distance::l2sq_query;
use crate::engine::AnnSystem;
use crate::io::{open_auto, PageStore, SimSsdStore, SsdModel};
use crate::metrics::QueryStats;
use crate::pq::kmeans;
use crate::Result;
use std::cell::RefCell;
use std::path::Path;
use std::time::Instant;

pub struct SpannLike {
    /// Head vectors (f32, flat) — in memory.
    heads: Vec<f32>,
    dim: usize,
    n_heads: usize,
    /// Per head: (first page, #pages, #vectors).
    postings: Vec<(u32, u32, u32)>,
    store: Box<dyn PageStore>,
    page_size: usize,
    dtype: crate::dataset::Dtype,
    vec_stride: usize,
    /// Vectors per page within posting lists.
    per_page: usize,
    /// Resident bytes (heads + maps) for memory accounting.
    resident_bytes: usize,
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
}

#[derive(Default)]
struct Scratch {
    bufs: Vec<Vec<u8>>,
    results: Vec<(f32, u32)>,
}

impl SpannLike {
    /// Build with `target_posting` vectors per head and duplication factor
    /// `dup` (≥1.0; 1.5 ≈ every other vector in two postings).
    pub fn build(
        base: &VectorSet,
        target_posting: usize,
        dup: f64,
        page_size: usize,
        dir: &Path,
        _nthreads: usize,
    ) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        let n = base.len();
        let dim = base.dim();
        let n_heads = (n / target_posting.max(1)).clamp(1, n);
        // Train heads on f32 rows.
        let mut rows = vec![0f32; n * dim];
        for i in 0..n {
            base.decode_into(i, &mut rows[i * dim..(i + 1) * dim]);
        }
        let km = kmeans(&rows, dim, n_heads, 10, 0x59A0);

        // Assignment with duplication: every vector to its nearest head;
        // a `dup-1` fraction also to the second nearest.
        let mut lists: Vec<Vec<u32>> = vec![Vec::new(); km.k];
        let extra_frac = (dup - 1.0).clamp(0.0, 1.0);
        let mut rng = crate::util::XorShift::new(0x59A1);
        for i in 0..n {
            let v = &rows[i * dim..(i + 1) * dim];
            let (mut b1, mut d1, mut b2, mut d2) = (0usize, f32::INFINITY, 0usize, f32::INFINITY);
            for c in 0..km.k {
                let d = crate::distance::l2sq_f32(v, km.centroid(c));
                if d < d1 {
                    b2 = b1;
                    d2 = d1;
                    b1 = c;
                    d1 = d;
                } else if d < d2 {
                    b2 = c;
                    d2 = d;
                }
            }
            lists[b1].push(i as u32);
            if km.k > 1 && rng.next_f64() < extra_frac {
                lists[b2].push(i as u32);
            }
        }

        // Posting file: each list page-aligned; page = [u16 count][entries:
        // u32 id + vector bytes].
        let vec_stride = base.dim() * base.dtype().size_bytes();
        let entry = 4 + vec_stride;
        let per_page = ((page_size - 2) / entry).max(1);
        let mut postings = Vec::with_capacity(km.k);
        let mut file = Vec::new();
        for list in &lists {
            let first_page = (file.len() / page_size) as u32;
            let n_pages = crate::util::div_ceil(list.len().max(1), per_page) as u32;
            for chunk in list.chunks(per_page.max(1)) {
                let mut page = vec![0u8; page_size];
                page[..2].copy_from_slice(&(chunk.len() as u16).to_le_bytes());
                for (s, &id) in chunk.iter().enumerate() {
                    let off = 2 + s * entry;
                    page[off..off + 4].copy_from_slice(&id.to_le_bytes());
                    page[off + 4..off + 4 + vec_stride].copy_from_slice(base.raw(id as usize));
                }
                file.extend_from_slice(&page);
            }
            if list.is_empty() {
                file.extend_from_slice(&vec![0u8; page_size]);
            }
            postings.push((first_page, n_pages, list.len() as u32));
        }
        std::fs::write(dir.join("postings.bin"), &file)?;

        let resident_bytes = km.centroids.len() * 4 + postings.len() * 12 + n * 4 / 10;
        let store = open_auto(&dir.join("postings.bin"), page_size)?;
        Ok(Self {
            heads: km.centroids,
            dim,
            n_heads: km.k,
            postings,
            store,
            page_size,
            dtype: base.dtype(),
            vec_stride,
            per_page,
            resident_bytes,
        })
    }

    pub fn with_sim_ssd(mut self, model: SsdModel) -> Self {
        let inner = std::mem::replace(&mut self.store, Box::new(super::diskann_null_store()));
        self.store = Box::new(SimSsdStore::new(inner, model));
        self
    }

    pub fn n_heads(&self) -> usize {
        self.n_heads
    }
}

impl AnnSystem for SpannLike {
    fn name(&self) -> String {
        "SPANN".to_string()
    }

    /// `l` plays the role of `nprobe` (number of posting lists visited) —
    /// the same recall knob semantics as the graph schemes' search list.
    fn search_one(
        &self,
        query: &[f32],
        k: usize,
        l: usize,
        stats: &mut QueryStats,
    ) -> crate::Result<Vec<u32>> {
        SCRATCH.with(|s| self.search_inner(query, k, l, stats, &mut s.borrow_mut()))
    }

    fn memory_bytes(&self) -> usize {
        self.resident_bytes
    }
}

impl SpannLike {
    fn search_inner(
        &self,
        query: &[f32],
        k: usize,
        nprobe: usize,
        stats: &mut QueryStats,
        scratch: &mut Scratch,
    ) -> crate::Result<Vec<u32>> {
        // In-memory head ranking (all I/O happens after, like SPANN).
        let t_cpu = Instant::now();
        let mut heads: Vec<(f32, u32)> = (0..self.n_heads)
            .map(|c| {
                stats.approx_dists += 1;
                (
                    crate::distance::l2sq_f32(query, &self.heads[c * self.dim..(c + 1) * self.dim]),
                    c as u32,
                )
            })
            .collect();
        let nprobe = nprobe.clamp(1, self.n_heads);
        heads.select_nth_unstable_by(nprobe - 1, |a, b| a.0.total_cmp(&b.0));
        heads.truncate(nprobe);
        stats.compute_time += t_cpu.elapsed();
        stats.hops = 1; // single traversal phase

        // Gather pages of the chosen postings.
        let mut pages: Vec<u32> = Vec::new();
        for &(_, h) in &heads {
            let (first, np, _) = self.postings[h as usize];
            for p in first..first + np {
                pages.push(p);
            }
        }
        let t_io = Instant::now();
        if scratch.bufs.len() < pages.len() {
            scratch.bufs.resize_with(pages.len(), || vec![0u8; self.page_size]);
        }
        // One retry for transient faults, then propagate — a dead read
        // must fail the query, not the process.
        if let Err(first) = self.store.read_pages(&pages, &mut scratch.bufs[..pages.len()]) {
            stats.retries += 1;
            self.store
                .read_pages(&pages, &mut scratch.bufs[..pages.len()])
                .map_err(|_| first)?;
        }
        stats.ios += pages.len() as u64;
        stats.bytes_read += (pages.len() * self.page_size) as u64;
        stats.io_time += t_io.elapsed();

        // Exact scan of the postings.
        let t_cpu = Instant::now();
        scratch.results.clear();
        let entry = 4 + self.vec_stride;
        for buf in scratch.bufs[..pages.len()].iter() {
            let count = u16::from_le_bytes([buf[0], buf[1]]) as usize;
            stats.bytes_used += (2 + count * entry) as u64;
            for s in 0..count.min(self.per_page) {
                let off = 2 + s * entry;
                let id = u32::from_le_bytes([buf[off], buf[off + 1], buf[off + 2], buf[off + 3]]);
                let vec_bytes = &buf[off + 4..off + 4 + self.vec_stride];
                let d = l2sq_query(query, VectorView { bytes: vec_bytes, dtype: self.dtype });
                stats.exact_dists += 1;
                scratch.results.push((d, id));
            }
        }
        scratch.results.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        scratch.results.dedup_by_key(|r| r.1);
        stats.compute_time += t_cpu.elapsed();
        Ok(scratch.results.iter().take(k).map(|&(_, id)| id).collect())
    }
}
