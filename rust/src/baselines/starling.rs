//! Starling-like baseline: locality-aware page packing + block search.
//!
//! Starling (SIGMOD'24) keeps DiskANN's vector-level graph but (i) reorders
//! nodes so graph neighbors share SSD pages and (ii) when a page is
//! fetched, scans *all* records in it ("block search"), cutting read
//! amplification to ~1.3–2 (Table 1). Vectors are still graph nodes — a
//! search hop is a node, not a page, so traversal paths stay long; that is
//! the gap PageANN closes.
//!
//! We reuse PageANN's hop-bounded grouping as the packing heuristic (it is
//! exactly a graph-partitioning pass like Starling's) and remap node ids to
//! `page * nodes_per_page + slot`.

use super::record::RecordLayout;
use crate::dataset::{Dtype, VectorSet, VectorView};
use crate::distance::l2sq_query;
use crate::engine::AnnSystem;
use crate::io::{open_auto, PageStore, SimSsdStore, SsdModel};
use crate::metrics::QueryStats;
use crate::pagegraph::{group_into_pages, GroupingParams};
use crate::pq::{PqCodebook, PqEncoder};
use crate::search::{CandidateSet, TopReservoir};
use crate::vamana::{VamanaGraph, VamanaParams};
use crate::Result;
use std::cell::RefCell;
use std::path::Path;
use std::time::Instant;

pub struct StarlingLike {
    layout: RecordLayout,
    store: Box<dyn PageStore>,
    n_slots: usize,
    dtype: Dtype,
    medoid_new: u32,
    pq: PqCodebook,
    /// Dense PQ codes in *new-id* space (slots; holes zeroed, never read).
    codes: Vec<u8>,
    /// new-id → original id (result reporting).
    new_to_orig: Vec<u32>,
    beam: usize,
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
}

#[derive(Default)]
struct Scratch {
    visited: std::collections::HashSet<u32>,
    visited_pages: std::collections::HashSet<u32>,
    bufs: Vec<Vec<u8>>,
    results: TopReservoir,
    /// Gathered neighbor ids/codes for the per-round batched ADC call.
    nbr_ids: Vec<u32>,
    nbr_codes: Vec<u8>,
    nbr_dists: Vec<f32>,
}

impl StarlingLike {
    pub fn build(
        base: &VectorSet,
        vamana: &VamanaParams,
        pq_m: usize,
        page_size: usize,
        dir: &Path,
        beam: usize,
    ) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        let graph = VamanaGraph::build(base, vamana);
        let layout = RecordLayout {
            vec_stride: base.dim() * base.dtype().size_bytes(),
            max_degree: vamana.r,
            page_size,
        };
        let npp = layout.nodes_per_page();

        // Locality-aware packing: reuse the hop-bounded grouping with page
        // capacity = nodes/page.
        let pages = group_into_pages(
            base,
            &graph,
            &GroupingParams { capacity: npp, hops: 2, seed: 0x57A8 },
        );
        // new-id = page * npp + slot; build maps.
        let n_slots = pages.len() * npp;
        let mut new_to_orig = vec![u32::MAX; n_slots];
        let mut orig_to_new = vec![u32::MAX; base.len()];
        for (p, members) in pages.iter().enumerate() {
            for (s, &orig) in members.iter().enumerate() {
                let new_id = (p * npp + s) as u32;
                new_to_orig[new_id as usize] = orig;
                orig_to_new[orig as usize] = new_id;
            }
        }

        // Reordered vector set + remapped adjacency, written as records.
        let mut reordered = VectorSet::new(base.dtype(), base.dim(), n_slots);
        let mut adj_new: Vec<Vec<u32>> = vec![Vec::new(); n_slots];
        for new_id in 0..n_slots {
            let orig = new_to_orig[new_id];
            if orig == u32::MAX {
                continue;
            }
            reordered
                .raw_mut(new_id)
                .copy_from_slice(base.raw(orig as usize));
            adj_new[new_id] = graph.adj[orig as usize]
                .iter()
                .map(|&nb| orig_to_new[nb as usize])
                .collect();
        }
        layout.write_file(&dir.join("records.bin"), &reordered, &adj_new)?;

        // PQ codes in new-id space (storage width — nibble-packed if the
        // codebook ever trains as PQ4; the search below is width-agnostic).
        let pq = PqCodebook::train(base, pq_m, 12, 0x57A1);
        let enc = PqEncoder::new(&pq);
        let cw = pq.code_bytes();
        let mut codes = vec![0u8; n_slots * cw];
        for new_id in 0..n_slots {
            let orig = new_to_orig[new_id];
            if orig == u32::MAX {
                continue;
            }
            let code = enc.encode_packed(&base.get_f32(orig as usize));
            codes[new_id * cw..(new_id + 1) * cw].copy_from_slice(&code);
        }

        let store = open_auto(&dir.join("records.bin"), page_size)?;
        Ok(Self {
            layout,
            store,
            n_slots,
            dtype: base.dtype(),
            medoid_new: orig_to_new[graph.medoid as usize],
            pq,
            codes,
            new_to_orig,
            beam,
        })
    }

    pub fn with_sim_ssd(mut self, model: SsdModel) -> Self {
        let inner = std::mem::replace(&mut self.store, Box::new(super::diskann_null_store()));
        self.store = Box::new(SimSsdStore::new(inner, model));
        self
    }
}

impl AnnSystem for StarlingLike {
    fn name(&self) -> String {
        "Starling".to_string()
    }

    fn search_one(
        &self,
        query: &[f32],
        k: usize,
        l: usize,
        stats: &mut QueryStats,
    ) -> crate::Result<Vec<u32>> {
        SCRATCH.with(|s| self.search_inner(query, k, l, stats, &mut s.borrow_mut()))
    }

    fn memory_bytes(&self) -> usize {
        self.codes.len() + self.pq.centroids.len() * 4
    }
}

impl StarlingLike {
    fn search_inner(
        &self,
        query: &[f32],
        k: usize,
        l: usize,
        stats: &mut QueryStats,
        scratch: &mut Scratch,
    ) -> crate::Result<Vec<u32>> {
        let lut = self.pq.build_lut(query);
        // Storage stride of one code (width-agnostic, like DiskANN's).
        let cw = self.pq.code_bytes();
        let npp = self.layout.nodes_per_page();
        let mut cands = CandidateSet::new(l);
        scratch.visited.clear();
        scratch.visited_pages.clear();
        scratch.results.reset(l.max(k));

        let entry = self.medoid_new;
        scratch.visited.insert(entry);
        cands.push(lut.distance(&self.codes[entry as usize * cw..(entry as usize + 1) * cw]), entry);
        stats.approx_dists += 1;

        let mut pages: Vec<u32> = Vec::with_capacity(self.beam);
        loop {
            pages.clear();
            while pages.len() < self.beam {
                let Some(v) = cands.pop_closest_unvisited() else { break };
                let p = self.layout.page_of(v);
                // Block search: once a page is scanned, popping another of
                // its members triggers no new I/O.
                if scratch.visited_pages.insert(p) {
                    pages.push(p);
                }
            }
            if pages.is_empty() {
                if !cands.has_unvisited() {
                    break;
                }
                continue;
            }
            stats.hops += 1;

            let t_io = Instant::now();
            if scratch.bufs.len() < pages.len() {
                scratch
                    .bufs
                    .resize_with(pages.len(), || vec![0u8; self.layout.page_size]);
            }
            // One retry for transient faults, then propagate — a dead read
            // must fail the query, not the process.
            if let Err(first) = self.store.read_pages(&pages, &mut scratch.bufs[..pages.len()]) {
                stats.retries += 1;
                self.store
                    .read_pages(&pages, &mut scratch.bufs[..pages.len()])
                    .map_err(|_| first)?;
            }
            stats.ios += pages.len() as u64;
            stats.bytes_read += (pages.len() * self.layout.page_size) as u64;
            stats.io_time += t_io.elapsed();

            let t_cpu = Instant::now();
            // Gather the round's unvisited neighbors for one batched ADC
            // call (block search scans whole pages, so rounds gather many).
            scratch.nbr_ids.clear();
            scratch.nbr_codes.clear();
            for (slot, &p) in pages.iter().enumerate() {
                // Scan every record in the block.
                for s in 0..npp {
                    let new_id = p * npp as u32 + s as u32;
                    if (new_id as usize) >= self.n_slots
                        || self.new_to_orig[new_id as usize] == u32::MAX
                    {
                        continue;
                    }
                    let rec = self.layout.parse_slot(&scratch.bufs[slot], s);
                    stats.bytes_used += rec.used_bytes() as u64;
                    let d = l2sq_query(query, VectorView { bytes: rec.vector(), dtype: self.dtype });
                    stats.exact_dists += 1;
                    scratch.results.push(d, new_id);
                    for j in 0..rec.n_nbrs() {
                        let nb = rec.nbr(j);
                        if !scratch.visited.insert(nb) {
                            continue;
                        }
                        scratch.nbr_ids.push(nb);
                        scratch
                            .nbr_codes
                            .extend_from_slice(&self.codes[nb as usize * cw..(nb as usize + 1) * cw]);
                    }
                }
            }
            let n_gathered = scratch.nbr_ids.len();
            lut.score_into(&scratch.nbr_codes, n_gathered, &mut scratch.nbr_dists);
            stats.approx_dists += n_gathered as u64;
            for i in 0..n_gathered {
                cands.push(scratch.nbr_dists[i], scratch.nbr_ids[i]);
            }
            stats.compute_time += t_cpu.elapsed();
        }

        Ok(scratch
            .results
            .sorted()
            .into_iter()
            .take(k)
            .map(|(_, new_id)| self.new_to_orig[new_id as usize])
            .collect())
    }
}
