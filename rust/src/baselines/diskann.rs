//! DiskANN-like and PipeANN-like baselines.
//!
//! Both traverse the vector-level Vamana graph with node records on disk
//! and all PQ codes in memory (the DiskANN minimum-memory configuration).
//! Each beam expansion reads the SSD pages containing the popped nodes but
//! consumes only those nodes' records — the read-amplification behaviour of
//! Table 1.
//!
//! PipeANN-like models the OSDI'25 pipelined best-first search: the same
//! I/O volume, but submission of the next beam overlaps the current beam's
//! distance computations. On the simulated SSD this shows up as higher
//! in-flight parallelism (wider batches), trading per-query latency for
//! queue pressure — matching the paper's observation that PipeANN needs
//! more memory/queue resources and degrades at high thread counts.

use super::record::RecordLayout;
use crate::dataset::{Dtype, VectorSet};
use crate::distance::l2sq_query;
use crate::engine::AnnSystem;
use crate::io::{open_auto, PageStore, SimSsdStore, SsdModel};
use crate::metrics::QueryStats;
use crate::pq::{PqCodebook, PqEncoder};
use crate::search::{CandidateSet, TopReservoir};
use crate::util::WriteExt;
use crate::vamana::{VamanaGraph, VamanaParams};
use crate::Result;
use std::cell::RefCell;
use std::path::Path;
use std::time::Instant;

/// The on-disk DiskANN index plus its resident state.
pub struct DiskAnnIndex {
    pub layout: RecordLayout,
    pub n_vectors: usize,
    pub dtype: Dtype,
    pub dim: usize,
    pub medoid: u32,
    pub pq: PqCodebook,
    /// All PQ codes, dense (n × code_bytes, storage width) — DiskANN's
    /// resident memory.
    pub codes: Vec<u8>,
    pub dir: std::path::PathBuf,
}

impl DiskAnnIndex {
    /// Build: Vamana + PQ + record file, written into `dir`.
    pub fn build(
        base: &VectorSet,
        vamana: &VamanaParams,
        pq_m: usize,
        page_size: usize,
        dir: &Path,
    ) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        let graph = VamanaGraph::build(base, vamana);
        let pq = PqCodebook::train(base, pq_m, 12, 0xD15C);
        let codes = PqEncoder::new(&pq).encode_all(base, vamana.nthreads);
        let layout = RecordLayout {
            vec_stride: base.dim() * base.dtype().size_bytes(),
            max_degree: vamana.r,
            page_size,
        };
        layout.write_file(&dir.join("records.bin"), base, &graph.adj)?;
        // Persist PQ + meta for completeness (reopened in experiments).
        {
            let mut f = std::io::BufWriter::new(std::fs::File::create(dir.join("pq.bin"))?);
            pq.write_to(&mut f)?;
        }
        {
            let mut f = std::io::BufWriter::new(std::fs::File::create(dir.join("meta.bin"))?);
            f.write_u32(base.len() as u32)?;
            f.write_u32(graph.medoid)?;
        }
        Ok(Self {
            layout,
            n_vectors: base.len(),
            dtype: base.dtype(),
            dim: base.dim(),
            medoid: graph.medoid,
            pq,
            codes,
            dir: dir.to_path_buf(),
        })
    }
}

/// Shared search core for DiskANN-like and PipeANN-like.
struct BeamSearcher {
    index: DiskAnnIndex,
    store: Box<dyn PageStore>,
    /// Beam width (pages in flight per round).
    beam: usize,
    /// Dedup pages within a round only (DiskANN re-reads across rounds).
    name: &'static str,
}

thread_local! {
    static SCRATCH: RefCell<BeamScratch> = RefCell::new(BeamScratch::default());
}

#[derive(Default)]
struct BeamScratch {
    visited: std::collections::HashSet<u32>,
    bufs: Vec<Vec<u8>>,
    results: TopReservoir,
    /// Gathered neighbor ids/codes for the per-round batched ADC call.
    nbr_ids: Vec<u32>,
    nbr_codes: Vec<u8>,
    nbr_dists: Vec<f32>,
}

impl BeamSearcher {
    fn search(&self, query: &[f32], k: usize, l: usize, stats: &mut QueryStats) -> Result<Vec<u32>> {
        SCRATCH.with(|s| {
            let mut scratch = s.borrow_mut();
            self.search_inner(query, k, l, stats, &mut scratch)
        })
    }

    fn search_inner(
        &self,
        query: &[f32],
        k: usize,
        l: usize,
        stats: &mut QueryStats,
        scratch: &mut BeamScratch,
    ) -> Result<Vec<u32>> {
        let idx = &self.index;
        let lut = idx.pq.build_lut(query);
        // Storage stride of one code (nibble-packed when the codebook is
        // PQ4) — the baselines are code-width-agnostic.
        let cw = idx.pq.code_bytes();
        let mut cands = CandidateSet::new(l);
        scratch.visited.clear();
        scratch.results.reset(l.max(k));

        let entry = idx.medoid;
        scratch.visited.insert(entry);
        cands.push(lut.distance(&idx.codes[entry as usize * cw..(entry as usize + 1) * cw]), entry);
        stats.approx_dists += 1;

        let mut nodes: Vec<u32> = Vec::with_capacity(self.beam);
        let mut pages: Vec<u32> = Vec::with_capacity(self.beam);
        loop {
            nodes.clear();
            pages.clear();
            while nodes.len() < self.beam {
                let Some(v) = cands.pop_closest_unvisited() else { break };
                nodes.push(v);
                let p = idx.layout.page_of(v);
                if !pages.contains(&p) {
                    pages.push(p);
                }
            }
            if nodes.is_empty() {
                break;
            }
            stats.hops += 1;

            let t_io = Instant::now();
            if scratch.bufs.len() < pages.len() {
                scratch
                    .bufs
                    .resize_with(pages.len(), || vec![0u8; idx.layout.page_size]);
            }
            // One retry for transient faults, then propagate — a dead read
            // must fail the query, not the process.
            if let Err(first) = self.store.read_pages(&pages, &mut scratch.bufs[..pages.len()]) {
                stats.retries += 1;
                self.store
                    .read_pages(&pages, &mut scratch.bufs[..pages.len()])
                    .map_err(|_| first)?;
            }
            stats.ios += pages.len() as u64;
            stats.bytes_read += (pages.len() * idx.layout.page_size) as u64;
            stats.io_time += t_io.elapsed();

            let t_cpu = Instant::now();
            // Gather this round's unvisited neighbors, then score them with
            // one batched ADC call instead of per-neighbor table walks.
            scratch.nbr_ids.clear();
            scratch.nbr_codes.clear();
            for &v in &nodes {
                let p = idx.layout.page_of(v);
                let slot = pages.iter().position(|&x| x == p).unwrap();
                let rec = idx.layout.parse(&scratch.bufs[slot], v);
                stats.bytes_used += rec.used_bytes() as u64;
                // Exact distance on the full vector.
                let d = l2sq_query(query, crate::dataset::VectorView { bytes: rec.vector(), dtype: idx.dtype });
                stats.exact_dists += 1;
                scratch.results.push(d, v);
                for j in 0..rec.n_nbrs() {
                    let nb = rec.nbr(j);
                    if !scratch.visited.insert(nb) {
                        continue;
                    }
                    scratch.nbr_ids.push(nb);
                    scratch
                        .nbr_codes
                        .extend_from_slice(&idx.codes[nb as usize * cw..(nb as usize + 1) * cw]);
                }
            }
            let n_gathered = scratch.nbr_ids.len();
            lut.score_into(&scratch.nbr_codes, n_gathered, &mut scratch.nbr_dists);
            stats.approx_dists += n_gathered as u64;
            for i in 0..n_gathered {
                cands.push(scratch.nbr_dists[i], scratch.nbr_ids[i]);
            }
            stats.compute_time += t_cpu.elapsed();
        }

        Ok(scratch.results.sorted().into_iter().take(k).map(|(_, id)| id).collect())
    }

    fn memory_bytes(&self) -> usize {
        // Resident: all PQ codes + codebooks.
        self.index.codes.len() + self.index.pq.centroids.len() * 4
    }
}

/// DiskANN-like: beam width = the paper's I/O batch (5).
pub struct DiskAnnLike {
    core: BeamSearcher,
}

impl DiskAnnLike {
    pub fn open(index: DiskAnnIndex, beam: usize) -> Result<Self> {
        let store = open_auto(&index.dir.join("records.bin"), index.layout.page_size)?;
        Ok(Self { core: BeamSearcher { index, store, beam, name: "DiskANN" } })
    }

    /// Wrap the store in the simulated-SSD timing model.
    pub fn with_sim_ssd(mut self, model: SsdModel) -> Self {
        let store = std::mem::replace(&mut self.core.store, Box::new(super::diskann_null_store()));
        self.core.store = Box::new(SimSsdStore::new(store, model));
        self
    }
}

/// PipeANN-like: double beam width models pipelined submission (same I/O
/// count per query, more in-flight).
pub struct PipeAnnLike {
    core: BeamSearcher,
}

impl PipeAnnLike {
    pub fn open(index: DiskAnnIndex, beam: usize) -> Result<Self> {
        let store = open_auto(&index.dir.join("records.bin"), index.layout.page_size)?;
        Ok(Self { core: BeamSearcher { index, store, beam: beam * 2, name: "PipeANN" } })
    }

    pub fn with_sim_ssd(mut self, model: SsdModel) -> Self {
        let store = std::mem::replace(&mut self.core.store, Box::new(super::diskann_null_store()));
        self.core.store = Box::new(SimSsdStore::new(store, model));
        self
    }
}

impl AnnSystem for DiskAnnLike {
    fn name(&self) -> String {
        self.core.name.to_string()
    }
    fn search_one(
        &self,
        query: &[f32],
        k: usize,
        l: usize,
        stats: &mut QueryStats,
    ) -> Result<Vec<u32>> {
        self.core.search(query, k, l, stats)
    }
    fn memory_bytes(&self) -> usize {
        self.core.memory_bytes()
    }
}

impl AnnSystem for PipeAnnLike {
    fn name(&self) -> String {
        self.core.name.to_string()
    }
    fn search_one(
        &self,
        query: &[f32],
        k: usize,
        l: usize,
        stats: &mut QueryStats,
    ) -> Result<Vec<u32>> {
        self.core.search(query, k, l, stats)
    }
    fn memory_bytes(&self) -> usize {
        // PipeANN additionally pins in-flight buffers (its open-source setup
        // requires a larger resident set — paper Table 4).
        self.core.memory_bytes() * 2
    }
}
