//! Warm-up-driven static page cache.
//!
//! PageANN runs a warm-up query batch, counts page visit frequencies, and
//! pins the hottest pages in memory up to the budget (paper §4.3). The
//! cache is immutable afterwards — no eviction on the query path, so a hit
//! is a single hash probe.

use crate::Result;
use std::collections::HashMap;

pub struct PageCache {
    pages: HashMap<u32, Box<[u8]>>,
    page_size: usize,
}

impl PageCache {
    /// Empty cache (zero budget).
    pub fn empty(page_size: usize) -> Self {
        Self { pages: HashMap::new(), page_size }
    }

    /// Build from `(page_id, frequency)` warm-up counts: hottest pages
    /// first until `budget_bytes` is exhausted. `fetch` reads page
    /// contents (usually `PageStore::read_pages` plus verification) and
    /// returns a keep mask — pages it marks false (unreadable, checksum
    /// failure) are left out of the cache rather than pinned corrupt.
    pub fn build<F>(
        freqs: &[(u32, u64)],
        page_size: usize,
        budget_bytes: usize,
        fetch: F,
    ) -> Result<Self>
    where
        F: FnOnce(&[u32], &mut [Vec<u8>]) -> Result<Vec<bool>>,
    {
        let n_fit = budget_bytes / page_size.max(1);
        let mut ranked: Vec<(u32, u64)> = freqs.to_vec();
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        ranked.truncate(n_fit);
        let ids: Vec<u32> = ranked.iter().map(|&(p, _)| p).collect();
        let mut bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; page_size]).collect();
        let keep = if ids.is_empty() { Vec::new() } else { fetch(&ids, &mut bufs)? };
        anyhow::ensure!(keep.len() == ids.len(), "cache fetch returned a bad keep mask");
        let mut pages = HashMap::with_capacity(ids.len());
        for ((id, buf), keep) in ids.into_iter().zip(bufs).zip(keep) {
            if keep {
                pages.insert(id, buf.into_boxed_slice());
            }
        }
        Ok(Self { pages, page_size })
    }

    #[inline]
    pub fn get(&self, page_id: u32) -> Option<&[u8]> {
        self.pages.get(&page_id).map(|b| b.as_ref())
    }

    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    pub fn memory_bytes(&self) -> usize {
        self.pages.len() * (self.page_size + 48) // payload + map overhead
    }
}
