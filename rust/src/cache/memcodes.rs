//! Resident compressed-vector table (memcodes.bin): the query-time half of
//! the §4.3 memory-disk coordination.
//!
//! Two layouts behind one lookup (entry stride = the *storage* code width:
//! `M` bytes for PQ8, `⌈M/2⌉` nibble-packed bytes for PQ4 — the header's
//! first word, which must match `IndexMeta::code_bytes()`):
//! * **sparse** — (sorted new-id array, packed codes), O(log n) binary
//!   search, 4+code_bytes bytes/entry; used for OnPage/Hybrid placements
//!   where only routing samples / hot neighbors are resident.
//! * **dense** — flat `n_slots × code_bytes` array, O(1); used for
//!   InMemory placement where every valid slot has a code.

use crate::util::checked::{to_usize, Ix};
use crate::util::ReadExt;
use crate::Result;
use std::io::Read;
use std::path::Path;

pub struct MemCodes {
    /// Bytes per stored code — the *storage* width (`⌈pq_m/2⌉` for
    /// nibble-packed PQ4 indexes, `pq_m` otherwise); must equal
    /// `IndexMeta::code_bytes()` of the owning index.
    code_bytes: usize,
    repr: Repr,
}

enum Repr {
    Sparse { ids: Vec<u32>, codes: Vec<u8> },
    Dense { codes: Vec<u8> },
}

impl MemCodes {
    pub fn empty(code_bytes: usize) -> Self {
        Self { code_bytes, repr: Repr::Sparse { ids: Vec::new(), codes: Vec::new() } }
    }

    /// Load memcodes.bin. Switches to the dense layout when the table
    /// covers most of the slot space (the InMemory regime).
    pub fn load(dir: &Path, n_slots: usize) -> Result<Self> {
        let mut f = std::io::BufReader::new(std::fs::File::open(dir.join("memcodes.bin"))?);
        let m = f.read_u32v()?.ix(); // storage stride, not subspaces
        let n = to_usize(f.read_u64v()?)?;
        anyhow::ensure!(m > 0 && m <= 64, "corrupt memcodes header");
        let mut ids = Vec::with_capacity(n);
        let mut codes = vec![0u8; n * m];
        for i in 0..n {
            ids.push(f.read_u32v()?);
            f.read_exact(&mut codes[i * m..(i + 1) * m])?;
        }
        anyhow::ensure!(ids.windows(2).all(|w| w[0] < w[1]), "memcodes not sorted");
        // Densify when ≥ 50% of slots covered: the id array + binary search
        // would cost more than the padding wastes.
        if n * 2 >= n_slots && n_slots > 0 {
            let mut dense = vec![0u8; n_slots * m];
            for (i, &id) in ids.iter().enumerate() {
                let id = id.ix();
                anyhow::ensure!(id < n_slots, "memcode id {id} out of slot range");
                dense[id * m..(id + 1) * m].copy_from_slice(&codes[i * m..(i + 1) * m]);
            }
            Ok(Self { code_bytes: m, repr: Repr::Dense { codes: dense } })
        } else {
            Ok(Self { code_bytes: m, repr: Repr::Sparse { ids, codes } })
        }
    }

    /// Bytes per stored code (the storage stride, PQ4-aware).
    #[inline]
    pub fn code_bytes(&self) -> usize {
        self.code_bytes
    }

    /// Code for `new_id`, if resident.
    #[inline]
    pub fn get(&self, new_id: u32) -> Option<&[u8]> {
        match &self.repr {
            Repr::Sparse { ids, codes } => {
                let i = ids.binary_search(&new_id).ok()?;
                Some(&codes[i * self.code_bytes..(i + 1) * self.code_bytes])
            }
            Repr::Dense { codes } => {
                let o = new_id.ix() * self.code_bytes;
                codes.get(o..o + self.code_bytes)
            }
        }
    }

    pub fn len(&self) -> usize {
        match &self.repr {
            Repr::Sparse { ids, .. } => ids.len(),
            Repr::Dense { codes } => codes.len() / self.code_bytes,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_dense(&self) -> bool {
        matches!(self.repr, Repr::Dense { .. })
    }

    pub fn memory_bytes(&self) -> usize {
        match &self.repr {
            Repr::Sparse { ids, codes } => ids.len() * 4 + codes.len(),
            Repr::Dense { codes } => codes.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::WriteExt;
    use std::io::Write;

    fn write_memcodes(dir: &Path, m: usize, entries: &[(u32, Vec<u8>)]) {
        let mut f = std::fs::File::create(dir.join("memcodes.bin")).unwrap();
        f.write_u32(m as u32).unwrap();
        f.write_u64(entries.len() as u64).unwrap();
        for (id, code) in entries {
            f.write_u32(*id).unwrap();
            f.write_all(code).unwrap();
        }
    }

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("pageann-mc-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn sparse_lookup() {
        let dir = tmpdir("sparse");
        write_memcodes(&dir, 2, &[(3, vec![1, 2]), (10, vec![3, 4]), (90, vec![5, 6])]);
        let mc = MemCodes::load(&dir, 1000).unwrap();
        assert!(!mc.is_dense());
        assert_eq!(mc.get(10), Some(&[3u8, 4][..]));
        assert_eq!(mc.get(11), None);
        assert_eq!(mc.get(90), Some(&[5u8, 6][..]));
        assert_eq!(mc.len(), 3);
        assert!(mc.memory_bytes() > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dense_promotion() {
        let dir = tmpdir("dense");
        let entries: Vec<(u32, Vec<u8>)> = (0..8).map(|i| (i, vec![i as u8; 2])).collect();
        write_memcodes(&dir, 2, &entries);
        let mc = MemCodes::load(&dir, 10).unwrap(); // 8/10 ≥ 50% → dense
        assert!(mc.is_dense());
        assert_eq!(mc.get(5), Some(&[5u8, 5][..]));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unsorted_rejected() {
        let dir = tmpdir("unsorted");
        write_memcodes(&dir, 2, &[(10, vec![0, 0]), (3, vec![0, 0])]);
        assert!(MemCodes::load(&dir, 100).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_is_empty() {
        let mc = MemCodes::empty(4);
        assert!(mc.is_empty());
        assert_eq!(mc.get(0), None);
    }
}
