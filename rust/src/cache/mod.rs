//! In-memory caches: warm-up-driven page cache and the resident
//! compressed-vector table (paper §4.3).

mod memcodes;
mod pagecache;

pub use memcodes::MemCodes;
pub use pagecache::PageCache;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_cache_prefers_hot_pages() {
        // Frequencies: page 3 hottest, then 1, then others.
        let freqs = vec![(3u32, 100u64), (1, 50), (0, 5), (2, 1)];
        let page_size = 128;
        let fetch = |ids: &[u32], out: &mut [Vec<u8>]| {
            for (k, &p) in ids.iter().enumerate() {
                out[k] = vec![p as u8; page_size];
            }
            Ok(vec![true; ids.len()])
        };
        // Budget for exactly two pages.
        let cache = PageCache::build(&freqs, page_size, 2 * page_size + 1, fetch).unwrap();
        assert!(cache.get(3).is_some());
        assert!(cache.get(1).is_some());
        assert!(cache.get(0).is_none());
        assert_eq!(cache.get(3).unwrap()[0], 3);
        assert_eq!(cache.n_pages(), 2);
        assert!(cache.memory_bytes() >= 2 * page_size);
    }

    #[test]
    fn page_cache_skips_unkept_pages() {
        // A page the fetcher can't read/verify must not be pinned — and
        // must not take down the rest of the build.
        let freqs = vec![(3u32, 100u64), (1, 50), (0, 5)];
        let page_size = 64;
        let fetch = |ids: &[u32], out: &mut [Vec<u8>]| {
            let mut keep = vec![true; ids.len()];
            for (k, &p) in ids.iter().enumerate() {
                out[k] = vec![p as u8; page_size];
                if p == 1 {
                    keep[k] = false; // "unreadable"
                }
            }
            Ok(keep)
        };
        let cache = PageCache::build(&freqs, page_size, 3 * page_size + 1, fetch).unwrap();
        assert!(cache.get(3).is_some());
        assert!(cache.get(1).is_none(), "failed page must not be cached");
        assert!(cache.get(0).is_some());
        assert_eq!(cache.n_pages(), 2);
    }
}
