//! Memory-disk coordination (paper §4.3): given a host-memory budget,
//! decide what lives in memory — routing index, compressed vectors, cached
//! pages — and therefore how the index is built (CV placement changes page
//! capacity and graph size).
//!
//! The three regimes of the paper:
//! 1. **severe** (budget ≪ code table): all codes on-page; memory only
//!    holds the tiny routing index.
//! 2. **moderate**: hybrid — the hottest codes move to memory.
//! 3. **ample** (budget ≥ code table): all codes in memory, pages fit more
//!    vectors (smaller graph), leftover budget pins hot pages.

use crate::layout::CvPlacement;

/// A concrete plan for one (dataset, budget) pair.
#[derive(Debug, Clone)]
pub struct MemoryPlan {
    pub budget_bytes: usize,
    pub cv_placement: CvPlacement,
    pub routing_bits: usize,
    pub routing_sample_frac: f64,
    /// Bytes left for the warm-up page cache after codes + routing.
    pub cache_budget_bytes: usize,
}

/// Summary of what a plan will consume (for experiment reporting).
#[derive(Debug, Clone)]
pub struct PlanEstimate {
    pub routing_bytes: usize,
    pub code_bytes: usize,
    pub cache_bytes: usize,
}

/// Derive the plan. `dataset_bytes` is the raw vector payload (the paper's
/// memory-ratio denominator); `n_vectors`, `dim`, `code_bytes` size the
/// tables. `code_bytes` is the *storage* width of one PQ code
/// (`pq::storage_bytes(m, k)` — `⌈m/2⌉` for a PQ4 build), so nibble-packed
/// indexes plan against their real footprint, not `m` bytes.
pub fn plan(
    budget_bytes: usize,
    n_vectors: usize,
    dim: usize,
    code_bytes: usize,
) -> MemoryPlan {
    let code_table = n_vectors * code_bytes;

    // Routing tier: scale the sample with the budget, floor at a token
    // sample (the paper's 0.05% configuration still routes).
    let (routing_bits, routing_sample_frac) = if budget_bytes < code_table / 4 {
        (32usize, 0.002f64)
    } else if budget_bytes < code_table * 2 {
        (32, 0.01)
    } else {
        (32, 0.02)
    };
    let routing_bytes = routing_cost(n_vectors, dim, code_bytes, routing_bits, routing_sample_frac);
    let after_routing = budget_bytes.saturating_sub(routing_bytes);

    // CV placement tiers (§4.3 / Fig. 11 inflection points).
    let cv_placement = if after_routing < (code_table as f64 * 0.35) as usize {
        CvPlacement::OnPage
    } else if after_routing < code_table {
        let mem_frac = (after_routing as f64 / code_table as f64 * 0.9).clamp(0.05, 0.95);
        CvPlacement::Hybrid { mem_frac }
    } else {
        CvPlacement::InMemory
    };

    let resident_code_bytes = (code_table as f64 * cv_placement.mem_frac()) as usize;
    let cache_budget_bytes = after_routing.saturating_sub(resident_code_bytes);

    MemoryPlan { budget_bytes, cv_placement, routing_bits, routing_sample_frac, cache_budget_bytes }
}

/// Rough memory cost of the routing tier: planes + buckets + pinned sample
/// codes (which write_memcodes adds on top of the CV placement).
/// `code_bytes` is the storage width of one code (see [`plan`]).
pub fn routing_cost(n_vectors: usize, dim: usize, code_bytes: usize, bits: usize, frac: f64) -> usize {
    let planes = bits * dim * 4;
    let sample = (n_vectors as f64 * frac) as usize;
    planes + sample * (4 + 4 + code_bytes) // bucket id + memcode id + code
}

impl MemoryPlan {
    /// `code_bytes` is the storage width of one code (see [`plan`]).
    pub fn estimate(&self, n_vectors: usize, dim: usize, code_bytes: usize) -> PlanEstimate {
        PlanEstimate {
            routing_bytes: routing_cost(n_vectors, dim, code_bytes, self.routing_bits, self.routing_sample_frac),
            code_bytes: (n_vectors as f64 * code_bytes as f64 * self.cv_placement.mem_frac()) as usize,
            cache_bytes: self.cache_budget_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const N: usize = 100_000;
    const DIM: usize = 128;
    const M: usize = 16;

    fn dataset_bytes() -> usize {
        N * DIM // u8 SIFT-like
    }

    #[test]
    fn severe_budget_keeps_codes_on_page() {
        // 0.05% of dataset — the paper's Table 4 headline point.
        let p = plan(dataset_bytes() / 2000, N, DIM, M);
        assert!(matches!(p.cv_placement, CvPlacement::OnPage), "{:?}", p.cv_placement);
        assert_eq!(p.cache_budget_bytes, 0);
    }

    #[test]
    fn moderate_budget_goes_hybrid() {
        // 10% of dataset ≈ 0.8 × code table for these params.
        let p = plan(dataset_bytes() / 10, N, DIM, M);
        match p.cv_placement {
            CvPlacement::Hybrid { mem_frac } => {
                assert!(mem_frac > 0.2 && mem_frac < 0.95, "{mem_frac}");
            }
            other => panic!("expected hybrid, got {other:?}"),
        }
    }

    #[test]
    fn ample_budget_goes_in_memory_with_cache() {
        // 30% of dataset ≫ code table.
        let p = plan(dataset_bytes() * 3 / 10, N, DIM, M);
        assert!(matches!(p.cv_placement, CvPlacement::InMemory));
        assert!(p.cache_budget_bytes > 0);
        let est = p.estimate(N, DIM, M);
        assert!(est.cache_bytes > 0 && est.code_bytes == N * M);
    }

    #[test]
    fn plan_is_monotone_in_budget() {
        let mut last_frac = -1.0;
        for ratio in [0.001, 0.01, 0.05, 0.1, 0.2, 0.3, 0.5] {
            let p = plan((dataset_bytes() as f64 * ratio) as usize, N, DIM, M);
            let frac = p.cv_placement.mem_frac();
            assert!(frac >= last_frac, "mem_frac not monotone at ratio {ratio}");
            last_frac = frac;
        }
    }

    #[test]
    fn estimate_fits_budget_approximately() {
        for ratio in [0.05, 0.1, 0.3] {
            let budget = (dataset_bytes() as f64 * ratio) as usize;
            let p = plan(budget, N, DIM, M);
            let est = p.estimate(N, DIM, M);
            let total = est.routing_bytes + est.code_bytes + est.cache_bytes;
            assert!(
                total <= budget + budget / 5,
                "plan overshoots at ratio {ratio}: {total} > {budget}"
            );
        }
    }
}
