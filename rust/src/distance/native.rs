//! Scalar (pure-rust) distance kernels and the batch-scanner trait.
//!
//! The kernels are 4-way unrolled scalar loops; rustc/LLVM auto-vectorizes
//! them to SSE/AVX on x86-64. They are the **correctness oracle** for the
//! explicit-SIMD kernels in [`super::simd`] and for the XLA backend. The
//! hot path goes through [`NativeBatch`], which calls the runtime-dispatched
//! kernel table; [`ScalarBatch`] pins the oracle for A/B runs.
#![deny(unsafe_op_in_unsafe_fn)]

/// Squared L2 between two f32 slices of equal length.
#[inline]
pub fn l2sq_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// Squared L2 between an f32 query and a u8 vector (SIFT-style).
#[inline]
pub fn l2sq_f32_u8(a: &[f32], b: &[u8]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j] as f32;
        let d1 = a[j + 1] - b[j + 1] as f32;
        let d2 = a[j + 2] - b[j + 2] as f32;
        let d3 = a[j + 3] - b[j + 3] as f32;
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        let d = a[j] - b[j] as f32;
        s += d * d;
    }
    s
}

/// Squared L2 between an f32 query and an i8 vector (SPACEV-style).
#[inline]
pub fn l2sq_f32_i8(a: &[f32], b: &[i8]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j] as f32;
        let d1 = a[j + 1] - b[j + 1] as f32;
        let d2 = a[j + 2] - b[j + 2] as f32;
        let d3 = a[j + 3] - b[j + 3] as f32;
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        let d = a[j] - b[j] as f32;
        s += d * d;
    }
    s
}

/// Squared norm of an f32 slice.
#[inline]
pub fn norm_sq_f32(a: &[f32]) -> f32 {
    let mut s = 0f32;
    for &x in a {
        s += x * x;
    }
    s
}

use crate::dataset::Dtype;

/// Batch scanner interface: distances from one query to a packed block of
/// vectors. Both the native and XLA backends implement this, so the search
/// engine is backend-agnostic.
pub trait BatchScanner: Send + Sync {
    /// Compute squared L2 from `query` (f32, dim d) to `n` vectors packed
    /// row-major in `block` with dtype `dtype`, writing into `out[..n]`.
    fn scan(&self, query: &[f32], block: &[u8], dtype: Dtype, n: usize, out: &mut [f32]);

    /// Backend name for logs/experiments.
    fn name(&self) -> &'static str;
}

/// The native batch scanner: rows scored with the runtime-dispatched SIMD
/// kernels (AVX2/NEON when available, scalar otherwise).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBatch;

/// Scan a packed row-major block with an explicit kernel table. The kernel
/// fn pointer is hoisted out of the row loop (one indirect target → fully
/// predicted).
#[inline]
fn scan_with(
    ks: &'static crate::distance::simd::Kernels,
    query: &[f32],
    block: &[u8],
    dtype: Dtype,
    n: usize,
    out: &mut [f32],
) {
    let d = query.len();
    let stride = d * dtype.size_bytes();
    debug_assert!(block.len() >= n * stride);
    match dtype {
        Dtype::U8 => {
            let f = ks.l2sq_f32_u8;
            for i in 0..n {
                out[i] = f(query, &block[i * stride..(i + 1) * stride]);
            }
        }
        Dtype::I8 => {
            let f = ks.l2sq_f32_i8;
            for i in 0..n {
                let bytes = &block[i * stride..(i + 1) * stride];
                // SAFETY: u8 and i8 share size/alignment, so reinterpreting
                // the borrowed byte slice in place is sound.
                let v = unsafe {
                    std::slice::from_raw_parts(bytes.as_ptr() as *const i8, bytes.len())
                };
                out[i] = f(query, v);
            }
        }
        Dtype::F32 => {
            // Page buffers slice f32 rows at odd byte offsets (5-byte
            // header), so go through the alignment-safe bytes kernel.
            let f = ks.l2sq_f32_bytes;
            for i in 0..n {
                out[i] = f(query, &block[i * stride..(i + 1) * stride]);
            }
        }
    }
}

impl BatchScanner for NativeBatch {
    fn scan(&self, query: &[f32], block: &[u8], dtype: Dtype, n: usize, out: &mut [f32]) {
        scan_with(crate::distance::simd::kernels(), query, block, dtype, n, out);
    }

    fn name(&self) -> &'static str {
        crate::distance::simd::kernels().isa
    }
}

/// The scalar-oracle batch scanner: identical semantics to [`NativeBatch`]
/// but pinned to the unrolled scalar kernels regardless of host ISA. Used
/// by the recall-parity checks and as the baseline in the hot-path benches.
#[derive(Debug, Default, Clone, Copy)]
pub struct ScalarBatch;

impl BatchScanner for ScalarBatch {
    fn scan(&self, query: &[f32], block: &[u8], dtype: Dtype, n: usize, out: &mut [f32]) {
        scan_with(crate::distance::simd::scalar_kernels(), query, block, dtype, n, out);
    }

    fn name(&self) -> &'static str {
        "scalar"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    fn naive_l2(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }

    #[test]
    fn f32_matches_naive_all_lengths() {
        let mut rng = XorShift::new(11);
        for n in [0usize, 1, 3, 4, 5, 7, 8, 96, 100, 128] {
            let a: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();
            let got = l2sq_f32(&a, &b);
            let want = naive_l2(&a, &b);
            assert!((got - want).abs() <= 1e-4 * want.max(1.0), "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn u8_matches_f32_path() {
        let mut rng = XorShift::new(12);
        for n in [1usize, 5, 128] {
            let q: Vec<f32> = (0..n).map(|_| rng.next_f32() * 255.0).collect();
            let v: Vec<u8> = (0..n).map(|_| rng.next_below(256) as u8).collect();
            let vf: Vec<f32> = v.iter().map(|&x| x as f32).collect();
            let got = l2sq_f32_u8(&q, &v);
            let want = l2sq_f32(&q, &vf);
            assert!((got - want).abs() <= 1e-3 * want.max(1.0));
        }
    }

    #[test]
    fn i8_matches_f32_path() {
        let mut rng = XorShift::new(13);
        for n in [1usize, 5, 100] {
            let q: Vec<f32> = (0..n).map(|_| rng.next_gaussian() * 50.0).collect();
            let v: Vec<i8> = (0..n).map(|_| (rng.next_below(256) as i16 - 128) as i8).collect();
            let vf: Vec<f32> = v.iter().map(|&x| x as f32).collect();
            let got = l2sq_f32_i8(&q, &v);
            let want = l2sq_f32(&q, &vf);
            assert!((got - want).abs() <= 1e-3 * want.max(1.0));
        }
    }

    #[test]
    fn norm_is_distance_to_zero() {
        let a = [3.0f32, 4.0];
        assert_eq!(norm_sq_f32(&a), 25.0);
        assert_eq!(l2sq_f32(&a, &[0.0, 0.0]), 25.0);
    }

    #[test]
    fn native_batch_scans_rows() {
        let q = [1.0f32, 0.0];
        // Two u8 vectors: (1,0) and (3,4).
        let block = [1u8, 0, 3, 4];
        let mut out = [0f32; 2];
        NativeBatch.scan(&q, &block, Dtype::U8, 2, &mut out);
        assert_eq!(out[0], 0.0);
        assert_eq!(out[1], 20.0);
    }
}
