//! Distance computation backends.
//!
//! Three implementations of the same batch-distance interface:
//!
//! * [`simd`] — explicit `std::arch` kernels (AVX2+FMA / NEON) selected once
//!   at startup by runtime CPU-feature dispatch. This is the default hot
//!   path: [`NativeBatch`] and the free functions below route through it.
//! * [`native`] — hand-unrolled scalar kernels per dtype (u8/i8/f32): the
//!   rust-layer correctness oracle, pinned by [`ScalarBatch`].
//! * [`xla_backend`] — executes the AOT-compiled Pallas/JAX page-scan
//!   artifact through PJRT. Used for large batch scans; the backend choice
//!   is an ablation (`paper_experiments ablC`).
//!
//! All distances are **squared Euclidean** (monotone in L2, so rankings are
//! identical and we skip the sqrt everywhere, like the reference systems).
#![deny(unsafe_op_in_unsafe_fn)]

mod native;
pub mod simd;
mod xla_backend;

pub use native::{BatchScanner, NativeBatch, ScalarBatch};
pub use simd::{kernels, scalar_kernels, Kernels};
pub use xla_backend::XlaBatch;

// Scalar oracle kernels, exported for tests/benches that pin the baseline.
pub use native::{
    l2sq_f32 as l2sq_f32_scalar, l2sq_f32_i8 as l2sq_f32_i8_scalar,
    l2sq_f32_u8 as l2sq_f32_u8_scalar, norm_sq_f32 as norm_sq_f32_scalar,
};

use crate::dataset::{Dtype, VectorView};

/// Squared L2 between two f32 slices of equal length (dispatched).
#[inline]
pub fn l2sq_f32(a: &[f32], b: &[f32]) -> f32 {
    (simd::kernels().l2sq_f32)(a, b)
}

/// Squared L2 between an f32 query and a u8 vector (dispatched).
#[inline]
pub fn l2sq_f32_u8(a: &[f32], b: &[u8]) -> f32 {
    (simd::kernels().l2sq_f32_u8)(a, b)
}

/// Squared L2 between an f32 query and an i8 vector (dispatched).
#[inline]
pub fn l2sq_f32_i8(a: &[f32], b: &[i8]) -> f32 {
    (simd::kernels().l2sq_f32_i8)(a, b)
}

/// Squared norm of an f32 slice (dispatched).
#[inline]
pub fn norm_sq_f32(a: &[f32]) -> f32 {
    (simd::kernels().norm_sq_f32)(a)
}

/// Squared L2 between an f32 query and a raw-dtype vector.
#[inline]
pub fn l2sq_query(query: &[f32], v: VectorView<'_>) -> f32 {
    let ks = simd::kernels();
    match v.dtype {
        // Page buffers slice f32 rows at unaligned byte offsets, so the
        // f32 arm reads little-endian bytes rather than casting the slice.
        Dtype::F32 => (ks.l2sq_f32_bytes)(query, v.bytes),
        Dtype::U8 => (ks.l2sq_f32_u8)(query, v.bytes),
        // SAFETY: u8 and i8 share size/alignment, so reinterpreting the
        // borrowed byte slice in place (same pointer, same length) is sound.
        Dtype::I8 => (ks.l2sq_f32_i8)(query, unsafe {
            std::slice::from_raw_parts(v.bytes.as_ptr() as *const i8, v.bytes.len())
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::Dtype;

    fn view(bytes: &[u8], dtype: Dtype) -> VectorView<'_> {
        VectorView { bytes, dtype }
    }

    #[test]
    fn l2sq_query_dispatch_f32() {
        let q = [1.0f32, 2.0, 3.0];
        let v = [1.5f32, 0.0, 3.0];
        let mut bytes = Vec::new();
        for x in v {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        let d = l2sq_query(&q, view(&bytes, Dtype::F32));
        assert!((d - (0.25 + 4.0)).abs() < 1e-6);
    }

    #[test]
    fn l2sq_query_dispatch_u8() {
        let q = [10.0f32, 0.0];
        let bytes = [8u8, 3u8];
        let d = l2sq_query(&q, view(&bytes, Dtype::U8));
        assert!((d - (4.0 + 9.0)).abs() < 1e-6);
    }

    #[test]
    fn l2sq_query_dispatch_i8() {
        let q = [0.0f32, 0.0];
        let bytes = [(-3i8) as u8, 4u8];
        let d = l2sq_query(&q, view(&bytes, Dtype::I8));
        assert!((d - 25.0).abs() < 1e-6);
    }
}
