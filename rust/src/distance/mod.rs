//! Distance computation backends.
//!
//! Two implementations of the same batch-distance interface:
//!
//! * [`native`] — hand-unrolled scalar kernels per dtype (u8/i8/f32). This is
//!   the rust-layer correctness oracle and the default hot-path backend for
//!   tiny batches where PJRT dispatch overhead dominates.
//! * [`xla_backend`] — executes the AOT-compiled Pallas/JAX page-scan
//!   artifact through PJRT. Used for large batch scans; the backend choice
//!   is an ablation (`paper_experiments ablC`).
//!
//! All distances are **squared Euclidean** (monotone in L2, so rankings are
//! identical and we skip the sqrt everywhere, like the reference systems).

mod native;
mod xla_backend;

pub use native::{l2sq_f32, l2sq_f32_i8, l2sq_f32_u8, norm_sq_f32, BatchScanner, NativeBatch};
pub use xla_backend::XlaBatch;

use crate::dataset::{Dtype, VectorView};

/// Squared L2 between an f32 query and a raw-dtype vector.
#[inline]
pub fn l2sq_query(query: &[f32], v: VectorView<'_>) -> f32 {
    match v.dtype {
        Dtype::F32 => l2sq_f32(query, bytemuck_f32(v.bytes)),
        Dtype::U8 => l2sq_f32_u8(query, v.bytes),
        Dtype::I8 => l2sq_f32_i8(query, unsafe {
            std::slice::from_raw_parts(v.bytes.as_ptr() as *const i8, v.bytes.len())
        }),
    }
}

/// Reinterpret little-endian raw bytes as f32. Callers guarantee alignment
/// by construction (vector sets allocate `Vec<u8>` and offsets are multiples
/// of 4 bytes for f32 data).
#[inline]
pub(crate) fn bytemuck_f32(bytes: &[u8]) -> &[f32] {
    debug_assert_eq!(bytes.len() % 4, 0);
    debug_assert_eq!(bytes.as_ptr() as usize % 4, 0, "unaligned f32 view");
    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const f32, bytes.len() / 4) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::Dtype;

    fn view(bytes: &[u8], dtype: Dtype) -> VectorView<'_> {
        VectorView { bytes, dtype }
    }

    #[test]
    fn l2sq_query_dispatch_f32() {
        let q = [1.0f32, 2.0, 3.0];
        let v = [1.5f32, 0.0, 3.0];
        let mut bytes = Vec::new();
        for x in v {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        let d = l2sq_query(&q, view(&bytes, Dtype::F32));
        assert!((d - (0.25 + 4.0)).abs() < 1e-6);
    }

    #[test]
    fn l2sq_query_dispatch_u8() {
        let q = [10.0f32, 0.0];
        let bytes = [8u8, 3u8];
        let d = l2sq_query(&q, view(&bytes, Dtype::U8));
        assert!((d - (4.0 + 9.0)).abs() < 1e-6);
    }

    #[test]
    fn l2sq_query_dispatch_i8() {
        let q = [0.0f32, 0.0];
        let bytes = [(-3i8) as u8, 4u8];
        let d = l2sq_query(&q, view(&bytes, Dtype::I8));
        assert!((d - 25.0).abs() < 1e-6);
    }
}
