//! XLA/PJRT batch-distance backend.
//!
//! Executes the AOT-compiled `l2_batch` artifact (Layer-1 Pallas kernel
//! wrapped by the Layer-2 JAX graph, lowered to HLO text by
//! `python/compile/aot.py`). Shapes are fixed at AOT time: `R` rows of
//! dimension `D`; shorter scans are zero-padded and the tail ignored.
//!
//! The backend decodes raw-dtype blocks into a reused f32 staging buffer —
//! the PJRT boundary takes f32 — so the only per-call allocations are inside
//! PJRT itself.

use super::native::BatchScanner;
use crate::dataset::{Dtype, VectorView};
use crate::runtime::{execute_f32, ArtifactSet, ExecPool, XlaRuntime};
use crate::Result;
use std::sync::Mutex;

pub struct XlaBatch {
    pool: ExecPool,
    /// Fixed row count the artifact was lowered with.
    rows: usize,
    dim: usize,
    /// Reused decode buffers, one per concurrent caller (sized lazily).
    staging: Mutex<Vec<Vec<f32>>>,
}

impl XlaBatch {
    /// Load the `l2_batch_d{dim}` artifact from `artifacts/` and compile
    /// `pool_size` executables.
    pub fn load(rt: &XlaRuntime, artifacts: &ArtifactSet, dim: usize, pool_size: usize) -> Result<Self> {
        let art = artifacts.get(&format!("l2_batch_d{dim}"))?;
        let rows = art.meta_usize("rows")?;
        anyhow::ensure!(art.meta_usize("dim")? == dim, "manifest dim mismatch");
        let pool = ExecPool::new(rt, &art.file, pool_size)?;
        Ok(Self { pool, rows, dim, staging: Mutex::new(Vec::new()) })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    fn take_staging(&self) -> Vec<f32> {
        self.staging
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_else(|| vec![0f32; self.rows * self.dim])
    }

    fn put_staging(&self, buf: Vec<f32>) {
        let mut g = self.staging.lock().unwrap();
        if g.len() < 64 {
            g.push(buf);
        }
    }

    /// Scan up to `rows` vectors; returns error if `n > rows` (callers split
    /// larger scans).
    fn scan_padded(&self, query: &[f32], block: &[u8], dtype: Dtype, n: usize, out: &mut [f32]) -> Result<()> {
        anyhow::ensure!(n <= self.rows, "batch {n} exceeds artifact rows {}", self.rows);
        anyhow::ensure!(query.len() == self.dim, "dim mismatch");
        let mut buf = self.take_staging();
        let stride = self.dim * dtype.size_bytes();
        for i in 0..n {
            let bytes = &block[i * stride..(i + 1) * stride];
            VectorView { bytes, dtype }.decode_into(&mut buf[i * self.dim..(i + 1) * self.dim]);
        }
        // Zero the padded tail so results there are finite (ignored anyway).
        for x in buf[n * self.dim..].iter_mut() {
            *x = 0.0;
        }
        let exe = self.pool.acquire();
        let dists = execute_f32(
            &exe,
            &[
                (query, &[self.dim as i64]),
                (&buf, &[self.rows as i64, self.dim as i64]),
            ],
        )?;
        drop(exe);
        out[..n].copy_from_slice(&dists[..n]);
        self.put_staging(buf);
        Ok(())
    }
}

impl BatchScanner for XlaBatch {
    fn scan(&self, query: &[f32], block: &[u8], dtype: Dtype, n: usize, out: &mut [f32]) {
        let stride = query.len() * dtype.size_bytes();
        let mut done = 0usize;
        while done < n {
            let take = (n - done).min(self.rows);
            self.scan_padded(
                query,
                &block[done * stride..],
                dtype,
                take,
                &mut out[done..done + take],
            )
            .expect("xla batch scan failed");
            done += take;
        }
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
