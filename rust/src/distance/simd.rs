//! Explicit-SIMD distance/ADC kernels with one-time runtime dispatch.
//!
//! # Dispatch contract
//!
//! [`kernels()`] returns a `&'static Kernels` — a table of plain function
//! pointers selected **once** per process (first call, `OnceLock`) by CPU
//! feature detection:
//!
//! * x86-64 with AVX2+FMA → 256-bit kernels (`isa = "avx2"`), including a
//!   gather-based batched ADC.
//! * aarch64 → NEON kernels (`isa = "neon"`; NEON is part of the aarch64
//!   baseline, so no detection is needed). The batched ADC stays scalar —
//!   NEON has no gather, and the table walk is load-bound either way.
//! * anything else → the unrolled scalar kernels from
//!   [`super::native`] (`isa = "scalar"`), which double as the
//!   correctness oracle for every SIMD path.
//!
//! `PAGEANN_SIMD=scalar` forces the scalar table (A/B runs, debugging);
//! `PAGEANN_SIMD=avx2|neon` requests an ISA and silently falls back to
//! scalar when the host cannot run it, so a forced value can never fault.
//!
//! Every kernel tolerates **unaligned** inputs (`loadu` / byte loads): page
//! buffers slice vectors at odd offsets (5-byte header + 4·n id table), so
//! alignment is a property callers cannot promise. All kernels follow the
//! same contract as the scalar oracle: equal-length inputs, squared-L2
//! semantics, and ≤1e-4 relative divergence (FMA contraction) — asserted by
//! `tests/simd_kernels.rs` across dims, dtypes and offsets.
//!
//! The ADC kernel signature is shaped for [`crate::pq::AdcLut`]: a flat
//! `m × k` f32 table (row stride `k`), row-major `n × m` code bytes, and an
//! `out[..n]` distance buffer. Code values are always `< k` by construction
//! (PQ encoding), which is what makes the unchecked gather sound.

use super::native;
use std::sync::OnceLock;

/// Largest PQ subspace count the batched ADC kernels support; wider codes
/// fall back to the scalar row loop. Matches the memcodes format bound.
pub const ADC_MAX_M: usize = 64;

/// The dispatched kernel table. All members are plain `fn` pointers so the
/// indirect call is branch-predictor friendly and `Send + Sync` for free.
pub struct Kernels {
    /// Which implementation was selected ("avx2", "neon", "scalar").
    pub isa: &'static str,
    /// Squared L2 between two f32 slices of equal length.
    pub l2sq_f32: fn(&[f32], &[f32]) -> f32,
    /// Squared L2 between an f32 query and little-endian f32 bytes
    /// (`b.len() == 4 * a.len()`, any alignment — the page-scan case).
    pub l2sq_f32_bytes: fn(&[f32], &[u8]) -> f32,
    /// Squared L2 between an f32 query and a u8 vector.
    pub l2sq_f32_u8: fn(&[f32], &[u8]) -> f32,
    /// Squared L2 between an f32 query and an i8 vector.
    pub l2sq_f32_i8: fn(&[f32], &[i8]) -> f32,
    /// Squared norm of an f32 slice.
    pub norm_sq_f32: fn(&[f32]) -> f32,
    /// Batched ADC: `out[i] = Σ_s table[s*k + codes[i*m + s]]` for
    /// `i in 0..n`. `table` is `m × k` row-major; codes are `n × m`.
    pub adc_batch: fn(table: &[f32], m: usize, k: usize, codes: &[u8], n: usize, out: &mut [f32]),
}

/// The process-wide kernel table (selected once, then immutable).
#[inline]
pub fn kernels() -> &'static Kernels {
    static SELECTED: OnceLock<&'static Kernels> = OnceLock::new();
    *SELECTED.get_or_init(select)
}

/// The scalar kernel table — the correctness oracle, always available.
pub fn scalar_kernels() -> &'static Kernels {
    &SCALAR
}

fn select() -> &'static Kernels {
    let forced = std::env::var("PAGEANN_SIMD").ok();
    if forced.as_deref() == Some("scalar") {
        return &SCALAR;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if forced.as_deref().map(|f| f == "avx2").unwrap_or(true)
            && is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
        {
            return &AVX2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if forced.as_deref().map(|f| f == "neon").unwrap_or(true) {
            return &NEON;
        }
    }
    &SCALAR
}

// ---- scalar fallback ----------------------------------------------------

static SCALAR: Kernels = Kernels {
    isa: "scalar",
    l2sq_f32: native::l2sq_f32,
    l2sq_f32_bytes: scalar_l2sq_f32_bytes,
    l2sq_f32_u8: native::l2sq_f32_u8,
    l2sq_f32_i8: native::l2sq_f32_i8,
    norm_sq_f32: native::norm_sq_f32,
    adc_batch: scalar_adc_batch,
};

/// Scalar oracle for the bytes-as-f32 kernel (alignment-safe by reading
/// each element with `from_le_bytes`).
pub fn scalar_l2sq_f32_bytes(a: &[f32], b: &[u8]) -> f32 {
    debug_assert_eq!(a.len() * 4, b.len());
    let mut s = 0f32;
    for (x, c) in a.iter().zip(b.chunks_exact(4)) {
        let y = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        let d = x - y;
        s += d * d;
    }
    s
}

/// Scalar oracle for the batched ADC: 4-way unrolled over subspaces with a
/// strength-reduced table offset (no `sub * k` multiply per byte).
pub fn scalar_adc_batch(table: &[f32], m: usize, k: usize, codes: &[u8], n: usize, out: &mut [f32]) {
    debug_assert!(codes.len() >= n * m);
    debug_assert!(out.len() >= n);
    debug_assert_eq!(table.len(), m * k);
    for i in 0..n {
        let code = &codes[i * m..(i + 1) * m];
        let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
        let mut base = 0usize;
        let pairs = m / 4;
        for j in 0..pairs {
            let c = &code[j * 4..j * 4 + 4];
            s0 += table[base + c[0] as usize];
            s1 += table[base + k + c[1] as usize];
            s2 += table[base + 2 * k + c[2] as usize];
            s3 += table[base + 3 * k + c[3] as usize];
            base += 4 * k;
        }
        let mut s = (s0 + s1) + (s2 + s3);
        for &c in &code[pairs * 4..] {
            s += table[base + c as usize];
            base += k;
        }
        out[i] = s;
    }
}

// ---- AVX2 + FMA ---------------------------------------------------------

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    isa: "avx2",
    l2sq_f32: avx2::l2sq_f32,
    l2sq_f32_bytes: avx2::l2sq_f32_bytes,
    l2sq_f32_u8: avx2::l2sq_f32_u8,
    l2sq_f32_i8: avx2::l2sq_f32_i8,
    norm_sq_f32: avx2::norm_sq_f32,
    adc_batch: avx2::adc_batch,
};

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2+FMA kernels. The safe wrappers are only ever reachable through
    //! [`super::select`], which verifies `avx2 && fma` first — that is the
    //! safety argument for every `unsafe` block below.
    use super::ADC_MAX_M;
    use std::arch::x86_64::*;

    /// Sum the 8 lanes of an AVX register.
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps::<1>(s, s));
        _mm_cvtss_f32(s)
    }

    pub fn l2sq_f32(a: &[f32], b: &[f32]) -> f32 {
        // Hard assert: the unsafe body does unchecked loads, so a length
        // mismatch must panic (not UB) even in release builds.
        assert_eq!(a.len(), b.len());
        unsafe { l2sq_f32_imp(a, b) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn l2sq_f32_imp(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            let d0 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            let d1 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i + 8)), _mm256_loadu_ps(pb.add(i + 8)));
            acc0 = _mm256_fmadd_ps(d0, d0, acc0);
            acc1 = _mm256_fmadd_ps(d1, d1, acc1);
            i += 16;
        }
        if i + 8 <= n {
            let d = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            acc0 = _mm256_fmadd_ps(d, d, acc0);
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            let d = *a.get_unchecked(i) - *b.get_unchecked(i);
            s += d * d;
            i += 1;
        }
        s
    }

    pub fn l2sq_f32_bytes(a: &[f32], b: &[u8]) -> f32 {
        assert_eq!(a.len() * 4, b.len());
        unsafe { l2sq_f32_bytes_imp(a, b) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn l2sq_f32_bytes_imp(a: &[f32], b: &[u8]) -> f32 {
        // x86 is little-endian, so the raw bytes ARE the f32 payload;
        // `loadu` has no alignment requirement.
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            let d0 = _mm256_sub_ps(
                _mm256_loadu_ps(pa.add(i)),
                _mm256_loadu_ps(pb.add(i * 4) as *const f32),
            );
            let d1 = _mm256_sub_ps(
                _mm256_loadu_ps(pa.add(i + 8)),
                _mm256_loadu_ps(pb.add((i + 8) * 4) as *const f32),
            );
            acc0 = _mm256_fmadd_ps(d0, d0, acc0);
            acc1 = _mm256_fmadd_ps(d1, d1, acc1);
            i += 16;
        }
        if i + 8 <= n {
            let d = _mm256_sub_ps(
                _mm256_loadu_ps(pa.add(i)),
                _mm256_loadu_ps(pb.add(i * 4) as *const f32),
            );
            acc0 = _mm256_fmadd_ps(d, d, acc0);
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            let y = (pb.add(i * 4) as *const f32).read_unaligned();
            let d = *a.get_unchecked(i) - y;
            s += d * d;
            i += 1;
        }
        s
    }

    pub fn l2sq_f32_u8(a: &[f32], b: &[u8]) -> f32 {
        assert_eq!(a.len(), b.len());
        unsafe { l2sq_f32_u8_imp(a, b) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn l2sq_f32_u8_imp(a: &[f32], b: &[u8]) -> f32 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            let bytes = _mm_loadu_si128(pb.add(i) as *const __m128i);
            let lo = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(bytes));
            let hi = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_srli_si128::<8>(bytes)));
            let d0 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), lo);
            let d1 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i + 8)), hi);
            acc0 = _mm256_fmadd_ps(d0, d0, acc0);
            acc1 = _mm256_fmadd_ps(d1, d1, acc1);
            i += 16;
        }
        if i + 8 <= n {
            let bytes = _mm_loadl_epi64(pb.add(i) as *const __m128i);
            let v = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(bytes));
            let d = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), v);
            acc0 = _mm256_fmadd_ps(d, d, acc0);
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            let d = *a.get_unchecked(i) - *b.get_unchecked(i) as f32;
            s += d * d;
            i += 1;
        }
        s
    }

    pub fn l2sq_f32_i8(a: &[f32], b: &[i8]) -> f32 {
        assert_eq!(a.len(), b.len());
        unsafe { l2sq_f32_i8_imp(a, b) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn l2sq_f32_i8_imp(a: &[f32], b: &[i8]) -> f32 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            let bytes = _mm_loadu_si128(pb.add(i) as *const __m128i);
            let lo = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(bytes));
            let hi = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_srli_si128::<8>(bytes)));
            let d0 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), lo);
            let d1 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i + 8)), hi);
            acc0 = _mm256_fmadd_ps(d0, d0, acc0);
            acc1 = _mm256_fmadd_ps(d1, d1, acc1);
            i += 16;
        }
        if i + 8 <= n {
            let bytes = _mm_loadl_epi64(pb.add(i) as *const __m128i);
            let v = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(bytes));
            let d = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), v);
            acc0 = _mm256_fmadd_ps(d, d, acc0);
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            let d = *a.get_unchecked(i) - *b.get_unchecked(i) as f32;
            s += d * d;
            i += 1;
        }
        s
    }

    pub fn norm_sq_f32(a: &[f32]) -> f32 {
        unsafe { norm_sq_f32_imp(a) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn norm_sq_f32_imp(a: &[f32]) -> f32 {
        let n = a.len();
        let pa = a.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(pa.add(i));
            acc = _mm256_fmadd_ps(v, v, acc);
            i += 8;
        }
        let mut s = hsum(acc);
        while i < n {
            let x = *a.get_unchecked(i);
            s += x * x;
            i += 1;
        }
        s
    }

    pub fn adc_batch(table: &[f32], m: usize, k: usize, codes: &[u8], n: usize, out: &mut [f32]) {
        // Hard asserts: the unsafe body gathers/stores unchecked.
        assert!(codes.len() >= n * m);
        assert!(out.len() >= n);
        assert_eq!(table.len(), m * k);
        if m == 0 || m > ADC_MAX_M || k == 0 {
            return super::scalar_adc_batch(table, m, k, codes, n, out);
        }
        unsafe { adc_batch_imp(table, m, k, codes, n, out) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn adc_batch_imp(
        table: &[f32],
        m: usize,
        k: usize,
        codes: &[u8],
        n: usize,
        out: &mut [f32],
    ) {
        // 8 codes per iteration: transpose their bytes to subspace-major so
        // each subspace contributes one 8-wide gather into its table row.
        let mut tmp = [0u8; 8 * ADC_MAX_M];
        // Valid code values are < k (PQ encoding), but codes come from
        // on-disk pages/memcodes — clamp so a corrupt byte yields a wrong
        // distance instead of an out-of-bounds gather (the scalar path
        // bounds-checks; this is the SIMD equivalent of that guarantee).
        let max_idx = _mm256_set1_epi32((k - 1) as i32);
        let mut i = 0usize;
        while i + 8 <= n {
            for r in 0..8 {
                let row = codes.as_ptr().add((i + r) * m);
                for s in 0..m {
                    *tmp.get_unchecked_mut(s * 8 + r) = *row.add(s);
                }
            }
            let mut acc = _mm256_setzero_ps();
            let mut base = table.as_ptr();
            for s in 0..m {
                let idx =
                    _mm256_cvtepu8_epi32(_mm_loadl_epi64(tmp.as_ptr().add(s * 8) as *const __m128i));
                let idx = _mm256_min_epi32(idx, max_idx);
                acc = _mm256_add_ps(acc, _mm256_i32gather_ps::<4>(base, idx));
                base = base.add(k);
            }
            _mm256_storeu_ps(out.as_mut_ptr().add(i), acc);
            i += 8;
        }
        if i < n {
            super::scalar_adc_batch(table, m, k, &codes[i * m..], n - i, &mut out[i..]);
        }
    }
}

// ---- NEON ---------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    isa: "neon",
    l2sq_f32: neon::l2sq_f32,
    l2sq_f32_bytes: neon::l2sq_f32_bytes,
    l2sq_f32_u8: neon::l2sq_f32_u8,
    l2sq_f32_i8: neon::l2sq_f32_i8,
    norm_sq_f32: neon::norm_sq_f32,
    // No NEON gather; the unrolled scalar table walk is already load-bound.
    adc_batch: scalar_adc_batch,
};

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON kernels. NEON is part of the aarch64 baseline target features,
    //! so the intrinsics are unconditionally available.
    use std::arch::aarch64::*;

    pub fn l2sq_f32(a: &[f32], b: &[f32]) -> f32 {
        // Hard assert: the unsafe body does unchecked loads, so a length
        // mismatch must panic (not UB) even in release builds.
        assert_eq!(a.len(), b.len());
        unsafe {
            let n = a.len();
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            let mut i = 0usize;
            while i + 8 <= n {
                let d0 = vsubq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
                let d1 = vsubq_f32(vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
                acc0 = vfmaq_f32(acc0, d0, d0);
                acc1 = vfmaq_f32(acc1, d1, d1);
                i += 8;
            }
            if i + 4 <= n {
                let d = vsubq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
                acc0 = vfmaq_f32(acc0, d, d);
                i += 4;
            }
            let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
            while i < n {
                let d = *a.get_unchecked(i) - *b.get_unchecked(i);
                s += d * d;
                i += 1;
            }
            s
        }
    }

    pub fn l2sq_f32_bytes(a: &[f32], b: &[u8]) -> f32 {
        assert_eq!(a.len() * 4, b.len());
        unsafe {
            // Byte loads have alignment 1; reinterpret to f32 lanes (LE).
            let n = a.len();
            let pa = a.as_ptr();
            let pb = b.as_ptr();
            let mut acc = vdupq_n_f32(0.0);
            let mut i = 0usize;
            while i + 4 <= n {
                let v = vreinterpretq_f32_u8(vld1q_u8(pb.add(i * 4)));
                let d = vsubq_f32(vld1q_f32(pa.add(i)), v);
                acc = vfmaq_f32(acc, d, d);
                i += 4;
            }
            let mut s = vaddvq_f32(acc);
            while i < n {
                let y = (pb.add(i * 4) as *const f32).read_unaligned();
                let d = *a.get_unchecked(i) - y;
                s += d * d;
                i += 1;
            }
            s
        }
    }

    pub fn l2sq_f32_u8(a: &[f32], b: &[u8]) -> f32 {
        assert_eq!(a.len(), b.len());
        unsafe {
            let n = a.len();
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            let mut i = 0usize;
            while i + 8 <= n {
                let wide = vmovl_u8(vld1_u8(pb.add(i)));
                let lo = vcvtq_f32_u32(vmovl_u16(vget_low_u16(wide)));
                let hi = vcvtq_f32_u32(vmovl_u16(vget_high_u16(wide)));
                let d0 = vsubq_f32(vld1q_f32(pa.add(i)), lo);
                let d1 = vsubq_f32(vld1q_f32(pa.add(i + 4)), hi);
                acc0 = vfmaq_f32(acc0, d0, d0);
                acc1 = vfmaq_f32(acc1, d1, d1);
                i += 8;
            }
            let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
            while i < n {
                let d = *a.get_unchecked(i) - *b.get_unchecked(i) as f32;
                s += d * d;
                i += 1;
            }
            s
        }
    }

    pub fn l2sq_f32_i8(a: &[f32], b: &[i8]) -> f32 {
        assert_eq!(a.len(), b.len());
        unsafe {
            let n = a.len();
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            let mut i = 0usize;
            while i + 8 <= n {
                let wide = vmovl_s8(vld1_s8(pb.add(i)));
                let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(wide)));
                let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(wide)));
                let d0 = vsubq_f32(vld1q_f32(pa.add(i)), lo);
                let d1 = vsubq_f32(vld1q_f32(pa.add(i + 4)), hi);
                acc0 = vfmaq_f32(acc0, d0, d0);
                acc1 = vfmaq_f32(acc1, d1, d1);
                i += 8;
            }
            let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
            while i < n {
                let d = *a.get_unchecked(i) - *b.get_unchecked(i) as f32;
                s += d * d;
                i += 1;
            }
            s
        }
    }

    pub fn norm_sq_f32(a: &[f32]) -> f32 {
        unsafe {
            let n = a.len();
            let pa = a.as_ptr();
            let mut acc = vdupq_n_f32(0.0);
            let mut i = 0usize;
            while i + 4 <= n {
                let v = vld1q_f32(pa.add(i));
                acc = vfmaq_f32(acc, v, v);
                i += 4;
            }
            let mut s = vaddvq_f32(acc);
            while i < n {
                let x = *a.get_unchecked(i);
                s += x * x;
                i += 1;
            }
            s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn dispatch_is_stable_and_named() {
        let k1 = kernels();
        let k2 = kernels();
        assert!(std::ptr::eq(k1, k2), "dispatch must select once");
        assert!(["avx2", "neon", "scalar"].contains(&k1.isa));
        assert_eq!(scalar_kernels().isa, "scalar");
    }

    #[test]
    fn dispatched_matches_scalar_spot() {
        // The exhaustive property sweep lives in tests/simd_kernels.rs;
        // this is a fast in-crate smoke check.
        let mut rng = XorShift::new(42);
        let n = 128;
        let a: Vec<f32> = (0..n).map(|_| rng.next_gaussian() * 10.0).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.next_gaussian() * 10.0).collect();
        let got = (kernels().l2sq_f32)(&a, &b);
        let want = (scalar_kernels().l2sq_f32)(&a, &b);
        assert!((got - want).abs() <= 1e-4 * want.max(1.0), "{got} vs {want}");
    }

    #[test]
    fn adc_batch_matches_scalar() {
        let mut rng = XorShift::new(7);
        let (m, k, n) = (16usize, 256usize, 37usize);
        let table: Vec<f32> = (0..m * k).map(|_| rng.next_f32() * 100.0).collect();
        let codes: Vec<u8> = (0..n * m).map(|_| rng.next_below(k) as u8).collect();
        let mut got = vec![0f32; n];
        let mut want = vec![0f32; n];
        (kernels().adc_batch)(&table, m, k, &codes, n, &mut got);
        scalar_adc_batch(&table, m, k, &codes, n, &mut want);
        for i in 0..n {
            assert!((got[i] - want[i]).abs() <= 1e-4 * want[i].max(1.0), "row {i}");
        }
    }
}
