//! Explicit-SIMD distance/ADC kernels with one-time runtime dispatch.
//!
//! # Dispatch contract
//!
//! [`kernels()`] returns a `&'static Kernels` — a table of plain function
//! pointers selected **once** per process (first call, `OnceLock`) by CPU
//! feature detection:
//!
//! * x86-64 with AVX2+FMA → 256-bit kernels (`isa = "avx2"`), including a
//!   gather-based batched ADC.
//! * aarch64 → NEON kernels (`isa = "neon"`; NEON is part of the aarch64
//!   baseline, so no detection is needed). The batched ADC stays scalar —
//!   NEON has no gather, and the table walk is load-bound either way.
//! * anything else → the unrolled scalar kernels from
//!   [`super::native`] (`isa = "scalar"`), which double as the
//!   correctness oracle for every SIMD path.
//!
//! `PAGEANN_SIMD=scalar` forces the scalar table (A/B runs, debugging);
//! `PAGEANN_SIMD=avx2|neon` requests an ISA and silently falls back to
//! scalar when the host cannot run it, so a forced value can never fault.
//!
//! Every kernel tolerates **unaligned** inputs (`loadu` / byte loads): page
//! buffers slice vectors at odd offsets (5-byte header + 4·n id table), so
//! alignment is a property callers cannot promise. All kernels follow the
//! same contract as the scalar oracle: equal-length inputs, squared-L2
//! semantics, and ≤1e-4 relative divergence (FMA contraction) — asserted by
//! `tests/simd_kernels.rs` across dims, dtypes and offsets.
//!
//! The ADC kernel signature is shaped for [`crate::pq::AdcLut`]: a flat
//! `m × k` f32 table (row stride `k`), row-major `n × m` code bytes, and an
//! `out[..n]` distance buffer. Code values are always `< k` by construction
//! (PQ encoding), which is what makes the unchecked gather sound.
//!
//! # PQ4 fast-scan (`adc4_batch`)
//!
//! When `k ≤ 16` an entire u8-quantized LUT row fits one 128-bit register,
//! so ADC needs no gather at all: the nibble codes become shuffle indices
//! (`pshufb` on x86, `tbl` on aarch64) and 16 codes are scored per shuffle
//! — the FAISS fast-scan trick. The quantized table is `m × 16` u8 rows
//! (built per query by [`crate::pq::AdcLut`]): per-subspace minimum folded
//! into a single `bias`, one shared `scale = max row range / 255`. The
//! kernel contract is **bit-exact** with [`scalar_adc4_batch`]: the nibble
//! sums are exact integers (≤ 64·255 < 2¹⁶) and both paths dequantize with
//! the same unfused `sum as f32 * scale + bias`, so tests assert `to_bits`
//! equality rather than a tolerance. Codes are nibble-packed, subspace `s`
//! in byte `s/2`, even `s` in the low nibble; any corrupt nibble still
//! lands inside the 16-byte row, so the shuffle is memory-safe by
//! construction.
#![deny(unsafe_op_in_unsafe_fn)]

use super::native;
use std::sync::OnceLock;

/// Largest PQ subspace count the batched ADC kernels support; wider codes
/// fall back to the scalar row loop. Matches the memcodes format bound.
pub const ADC_MAX_M: usize = 64;

/// The dispatched kernel table. All members are plain `fn` pointers so the
/// indirect call is branch-predictor friendly and `Send + Sync` for free.
pub struct Kernels {
    /// Which implementation was selected ("avx2", "neon", "scalar").
    pub isa: &'static str,
    /// Which implementation `adc_batch` actually runs — NEON has no gather,
    /// so its table routes adc8 to the scalar walk. Benches label rows from
    /// this rather than comparing function pointers (whose equality rustc
    /// does not guarantee to be meaningful).
    pub adc_isa: &'static str,
    /// Which implementation `adc4_batch` actually runs.
    pub adc4_isa: &'static str,
    /// Squared L2 between two f32 slices of equal length.
    pub l2sq_f32: fn(&[f32], &[f32]) -> f32,
    /// Squared L2 between an f32 query and little-endian f32 bytes
    /// (`b.len() == 4 * a.len()`, any alignment — the page-scan case).
    pub l2sq_f32_bytes: fn(&[f32], &[u8]) -> f32,
    /// Squared L2 between an f32 query and a u8 vector.
    pub l2sq_f32_u8: fn(&[f32], &[u8]) -> f32,
    /// Squared L2 between an f32 query and an i8 vector.
    pub l2sq_f32_i8: fn(&[f32], &[i8]) -> f32,
    /// Squared norm of an f32 slice.
    pub norm_sq_f32: fn(&[f32]) -> f32,
    /// Batched ADC: `out[i] = Σ_s table[s*k + codes[i*m + s]]` for
    /// `i in 0..n`. `table` is `m × k` row-major; codes are `n × m`.
    pub adc_batch: fn(table: &[f32], m: usize, k: usize, codes: &[u8], n: usize, out: &mut [f32]),
    /// Batched PQ4 fast-scan ADC over nibble-packed codes:
    /// `out[i] = (Σ_s qtable[s*16 + nib(i, s)]) as f32 * scale + bias`,
    /// where `qtable` is `m × 16` u8-quantized rows and codes are
    /// `n × ceil(m/2)` bytes (subspace `s` in byte `s/2`, even `s` in the
    /// low nibble). Bit-exact with [`scalar_adc4_batch`].
    pub adc4_batch:
        fn(qtable: &[u8], m: usize, codes: &[u8], n: usize, scale: f32, bias: f32, out: &mut [f32]),
}

/// The process-wide kernel table (selected once, then immutable).
#[inline]
pub fn kernels() -> &'static Kernels {
    static SELECTED: OnceLock<&'static Kernels> = OnceLock::new();
    *SELECTED.get_or_init(select)
}

/// The scalar kernel table — the correctness oracle, always available.
pub fn scalar_kernels() -> &'static Kernels {
    &SCALAR
}

fn select() -> &'static Kernels {
    let forced = std::env::var("PAGEANN_SIMD").ok();
    if forced.as_deref() == Some("scalar") {
        return &SCALAR;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if forced.as_deref().map(|f| f == "avx2").unwrap_or(true)
            && is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
        {
            return &AVX2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if forced.as_deref().map(|f| f == "neon").unwrap_or(true) {
            return &NEON;
        }
    }
    &SCALAR
}

// ---- scalar fallback ----------------------------------------------------

static SCALAR: Kernels = Kernels {
    isa: "scalar",
    adc_isa: "scalar",
    adc4_isa: "scalar",
    l2sq_f32: native::l2sq_f32,
    l2sq_f32_bytes: scalar_l2sq_f32_bytes,
    l2sq_f32_u8: native::l2sq_f32_u8,
    l2sq_f32_i8: native::l2sq_f32_i8,
    norm_sq_f32: native::norm_sq_f32,
    adc_batch: scalar_adc_batch,
    adc4_batch: scalar_adc4_batch,
};

/// Scalar oracle for the bytes-as-f32 kernel (alignment-safe by reading
/// each element with `from_le_bytes`).
pub fn scalar_l2sq_f32_bytes(a: &[f32], b: &[u8]) -> f32 {
    debug_assert_eq!(a.len() * 4, b.len());
    let mut s = 0f32;
    for (x, c) in a.iter().zip(b.chunks_exact(4)) {
        let y = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        let d = x - y;
        s += d * d;
    }
    s
}

/// Scalar oracle for the batched ADC: 4-way unrolled over subspaces with a
/// strength-reduced table offset (no `sub * k` multiply per byte).
pub fn scalar_adc_batch(table: &[f32], m: usize, k: usize, codes: &[u8], n: usize, out: &mut [f32]) {
    debug_assert!(codes.len() >= n * m);
    debug_assert!(out.len() >= n);
    debug_assert_eq!(table.len(), m * k);
    for i in 0..n {
        let code = &codes[i * m..(i + 1) * m];
        let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
        let mut base = 0usize;
        let pairs = m / 4;
        for j in 0..pairs {
            let c = &code[j * 4..j * 4 + 4];
            s0 += table[base + c[0] as usize];
            s1 += table[base + k + c[1] as usize];
            s2 += table[base + 2 * k + c[2] as usize];
            s3 += table[base + 3 * k + c[3] as usize];
            base += 4 * k;
        }
        let mut s = (s0 + s1) + (s2 + s3);
        for &c in &code[pairs * 4..] {
            s += table[base + c as usize];
            base += k;
        }
        out[i] = s;
    }
}

/// Scalar oracle for the PQ4 fast-scan ADC (and the reference the SIMD
/// kernels must match **bit-for-bit**): exact integer nibble sums, then one
/// unfused `sum * scale + bias` dequant per code.
pub fn scalar_adc4_batch(
    qtable: &[u8],
    m: usize,
    codes: &[u8],
    n: usize,
    scale: f32,
    bias: f32,
    out: &mut [f32],
) {
    let cw = (m + 1) / 2;
    debug_assert!(codes.len() >= n * cw);
    debug_assert!(out.len() >= n);
    debug_assert_eq!(qtable.len(), m * 16);
    for i in 0..n {
        let code = &codes[i * cw..(i + 1) * cw];
        let mut sum = 0u32;
        let mut row = 0usize;
        for s in 0..m {
            let b = code[s / 2];
            let nib = (if s % 2 == 0 { b & 0x0f } else { b >> 4 }) as usize;
            sum += qtable[row + nib] as u32;
            row += 16;
        }
        out[i] = sum as f32 * scale + bias;
    }
}

// ---- AVX2 + FMA ---------------------------------------------------------

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    isa: "avx2",
    adc_isa: "avx2",
    adc4_isa: "avx2",
    l2sq_f32: avx2::l2sq_f32,
    l2sq_f32_bytes: avx2::l2sq_f32_bytes,
    l2sq_f32_u8: avx2::l2sq_f32_u8,
    l2sq_f32_i8: avx2::l2sq_f32_i8,
    norm_sq_f32: avx2::norm_sq_f32,
    adc_batch: avx2::adc_batch,
    adc4_batch: avx2::adc4_batch,
};

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2+FMA kernels. The safe wrappers are only ever reachable through
    //! [`super::select`], which verifies `avx2 && fma` first — that is the
    //! safety argument for every `unsafe` block below.
    use super::ADC_MAX_M;
    use std::arch::x86_64::*;

    /// Sum the 8 lanes of an AVX register.
    ///
    /// # Safety
    /// The caller must have verified avx2+fma support (dispatch contract).
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        // SAFETY: register-only intrinsics; the target features are enabled
        // on this fn and verified by the dispatcher.
        unsafe {
            let lo = _mm256_castps256_ps128(v);
            let hi = _mm256_extractf128_ps::<1>(v);
            let s = _mm_add_ps(lo, hi);
            let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
            let s = _mm_add_ss(s, _mm_shuffle_ps::<1>(s, s));
            _mm_cvtss_f32(s)
        }
    }

    pub fn l2sq_f32(a: &[f32], b: &[f32]) -> f32 {
        // Hard assert: the unsafe body does unchecked loads, so a length
        // mismatch must panic (not UB) even in release builds.
        assert_eq!(a.len(), b.len());
        // SAFETY: lengths are equal (asserted above) and this table is only
        // reachable after the dispatcher verified avx2+fma.
        unsafe { l2sq_f32_imp(a, b) }
    }

    /// # Safety
    /// Requires `a.len() == b.len()` and verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn l2sq_f32_imp(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: every load/get_unchecked stays below n = a.len() = b.len()
        // (caller contract); unaligned loads are used throughout.
        unsafe {
            let n = a.len();
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            let mut i = 0usize;
            while i + 16 <= n {
                let d0 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
                let d1 =
                    _mm256_sub_ps(_mm256_loadu_ps(pa.add(i + 8)), _mm256_loadu_ps(pb.add(i + 8)));
                acc0 = _mm256_fmadd_ps(d0, d0, acc0);
                acc1 = _mm256_fmadd_ps(d1, d1, acc1);
                i += 16;
            }
            if i + 8 <= n {
                let d = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
                acc0 = _mm256_fmadd_ps(d, d, acc0);
                i += 8;
            }
            let mut s = hsum(_mm256_add_ps(acc0, acc1));
            while i < n {
                let d = *a.get_unchecked(i) - *b.get_unchecked(i);
                s += d * d;
                i += 1;
            }
            s
        }
    }

    pub fn l2sq_f32_bytes(a: &[f32], b: &[u8]) -> f32 {
        assert_eq!(a.len() * 4, b.len());
        // SAFETY: b holds exactly 4·a.len() bytes (asserted above); avx2+fma
        // were verified by the dispatcher.
        unsafe { l2sq_f32_bytes_imp(a, b) }
    }

    /// # Safety
    /// Requires `b.len() == 4 * a.len()` and verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn l2sq_f32_bytes_imp(a: &[f32], b: &[u8]) -> f32 {
        // x86 is little-endian, so the raw bytes ARE the f32 payload;
        // `loadu` has no alignment requirement.
        // SAFETY: byte offsets stay below 4n = b.len() (caller contract);
        // only unaligned loads/reads are used on the byte side.
        unsafe {
            let n = a.len();
            let pa = a.as_ptr();
            let pb = b.as_ptr();
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            let mut i = 0usize;
            while i + 16 <= n {
                let d0 = _mm256_sub_ps(
                    _mm256_loadu_ps(pa.add(i)),
                    _mm256_loadu_ps(pb.add(i * 4) as *const f32),
                );
                let d1 = _mm256_sub_ps(
                    _mm256_loadu_ps(pa.add(i + 8)),
                    _mm256_loadu_ps(pb.add((i + 8) * 4) as *const f32),
                );
                acc0 = _mm256_fmadd_ps(d0, d0, acc0);
                acc1 = _mm256_fmadd_ps(d1, d1, acc1);
                i += 16;
            }
            if i + 8 <= n {
                let d = _mm256_sub_ps(
                    _mm256_loadu_ps(pa.add(i)),
                    _mm256_loadu_ps(pb.add(i * 4) as *const f32),
                );
                acc0 = _mm256_fmadd_ps(d, d, acc0);
                i += 8;
            }
            let mut s = hsum(_mm256_add_ps(acc0, acc1));
            while i < n {
                let y = (pb.add(i * 4) as *const f32).read_unaligned();
                let d = *a.get_unchecked(i) - y;
                s += d * d;
                i += 1;
            }
            s
        }
    }

    pub fn l2sq_f32_u8(a: &[f32], b: &[u8]) -> f32 {
        assert_eq!(a.len(), b.len());
        // SAFETY: lengths are equal (asserted above); avx2+fma verified by
        // the dispatcher.
        unsafe { l2sq_f32_u8_imp(a, b) }
    }

    /// # Safety
    /// Requires `a.len() == b.len()` and verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn l2sq_f32_u8_imp(a: &[f32], b: &[u8]) -> f32 {
        // SAFETY: every load/get_unchecked stays below n = a.len() = b.len()
        // (caller contract); byte loads have no alignment requirement.
        unsafe {
            let n = a.len();
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            let mut i = 0usize;
            while i + 16 <= n {
                let bytes = _mm_loadu_si128(pb.add(i) as *const __m128i);
                let lo = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(bytes));
                let hi = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_srli_si128::<8>(bytes)));
                let d0 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), lo);
                let d1 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i + 8)), hi);
                acc0 = _mm256_fmadd_ps(d0, d0, acc0);
                acc1 = _mm256_fmadd_ps(d1, d1, acc1);
                i += 16;
            }
            if i + 8 <= n {
                let bytes = _mm_loadl_epi64(pb.add(i) as *const __m128i);
                let v = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(bytes));
                let d = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), v);
                acc0 = _mm256_fmadd_ps(d, d, acc0);
                i += 8;
            }
            let mut s = hsum(_mm256_add_ps(acc0, acc1));
            while i < n {
                let d = *a.get_unchecked(i) - *b.get_unchecked(i) as f32;
                s += d * d;
                i += 1;
            }
            s
        }
    }

    pub fn l2sq_f32_i8(a: &[f32], b: &[i8]) -> f32 {
        assert_eq!(a.len(), b.len());
        // SAFETY: lengths are equal (asserted above); avx2+fma verified by
        // the dispatcher.
        unsafe { l2sq_f32_i8_imp(a, b) }
    }

    /// # Safety
    /// Requires `a.len() == b.len()` and verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn l2sq_f32_i8_imp(a: &[f32], b: &[i8]) -> f32 {
        // SAFETY: every load/get_unchecked stays below n = a.len() = b.len()
        // (caller contract); byte loads have no alignment requirement.
        unsafe {
            let n = a.len();
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            let mut i = 0usize;
            while i + 16 <= n {
                let bytes = _mm_loadu_si128(pb.add(i) as *const __m128i);
                let lo = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(bytes));
                let hi = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_srli_si128::<8>(bytes)));
                let d0 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), lo);
                let d1 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i + 8)), hi);
                acc0 = _mm256_fmadd_ps(d0, d0, acc0);
                acc1 = _mm256_fmadd_ps(d1, d1, acc1);
                i += 16;
            }
            if i + 8 <= n {
                let bytes = _mm_loadl_epi64(pb.add(i) as *const __m128i);
                let v = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(bytes));
                let d = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), v);
                acc0 = _mm256_fmadd_ps(d, d, acc0);
                i += 8;
            }
            let mut s = hsum(_mm256_add_ps(acc0, acc1));
            while i < n {
                let d = *a.get_unchecked(i) - *b.get_unchecked(i) as f32;
                s += d * d;
                i += 1;
            }
            s
        }
    }

    pub fn norm_sq_f32(a: &[f32]) -> f32 {
        // SAFETY: the impl only reads within a.len(); avx2+fma verified by
        // the dispatcher.
        unsafe { norm_sq_f32_imp(a) }
    }

    /// # Safety
    /// Requires verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn norm_sq_f32_imp(a: &[f32]) -> f32 {
        // SAFETY: every load/get_unchecked stays below n = a.len().
        unsafe {
            let n = a.len();
            let pa = a.as_ptr();
            let mut acc = _mm256_setzero_ps();
            let mut i = 0usize;
            while i + 8 <= n {
                let v = _mm256_loadu_ps(pa.add(i));
                acc = _mm256_fmadd_ps(v, v, acc);
                i += 8;
            }
            let mut s = hsum(acc);
            while i < n {
                let x = *a.get_unchecked(i);
                s += x * x;
                i += 1;
            }
            s
        }
    }

    pub fn adc_batch(table: &[f32], m: usize, k: usize, codes: &[u8], n: usize, out: &mut [f32]) {
        // Hard asserts: the unsafe body gathers/stores unchecked.
        assert!(codes.len() >= n * m);
        assert!(out.len() >= n);
        assert_eq!(table.len(), m * k);
        if m == 0 || m > ADC_MAX_M || k == 0 {
            return super::scalar_adc_batch(table, m, k, codes, n, out);
        }
        // SAFETY: sizes were asserted above and m/k bounds checked; avx2+fma
        // verified by the dispatcher.
        unsafe { adc_batch_imp(table, m, k, codes, n, out) }
    }

    /// # Safety
    /// Requires `codes.len() ≥ n·m`, `out.len() ≥ n`, `table.len() == m·k`,
    /// `0 < m ≤ ADC_MAX_M`, `k > 0`, and verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn adc_batch_imp(
        table: &[f32],
        m: usize,
        k: usize,
        codes: &[u8],
        n: usize,
        out: &mut [f32],
    ) {
        // SAFETY: code-row reads stay below n·m, `tmp` writes below 8·m ≤
        // 8·ADC_MAX_M, stores below n (caller contract), and gather indices
        // are clamped to k-1 so every lane lands inside its table row.
        unsafe {
            // 8 codes per iteration: transpose their bytes to subspace-major
            // so each subspace contributes one 8-wide gather into its row.
            let mut tmp = [0u8; 8 * ADC_MAX_M];
            // Valid code values are < k (PQ encoding), but codes come from
            // on-disk pages/memcodes — clamp so a corrupt byte yields a
            // wrong distance instead of an out-of-bounds gather (the scalar
            // path bounds-checks; this is the SIMD equivalent).
            let max_idx = _mm256_set1_epi32((k - 1) as i32);
            let mut i = 0usize;
            while i + 8 <= n {
                for r in 0..8 {
                    let row = codes.as_ptr().add((i + r) * m);
                    for s in 0..m {
                        *tmp.get_unchecked_mut(s * 8 + r) = *row.add(s);
                    }
                }
                let mut acc = _mm256_setzero_ps();
                let mut base = table.as_ptr();
                for s in 0..m {
                    let idx = _mm256_cvtepu8_epi32(_mm_loadl_epi64(
                        tmp.as_ptr().add(s * 8) as *const __m128i
                    ));
                    let idx = _mm256_min_epi32(idx, max_idx);
                    acc = _mm256_add_ps(acc, _mm256_i32gather_ps::<4>(base, idx));
                    base = base.add(k);
                }
                _mm256_storeu_ps(out.as_mut_ptr().add(i), acc);
                i += 8;
            }
            if i < n {
                super::scalar_adc_batch(table, m, k, &codes[i * m..], n - i, &mut out[i..]);
            }
        }
    }

    pub fn adc4_batch(
        qtable: &[u8],
        m: usize,
        codes: &[u8],
        n: usize,
        scale: f32,
        bias: f32,
        out: &mut [f32],
    ) {
        // Hard asserts: the unsafe body loads/stores unchecked.
        let cw = (m + 1) / 2;
        assert!(codes.len() >= n * cw);
        assert!(out.len() >= n);
        assert_eq!(qtable.len(), m * 16);
        if m == 0 || m > ADC_MAX_M {
            return super::scalar_adc4_batch(qtable, m, codes, n, scale, bias, out);
        }
        // SAFETY: sizes were asserted above and m bounds checked; avx2+fma
        // verified by the dispatcher.
        unsafe { adc4_batch_imp(qtable, m, codes, n, scale, bias, out) }
    }

    /// # Safety
    /// Requires `codes.len() ≥ n·⌈m/2⌉`, `out.len() ≥ n`,
    /// `qtable.len() == 16·m`, `0 < m ≤ ADC_MAX_M`, and verified avx2+fma
    /// support.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn adc4_batch_imp(
        qtable: &[u8],
        m: usize,
        codes: &[u8],
        n: usize,
        scale: f32,
        bias: f32,
        out: &mut [f32],
    ) {
        // SAFETY: code-row reads stay below n·cw, `tmp` writes below 16·cw,
        // qtable row loads below 16·m, stores below n (caller contract);
        // shuffle indices are 4-bit so they always land inside a 16-byte
        // row.
        unsafe {
            // 16 codes per iteration: transpose their packed bytes to
            // byte-column-major, then each column feeds two in-register row
            // lookups (`pshufb` with the low / high nibbles as indices) — no
            // gather. u16 accumulators cannot overflow: m ≤ 64 rows of ≤
            // 255.
            let cw = (m + 1) / 2;
            let mut tmp = [0u8; 16 * ((ADC_MAX_M + 1) / 2)];
            let lo_mask = _mm_set1_epi8(0x0f);
            let zero = _mm_setzero_si128();
            let scale_v = _mm256_set1_ps(scale);
            let bias_v = _mm256_set1_ps(bias);
            let mut i = 0usize;
            while i + 16 <= n {
                for r in 0..16 {
                    let row = codes.as_ptr().add((i + r) * cw);
                    for t in 0..cw {
                        *tmp.get_unchecked_mut(t * 16 + r) = *row.add(t);
                    }
                }
                let mut acc_lo = _mm_setzero_si128(); // u16 sums, codes i..i+8
                let mut acc_hi = _mm_setzero_si128(); // u16 sums, codes i+8..i+16
                for t in 0..cw {
                    let bytes = _mm_loadu_si128(tmp.as_ptr().add(t * 16) as *const __m128i);
                    let idx_lo = _mm_and_si128(bytes, lo_mask);
                    let row0 = _mm_loadu_si128(qtable.as_ptr().add(2 * t * 16) as *const __m128i);
                    let v0 = _mm_shuffle_epi8(row0, idx_lo);
                    acc_lo = _mm_add_epi16(acc_lo, _mm_unpacklo_epi8(v0, zero));
                    acc_hi = _mm_add_epi16(acc_hi, _mm_unpackhi_epi8(v0, zero));
                    if 2 * t + 1 < m {
                        let idx_hi = _mm_and_si128(_mm_srli_epi16::<4>(bytes), lo_mask);
                        let row1 =
                            _mm_loadu_si128(qtable.as_ptr().add((2 * t + 1) * 16) as *const __m128i);
                        let v1 = _mm_shuffle_epi8(row1, idx_hi);
                        acc_lo = _mm_add_epi16(acc_lo, _mm_unpacklo_epi8(v1, zero));
                        acc_hi = _mm_add_epi16(acc_hi, _mm_unpackhi_epi8(v1, zero));
                    }
                }
                // Dequantize with mul+add (NOT fma): must match the scalar
                // oracle bit-for-bit.
                let s_lo = _mm256_cvtepi32_ps(_mm256_cvtepu16_epi32(acc_lo));
                let s_hi = _mm256_cvtepi32_ps(_mm256_cvtepu16_epi32(acc_hi));
                _mm256_storeu_ps(
                    out.as_mut_ptr().add(i),
                    _mm256_add_ps(_mm256_mul_ps(s_lo, scale_v), bias_v),
                );
                _mm256_storeu_ps(
                    out.as_mut_ptr().add(i + 8),
                    _mm256_add_ps(_mm256_mul_ps(s_hi, scale_v), bias_v),
                );
                i += 16;
            }
            if i < n {
                super::scalar_adc4_batch(
                    qtable,
                    m,
                    &codes[i * cw..],
                    n - i,
                    scale,
                    bias,
                    &mut out[i..],
                );
            }
        }
    }
}

// ---- NEON ---------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    isa: "neon",
    adc_isa: "scalar",
    adc4_isa: "neon",
    l2sq_f32: neon::l2sq_f32,
    l2sq_f32_bytes: neon::l2sq_f32_bytes,
    l2sq_f32_u8: neon::l2sq_f32_u8,
    l2sq_f32_i8: neon::l2sq_f32_i8,
    norm_sq_f32: neon::norm_sq_f32,
    // No NEON gather; the unrolled scalar table walk is already load-bound.
    adc_batch: scalar_adc_batch,
    // PQ4 needs no gather — `tbl` is the aarch64 shuffle.
    adc4_batch: neon::adc4_batch,
};

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON kernels. NEON is part of the aarch64 baseline target features,
    //! so the intrinsics are unconditionally available.
    use std::arch::aarch64::*;

    pub fn l2sq_f32(a: &[f32], b: &[f32]) -> f32 {
        // Hard assert: the unsafe body does unchecked loads, so a length
        // mismatch must panic (not UB) even in release builds.
        assert_eq!(a.len(), b.len());
        // SAFETY: every load/get_unchecked stays below n = a.len() = b.len()
        // (asserted above); NEON is baseline on aarch64.
        unsafe {
            let n = a.len();
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            let mut i = 0usize;
            while i + 8 <= n {
                let d0 = vsubq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
                let d1 = vsubq_f32(vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
                acc0 = vfmaq_f32(acc0, d0, d0);
                acc1 = vfmaq_f32(acc1, d1, d1);
                i += 8;
            }
            if i + 4 <= n {
                let d = vsubq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
                acc0 = vfmaq_f32(acc0, d, d);
                i += 4;
            }
            let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
            while i < n {
                let d = *a.get_unchecked(i) - *b.get_unchecked(i);
                s += d * d;
                i += 1;
            }
            s
        }
    }

    pub fn l2sq_f32_bytes(a: &[f32], b: &[u8]) -> f32 {
        assert_eq!(a.len() * 4, b.len());
        // SAFETY: byte offsets stay below 4n = b.len() (asserted above);
        // only alignment-1 byte loads and unaligned reads touch `b`.
        unsafe {
            // Byte loads have alignment 1; reinterpret to f32 lanes (LE).
            let n = a.len();
            let pa = a.as_ptr();
            let pb = b.as_ptr();
            let mut acc = vdupq_n_f32(0.0);
            let mut i = 0usize;
            while i + 4 <= n {
                let v = vreinterpretq_f32_u8(vld1q_u8(pb.add(i * 4)));
                let d = vsubq_f32(vld1q_f32(pa.add(i)), v);
                acc = vfmaq_f32(acc, d, d);
                i += 4;
            }
            let mut s = vaddvq_f32(acc);
            while i < n {
                let y = (pb.add(i * 4) as *const f32).read_unaligned();
                let d = *a.get_unchecked(i) - y;
                s += d * d;
                i += 1;
            }
            s
        }
    }

    pub fn l2sq_f32_u8(a: &[f32], b: &[u8]) -> f32 {
        assert_eq!(a.len(), b.len());
        // SAFETY: every load/get_unchecked stays below n = a.len() = b.len()
        // (asserted above).
        unsafe {
            let n = a.len();
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            let mut i = 0usize;
            while i + 8 <= n {
                let wide = vmovl_u8(vld1_u8(pb.add(i)));
                let lo = vcvtq_f32_u32(vmovl_u16(vget_low_u16(wide)));
                let hi = vcvtq_f32_u32(vmovl_u16(vget_high_u16(wide)));
                let d0 = vsubq_f32(vld1q_f32(pa.add(i)), lo);
                let d1 = vsubq_f32(vld1q_f32(pa.add(i + 4)), hi);
                acc0 = vfmaq_f32(acc0, d0, d0);
                acc1 = vfmaq_f32(acc1, d1, d1);
                i += 8;
            }
            let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
            while i < n {
                let d = *a.get_unchecked(i) - *b.get_unchecked(i) as f32;
                s += d * d;
                i += 1;
            }
            s
        }
    }

    pub fn l2sq_f32_i8(a: &[f32], b: &[i8]) -> f32 {
        assert_eq!(a.len(), b.len());
        // SAFETY: every load/get_unchecked stays below n = a.len() = b.len()
        // (asserted above).
        unsafe {
            let n = a.len();
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            let mut i = 0usize;
            while i + 8 <= n {
                let wide = vmovl_s8(vld1_s8(pb.add(i)));
                let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(wide)));
                let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(wide)));
                let d0 = vsubq_f32(vld1q_f32(pa.add(i)), lo);
                let d1 = vsubq_f32(vld1q_f32(pa.add(i + 4)), hi);
                acc0 = vfmaq_f32(acc0, d0, d0);
                acc1 = vfmaq_f32(acc1, d1, d1);
                i += 8;
            }
            let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
            while i < n {
                let d = *a.get_unchecked(i) - *b.get_unchecked(i) as f32;
                s += d * d;
                i += 1;
            }
            s
        }
    }

    pub fn norm_sq_f32(a: &[f32]) -> f32 {
        // SAFETY: every load/get_unchecked stays below n = a.len().
        unsafe {
            let n = a.len();
            let pa = a.as_ptr();
            let mut acc = vdupq_n_f32(0.0);
            let mut i = 0usize;
            while i + 4 <= n {
                let v = vld1q_f32(pa.add(i));
                acc = vfmaq_f32(acc, v, v);
                i += 4;
            }
            let mut s = vaddvq_f32(acc);
            while i < n {
                let x = *a.get_unchecked(i);
                s += x * x;
                i += 1;
            }
            s
        }
    }

    pub fn adc4_batch(
        qtable: &[u8],
        m: usize,
        codes: &[u8],
        n: usize,
        scale: f32,
        bias: f32,
        out: &mut [f32],
    ) {
        // Hard asserts: the unsafe body loads/stores unchecked.
        let cw = (m + 1) / 2;
        assert!(codes.len() >= n * cw);
        assert!(out.len() >= n);
        assert_eq!(qtable.len(), m * 16);
        if m == 0 || m > super::ADC_MAX_M {
            return super::scalar_adc4_batch(qtable, m, codes, n, scale, bias, out);
        }
        // SAFETY: code-row reads stay below n·cw, `tmp` writes below 16·cw,
        // qtable row loads below 16·m, stores below n (all asserted above);
        // `tbl` indexes are 4-bit so they land inside a 16-byte row.
        unsafe {
            // Mirror of the AVX2 fast-scan: 16 codes per iteration,
            // transposed to byte-column-major; `tbl` looks 16 nibbles up in
            // one 16-byte row at once. u16 accumulators cannot overflow
            // (m ≤ 64 rows of ≤ 255). Dequant is mul+add, not fma — the
            // kernel is bit-exact with the scalar oracle.
            let mut tmp = [0u8; 16 * ((super::ADC_MAX_M + 1) / 2)];
            let lo_mask = vdupq_n_u8(0x0f);
            let scale_v = vdupq_n_f32(scale);
            let bias_v = vdupq_n_f32(bias);
            let mut i = 0usize;
            while i + 16 <= n {
                for r in 0..16 {
                    let row = codes.as_ptr().add((i + r) * cw);
                    for t in 0..cw {
                        *tmp.get_unchecked_mut(t * 16 + r) = *row.add(t);
                    }
                }
                let mut acc_lo = vdupq_n_u16(0); // u16 sums, codes i..i+8
                let mut acc_hi = vdupq_n_u16(0); // u16 sums, codes i+8..i+16
                for t in 0..cw {
                    let bytes = vld1q_u8(tmp.as_ptr().add(t * 16));
                    let idx_lo = vandq_u8(bytes, lo_mask);
                    let row0 = vld1q_u8(qtable.as_ptr().add(2 * t * 16));
                    let v0 = vqtbl1q_u8(row0, idx_lo);
                    acc_lo = vaddw_u8(acc_lo, vget_low_u8(v0));
                    acc_hi = vaddw_u8(acc_hi, vget_high_u8(v0));
                    if 2 * t + 1 < m {
                        let idx_hi = vshrq_n_u8::<4>(bytes);
                        let row1 = vld1q_u8(qtable.as_ptr().add((2 * t + 1) * 16));
                        let v1 = vqtbl1q_u8(row1, idx_hi);
                        acc_lo = vaddw_u8(acc_lo, vget_low_u8(v1));
                        acc_hi = vaddw_u8(acc_hi, vget_high_u8(v1));
                    }
                }
                let f0 = vcvtq_f32_u32(vmovl_u16(vget_low_u16(acc_lo)));
                let f1 = vcvtq_f32_u32(vmovl_u16(vget_high_u16(acc_lo)));
                let f2 = vcvtq_f32_u32(vmovl_u16(vget_low_u16(acc_hi)));
                let f3 = vcvtq_f32_u32(vmovl_u16(vget_high_u16(acc_hi)));
                vst1q_f32(out.as_mut_ptr().add(i), vaddq_f32(vmulq_f32(f0, scale_v), bias_v));
                vst1q_f32(out.as_mut_ptr().add(i + 4), vaddq_f32(vmulq_f32(f1, scale_v), bias_v));
                vst1q_f32(out.as_mut_ptr().add(i + 8), vaddq_f32(vmulq_f32(f2, scale_v), bias_v));
                vst1q_f32(out.as_mut_ptr().add(i + 12), vaddq_f32(vmulq_f32(f3, scale_v), bias_v));
                i += 16;
            }
            if i < n {
                super::scalar_adc4_batch(
                    qtable,
                    m,
                    &codes[i * cw..],
                    n - i,
                    scale,
                    bias,
                    &mut out[i..],
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn dispatch_is_stable_and_named() {
        let k1 = kernels();
        let k2 = kernels();
        assert!(std::ptr::eq(k1, k2), "dispatch must select once");
        assert!(["avx2", "neon", "scalar"].contains(&k1.isa));
        assert_eq!(scalar_kernels().isa, "scalar");
    }

    #[test]
    fn dispatched_matches_scalar_spot() {
        // The exhaustive property sweep lives in tests/simd_kernels.rs;
        // this is a fast in-crate smoke check.
        let mut rng = XorShift::new(42);
        let n = 128;
        let a: Vec<f32> = (0..n).map(|_| rng.next_gaussian() * 10.0).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.next_gaussian() * 10.0).collect();
        let got = (kernels().l2sq_f32)(&a, &b);
        let want = (scalar_kernels().l2sq_f32)(&a, &b);
        assert!((got - want).abs() <= 1e-4 * want.max(1.0), "{got} vs {want}");
    }

    #[test]
    fn adc4_batch_matches_scalar_bit_exact() {
        // The exhaustive m/n sweep lives in tests/simd_kernels.rs; this is
        // a fast in-crate smoke check of the bit-exactness contract.
        let mut rng = XorShift::new(11);
        let (m, n) = (16usize, 53usize);
        let cw = (m + 1) / 2;
        let qtable: Vec<u8> = (0..m * 16).map(|_| rng.next_below(256) as u8).collect();
        let codes: Vec<u8> = (0..n * cw).map(|_| rng.next_below(256) as u8).collect();
        let (scale, bias) = (0.037f32, 1.25f32);
        let mut got = vec![0f32; n];
        let mut want = vec![0f32; n];
        (kernels().adc4_batch)(&qtable, m, &codes, n, scale, bias, &mut got);
        scalar_adc4_batch(&qtable, m, &codes, n, scale, bias, &mut want);
        for i in 0..n {
            assert_eq!(got[i].to_bits(), want[i].to_bits(), "row {i}");
        }
    }

    #[test]
    fn adc_batch_matches_scalar() {
        let mut rng = XorShift::new(7);
        let (m, k, n) = (16usize, 256usize, 37usize);
        let table: Vec<f32> = (0..m * k).map(|_| rng.next_f32() * 100.0).collect();
        let codes: Vec<u8> = (0..n * m).map(|_| rng.next_below(k) as u8).collect();
        let mut got = vec![0f32; n];
        let mut want = vec![0f32; n];
        (kernels().adc_batch)(&table, m, k, &codes, n, &mut got);
        scalar_adc_batch(&table, m, k, &codes, n, &mut want);
        for i in 0..n {
            assert!((got[i] - want[i]).abs() <= 1e-4 * want[i].max(1.0), "row {i}");
        }
    }
}
