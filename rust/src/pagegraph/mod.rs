//! Page-node graph construction (paper §4.1, Algorithm 1).
//!
//! Vectors are grouped into page nodes by hop-bounded proximity clustering
//! over the Vamana graph: take an ungrouped seed, collect its ungrouped
//! neighbors within `h` hops, keep the `n-1` closest, fill stragglers from
//! the ungrouped pool. Page-level edges are then derived by aggregating the
//! vector-level edges that cross page boundaries, dropping intra-page edges
//! and merging duplicates — keeping at most `reps_per_page` representative
//! vectors per neighboring page (closest-first), which is the paper's
//! "representative vectors" device for bounding per-page topology size.

mod grouping;

pub use grouping::{group_into_pages, GroupingParams};

use crate::dataset::VectorSet;
use crate::layout::IdRemap;
use crate::vamana::VamanaGraph;

/// The page-node graph in new-id space, ready for the layout writer.
pub struct PageGraph {
    /// `pages[p]` = original vector ids of page `p`'s members (ordered:
    /// member offset in the page = index here).
    pub pages: Vec<Vec<u32>>,
    /// `nbrs[p]` = neighbor entries of page `p`: new-ids of representative
    /// vectors in *other* pages, priority-ordered (closest reps first).
    pub nbrs: Vec<Vec<u32>>,
    pub remap: IdRemap,
    pub capacity: usize,
}

/// Derive the page-node graph from a vector-level Vamana graph.
///
/// `max_nbrs` bounds neighbor entries per page; `reps_per_page` bounds how
/// many representatives a single neighboring page may contribute.
pub fn build_page_graph(
    base: &VectorSet,
    graph: &VamanaGraph,
    params: &GroupingParams,
    max_nbrs: usize,
    reps_per_page: usize,
) -> PageGraph {
    let pages = group_into_pages(base, graph, params);
    let remap = IdRemap::from_pages(&pages, params.capacity, base.len());

    // Aggregate external edges per page (Alg. 1 lines 14-26) with
    // representative selection.
    let n_pages = pages.len();
    let mut nbrs: Vec<Vec<u32>> = Vec::with_capacity(n_pages);
    for (p, members) in pages.iter().enumerate() {
        // target page -> (distance of edge source to member centroid proxy,
        // new-id of the external endpoint). We rank candidate reps by the
        // *edge distance* (d(source member, external endpoint)): short
        // cross-page edges are exactly the original graph's strongest
        // connections (robust-pruned), so they are the best reps.
        let mut per_page: std::collections::HashMap<u32, Vec<(f32, u32)>> =
            std::collections::HashMap::new();
        for &orig in members {
            let vq = base.get_f32(orig as usize);
            for &nb_orig in &graph.adj[orig as usize] {
                let nb_new = remap.to_new(nb_orig);
                let nb_page = remap.page_of(nb_new);
                if nb_page as usize == p {
                    continue; // intra-page edge: merged away
                }
                let d = crate::distance::l2sq_query(&vq, base.view(nb_orig as usize));
                per_page.entry(nb_page).or_default().push((d, nb_new));
            }
        }
        // Per neighboring page: dedup endpoints, keep closest reps.
        let mut entries: Vec<(f32, u32)> = Vec::new();
        for (_, mut cands) in per_page {
            cands.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            cands.dedup_by_key(|&mut (_, id)| id);
            for &(d, id) in cands.iter().take(reps_per_page) {
                entries.push((d, id));
            }
        }
        // Priority order across all neighbor pages, capped.
        entries.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        entries.truncate(max_nbrs);
        nbrs.push(entries.into_iter().map(|(_, id)| id).collect());
    }

    PageGraph { pages, nbrs, remap, capacity: params.capacity }
}

impl PageGraph {
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    pub fn avg_page_degree(&self) -> f64 {
        let total: usize = self.nbrs.iter().map(|n| n.len()).sum();
        total as f64 / self.n_pages().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, SynthSpec};
    use crate::vamana::VamanaParams;

    fn setup() -> (VectorSet, VamanaGraph) {
        let spec = SynthSpec::new(DatasetKind::DeepLike, 600).with_dim(16).with_clusters(8);
        let base = spec.generate(12);
        let g = VamanaGraph::build(
            &base,
            &VamanaParams { r: 12, l_build: 24, alpha: 1.2, seed: 4, nthreads: 4 },
        );
        (base, g)
    }

    #[test]
    fn page_graph_invariants() {
        let (base, g) = setup();
        let params = GroupingParams { capacity: 8, hops: 2, seed: 1 };
        let pg = build_page_graph(&base, &g, &params, 32, 2);

        // Every vector appears in exactly one page.
        let mut seen = vec![false; base.len()];
        for page in &pg.pages {
            assert!(page.len() <= 8);
            for &v in page {
                assert!(!seen[v as usize], "vector {v} in two pages");
                seen[v as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));

        // Neighbor entries: valid slots, never the owning page, ≤ cap,
        // no duplicate endpoints.
        for (p, nbrs) in pg.nbrs.iter().enumerate() {
            assert!(nbrs.len() <= 32);
            let set: std::collections::HashSet<_> = nbrs.iter().collect();
            assert_eq!(set.len(), nbrs.len(), "dup endpoint in page {p}");
            for &nb in nbrs {
                assert_ne!(pg.remap.page_of(nb) as usize, p, "self-edge on page {p}");
                // Endpoints must be occupied slots, not holes.
                assert_ne!(pg.remap.to_orig(nb), u32::MAX, "neighbor {nb} is a hole");
            }
        }
    }

    #[test]
    fn reps_per_page_bound_holds() {
        let (base, g) = setup();
        let params = GroupingParams { capacity: 8, hops: 2, seed: 1 };
        let pg = build_page_graph(&base, &g, &params, 64, 2);
        for (p, nbrs) in pg.nbrs.iter().enumerate() {
            let mut count: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
            for &nb in nbrs {
                *count.entry(pg.remap.page_of(nb)).or_default() += 1;
            }
            for (tp, c) in count {
                assert!(c <= 2, "page {p}: {c} reps for neighbor page {tp}");
            }
        }
    }

    #[test]
    fn page_count_shrinks_graph() {
        let (base, g) = setup();
        let params = GroupingParams { capacity: 8, hops: 2, seed: 1 };
        let pg = build_page_graph(&base, &g, &params, 32, 2);
        // ~600/8 pages; mild slack for stragglers.
        assert!(pg.n_pages() >= 75 && pg.n_pages() <= 100, "{}", pg.n_pages());
        assert!(pg.avg_page_degree() > 2.0);
    }
}
