//! Hop-bounded proximity grouping (Algorithm 1 lines 1–13).

use crate::dataset::VectorSet;
use crate::util::XorShift;
use crate::vamana::VamanaGraph;

#[derive(Debug, Clone)]
pub struct GroupingParams {
    /// Page-node capacity `n` (vectors per page).
    pub capacity: usize,
    /// Hop bound `h` for candidate collection.
    pub hops: usize,
    pub seed: u64,
}

impl Default for GroupingParams {
    fn default() -> Self {
        Self { capacity: 16, hops: 2, seed: 42 }
    }
}

/// Group all vectors into pages of at most `capacity` members.
///
/// Seeds are taken in a deterministic shuffled order. For each seed we BFS
/// up to `hops` levels over the vector graph, restricted to ungrouped
/// vectors (matching `ungroupedNbrsWithinHops` in the paper), sort the
/// candidates by distance to the seed and keep the closest `capacity - 1`.
/// If the neighborhood is exhausted (tail of construction), the page is
/// back-filled from the ungrouped pool (Alg. 1 lines 9–11).
pub fn group_into_pages(
    base: &VectorSet,
    graph: &VamanaGraph,
    params: &GroupingParams,
) -> Vec<Vec<u32>> {
    let n = base.len();
    let cap = params.capacity.max(1);
    let mut grouped = vec![false; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    let mut rng = XorShift::new(params.seed);
    rng.shuffle(&mut order);

    // Ungrouped pool for O(1) back-fill extraction: a cursor over `order`.
    let mut cursor = 0usize;
    let mut pages: Vec<Vec<u32>> = Vec::with_capacity(n / cap + 1);

    let mut bfs_buf: Vec<u32> = Vec::new();
    let mut depth_buf: Vec<usize> = Vec::new();
    let mut in_frontier = vec![false; n];

    for &seed in order.iter() {
        if grouped[seed as usize] {
            continue;
        }
        let mut page = Vec::with_capacity(cap);
        grouped[seed as usize] = true;
        page.push(seed);

        if cap > 1 {
            // BFS over ungrouped vectors within `hops`.
            bfs_buf.clear();
            depth_buf.clear();
            bfs_buf.push(seed);
            depth_buf.push(0);
            in_frontier[seed as usize] = true;
            let mut head = 0usize;
            let mut candidates: Vec<u32> = Vec::new();
            while head < bfs_buf.len() {
                let v = bfs_buf[head];
                let d = depth_buf[head];
                head += 1;
                if d >= params.hops {
                    continue;
                }
                for &nb in &graph.adj[v as usize] {
                    if in_frontier[nb as usize] || grouped[nb as usize] {
                        continue;
                    }
                    in_frontier[nb as usize] = true;
                    bfs_buf.push(nb);
                    depth_buf.push(d + 1);
                    candidates.push(nb);
                }
            }
            for &v in &bfs_buf {
                in_frontier[v as usize] = false;
            }

            // Keep the capacity-1 closest candidates to the seed.
            let sq = base.get_f32(seed as usize);
            let mut scored: Vec<(f32, u32)> = candidates
                .into_iter()
                .map(|c| (crate::distance::l2sq_query(&sq, base.view(c as usize)), c))
                .collect();
            scored.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            for &(_, c) in scored.iter().take(cap - 1) {
                grouped[c as usize] = true;
                page.push(c);
            }

            // Back-fill from the ungrouped pool.
            while page.len() < cap {
                while cursor < order.len() && grouped[order[cursor] as usize] {
                    cursor += 1;
                }
                if cursor >= order.len() {
                    break;
                }
                let v = order[cursor];
                grouped[v as usize] = true;
                page.push(v);
            }
        }
        pages.push(page);
    }
    pages
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, SynthSpec};
    use crate::vamana::VamanaParams;

    fn setup(n: usize) -> (VectorSet, VamanaGraph) {
        let spec = SynthSpec::new(DatasetKind::DeepLike, n).with_dim(12).with_clusters(6);
        let base = spec.generate(3);
        let g = VamanaGraph::build(
            &base,
            &VamanaParams { r: 10, l_build: 20, alpha: 1.2, seed: 2, nthreads: 2 },
        );
        (base, g)
    }

    #[test]
    fn partition_is_exact_and_bounded() {
        let (base, g) = setup(500);
        let pages = group_into_pages(&base, &g, &GroupingParams { capacity: 7, hops: 2, seed: 9 });
        let mut seen = vec![false; 500];
        for p in &pages {
            assert!(!p.is_empty() && p.len() <= 7);
            for &v in p {
                assert!(!seen[v as usize]);
                seen[v as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        // All but the tail pages should be full (back-fill guarantees it).
        let full = pages.iter().filter(|p| p.len() == 7).count();
        assert!(full >= pages.len() - 1, "{full}/{}", pages.len());
    }

    #[test]
    fn pages_are_spatially_coherent() {
        // Mean intra-page distance must be well below the global mean
        // distance — that's the clustering property the page graph relies
        // on (wasted-read elimination).
        let (base, g) = setup(600);
        let pages = group_into_pages(&base, &g, &GroupingParams { capacity: 8, hops: 2, seed: 9 });
        let mut rng = XorShift::new(1);
        let mut intra = 0f64;
        let mut intra_n = 0usize;
        for p in pages.iter().take(30) {
            for i in 0..p.len() {
                for j in (i + 1)..p.len() {
                    intra += crate::distance::l2sq_f32(
                        &base.get_f32(p[i] as usize),
                        &base.get_f32(p[j] as usize),
                    ) as f64;
                    intra_n += 1;
                }
            }
        }
        let mut global = 0f64;
        for _ in 0..2000 {
            let a = rng.next_below(600);
            let b = rng.next_below(600);
            global += crate::distance::l2sq_f32(&base.get_f32(a), &base.get_f32(b)) as f64 / 2000.0;
        }
        let intra_mean = intra / intra_n as f64;
        assert!(
            intra_mean < global * 0.6,
            "pages not coherent: intra {intra_mean:.3} vs global {global:.3}"
        );
    }

    #[test]
    fn capacity_one_degenerates_to_singletons() {
        let (base, g) = setup(100);
        let pages = group_into_pages(&base, &g, &GroupingParams { capacity: 1, hops: 1, seed: 0 });
        assert_eq!(pages.len(), 100);
        assert!(pages.iter().all(|p| p.len() == 1));
    }

    #[test]
    fn deterministic_per_seed() {
        let (base, g) = setup(200);
        let p1 = group_into_pages(&base, &g, &GroupingParams { capacity: 5, hops: 2, seed: 7 });
        let p2 = group_into_pages(&base, &g, &GroupingParams { capacity: 5, hops: 2, seed: 7 });
        assert_eq!(p1, p2);
    }
}
