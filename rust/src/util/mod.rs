//! Small shared utilities: deterministic RNG, timing, binary encoding
//! helpers, and a scoped parallel-for built on `std::thread` (the build is
//! fully offline — no rayon/tokio — so the crate carries its own).

pub mod binio;
pub mod checked;
mod crc32c;
mod parallel;
mod rng;
pub mod sync;
mod timer;

pub use binio::{ReadExt, WriteExt};
pub use checked::{hi32, lo32, to_u16, to_u32, to_usize, Ix};
pub use crc32c::crc32c;
pub use parallel::{num_threads, parallel_chunks, parallel_for};
pub use rng::XorShift;
pub use timer::{format_duration, Stopwatch};

/// Ceiling division.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    div_ceil(a, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_edges() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
    }

    #[test]
    fn round_up_edges() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }
}
