//! Little-endian binary encoding helpers for the index file formats.
//!
//! Everything on disk (meta.bin, pages.bin, pq.bin, routing.bin, remap.bin,
//! and the fvecs/bvecs dataset formats) goes through these, so endianness
//! and width decisions live in exactly one place.

use std::io::{self, Read, Write};

pub trait WriteExt: Write {
    #[inline]
    fn write_u8(&mut self, v: u8) -> io::Result<()> {
        self.write_all(&[v])
    }
    #[inline]
    fn write_u16(&mut self, v: u16) -> io::Result<()> {
        self.write_all(&v.to_le_bytes())
    }
    #[inline]
    fn write_u32(&mut self, v: u32) -> io::Result<()> {
        self.write_all(&v.to_le_bytes())
    }
    #[inline]
    fn write_u64(&mut self, v: u64) -> io::Result<()> {
        self.write_all(&v.to_le_bytes())
    }
    #[inline]
    fn write_f32(&mut self, v: f32) -> io::Result<()> {
        self.write_all(&v.to_le_bytes())
    }
    fn write_f32_slice(&mut self, vs: &[f32]) -> io::Result<()> {
        for &v in vs {
            self.write_f32(v)?;
        }
        Ok(())
    }
    fn write_u32_slice(&mut self, vs: &[u32]) -> io::Result<()> {
        for &v in vs {
            self.write_u32(v)?;
        }
        Ok(())
    }
}
impl<W: Write + ?Sized> WriteExt for W {}

pub trait ReadExt: Read {
    #[inline]
    fn read_u8v(&mut self) -> io::Result<u8> {
        let mut b = [0u8; 1];
        self.read_exact(&mut b)?;
        Ok(b[0])
    }
    #[inline]
    fn read_u16v(&mut self) -> io::Result<u16> {
        let mut b = [0u8; 2];
        self.read_exact(&mut b)?;
        Ok(u16::from_le_bytes(b))
    }
    #[inline]
    fn read_u32v(&mut self) -> io::Result<u32> {
        let mut b = [0u8; 4];
        self.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    #[inline]
    fn read_u64v(&mut self) -> io::Result<u64> {
        let mut b = [0u8; 8];
        self.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    #[inline]
    fn read_f32v(&mut self) -> io::Result<f32> {
        let mut b = [0u8; 4];
        self.read_exact(&mut b)?;
        Ok(f32::from_le_bytes(b))
    }
    fn read_f32_vec(&mut self, n: usize) -> io::Result<Vec<f32>> {
        let mut out = vec![0f32; n];
        let mut buf = vec![0u8; n * 4];
        self.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        Ok(out)
    }
    fn read_u32_vec(&mut self, n: usize) -> io::Result<Vec<u32>> {
        let mut out = vec![0u32; n];
        let mut buf = vec![0u8; n * 4];
        self.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(4).enumerate() {
            out[i] = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        Ok(out)
    }
}
impl<R: Read + ?Sized> ReadExt for R {}

/// Decode a `f32` slice from raw little-endian bytes (zero-copy caller owns
/// the buffer; used by the page deserializer on the hot path).
#[inline]
pub fn f32_from_le(buf: &[u8], out: &mut [f32]) {
    debug_assert_eq!(buf.len(), out.len() * 4);
    for (i, c) in buf.chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_scalars() {
        let mut buf = Vec::new();
        buf.write_u8(7).unwrap();
        buf.write_u16(300).unwrap();
        buf.write_u32(70000).unwrap();
        buf.write_u64(1 << 40).unwrap();
        buf.write_f32(3.5).unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(c.read_u8v().unwrap(), 7);
        assert_eq!(c.read_u16v().unwrap(), 300);
        assert_eq!(c.read_u32v().unwrap(), 70000);
        assert_eq!(c.read_u64v().unwrap(), 1 << 40);
        assert_eq!(c.read_f32v().unwrap(), 3.5);
    }

    #[test]
    fn roundtrip_slices() {
        let f = vec![1.0f32, -2.5, 1e-8, f32::MAX];
        let u = vec![0u32, 1, u32::MAX];
        let mut buf = Vec::new();
        buf.write_f32_slice(&f).unwrap();
        buf.write_u32_slice(&u).unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(c.read_f32_vec(4).unwrap(), f);
        assert_eq!(c.read_u32_vec(3).unwrap(), u);
    }

    #[test]
    fn f32_from_le_matches() {
        let vals = [0.5f32, -1.25, 3e7];
        let mut bytes = Vec::new();
        bytes.write_f32_slice(&vals).unwrap();
        let mut out = [0f32; 3];
        f32_from_le(&bytes, &mut out);
        assert_eq!(out, vals);
    }

    #[test]
    fn short_read_errors() {
        let mut c = Cursor::new(vec![1u8, 2]);
        assert!(c.read_u32v().is_err());
    }
}
