//! Poison-tolerant mutex helpers for the hot paths.
//!
//! The I/O stores and runner aggregate state behind `std::sync::Mutex`;
//! `lock().unwrap()` there is banned by `pallas-lint` rule
//! `hot-path-unwrap` (see LINTS.md). A poisoned mutex only means some
//! thread panicked while holding it — every protected structure in this
//! crate is either repaired by its owner (the uring `Ring` keeps its own
//! `poisoned` flag and re-checks invariants on entry) or is plain data
//! whose partially-updated state the caller re-validates. Recovering the
//! guard is therefore sound, and it keeps panic-propagation off the
//! latency-critical path.

use std::sync::{Condvar, Mutex, MutexGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
#[inline]
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Wait on `cv`, recovering the guard if the mutex was poisoned while we
/// were parked.
#[inline]
pub fn cond_wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

/// Wait on `cv` with a timeout, recovering the guard if the mutex was
/// poisoned while we were parked. Returns the guard and whether the wait
/// timed out (the server's batch gather window uses this to bound how
/// long an executor holds a partial batch waiting for batchmates).
#[inline]
pub fn cond_wait_timeout<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: std::time::Duration,
) -> (MutexGuard<'a, T>, bool) {
    let (g, res) = cv.wait_timeout(g, dur).unwrap_or_else(|e| e.into_inner());
    (g, res.timed_out())
}

/// Consume a mutex, recovering the inner value even if poisoned.
#[inline]
pub fn into_inner<T>(m: Mutex<T>) -> T {
    m.into_inner().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock(&m), 7);
        assert_eq!(into_inner(Arc::try_unwrap(m).unwrap()), 7);
    }

    #[test]
    fn cond_wait_timeout_reports_expiry() {
        let pair = Arc::new((Mutex::new(()), Condvar::new()));
        let (m, cv) = &*pair;
        let g = lock(m);
        let (_g, timed_out) = cond_wait_timeout(cv, g, std::time::Duration::from_millis(1));
        assert!(timed_out);
    }

    #[test]
    fn cond_wait_passes_through() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let h = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut done = lock(m);
            while !*done {
                done = cond_wait(cv, done);
            }
        });
        {
            let (m, cv) = &*pair;
            *lock(m) = true;
            cv.notify_all();
        }
        h.join().unwrap();
    }
}
