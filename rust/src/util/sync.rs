//! Poison-tolerant mutex helpers for the hot paths.
//!
//! The I/O stores and runner aggregate state behind `std::sync::Mutex`;
//! `lock().unwrap()` there is banned by `pallas-lint` rule
//! `hot-path-unwrap` (see LINTS.md). A poisoned mutex only means some
//! thread panicked while holding it — every protected structure in this
//! crate is either repaired by its owner (the uring `Ring` keeps its own
//! `poisoned` flag and re-checks invariants on entry) or is plain data
//! whose partially-updated state the caller re-validates. Recovering the
//! guard is therefore sound, and it keeps panic-propagation off the
//! latency-critical path.

use std::sync::{Condvar, Mutex, MutexGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
#[inline]
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Wait on `cv`, recovering the guard if the mutex was poisoned while we
/// were parked.
#[inline]
pub fn cond_wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

/// Wait on `cv` with a timeout, recovering the guard if the mutex was
/// poisoned while we were parked. Returns the guard and whether the wait
/// timed out (the server's batch gather window uses this to bound how
/// long an executor holds a partial batch waiting for batchmates).
///
/// # Spurious wakeups
///
/// Like [`Condvar::wait_timeout`], this can return `(guard, false)` with
/// the awaited condition still false — either a spurious wakeup or a
/// notify meant for a different waiter. Callers MUST loop, re-checking
/// both the predicate and their own deadline each time around:
///
/// ```ignore
/// let deadline = Instant::now() + window;
/// while !ready(&g) {
///     let remaining = deadline.saturating_duration_since(Instant::now());
///     if remaining.is_zero() { break; }        // deadline owned by caller
///     let (g2, _timed_out) = cond_wait_timeout(&cv, g, remaining);
///     g = g2;                                  // ignore timed_out; re-check
/// }
/// ```
///
/// Passing the *remaining* time (not the full window) on every iteration
/// is what keeps a stream of spurious wakeups from extending the wait
/// indefinitely; trusting the returned `timed_out` flag alone does not —
/// a wakeup in the last microsecond reports `false` yet the window is
/// effectively spent. The server's executor gather loop
/// (`engine::server`) follows exactly this shape.
#[inline]
pub fn cond_wait_timeout<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: std::time::Duration,
) -> (MutexGuard<'a, T>, bool) {
    let (g, res) = cv.wait_timeout(g, dur).unwrap_or_else(|e| e.into_inner());
    (g, res.timed_out())
}

/// Consume a mutex, recovering the inner value even if poisoned.
#[inline]
pub fn into_inner<T>(m: Mutex<T>) -> T {
    m.into_inner().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock(&m), 7);
        assert_eq!(into_inner(Arc::try_unwrap(m).unwrap()), 7);
    }

    #[test]
    fn cond_wait_timeout_reports_expiry() {
        let pair = Arc::new((Mutex::new(()), Condvar::new()));
        let (m, cv) = &*pair;
        let g = lock(m);
        let (_g, timed_out) = cond_wait_timeout(cv, g, std::time::Duration::from_millis(1));
        assert!(timed_out);
    }

    #[test]
    fn spurious_notifies_neither_release_early_nor_lose_the_deadline() {
        // Regression for the gather-window idiom documented on
        // `cond_wait_timeout`: a waiter hammered with notifies whose
        // predicate stays false must (a) never return before its
        // deadline and (b) still return promptly once it passes, even
        // though every individual wait ends with `timed_out == false`.
        use std::time::{Duration, Instant};
        let pair = Arc::new((Mutex::new(0u32), Condvar::new(), std::sync::atomic::AtomicBool::new(false)));
        let pair2 = Arc::clone(&pair);
        // Noise thread: bump the counter and notify in a tight loop —
        // real notifies with no predicate change, the worst case the
        // loop idiom has to absorb.
        let noise = std::thread::spawn(move || {
            let (m, cv, stop) = &*pair2;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                *lock(m) += 1;
                cv.notify_all();
                std::thread::sleep(Duration::from_micros(200));
            }
        });

        let (m, cv, stop) = &*pair;
        let window = Duration::from_millis(30);
        let start = Instant::now();
        let deadline = start + window;
        let mut g = lock(m);
        let mut wakeups = 0u32;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            let (g2, _timed_out) = cond_wait_timeout(cv, g, remaining);
            g = g2;
            wakeups += 1;
        }
        drop(g);
        let waited = start.elapsed();
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        noise.join().unwrap();

        assert!(
            waited >= window,
            "released {waited:?} into a {window:?} window after {wakeups} wakeups"
        );
        // The deadline must not stretch under notify pressure: each
        // iteration waits only the *remaining* time. Generous ceiling —
        // CI schedulers are coarse — but far below the ~unbounded drift
        // of re-waiting the full window per wakeup.
        assert!(
            waited < window + Duration::from_millis(250),
            "deadline drifted to {waited:?} under spurious notifies ({wakeups} wakeups)"
        );
        assert!(wakeups >= 1);
    }

    #[test]
    fn cond_wait_passes_through() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let h = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut done = lock(m);
            while !*done {
                done = cond_wait(cv, done);
            }
        });
        {
            let (m, cv) = &*pair;
            *lock(m) = true;
            cv.notify_all();
        }
        h.join().unwrap();
    }
}
