//! Deterministic xorshift128+ RNG.
//!
//! Everything that needs randomness (dataset synthesis, k-means init, LSH
//! hyperplanes, property tests) takes an explicit seed so builds, tests and
//! experiments are exactly reproducible.

#[derive(Debug, Clone)]
pub struct XorShift {
    s0: u64,
    s1: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread a possibly-small seed over both words.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        let s0 = next();
        let mut s1 = next();
        if s0 == 0 && s1 == 0 {
            s1 = 1;
        }
        Self { s0, s1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift; bias is negligible for our n ≪ 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            // Rejection sampling for sparse draws.
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.next_below(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = XorShift::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn next_below_in_range() {
        let mut r = XorShift::new(7);
        for n in [1usize, 2, 3, 17, 1000] {
            for _ in 0..200 {
                assert!(r.next_below(n) < n);
            }
        }
    }

    #[test]
    fn unit_floats_in_range() {
        let mut r = XorShift::new(1);
        for _ in 0..1000 {
            let f = r.next_f32();
            assert!((0.0..1.0).contains(&f));
            let d = r.next_f64();
            assert!((0.0..1.0).contains(&d));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = XorShift::new(9);
        let n = 20_000;
        let mut sum = 0.0f64;
        let mut sumsq = 0.0f64;
        for _ in 0..n {
            let g = r.next_gaussian() as f64;
            sum += g;
            sumsq += g * g;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = XorShift::new(5);
        for (n, k) in [(10, 10), (100, 5), (1000, 100)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShift::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
