//! Minimal data-parallel helpers on `std::thread::scope` — the offline build
//! has no rayon, and the workloads here (ground-truth brute force, Vamana
//! construction, query fan-out) are embarrassingly parallel over index
//! ranges.
#![deny(unsafe_op_in_unsafe_fn)]

/// Number of worker threads to use by default (host parallelism, capped).
pub fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(32)
}

/// Run `f(start, end)` over `nthreads` contiguous chunks of `[0, n)`.
///
/// `f` is called once per chunk, from separate threads. Chunks are
/// near-equal-sized; the remainder is spread over the first chunks.
pub fn parallel_chunks<F>(n: usize, nthreads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let nthreads = nthreads.max(1).min(n.max(1));
    if nthreads <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let base = n / nthreads;
    let rem = n % nthreads;
    std::thread::scope(|s| {
        let mut start = 0usize;
        for t in 0..nthreads {
            let len = base + usize::from(t < rem);
            let end = start + len;
            let fref = &f;
            s.spawn(move || fref(start, end));
            start = end;
        }
    });
}

/// Parallel map over `[0, n)` producing a `Vec<T>` in index order.
///
/// Work is split into contiguous chunks (one per thread); each element is
/// produced by `f(i)`.
pub fn parallel_for<T, F>(n: usize, nthreads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        parallel_chunks(n, nthreads, |start, end| {
            let p = out_ptr;
            for i in start..end {
                // SAFETY: i < n = out.len(); chunks are disjoint index
                // ranges, so each slot is written by exactly one thread, and
                // `out` outlives every worker (the scope joins before
                // parallel_chunks returns).
                unsafe { *p.0.add(i) = f(i) };
            }
        });
    }
    out
}

struct SendPtr<T>(*mut T);
// Manual impls: derived Copy/Clone would require `T: Copy`, but the raw
// pointer itself is always freely copyable.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
// SAFETY: the pointer is only ever used for disjoint-range writes from
// scoped threads that the owning call joins before returning, and the
// pointee type must itself be Send to cross threads.
unsafe impl<T: Send> Send for SendPtr<T> {}
// SAFETY: as above — shared references only enable the same disjoint
// writes, which cannot race.
unsafe impl<T: Send> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_range_exactly_once() {
        for n in [0usize, 1, 7, 100, 1001] {
            for t in [1usize, 2, 3, 8] {
                let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                parallel_chunks(n, t, |s, e| {
                    for i in s..e {
                        hits[i].fetch_add(1, Ordering::SeqCst);
                    }
                });
                assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1), "n={n} t={t}");
            }
        }
    }

    #[test]
    fn parallel_for_preserves_order() {
        let v = parallel_for(1000, 8, |i| i * 3);
        assert_eq!(v.len(), 1000);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * 3);
        }
    }

    #[test]
    fn single_thread_fallback() {
        let v = parallel_for(5, 1, |i| i);
        assert_eq!(v, vec![0, 1, 2, 3, 4]);
    }
}
