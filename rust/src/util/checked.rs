//! Checked integer conversions for page/offset arithmetic.
//!
//! The `layout/`, `io/` and `cache/` modules are banned (by `pallas-lint`
//! rule `truncating-cast`, see LINTS.md) from using bare `as` casts to
//! narrowing or platform-width integer types: one silently truncated page
//! offset corrupts the on-disk layout. They route through these helpers
//! instead. This module itself lives outside the banned scope, so the
//! widening conversions below may use `as` internally where provably
//! lossless.

// The whole page-offset design assumes at least a 32-bit address space;
// `Ix` widenings below rely on it.
const _: () = assert!(usize::BITS >= 32, "pallas requires a >= 32-bit target");

/// Infallible widening to `usize` for types that always fit (given the
/// 32-bit-floor assertion above). Spelled `x.ix()` at call sites to keep
/// index arithmetic readable.
pub trait Ix {
    fn ix(self) -> usize;
}

impl Ix for u8 {
    #[inline(always)]
    fn ix(self) -> usize {
        self as usize
    }
}

impl Ix for u16 {
    #[inline(always)]
    fn ix(self) -> usize {
        self as usize
    }
}

impl Ix for u32 {
    #[inline(always)]
    fn ix(self) -> usize {
        self as usize
    }
}

/// `u64` → `usize`, failing on 32-bit targets when the value is too large
/// (file offsets and element counts come from headers and can be hostile).
#[inline]
pub fn to_usize(v: u64) -> anyhow::Result<usize> {
    usize::try_from(v).map_err(|_| anyhow::anyhow!("value {v} does not fit usize"))
}

/// `usize` → `u32`, for counts serialized as fixed 32-bit fields.
#[inline]
pub fn to_u32(v: usize) -> anyhow::Result<u32> {
    u32::try_from(v).map_err(|_| anyhow::anyhow!("value {v} does not fit u32"))
}

/// `usize` → `u16`, for per-page slot counts.
#[inline]
pub fn to_u16(v: usize) -> anyhow::Result<u16> {
    u16::try_from(v).map_err(|_| anyhow::anyhow!("value {v} does not fit u16"))
}

/// Low 32 bits of a packed 64-bit tag (io_uring `user_data` packing).
#[inline(always)]
pub fn lo32(v: u64) -> u32 {
    (v & 0xffff_ffff) as u32
}

/// High 32 bits of a packed 64-bit tag.
#[inline(always)]
pub fn hi32(v: u64) -> u32 {
    (v >> 32) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widenings_are_identity() {
        assert_eq!(0xffu8.ix(), 255usize);
        assert_eq!(0xffffu16.ix(), 65535usize);
        assert_eq!(0xffff_ffffu32.ix(), 4_294_967_295usize);
    }

    #[test]
    fn fallible_conversions() {
        assert_eq!(to_usize(12).unwrap(), 12);
        assert_eq!(to_u32(12).unwrap(), 12);
        assert_eq!(to_u16(65535).unwrap(), 65535);
        assert!(to_u16(65536).is_err());
        assert!(to_u32(usize::MAX).is_err() || usize::BITS == 32);
    }

    #[test]
    fn tag_packing_roundtrip() {
        let v = (0xdead_beefu64 << 32) | 0x0123_4567;
        assert_eq!(hi32(v), 0xdead_beef);
        assert_eq!(lo32(v), 0x0123_4567);
        assert_eq!(((hi32(v) as u64) << 32) | lo32(v) as u64, v);
    }
}
