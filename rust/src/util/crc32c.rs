//! CRC32C (Castagnoli) — the page-integrity checksum (ISSUE 6).
//!
//! Software slicing-by-8 over compile-time tables: no external crates, no
//! ISA requirements, ~1 GB/s — far above what the 4 KiB-page verification
//! path needs. The polynomial is the same one SSE4.2's `crc32` instruction
//! and every storage system (iSCSI, ext4, Btrfs) uses, so stored checksums
//! stay meaningful if a hardware tier is added to the dispatch table later.

/// Reflected Castagnoli polynomial.
const POLY: u32 = 0x82F6_3B78;

const fn make_tables() -> [[u32; 256]; 8] {
    let mut t = [[0u32; 256]; 8];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut j = 0;
        while j < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            j += 1;
        }
        t[0][i] = crc;
        i += 1;
    }
    let mut k = 1;
    while k < 8 {
        let mut i = 0;
        while i < 256 {
            let prev = t[k - 1][i];
            t[k][i] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            i += 1;
        }
        k += 1;
    }
    t
}

static TABLES: [[u32; 256]; 8] = make_tables();

/// CRC32C of `data` (standard finalization: init `!0`, output inverted).
pub fn crc32c(data: &[u8]) -> u32 {
    let mut crc = !0u32;
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        let lo = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) ^ crc;
        let hi = u32::from_le_bytes([c[4], c[5], c[6], c[7]]);
        crc = TABLES[7][(lo & 0xFF) as usize]
            ^ TABLES[6][((lo >> 8) & 0xFF) as usize]
            ^ TABLES[5][((lo >> 16) & 0xFF) as usize]
            ^ TABLES[4][(lo >> 24) as usize]
            ^ TABLES[3][(hi & 0xFF) as usize]
            ^ TABLES[2][((hi >> 8) & 0xFF) as usize]
            ^ TABLES[1][((hi >> 16) & 0xFF) as usize]
            ^ TABLES[0][(hi >> 24) as usize];
    }
    for &b in chunks.remainder() {
        crc = (crc >> 8) ^ TABLES[0][((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc3720_vectors() {
        // The iSCSI test vectors every CRC32C implementation must match.
        assert_eq!(crc32c(b"123456789"), 0xE306_9283);
        assert_eq!(crc32c(&[0u8; 32]), 0x8A91_36AA);
        assert_eq!(crc32c(&[0xFFu8; 32]), 0x62A8_AB43);
        let ascending: Vec<u8> = (0u8..32).collect();
        assert_eq!(crc32c(&ascending), 0x46DD_794E);
    }

    #[test]
    fn empty_and_incremental_shapes() {
        assert_eq!(crc32c(&[]), 0);
        // Slicing path (≥ 8 bytes) and byte-at-a-time tail must agree with
        // a pure byte-at-a-time reference.
        let data: Vec<u8> = (0..1027u32).map(|i| (i * 131 % 251) as u8).collect();
        let mut reference = !0u32;
        for &b in &data {
            reference = (reference >> 8) ^ TABLES[0][((reference ^ b as u32) & 0xFF) as usize];
        }
        assert_eq!(crc32c(&data), !reference);
    }

    #[test]
    fn single_bit_flip_always_detected() {
        let mut page = vec![0u8; 4096];
        for (i, b) in page.iter_mut().enumerate() {
            *b = (i % 251) as u8;
        }
        let clean = crc32c(&page);
        for bit in [0usize, 7, 1000 * 8 + 3, 4095 * 8 + 7] {
            page[bit / 8] ^= 1 << (bit % 8);
            assert_ne!(crc32c(&page), clean, "bit {bit} undetected");
            page[bit / 8] ^= 1 << (bit % 8);
        }
        assert_eq!(crc32c(&page), clean);
    }
}
