//! Wall-clock stopwatch and duration formatting used by the bench harness
//! and the per-query phase timers (I/O vs compute breakdown, Fig. 2).

use std::time::{Duration, Instant};

/// Accumulating stopwatch: start/stop many times, read the total.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { total: Duration::ZERO, started: None }
    }

    #[inline]
    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch already running");
        self.started = Some(Instant::now());
    }

    #[inline]
    pub fn stop(&mut self) {
        if let Some(t) = self.started.take() {
            self.total += t.elapsed();
        }
    }

    /// Total accumulated time (excludes a currently-running interval).
    pub fn total(&self) -> Duration {
        self.total
    }

    pub fn total_ms(&self) -> f64 {
        self.total.as_secs_f64() * 1e3
    }

    pub fn reset(&mut self) {
        self.total = Duration::ZERO;
        self.started = None;
    }
}

/// Human formatting: `1.23 µs`, `4.56 ms`, `7.89 s`.
pub fn format_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(2));
        sw.stop();
        let first = sw.total();
        assert!(first >= Duration::from_millis(2));
        sw.start();
        std::thread::sleep(Duration::from_millis(2));
        sw.stop();
        assert!(sw.total() > first);
        sw.reset();
        assert_eq!(sw.total(), Duration::ZERO);
    }

    #[test]
    fn stop_without_start_is_noop() {
        let mut sw = Stopwatch::new();
        sw.stop();
        assert_eq!(sw.total(), Duration::ZERO);
    }

    #[test]
    fn formatting_units() {
        assert!(format_duration(Duration::from_secs(2)).ends_with(" s"));
        assert!(format_duration(Duration::from_millis(5)).ends_with(" ms"));
        assert!(format_duration(Duration::from_micros(5)).ends_with(" µs"));
        assert!(format_duration(Duration::from_nanos(5)).ends_with(" ns"));
    }
}
