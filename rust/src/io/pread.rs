//! Positional-read page store (`pread64` through libc) — the portable
//! fallback and the backend the simulated-SSD wrapper defaults to.
//!
//! The read loop distinguishes the three `pread` outcomes precisely:
//! a negative return with `EINTR` is retried (a signal mid-read is not a
//! failure), any other negative return surfaces the real errno, and a
//! zero return is reported as a distinct unexpected-EOF error — folding it
//! into the generic failure path used to print the misleading
//! "pread failed: Success" (errno is not set on EOF).
#![deny(unsafe_op_in_unsafe_fn)]

use super::PageStore;
use crate::util::checked::{to_usize, Ix};
use crate::Result;
use std::os::unix::io::AsRawFd;
use std::path::Path;

pub struct PreadPageStore {
    file: std::fs::File,
    page_size: usize,
    n_pages: usize,
}

impl PreadPageStore {
    pub fn open(path: &Path, page_size: usize) -> Result<Self> {
        let file = std::fs::File::open(path)?;
        let len = to_usize(file.metadata()?.len())?;
        anyhow::ensure!(page_size > 0 && len % page_size == 0, "file not page-aligned");
        Ok(Self { file, page_size, n_pages: len / page_size })
    }
}

impl PageStore for PreadPageStore {
    fn page_size(&self) -> usize {
        self.page_size
    }

    fn n_pages(&self) -> usize {
        self.n_pages
    }

    fn read_pages(&self, page_ids: &[u32], out: &mut [Vec<u8>]) -> Result<()> {
        // An error, not an assert: the default begin_read routes here, and
        // the trait contract promises invalid input surfaces from wait()
        // with the buffers intact rather than panicking the query thread.
        anyhow::ensure!(page_ids.len() == out.len(), "ids/buffers length mismatch");
        let fd = self.file.as_raw_fd();
        for (k, &p) in page_ids.iter().enumerate() {
            anyhow::ensure!(p.ix() < self.n_pages, "page {p} out of range");
            let buf = &mut out[k];
            anyhow::ensure!(buf.len() == self.page_size, "bad buffer size");
            let mut done = 0usize;
            while done < self.page_size {
                // SAFETY: fd is a live File owned by self; the pointer and
                // length describe the tail of `buf`, whose size was checked
                // against page_size above, so the kernel writes in bounds.
                let rc = unsafe {
                    libc::pread64(
                        fd,
                        buf[done..].as_mut_ptr() as *mut libc::c_void,
                        (self.page_size - done) as libc::size_t,
                        (p as i64 * self.page_size as i64 + done as i64) as libc::off64_t,
                    )
                };
                if rc < 0 {
                    let err = std::io::Error::last_os_error();
                    if err.raw_os_error() == Some(libc::EINTR) {
                        continue; // interrupted by a signal: retry, not an error
                    }
                    anyhow::bail!("pread failed: {err}");
                }
                anyhow::ensure!(
                    rc != 0,
                    "pread hit unexpected EOF at page {p} byte {done} (file truncated?)"
                );
                done += usize::try_from(rc)?;
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "pread"
    }
}
