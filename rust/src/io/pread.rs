//! Positional-read page store (`pread64` through libc) — the portable
//! fallback and the backend the simulated-SSD wrapper defaults to.

use super::PageStore;
use crate::Result;
use std::os::unix::io::AsRawFd;
use std::path::Path;

pub struct PreadPageStore {
    file: std::fs::File,
    page_size: usize,
    n_pages: usize,
}

impl PreadPageStore {
    pub fn open(path: &Path, page_size: usize) -> Result<Self> {
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len() as usize;
        anyhow::ensure!(page_size > 0 && len % page_size == 0, "file not page-aligned");
        Ok(Self { file, page_size, n_pages: len / page_size })
    }
}

impl PageStore for PreadPageStore {
    fn page_size(&self) -> usize {
        self.page_size
    }

    fn n_pages(&self) -> usize {
        self.n_pages
    }

    fn read_pages(&self, page_ids: &[u32], out: &mut [Vec<u8>]) -> Result<()> {
        assert_eq!(page_ids.len(), out.len());
        let fd = self.file.as_raw_fd();
        for (k, &p) in page_ids.iter().enumerate() {
            anyhow::ensure!((p as usize) < self.n_pages, "page {p} out of range");
            let buf = &mut out[k];
            anyhow::ensure!(buf.len() == self.page_size, "bad buffer size");
            let mut done = 0usize;
            while done < self.page_size {
                let rc = unsafe {
                    libc::pread64(
                        fd,
                        buf[done..].as_mut_ptr() as *mut libc::c_void,
                        (self.page_size - done) as libc::size_t,
                        (p as i64 * self.page_size as i64 + done as i64) as libc::off64_t,
                    )
                };
                anyhow::ensure!(rc > 0, "pread failed: {}", std::io::Error::last_os_error());
                done += rc as usize;
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "pread"
    }
}
