//! Linux AIO page store: one `io_submit` per batch, one `io_getevents`
//! wait — the paper's §5 I/O engine (io_submit/io_getevents), issued
//! through raw `libc` syscalls (the offline build has no io-uring/tokio).
//!
//! Each `read_pages` call creates its own set of iocbs over a per-thread
//! AIO context, so the store is `Sync` without internal locking beyond the
//! context pool.
//!
//! Error-path contract: once `io_submit` accepts an iocb the kernel owns it
//! — and the buffer it points into — until `io_getevents` returns it. Every
//! submit path here therefore goes through [`submit_all`], which reaps all
//! in-flight iocbs before surfacing a submit failure (and propagates a reap
//! failure instead of discarding it), so no error return ever leaves the
//! kernel writing into freed memory.
#![deny(unsafe_op_in_unsafe_fn)]

use super::PageStore;
use crate::util::checked::{to_usize, Ix};
use crate::util::sync::lock;
use crate::Result;
use std::os::unix::io::AsRawFd;
use std::path::Path;
use std::sync::Mutex;

// Minimal Linux AIO ABI (not exposed by the libc crate).
#[repr(C)]
#[derive(Clone, Copy)]
struct Iocb {
    aio_data: u64,
    aio_key: u32,
    aio_rw_flags: u32,
    aio_lio_opcode: u16,
    aio_reqprio: i16,
    aio_fildes: u32,
    aio_buf: u64,
    aio_nbytes: u64,
    aio_offset: i64,
    aio_reserved2: u64,
    aio_flags: u32,
    aio_resfd: u32,
}

#[repr(C)]
#[derive(Clone, Copy, Default)]
struct IoEvent {
    data: u64,
    obj: u64,
    res: i64,
    res2: i64,
}

const IOCB_CMD_PREAD: u16 = 0;

/// # Safety
/// `ctx` must point to a zeroed `aio_context_t` that outlives the context.
unsafe fn io_setup(nr: libc::c_long, ctx: *mut libc::c_ulong) -> libc::c_long {
    // SAFETY: raw syscall; the caller guarantees `ctx` is a valid out-pointer.
    unsafe { libc::syscall(libc::SYS_io_setup, nr, ctx) }
}

/// # Safety
/// `ctx` must be a live context from `io_setup`, not used again afterwards.
unsafe fn io_destroy(ctx: libc::c_ulong) -> libc::c_long {
    // SAFETY: raw syscall on a caller-guaranteed live context id.
    unsafe { libc::syscall(libc::SYS_io_destroy, ctx) }
}

/// # Safety
/// Every pointer in `iocbs[..n]` must reference a valid `Iocb` whose buffer
/// stays live (and unmoved) until the iocb is reaped by `io_getevents`.
unsafe fn io_submit(ctx: libc::c_ulong, n: libc::c_long, iocbs: *mut *mut Iocb) -> libc::c_long {
    // SAFETY: raw syscall; iocb/buffer lifetimes are the caller's contract.
    unsafe { libc::syscall(libc::SYS_io_submit, ctx, n, iocbs) }
}

/// # Safety
/// `events` must be valid for `max` writes; `timeout` null or valid.
unsafe fn io_getevents(
    ctx: libc::c_ulong,
    min: libc::c_long,
    max: libc::c_long,
    events: *mut IoEvent,
    timeout: *mut libc::timespec,
) -> libc::c_long {
    // SAFETY: raw syscall; the caller sizes `events` for `max` entries.
    unsafe { libc::syscall(libc::SYS_io_getevents, ctx, min, max, events, timeout) }
}

/// A pool of AIO contexts, one leased per in-flight batch.
struct CtxPool {
    free: Mutex<Vec<libc::c_ulong>>,
    depth: usize,
    /// Contexts created at open — the cap on concurrently-async batches.
    total: usize,
}

impl CtxPool {
    fn new(n_ctx: usize, depth: usize) -> Result<Self> {
        let mut free = Vec::with_capacity(n_ctx);
        for _ in 0..n_ctx {
            let mut ctx: libc::c_ulong = 0;
            // SAFETY: `ctx` is a zeroed local that io_setup may write to.
            let rc = unsafe { io_setup(depth as libc::c_long, &mut ctx) };
            if rc != 0 {
                for c in &free {
                    // SAFETY: each id in `free` came from a successful
                    // io_setup and is destroyed exactly once here.
                    unsafe { io_destroy(*c) };
                }
                anyhow::bail!("io_setup failed: {}", std::io::Error::last_os_error());
            }
            free.push(ctx);
        }
        Ok(Self { free: Mutex::new(free), depth, total: n_ctx })
    }

    fn lease(&self) -> Option<libc::c_ulong> {
        lock(&self.free).pop()
    }

    fn put_back(&self, ctx: libc::c_ulong) {
        lock(&self.free).push(ctx);
    }
}

impl Drop for CtxPool {
    fn drop(&mut self) {
        for c in lock(&self.free).iter() {
            // SAFETY: pooled ids are live contexts (leased ones were removed
            // from `free`), each destroyed exactly once as the pool drops.
            unsafe { io_destroy(*c) };
        }
    }
}

pub struct AioPageStore {
    file: std::fs::File,
    page_size: usize,
    n_pages: usize,
    ctxs: CtxPool,
    /// pread fallback for when all contexts are leased.
    fallback: super::PreadPageStore,
}

impl AioPageStore {
    pub fn open(path: &Path, page_size: usize) -> Result<Self> {
        let file = std::fs::File::open(path)?;
        let len = to_usize(file.metadata()?.len())?;
        anyhow::ensure!(page_size > 0 && len % page_size == 0, "file not page-aligned");
        // 2× host threads contexts, each up to 128 in-flight pages.
        let n_ctx = (crate::util::num_threads() * 2).max(4);
        let ctxs = CtxPool::new(n_ctx, 128)?;
        // Smoke-test one submit so we fail over to pread at open() time on
        // kernels that accept io_setup but reject filesystem reads.
        let store = Self {
            fallback: super::PreadPageStore::open(path, page_size)?,
            file,
            page_size,
            n_pages: len / page_size,
            ctxs,
        };
        if store.n_pages > 0 {
            let mut probe = vec![vec![0u8; page_size]];
            store
                .read_batch_aio(&[0], &mut probe)
                .map_err(|e| anyhow::anyhow!("AIO probe read failed: {e}"))?;
        }
        Ok(store)
    }

    fn read_batch_aio(&self, page_ids: &[u32], out: &mut [Vec<u8>]) -> Result<()> {
        let Some(ctx) = self.ctxs.lease() else {
            return self.fallback.read_pages(page_ids, out);
        };
        match self.read_batch_on_ctx(ctx, page_ids, out) {
            Ok(()) => {
                self.ctxs.put_back(ctx);
                Ok(())
            }
            // A clean ctx goes back to the pool; one with iocbs still in
            // flight is destroyed (io_destroy blocks until the kernel
            // releases the buffers) — see `dispose_ctx_on_error`.
            Err(e) => Err(dispose_ctx_on_error(&self.ctxs, ctx, e)),
        }
    }

    fn read_batch_on_ctx(
        &self,
        ctx: libc::c_ulong,
        page_ids: &[u32],
        out: &mut [Vec<u8>],
    ) -> std::result::Result<(), AioBatchError> {
        // lint:allow(truncating-cast): a live File's fd is non-negative, so
        // the i32 → u32 reinterpretation for the iocb field is lossless.
        let fd = self.file.as_raw_fd() as u32;
        let depth = self.ctxs.depth;
        let mut start = 0usize;
        while start < page_ids.len() {
            let end = (start + depth).min(page_ids.len());
            let n = end - start;
            let mut iocbs: Vec<Iocb> = (0..n)
                .map(|k| {
                    let p = page_ids[start + k] as u64;
                    Iocb {
                        aio_data: (start + k) as u64,
                        aio_key: 0,
                        aio_rw_flags: 0,
                        aio_lio_opcode: IOCB_CMD_PREAD,
                        aio_reqprio: 0,
                        aio_fildes: fd,
                        aio_buf: out[start + k].as_mut_ptr() as u64,
                        aio_nbytes: self.page_size as u64,
                        aio_offset: (p * self.page_size as u64) as i64,
                        aio_reserved2: 0,
                        aio_flags: 0,
                        aio_resfd: 0,
                    }
                })
                .collect();
            let mut ptrs: Vec<*mut Iocb> = iocbs.iter_mut().map(|c| c as *mut Iocb).collect();
            // submit_all reaps anything already in flight before it bails
            // and reports what it could not collect, so the caller knows
            // whether `iocbs`/`out` are safe to unwind (`outstanding == 0`)
            // or the ctx must be destroyed first. Each chunk is fully
            // reaped before the next one is built (`reap` blocks until all
            // `n` complete).
            submit_all(ctx, &mut ptrs, self.page_size, io_submit)?;
            reap(ctx, n, self.page_size)?;
            start = end;
        }
        Ok(())
    }
}

/// The `io_submit`-shaped entry point [`submit_all`] drives. Tests inject a
/// fault here; production passes [`io_submit`] itself.
///
/// # Safety
/// Implementations inherit [`io_submit`]'s contract: every iocb (and the
/// buffer it points into) referenced by the pointer array must stay live
/// until reaped.
type SubmitFn = unsafe fn(libc::c_ulong, libc::c_long, *mut *mut Iocb) -> libc::c_long;

/// Error from the submit/reap path. `outstanding > 0` means the kernel
/// still owns that many iocbs on the ctx — the ctx must go through
/// [`dispose_ctx_on_error`] (which destroys it) rather than back into the
/// pool, or the next lease would reap this batch's stale completions as
/// its own.
struct AioBatchError {
    outstanding: usize,
    msg: String,
}

/// Route a failed batch's ctx to safety and produce the caller-facing
/// error. A clean ctx (all completions collected, e.g. a short read) goes
/// back to the pool. A dirty ctx is destroyed instead: `io_destroy`
/// cancels what it can and **blocks until the kernel has released every
/// remaining buffer**, so the caller may free its buffers the moment this
/// returns — the module's no-use-after-free contract holds even here. The
/// pool permanently shrinks by one ctx; overflow leases already fall back
/// to pread.
fn dispose_ctx_on_error(ctxs: &CtxPool, ctx: libc::c_ulong, e: AioBatchError) -> anyhow::Error {
    if e.outstanding == 0 {
        ctxs.put_back(ctx);
        anyhow::anyhow!("{}", e.msg)
    } else {
        // SAFETY: `ctx` was leased (removed from the pool), so this is its
        // sole owner; it is destroyed once and never used again.
        let rc = unsafe { io_destroy(ctx) };
        if rc == 0 {
            anyhow::anyhow!(
                "{} ({} iocbs were outstanding; AIO ctx destroyed to reclaim kernel-owned buffers)",
                e.msg,
                e.outstanding
            )
        } else {
            // Destruction itself failed: the kernel may still own the
            // buffers. Nothing more can be done here, but the caller must
            // not be told they were reclaimed.
            anyhow::anyhow!(
                "{} ({} iocbs outstanding AND io_destroy failed: {} — kernel may still own the read buffers)",
                e.msg,
                e.outstanding,
                std::io::Error::last_os_error()
            )
        }
    }
}

/// Submit every iocb in `ptrs`, looping over partial submissions. On a
/// failed `io_submit` this **reaps everything already submitted before
/// returning the error**: the kernel owns the iocbs and their target
/// buffers until `io_getevents` yields them back, so bailing without the
/// reap lets completions land in memory the caller has since freed
/// (use-after-free). A reap failure on this path is folded into the
/// returned error rather than discarded — a short read while unwinding
/// must not be swallowed, and `outstanding` reports any iocbs the kernel
/// still holds.
fn submit_all(
    ctx: libc::c_ulong,
    ptrs: &mut [*mut Iocb],
    page_size: usize,
    submit: SubmitFn,
) -> std::result::Result<(), AioBatchError> {
    let n = ptrs.len();
    let mut submitted = 0usize;
    while submitted < n {
        let remaining = (n - submitted) as libc::c_long;
        // SAFETY: every pointer in `ptrs` references an iocb in the caller's
        // live `iocbs` vec, whose buffers stay allocated until `reap`
        // collects them (or this function reaps on the error path below).
        let rc = unsafe { submit(ctx, remaining, ptrs[submitted..].as_mut_ptr()) };
        if rc <= 0 {
            let err = std::io::Error::last_os_error();
            let msg = format!("io_submit failed after {submitted}/{n}: {err}");
            return match reap(ctx, submitted, page_size) {
                Ok(()) => Err(AioBatchError { outstanding: 0, msg }),
                Err(re) => Err(AioBatchError {
                    outstanding: re.outstanding,
                    msg: format!("{msg}; reaping in-flight reads also failed: {}", re.msg),
                }),
            };
        }
        // lint:allow(truncating-cast): rc ≥ 1 here (the ≤ 0 branch returned
        // above), and a positive c_long submit count always fits usize.
        submitted += rc as usize;
    }
    Ok(())
}

impl AioPageStore {
    fn validate(&self, page_ids: &[u32], out: &[Vec<u8>]) -> Result<()> {
        // An error, not an assert: the trait's multi-batch contract says
        // invalid input surfaces from wait() with the buffers intact.
        anyhow::ensure!(page_ids.len() == out.len(), "ids/buffers length mismatch");
        for (&p, buf) in page_ids.iter().zip(out.iter()) {
            anyhow::ensure!(p.ix() < self.n_pages, "page {p} out of range");
            anyhow::ensure!(buf.len() == self.page_size, "bad buffer size");
        }
        Ok(())
    }

    /// Submit now; completion happens in the returned waiter (io_getevents)
    /// — the paper's §5 submit/compute/getevents pipeline primitive. Takes
    /// ownership of the buffers and hands them back from `wait` (even on
    /// error), per the trait's multi-batch contract; each batch leases its
    /// own AIO context, so up to `ctxs.total` batches can be in flight.
    fn submit_only(&self, page_ids: &[u32], mut bufs: Vec<Vec<u8>>) -> super::PendingRead<'_> {
        let n = page_ids.len();
        if n == 0 {
            return super::PendingRead::done(bufs, Ok(()));
        }
        // Deep overflow or no free context: fall back to synchronous.
        let Some(ctx) = (n <= self.ctxs.depth).then(|| self.ctxs.lease()).flatten() else {
            let result = self.read_batch_aio(page_ids, &mut bufs);
            return super::PendingRead::done(bufs, result);
        };
        // lint:allow(truncating-cast): a live File's fd is non-negative, so
        // the i32 → u32 reinterpretation for the iocb field is lossless.
        let fd = self.file.as_raw_fd() as u32;
        let mut iocbs: Vec<Iocb> = (0..n)
            .map(|k| Iocb {
                aio_data: k as u64,
                aio_key: 0,
                aio_rw_flags: 0,
                aio_lio_opcode: IOCB_CMD_PREAD,
                aio_reqprio: 0,
                aio_fildes: fd,
                aio_buf: bufs[k].as_mut_ptr() as u64,
                aio_nbytes: self.page_size as u64,
                aio_offset: (page_ids[k] as u64 * self.page_size as u64) as i64,
                aio_reserved2: 0,
                aio_flags: 0,
                aio_resfd: 0,
            })
            .collect();
        let mut ptrs: Vec<*mut Iocb> = iocbs.iter_mut().map(|c| c as *mut Iocb).collect();
        // Partial-submit failure: submit_all reaps what went out (and folds
        // a reap error into the returned one instead of discarding it)
        // before bailing; disposal then pools or destroys the ctx depending
        // on whether the kernel still owns iocbs. Either way nothing stays
        // in flight, so the buffers go straight back to the caller.
        if let Err(e) = submit_all(ctx, &mut ptrs, self.page_size, io_submit) {
            let err = dispose_ctx_on_error(&self.ctxs, ctx, e);
            return super::PendingRead::done(bufs, Err(err));
        }
        let page_size = self.page_size;
        let ctxs = &self.ctxs;
        // `bufs` moves into the closure: moving the outer Vec does not move
        // the heap blocks the submitted iocbs point into.
        super::PendingRead::deferred(move || {
            let result = match reap(ctx, n, page_size) {
                Ok(()) => {
                    ctxs.put_back(ctx);
                    Ok(())
                }
                Err(e) => Err(dispose_ctx_on_error(ctxs, ctx, e)),
            };
            (bufs, result)
        })
    }
}

/// Collect `n` completions on `ctx`, verifying full-page reads. Retries
/// `EINTR` — an interrupted wait must not strand in-flight iocbs (the
/// kernel would keep writing into buffers the caller then frees). A short
/// read fails with `outstanding = 0` (every completion was collected; the
/// ctx is clean); a hard `io_getevents` failure reports how many iocbs the
/// kernel still owns so the caller can destroy the ctx instead of pooling
/// it.
fn reap(ctx: libc::c_ulong, n: usize, page_size: usize) -> std::result::Result<(), AioBatchError> {
    if n == 0 {
        return Ok(());
    }
    let mut events = vec![IoEvent::default(); n];
    let mut got = 0usize;
    while got < n {
        // SAFETY: `events[got..]` holds exactly `n - got` writable entries,
        // matching the `max` argument; the timeout pointer is null.
        let rc = unsafe {
            io_getevents(
                ctx,
                1,
                (n - got) as libc::c_long,
                events[got..].as_mut_ptr(),
                std::ptr::null_mut(),
            )
        };
        if rc < 0 {
            let err = std::io::Error::last_os_error();
            if err.raw_os_error() == Some(libc::EINTR) {
                continue;
            }
            return Err(AioBatchError {
                outstanding: n - got,
                msg: format!("io_getevents failed with {got}/{n} reaped: {err}"),
            });
        }
        if rc == 0 {
            return Err(AioBatchError {
                outstanding: n - got,
                msg: format!("io_getevents returned 0 with {got}/{n} reaped"),
            });
        }
        // lint:allow(truncating-cast): rc ≥ 1 here (negative and zero
        // returns were handled above), so the c_long count fits usize.
        got += rc as usize;
    }
    for ev in &events {
        if ev.res != page_size as i64 {
            return Err(AioBatchError {
                outstanding: 0,
                msg: format!("aio read returned {} (want {page_size})", ev.res),
            });
        }
    }
    Ok(())
}

impl PageStore for AioPageStore {
    fn page_size(&self) -> usize {
        self.page_size
    }

    fn n_pages(&self) -> usize {
        self.n_pages
    }

    fn read_pages(&self, page_ids: &[u32], out: &mut [Vec<u8>]) -> Result<()> {
        if page_ids.is_empty() {
            return Ok(());
        }
        self.validate(page_ids, out)?;
        self.read_batch_aio(page_ids, out)
    }

    fn begin_read(&self, page_ids: &[u32], bufs: Vec<Vec<u8>>) -> super::PendingRead<'_> {
        if let Err(e) = self.validate(page_ids, &bufs) {
            return super::PendingRead::done(bufs, Err(e));
        }
        self.submit_only(page_ids, bufs)
    }

    fn max_inflight_batches(&self) -> usize {
        self.ctxs.total
    }

    fn name(&self) -> &'static str {
        "linux-aio"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    static FAULTY_CALLS: AtomicUsize = AtomicUsize::new(0);
    static FAULTY2_CALLS: AtomicUsize = AtomicUsize::new(0);

    /// Fault injection for [`submit_all`]: submits exactly one iocb for
    /// real on the first call, then fails with `EINVAL` — a deterministic
    /// partial-submit failure with work genuinely in flight.
    ///
    /// # Safety
    /// Same contract as [`io_submit`]: every iocb/buffer referenced by
    /// `iocbs[..n]` must stay live until reaped.
    unsafe fn faulty_submit(
        ctx: libc::c_ulong,
        n: libc::c_long,
        iocbs: *mut *mut Iocb,
    ) -> libc::c_long {
        if FAULTY_CALLS.fetch_add(1, Ordering::SeqCst) == 0 && n >= 1 {
            // SAFETY: forwards the caller's io_submit contract unchanged.
            unsafe { io_submit(ctx, 1, iocbs) }
        } else {
            // SAFETY: errno_location is a valid thread-local pointer.
            unsafe { *libc::__errno_location() = libc::EINVAL };
            -1
        }
    }

    /// Same shape with its own counter (tests run concurrently).
    ///
    /// # Safety
    /// Same contract as [`io_submit`].
    unsafe fn faulty_submit2(
        ctx: libc::c_ulong,
        n: libc::c_long,
        iocbs: *mut *mut Iocb,
    ) -> libc::c_long {
        if FAULTY2_CALLS.fetch_add(1, Ordering::SeqCst) == 0 && n >= 1 {
            // SAFETY: forwards the caller's io_submit contract unchanged.
            unsafe { io_submit(ctx, 1, iocbs) }
        } else {
            // SAFETY: errno_location is a valid thread-local pointer.
            unsafe { *libc::__errno_location() = libc::EINVAL };
            -1
        }
    }

    fn mk_iocbs(fd: u32, bufs: &mut [Vec<u8>]) -> Vec<Iocb> {
        bufs.iter_mut()
            .enumerate()
            .map(|(k, buf)| Iocb {
                aio_data: k as u64,
                aio_key: 0,
                aio_rw_flags: 0,
                aio_lio_opcode: IOCB_CMD_PREAD,
                aio_reqprio: 0,
                aio_fildes: fd,
                aio_buf: buf.as_mut_ptr() as u64,
                aio_nbytes: 4096,
                aio_offset: (k * 4096) as i64,
                aio_reserved2: 0,
                aio_flags: 0,
                aio_resfd: 0,
            })
            .collect()
    }

    #[test]
    fn partial_submit_failure_reaps_in_flight_iocbs() {
        let path =
            std::env::temp_dir().join(format!("pageann-aio-fault-{}", std::process::id()));
        crate::io::write_test_pages(&path, 4096, 8);
        let store = match AioPageStore::open(&path, 4096) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("AIO unavailable in this environment: {e}");
                let _ = std::fs::remove_file(&path);
                return;
            }
        };
        let ctx = store.ctxs.lease().expect("fresh store must have free ctxs");
        let fd = store.file.as_raw_fd() as u32;
        let mut bufs: Vec<Vec<u8>> = (0..4).map(|_| vec![0u8; 4096]).collect();
        let mut iocbs = mk_iocbs(fd, &mut bufs);
        let mut ptrs: Vec<*mut Iocb> = iocbs.iter_mut().map(|c| c as *mut Iocb).collect();
        FAULTY_CALLS.store(0, Ordering::SeqCst);
        let err = submit_all(ctx, &mut ptrs, 4096, faulty_submit).unwrap_err();
        assert!(err.msg.contains("io_submit failed"), "unexpected error: {}", err.msg);
        assert_eq!(err.outstanding, 0, "reap must have collected the in-flight iocb");
        // The iocb submitted before the failure was reaped before the error
        // surfaced: a zero-timeout getevents must find the ctx empty…
        let mut events = [IoEvent::default(); 8];
        let mut zero = libc::timespec { tv_sec: 0, tv_nsec: 0 };
        // SAFETY: `events` holds 8 writable entries matching `max`, and the
        // timeout points at a live timespec.
        let rc = unsafe { io_getevents(ctx, 0, 8, events.as_mut_ptr(), &mut zero) };
        assert_eq!(rc, 0, "in-flight iocbs left unreaped on the error path");
        // …and its read has fully landed in the (still-live) buffer.
        for (i, &b) in bufs[0].iter().enumerate() {
            assert_eq!(b, (i % 251) as u8, "page 0 byte {i}");
        }
        store.ctxs.put_back(ctx);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn failed_submit_returns_ctx_and_store_keeps_working() {
        // End-to-end on the disposal path: inject a partial-submit failure
        // on a leased ctx, route it through `dispose_ctx_on_error` exactly
        // as the public paths do (clean ctx → pooled), then verify the pool
        // still serves correct batched reads.
        let path =
            std::env::temp_dir().join(format!("pageann-aio-recover-{}", std::process::id()));
        crate::io::write_test_pages(&path, 4096, 8);
        let store = match AioPageStore::open(&path, 4096) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("AIO unavailable in this environment: {e}");
                let _ = std::fs::remove_file(&path);
                return;
            }
        };
        let free_before = store.ctxs.free.lock().unwrap().len();
        let ctx = store.ctxs.lease().expect("fresh store must have free ctxs");
        let fd = store.file.as_raw_fd() as u32;
        let mut bufs: Vec<Vec<u8>> = (0..3).map(|_| vec![0u8; 4096]).collect();
        let mut iocbs = mk_iocbs(fd, &mut bufs);
        let mut ptrs: Vec<*mut Iocb> = iocbs.iter_mut().map(|c| c as *mut Iocb).collect();
        FAULTY2_CALLS.store(0, Ordering::SeqCst);
        let err = submit_all(ctx, &mut ptrs, 4096, faulty_submit2).unwrap_err();
        assert_eq!(err.outstanding, 0);
        let e = dispose_ctx_on_error(&store.ctxs, ctx, err);
        assert!(e.to_string().contains("io_submit failed"), "unexpected error: {e}");
        // The clean ctx went back to the pool, not into io_destroy.
        assert_eq!(store.ctxs.free.lock().unwrap().len(), free_before);
        // And the store still serves correct reads through the pool.
        let ids = vec![3u32, 1, 7];
        let mut bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; 4096]).collect();
        store.read_pages(&ids, &mut bufs).unwrap();
        for (k, &p) in ids.iter().enumerate() {
            for (i, &b) in bufs[k].iter().enumerate() {
                assert_eq!(b, ((p as usize * 131 + i) % 251) as u8, "page {p} byte {i}");
            }
        }
        std::fs::remove_file(&path).unwrap();
    }
}
