//! Linux AIO page store: one `io_submit` per batch, one `io_getevents`
//! wait — the paper's §5 I/O engine (io_submit/io_getevents), issued
//! through raw `libc` syscalls (the offline build has no io-uring/tokio).
//!
//! Each `read_pages` call creates its own set of iocbs over a per-thread
//! AIO context, so the store is `Sync` without internal locking beyond the
//! context pool.

use super::PageStore;
use crate::Result;
use std::os::unix::io::AsRawFd;
use std::path::Path;
use std::sync::Mutex;

// Minimal Linux AIO ABI (not exposed by the libc crate).
#[repr(C)]
#[derive(Clone, Copy)]
struct Iocb {
    aio_data: u64,
    aio_key: u32,
    aio_rw_flags: u32,
    aio_lio_opcode: u16,
    aio_reqprio: i16,
    aio_fildes: u32,
    aio_buf: u64,
    aio_nbytes: u64,
    aio_offset: i64,
    aio_reserved2: u64,
    aio_flags: u32,
    aio_resfd: u32,
}

#[repr(C)]
#[derive(Clone, Copy, Default)]
struct IoEvent {
    data: u64,
    obj: u64,
    res: i64,
    res2: i64,
}

const IOCB_CMD_PREAD: u16 = 0;

unsafe fn io_setup(nr: libc::c_long, ctx: *mut libc::c_ulong) -> libc::c_long {
    libc::syscall(libc::SYS_io_setup, nr, ctx)
}

unsafe fn io_destroy(ctx: libc::c_ulong) -> libc::c_long {
    libc::syscall(libc::SYS_io_destroy, ctx)
}

unsafe fn io_submit(ctx: libc::c_ulong, n: libc::c_long, iocbs: *mut *mut Iocb) -> libc::c_long {
    libc::syscall(libc::SYS_io_submit, ctx, n, iocbs)
}

unsafe fn io_getevents(
    ctx: libc::c_ulong,
    min: libc::c_long,
    max: libc::c_long,
    events: *mut IoEvent,
    timeout: *mut libc::timespec,
) -> libc::c_long {
    libc::syscall(libc::SYS_io_getevents, ctx, min, max, events, timeout)
}

/// A pool of AIO contexts, one leased per in-flight batch.
struct CtxPool {
    free: Mutex<Vec<libc::c_ulong>>,
    depth: usize,
}

impl CtxPool {
    fn new(n_ctx: usize, depth: usize) -> Result<Self> {
        let mut free = Vec::with_capacity(n_ctx);
        for _ in 0..n_ctx {
            let mut ctx: libc::c_ulong = 0;
            let rc = unsafe { io_setup(depth as libc::c_long, &mut ctx) };
            if rc != 0 {
                for c in &free {
                    unsafe { io_destroy(*c) };
                }
                anyhow::bail!("io_setup failed: {}", std::io::Error::last_os_error());
            }
            free.push(ctx);
        }
        Ok(Self { free: Mutex::new(free), depth })
    }

    fn lease(&self) -> Option<libc::c_ulong> {
        self.free.lock().unwrap().pop()
    }

    fn put_back(&self, ctx: libc::c_ulong) {
        self.free.lock().unwrap().push(ctx);
    }
}

impl Drop for CtxPool {
    fn drop(&mut self) {
        for c in self.free.lock().unwrap().iter() {
            unsafe { io_destroy(*c) };
        }
    }
}

pub struct AioPageStore {
    file: std::fs::File,
    page_size: usize,
    n_pages: usize,
    ctxs: CtxPool,
    /// pread fallback for when all contexts are leased.
    fallback: super::PreadPageStore,
}

impl AioPageStore {
    pub fn open(path: &Path, page_size: usize) -> Result<Self> {
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len() as usize;
        anyhow::ensure!(page_size > 0 && len % page_size == 0, "file not page-aligned");
        // 2× host threads contexts, each up to 128 in-flight pages.
        let n_ctx = (crate::util::num_threads() * 2).max(4);
        let ctxs = CtxPool::new(n_ctx, 128)?;
        // Smoke-test one submit so we fail over to pread at open() time on
        // kernels that accept io_setup but reject filesystem reads.
        let store = Self {
            fallback: super::PreadPageStore::open(path, page_size)?,
            file,
            page_size,
            n_pages: len / page_size,
            ctxs,
        };
        if store.n_pages > 0 {
            let mut probe = vec![vec![0u8; page_size]];
            store
                .read_batch_aio(&[0], &mut probe)
                .map_err(|e| anyhow::anyhow!("AIO probe read failed: {e}"))?;
        }
        Ok(store)
    }

    fn read_batch_aio(&self, page_ids: &[u32], out: &mut [Vec<u8>]) -> Result<()> {
        let Some(ctx) = self.ctxs.lease() else {
            return self.fallback.read_pages(page_ids, out);
        };
        let result = self.read_batch_on_ctx(ctx, page_ids, out);
        self.ctxs.put_back(ctx);
        result
    }

    fn read_batch_on_ctx(
        &self,
        ctx: libc::c_ulong,
        page_ids: &[u32],
        out: &mut [Vec<u8>],
    ) -> Result<()> {
        let fd = self.file.as_raw_fd() as u32;
        let depth = self.ctxs.depth;
        let mut start = 0usize;
        while start < page_ids.len() {
            let end = (start + depth).min(page_ids.len());
            let n = end - start;
            let mut iocbs: Vec<Iocb> = (0..n)
                .map(|k| {
                    let p = page_ids[start + k] as u64;
                    Iocb {
                        aio_data: (start + k) as u64,
                        aio_key: 0,
                        aio_rw_flags: 0,
                        aio_lio_opcode: IOCB_CMD_PREAD,
                        aio_reqprio: 0,
                        aio_fildes: fd,
                        aio_buf: out[start + k].as_mut_ptr() as u64,
                        aio_nbytes: self.page_size as u64,
                        aio_offset: (p * self.page_size as u64) as i64,
                        aio_reserved2: 0,
                        aio_flags: 0,
                        aio_resfd: 0,
                    }
                })
                .collect();
            let mut ptrs: Vec<*mut Iocb> = iocbs.iter_mut().map(|c| c as *mut Iocb).collect();
            let mut submitted = 0usize;
            while submitted < n {
                let rc = unsafe {
                    io_submit(ctx, (n - submitted) as libc::c_long, ptrs[submitted..].as_mut_ptr())
                };
                anyhow::ensure!(rc > 0, "io_submit failed: {}", std::io::Error::last_os_error());
                submitted += rc as usize;
            }
            let mut events = vec![IoEvent::default(); n];
            let mut got = 0usize;
            while got < n {
                let rc = unsafe {
                    io_getevents(
                        ctx,
                        1,
                        (n - got) as libc::c_long,
                        events[got..].as_mut_ptr(),
                        std::ptr::null_mut(),
                    )
                };
                anyhow::ensure!(rc > 0, "io_getevents failed: {}", std::io::Error::last_os_error());
                got += rc as usize;
            }
            for ev in &events {
                anyhow::ensure!(
                    ev.res == self.page_size as i64,
                    "aio read returned {} (want {})",
                    ev.res,
                    self.page_size
                );
            }
            start = end;
        }
        Ok(())
    }
}

impl AioPageStore {
    fn validate(&self, page_ids: &[u32], out: &[Vec<u8>]) -> Result<()> {
        assert_eq!(page_ids.len(), out.len());
        for (&p, buf) in page_ids.iter().zip(out.iter()) {
            anyhow::ensure!((p as usize) < self.n_pages, "page {p} out of range");
            anyhow::ensure!(buf.len() == self.page_size, "bad buffer size");
        }
        Ok(())
    }

    /// Submit now; completion happens in the returned waiter (io_getevents)
    /// — the paper's §5 submit/compute/getevents pipeline primitive.
    fn submit_only<'a>(
        &'a self,
        page_ids: &[u32],
        out: &'a mut [Vec<u8>],
    ) -> Result<super::PendingRead<'a>> {
        let n = page_ids.len();
        if n == 0 {
            return Ok(super::PendingRead::ready());
        }
        // Deep overflow or no free context: fall back to synchronous.
        let Some(ctx) = (n <= self.ctxs.depth).then(|| self.ctxs.lease()).flatten() else {
            self.read_batch_aio(page_ids, out)?;
            return Ok(super::PendingRead::ready());
        };
        let fd = self.file.as_raw_fd() as u32;
        let mut iocbs: Vec<Iocb> = (0..n)
            .map(|k| Iocb {
                aio_data: k as u64,
                aio_key: 0,
                aio_rw_flags: 0,
                aio_lio_opcode: IOCB_CMD_PREAD,
                aio_reqprio: 0,
                aio_fildes: fd,
                aio_buf: out[k].as_mut_ptr() as u64,
                aio_nbytes: self.page_size as u64,
                aio_offset: (page_ids[k] as u64 * self.page_size as u64) as i64,
                aio_reserved2: 0,
                aio_flags: 0,
                aio_resfd: 0,
            })
            .collect();
        let mut ptrs: Vec<*mut Iocb> = iocbs.iter_mut().map(|c| c as *mut Iocb).collect();
        let mut submitted = 0usize;
        while submitted < n {
            let rc = unsafe {
                io_submit(ctx, (n - submitted) as libc::c_long, ptrs[submitted..].as_mut_ptr())
            };
            if rc <= 0 {
                // Partial-submit failure: reap what went out, then bail.
                let err = std::io::Error::last_os_error();
                reap(ctx, submitted, self.page_size);
                self.ctxs.put_back(ctx);
                anyhow::bail!("io_submit failed: {err}");
            }
            submitted += rc as usize;
        }
        let page_size = self.page_size;
        let ctxs = &self.ctxs;
        Ok(super::PendingRead::deferred(move || {
            let result = reap(ctx, n, page_size);
            ctxs.put_back(ctx);
            result
        }))
    }
}

/// Collect `n` completions on `ctx`, verifying full-page reads.
fn reap(ctx: libc::c_ulong, n: usize, page_size: usize) -> Result<()> {
    if n == 0 {
        return Ok(());
    }
    let mut events = vec![IoEvent::default(); n];
    let mut got = 0usize;
    while got < n {
        let rc = unsafe {
            io_getevents(
                ctx,
                1,
                (n - got) as libc::c_long,
                events[got..].as_mut_ptr(),
                std::ptr::null_mut(),
            )
        };
        anyhow::ensure!(rc > 0, "io_getevents failed: {}", std::io::Error::last_os_error());
        got += rc as usize;
    }
    for ev in &events {
        anyhow::ensure!(
            ev.res == page_size as i64,
            "aio read returned {} (want {page_size})",
            ev.res
        );
    }
    Ok(())
}

impl PageStore for AioPageStore {
    fn page_size(&self) -> usize {
        self.page_size
    }

    fn n_pages(&self) -> usize {
        self.n_pages
    }

    fn read_pages(&self, page_ids: &[u32], out: &mut [Vec<u8>]) -> Result<()> {
        if page_ids.is_empty() {
            return Ok(());
        }
        self.validate(page_ids, out)?;
        self.read_batch_aio(page_ids, out)
    }

    fn begin_read<'a>(&'a self, page_ids: &[u32], out: &'a mut [Vec<u8>]) -> Result<super::PendingRead<'a>> {
        self.validate(page_ids, out)?;
        self.submit_only(page_ids, out)
    }

    fn name(&self) -> &'static str {
        "linux-aio"
    }
}
