//! Deterministic fault injection (ISSUE 6).
//!
//! [`FaultStore`] wraps any [`PageStore`] — uring, AIO, pread, or the
//! sim-SSD model — and injects seeded, reproducible failures so every
//! recovery path in the search/engine layers can be exercised in tests and
//! CI without flaky hardware:
//!
//! * **transient EIO** — a page read fails with an I/O error but the next
//!   attempt may succeed (`eio_rate`, plus `fail_first` for a guaranteed
//!   fail-N-then-succeed schedule per page);
//! * **bit flips** — the read "succeeds" but one bit in the returned
//!   buffer is wrong (`flip_every`), which only the CRC32C page tail can
//!   catch;
//! * **torn reads** — the tail half of the buffer is stale zeros, as a
//!   partial write/read leaves it (`torn_every`);
//! * **latency spikes** — every Nth batch sleeps `spike_us` before
//!   completing (`spike_every`), for deadline/timeout tests;
//! * **dead pages** — pages in `dead` fail every attempt (permanent loss),
//!   forcing the degraded-traversal path.
//!
//! All decisions derive from an explicit `seed` plus atomic read/batch
//! counters, so a given config replays the same fault schedule regardless
//! of wall-clock timing. Configure programmatically via
//! [`crate::engine::OpenOptions`] or externally via the `PAGEANN_FAULTS`
//! environment variable (see [`FaultConfig::parse`] for the grammar).
//!
//! Error semantics follow the batch API: any injected EIO inside a batch
//! fails the whole `read_pages`/`wait` call (mirroring how the real
//! backends report batch failures), while corruption faults leave the call
//! "successful" — detection is the checksum layer's job.

use super::{PageStore, PendingRead};
use crate::util::sync::lock;
use crate::util::XorShift;
use crate::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// What to do to one page read. Decided up front (advancing the seeded
/// schedule) and applied after the inner read completes.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Fault {
    None,
    /// Fail the batch with a transient I/O error.
    Eio,
    /// Flip one bit at this offset (bits, within the page).
    Flip(usize),
    /// Zero the buffer from this byte offset on.
    Torn(usize),
}

/// Injection knobs. `Default` injects nothing.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Seed for the fault schedule (EIO draws, flip positions).
    pub seed: u64,
    /// Probability in `[0, 1]` that a page read draws a transient EIO.
    pub eio_rate: f64,
    /// Every Nth page read gets one bit flipped (0 = off).
    pub flip_every: u64,
    /// Every Nth page read comes back torn — tail half zeroed (0 = off).
    pub torn_every: u64,
    /// Every Nth batch sleeps [`FaultConfig::spike`] before completing
    /// (0 = off).
    pub spike_every: u64,
    /// Latency-spike duration.
    pub spike: Duration,
    /// The first N reads of *every* page fail with EIO, then succeed —
    /// a deterministic retry-depth probe.
    pub fail_first: u32,
    /// Pages that fail every read (permanent loss).
    pub dead: Vec<u32>,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            seed: 0x5EED,
            eio_rate: 0.0,
            flip_every: 0,
            torn_every: 0,
            spike_every: 0,
            spike: Duration::from_micros(500),
            fail_first: 0,
            dead: Vec::new(),
        }
    }
}

impl FaultConfig {
    /// True when no knob is set — wrapping would be pure overhead.
    pub fn is_noop(&self) -> bool {
        self.eio_rate <= 0.0
            && self.flip_every == 0
            && self.torn_every == 0
            && self.spike_every == 0
            && self.fail_first == 0
            && self.dead.is_empty()
    }

    /// Parse the `PAGEANN_FAULTS` grammar: comma-separated `key=value`
    /// pairs, unknown keys rejected.
    ///
    /// ```text
    /// seed=7,eio=0.05,flip_every=97,torn_every=0,spike_every=64,spike_us=500,fail_first=2,dead=3:17
    /// ```
    ///
    /// `dead` takes `:`-separated page ids. An empty string parses to the
    /// no-op config.
    pub fn parse(s: &str) -> Result<Self> {
        let mut cfg = Self::default();
        for pair in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = pair
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("PAGEANN_FAULTS: expected key=value, got {pair:?}"))?;
            let bad = |e: &dyn std::fmt::Display| {
                anyhow::anyhow!("PAGEANN_FAULTS: bad value for {key}: {e}")
            };
            match key {
                "seed" => cfg.seed = val.parse().map_err(|e| bad(&e))?,
                "eio" => {
                    cfg.eio_rate = val.parse().map_err(|e| bad(&e))?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&cfg.eio_rate),
                        "PAGEANN_FAULTS: eio must be in [0,1], got {}",
                        cfg.eio_rate
                    );
                }
                "flip_every" => cfg.flip_every = val.parse().map_err(|e| bad(&e))?,
                "torn_every" => cfg.torn_every = val.parse().map_err(|e| bad(&e))?,
                "spike_every" => cfg.spike_every = val.parse().map_err(|e| bad(&e))?,
                "spike_us" => {
                    cfg.spike = Duration::from_micros(val.parse().map_err(|e| bad(&e))?)
                }
                "fail_first" => cfg.fail_first = val.parse().map_err(|e| bad(&e))?,
                "dead" => {
                    for id in val.split(':').filter(|v| !v.is_empty()) {
                        cfg.dead.push(id.parse().map_err(|e| bad(&e))?);
                    }
                }
                other => anyhow::bail!("PAGEANN_FAULTS: unknown key {other:?}"),
            }
        }
        Ok(cfg)
    }

    /// Read `PAGEANN_FAULTS` from the environment. `None` when unset or
    /// set to a no-op config; a malformed value is a hard error (silently
    /// ignoring a typo'd fault spec would fake passing fault tests).
    pub fn from_env() -> Result<Option<Self>> {
        match std::env::var("PAGEANN_FAULTS") {
            Ok(s) if !s.trim().is_empty() => {
                let cfg = Self::parse(&s)?;
                Ok(if cfg.is_noop() { None } else { Some(cfg) })
            }
            _ => Ok(None),
        }
    }
}

/// Injection totals — what actually fired, for test assertions and CI
/// logs. All monotonic.
#[derive(Debug, Default)]
pub struct FaultCounters {
    pub eio: AtomicU64,
    pub flips: AtomicU64,
    pub torn: AtomicU64,
    pub spikes: AtomicU64,
}

impl FaultCounters {
    pub fn total_injected(&self) -> u64 {
        self.eio.load(Ordering::Relaxed)
            + self.flips.load(Ordering::Relaxed)
            + self.torn.load(Ordering::Relaxed)
    }
}

/// A [`PageStore`] wrapper that injects the configured faults. Composable:
/// wrap the raw backend, or wrap the sim-SSD wrapper to model a flaky
/// device with realistic latencies.
pub struct FaultStore {
    inner: Box<dyn PageStore>,
    cfg: FaultConfig,
    rng: Mutex<XorShift>,
    /// Per-page-read sequence number driving the every-Nth knobs.
    reads: AtomicU64,
    /// Batch sequence number driving latency spikes.
    batches: AtomicU64,
    /// Remaining `fail_first` countdown per page (absent = exhausted).
    remaining_fails: Mutex<HashMap<u32, u32>>,
    counters: FaultCounters,
}

impl FaultStore {
    pub fn new(inner: Box<dyn PageStore>, cfg: FaultConfig) -> Self {
        let rng = Mutex::new(XorShift::new(cfg.seed));
        Self {
            inner,
            cfg,
            rng,
            reads: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            remaining_fails: Mutex::new(HashMap::new()),
            counters: FaultCounters::default(),
        }
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    pub fn counters(&self) -> &FaultCounters {
        &self.counters
    }

    /// Decide the fault for one page read, advancing the deterministic
    /// schedule. Priority: dead page > fail-first countdown > random EIO >
    /// periodic corruption.
    fn decide(&self, page: u32) -> Fault {
        if self.cfg.dead.contains(&page) {
            return Fault::Eio;
        }
        let seq = self.reads.fetch_add(1, Ordering::Relaxed) + 1;
        if self.cfg.fail_first > 0 {
            let mut map = lock(&self.remaining_fails);
            let left = map.entry(page).or_insert(self.cfg.fail_first);
            if *left > 0 {
                *left -= 1;
                return Fault::Eio;
            }
        }
        if self.cfg.eio_rate > 0.0 {
            let draw = lock(&self.rng).next_f64();
            if draw < self.cfg.eio_rate {
                return Fault::Eio;
            }
        }
        if self.cfg.flip_every > 0 && seq % self.cfg.flip_every == 0 {
            let bit = lock(&self.rng).next_below(self.page_size() * 8);
            return Fault::Flip(bit);
        }
        if self.cfg.torn_every > 0 && seq % self.cfg.torn_every == 0 {
            return Fault::Torn(self.page_size() / 2);
        }
        Fault::None
    }

    fn maybe_spike(&self) {
        if self.cfg.spike_every == 0 {
            return;
        }
        let b = self.batches.fetch_add(1, Ordering::Relaxed) + 1;
        if b % self.cfg.spike_every == 0 {
            self.counters.spikes.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.cfg.spike);
        }
    }

    /// Apply pre-decided faults to a completed batch. Corruption mutates
    /// the buffers in place; any EIO fails the whole batch (batch-level
    /// error semantics, like the real backends).
    fn apply(&self, page_ids: &[u32], plans: &[Fault], bufs: &mut [Vec<u8>]) -> Result<()> {
        let mut eio_page = None;
        for (k, plan) in plans.iter().enumerate() {
            match *plan {
                Fault::None => {}
                Fault::Eio => {
                    self.counters.eio.fetch_add(1, Ordering::Relaxed);
                    eio_page = Some(page_ids[k]);
                }
                Fault::Flip(bit) => {
                    self.counters.flips.fetch_add(1, Ordering::Relaxed);
                    if let Some(b) = bufs[k].get_mut(bit / 8) {
                        *b ^= 1 << (bit % 8);
                    }
                }
                Fault::Torn(from) => {
                    self.counters.torn.fetch_add(1, Ordering::Relaxed);
                    for b in bufs[k].iter_mut().skip(from) {
                        *b = 0;
                    }
                }
            }
        }
        match eio_page {
            Some(p) => anyhow::bail!("injected I/O error reading page {p}"),
            None => Ok(()),
        }
    }
}

impl PageStore for FaultStore {
    fn page_size(&self) -> usize {
        self.inner.page_size()
    }

    fn n_pages(&self) -> usize {
        self.inner.n_pages()
    }

    fn read_pages(&self, page_ids: &[u32], out: &mut [Vec<u8>]) -> Result<()> {
        if page_ids.is_empty() {
            return Ok(());
        }
        self.maybe_spike();
        // Decide first so the schedule advances even if the inner read
        // fails — replaying a config replays the same fault sequence.
        let plans: Vec<Fault> = page_ids.iter().map(|&p| self.decide(p)).collect();
        self.inner.read_pages(page_ids, out)?;
        self.apply(page_ids, &plans, out)
    }

    fn begin_read(&self, page_ids: &[u32], bufs: Vec<Vec<u8>>) -> PendingRead<'_> {
        if page_ids.is_empty() {
            return PendingRead::done(bufs, Ok(()));
        }
        let plans: Vec<Fault> = page_ids.iter().map(|&p| self.decide(p)).collect();
        let ids: Vec<u32> = page_ids.to_vec();
        let inner = self.inner.begin_read(page_ids, bufs);
        if inner.completed_err() {
            let (bufs, result) = inner.wait();
            return PendingRead::done(bufs, result);
        }
        PendingRead::deferred(move || {
            let (mut bufs, result) = inner.wait();
            if result.is_err() {
                return (bufs, result);
            }
            self.maybe_spike();
            let r = self.apply(&ids, &plans, &mut bufs);
            (bufs, r)
        })
    }

    fn max_inflight_batches(&self) -> usize {
        self.inner.max_inflight_batches()
    }

    fn name(&self) -> &'static str {
        "faults"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::PreadPageStore;

    fn store_with(cfg: FaultConfig, name: &str) -> (FaultStore, std::path::PathBuf) {
        let path = std::env::temp_dir().join(format!("pageann-faults-{}-{name}", std::process::id()));
        crate::io::write_test_pages(&path, 4096, 16);
        let inner = Box::new(PreadPageStore::open(&path, 4096).unwrap());
        (FaultStore::new(inner, cfg), path)
    }

    fn mk_bufs(n: usize) -> Vec<Vec<u8>> {
        (0..n).map(|_| vec![0u8; 4096]).collect()
    }

    #[test]
    fn parse_grammar_and_noop() {
        let c = FaultConfig::parse(
            "seed=7, eio=0.05, flip_every=97, spike_every=64, spike_us=500, fail_first=2, dead=3:17",
        )
        .unwrap();
        assert_eq!(c.seed, 7);
        assert!((c.eio_rate - 0.05).abs() < 1e-12);
        assert_eq!(c.flip_every, 97);
        assert_eq!(c.spike_every, 64);
        assert_eq!(c.spike, Duration::from_micros(500));
        assert_eq!(c.fail_first, 2);
        assert_eq!(c.dead, vec![3, 17]);
        assert!(!c.is_noop());
        assert!(FaultConfig::parse("").unwrap().is_noop());
        assert!(FaultConfig::parse("seed=9").unwrap().is_noop());
        assert!(FaultConfig::parse("bogus=1").is_err());
        assert!(FaultConfig::parse("eio=1.5").is_err());
        assert!(FaultConfig::parse("eio").is_err());
    }

    #[test]
    fn no_faults_is_transparent() {
        let (s, path) = store_with(FaultConfig::default(), "noop");
        let ids = vec![3u32, 0, 7];
        let mut bufs = mk_bufs(3);
        s.read_pages(&ids, &mut bufs).unwrap();
        for (k, &p) in ids.iter().enumerate() {
            assert_eq!(bufs[k][5], ((p as usize * 131 + 5) % 251) as u8);
        }
        assert_eq!(s.counters().total_injected(), 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn eio_schedule_is_deterministic() {
        let cfg = FaultConfig { eio_rate: 0.3, seed: 11, ..Default::default() };
        let run = || {
            let (s, path) = store_with(cfg.clone(), "det");
            let mut outcomes = Vec::new();
            for round in 0..50u32 {
                let ids = vec![round % 16];
                let mut bufs = mk_bufs(1);
                outcomes.push(s.read_pages(&ids, &mut bufs).is_ok());
            }
            std::fs::remove_file(&path).unwrap();
            (outcomes, s.counters().eio.load(Ordering::Relaxed))
        };
        let (a, ea) = run();
        let (b, eb) = run();
        assert_eq!(a, b, "same seed must replay the same fault schedule");
        assert_eq!(ea, eb);
        assert!(ea > 0, "0.3 EIO rate fired never in 50 reads");
        assert!(a.iter().any(|ok| *ok), "0.3 EIO rate fired always");
    }

    #[test]
    fn fail_first_then_succeeds() {
        let cfg = FaultConfig { fail_first: 2, ..Default::default() };
        let (s, path) = store_with(cfg, "failfirst");
        for attempt in 0..4 {
            let mut bufs = mk_bufs(1);
            let r = s.read_pages(&[5], &mut bufs);
            if attempt < 2 {
                assert!(r.is_err(), "attempt {attempt} should fail");
            } else {
                assert!(r.is_ok(), "attempt {attempt} should succeed");
                assert_eq!(bufs[0][0], ((5 * 131) % 251) as u8);
            }
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn dead_pages_always_fail() {
        let cfg = FaultConfig { dead: vec![9], ..Default::default() };
        let (s, path) = store_with(cfg, "dead");
        for _ in 0..5 {
            let mut bufs = mk_bufs(1);
            assert!(s.read_pages(&[9], &mut bufs).is_err());
            let mut bufs = mk_bufs(1);
            assert!(s.read_pages(&[8], &mut bufs).is_ok());
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn bit_flips_and_torn_reads_corrupt_quietly() {
        let cfg = FaultConfig { flip_every: 3, torn_every: 0, ..Default::default() };
        let (s, path) = store_with(cfg, "flip");
        let mut corrupted = 0;
        for round in 0..12u32 {
            let mut bufs = mk_bufs(1);
            s.read_pages(&[round % 16], &mut bufs).unwrap(); // flips never error
            let p = (round % 16) as usize;
            let clean: Vec<u8> = (0..4096).map(|i| ((p * 131 + i) % 251) as u8).collect();
            if bufs[0] != clean {
                corrupted += 1;
                // Exactly one bit differs.
                let bits: u32 =
                    bufs[0].iter().zip(&clean).map(|(a, b)| (a ^ b).count_ones()).sum();
                assert_eq!(bits, 1);
            }
        }
        assert_eq!(corrupted, 4, "flip_every=3 over 12 reads");
        assert_eq!(s.counters().flips.load(Ordering::Relaxed), 4);
        std::fs::remove_file(&path).unwrap();

        let cfg = FaultConfig { torn_every: 2, ..Default::default() };
        let (s, path) = store_with(cfg, "torn");
        let mut bufs = mk_bufs(2);
        s.read_pages(&[1, 2], &mut bufs).unwrap();
        let torn: Vec<&Vec<u8>> =
            bufs.iter().filter(|b| b[2048..].iter().all(|&x| x == 0)).collect();
        assert_eq!(torn.len(), 1, "torn_every=2 over 2 reads tears exactly one");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn begin_read_returns_buffers_on_injected_error() {
        // The owned-buffer contract must hold for injected faults too.
        let cfg = FaultConfig { dead: vec![0], ..Default::default() };
        let (s, path) = store_with(cfg, "ownership");
        let (back, r) = s.begin_read(&[0, 1], mk_bufs(2)).wait();
        assert!(r.is_err());
        assert_eq!(back.len(), 2, "buffers lost on the injected-error path");
        // Non-dead page content still intact in its buffer.
        assert_eq!(back[1][0], (131 % 251) as u8);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn latency_spikes_fire_on_schedule() {
        let cfg = FaultConfig {
            spike_every: 2,
            spike: Duration::from_millis(30),
            ..Default::default()
        };
        let (s, path) = store_with(cfg, "spike");
        let t = std::time::Instant::now();
        for _ in 0..2 {
            let mut bufs = mk_bufs(1);
            s.read_pages(&[0], &mut bufs).unwrap();
        }
        assert!(t.elapsed() >= Duration::from_millis(30), "spike never fired");
        assert_eq!(s.counters().spikes.load(Ordering::Relaxed), 1);
        std::fs::remove_file(&path).unwrap();
    }
}
