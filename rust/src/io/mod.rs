//! Page I/O engine.
//!
//! Three page stores behind one trait:
//!
//! * [`AioPageStore`] — real Linux AIO (`io_submit`/`io_getevents` through
//!   `libc`), submitting each batch as one syscall and overlapping
//!   completion waits with deferred computation, as in the paper's §5
//!   pipeline. Falls back automatically when the kernel lacks AIO.
//! * [`PreadPageStore`] — positional reads (`pread64`), batched loop.
//! * [`SimSsdStore`] — wraps another store and enforces a deterministic
//!   NVMe timing model (base latency + bandwidth + bounded queue depth), so
//!   experiments measure the paper's I/O-bound regime even when the host
//!   page cache would hide it (DESIGN.md §3 substitution table).

mod aio;
mod pread;
mod simssd;

pub use aio::AioPageStore;
pub use pread::PreadPageStore;
pub use simssd::{SimSsdStore, SsdModel};

use crate::Result;
use std::path::Path;

/// A not-yet-completed batch read: call [`PendingRead::wait`] before
/// touching the output buffers. Stores without true async I/O return an
/// already-completed handle (the default `begin_read` reads synchronously).
pub struct PendingRead<'a> {
    complete: Option<Box<dyn FnOnce() -> Result<()> + 'a>>,
}

impl<'a> PendingRead<'a> {
    /// An already-completed read.
    pub fn ready() -> Self {
        Self { complete: None }
    }

    /// A read whose completion is driven by `f`.
    pub fn deferred(f: impl FnOnce() -> Result<()> + 'a) -> Self {
        Self { complete: Some(Box::new(f)) }
    }

    /// Block until the buffers are filled.
    pub fn wait(mut self) -> Result<()> {
        match self.complete.take() {
            Some(f) => f(),
            None => Ok(()),
        }
    }

    pub fn is_async(&self) -> bool {
        self.complete.is_some()
    }
}

impl<'a> Drop for PendingRead<'a> {
    fn drop(&mut self) {
        // A dropped-without-wait pending read must still complete: the
        // kernel owns the buffers until io_getevents returns.
        if let Some(f) = self.complete.take() {
            let _ = f();
        }
    }
}

/// A batch page reader. `read_pages` fills `out[i]` with the contents of
/// `page_ids[i]`; each buffer must be exactly `page_size` long.
pub trait PageStore: Send + Sync {
    fn page_size(&self) -> usize;
    fn n_pages(&self) -> usize;
    fn read_pages(&self, page_ids: &[u32], out: &mut [Vec<u8>]) -> Result<()>;
    fn name(&self) -> &'static str;

    /// Start a batch read, returning a completion handle (paper §5:
    /// io_submit now, io_getevents inside [`PendingRead::wait`], with the
    /// caller free to compute in between). Default: synchronous.
    ///
    /// The output buffers must not be read until `wait` returns.
    fn begin_read<'a>(&'a self, page_ids: &[u32], out: &'a mut [Vec<u8>]) -> Result<PendingRead<'a>> {
        self.read_pages(page_ids, out)?;
        Ok(PendingRead::ready())
    }
}

/// Open the best available store for `path`: AIO if the kernel supports it,
/// otherwise pread.
pub fn open_auto(path: &Path, page_size: usize) -> Result<Box<dyn PageStore>> {
    match AioPageStore::open(path, page_size) {
        Ok(s) => Ok(Box::new(s)),
        Err(e) => {
            eprintln!("io: AIO unavailable ({e}); falling back to pread");
            Ok(Box::new(PreadPageStore::open(path, page_size)?))
        }
    }
}

#[cfg(test)]
pub(crate) fn write_test_pages(path: &Path, page_size: usize, n: usize) {
    let mut data = vec![0u8; page_size * n];
    for p in 0..n {
        for (i, b) in data[p * page_size..(p + 1) * page_size].iter_mut().enumerate() {
            *b = ((p * 131 + i) % 251) as u8;
        }
    }
    std::fs::write(path, &data).unwrap();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("pageann-io-{}-{name}", std::process::id()))
    }

    fn check_store(store: &dyn PageStore, page_size: usize) {
        // Batched read of out-of-order, duplicate-free pages.
        let ids = vec![7u32, 0, 3, 9, 1];
        let mut bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; page_size]).collect();
        store.read_pages(&ids, &mut bufs).unwrap();
        for (k, &p) in ids.iter().enumerate() {
            for (i, &b) in bufs[k].iter().enumerate() {
                assert_eq!(b, ((p as usize * 131 + i) % 251) as u8, "page {p} byte {i}");
            }
        }
        // Out-of-range page rejected.
        let mut one = vec![vec![0u8; page_size]];
        assert!(store.read_pages(&[99], &mut one).is_err());
        // Empty batch is a no-op.
        store.read_pages(&[], &mut []).unwrap();
    }

    #[test]
    fn pread_store_reads_correct_pages() {
        let path = tmpfile("pread");
        write_test_pages(&path, 4096, 10);
        let s = PreadPageStore::open(&path, 4096).unwrap();
        assert_eq!(s.n_pages(), 10);
        check_store(&s, 4096);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn aio_store_reads_correct_pages_or_is_unavailable() {
        let path = tmpfile("aio");
        write_test_pages(&path, 4096, 10);
        match AioPageStore::open(&path, 4096) {
            Ok(s) => {
                assert_eq!(s.n_pages(), 10);
                check_store(&s, 4096);
            }
            Err(e) => eprintln!("AIO unavailable in this environment: {e}"),
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn open_auto_always_works() {
        let path = tmpfile("auto");
        write_test_pages(&path, 2048, 10);
        let s = open_auto(&path, 2048).unwrap();
        check_store(s.as_ref(), 2048);
        std::fs::remove_file(&path).unwrap();
    }
}
