//! Page I/O engine.
//!
//! Four page stores behind one trait:
//!
//! * [`UringPageStore`] — io_uring (`io_uring_setup`/`io_uring_enter`
//!   through raw syscalls + mmap'd SQ/CQ rings), one shared deep-queue
//!   ring per store with tagged submissions, so any number of batches can
//!   be in flight and complete out of order.
//! * [`AioPageStore`] — Linux AIO (`io_submit`/`io_getevents`), one AIO
//!   context leased per in-flight batch from a pool.
//! * [`PreadPageStore`] — positional reads (`pread64`), batched loop; the
//!   portable synchronous fallback.
//! * [`SimSsdStore`] — wraps another store and enforces a deterministic
//!   NVMe timing model (base latency + bandwidth + bounded queue depth), so
//!   experiments measure the paper's I/O-bound regime even when the host
//!   page cache would hide it (DESIGN.md §3 substitution table).
//!
//! # Backend selection matrix
//!
//! [`open_auto`] probes backends in order **uring → aio → pread** and
//! returns the first that passes an *actual read* at open time — a backend
//! whose setup syscall succeeds but whose first submission fails (seccomp
//! filters, weird filesystems) must fall back cleanly, not at query time.
//! The CI kernel (4.4) predates io_uring entirely, so the fallback path is
//! first-class, like the `xla` feature stub.
//!
//! | `PAGEANN_IO` | behaviour                                            |
//! |--------------|------------------------------------------------------|
//! | unset        | probe uring → aio → pread, first healthy one wins    |
//! | `uring`      | try uring; on failure fall through to aio → pread    |
//! | `aio`        | try aio; on failure fall through to pread            |
//! | `pread`      | pread unconditionally                                |
//! | other        | warn, then behave as unset                           |
//!
//! The override mirrors `PAGEANN_SIMD`: a forced value can never fail the
//! open — it only changes where probing starts.
//!
//! # Fault injection
//!
//! Any backend can be wrapped in a [`FaultStore`] (see `faults`), which
//! injects deterministic, seeded faults for robustness testing. The
//! engine honors the `PAGEANN_FAULTS` environment variable (comma-
//! separated `key=value`):
//!
//! | knob          | effect                                               |
//! |---------------|------------------------------------------------------|
//! | `seed=N`      | seed for the fault schedule (default 0x5EED)         |
//! | `eio=P`       | each page read fails with probability P (transient)  |
//! | `flip_every=N`| every Nth page read gets one bit flipped             |
//! | `torn_every=N`| every Nth page read returns a zeroed tail half       |
//! | `spike_every=N` + `spike_us=U` | every Nth batch sleeps U µs        |
//! | `fail_first=N`| first N reads of every page fail, then succeed       |
//! | `dead=A:B:…`  | listed pages fail every read (permanent loss)        |
//!
//! # Failure semantics
//!
//! The read path layers three defenses, from the bottom up:
//!
//! 1. **Detection.** v5 pages carry a CRC32C in their last 4 bytes
//!    ([`crate::layout::PageRef::verify_checksum`]); the searcher verifies
//!    every page as it comes off the device, so bit flips and torn reads
//!    are *detected*, never silently scored.
//! 2. **Bounded retry.** A failed batch (EIO) or a checksum-failed page is
//!    re-read individually up to `SearchParams::max_io_retries` times with
//!    exponential backoff; a speculative (pipelined) batch that fails
//!    falls back to a plain synchronous re-read. Retries are counted in
//!    `QueryStats::retries`.
//! 3. **Degraded traversal.** A page that stays unreadable after retries
//!    is *skipped*: the search marks the query degraded
//!    (`QueryStats::degraded`, `failed_ios`) and continues the traversal
//!    with the neighbors it has, instead of aborting. Results stay
//!    identical to the fault-free run whenever every page was eventually
//!    readable, and lose only the lost pages' candidates otherwise.
//!
//! Batch errors are *batch-level*: one injected or real EIO fails the
//! whole `read_pages`/`wait` call, and the caller re-reads pages
//! individually to isolate the failing ones. The owned-buffer contract
//! (below) guarantees no buffer-pool leaks on any of these paths.
//!
//! These failure-path conventions are machine-checked: `pallas-lint`
//! (see `LINTS.md` at the repo root) bans panics and unchecked `unwrap`
//! in this module tree (`hot-path-unwrap`), truncating offset casts
//! (`truncating-cast`), and any pool-bypassing `mem::forget` outside the
//! individually waived uring poison sites (`forbidden-forget`); every
//! `unsafe` syscall site here carries a SAFETY argument inventoried in
//! `UNSAFETY.md`.
//!
//! # Multi-batch contract
//!
//! [`PageStore::begin_read`] takes *owned* buffers and hands them back
//! from [`PendingRead::wait`] — even on error — so a caller can hold any
//! number of outstanding `PendingRead`s against one store (the uring store
//! tags each submission and completes them out of order from a single
//! ring) and its buffer pool can never leak through an error path.

mod aio;
mod faults;
mod pread;
mod simssd;
mod uring;

pub use aio::AioPageStore;
pub use faults::{FaultConfig, FaultCounters, FaultStore};
pub use pread::PreadPageStore;
pub use simssd::{SimSsdStore, SsdModel};
pub use uring::UringPageStore;

use crate::Result;
use std::path::Path;

/// A not-yet-completed batch read that **owns its output buffers**: call
/// [`PendingRead::wait`] to get them back, filled. Stores without true
/// async I/O return an already-completed handle (the default `begin_read`
/// reads synchronously before returning).
///
/// Any number of `PendingRead`s may be outstanding against one store at a
/// time; they may be waited in any order. Dropping a handle without
/// waiting still drives the read to completion (the kernel owns the
/// buffers until then) but discards the buffers — wait if you pool them.
pub struct PendingRead<'a> {
    inner: Option<PendingInner<'a>>,
}

enum PendingInner<'a> {
    /// Completed (or failed) at submit time.
    Done { bufs: Vec<Vec<u8>>, result: Result<()> },
    /// Completion is driven by the closure, which owns the buffers (and
    /// whatever kernel-visible state — iovecs, ring tags — must outlive
    /// the submission).
    Deferred(Box<dyn FnOnce() -> (Vec<Vec<u8>>, Result<()>) + 'a>),
}

impl<'a> PendingRead<'a> {
    /// An already-completed read (also used to surface submit-time errors
    /// without losing the caller's buffers).
    pub fn done(bufs: Vec<Vec<u8>>, result: Result<()>) -> Self {
        Self { inner: Some(PendingInner::Done { bufs, result }) }
    }

    /// A read whose completion is driven by `f`. `f` must return the
    /// output buffers in their original order, filled on `Ok`.
    pub fn deferred(f: impl FnOnce() -> (Vec<Vec<u8>>, Result<()>) + 'a) -> Self {
        Self { inner: Some(PendingInner::Deferred(Box::new(f))) }
    }

    /// Block until the read completes, returning the buffers. The buffers
    /// come back on the error path too, so pooled buffers survive every
    /// exit.
    pub fn wait(mut self) -> (Vec<Vec<u8>>, Result<()>) {
        match self.inner.take() {
            Some(PendingInner::Done { bufs, result }) => (bufs, result),
            Some(PendingInner::Deferred(f)) => f(),
            None => (Vec::new(), Ok(())),
        }
    }

    pub fn is_async(&self) -> bool {
        matches!(self.inner, Some(PendingInner::Deferred(_)))
    }

    /// True when the read has already completed **with an error** —
    /// submit-time failures surface this way under the owned-buffer
    /// contract, letting wrappers (e.g. the sim-SSD model) short-circuit
    /// before charging modeled device time for a command that never ran.
    pub fn completed_err(&self) -> bool {
        matches!(&self.inner, Some(PendingInner::Done { result: Err(_), .. }))
    }
}

impl<'a> Drop for PendingRead<'a> {
    fn drop(&mut self) {
        // A dropped-without-wait pending read must still complete: the
        // kernel owns the buffers until the completion is reaped.
        if let Some(PendingInner::Deferred(f)) = self.inner.take() {
            let _ = f();
        }
    }
}

/// A batch page reader. `read_pages` fills `out[i]` with the contents of
/// `page_ids[i]`; each buffer must be exactly `page_size` long.
pub trait PageStore: Send + Sync {
    fn page_size(&self) -> usize;
    fn n_pages(&self) -> usize;
    fn read_pages(&self, page_ids: &[u32], out: &mut [Vec<u8>]) -> Result<()>;
    fn name(&self) -> &'static str;

    /// Start a batch read, taking ownership of `bufs` (one buffer per page
    /// id, each exactly `page_size` long) and returning a completion
    /// handle that yields them back (paper §5: submit now, complete inside
    /// [`PendingRead::wait`], with the caller free to compute — or submit
    /// more batches — in between). Invalid input surfaces as an error from
    /// `wait`, never by swallowing the buffers. Default: synchronous.
    ///
    /// Callers may hold several outstanding handles per store (see the
    /// module-level multi-batch contract) and wait them in any order.
    fn begin_read(&self, page_ids: &[u32], mut bufs: Vec<Vec<u8>>) -> PendingRead<'_> {
        let result = self.read_pages(page_ids, &mut bufs);
        PendingRead::done(bufs, result)
    }

    /// Upper bound on how many `begin_read` batches can *usefully* be in
    /// flight at once. 1 means `begin_read` completes synchronously, so
    /// speculative submission buys nothing (and costs wasted reads).
    fn max_inflight_batches(&self) -> usize {
        1
    }
}

/// Open the best available store for `path`: io_uring if the kernel
/// supports it, else Linux AIO, else pread — each verified with a real
/// probe read at open time. `PAGEANN_IO=uring|aio|pread` overrides where
/// probing starts (see the module docs); an override can redirect the
/// probe but never make the open fail.
pub fn open_auto(path: &Path, page_size: usize) -> Result<Box<dyn PageStore>> {
    open_with(path, page_size, None)
}

/// [`open_auto`] with an explicit backend preference taking precedence
/// over the `PAGEANN_IO` environment override.
pub fn open_with(
    path: &Path,
    page_size: usize,
    prefer: Option<&str>,
) -> Result<Box<dyn PageStore>> {
    let env = std::env::var("PAGEANN_IO").ok();
    let pref = prefer.or(env.as_deref());
    // Which rung of the uring → aio → pread ladder to start on.
    let start = match pref {
        Some("uring") | None => 0,
        Some("aio") => 1,
        Some("pread") => 2,
        Some(other) => {
            eprintln!("io: unknown PAGEANN_IO={other:?} (uring|aio|pread); probing all backends");
            0
        }
    };
    if start <= 0 {
        match UringPageStore::open(path, page_size) {
            Ok(s) => return Ok(Box::new(s)),
            Err(e) => {
                // Expected on kernels < 5.1 (ENOSYS) — stay quiet unless
                // the user explicitly asked for uring.
                if pref == Some("uring") {
                    eprintln!("io: io_uring unavailable ({e}); falling back");
                }
            }
        }
    }
    if start <= 1 {
        match AioPageStore::open(path, page_size) {
            Ok(s) => return Ok(Box::new(s)),
            Err(e) => eprintln!("io: AIO unavailable ({e}); falling back to pread"),
        }
    }
    Ok(Box::new(PreadPageStore::open(path, page_size)?))
}

#[cfg(test)]
pub(crate) fn write_test_pages(path: &Path, page_size: usize, n: usize) {
    let mut data = vec![0u8; page_size * n];
    for p in 0..n {
        for (i, b) in data[p * page_size..(p + 1) * page_size].iter_mut().enumerate() {
            *b = ((p * 131 + i) % 251) as u8;
        }
    }
    std::fs::write(path, &data).unwrap();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("pageann-io-{}-{name}", std::process::id()))
    }

    fn check_store(store: &dyn PageStore, page_size: usize) {
        // Batched read of out-of-order, duplicate-free pages.
        let ids = vec![7u32, 0, 3, 9, 1];
        let mut bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; page_size]).collect();
        store.read_pages(&ids, &mut bufs).unwrap();
        for (k, &p) in ids.iter().enumerate() {
            for (i, &b) in bufs[k].iter().enumerate() {
                assert_eq!(b, ((p as usize * 131 + i) % 251) as u8, "page {p} byte {i}");
            }
        }
        // Out-of-range page rejected.
        let mut one = vec![vec![0u8; page_size]];
        assert!(store.read_pages(&[99], &mut one).is_err());
        // Empty batch is a no-op.
        store.read_pages(&[], &mut []).unwrap();
        // begin_read hands the buffers back, filled, even across two
        // simultaneously outstanding batches waited in reverse order.
        let ids_a = vec![2u32, 5];
        let ids_b = vec![8u32, 4];
        let mk = |n: usize| -> Vec<Vec<u8>> { (0..n).map(|_| vec![0u8; page_size]).collect() };
        let pa = store.begin_read(&ids_a, mk(2));
        let pb = store.begin_read(&ids_b, mk(2));
        let (bufs_b, rb) = pb.wait();
        let (bufs_a, ra) = pa.wait();
        ra.unwrap();
        rb.unwrap();
        for (ids, bufs) in [(&ids_a, &bufs_a), (&ids_b, &bufs_b)] {
            for (k, &p) in ids.iter().enumerate() {
                for (i, &b) in bufs[k].iter().enumerate() {
                    assert_eq!(b, ((p as usize * 131 + i) % 251) as u8, "page {p} byte {i}");
                }
            }
        }
        // Errors surface from wait() WITH the buffers (pool-leak contract).
        let (back, r) = store.begin_read(&[99], mk(1)).wait();
        assert!(r.is_err(), "out-of-range begin_read must fail");
        assert_eq!(back.len(), 1, "buffers must come back on the error path");
    }

    #[test]
    fn pread_store_reads_correct_pages() {
        let path = tmpfile("pread");
        write_test_pages(&path, 4096, 10);
        let s = PreadPageStore::open(&path, 4096).unwrap();
        assert_eq!(s.n_pages(), 10);
        check_store(&s, 4096);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn aio_store_reads_correct_pages_or_is_unavailable() {
        let path = tmpfile("aio");
        write_test_pages(&path, 4096, 10);
        match AioPageStore::open(&path, 4096) {
            Ok(s) => {
                assert_eq!(s.n_pages(), 10);
                check_store(&s, 4096);
            }
            Err(e) => eprintln!("AIO unavailable in this environment: {e}"),
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn uring_store_reads_correct_pages_or_is_unavailable() {
        let path = tmpfile("uring");
        write_test_pages(&path, 4096, 10);
        match UringPageStore::open(&path, 4096) {
            Ok(s) => {
                assert_eq!(s.n_pages(), 10);
                check_store(&s, 4096);
            }
            Err(e) => eprintln!("io_uring unavailable in this environment: {e}"),
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn open_auto_always_works() {
        let path = tmpfile("auto");
        write_test_pages(&path, 2048, 10);
        let s = open_auto(&path, 2048).unwrap();
        check_store(s.as_ref(), 2048);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn open_with_any_preference_always_works() {
        // A preference changes where probing starts; it can never fail the
        // open — the acceptance contract for kernels without io_uring.
        let path = tmpfile("pref");
        write_test_pages(&path, 2048, 10);
        for pref in ["uring", "aio", "pread", "bogus"] {
            let s = open_with(&path, 2048, Some(pref)).unwrap();
            check_store(s.as_ref(), 2048);
        }
        // An explicit pread preference must actually select pread.
        let s = open_with(&path, 2048, Some("pread")).unwrap();
        assert_eq!(s.name(), "pread");
        std::fs::remove_file(&path).unwrap();
    }
}
