//! Simulated-SSD timing wrapper (DESIGN.md §3).
//!
//! The paper's numbers come from a real NVMe drive whose page reads cost
//! ~60–100 µs — far above what a dev box's OS page cache serves. To measure
//! the I/O-bound regime the paper studies, this wrapper performs the real
//! read through the inner store and then *enforces* a deterministic device
//! model before returning:
//!
//! * per-batch service time = `base_latency + batch_bytes / bandwidth`
//!   (a batched submission overlaps per-page latencies, as NVMe queues do);
//! * a global in-flight token pool of `queue_depth` pages creates the
//!   cross-thread contention a real device exhibits at high concurrency.
//!
//! The model is intentionally simple and documented; experiments report
//! both modeled and raw-store timings.

use super::PageStore;
use crate::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Waits longer than this sleep (releasing the CPU so concurrent query
/// threads overlap their device waits — essential on small hosts); the
/// tail below it yields in a loop, which is granular enough for the NVMe
/// model without starving other runnable threads (see §Perf L3.2 in
/// EXPERIMENTS.md).
const SPIN_THRESHOLD: Duration = Duration::from_micros(200);

/// NVMe-like device model.
#[derive(Debug, Clone)]
pub struct SsdModel {
    /// Fixed per-batch submission+completion latency.
    pub base_latency: Duration,
    /// Sustained read bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Max pages concurrently in service across all threads.
    pub queue_depth: usize,
}

impl Default for SsdModel {
    fn default() -> Self {
        // A mid-range NVMe drive: ~80 µs read latency, ~3.2 GB/s, QD 64.
        Self { base_latency: Duration::from_micros(80), bandwidth_bps: 3.2e9, queue_depth: 64 }
    }
}

impl SsdModel {
    /// Service time for one batch of `n_pages` pages of `page_size` bytes.
    pub fn batch_time(&self, n_pages: usize, page_size: usize) -> Duration {
        let transfer = (n_pages * page_size) as f64 / self.bandwidth_bps;
        self.base_latency + Duration::from_secs_f64(transfer)
    }
}

pub struct SimSsdStore {
    inner: Box<dyn PageStore>,
    model: SsdModel,
    in_flight: AtomicUsize,
}

impl SimSsdStore {
    pub fn new(inner: Box<dyn PageStore>, model: SsdModel) -> Self {
        Self { inner, model, in_flight: AtomicUsize::new(0) }
    }

    pub fn model(&self) -> &SsdModel {
        &self.model
    }

    /// Acquire `n` queue slots as an RAII lease, spinning (with yields)
    /// while the device is saturated — this is what makes 16 threads
    /// contend like the paper's Fig. 12 setup. The lease releases on drop,
    /// so every exit (normal completion, an inner-store error unwinding
    /// through `?`, a `PendingRead` dropped without `wait()`) gives the
    /// slots back; leaking them would eventually deadlock every thread in
    /// `acquire_slots`.
    fn acquire_slots(&self, n: usize) -> SlotLease<'_> {
        loop {
            let cur = self.in_flight.load(Ordering::Acquire);
            if cur + n <= self.model.queue_depth
                && self
                    .in_flight
                    .compare_exchange(cur, cur + n, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            {
                return SlotLease { store: self, n };
            }
            std::thread::yield_now();
        }
    }

    #[cfg(test)]
    fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }
}

/// RAII lease over `n` sim-SSD queue slots (see
/// [`SimSsdStore::acquire_slots`]).
struct SlotLease<'a> {
    store: &'a SimSsdStore,
    n: usize,
}

impl Drop for SlotLease<'_> {
    fn drop(&mut self) {
        self.store.in_flight.fetch_sub(self.n, Ordering::AcqRel);
    }
}

impl PageStore for SimSsdStore {
    fn page_size(&self) -> usize {
        self.inner.page_size()
    }

    fn n_pages(&self) -> usize {
        self.inner.n_pages()
    }

    fn read_pages(&self, page_ids: &[u32], out: &mut [Vec<u8>]) -> Result<()> {
        if page_ids.is_empty() {
            return Ok(());
        }
        let slots = page_ids.len().min(self.model.queue_depth);
        let _lease = self.acquire_slots(slots);
        let start = Instant::now();
        let result = self.inner.read_pages(page_ids, out);
        let target = self.model.batch_time(page_ids.len(), self.page_size());
        // Enforce the modeled service time (sleep the remainder; spin the
        // sub-50µs tail where sleep granularity is too coarse).
        loop {
            let elapsed = start.elapsed();
            if elapsed >= target {
                break;
            }
            let remain = target - elapsed;
            if remain > SPIN_THRESHOLD {
                std::thread::sleep(remain - SPIN_THRESHOLD);
            } else {
                std::thread::yield_now();
            }
        }
        result
    }

    fn begin_read<'a>(
        &'a self,
        page_ids: &[u32],
        out: &'a mut [Vec<u8>],
    ) -> Result<super::PendingRead<'a>> {
        if page_ids.is_empty() {
            return Ok(super::PendingRead::ready());
        }
        let slots = page_ids.len().min(self.model.queue_depth);
        // The lease moves into the completion closure; it releases when the
        // closure finishes — or, because `PendingRead::drop` runs the
        // closure and a panic unwinds the lease either way, whenever the
        // handle is dropped without `wait()`. An inner `begin_read` error
        // releases via `?` unwinding the lease right here.
        let lease = self.acquire_slots(slots);
        let start = Instant::now();
        let target = self.model.batch_time(page_ids.len(), self.page_size());
        let inner = self.inner.begin_read(page_ids, out)?;
        Ok(super::PendingRead::deferred(move || {
            let _lease = lease;
            let result = inner.wait();
            // Enforce the modeled service time measured from submission —
            // overlapped computation between submit and wait comes "for
            // free", exactly like a real device.
            loop {
                let elapsed = start.elapsed();
                if elapsed >= target {
                    break;
                }
                let remain = target - elapsed;
                if remain > SPIN_THRESHOLD {
                    std::thread::sleep(remain - SPIN_THRESHOLD);
                } else {
                    std::thread::yield_now();
                }
            }
            result
        }))
    }

    fn name(&self) -> &'static str {
        "sim-ssd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::PreadPageStore;

    #[test]
    fn enforces_minimum_service_time() {
        let path = std::env::temp_dir().join(format!("pageann-sim-{}", std::process::id()));
        crate::io::write_test_pages(&path, 4096, 8);
        let inner = Box::new(PreadPageStore::open(&path, 4096).unwrap());
        let model = SsdModel {
            base_latency: Duration::from_millis(2),
            bandwidth_bps: 1e9,
            queue_depth: 4,
        };
        let sim = SimSsdStore::new(inner, model);
        let ids = vec![0u32, 1, 2];
        let mut bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; 4096]).collect();
        let t = Instant::now();
        sim.read_pages(&ids, &mut bufs).unwrap();
        let dt = t.elapsed();
        assert!(dt >= Duration::from_millis(2), "returned too fast: {dt:?}");
        // Data still correct through the wrapper.
        assert_eq!(bufs[1][0], ((1 * 131) % 251) as u8);
        std::fs::remove_file(&path).unwrap();
    }

    /// Inner store whose async path always fails — exercises the
    /// error-unwind slot accounting.
    struct FailingStore;

    impl PageStore for FailingStore {
        fn page_size(&self) -> usize {
            4096
        }
        fn n_pages(&self) -> usize {
            8
        }
        fn read_pages(&self, _page_ids: &[u32], _out: &mut [Vec<u8>]) -> crate::Result<()> {
            anyhow::bail!("injected device fault")
        }
        fn name(&self) -> &'static str {
            "failing"
        }
    }

    fn fast_model(queue_depth: usize) -> SsdModel {
        SsdModel { base_latency: Duration::from_micros(10), bandwidth_bps: 1e10, queue_depth }
    }

    #[test]
    fn dropped_pending_read_releases_queue_slots() {
        let path = std::env::temp_dir().join(format!("pageann-sim-drop-{}", std::process::id()));
        crate::io::write_test_pages(&path, 4096, 8);
        let inner = Box::new(PreadPageStore::open(&path, 4096).unwrap());
        let sim = SimSsdStore::new(inner, fast_model(2));
        let ids = vec![0u32, 1];
        // More drop-without-wait cycles than the queue depth: if any cycle
        // leaked its slots, acquire_slots would spin forever below.
        for round in 0..5 {
            let mut bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; 4096]).collect();
            let pending = sim.begin_read(&ids, &mut bufs).unwrap();
            drop(pending); // never waited
            assert_eq!(sim.in_flight(), 0, "slots leaked after drop round {round}");
        }
        // The device is still usable at full queue depth.
        let mut bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; 4096]).collect();
        sim.read_pages(&ids, &mut bufs).unwrap();
        assert_eq!(bufs[1][0], (131 % 251) as u8);
        assert_eq!(sim.in_flight(), 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn failed_begin_read_releases_queue_slots() {
        let sim = SimSsdStore::new(Box::new(FailingStore), fast_model(2));
        let ids = vec![0u32, 1];
        for _ in 0..5 {
            let mut bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; 4096]).collect();
            // The default `begin_read` reads synchronously, so the injected
            // fault surfaces here — and must not strand the two slots.
            assert!(sim.begin_read(&ids, &mut bufs).is_err());
            assert_eq!(sim.in_flight(), 0, "slots leaked on the error path");
        }
    }

    #[test]
    fn batch_time_model_shape() {
        let m = SsdModel { base_latency: Duration::from_micros(100), bandwidth_bps: 1e9, queue_depth: 8 };
        let one = m.batch_time(1, 4096);
        let five = m.batch_time(5, 4096);
        // Batching amortizes latency: 5 pages cost far less than 5×1.
        assert!(five < one * 3, "batching not amortized: {one:?} vs {five:?}");
        assert!(five > one);
    }
}
