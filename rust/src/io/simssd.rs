//! Simulated-SSD timing wrapper (DESIGN.md §3).
//!
//! The paper's numbers come from a real NVMe drive whose page reads cost
//! ~60–100 µs — far above what a dev box's OS page cache serves. To measure
//! the I/O-bound regime the paper studies, this wrapper performs the real
//! read through the inner store and then *enforces* a deterministic device
//! model before returning:
//!
//! * per-batch service time = `base_latency + batch_bytes / bandwidth`
//!   (a batched submission overlaps per-page latencies, as NVMe queues do);
//! * a **virtual-time channel queue**: the device has `queue_depth` service
//!   channels, each with a "free again at" timestamp. A batch of `n` pages
//!   claims the `min(n, queue_depth)` earliest-free channels; its service
//!   starts at `max(submit, all claimed channels free)` and the channels
//!   stay busy until `service_start + batch_time`. Saturation therefore
//!   shows up as *later completion deadlines* — the modeled IOPS cap the
//!   paper's Fig. 12 setup exhibits — rather than as threads blocking on a
//!   token pool. Because nothing ever blocks waiting for slots, callers
//!   may hold any number of pending batches (the two-deep search pipeline)
//!   with no hold-and-wait deadlock by construction.
//!
//! The model is intentionally simple and documented; experiments report
//! both modeled and raw-store timings.

use super::PageStore;
use crate::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Waits longer than this sleep (releasing the CPU so concurrent query
/// threads overlap their device waits — essential on small hosts); the
/// tail below it yields in a loop, which is granular enough for the NVMe
/// model without starving other runnable threads (see §Perf L3.2 in
/// EXPERIMENTS.md).
const SPIN_THRESHOLD: Duration = Duration::from_micros(200);

/// NVMe-like device model.
#[derive(Debug, Clone)]
pub struct SsdModel {
    /// Fixed per-batch submission+completion latency.
    pub base_latency: Duration,
    /// Sustained read bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Max pages concurrently in service across all threads.
    pub queue_depth: usize,
}

impl Default for SsdModel {
    fn default() -> Self {
        // A mid-range NVMe drive: ~80 µs read latency, ~3.2 GB/s, QD 64.
        Self { base_latency: Duration::from_micros(80), bandwidth_bps: 3.2e9, queue_depth: 64 }
    }
}

impl SsdModel {
    /// Service time for one batch of `n_pages` pages of `page_size` bytes.
    pub fn batch_time(&self, n_pages: usize, page_size: usize) -> Duration {
        self.base_latency + self.transfer_time(n_pages, page_size)
    }

    /// Bandwidth component only — how long the device's data path is
    /// occupied by this batch's bytes.
    pub fn transfer_time(&self, n_pages: usize, page_size: usize) -> Duration {
        Duration::from_secs_f64((n_pages * page_size) as f64 / self.bandwidth_bps)
    }
}

pub struct SimSsdStore {
    inner: Box<dyn PageStore>,
    model: SsdModel,
    /// Per-channel "free again at" timestamps (len == queue_depth).
    channels: Mutex<Vec<Instant>>,
    /// Pages whose modeled service has not completed yet — introspection
    /// for leak tests, never used for control flow.
    in_flight: AtomicUsize,
}

impl SimSsdStore {
    pub fn new(inner: Box<dyn PageStore>, model: SsdModel) -> Self {
        let depth = model.queue_depth.max(1);
        Self {
            inner,
            model,
            channels: Mutex::new(vec![Instant::now(); depth]),
            in_flight: AtomicUsize::new(0),
        }
    }

    pub fn model(&self) -> &SsdModel {
        &self.model
    }

    /// Queue one batch on the modeled device: claim the `min(n, depth)`
    /// earliest-free channels and return the completion deadline
    /// `max(now, channels free) + batch_time`. Pure virtual time — never
    /// blocks — so any number of batches may be outstanding per thread.
    fn schedule(&self, n_pages: usize) -> Instant {
        let k = n_pages.min(self.model.queue_depth).max(1);
        let target = self.model.batch_time(n_pages, self.page_size());
        let now = Instant::now();
        let mut ch = crate::util::sync::lock(&self.channels);
        // Claim the k earliest-free channels (depth is small; a sort keeps
        // this deterministic and obvious).
        ch.sort_unstable();
        let service_start = now.max(ch[k - 1]);
        let completion = service_start + target;
        for slot in ch.iter_mut().take(k) {
            *slot = completion;
        }
        completion
    }

    /// Pages currently inside their modeled service window — 0 when idle.
    /// Public for leak assertions in the cross-backend conformance suite.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }
}

/// RAII in-flight page counter (diagnostics only; see
/// [`SimSsdStore::in_flight`]).
struct InFlight<'a> {
    store: &'a SimSsdStore,
    n: usize,
}

impl<'a> InFlight<'a> {
    fn track(store: &'a SimSsdStore, n: usize) -> Self {
        store.in_flight.fetch_add(n, Ordering::AcqRel);
        Self { store, n }
    }
}

impl Drop for InFlight<'_> {
    fn drop(&mut self) {
        self.store.in_flight.fetch_sub(self.n, Ordering::AcqRel);
    }
}

/// Sleep (coarse) then yield (fine) until `deadline`.
fn wait_until(deadline: Instant) {
    loop {
        let now = Instant::now();
        if now >= deadline {
            return;
        }
        let remain = deadline - now;
        if remain > SPIN_THRESHOLD {
            std::thread::sleep(remain - SPIN_THRESHOLD);
        } else {
            std::thread::yield_now();
        }
    }
}

impl PageStore for SimSsdStore {
    fn page_size(&self) -> usize {
        self.inner.page_size()
    }

    fn n_pages(&self) -> usize {
        self.inner.n_pages()
    }

    fn read_pages(&self, page_ids: &[u32], out: &mut [Vec<u8>]) -> Result<()> {
        if page_ids.is_empty() {
            return Ok(());
        }
        let _guard = InFlight::track(self, page_ids.len());
        // An inner-store failure surfaces immediately — and charges no
        // modeled channel time: a command that never ran must not occupy
        // the device (channels are claimed only after the read succeeds;
        // the µs-scale shift of the service window is noise next to the
        // modeled latencies).
        self.inner.read_pages(page_ids, out)?;
        let completion = self.schedule(page_ids.len());
        wait_until(completion);
        Ok(())
    }

    fn begin_read(&self, page_ids: &[u32], bufs: Vec<Vec<u8>>) -> super::PendingRead<'_> {
        if page_ids.is_empty() {
            return super::PendingRead::done(bufs, Ok(()));
        }
        // The command enters the modeled device queue at submission; the
        // completion deadline accounts for channel contention, so
        // overlapped computation between submit and wait comes "for free"
        // exactly like a real device, while saturation pushes deadlines
        // out instead of blocking threads.
        //
        // The returned handle is always deferred (on success) —
        // `is_async()` reports whether the MODELED completion is pending,
        // which is what the modeled regime's consumers (e.g. the
        // searcher's speculation gate) should see: over a synchronous
        // inner store (pread, or AIO degraded by ctx-pool exhaustion) the
        // physical read happens right here, but in this regime modeled
        // time is the latency being measured and the overlap win is real
        // in that currency. The wrapper therefore intentionally masks
        // inner-store degradation.
        let guard = InFlight::track(self, page_ids.len());
        let inner = self.inner.begin_read(page_ids, bufs);
        if inner.completed_err() {
            // A submit-time failure charges no modeled channel time: the
            // command never ran on the device.
            drop(guard);
            let (bufs, result) = inner.wait();
            return super::PendingRead::done(bufs, result);
        }
        let completion = self.schedule(page_ids.len());
        super::PendingRead::deferred(move || {
            let _guard = guard;
            let (bufs, result) = inner.wait();
            if result.is_err() {
                // Propagate inner-store errors immediately instead of
                // waiting out the modeled service time first.
                return (bufs, result);
            }
            wait_until(completion);
            (bufs, result)
        })
    }

    fn max_inflight_batches(&self) -> usize {
        // The modeled device overlaps service windows up to its queue
        // depth even when the inner store reads synchronously.
        self.model.queue_depth.max(self.inner.max_inflight_batches())
    }

    fn name(&self) -> &'static str {
        "sim-ssd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::PreadPageStore;

    #[test]
    fn enforces_minimum_service_time() {
        let path = std::env::temp_dir().join(format!("pageann-sim-{}", std::process::id()));
        crate::io::write_test_pages(&path, 4096, 8);
        let inner = Box::new(PreadPageStore::open(&path, 4096).unwrap());
        let model = SsdModel {
            base_latency: Duration::from_millis(2),
            bandwidth_bps: 1e9,
            queue_depth: 4,
        };
        let sim = SimSsdStore::new(inner, model);
        let ids = vec![0u32, 1, 2];
        let mut bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; 4096]).collect();
        let t = Instant::now();
        sim.read_pages(&ids, &mut bufs).unwrap();
        let dt = t.elapsed();
        assert!(dt >= Duration::from_millis(2), "returned too fast: {dt:?}");
        // Data still correct through the wrapper.
        assert_eq!(bufs[1][0], ((1 * 131) % 251) as u8);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn saturation_pushes_completions_out() {
        // Two batches that together exceed the queue depth must serialize
        // in virtual time: the second completes roughly one batch_time
        // after the first, even though both were submitted back-to-back.
        let path = std::env::temp_dir().join(format!("pageann-sim-sat-{}", std::process::id()));
        crate::io::write_test_pages(&path, 4096, 8);
        let mk_sim = |depth: usize| {
            let inner = Box::new(PreadPageStore::open(&path, 4096).unwrap());
            SimSsdStore::new(
                inner,
                SsdModel {
                    base_latency: Duration::from_millis(2),
                    bandwidth_bps: 1e10,
                    queue_depth: depth,
                },
            )
        };
        let mk_bufs = || -> Vec<Vec<u8>> { (0..2).map(|_| vec![0u8; 4096]).collect() };
        // Saturated: depth 2, two 2-page batches → second waits its turn.
        let sim = mk_sim(2);
        let t = Instant::now();
        let pa = sim.begin_read(&[0, 1], mk_bufs());
        let pb = sim.begin_read(&[2, 3], mk_bufs());
        let (_, ra) = pa.wait();
        let (_, rb) = pb.wait();
        ra.unwrap();
        rb.unwrap();
        let saturated = t.elapsed();
        assert!(
            saturated >= Duration::from_millis(4),
            "saturated pair finished in {saturated:?}, expected ≥ 2×base_latency"
        );
        // Uncontended: depth 4 fits both → they overlap fully.
        let sim = mk_sim(4);
        let t = Instant::now();
        let pa = sim.begin_read(&[0, 1], mk_bufs());
        let pb = sim.begin_read(&[2, 3], mk_bufs());
        let (_, ra) = pa.wait();
        let (_, rb) = pb.wait();
        ra.unwrap();
        rb.unwrap();
        let overlapped = t.elapsed();
        assert!(
            overlapped < saturated,
            "deep queue ({overlapped:?}) not faster than saturated ({saturated:?})"
        );
        assert_eq!(sim.in_flight(), 0);
        std::fs::remove_file(&path).unwrap();
    }

    /// Inner store whose reads always fail — exercises the error-path
    /// accounting.
    struct FailingStore;

    impl PageStore for FailingStore {
        fn page_size(&self) -> usize {
            4096
        }
        fn n_pages(&self) -> usize {
            8
        }
        fn read_pages(&self, _page_ids: &[u32], _out: &mut [Vec<u8>]) -> crate::Result<()> {
            anyhow::bail!("injected device fault")
        }
        fn name(&self) -> &'static str {
            "failing"
        }
    }

    fn fast_model(queue_depth: usize) -> SsdModel {
        SsdModel { base_latency: Duration::from_micros(10), bandwidth_bps: 1e10, queue_depth }
    }

    #[test]
    fn dropped_pending_read_releases_tracking() {
        let path = std::env::temp_dir().join(format!("pageann-sim-drop-{}", std::process::id()));
        crate::io::write_test_pages(&path, 4096, 8);
        let inner = Box::new(PreadPageStore::open(&path, 4096).unwrap());
        let sim = SimSsdStore::new(inner, fast_model(2));
        let ids = vec![0u32, 1];
        for round in 0..5 {
            let bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; 4096]).collect();
            let pending = sim.begin_read(&ids, bufs);
            drop(pending); // never waited
            assert_eq!(sim.in_flight(), 0, "tracking leaked after drop round {round}");
        }
        // The device is still usable.
        let mut bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; 4096]).collect();
        sim.read_pages(&ids, &mut bufs).unwrap();
        assert_eq!(bufs[1][0], (131 % 251) as u8);
        assert_eq!(sim.in_flight(), 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn multiple_inflight_batches_account_exactly() {
        let path =
            std::env::temp_dir().join(format!("pageann-sim-multi-{}", std::process::id()));
        crate::io::write_test_pages(&path, 4096, 8);
        let inner = Box::new(PreadPageStore::open(&path, 4096).unwrap());
        // Queue depth smaller than the combined batches: completions are
        // scheduled in virtual time, so holding three pending handles at
        // once must neither deadlock nor leak.
        let sim = SimSsdStore::new(inner, fast_model(2));
        let mk = |ids: &[u32]| -> Vec<Vec<u8>> { ids.iter().map(|_| vec![0u8; 4096]).collect() };
        let (a, b, c) = ([0u32, 1], [2u32, 3], [4u32]);
        let pa = sim.begin_read(&a, mk(&a));
        let pb = sim.begin_read(&b, mk(&b));
        let pc = sim.begin_read(&c, mk(&c));
        // Wait out of submission order.
        let (bufs_c, rc_) = pc.wait();
        let (bufs_a, ra) = pa.wait();
        let (bufs_b, rb) = pb.wait();
        ra.unwrap();
        rb.unwrap();
        rc_.unwrap();
        assert_eq!(bufs_a[1][0], (131 % 251) as u8);
        assert_eq!(bufs_b[0][0], ((2 * 131) % 251) as u8);
        assert_eq!(bufs_c[0][0], ((4 * 131) % 251) as u8);
        assert_eq!(sim.in_flight(), 0, "tracking leaked with multiple in-flight batches");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn failed_begin_read_releases_tracking() {
        let sim = SimSsdStore::new(Box::new(FailingStore), fast_model(2));
        let ids = vec![0u32, 1];
        for _ in 0..5 {
            let bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; 4096]).collect();
            // The inner store's synchronous default fails; the error must
            // surface from wait() with the buffers — and must not leak the
            // tracking counter.
            let (back, r) = sim.begin_read(&ids, bufs).wait();
            assert!(r.is_err());
            assert_eq!(back.len(), 2, "buffers lost on the error path");
            assert_eq!(sim.in_flight(), 0, "tracking leaked on the error path");
        }
    }

    #[test]
    fn inner_errors_skip_the_modeled_service_time() {
        // A half-second device model must NOT delay an inner-store failure:
        // errors propagate immediately (ISSUE 3 satellite).
        let slow = SsdModel {
            base_latency: Duration::from_millis(500),
            bandwidth_bps: 1e9,
            queue_depth: 4,
        };
        let sim = SimSsdStore::new(Box::new(FailingStore), slow);
        let ids = vec![0u32, 1];
        // Synchronous path.
        let mut bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; 4096]).collect();
        let t = Instant::now();
        assert!(sim.read_pages(&ids, &mut bufs).is_err());
        assert!(
            t.elapsed() < Duration::from_millis(100),
            "read_pages sat out the modeled latency before erroring: {:?}",
            t.elapsed()
        );
        // Async path.
        let bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; 4096]).collect();
        let t = Instant::now();
        let (_back, r) = sim.begin_read(&ids, bufs).wait();
        assert!(r.is_err());
        assert!(
            t.elapsed() < Duration::from_millis(100),
            "begin_read sat out the modeled latency before erroring: {:?}",
            t.elapsed()
        );
        assert_eq!(sim.in_flight(), 0);
    }

    #[test]
    fn batch_time_model_shape() {
        let m = SsdModel { base_latency: Duration::from_micros(100), bandwidth_bps: 1e9, queue_depth: 8 };
        let one = m.batch_time(1, 4096);
        let five = m.batch_time(5, 4096);
        // Batching amortizes latency: 5 pages cost far less than 5×1.
        assert!(five < one * 3, "batching not amortized: {one:?} vs {five:?}");
        assert!(five > one);
    }
}
