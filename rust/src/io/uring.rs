//! io_uring page store: one shared deep-queue ring per store, tagged
//! submissions, out-of-order completion — the deepest submission path in
//! the backend matrix (module docs in `io/mod.rs`).
//!
//! Implemented over the raw `io_uring_setup`/`io_uring_enter` syscalls and
//! mmap'd SQ/CQ rings through the vendored `libc` shim (the offline build
//! has no io-uring crate). Design notes:
//!
//! * **One ring, many batches.** Every `begin_read` stamps its SQEs with
//!   `user_data = batch_id << 32 | index` and registers the batch in a
//!   table; whoever reaps a completion credits it to the owning batch, so
//!   any number of `PendingRead`s can be outstanding and waited in any
//!   order. The ring (and table) sit behind one mutex, but the mutex
//!   covers only short critical sections: the blocking
//!   `io_uring_enter(GETEVENTS)` park happens *outside* the lock, done by
//!   one designated reaper at a time while other waiters sleep on a
//!   condvar ([`await_ring`]) — so a thread waiting on the device never
//!   serializes other threads' submissions.
//! * **READV, not READ.** `IORING_OP_READV` works on every io_uring kernel
//!   (5.1+); `IORING_OP_READ` needs 5.6. The per-batch iovec array is
//!   owned by the `PendingRead` closure, so it outlives the submission.
//! * **SQ/CQ mapped separately.** Both the pre- and post-5.4
//!   (`IORING_FEAT_SINGLE_MMAP`) kernels serve the legacy two-mmap layout,
//!   so the store uses it unconditionally.
//! * **No CQ overflow.** Submission never lets more than `cq_entries`
//!   reads be in flight (pre-5.5 kernels drop overflowing completions);
//!   when the CQ budget is exhausted it reaps other batches' completions
//!   first. Batches wider than the budget fall back to chunked synchronous
//!   reads.
//! * **Error-path contract** (same spirit as the AIO store): once the
//!   kernel has accepted an SQE it may write into the target buffer until
//!   the CQE is reaped. A failed submit first *rewinds* the SQ tail over
//!   the entries the kernel has not consumed (we are the only submitter,
//!   under the lock), then reaps everything it did consume, so no error
//!   return ever leaves the kernel writing into freed memory. If that
//!   drain itself fails hard — not observed in practice — the ring is
//!   poisoned and its fd closed; because ring teardown is *asynchronous*
//!   on modern kernels (no blocking `io_destroy` equivalent), the
//!   still-outstanding buffers are then **leaked** rather than reused
//!   ([`UringError::buffers_released`]).
#![deny(unsafe_op_in_unsafe_fn)]

use super::{PageStore, PendingRead};
use crate::util::checked::{hi32, to_usize, Ix};
use crate::util::sync::{cond_wait, lock};
use crate::Result;
use std::collections::HashMap;
use std::os::unix::io::AsRawFd;
use std::path::Path;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Condvar, Mutex};

/// SQ depth hint passed to `io_uring_setup` (the kernel rounds to a power
/// of two and sizes the CQ at 2×).
const SQ_DEPTH: u32 = 256;

/// `user_data` tag for self-posted NOP wakeups (see [`Ring::post_nop`]);
/// never collides with read tags, whose batch ids are sequential.
const NOP_TAG: u64 = u64::MAX;

/// # Safety
/// `p` must point to a zeroed `io_uring_params` the kernel may write to.
unsafe fn io_uring_setup(entries: u32, p: *mut libc::io_uring_params) -> libc::c_long {
    // SAFETY: raw syscall; the caller guarantees `p` is a valid out-pointer.
    unsafe { libc::syscall(libc::SYS_io_uring_setup, entries as libc::c_ulong, p) }
}

/// # Safety
/// `fd` must be a live io_uring fd whose published SQEs (and the buffers
/// they target) stay alive until their CQEs are reaped.
unsafe fn io_uring_enter(
    fd: libc::c_int,
    to_submit: u32,
    min_complete: u32,
    flags: u32,
) -> libc::c_long {
    // SAFETY: raw syscall; SQE/buffer lifetimes are the caller's contract.
    unsafe {
        libc::syscall(
            libc::SYS_io_uring_enter,
            fd as libc::c_long,
            to_submit as libc::c_ulong,
            min_complete as libc::c_ulong,
            flags as libc::c_ulong,
            core::ptr::null::<libc::c_void>(),
            0usize,
        )
    }
}

/// Close-on-drop fd.
struct Fd(libc::c_int);

impl Drop for Fd {
    fn drop(&mut self) {
        if self.0 >= 0 {
            // SAFETY: self.0 is a live fd this wrapper owns; it is closed
            // exactly once (poison paths set it to -1 after closing).
            unsafe { libc::close(self.0) };
        }
    }
}

/// Unmapped-on-drop mmap region over the ring fd.
struct MmapRegion {
    ptr: *mut u8,
    len: usize,
}

impl MmapRegion {
    fn map(fd: libc::c_int, len: usize, offset: u64) -> Result<Self> {
        // SAFETY: a null-hint anonymous-address mmap over a caller-provided
        // live fd; the result is checked against MAP_FAILED below.
        let ptr = unsafe {
            libc::mmap(
                core::ptr::null_mut(),
                len,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED | libc::MAP_POPULATE,
                fd,
                offset as libc::off64_t,
            )
        };
        anyhow::ensure!(
            ptr != libc::MAP_FAILED,
            "io_uring mmap (offset {offset:#x}) failed: {}",
            std::io::Error::last_os_error()
        );
        Ok(Self { ptr: ptr as *mut u8, len })
    }

    /// Pointer `off` bytes into the region. The caller promises `T` fits.
    fn at<T>(&self, off: u32) -> *mut T {
        // SAFETY: kernel-reported ring offsets are in bounds of the mapped
        // length by the io_uring ABI; the add stays inside the region.
        unsafe { self.ptr.add(off.ix()) as *mut T }
    }
}

impl Drop for MmapRegion {
    fn drop(&mut self) {
        // SAFETY: unmaps exactly the region mmap returned, exactly once.
        unsafe { libc::munmap(self.ptr as *mut libc::c_void, self.len) };
    }
}

/// One in-flight batch: how many of its reads the kernel still owns, and
/// the first error observed among its completions.
struct BatchState {
    remaining: usize,
    error: Option<String>,
}

/// Error from the submit/wait paths, recording whether the kernel has
/// *verifiably* released every buffer of the failed batch.
struct UringError {
    /// False when the ring had to be poisoned with reads still
    /// outstanding: closing the fd starts teardown, but on modern kernels
    /// (5.10+) that teardown runs asynchronously in a workqueue
    /// (`io_ring_exit_work`), so the buffers must be treated as still
    /// kernel-owned — leaked, never returned to a pool.
    buffers_released: bool,
    err: anyhow::Error,
}

impl UringError {
    /// An error on a path where nothing of this batch is in flight.
    fn clean(err: anyhow::Error) -> Self {
        Self { buffers_released: true, err }
    }
}

/// The mmap'd ring plus all mutable submission/completion state, guarded
/// by one mutex in [`UringPageStore`].
struct Ring {
    fd: Fd,
    // Regions kept alive for the pointers below; never read directly.
    _sq: MmapRegion,
    _cq: MmapRegion,
    _sqes: MmapRegion,
    sq_head: *const AtomicU32,
    sq_tail: *const AtomicU32,
    sq_mask: u32,
    sq_entries: u32,
    sq_array: *mut u32,
    cq_head: *const AtomicU32,
    cq_tail: *const AtomicU32,
    cq_mask: u32,
    cq_entries: u32,
    cqes: *const libc::io_uring_cqe,
    sqes_ptr: *mut libc::io_uring_sqe,
    page_size: usize,
    /// Reads the kernel currently owns (≤ cq_entries, the no-overflow
    /// invariant).
    in_flight: usize,
    next_batch: u32,
    batches: HashMap<u32, BatchState>,
    /// True while one thread is parked in an *unlocked*
    /// `io_uring_enter(GETEVENTS)` (the designated reaper of
    /// [`await_ring`]); CQEs are normally only consumed when this is
    /// false, so the kernel's wait re-check cannot strand the sleeper.
    reaper_active: bool,
    /// A locked cold-path drain consumed CQEs while a reaper was parked,
    /// but the NOP wakeup could not be posted yet (SQEs of an in-progress
    /// submission were published, and an enter would consume *those*
    /// head-first). Retried by [`Ring::try_post_nop`] whenever the SQ is
    /// observed empty again.
    reaper_wake_pending: bool,
    /// A wake NOP has been submitted and its CQE not yet consumed. At most
    /// one is ever outstanding, which is exactly what the `+ 1` CQ-budget
    /// reservation in `submit_batch` accounts for.
    nop_in_flight: bool,
    /// The ring was poisoned while a reaper was parked in GETEVENTS, so
    /// the fd close was deferred (closing it would let the fd number be
    /// reused and strand the reaper on an unrelated file). The reaper
    /// performs the close when it unparks.
    close_deferred: bool,
    /// Set when an unrecoverable ring error forced the fd closed; all
    /// later operations fail fast.
    poisoned: bool,
}

// SAFETY: the raw pointers all target the mmap regions owned by this
// struct; access is serialized by the surrounding Mutex.
unsafe impl Send for Ring {}

impl Ring {
    fn create(page_size: usize) -> Result<Self> {
        let mut p = libc::io_uring_params::default();
        // SAFETY: `p` is a zeroed local the kernel fills in.
        let rc = unsafe { io_uring_setup(SQ_DEPTH, &mut p) };
        anyhow::ensure!(
            rc >= 0,
            "io_uring_setup failed: {}",
            std::io::Error::last_os_error()
        );
        let fd = Fd(rc as libc::c_int);
        let sq_len = p.sq_off.array.ix() + p.sq_entries.ix() * 4;
        let cq_len =
            p.cq_off.cqes.ix() + p.cq_entries.ix() * core::mem::size_of::<libc::io_uring_cqe>();
        let sqes_len = p.sq_entries.ix() * core::mem::size_of::<libc::io_uring_sqe>();
        let sq = MmapRegion::map(fd.0, sq_len, libc::IORING_OFF_SQ_RING)?;
        let cq = MmapRegion::map(fd.0, cq_len, libc::IORING_OFF_CQ_RING)?;
        let sqes = MmapRegion::map(fd.0, sqes_len, libc::IORING_OFF_SQES)?;
        let ring = Ring {
            sq_head: sq.at::<AtomicU32>(p.sq_off.head),
            sq_tail: sq.at::<AtomicU32>(p.sq_off.tail),
            // SAFETY: ring_mask is a kernel-initialized u32 inside the
            // freshly mapped SQ region.
            sq_mask: unsafe { *sq.at::<u32>(p.sq_off.ring_mask) },
            sq_entries: p.sq_entries,
            sq_array: sq.at::<u32>(p.sq_off.array),
            cq_head: cq.at::<AtomicU32>(p.cq_off.head),
            cq_tail: cq.at::<AtomicU32>(p.cq_off.tail),
            // SAFETY: ring_mask is a kernel-initialized u32 inside the
            // freshly mapped CQ region.
            cq_mask: unsafe { *cq.at::<u32>(p.cq_off.ring_mask) },
            cq_entries: p.cq_entries,
            cqes: cq.at::<libc::io_uring_cqe>(p.cq_off.cqes),
            sqes_ptr: sqes.at::<libc::io_uring_sqe>(0),
            page_size,
            in_flight: 0,
            next_batch: 0,
            batches: HashMap::new(),
            reaper_active: false,
            reaper_wake_pending: false,
            nop_in_flight: false,
            close_deferred: false,
            poisoned: false,
            fd,
            _sq: sq,
            _cq: cq,
            _sqes: sqes,
        };
        anyhow::ensure!(
            ring.sq_entries > 0 && ring.cq_entries > 0,
            "io_uring_setup returned empty rings"
        );
        Ok(ring)
    }

    /// Close the ring fd, which starts kernel-side cancellation of all
    /// outstanding requests. Unlike the AIO store's `io_destroy` (which
    /// blocks), ring teardown is asynchronous on modern kernels, so
    /// callers must treat any still-outstanding buffers as kernel-owned
    /// forever (`UringError::buffers_released == false` → leak them). The
    /// store is unusable afterwards.
    ///
    /// If a reaper is currently parked in `io_uring_enter(GETEVENTS)` on
    /// this fd, the close is deferred to its unpark ([`await_ring`]):
    /// closing now would free the fd *number* for reuse, and the parked
    /// enter could then block against an unrelated file.
    fn poison(&mut self) {
        self.poisoned = true;
        if self.reaper_active {
            self.close_deferred = true;
            return;
        }
        self.close_fd();
    }

    fn close_fd(&mut self) {
        self.close_deferred = false;
        if self.fd.0 >= 0 {
            // SAFETY: the fd is live (≥ 0) and owned by this ring; setting
            // it to -1 below keeps the Fd drop from double-closing.
            unsafe { libc::close(self.fd.0) };
            self.fd.0 = -1;
        }
    }

    /// Sweep every CQE currently visible (never blocks), crediting each to
    /// its batch. Returns how many *read* completions were processed (NOP
    /// wakeups are consumed but not counted). If a reaper thread is parked
    /// in GETEVENTS while this locked sweep consumes CQEs, a NOP is posted
    /// so the kernel's availability re-check cannot strand it.
    fn drain_cq(&mut self) -> usize {
        // SAFETY: cq_tail/cq_head point at kernel-shared atomics inside the
        // live CQ mapping (owned by self, serialized by the ring mutex).
        let tail = unsafe { (*self.cq_tail).load(Ordering::Acquire) };
        // SAFETY: as above — head is only advanced by us, under the lock.
        let mut head = unsafe { (*self.cq_head).load(Ordering::Relaxed) };
        let mut real = 0usize;
        let mut consumed = 0usize;
        while head != tail {
            // SAFETY: `head & cq_mask` indexes within the kernel-sized CQE
            // array, and head != tail means the kernel published this entry.
            let cqe = unsafe { *self.cqes.add((head & self.cq_mask).ix()) };
            head = head.wrapping_add(1);
            consumed += 1;
            if cqe.user_data == NOP_TAG {
                self.nop_in_flight = false;
                continue;
            }
            let batch = hi32(cqe.user_data);
            if let Some(st) = self.batches.get_mut(&batch) {
                st.remaining -= 1;
                if st.error.is_none() {
                    if cqe.res < 0 {
                        st.error = Some(format!(
                            "io_uring read failed: {}",
                            std::io::Error::from_raw_os_error(-cqe.res)
                        ));
                    // lint:allow(truncating-cast): res ≥ 0 in this branch
                    // (the negative case was handled just above).
                    } else if cqe.res as usize != self.page_size {
                        st.error = Some(format!(
                            "io_uring short read: {} of {} bytes",
                            cqe.res, self.page_size
                        ));
                    }
                }
            }
            self.in_flight = self.in_flight.saturating_sub(1);
            real += 1;
        }
        // SAFETY: publishing the new head through the shared CQ atomic —
        // the pointer targets the live mapping owned by self.
        unsafe { (*self.cq_head).store(head, Ordering::Release) };
        if consumed > 0 && self.reaper_active {
            // The parked reaper's kernel-side availability re-check will
            // now see an empty CQ and go back to sleep: wake it with a
            // NOP — possibly deferred, see `try_post_nop`.
            self.reaper_wake_pending = true;
            self.try_post_nop();
        }
        real
    }

    /// Post the pending reaper-wake NOP if it is currently safe to do so.
    /// It is **not** safe while another submission's SQEs sit published
    /// but unconsumed in the SQ: `io_uring_enter(to_submit=1)` consumes
    /// head-first, so it would submit *that* batch's read and wreck its
    /// accounting (and a later tail rewind). In that case the wake stays
    /// pending; `submit_batch` retries it at its exits, by which point the
    /// SQ is empty again (entries consumed) or rewound.
    fn try_post_nop(&mut self) {
        if !self.reaper_wake_pending {
            return;
        }
        if self.poisoned {
            // A poisoned ring's fd is closed; any parked reaper's enter
            // has already failed back to userspace.
            self.reaper_wake_pending = false;
            return;
        }
        if self.nop_in_flight {
            // A wake is already on its way; a second NOP would exceed the
            // single reserved CQ slot.
            self.reaper_wake_pending = false;
            return;
        }
        // SAFETY: sq_head/sq_tail point at kernel-shared atomics inside the
        // live SQ mapping; tail is only advanced by us, under the lock.
        let head = unsafe { (*self.sq_head).load(Ordering::Acquire) };
        // SAFETY: as above.
        let tail = unsafe { (*self.sq_tail).load(Ordering::Relaxed) };
        if tail != head {
            return; // foreign SQEs published: defer (their completions or
                    // a later retry will wake the reaper)
        }
        let slot = tail & self.sq_mask;
        let sqe = libc::io_uring_sqe {
            opcode: libc::IORING_OP_NOP,
            flags: 0,
            ioprio: 0,
            fd: -1,
            off: 0,
            addr: 0,
            len: 0,
            rw_flags: 0,
            user_data: NOP_TAG,
            buf_index: 0,
            personality: 0,
            splice_fd_in: 0,
            __pad2: [0; 2],
        };
        // SAFETY: `slot` is masked into the SQE/array bounds; the tail
        // store publishes the entry; enter is called on our live ring fd
        // with a NOP that references no external buffers. All SQ state is
        // owned by self and serialized by the ring mutex.
        unsafe {
            *self.sqes_ptr.add(slot.ix()) = sqe;
            *self.sq_array.add(slot.ix()) = slot;
            (*self.sq_tail).store(tail.wrapping_add(1), Ordering::Release);
            // Bounded retry: an EAGAIN here is transient kernel memory
            // pressure; yielding a few times almost always clears it. If
            // it persists the wake stays pending for the next retry site —
            // a stranded reaper then needs EAGAIN to persist across every
            // later ring operation too, which compounds into vanishing
            // probability.
            for _ in 0..64 {
                let rc = io_uring_enter(self.fd.0, 1, 0, 0);
                if rc > 0 {
                    self.nop_in_flight = true;
                    self.reaper_wake_pending = false;
                    return;
                }
                let err = std::io::Error::last_os_error();
                if rc < 0
                    && (err.raw_os_error() == Some(libc::EINTR)
                        || err.raw_os_error() == Some(libc::EAGAIN))
                {
                    std::thread::yield_now();
                    continue;
                }
                break;
            }
            // Not consumed: un-publish so a later batch submission's
            // accounting never counts this stale entry as its own.
            (*self.sq_tail).store(tail, Ordering::Release);
        }
    }

    /// Locked, blocking completion wait for the *cold* submit/abort paths:
    /// process completions until at least `min` read CQEs were credited.
    /// Holding the ring lock across the blocking enter is acceptable here
    /// (rare paths, bounded work); the hot wait path goes through
    /// [`await_ring`], which parks outside the lock. A concurrently-parked
    /// reaper is re-woken by `drain_cq`'s NOP.
    fn reap(&mut self, min: usize) -> Result<()> {
        anyhow::ensure!(!self.poisoned, "io_uring ring poisoned by an earlier failure");
        let mut reaped = 0usize;
        loop {
            reaped += self.drain_cq();
            if reaped >= min {
                return Ok(());
            }
            // SAFETY: fd is the live ring fd (poison checked on entry);
            // GETEVENTS submits nothing, so no buffer contract is involved.
            let rc = unsafe { io_uring_enter(self.fd.0, 0, 1, libc::IORING_ENTER_GETEVENTS) };
            if rc < 0 {
                let err = std::io::Error::last_os_error();
                if err.raw_os_error() == Some(libc::EINTR) {
                    continue;
                }
                anyhow::bail!("io_uring_enter(GETEVENTS) failed: {err}");
            }
        }
    }

    /// Submit one batch of page reads; `iovs[i]` must point at the caller's
    /// buffer for `page_ids[i]` and stay alive until the batch completes.
    /// Returns the batch id to wait on. On error no reads remain in flight
    /// for this batch **unless** the returned error says
    /// `buffers_released == false` (poisoned ring — leak the buffers).
    fn submit_batch(
        &mut self,
        file_fd: libc::c_int,
        page_ids: &[u32],
        iovs: &[libc::iovec],
    ) -> std::result::Result<u32, UringError> {
        if self.poisoned {
            return Err(UringError::clean(anyhow::anyhow!(
                "io_uring ring poisoned by an earlier failure"
            )));
        }
        let n = page_ids.len();
        debug_assert_eq!(n, iovs.len());
        // No-overflow invariant: completions must never outnumber CQ slots
        // (one slot is reserved for a reaper-wake NOP, which can land on a
        // full ring). A reap failure here is clean for *this* batch
        // (nothing submitted yet); the batches it strands are handled by
        // their own waiters.
        while self.in_flight + n + 1 > self.cq_entries.ix() {
            self.reap(1).map_err(UringError::clean)?;
        }
        let id = self.next_batch;
        self.next_batch = self.next_batch.wrapping_add(1);
        self.batches.insert(id, BatchState { remaining: 0, error: None });
        let mut accepted = 0usize; // consumed by the kernel, now in flight
        while accepted < n {
            // SQ space: the kernel advances head as it consumes entries
            // (always fully, in non-SQPOLL mode, by the time enter returns).
            // SAFETY: sq_head/sq_tail point at kernel-shared atomics inside
            // the live SQ mapping; tail is only advanced by us, under the
            // ring mutex.
            let head = unsafe { (*self.sq_head).load(Ordering::Acquire) };
            // SAFETY: as above.
            let tail = unsafe { (*self.sq_tail).load(Ordering::Relaxed) };
            let free = self.sq_entries.wrapping_sub(tail.wrapping_sub(head)).ix();
            let take = free.min(n - accepted);
            if take == 0 {
                // Cannot happen (enter below always consumes), but bail
                // rather than spin forever if a kernel ever behaves oddly.
                return Err(self.abort_batch(
                    id,
                    accepted,
                    0,
                    tail,
                    anyhow::anyhow!("io_uring SQ full with nothing to consume"),
                ));
            }
            for k in 0..take {
                let i = accepted + k;
                // lint:allow(truncating-cast): k < take ≤ sq_entries, which
                // is a u32.
                let slot = tail.wrapping_add(k as u32) & self.sq_mask;
                let sqe = libc::io_uring_sqe {
                    opcode: libc::IORING_OP_READV,
                    flags: 0,
                    ioprio: 0,
                    fd: file_fd,
                    off: page_ids[i] as u64 * self.page_size as u64,
                    addr: &iovs[i] as *const libc::iovec as u64,
                    len: 1,
                    rw_flags: 0,
                    user_data: ((id as u64) << 32) | i as u64,
                    buf_index: 0,
                    personality: 0,
                    splice_fd_in: 0,
                    __pad2: [0; 2],
                };
                // SAFETY: `slot` is masked into the SQE/array bounds of the
                // live mappings owned by self, serialized by the ring mutex.
                unsafe {
                    *self.sqes_ptr.add(slot.ix()) = sqe;
                    *self.sq_array.add(slot.ix()) = slot;
                }
            }
            // lint:allow(truncating-cast): take ≤ sq_entries, which is a
            // u32.
            let published = tail.wrapping_add(take as u32);
            // SAFETY: publishes the prepared SQEs through the shared tail
            // atomic in the live SQ mapping.
            unsafe { (*self.sq_tail).store(published, Ordering::Release) };
            // lint:allow(truncating-cast): take ≤ sq_entries (see above).
            let mut to_submit = take as u32;
            while to_submit > 0 {
                // SAFETY: fd is the live ring fd; every published SQE
                // references an iovec/buffer the caller keeps alive until
                // the batch is reaped (submit_batch's contract).
                let rc = unsafe { io_uring_enter(self.fd.0, to_submit, 0, 0) };
                if rc < 0 {
                    let err = std::io::Error::last_os_error();
                    if err.raw_os_error() == Some(libc::EINTR) {
                        continue;
                    }
                    if err.raw_os_error() == Some(libc::EAGAIN) && self.in_flight > 0 {
                        // Kernel out of request slots: free some by reaping
                        // completions, then retry. A reap failure here must
                        // unwind like any other submit failure — rewind the
                        // published-but-unconsumed SQEs and drain (or
                        // poison) — or the caller would free buffers the
                        // kernel still owns.
                        if let Err(re) = self.reap(1) {
                            return Err(self.abort_batch(
                                id,
                                accepted,
                                to_submit,
                                published,
                                anyhow::anyhow!(
                                    "io_uring_enter(submit) EAGAIN after {accepted}/{n}, \
                                     and reaping to free slots failed: {re}"
                                ),
                            ));
                        }
                        continue;
                    }
                    return Err(self.abort_batch(
                        id,
                        accepted,
                        to_submit,
                        published,
                        anyhow::anyhow!(
                            "io_uring_enter(submit) failed after {accepted}/{n}: {err}"
                        ),
                    ));
                }
                // lint:allow(truncating-cast): rc ≥ 0 here (the negative
                // branch returned above) and is bounded by to_submit, a u32.
                let got = rc as u32;
                to_submit -= got;
                accepted += got.ix();
                self.in_flight += got.ix();
                if let Some(st) = self.batches.get_mut(&id) {
                    st.remaining += got.ix();
                }
            }
        }
        // The SQ is empty again: deliver any reaper wake that a mid-submit
        // drain had to defer.
        self.try_post_nop();
        Ok(id)
    }

    /// Unwind a partially-submitted batch: rewind the SQ tail over the
    /// `unconsumed` entries the kernel never took (we are the only
    /// submitter), then reap every read it *did* take so the caller's
    /// buffers are safe to free. Consumes the batch's table entry.
    fn abort_batch(
        &mut self,
        id: u32,
        _accepted: usize,
        unconsumed: u32,
        published_tail: u32,
        err: anyhow::Error,
    ) -> UringError {
        // SAFETY: rewinds the shared tail atomic over entries the kernel
        // never consumed — we are the only submitter, under the ring mutex.
        unsafe {
            (*self.sq_tail)
                .store(published_tail.wrapping_sub(unconsumed), Ordering::Release)
        };
        loop {
            let outstanding = self.batches.get(&id).map(|st| st.remaining).unwrap_or(0);
            if outstanding == 0 {
                break;
            }
            if let Err(re) = self.reap(1) {
                // Cannot drain: poison the ring. Teardown via fd close is
                // asynchronous on modern kernels, so the caller must LEAK
                // this batch's buffers (buffers_released = false).
                self.poison();
                self.batches.remove(&id);
                return UringError {
                    buffers_released: false,
                    err: anyhow::anyhow!(
                        "{err}; draining in-flight reads also failed ({re}); ring poisoned \
                         and the batch buffers remain kernel-owned"
                    ),
                };
            }
        }
        self.batches.remove(&id);
        // The rewind emptied the SQ: deliver any deferred reaper wake so a
        // reaper whose completions this drain consumed cannot stay parked.
        self.try_post_nop();
        UringError::clean(err)
    }
}

pub struct UringPageStore {
    file: std::fs::File,
    page_size: usize,
    n_pages: usize,
    ring: Mutex<Ring>,
    /// Wakes waiters sleeping in [`await_ring`] while another thread is
    /// the designated reaper.
    ring_cv: Condvar,
    /// Largest batch submitted asynchronously; wider ones chunk through
    /// the synchronous path (keeps the no-overflow invariant satisfiable).
    max_batch: usize,
}

impl UringPageStore {
    pub fn open(path: &Path, page_size: usize) -> Result<Self> {
        let file = std::fs::File::open(path)?;
        let len = to_usize(file.metadata()?.len())?;
        anyhow::ensure!(page_size > 0 && len % page_size == 0, "file not page-aligned");
        let ring = Ring::create(page_size)?;
        let max_batch = (ring.cq_entries.ix() / 2).max(1);
        let store = Self {
            file,
            page_size,
            n_pages: len / page_size,
            ring: Mutex::new(ring),
            ring_cv: Condvar::new(),
            max_batch,
        };
        // Probe with a real read: a ring that opens but cannot submit
        // (seccomp, exotic filesystems) must fail over at open() time.
        if store.n_pages > 0 {
            let mut probe = vec![vec![0u8; page_size]];
            store
                .read_pages(&[0], &mut probe)
                .map_err(|e| anyhow::anyhow!("io_uring probe read failed: {e}"))?;
        }
        Ok(store)
    }

    fn validate(&self, page_ids: &[u32], bufs: &[Vec<u8>]) -> Result<()> {
        anyhow::ensure!(page_ids.len() == bufs.len(), "ids/buffers length mismatch");
        for (&p, buf) in page_ids.iter().zip(bufs.iter()) {
            anyhow::ensure!(p.ix() < self.n_pages, "page {p} out of range");
            anyhow::ensure!(buf.len() == self.page_size, "bad buffer size");
        }
        Ok(())
    }

    /// Submit + wait one batch (bounded by `max_batch`).
    fn read_chunk(&self, page_ids: &[u32], out: &mut [Vec<u8>]) -> Result<()> {
        let iovs: Vec<libc::iovec> = out
            .iter_mut()
            .map(|b| libc::iovec {
                iov_base: b.as_mut_ptr() as *mut libc::c_void,
                iov_len: self.page_size,
            })
            .collect();
        // Two statements so the lock guard (a temporary of the first) is
        // dropped before wait_batch re-locks the ring.
        let submitted = lock(&self.ring).submit_batch(self.file.as_raw_fd(), page_ids, &iovs);
        let result = submitted.and_then(|id| wait_batch(&self.ring, &self.ring_cv, id));
        match result {
            Ok(()) => Ok(()),
            Err(ue) => {
                if !ue.buffers_released {
                    // The poisoned ring may still DMA into these buffers:
                    // swap each one out, leak the kernel-targeted memory,
                    // and leave the caller a correctly-sized replacement
                    // so buffer-pool invariants hold.
                    for b in out.iter_mut() {
                        let kernel_owned = std::mem::replace(b, vec![0u8; self.page_size]);
                        // lint:allow(forbidden-forget): sanctioned leak —
                        // the poisoned ring's teardown is asynchronous, so
                        // the kernel may still DMA into this buffer.
                        std::mem::forget(kernel_owned);
                    }
                    // lint:allow(forbidden-forget): the submitted SQEs point
                    // at these iovecs; they stay kernel-owned with the ring.
                    std::mem::forget(iovs);
                }
                Err(ue.err)
            }
        }
    }
}

/// Run `f` under the ring lock, blocking until it yields a value. At most
/// one thread at a time — the designated reaper — parks in
/// `io_uring_enter(GETEVENTS)` *without* the lock, so a blocked waiter
/// never serializes other threads' submissions; the rest sleep on the
/// condvar. CQEs are consumed only while no reaper is parked (plus the
/// NOP re-wake for locked cold-path drains), so the kernel's availability
/// re-check can never strand a sleeper.
fn await_ring<T>(
    ring: &Mutex<Ring>,
    cv: &Condvar,
    mut f: impl FnMut(&mut Ring) -> std::result::Result<Option<T>, UringError>,
) -> std::result::Result<T, UringError> {
    let mut r = lock(ring);
    loop {
        if !r.reaper_active && r.drain_cq() > 0 {
            cv.notify_all();
        }
        if let Some(v) = f(&mut r)? {
            cv.notify_all();
            return Ok(v);
        }
        if r.reaper_active {
            r = cond_wait(cv, r);
            continue;
        }
        // Become the reaper: park in GETEVENTS without the lock.
        r.reaper_active = true;
        let fd = r.fd.0;
        drop(r);
        // SAFETY: the fd stays open while we are parked — a concurrent
        // poison defers its close until this reaper unparks
        // (`close_deferred`); GETEVENTS submits nothing, so no buffer
        // contract is involved.
        let rc = unsafe { io_uring_enter(fd, 0, 1, libc::IORING_ENTER_GETEVENTS) };
        let enter_err = if rc < 0 { Some(std::io::Error::last_os_error()) } else { None };
        r = lock(ring);
        r.reaper_active = false;
        // Awake again: any wake that was queued for this park is obsolete,
        // and a poison that deferred its fd close to us can complete now.
        r.reaper_wake_pending = false;
        if r.close_deferred {
            r.close_fd();
        }
        cv.notify_all();
        if let Some(e) = enter_err {
            if e.raw_os_error() != Some(libc::EINTR) {
                // Unrecoverable wait failure with reads outstanding:
                // poison the ring; the caller must treat its buffers as
                // kernel-owned (ring teardown is asynchronous).
                r.poison();
                return Err(UringError {
                    buffers_released: false,
                    err: anyhow::anyhow!("io_uring_enter(GETEVENTS) failed: {e}"),
                });
            }
        }
    }
}

/// Block until batch `id` fully completes. Completions reaped along the
/// way may belong to other threads' batches; they are credited to those
/// batches' table entries. `buffers_released == false` in the error means
/// the batch's buffers are still kernel-owned (leak them).
fn wait_batch(ring: &Mutex<Ring>, cv: &Condvar, id: u32) -> std::result::Result<(), UringError> {
    await_ring(ring, cv, |r| {
        let remaining = match r.batches.get(&id) {
            None => {
                return Err(UringError::clean(anyhow::anyhow!("unknown io_uring batch {id}")))
            }
            Some(st) => st.remaining,
        };
        if remaining > 0 {
            return Ok(None);
        }
        let Some(st) = r.batches.remove(&id) else {
            return Err(UringError::clean(anyhow::anyhow!("io_uring batch {id} vanished")));
        };
        match st.error {
            None => Ok(Some(())),
            // Every completion was reaped; the buffers are ours again.
            Some(msg) => Err(UringError::clean(anyhow::anyhow!(msg))),
        }
    })
}

impl PageStore for UringPageStore {
    fn page_size(&self) -> usize {
        self.page_size
    }

    fn n_pages(&self) -> usize {
        self.n_pages
    }

    fn read_pages(&self, page_ids: &[u32], out: &mut [Vec<u8>]) -> Result<()> {
        if page_ids.is_empty() {
            return Ok(());
        }
        self.validate(page_ids, out)?;
        let mut start = 0usize;
        while start < page_ids.len() {
            let end = (start + self.max_batch).min(page_ids.len());
            self.read_chunk(&page_ids[start..end], &mut out[start..end])?;
            start = end;
        }
        Ok(())
    }

    fn begin_read(&self, page_ids: &[u32], mut bufs: Vec<Vec<u8>>) -> PendingRead<'_> {
        if page_ids.is_empty() {
            return PendingRead::done(bufs, Ok(()));
        }
        if let Err(e) = self.validate(page_ids, &bufs) {
            return PendingRead::done(bufs, Err(e));
        }
        // Batches wider than the CQ budget run synchronously in chunks.
        if page_ids.len() > self.max_batch {
            let result = self.read_pages(page_ids, &mut bufs);
            return PendingRead::done(bufs, result);
        }
        // The iovec array and the buffers move into the completion closure
        // together: the kernel reads the iovecs and writes the buffers
        // until the batch is reaped, and the inner Vec<u8> allocations do
        // not move when the outer Vec is moved.
        let iovs: Vec<libc::iovec> = bufs
            .iter_mut()
            .map(|b| libc::iovec {
                iov_base: b.as_mut_ptr() as *mut libc::c_void,
                iov_len: self.page_size,
            })
            .collect();
        let id = match lock(&self.ring).submit_batch(self.file.as_raw_fd(), page_ids, &iovs) {
            Ok(id) => id,
            Err(ue) => {
                if ue.buffers_released {
                    // Nothing remains in flight: hand the buffers back.
                    return PendingRead::done(bufs, Err(ue.err));
                }
                // Poisoned ring with reads outstanding: the kernel may
                // still write into these buffers — leak them.
                // lint:allow(forbidden-forget): sanctioned leak — ring
                // teardown is asynchronous, buffers stay kernel-owned.
                std::mem::forget(bufs);
                // lint:allow(forbidden-forget): as above, for the iovecs.
                std::mem::forget(iovs);
                return PendingRead::done(Vec::new(), Err(ue.err));
            }
        };
        let ring = &self.ring;
        let cv = &self.ring_cv;
        PendingRead::deferred(move || match wait_batch(ring, cv, id) {
            Ok(()) => {
                drop(iovs); // kernel is done with the batch; release the iovecs
                (bufs, Ok(()))
            }
            Err(ue) if ue.buffers_released => (bufs, Err(ue.err)),
            Err(ue) => {
                // Poisoned mid-wait: buffers stay kernel-owned — leak them
                // rather than returning them to a pool the kernel can
                // still scribble over.
                // lint:allow(forbidden-forget): sanctioned leak — ring
                // teardown is asynchronous, buffers stay kernel-owned.
                std::mem::forget(bufs);
                // lint:allow(forbidden-forget): as above, for the iovecs.
                std::mem::forget(iovs);
                (Vec::new(), Err(ue.err))
            }
        })
    }

    fn max_inflight_batches(&self) -> usize {
        // Bounded in practice by the CQ budget at submit time; report a
        // conservative deep-queue figure for pipeline planning.
        32
    }

    fn name(&self) -> &'static str {
        "io-uring"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("pageann-uring-{}-{name}", std::process::id()))
    }

    /// Skip (not fail) on kernels without io_uring — the CI kernel is 4.4.
    macro_rules! open_or_skip {
        ($path:expr, $page:expr) => {
            match UringPageStore::open($path, $page) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("io_uring unavailable in this environment: {e}");
                    let _ = std::fs::remove_file($path);
                    return;
                }
            }
        };
    }

    #[test]
    fn many_tagged_batches_complete_out_of_order() {
        let path = tmpfile("ooo");
        crate::io::write_test_pages(&path, 4096, 32);
        let store = open_or_skip!(&path, 4096);
        // Six overlapping batches, waited in reverse submission order.
        let batches: Vec<Vec<u32>> =
            (0..6u32).map(|b| vec![b * 5, b * 5 + 1, (b * 7 + 3) % 32]).collect();
        let mut pending = Vec::new();
        for ids in &batches {
            let bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; 4096]).collect();
            pending.push(store.begin_read(ids, bufs));
        }
        for (ids, p) in batches.iter().zip(pending.drain(..)).rev() {
            let (bufs, r) = p.wait();
            r.unwrap();
            for (k, &pg) in ids.iter().enumerate() {
                for (i, &b) in bufs[k].iter().enumerate() {
                    assert_eq!(b, ((pg as usize * 131 + i) % 251) as u8, "page {pg} byte {i}");
                }
            }
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn oversized_batch_falls_back_to_chunked_sync() {
        let path = tmpfile("big");
        crate::io::write_test_pages(&path, 512, 64);
        let store = open_or_skip!(&path, 512);
        // Wider than max_batch by construction of a tiny repeated id list.
        let n = store.max_batch + 17;
        let ids: Vec<u32> = (0..n).map(|i| (i % 64) as u32).collect();
        let bufs: Vec<Vec<u8>> = ids.iter().map(|_| vec![0u8; 512]).collect();
        let (bufs, r) = store.begin_read(&ids, bufs).wait();
        r.unwrap();
        for (k, &pg) in ids.iter().enumerate() {
            assert_eq!(bufs[k][1], ((pg as usize * 131 + 1) % 251) as u8);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn drop_without_wait_completes_and_ring_stays_usable() {
        let path = tmpfile("drop");
        crate::io::write_test_pages(&path, 4096, 8);
        let store = open_or_skip!(&path, 4096);
        for _ in 0..4 {
            let bufs: Vec<Vec<u8>> = (0..3).map(|_| vec![0u8; 4096]).collect();
            let p = store.begin_read(&[1, 2, 3], bufs);
            drop(p); // never waited: Drop must reap the batch
        }
        assert_eq!(store.ring.lock().unwrap().in_flight, 0, "reads leaked in flight");
        assert!(store.ring.lock().unwrap().batches.is_empty(), "batch table leaked");
        let mut bufs = vec![vec![0u8; 4096]];
        store.read_pages(&[5], &mut bufs).unwrap();
        assert_eq!(bufs[0][0], ((5 * 131) % 251) as u8);
        std::fs::remove_file(&path).unwrap();
    }
}
