//! Process CPU utilization sampling from /proc (Table 5's CPU column).
#![deny(unsafe_op_in_unsafe_fn)]

use std::time::Instant;

/// Measures process CPU utilization (% of one core; >100% means more than
/// one core busy) between `start()` and `stop()`.
pub struct CpuMeter {
    start_wall: Instant,
    start_cpu: f64,
}

/// Total user+system CPU seconds consumed by this process so far.
fn process_cpu_seconds() -> f64 {
    // /proc/self/stat fields 14,15 (utime, stime) in clock ticks.
    let Ok(stat) = std::fs::read_to_string("/proc/self/stat") else {
        return 0.0;
    };
    // The comm field may contain spaces; skip to after the closing paren.
    let Some(rest) = stat.rsplit_once(')').map(|(_, r)| r) else {
        return 0.0;
    };
    let fields: Vec<&str> = rest.split_whitespace().collect();
    // After ") ", field index 11 = utime, 12 = stime (0-based in `rest`).
    if fields.len() < 13 {
        return 0.0;
    }
    let utime: f64 = fields[11].parse().unwrap_or(0.0);
    let stime: f64 = fields[12].parse().unwrap_or(0.0);
    let hz = ticks_per_second();
    (utime + stime) / hz
}

fn ticks_per_second() -> f64 {
    // SC_CLK_TCK is 100 on every Linux we target.
    // SAFETY: sysconf reads a process-wide constant; no pointers involved.
    let v = unsafe { libc::sysconf(libc::_SC_CLK_TCK) };
    if v > 0 {
        v as f64
    } else {
        100.0
    }
}

impl CpuMeter {
    pub fn start() -> Self {
        Self { start_wall: Instant::now(), start_cpu: process_cpu_seconds() }
    }

    /// CPU utilization since `start()`, in percent of one core.
    pub fn utilization_pct(&self) -> f64 {
        let wall = self.start_wall.elapsed().as_secs_f64();
        if wall <= 0.0 {
            return 0.0;
        }
        let cpu = process_cpu_seconds() - self.start_cpu;
        (cpu / wall) * 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_loop_registers_cpu() {
        let meter = CpuMeter::start();
        // Burn ~30ms of CPU.
        let t = Instant::now();
        let mut x = 0u64;
        while t.elapsed().as_millis() < 30 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
        }
        std::hint::black_box(x);
        let pct = meter.utilization_pct();
        assert!(pct > 20.0, "cpu meter too low: {pct}");
        assert!(pct < 3000.0, "cpu meter absurd: {pct}");
    }

    #[test]
    fn clk_tck_sane() {
        let hz = ticks_per_second();
        assert!(hz >= 50.0 && hz <= 1000.0, "{hz}");
    }
}
