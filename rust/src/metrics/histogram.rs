//! Latency histogram with log-spaced buckets (1µs … 10s) for percentile
//! reporting without storing every sample.

use std::time::Duration;

const BUCKETS: usize = 200;
const MIN_US: f64 = 1.0;
const MAX_US: f64 = 10_000_000.0; // 10 s

#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    sum_us: f64,
    max_us: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { counts: vec![0; BUCKETS], total: 0, sum_us: 0.0, max_us: 0.0 }
    }

    fn bucket_of(us: f64) -> usize {
        if us <= MIN_US {
            return 0;
        }
        let frac = (us.ln() - MIN_US.ln()) / (MAX_US.ln() - MIN_US.ln());
        ((frac * BUCKETS as f64) as usize).min(BUCKETS - 1)
    }

    /// Representative (geometric-mid) latency of bucket `b`, in µs.
    fn bucket_value(b: usize) -> f64 {
        let frac = (b as f64 + 0.5) / BUCKETS as f64;
        (MIN_US.ln() + frac * (MAX_US.ln() - MIN_US.ln())).exp()
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        self.counts[Self::bucket_of(us)] += 1;
        self.total += 1;
        self.sum_us += us;
        if us > self.max_us {
            self.max_us = us;
        }
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    /// Approximate percentile (0.0–1.0) in µs.
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (p.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return Self::bucket_value(b);
            }
        }
        self.max_us
    }

    pub fn p50_ms(&self) -> f64 {
        self.percentile_us(0.50) / 1e3
    }

    pub fn p99_ms(&self) -> f64 {
        self.percentile_us(0.99) / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_percentiles_track_samples() {
        let mut h = LatencyHistogram::new();
        for ms in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 10);
        let mean = h.mean_us() / 1e3;
        assert!((mean - 14.5).abs() < 0.1, "{mean}");
        // p50 around 5ms (log buckets — allow wide slack).
        let p50 = h.p50_ms();
        assert!(p50 > 2.0 && p50 < 9.0, "{p50}");
        // p99 near the 100ms outlier.
        let p99 = h.p99_ms();
        assert!(p99 > 50.0, "{p99}");
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(Duration::from_millis(1));
        b.record(Duration::from_millis(10));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.percentile_us(1.0) >= 9_000.0);
    }

    #[test]
    fn extremes_clamped() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_nanos(1));
        h.record(Duration::from_secs(100));
        assert_eq!(h.count(), 2);
        assert!(h.percentile_us(0.0) >= 0.0);
    }
}
