//! Reusable log-bucketed histograms: fixed memory, mergeable, percentile
//! reporting without storing every sample.
//!
//! `LogHistogram` is the general primitive — any positive value domain
//! (latency µs, inter-arrival gaps, batch occupancy) over caller-chosen
//! bounds. `LatencyHistogram` is the µs-domain wrapper (1µs … 10s) used
//! throughout the query path. Semantics are documented in
//! `OBSERVABILITY.md` ("Histogram semantics").

use std::time::Duration;

const DEFAULT_BUCKETS: usize = 200;
const MIN_US: f64 = 1.0;
const MAX_US: f64 = 10_000_000.0; // 10 s

/// Compact percentile summary of one histogram — the unit that crosses
/// the `PANT` stats wire frame and lands in bench JSON.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub p999: f64,
    pub max: f64,
}

/// Log-spaced bucketed histogram over `[min, max]` with a fixed number of
/// buckets. Values below `min` clamp into bucket 0; values above `max`
/// clamp into the last bucket (and are still reflected exactly in
/// `max_value()`). Merging requires identical bucket geometry — merge of
/// mismatched shapes is a debug-assert and degrades to totals-only in
/// release builds.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    ln_min: f64,
    ln_span: f64,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    max_seen: f64,
}

impl LogHistogram {
    /// `min`/`max` must be positive with `min < max`; out-of-range values
    /// clamp rather than error.
    pub fn new(min: f64, max: f64, buckets: usize) -> Self {
        let min = if min > 0.0 { min } else { 1.0 };
        let max = if max > min { max } else { min * 2.0 };
        let buckets = buckets.max(1);
        Self {
            ln_min: min.ln(),
            ln_span: max.ln() - min.ln(),
            counts: vec![0; buckets],
            total: 0,
            sum: 0.0,
            max_seen: 0.0,
        }
    }

    pub fn buckets(&self) -> usize {
        self.counts.len()
    }

    /// Raw per-bucket counts (low bucket first).
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    pub(crate) fn bucket_of(&self, v: f64) -> usize {
        if !(v.ln() > self.ln_min) {
            return 0;
        }
        let frac = (v.ln() - self.ln_min) / self.ln_span;
        ((frac * self.counts.len() as f64) as usize).min(self.counts.len() - 1)
    }

    /// Representative (geometric-mid) value of bucket `b`.
    pub(crate) fn bucket_value(&self, b: usize) -> f64 {
        let frac = (b as f64 + 0.5) / self.counts.len() as f64;
        (self.ln_min + frac * self.ln_span).exp()
    }

    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let b = self.bucket_of(v);
        self.counts[b] += 1;
        self.total += 1;
        self.sum += v;
        if v > self.max_seen {
            self.max_seen = v;
        }
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        debug_assert_eq!(self.counts.len(), other.counts.len(), "histogram shape mismatch");
        debug_assert!(
            (self.ln_min - other.ln_min).abs() < 1e-12
                && (self.ln_span - other.ln_span).abs() < 1e-12,
            "histogram bounds mismatch"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max_seen = self.max_seen.max(other.max_seen);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Largest value ever recorded (exact, not bucket-quantized).
    pub fn max_value(&self) -> f64 {
        self.max_seen
    }

    /// Approximate percentile (`p` in 0.0–1.0): the geometric mid of the
    /// bucket holding the `⌈p·count⌉`-th sample. Monotone in `p` by
    /// construction; the top percentile is capped at the exact max.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (p.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                // Bucket mid, capped at the exact max so the top percentile
                // never reports beyond a value that was actually seen.
                return self.bucket_value(b).min(self.max_seen);
            }
        }
        self.max_seen
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p90(&self) -> f64 {
        self.percentile(0.90)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }

    pub fn p999(&self) -> f64 {
        self.percentile(0.999)
    }

    pub fn summary(&self) -> HistSummary {
        HistSummary {
            count: self.total,
            mean: self.mean(),
            p50: self.p50(),
            p90: self.p90(),
            p99: self.p99(),
            p999: self.p999(),
            max: self.max_seen,
        }
    }
}

/// Latency histogram in µs (1µs … 10s, 200 log buckets).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    h: LogHistogram,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { h: LogHistogram::new(MIN_US, MAX_US, DEFAULT_BUCKETS) }
    }

    pub fn record(&mut self, d: Duration) {
        self.h.record(d.as_secs_f64() * 1e6);
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        self.h.merge(&other.h);
    }

    pub fn count(&self) -> u64 {
        self.h.count()
    }

    pub fn mean_us(&self) -> f64 {
        self.h.mean()
    }

    /// Approximate percentile (0.0–1.0) in µs.
    pub fn percentile_us(&self, p: f64) -> f64 {
        self.h.percentile(p)
    }

    pub fn p50_ms(&self) -> f64 {
        self.percentile_us(0.50) / 1e3
    }

    pub fn p90_ms(&self) -> f64 {
        self.percentile_us(0.90) / 1e3
    }

    pub fn p99_ms(&self) -> f64 {
        self.percentile_us(0.99) / 1e3
    }

    pub fn p999_ms(&self) -> f64 {
        self.percentile_us(0.999) / 1e3
    }

    /// Summary in µs units.
    pub fn summary(&self) -> HistSummary {
        self.h.summary()
    }

    pub fn inner(&self) -> &LogHistogram {
        &self.h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_percentiles_track_samples() {
        let mut h = LatencyHistogram::new();
        for ms in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 10);
        let mean = h.mean_us() / 1e3;
        assert!((mean - 14.5).abs() < 0.1, "{mean}");
        // p50 around 5ms (log buckets — allow wide slack).
        let p50 = h.p50_ms();
        assert!(p50 > 2.0 && p50 < 9.0, "{p50}");
        // p99 near the 100ms outlier.
        let p99 = h.p99_ms();
        assert!(p99 > 50.0, "{p99}");
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(Duration::from_millis(1));
        b.record(Duration::from_millis(10));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.percentile_us(1.0) >= 9_000.0);
    }

    #[test]
    fn extremes_clamped() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_nanos(1));
        h.record(Duration::from_secs(100));
        assert_eq!(h.count(), 2);
        assert!(h.percentile_us(0.0) >= 0.0);
        // Above-range samples clamp into the last bucket but keep the exact max.
        assert!((h.inner().max_value() - 100e6).abs() < 1.0);
    }

    #[test]
    fn bucket_boundaries_clamp_and_cover() {
        let h = LogHistogram::new(1.0, 1000.0, 30);
        // Below-min and at-min land in bucket 0.
        assert_eq!(h.bucket_of(0.0), 0);
        assert_eq!(h.bucket_of(0.5), 0);
        assert_eq!(h.bucket_of(1.0), 0);
        // Above-max clamps to the last bucket.
        assert_eq!(h.bucket_of(1000.0), 29);
        assert_eq!(h.bucket_of(1e12), 29);
        // bucket_of is monotone over a geometric sweep and bucket_value is
        // a value inside the bucket's bounds.
        let mut last = 0usize;
        let mut v = 1.0f64;
        while v <= 1000.0 {
            let b = h.bucket_of(v);
            assert!(b >= last, "bucket_of not monotone at {v}");
            last = b;
            let mid = h.bucket_value(b);
            assert!(mid > 0.9 && mid < 1100.0);
            v *= 1.07;
        }
        // Every bucket's representative value maps back to that bucket.
        for b in 0..30 {
            assert_eq!(h.bucket_of(h.bucket_value(b)), b, "bucket {b} roundtrip");
        }
    }

    #[test]
    fn merge_is_associative() {
        // xorshift-ish deterministic sample stream split three ways.
        let mut x = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            1.0 + (x % 1_000_000) as f64
        };
        let mk = || LogHistogram::new(1.0, 1e7, 64);
        let (mut a, mut b, mut c) = (mk(), mk(), mk());
        for i in 0..3000 {
            let v = next();
            [&mut a, &mut b, &mut c][i % 3].record(v);
        }
        // (a ⊕ b) ⊕ c  ==  a ⊕ (b ⊕ c), bucket-for-bucket.
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left.bucket_counts(), right.bucket_counts());
        assert_eq!(left.count(), right.count());
        assert!((left.mean() - right.mean()).abs() < 1e-9);
        assert_eq!(left.max_value(), right.max_value());
    }

    #[test]
    fn percentiles_monotone_and_near_sorted_oracle() {
        // Compare against the exact sorted-vector percentile: the log-bucket
        // estimate must stay within one bucket's relative width
        // ((1e7)^(1/200) ≈ 1.084 per bucket — allow 1.10 slack).
        let mut x = 0xdeadbeefcafef00du64;
        let mut next = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            2.0 + (x % 5_000_000) as f64
        };
        let mut h = LogHistogram::new(1.0, 1e7, 200);
        let mut vals: Vec<f64> = (0..5000).map(|_| next()).collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev = 0.0f64;
        for p in [0.01, 0.10, 0.25, 0.50, 0.75, 0.90, 0.99, 0.999] {
            let est = h.percentile(p);
            assert!(est >= prev, "percentile not monotone at p={p}: {est} < {prev}");
            prev = est;
            let idx = ((p * vals.len() as f64).ceil() as usize).clamp(1, vals.len()) - 1;
            let exact = vals[idx];
            let ratio = est / exact;
            assert!(
                (0.90..=1.10).contains(&ratio),
                "p={p}: estimate {est} vs oracle {exact} (ratio {ratio})"
            );
        }
        // The full summary is ordered.
        let s = h.summary();
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.p999);
        assert!(s.p999 <= s.max * 1.10);
    }

    #[test]
    fn summary_of_empty_is_zero() {
        let s = LogHistogram::new(1.0, 100.0, 8).summary();
        assert_eq!(s, HistSummary::default());
    }
}
