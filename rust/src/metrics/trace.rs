//! Opt-in bounded query tracing: one JSONL span record per hop.
//!
//! Off by default (a `None` check per hop is the entire happy-path cost).
//! Enabled via `--trace <path>` or `PAGEANN_TRACE=<path>`: every hop of
//! every query appends one JSON line — page ids wanted, speculation
//! hit/miss, retries, and per-phase durations — to the trace file. A
//! dedicated writer thread drains a bounded in-memory queue; when the
//! writer falls behind, new spans are *dropped and counted* instead of
//! ever blocking the query path. The JSONL schema is documented in
//! `OBSERVABILITY.md` ("Trace JSONL schema").

use crate::util::sync::{cond_wait, cond_wait_timeout, lock};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Version stamped into the trace file's `open` record; bump on any
/// field change to the hop span schema.
pub const TRACE_SCHEMA_VERSION: u32 = 1;

/// Default bounded-queue capacity (spans). ~100 bytes/span, so the queue
/// caps at sub-MB memory even when the writer stalls completely.
pub const DEFAULT_CAPACITY: usize = 8192;

struct Queue {
    q: VecDeque<String>,
    shutdown: bool,
    /// True while the writer holds drained-but-unflushed lines, so
    /// `sync()` really means "on disk", not just "dequeued".
    in_flight: bool,
}

struct Shared {
    state: Mutex<Queue>,
    /// Producers → writer: "there is work".
    cv: Condvar,
    /// Writer → `sync()` waiters: "queue drained and flushed".
    drained: Condvar,
    /// Test/debug hook: while true the writer parks without draining, so
    /// queue-full drop behavior becomes deterministic.
    paused: AtomicBool,
    dropped: AtomicU64,
    emitted: AtomicU64,
    cap: usize,
}

/// One hop of one query, as recorded by the search loop. All durations
/// are µs of wall time charged to this query for this hop.
#[derive(Debug, Clone, Copy, Default)]
pub struct HopSpan<'a> {
    /// Process-wide query sequence number (`TraceSink::next_query_id`).
    pub query: u64,
    /// Hop index within the query, starting at 0.
    pub hop: u64,
    /// Queries sharing this round's deduplicated read (1 = sequential).
    pub batch: u64,
    /// Page ids this query wanted this hop (cache hits included).
    pub pages: &'a [u32],
    /// Pages of `pages` served from the in-memory cache.
    pub cache_hits: u64,
    /// Speculatively-read pages this hop consumed.
    pub spec_hits: u64,
    /// Speculatively-read pages this hop discarded.
    pub spec_wasted: u64,
    /// Read attempts retried-then-OK during this hop.
    pub retries: u64,
    /// Pages that stayed unreadable and were skipped this hop.
    pub failed_ios: u64,
    pub lut_build_us: f64,
    pub io_submit_us: f64,
    pub io_wait_us: f64,
    pub topology_us: f64,
    pub rerank_us: f64,
}

/// Bounded, non-blocking JSONL trace writer. Clone the `Arc` freely —
/// emission is `&self` and thread-safe.
pub struct TraceSink {
    shared: Arc<Shared>,
    writer: Mutex<Option<JoinHandle<()>>>,
    seq: AtomicU64,
}

impl TraceSink {
    /// Create (truncate) `path` and start the writer thread.
    pub fn create(path: &Path) -> Result<TraceSink> {
        Self::create_with_capacity(path, DEFAULT_CAPACITY)
    }

    pub fn create_with_capacity(path: &Path, cap: usize) -> Result<TraceSink> {
        let file = File::create(path)
            .with_context(|| format!("trace: create {}", path.display()))?;
        let shared = Arc::new(Shared {
            state: Mutex::new(Queue { q: VecDeque::new(), shutdown: false, in_flight: false }),
            cv: Condvar::new(),
            drained: Condvar::new(),
            paused: AtomicBool::new(false),
            dropped: AtomicU64::new(0),
            emitted: AtomicU64::new(0),
            cap: cap.max(1),
        });
        let shared2 = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name("pageann-trace".into())
            .spawn(move || writer_loop(shared2, file))
            .context("trace: spawn writer thread")?;
        let sink = TraceSink {
            shared,
            writer: Mutex::new(Some(handle)),
            seq: AtomicU64::new(0),
        };
        sink.emit_line(format!(
            "{{\"ev\":\"open\",\"schema_version\":{TRACE_SCHEMA_VERSION}}}"
        ));
        Ok(sink)
    }

    /// Resolve the trace target: explicit path (CLI) wins, else the
    /// `PAGEANN_TRACE` environment variable, else tracing stays off.
    pub fn from_env_or(explicit: Option<&Path>) -> Result<Option<Arc<TraceSink>>> {
        let path = match explicit {
            Some(p) => Some(p.to_path_buf()),
            None => std::env::var_os("PAGEANN_TRACE").map(std::path::PathBuf::from),
        };
        match path {
            Some(p) => Ok(Some(Arc::new(TraceSink::create(&p)?))),
            None => Ok(None),
        }
    }

    /// Allocate a process-unique query id for span correlation.
    pub fn next_query_id(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Spans dropped because the queue was full (writer behind).
    pub fn dropped(&self) -> u64 {
        self.shared.dropped.load(Ordering::Relaxed)
    }

    /// Spans accepted into the queue (including not-yet-written ones).
    pub fn emitted(&self) -> u64 {
        self.shared.emitted.load(Ordering::Relaxed)
    }

    /// Enqueue one raw JSONL line. Never blocks: a full queue increments
    /// the drop counter and returns.
    pub fn emit_line(&self, line: String) {
        let mut g = lock(&self.shared.state);
        if g.shutdown || g.q.len() >= self.shared.cap {
            drop(g);
            self.shared.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        g.q.push_back(line);
        drop(g);
        self.shared.emitted.fetch_add(1, Ordering::Relaxed);
        self.shared.cv.notify_one();
    }

    /// Format and enqueue one hop span.
    pub fn emit_hop(&self, s: &HopSpan) {
        let mut line = String::with_capacity(160 + 8 * s.pages.len());
        let _ = write!(
            line,
            "{{\"ev\":\"hop\",\"q\":{},\"hop\":{},\"batch\":{},\"pages\":[",
            s.query, s.hop, s.batch
        );
        for (i, p) in s.pages.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            let _ = write!(line, "{p}");
        }
        let _ = write!(
            line,
            "],\"cache_hits\":{},\"spec_hits\":{},\"spec_wasted\":{},\"retries\":{},\"failed_ios\":{}",
            s.cache_hits, s.spec_hits, s.spec_wasted, s.retries, s.failed_ios
        );
        let _ = write!(
            line,
            ",\"lut_build_us\":{:.1},\"io_submit_us\":{:.1},\"io_wait_us\":{:.1},\"topology_us\":{:.1},\"rerank_us\":{:.1}}}",
            s.lut_build_us, s.io_submit_us, s.io_wait_us, s.topology_us, s.rerank_us
        );
        self.emit_line(line);
    }

    /// Block until every span enqueued before this call has been written
    /// and flushed (bounded wait per iteration; used by tests and by the
    /// CLI before printing a "trace written" notice).
    pub fn sync(&self) {
        let mut g = lock(&self.shared.state);
        while (!g.q.is_empty() || g.in_flight) && !g.shutdown {
            let (g2, _) = cond_wait_timeout(&self.shared.drained, g, Duration::from_millis(50));
            g = g2;
        }
    }

    #[cfg(test)]
    fn set_paused(&self, paused: bool) {
        self.shared.paused.store(paused, Ordering::SeqCst);
        self.shared.cv.notify_all();
    }
}

impl Drop for TraceSink {
    fn drop(&mut self) {
        {
            let mut g = lock(&self.shared.state);
            g.shutdown = true;
        }
        self.shared.paused.store(false, Ordering::SeqCst);
        self.shared.cv.notify_all();
        let handle = lock(&self.writer).take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

fn writer_loop(shared: Arc<Shared>, file: File) {
    let mut out = BufWriter::new(file);
    let mut batch: Vec<String> = Vec::new();
    loop {
        let shutdown = {
            let mut g = lock(&shared.state);
            while (g.q.is_empty() || shared.paused.load(Ordering::SeqCst)) && !g.shutdown {
                g = cond_wait(&shared.cv, g);
            }
            batch.extend(g.q.drain(..));
            g.in_flight = !batch.is_empty();
            g.shutdown
        };
        for line in batch.drain(..) {
            let _ = out.write_all(line.as_bytes());
            let _ = out.write_all(b"\n");
        }
        let _ = out.flush();
        lock(&shared.state).in_flight = false;
        shared.drained.notify_all();
        if shutdown {
            let summary = format!(
                "{{\"ev\":\"summary\",\"emitted\":{},\"dropped\":{}}}\n",
                shared.emitted.load(Ordering::Relaxed),
                shared.dropped.load(Ordering::Relaxed)
            );
            let _ = out.write_all(summary.as_bytes());
            let _ = out.flush();
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("pageann_trace_{}_{}", std::process::id(), name));
        p
    }

    /// Minimal structural JSON check: balanced braces/brackets outside
    /// strings, even quote count, single-line.
    fn looks_like_json_object(line: &str) -> bool {
        if !line.starts_with('{') || !line.ends_with('}') {
            return false;
        }
        let (mut depth, mut quotes) = (0i64, 0u64);
        let mut in_str = false;
        for c in line.chars() {
            match c {
                '"' => {
                    in_str = !in_str;
                    quotes += 1;
                }
                '{' | '[' if !in_str => depth += 1,
                '}' | ']' if !in_str => depth -= 1,
                '\n' => return false,
                _ => {}
            }
            if depth < 0 {
                return false;
            }
        }
        depth == 0 && quotes % 2 == 0 && !in_str
    }

    #[test]
    fn bounded_queue_counts_drops() {
        let path = tmpfile("drops");
        {
            let sink = TraceSink::create_with_capacity(&path, 4).unwrap();
            sink.sync(); // let the open record drain
            sink.set_paused(true);
            for i in 0..20 {
                sink.emit_line(format!("{{\"ev\":\"t\",\"i\":{i}}}"));
            }
            // Queue holds 4; the other 16 were dropped, not blocked on.
            assert_eq!(sink.dropped(), 16, "dropped={}", sink.dropped());
            assert_eq!(sink.emitted(), 1 + 4);
            sink.set_paused(false);
            sink.sync();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // open + 4 surviving spans + shutdown summary.
        assert_eq!(lines.len(), 6, "{text}");
        assert!(lines[0].contains("\"ev\":\"open\""));
        assert!(lines[5].contains("\"dropped\":16"), "{}", lines[5]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn concurrent_writers_produce_valid_jsonl() {
        let path = tmpfile("concurrent");
        let n_threads = 8;
        let per_thread = 200;
        {
            let sink = Arc::new(TraceSink::create(&path).unwrap());
            let mut handles = Vec::new();
            for t in 0..n_threads {
                let s = Arc::clone(&sink);
                handles.push(std::thread::spawn(move || {
                    for i in 0..per_thread {
                        let q = s.next_query_id();
                        let pages = [t as u32, i as u32, 7];
                        s.emit_hop(&HopSpan {
                            query: q,
                            hop: i as u64,
                            batch: 1,
                            pages: &pages,
                            cache_hits: 1,
                            retries: 0,
                            io_wait_us: 12.5,
                            topology_us: 3.25,
                            ..Default::default()
                        });
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            sink.sync();
            assert_eq!(sink.dropped(), 0);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // open + all spans + summary: nothing torn, every line standalone JSON.
        assert_eq!(lines.len(), 1 + n_threads * per_thread + 1);
        for line in &lines {
            assert!(looks_like_json_object(line), "bad line: {line}");
        }
        let hops = lines.iter().filter(|l| l.contains("\"ev\":\"hop\"")).count();
        assert_eq!(hops, n_threads * per_thread);
        // Query ids were allocated uniquely across threads.
        assert!(text.contains(&format!("\"q\":{}", n_threads * per_thread - 1)));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn emit_after_shutdown_is_counted_not_lost_silently() {
        let path = tmpfile("shutdown");
        let sink = TraceSink::create(&path).unwrap();
        {
            let mut g = lock(&sink.shared.state);
            g.shutdown = true;
        }
        sink.emit_line("{\"ev\":\"late\"}".into());
        assert_eq!(sink.dropped(), 1);
        drop(sink);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn hop_span_json_shape() {
        let pages = [3u32, 9, 1024];
        let path = tmpfile("shape");
        {
            let sink = TraceSink::create(&path).unwrap();
            sink.emit_hop(&HopSpan {
                query: 42,
                hop: 3,
                batch: 8,
                pages: &pages,
                cache_hits: 2,
                spec_hits: 1,
                spec_wasted: 0,
                retries: 1,
                failed_ios: 0,
                lut_build_us: 1.0,
                io_submit_us: 2.0,
                io_wait_us: 150.0,
                topology_us: 30.5,
                rerank_us: 12.0,
            });
            sink.sync();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let hop = text.lines().find(|l| l.contains("\"ev\":\"hop\"")).unwrap();
        assert!(looks_like_json_object(hop));
        assert!(hop.contains("\"q\":42"));
        assert!(hop.contains("\"pages\":[3,9,1024]"));
        assert!(hop.contains("\"io_wait_us\":150.0"));
        let _ = std::fs::remove_file(&path);
    }
}
