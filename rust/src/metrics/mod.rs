//! Query metrics: the quantities every table and figure in the paper
//! reports — latency (mean + percentiles), throughput (QPS), mean I/Os,
//! read amplification, I/O-vs-compute breakdown (Fig. 2), and CPU
//! utilization (Table 5).

mod cpu;
mod histogram;
pub mod trace;

pub use cpu::CpuMeter;
pub use histogram::{HistSummary, LatencyHistogram, LogHistogram};
pub use trace::TraceSink;

use std::time::Duration;

/// Number of phases in [`PhaseTimes`] / the order of [`PhaseTimes::NAMES`].
pub const N_PHASES: usize = 6;

/// Per-phase wall-time breakdown of one query (the observability layer's
/// phase taxonomy — see `OBSERVABILITY.md`). Every phase is a disjoint
/// span, so `sum() ≤ total_time` always holds; the coarse
/// `io_time`/`compute_time` pair is preserved unchanged and decomposes as
/// `io_time = io_submit + io_wait`, `compute_time = lut_build + topology
/// + rerank` on the search path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PhaseTimes {
    /// Time spent parked in the server's admission queue waiting for the
    /// gather window to close (zero outside the server path).
    pub gather_wait: Duration,
    /// ADC LUT construction (`build_lut_into`/`build_luts_into`),
    /// including cross-tick cache probes.
    pub lut_build: Duration,
    /// Submitting page reads to the I/O backend (`begin_read`), including
    /// speculative submissions.
    pub io_submit: Duration,
    /// Blocked on in-flight reads (`PendingRead::wait`).
    pub io_wait: Duration,
    /// Topology scan: neighbor gathering, ADC scoring, frontier pushes.
    pub topology: Duration,
    /// Exact-distance rerank: deferred exact scans + final result ranking.
    pub rerank: Duration,
}

impl PhaseTimes {
    /// Phase names in field order — the canonical spelling used by the
    /// stats wire frame ("<name>_us" histograms) and trace spans.
    pub const NAMES: [&'static str; N_PHASES] =
        ["gather_wait", "lut_build", "io_submit", "io_wait", "topology", "rerank"];

    pub fn as_array(&self) -> [Duration; N_PHASES] {
        [
            self.gather_wait,
            self.lut_build,
            self.io_submit,
            self.io_wait,
            self.topology,
            self.rerank,
        ]
    }

    /// Total accounted time across all phases.
    pub fn sum(&self) -> Duration {
        self.as_array().iter().sum()
    }

    pub fn merge(&mut self, other: &PhaseTimes) {
        self.gather_wait += other.gather_wait;
        self.lut_build += other.lut_build;
        self.io_submit += other.io_submit;
        self.io_wait += other.io_wait;
        self.topology += other.topology;
        self.rerank += other.rerank;
    }
}

/// One page's fault tally within a single query: recorded by the search
/// read path whenever a page needed retries, failed checksum verification,
/// or stayed unreadable after the retry budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageFaultRecord {
    /// Page id within the index file.
    pub page: u32,
    /// Successful-after-retry attempts charged to this page.
    pub retries: u32,
    /// CRC32C tail verification failures observed on this page.
    pub crc_failures: u32,
    /// True when the page stayed unreadable and was skipped (degraded).
    pub failed: bool,
}

/// Per-query statistics, filled in by the searcher.
#[derive(Debug, Clone, Default)]
pub struct QueryStats {
    /// Disk page reads issued (cache hits excluded).
    pub ios: u64,
    /// Bytes fetched from disk.
    pub bytes_read: u64,
    /// Bytes of fetched data actually consumed (vectors scanned + topology
    /// used) — numerator of read-amplification's inverse.
    pub bytes_used: u64,
    /// Pages served from the in-memory cache.
    pub cache_hits: u64,
    /// Graph hops (batched expansion rounds).
    pub hops: u64,
    /// Number of exact distance computations.
    pub exact_dists: u64,
    /// Number of ADC (compressed) distance computations.
    pub approx_dists: u64,
    /// Speculatively-read pages the next hop actually consumed (the §5
    /// two-deep pipeline's hit counter). Consumed pages are also counted
    /// in `ios`/`bytes_read` — exactly once, like a non-speculative read.
    pub spec_hits: u64,
    /// Speculatively-read pages discarded because the candidate frontier
    /// changed. Not counted in `ios`: the paper's I/O metric measures
    /// algorithmic reads, and keeping it speculation-invariant also keeps
    /// results comparable across backends. The wasted bandwidth is
    /// `spec_wasted * page_size`.
    pub spec_wasted: u64,
    /// Read attempts that failed (transient EIO or checksum mismatch) and
    /// were retried successfully. Retried-then-OK reads still count once
    /// in `ios`.
    pub retries: u64,
    /// Pages that stayed unreadable after all retries and were skipped.
    pub failed_ios: u64,
    /// Pages whose CRC32C tail failed verification (subset of the retry /
    /// failed accounting; 0 on legacy un-checksummed indexes).
    pub crc_failures: u64,
    /// True when at least one page was permanently skipped — results may
    /// be missing that page's candidates.
    pub degraded: bool,
    /// Pages this query wanted in a batched round that were physically read
    /// once for another query in the same batch (the cross-query I/O
    /// coalescing of `search_batch`). Shared pages still count in `ios` for
    /// *every* wanting query — `ios` keeps its sequential-parity meaning of
    /// "algorithmic reads" — so physical reads = Σ ios − Σ batch_shared_ios.
    pub batch_shared_ios: u64,
    /// 1 when this query's ADC LUT aliased a near-duplicate batchmate's
    /// table instead of being built (see `pq::LutArena`); 0 otherwise.
    /// Summed across queries by `merge`.
    pub lut_reused: u64,
    /// 1 when this query's ADC LUT came out of the server's cross-tick
    /// `pq::LutCache` (the query recurred bit-identically since a prior
    /// tick), skipping `build_luts_into` entirely; 0 otherwise. Summed
    /// across queries by `merge`.
    pub lut_cache_hits: u64,
    /// Per-page fault records for this query: one entry per page that
    /// needed retries, failed its CRC, or stayed unreadable. Empty on the
    /// happy path (no allocation). The server aggregates these per page id
    /// into its top-offenders table (`ServerStats`).
    pub page_faults: Vec<PageFaultRecord>,
    /// Wall time inside I/O waits.
    pub io_time: Duration,
    /// Wall time in distance computation / heap maintenance.
    pub compute_time: Duration,
    /// End-to-end query latency.
    pub total_time: Duration,
    /// Fine-grained per-phase breakdown (disjoint spans; `phases.sum() ≤
    /// total_time`). The coarse `io_time`/`compute_time` pair above is
    /// kept bit-compatible for existing consumers.
    pub phases: PhaseTimes,
}

impl QueryStats {
    pub fn merge(&mut self, other: &QueryStats) {
        self.ios += other.ios;
        self.bytes_read += other.bytes_read;
        self.bytes_used += other.bytes_used;
        self.cache_hits += other.cache_hits;
        self.hops += other.hops;
        self.exact_dists += other.exact_dists;
        self.approx_dists += other.approx_dists;
        self.spec_hits += other.spec_hits;
        self.spec_wasted += other.spec_wasted;
        self.retries += other.retries;
        self.failed_ios += other.failed_ios;
        self.crc_failures += other.crc_failures;
        self.degraded |= other.degraded;
        self.batch_shared_ios += other.batch_shared_ios;
        self.lut_reused += other.lut_reused;
        self.lut_cache_hits += other.lut_cache_hits;
        self.page_faults.extend_from_slice(&other.page_faults);
        self.io_time += other.io_time;
        self.compute_time += other.compute_time;
        self.total_time += other.total_time;
        self.phases.merge(&other.phases);
    }

    /// Read amplification: bytes fetched / bytes useful. 1.0 is ideal.
    pub fn read_amplification(&self) -> f64 {
        if self.bytes_used == 0 {
            return if self.bytes_read == 0 { 1.0 } else { f64::INFINITY };
        }
        self.bytes_read as f64 / self.bytes_used as f64
    }
}

/// Aggregate over a batch of queries.
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    pub queries: u64,
    /// Queries that returned an error (no results) instead of completing.
    pub errors: u64,
    pub wall: Duration,
    pub totals: QueryStats,
    pub latency: LatencyHistogram,
    pub recall: f64,
}

impl RunSummary {
    pub fn qps(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.queries as f64 / self.wall.as_secs_f64()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        if self.queries == 0 {
            return 0.0;
        }
        self.totals.total_time.as_secs_f64() * 1e3 / self.queries as f64
    }

    pub fn mean_ios(&self) -> f64 {
        if self.queries == 0 {
            return 0.0;
        }
        self.totals.ios as f64 / self.queries as f64
    }

    pub fn io_fraction(&self) -> f64 {
        let tot = self.totals.total_time.as_secs_f64();
        if tot == 0.0 {
            return 0.0;
        }
        self.totals.io_time.as_secs_f64() / tot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_amplification_edge_cases() {
        let mut s = QueryStats::default();
        assert_eq!(s.read_amplification(), 1.0);
        s.bytes_read = 4096;
        assert_eq!(s.read_amplification(), f64::INFINITY);
        s.bytes_used = 2048;
        assert_eq!(s.read_amplification(), 2.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = QueryStats { ios: 2, bytes_read: 100, ..Default::default() };
        let b = QueryStats { ios: 3, bytes_read: 50, hops: 1, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.ios, 5);
        assert_eq!(a.bytes_read, 150);
        assert_eq!(a.hops, 1);
    }

    #[test]
    fn merge_fault_accounting() {
        let mut a = QueryStats { retries: 1, ..Default::default() };
        let b = QueryStats {
            retries: 2,
            failed_ios: 1,
            crc_failures: 3,
            degraded: true,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.retries, 3);
        assert_eq!(a.failed_ios, 1);
        assert_eq!(a.crc_failures, 3);
        assert!(a.degraded);
        // degraded is sticky: merging a clean query doesn't clear it.
        a.merge(&QueryStats::default());
        assert!(a.degraded);
    }

    #[test]
    fn merge_batch_and_page_fault_accounting() {
        let mut a = QueryStats { batch_shared_ios: 1, lut_reused: 1, ..Default::default() };
        let mut b = QueryStats { batch_shared_ios: 4, lut_cache_hits: 1, ..Default::default() };
        b.page_faults.push(PageFaultRecord { page: 7, retries: 2, crc_failures: 1, failed: false });
        a.merge(&b);
        assert_eq!(a.batch_shared_ios, 5);
        assert_eq!(a.lut_reused, 1);
        assert_eq!(a.lut_cache_hits, 1);
        assert_eq!(
            a.page_faults,
            vec![PageFaultRecord { page: 7, retries: 2, crc_failures: 1, failed: false }]
        );
    }

    #[test]
    fn phase_times_sum_and_merge() {
        let mut a = QueryStats::default();
        a.phases.lut_build = Duration::from_micros(10);
        a.phases.io_wait = Duration::from_micros(30);
        let mut b = QueryStats::default();
        b.phases.gather_wait = Duration::from_micros(5);
        b.phases.rerank = Duration::from_micros(7);
        a.merge(&b);
        assert_eq!(a.phases.lut_build, Duration::from_micros(10));
        assert_eq!(a.phases.gather_wait, Duration::from_micros(5));
        assert_eq!(a.phases.sum(), Duration::from_micros(52));
        assert_eq!(PhaseTimes::NAMES.len(), a.phases.as_array().len());
    }

    #[test]
    fn summary_rates() {
        let mut r = RunSummary { queries: 100, wall: Duration::from_secs(2), ..Default::default() };
        r.totals.total_time = Duration::from_secs(1);
        r.totals.io_time = Duration::from_millis(900);
        r.totals.ios = 500;
        assert!((r.qps() - 50.0).abs() < 1e-9);
        assert!((r.mean_latency_ms() - 10.0).abs() < 1e-9);
        assert!((r.mean_ios() - 5.0).abs() < 1e-9);
        assert!((r.io_fraction() - 0.9).abs() < 1e-9);
    }

    #[test]
    fn zero_division_safe() {
        let r = RunSummary::default();
        assert_eq!(r.qps(), 0.0);
        assert_eq!(r.mean_latency_ms(), 0.0);
        assert_eq!(r.mean_ios(), 0.0);
        assert_eq!(r.io_fraction(), 0.0);
    }
}
