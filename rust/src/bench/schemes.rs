//! Scheme instantiation under a memory budget — the per-scheme adaptation
//! logic behind every comparison figure.
//!
//! Each scheme reacts to a shrinking budget the way the real system does:
//!
//! * **PageANN** — memplan picks the CV placement / routing tier / cache
//!   size; always runs (Table 4: 0.05% suffices).
//! * **DiskANN / PipeANN** — must hold all PQ codes: `N × M ≤ budget`.
//!   Under pressure they drop to a coarser M (fewer bytes/vector, worse
//!   estimates → longer searches), and OOM when even the coarsest M
//!   doesn't fit.
//! * **Starling** — same resident set as DiskANN.
//! * **SPANN** — head vectors + index must fit; fewer heads → longer
//!   postings, and below a floor (postings > 512 vectors) it cannot run —
//!   the paper's ≥30% observation.

use crate::baselines::{DiskAnnIndex, DiskAnnLike, PipeAnnLike, SpannLike, StarlingLike};
use crate::dataset::Workload;
use crate::engine::{AnnSystem, OpenOptions, PageAnnIndex};
use crate::io::SsdModel;
use crate::layout::{BuildConfig, IndexBuilder};
use crate::memplan;
use crate::vamana::VamanaParams;
use crate::Result;
use std::path::Path;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchemeKind {
    PageAnn,
    DiskAnn,
    PipeAnn,
    Starling,
    Spann,
}

pub const ALL_SCHEMES: [SchemeKind; 5] = [
    SchemeKind::DiskAnn,
    SchemeKind::Spann,
    SchemeKind::Starling,
    SchemeKind::PipeAnn,
    SchemeKind::PageAnn,
];

impl SchemeKind {
    pub fn name(self) -> &'static str {
        match self {
            SchemeKind::PageAnn => "PageANN",
            SchemeKind::DiskAnn => "DiskANN",
            SchemeKind::PipeAnn => "PipeANN",
            SchemeKind::Starling => "Starling",
            SchemeKind::Spann => "SPANN",
        }
    }
}

/// A live system or an OOM marker.
pub enum SchemeInstance {
    Live(Box<dyn AnnSystem>),
    /// Could not run under this budget (paper's "OOM" label).
    Oom { required_bytes: usize },
}

/// Coarsest-to-finest PQ subspace counts available for a dimension.
fn pq_m_ladder(dim: usize) -> Vec<usize> {
    let mut v: Vec<usize> = (4..=32).filter(|m| dim % m == 0).collect();
    v.sort();
    v
}

/// Best M that fits `budget` for N vectors; None = OOM.
fn fit_pq_m(dim: usize, n: usize, budget: usize) -> Option<usize> {
    pq_m_ladder(dim).into_iter().rev().find(|m| n * m <= budget)
}

/// PageANN's default M: the largest divisor ≤ 16 (paper-comparable code
/// size across the three dims: 16 / 10 / 16).
pub fn default_pq_m(dim: usize) -> usize {
    pq_m_ladder(dim).into_iter().filter(|&m| m <= 16).max().unwrap_or(4)
}

/// Vamana parameters shared by all graph schemes (paper §6.1: identical
/// construction parameters).
pub fn shared_vamana(seed: u64) -> VamanaParams {
    VamanaParams { r: 24, l_build: 48, alpha: 1.2, seed, nthreads: crate::util::num_threads() }
}

/// Build + open `kind` for `w` under `budget_bytes`, storing index files
/// under `dir`. `sim` applies the NVMe timing model to every scheme
/// identically.
pub fn instantiate_scheme(
    kind: SchemeKind,
    w: &Workload,
    budget_bytes: usize,
    page_size: usize,
    dir: &Path,
    sim: Option<SsdModel>,
) -> Result<SchemeInstance> {
    let n = w.base.len();
    let dim = w.base.dim();
    let seed = 0xBEEF;
    std::fs::create_dir_all(dir)?;

    match kind {
        SchemeKind::PageAnn => {
            let default_m = default_pq_m(dim);
            // Storage width of one code: this scheme builds PQ8 (k = 256),
            // so the stride equals m; a PQ4 scheme would halve it here.
            let plan = memplan::plan(budget_bytes, n, dim, crate::pq::storage_bytes(default_m, 256));
            let cfg = BuildConfig {
                page_size,
                pq_m: default_m,
                cv_placement: plan.cv_placement,
                routing_bits: plan.routing_bits,
                routing_sample_frac: plan.routing_sample_frac,
                vamana: shared_vamana(seed),
                ..Default::default()
            };
            IndexBuilder::new(&w.base, cfg).build(dir)?;
            let mut idx = PageAnnIndex::open(
                dir,
                OpenOptions { sim_ssd: sim, ..Default::default() },
            )?;
            if plan.cache_budget_bytes > 0 {
                // Warm up on a held-out slice of the queries (first 25%).
                let warm = warmup_slice(w);
                idx.warmup(&warm, plan.cache_budget_bytes)?;
            }
            Ok(SchemeInstance::Live(Box::new(idx)))
        }
        SchemeKind::DiskAnn | SchemeKind::PipeAnn => {
            let Some(m) = fit_pq_m(dim, n, budget_bytes) else {
                return Ok(SchemeInstance::Oom { required_bytes: n * pq_m_ladder(dim)[0] });
            };
            let idx = DiskAnnIndex::build(&w.base, &shared_vamana(seed), m, page_size, dir)?;
            if kind == SchemeKind::DiskAnn {
                let mut s = DiskAnnLike::open(idx, 5)?;
                if let Some(model) = sim {
                    s = s.with_sim_ssd(model);
                }
                Ok(SchemeInstance::Live(Box::new(s)))
            } else {
                // PipeANN's pipelined setup needs 2× the resident set
                // (paper: >20% ratio required).
                if n * m * 2 > budget_bytes {
                    return Ok(SchemeInstance::Oom { required_bytes: n * pq_m_ladder(dim)[0] * 2 });
                }
                let mut s = PipeAnnLike::open(idx, 5)?;
                if let Some(model) = sim {
                    s = s.with_sim_ssd(model);
                }
                Ok(SchemeInstance::Live(Box::new(s)))
            }
        }
        SchemeKind::Starling => {
            let Some(m) = fit_pq_m(dim, n, budget_bytes) else {
                return Ok(SchemeInstance::Oom { required_bytes: n * pq_m_ladder(dim)[0] });
            };
            let mut s = StarlingLike::build(&w.base, &shared_vamana(seed), m, page_size, dir, 5)?;
            if let Some(model) = sim {
                s = s.with_sim_ssd(model);
            }
            Ok(SchemeInstance::Live(Box::new(s)))
        }
        SchemeKind::Spann => {
            // SPANN's design point selects ~1/8 of the vectors as heads
            // (SPTAG head-selection ratio); each resident head costs its
            // full vector plus ~100 B of in-memory SPTAG graph node. That
            // is what produces the paper's ≥30%-memory floor (Fig. 1,
            // Table 4).
            let head_cost = w.base.dim() * w.base.dtype().size_bytes() + 100;
            let needed_heads = (n / 8).max(1);
            if budget_bytes < needed_heads * head_cost {
                return Ok(SchemeInstance::Oom { required_bytes: needed_heads * head_cost });
            }
            let target_posting = crate::util::div_ceil(n, needed_heads).max(8);
            let mut s = SpannLike::build(&w.base, target_posting, 1.5, page_size, dir, 0)?;
            if let Some(model) = sim {
                s = s.with_sim_ssd(model);
            }
            Ok(SchemeInstance::Live(Box::new(s)))
        }
    }
}

/// First quarter of the query set, used for warm-up only.
fn warmup_slice(w: &Workload) -> crate::dataset::VectorSet {
    let n = (w.queries.len() / 4).max(1);
    let mut s = crate::dataset::VectorSet::new(w.queries.dtype(), w.queries.dim(), n);
    for i in 0..n {
        s.raw_mut(i).copy_from_slice(w.queries.raw(i));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, SynthSpec};

    #[test]
    fn pq_ladder_and_fit() {
        assert_eq!(pq_m_ladder(128), vec![4, 8, 16, 32]);
        assert_eq!(pq_m_ladder(100), vec![4, 5, 10, 20, 25]);
        assert_eq!(fit_pq_m(128, 1000, 16_000), Some(16));
        assert_eq!(fit_pq_m(128, 1000, 4_000), Some(4));
        assert_eq!(fit_pq_m(128, 1000, 3_999), None);
    }

    #[test]
    fn oom_markers_fire_at_tiny_budgets() {
        let spec = SynthSpec::new(DatasetKind::SiftLike, 1200).with_dim(32).with_clusters(6);
        let w = Workload::synthesize(&spec, 8, 10, 3);
        let dir = std::env::temp_dir().join(format!("pageann-schemes-{}", std::process::id()));
        // 100 bytes: everything but PageANN must OOM.
        for kind in [SchemeKind::DiskAnn, SchemeKind::PipeAnn, SchemeKind::Starling, SchemeKind::Spann] {
            let d = dir.join(format!("{:?}", kind));
            let inst = instantiate_scheme(kind, &w, 100, 4096, &d, None).unwrap();
            assert!(matches!(inst, SchemeInstance::Oom { .. }), "{kind:?} should OOM");
        }
        let d = dir.join("pageann");
        let inst = instantiate_scheme(SchemeKind::PageAnn, &w, 100, 4096, &d, None).unwrap();
        assert!(matches!(inst, SchemeInstance::Live(_)), "PageANN must run at ~0 budget");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
