//! Unified machine-readable bench emission (`BENCH_*.json`).
//!
//! Every bench target routes its JSON artifact through [`BenchReport`] so
//! the files share one stable schema (`schema_version`, a host/ISA
//! fingerprint, named rows) instead of three ad-hoc `format!` layouts.
//! `ci/bench_gate` parses these files and compares rows marked
//! `gate: true` against checked-in baselines (`ci/baselines/`); the
//! fingerprint keeps it from comparing numbers across different machines.
//! See `OBSERVABILITY.md` ("Bench gate").
//!
//! Artifacts land in `bench_out/` (gitignored), never the repo root;
//! `PAGEANN_BENCH_OUT` overrides the directory so CI can pin it
//! regardless of the bench binary's working directory.

use std::path::{Path, PathBuf};

/// Bumped when the JSON layout changes incompatibly.
pub const BENCH_SCHEMA_VERSION: u32 = 1;

/// Default output directory, relative to the bench binary's cwd.
pub const DEFAULT_OUT_DIR: &str = "bench_out";

/// Environment override for the output directory.
pub const OUT_DIR_ENV: &str = "PAGEANN_BENCH_OUT";

/// One JSON scalar — the only value shapes bench rows need.
#[derive(Debug, Clone)]
pub enum Val {
    Num(f64),
    Int(i64),
    Str(String),
    Bool(bool),
}

impl Val {
    fn render(&self, out: &mut String) {
        match self {
            // Rust's f64 Display never uses exponent notation and
            // round-trips, so it is valid JSON as-is; non-finite values
            // have no JSON spelling and degrade to null.
            Val::Num(v) if v.is_finite() => out.push_str(&format!("{v}")),
            Val::Num(_) => out.push_str("null"),
            Val::Int(v) => out.push_str(&format!("{v}")),
            Val::Str(s) => esc(s, out),
            Val::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        }
    }
}

fn esc(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// One named measurement. `gate: true` marks the row for regression
/// comparison by `ci/bench_gate` (lower value = better; a fresh value more
/// than the gate threshold above baseline fails CI). Rows dominated by
/// sleeps or real-device timing should stay ungated.
#[derive(Debug, Clone)]
pub struct BenchRow {
    pub name: String,
    /// Unit tag (`"ns_per_code"`, `"us_per_query"`, `"ratio"`, …) — part
    /// of the row identity: the gate refuses to compare mismatched units.
    pub unit: String,
    pub value: f64,
    pub gate: bool,
    /// Free-form context (kernel name, I/O counts, …), not compared.
    pub extra: Vec<(String, Val)>,
}

impl BenchRow {
    pub fn new(name: &str, unit: &str, value: f64) -> Self {
        Self { name: name.to_string(), unit: unit.to_string(), value, gate: false, extra: Vec::new() }
    }

    /// Mark this row for the CI regression gate.
    pub fn gated(mut self) -> Self {
        self.gate = true;
        self
    }

    pub fn extra(mut self, key: &str, v: Val) -> Self {
        self.extra.push((key.to_string(), v));
        self
    }
}

/// One bench artifact: schema header + host fingerprint + metadata + rows.
#[derive(Debug, Clone)]
pub struct BenchReport {
    bench: String,
    meta: Vec<(String, Val)>,
    rows: Vec<BenchRow>,
}

impl BenchReport {
    pub fn new(bench: &str) -> Self {
        Self { bench: bench.to_string(), meta: Vec::new(), rows: Vec::new() }
    }

    pub fn meta(&mut self, key: &str, v: Val) -> &mut Self {
        self.meta.push((key.to_string(), v));
        self
    }

    pub fn push(&mut self, row: BenchRow) -> &mut Self {
        self.rows.push(row);
        self
    }

    /// Render the artifact. Key order is fixed so diffs stay readable.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(512 + self.rows.len() * 128);
        s.push_str("{\n");
        s.push_str(&format!("  \"schema_version\": {BENCH_SCHEMA_VERSION},\n"));
        s.push_str("  \"bench\": ");
        esc(&self.bench, &mut s);
        s.push_str(",\n  \"host\": {\"os\": ");
        esc(std::env::consts::OS, &mut s);
        s.push_str(", \"arch\": ");
        esc(std::env::consts::ARCH, &mut s);
        s.push_str(", \"isa\": ");
        esc(crate::distance::kernels().isa, &mut s);
        s.push_str(&format!(", \"threads\": {}}},\n", crate::util::num_threads()));
        s.push_str("  \"meta\": {");
        for (i, (k, v)) in self.meta.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            esc(k, &mut s);
            s.push_str(": ");
            v.render(&mut s);
        }
        s.push_str("},\n  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            s.push_str("    {\"name\": ");
            esc(&r.name, &mut s);
            s.push_str(", \"unit\": ");
            esc(&r.unit, &mut s);
            s.push_str(", \"value\": ");
            Val::Num(r.value).render(&mut s);
            s.push_str(&format!(", \"gate\": {}", r.gate));
            if !r.extra.is_empty() {
                s.push_str(", \"extra\": {");
                for (j, (k, v)) in r.extra.iter().enumerate() {
                    if j > 0 {
                        s.push_str(", ");
                    }
                    esc(k, &mut s);
                    s.push_str(": ");
                    v.render(&mut s);
                }
                s.push('}');
            }
            s.push('}');
            if i + 1 < self.rows.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write `BENCH_<stem>.json` into `dir`, creating it if needed.
    pub fn write_to(&self, dir: &Path, stem: &str) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{stem}.json"));
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }

    /// Write `BENCH_<stem>.json` into [`out_dir`].
    pub fn write(&self, stem: &str) -> std::io::Result<PathBuf> {
        self.write_to(&out_dir(), stem)
    }
}

/// Where bench artifacts go: `PAGEANN_BENCH_OUT` or `bench_out/`.
pub fn out_dir() -> PathBuf {
    std::env::var_os(OUT_DIR_ENV).map(PathBuf::from).unwrap_or_else(|| PathBuf::from(DEFAULT_OUT_DIR))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BenchReport {
        let mut rep = BenchReport::new("unit_test");
        rep.meta("m", Val::Int(16)).meta("label", Val::Str("a \"b\"\n".into()));
        rep.push(
            BenchRow::new("fast_path", "ns_per_code", 12.5)
                .gated()
                .extra("kernel", Val::Str("scalar".into()))
                .extra("ok", Val::Bool(true)),
        );
        rep.push(BenchRow::new("slow_path", "us", 3.0));
        rep
    }

    #[test]
    fn json_shape_is_stable() {
        let j = sample().to_json();
        assert!(j.starts_with("{\n  \"schema_version\": 1,\n  \"bench\": \"unit_test\""), "{j}");
        assert!(j.contains("\"host\": {\"os\": "), "{j}");
        assert!(j.contains("\"isa\": "), "{j}");
        assert!(j.contains(
            "{\"name\": \"fast_path\", \"unit\": \"ns_per_code\", \"value\": 12.5, \"gate\": true"
        ));
        assert!(j.contains("\"extra\": {\"kernel\": \"scalar\", \"ok\": true}"));
        assert!(j.contains("{\"name\": \"slow_path\", \"unit\": \"us\", \"value\": 3, \"gate\": false}"));
        // Escaping: the quote and newline in the meta label are escaped.
        assert!(j.contains("\"label\": \"a \\\"b\\\"\\n\""), "{j}");
        // Balanced braces (structural sanity without a parser).
        let open = j.matches('{').count();
        let close = j.matches('}').count();
        assert_eq!(open, close);
    }

    #[test]
    fn non_finite_values_become_null() {
        let mut rep = BenchReport::new("nan");
        rep.push(BenchRow::new("bad", "ns", f64::NAN));
        assert!(rep.to_json().contains("\"value\": null"));
    }

    #[test]
    fn write_to_creates_dir_and_file() {
        let dir = std::env::temp_dir().join(format!("pageann-emit-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = sample().write_to(&dir.join("nested"), "unit").unwrap();
        assert!(path.ends_with("BENCH_unit.json"));
        let back = std::fs::read_to_string(&path).unwrap();
        assert_eq!(back, sample().to_json());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
