//! Table formatting + TSV persistence for experiment outputs.

use std::path::Path;

/// A printable results table (paper row/column shape).
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Append to a TSV sink (one file per experiment id).
    pub fn to_tsv(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("# {}\n", self.title));
        out.push_str(&self.headers.join("\t"));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join("\t"));
            out.push('\n');
        }
        out
    }
}

/// Writes tables under `results/<id>.tsv`.
pub struct TsvSink {
    dir: std::path::PathBuf,
}

impl TsvSink {
    pub fn new(dir: &Path) -> crate::Result<Self> {
        std::fs::create_dir_all(dir)?;
        Ok(Self { dir: dir.to_path_buf() })
    }

    pub fn write(&self, id: &str, table: &Table) -> crate::Result<()> {
        std::fs::write(self.dir.join(format!("{id}.tsv")), table.to_tsv())?;
        Ok(())
    }
}

/// Numeric formatting shared by all experiments.
pub fn fmt_f(v: f64, digits: usize) -> String {
    if v.is_nan() {
        "-".to_string()
    } else if v.is_infinite() {
        "OOM".to_string()
    } else {
        format!("{v:.digits$}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_and_tsv_roundtrips() {
        let mut t = Table::new("Demo", &["scheme", "qps"]);
        t.row(vec!["PageANN".into(), "2749.36".into()]);
        t.row(vec!["DiskANN".into(), "1099.62".into()]);
        let txt = t.render();
        assert!(txt.contains("Demo"));
        assert!(txt.contains("PageANN"));
        let tsv = t.to_tsv();
        assert_eq!(tsv.lines().count(), 4);
        assert!(tsv.lines().nth(2).unwrap().starts_with("PageANN\t"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fmt_handles_oom() {
        assert_eq!(fmt_f(f64::INFINITY, 2), "OOM");
        assert_eq!(fmt_f(f64::NAN, 2), "-");
        assert_eq!(fmt_f(1.234, 2), "1.23");
    }

    #[test]
    fn sink_writes_file() {
        let dir = std::env::temp_dir().join(format!("pageann-tsv-{}", std::process::id()));
        let sink = TsvSink::new(&dir).unwrap();
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["1".into()]);
        sink.write("tab1", &t).unwrap();
        assert!(dir.join("tab1.tsv").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
