//! Paper experiments: one function per table/figure (DESIGN.md §5).
//!
//! Scale note: the paper runs 100M–1B vector corpora on a 2TB NVMe
//! workstation; here the same protocols run on synthetic stand-ins of
//! 60K–800K vectors (`Scale`) over the simulated-SSD timing model, so the
//! *shapes* — who wins, by what factor, where OOM cliffs fall — are the
//! reproduction target, not absolute numbers (DESIGN.md §3).

use super::schemes::{instantiate_scheme, SchemeInstance, SchemeKind, ALL_SCHEMES};
use super::table::{fmt_f, Table, TsvSink};
use crate::dataset::{DatasetKind, SynthSpec, Workload};
use crate::engine::{run_workload, tune_to_recall, OpenOptions, PageAnnIndex};
use crate::io::SsdModel;
use crate::layout::{BuildConfig, CvPlacement, IndexBuilder};
use crate::metrics::CpuMeter;
use crate::util::Stopwatch;
use crate::Result;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

/// Experiment scale: stand-in corpus sizes for the paper's 100M/1B.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Smoke: 20K vectors (CI-fast).
    Xs,
    /// Default: 60K ("100M-like"), 240K ("1B-like").
    S,
    /// 200K / 800K.
    M,
}

impl Scale {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "xs" => Scale::Xs,
            "s" => Scale::S,
            "m" => Scale::M,
            _ => anyhow::bail!("unknown scale {s} (xs|s|m)"),
        })
    }

    fn n_base(self) -> usize {
        match self {
            Scale::Xs => 20_000,
            Scale::S => 60_000,
            Scale::M => 200_000,
        }
    }

    fn n_billion(self) -> usize {
        self.n_base() * 4
    }

    fn n_queries(self) -> usize {
        match self {
            Scale::Xs => 64,
            Scale::S => 128,
            Scale::M => 256,
        }
    }
}

/// Shared state across experiments in one invocation: lazily-built
/// workloads and scheme instances, keyed by their *derived* configuration
/// so budget sweeps reuse builds that land on the same config.
pub struct ExperimentCtx {
    pub scale: Scale,
    pub workdir: PathBuf,
    pub sink: TsvSink,
    pub sim: Option<SsdModel>,
    pub threads: usize,
    workloads: HashMap<(DatasetKind, usize), Rc<Workload>>,
    instances: HashMap<String, Rc<SchemeInstance>>,
}

const PAGE_SIZE: usize = 4096;
const TARGET_RECALL: f64 = 0.9;

impl ExperimentCtx {
    pub fn new(scale: Scale, workdir: &std::path::Path, results: &std::path::Path) -> Result<Self> {
        std::fs::create_dir_all(workdir)?;
        Ok(Self {
            scale,
            workdir: workdir.to_path_buf(),
            sink: TsvSink::new(results)?,
            sim: Some(SsdModel::default()),
            threads: 16.min(crate::util::num_threads()),
            workloads: HashMap::new(),
            instances: HashMap::new(),
        })
    }

    pub fn workload(&mut self, kind: DatasetKind, n: usize) -> Rc<Workload> {
        if let Some(w) = self.workloads.get(&(kind, n)) {
            return w.clone();
        }
        eprintln!("[ctx] synthesizing {} n={n} (+ ground truth)...", kind.name());
        let spec = SynthSpec::new(kind, n);
        let w = Rc::new(Workload::synthesize(&spec, self.scale.n_queries(), 10, 0xDA7A));
        self.workloads.insert((kind, n), w.clone());
        w
    }

    /// Instantiate (or reuse) a scheme at a budget. The cache key encodes
    /// the derived config, so e.g. DiskANN at 20% and 30% (same PQ-M) share
    /// one build.
    pub fn instance(
        &mut self,
        kind: SchemeKind,
        dkind: DatasetKind,
        n: usize,
        budget: usize,
    ) -> Result<Rc<SchemeInstance>> {
        let w = self.workload(dkind, n);
        let fp = config_fingerprint(kind, &w, budget);
        let key = format!("{}-{}-{n}-{fp}", kind.name(), dkind.name());
        if let Some(i) = self.instances.get(&key) {
            return Ok(i.clone());
        }
        eprintln!("[ctx] building {key} ...");
        let dir = self.workdir.join(&key);
        let inst = instantiate_scheme(kind, &w, budget, PAGE_SIZE, &dir, self.sim.clone())?;
        let rc = Rc::new(inst);
        self.instances.insert(key, rc.clone());
        Ok(rc)
    }

    fn ratio_budget(&mut self, dkind: DatasetKind, n: usize, ratio: f64) -> usize {
        let w = self.workload(dkind, n);
        (w.base.payload_bytes() as f64 * ratio) as usize
    }
}

/// Derived-config fingerprint for instance caching (mirrors
/// `instantiate_scheme`'s decisions).
fn config_fingerprint(kind: SchemeKind, w: &Workload, budget: usize) -> String {
    let n = w.base.len();
    let dim = w.base.dim();
    let ladder: Vec<usize> = (4..=32).filter(|m| dim % m == 0).collect();
    let fit = ladder.iter().rev().find(|&&m| n * m <= budget);
    match kind {
        SchemeKind::PageAnn => {
            let m = super::schemes::default_pq_m(dim);
            // Plan against the storage width (these schemes build PQ8, so
            // k = 256; a PQ4 scheme would pass its halved stride here).
            let plan = crate::memplan::plan(budget, n, dim, crate::pq::storage_bytes(m, 256));
            // Bucket the cache budget to pages/64 so near-identical budgets
            // share a build.
            let cache_bucket = plan.cache_budget_bytes / (PAGE_SIZE * 64);
            format!("pa-{:?}-c{}", placement_tag(plan.cv_placement), cache_bucket)
        }
        SchemeKind::DiskAnn => format!("da-m{:?}", fit),
        SchemeKind::PipeAnn => {
            let fit2 = ladder.iter().rev().find(|&&m| n * m * 2 <= budget);
            format!("pi-m{:?}", fit2)
        }
        SchemeKind::Starling => format!("st-m{:?}", fit),
        SchemeKind::Spann => {
            let head_cost = dim * w.base.dtype().size_bytes() + 100;
            let needed_heads = (n / 8).max(1);
            if budget < needed_heads * head_cost {
                "sp-oom".to_string()
            } else {
                format!("sp-h{needed_heads}")
            }
        }
    }
}

fn placement_tag(p: CvPlacement) -> String {
    match p {
        CvPlacement::OnPage => "onpage".into(),
        CvPlacement::Hybrid { mem_frac } => format!("hy{:.1}", mem_frac),
        CvPlacement::InMemory => "inmem".into(),
    }
}

fn datasets() -> [DatasetKind; 3] {
    [DatasetKind::SiftLike, DatasetKind::SpacevLike, DatasetKind::DeepLike]
}

/// All experiment ids in run order.
pub fn list_experiments() -> Vec<&'static str> {
    vec![
        "fig1", "fig2", "tab1", "fig7", "fig8", "tab3", "fig9", "fig10", "tab4", "fig11",
        "fig12", "tab5", "ablA", "ablB", "ablC", "ablD",
    ]
}

/// Dispatch one experiment; returns rendered tables.
pub fn run_experiment(ctx: &mut ExperimentCtx, id: &str) -> Result<Vec<Table>> {
    let tables = match id {
        "fig1" => fig1(ctx)?,
        "fig2" => fig2(ctx)?,
        "tab1" => tab1(ctx)?,
        "fig7" | "fig8" => fig7_fig8(ctx)?,
        "tab3" => tab3(ctx)?,
        "fig9" => fig9(ctx)?,
        "fig10" => fig10(ctx)?,
        "tab4" => tab4(ctx)?,
        "fig11" => fig11(ctx)?,
        "fig12" => fig12(ctx)?,
        "tab5" => tab5(ctx)?,
        "ablA" => abl_a(ctx)?,
        "ablB" => abl_b(ctx)?,
        "ablC" => abl_c(ctx)?,
        "ablD" => abl_d(ctx)?,
        _ => anyhow::bail!("unknown experiment id {id} (see list)"),
    };
    for t in &tables {
        let tsv_id = format!("{id}-{}", slug(&t.title));
        ctx.sink.write(&tsv_id, t)?;
    }
    Ok(tables)
}

fn slug(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '-' })
        .collect::<String>()
        .split('-')
        .filter(|p| !p.is_empty())
        .collect::<Vec<_>>()
        .join("-")
}

/// Measure one scheme at the target-recall operating point.
fn op_point(
    _ctx: &ExperimentCtx,
    inst: &SchemeInstance,
    w: &Workload,
    threads: usize,
) -> Option<(usize, crate::engine::WorkloadReport)> {
    match inst {
        SchemeInstance::Oom { .. } => None,
        SchemeInstance::Live(sys) => {
            Some(tune_to_recall(sys.as_ref(), &w.queries, &w.gt, 10, TARGET_RECALL, threads))
        }
    }
}

// --------------------------------------------------------------- fig1

/// Fig. 1: latency vs memory ratio (10–50%), all schemes, SIFT-like.
fn fig1(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_base();
    let dkind = DatasetKind::SiftLike;
    let mut t = Table::new(
        "Fig.1 — mean latency (ms) vs memory ratio, SIFT-like",
        &["scheme", "10%", "20%", "30%", "40%", "50%"],
    );
    for kind in ALL_SCHEMES {
        let mut cells = vec![kind.name().to_string()];
        for ratio in [0.1, 0.2, 0.3, 0.4, 0.5] {
            let budget = ctx.ratio_budget(dkind, n, ratio);
            let inst = ctx.instance(kind, dkind, n, budget)?;
            let w = ctx.workload(dkind, n);
            let cell = match op_point(ctx, &inst, &w, ctx.threads) {
                None => "OOM".to_string(),
                Some((_, rep)) if rep.summary.recall < TARGET_RECALL - 0.02 => "recall<0.9".into(),
                Some((_, rep)) => fmt_f(rep.summary.mean_latency_ms(), 2),
            };
            cells.push(cell);
        }
        t.row(cells);
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- fig2

/// Fig. 2: query latency breakdown (I/O vs compute), 30% ratio.
fn fig2(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_base();
    let dkind = DatasetKind::SiftLike;
    let budget = ctx.ratio_budget(dkind, n, 0.3);
    let mut t = Table::new(
        "Fig.2 — latency breakdown at 30% ratio, SIFT-like",
        &["scheme", "io_pct", "compute_pct", "other_pct"],
    );
    for kind in ALL_SCHEMES {
        let inst = ctx.instance(kind, dkind, n, budget)?;
        let w = ctx.workload(dkind, n);
        match op_point(ctx, &inst, &w, ctx.threads) {
            None => t.row(vec![kind.name().into(), "OOM".into(), "-".into(), "-".into()]),
            Some((_, rep)) => {
                let io = rep.summary.io_fraction() * 100.0;
                let total = rep.summary.totals.total_time.as_secs_f64();
                let comp = if total > 0.0 {
                    rep.summary.totals.compute_time.as_secs_f64() / total * 100.0
                } else {
                    0.0
                };
                t.row(vec![
                    kind.name().into(),
                    fmt_f(io, 1),
                    fmt_f(comp, 1),
                    fmt_f((100.0 - io - comp).max(0.0), 1),
                ]);
            }
        }
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- tab1

/// Table 1: read amplification per scheme per dataset.
fn tab1(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_base();
    let mut t = Table::new(
        "Table 1 — read amplification at recall 0.9 (30% ratio)",
        &["scheme", "SIFT-like", "SPACEV-like", "DEEP-like"],
    );
    for kind in ALL_SCHEMES {
        let mut cells = vec![kind.name().to_string()];
        for dkind in datasets() {
            let budget = ctx.ratio_budget(dkind, n, 0.3);
            let inst = ctx.instance(kind, dkind, n, budget)?;
            let w = ctx.workload(dkind, n);
            let cell = match op_point(ctx, &inst, &w, ctx.threads) {
                None => "OOM".into(),
                Some((_, rep)) => fmt_f(rep.summary.totals.read_amplification(), 2),
            };
            cells.push(cell);
        }
        t.row(cells);
    }
    Ok(vec![t])
}

// --------------------------------------------------------- fig7 + fig8

/// Figs. 7–8: latency and throughput vs recall@10 (L sweep), 30% ratio.
fn fig7_fig8(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_base();
    let mut lat = Table::new(
        "Fig.7 — latency (ms) vs recall@10 (L sweep, 30% ratio)",
        &["dataset", "scheme", "L", "recall", "latency_ms"],
    );
    let mut qps = Table::new(
        "Fig.8 — throughput (QPS) vs recall@10 (L sweep, 30% ratio)",
        &["dataset", "scheme", "L", "recall", "qps"],
    );
    for dkind in datasets() {
        let budget = ctx.ratio_budget(dkind, n, 0.3);
        for kind in ALL_SCHEMES {
            let inst = ctx.instance(kind, dkind, n, budget)?;
            let w = ctx.workload(dkind, n);
            let SchemeInstance::Live(sys) = inst.as_ref() else {
                continue;
            };
            for l in [10usize, 20, 40, 80, 160, 320] {
                let rep = run_workload(sys.as_ref(), &w.queries, Some(&w.gt), 10, l, ctx.threads);
                lat.row(vec![
                    dkind.name().into(),
                    kind.name().into(),
                    l.to_string(),
                    fmt_f(rep.summary.recall, 4),
                    fmt_f(rep.summary.mean_latency_ms(), 2),
                ]);
                qps.row(vec![
                    dkind.name().into(),
                    kind.name().into(),
                    l.to_string(),
                    fmt_f(rep.summary.recall, 4),
                    fmt_f(rep.summary.qps(), 1),
                ]);
                if rep.summary.recall > 0.99 {
                    break;
                }
            }
        }
    }
    Ok(vec![lat, qps])
}

// --------------------------------------------------------------- tab3

/// Table 3: QPS / latency / mean I/Os at recall 0.9, 30% ratio.
fn tab3(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_base();
    let mut t = Table::new(
        "Table 3 — QPS / latency(ms) / mean IOs at recall 0.9 (30% ratio)",
        &["scheme", "dataset", "qps", "latency_ms", "mean_ios", "recall"],
    );
    for kind in ALL_SCHEMES {
        for dkind in datasets() {
            let budget = ctx.ratio_budget(dkind, n, 0.3);
            let inst = ctx.instance(kind, dkind, n, budget)?;
            let w = ctx.workload(dkind, n);
            match op_point(ctx, &inst, &w, ctx.threads) {
                None => t.row(vec![
                    kind.name().into(),
                    dkind.name().into(),
                    "OOM".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]),
                Some((_, rep)) => t.row(vec![
                    kind.name().into(),
                    dkind.name().into(),
                    fmt_f(rep.summary.qps(), 1),
                    fmt_f(rep.summary.mean_latency_ms(), 2),
                    fmt_f(rep.summary.mean_ios(), 1),
                    fmt_f(rep.summary.recall, 4),
                ]),
            }
        }
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- fig9

/// Fig. 9: "billion-scale" (largest feasible stand-in), 20% ratio,
/// PageANN vs DiskANN vs PipeANN.
fn fig9(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_billion();
    let mut t = Table::new(
        "Fig.9 — billion-scale stand-in: latency/QPS vs recall (20% ratio)",
        &["dataset", "scheme", "L", "recall", "latency_ms", "qps"],
    );
    for dkind in [DatasetKind::SiftLike, DatasetKind::SpacevLike] {
        let budget = ctx.ratio_budget(dkind, n, 0.2);
        for kind in [SchemeKind::DiskAnn, SchemeKind::PipeAnn, SchemeKind::PageAnn] {
            let inst = ctx.instance(kind, dkind, n, budget)?;
            let w = ctx.workload(dkind, n);
            let SchemeInstance::Live(sys) = inst.as_ref() else {
                t.row(vec![
                    dkind.name().into(),
                    kind.name().into(),
                    "-".into(),
                    "OOM".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            };
            for l in [20usize, 60, 160, 400] {
                let rep = run_workload(sys.as_ref(), &w.queries, Some(&w.gt), 10, l, ctx.threads);
                t.row(vec![
                    dkind.name().into(),
                    kind.name().into(),
                    l.to_string(),
                    fmt_f(rep.summary.recall, 4),
                    fmt_f(rep.summary.mean_latency_ms(), 2),
                    fmt_f(rep.summary.qps(), 1),
                ]);
                if rep.summary.recall > 0.99 {
                    break;
                }
            }
        }
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- fig10

/// Fig. 10: latency vs memory ratio 0%→30% incl. OOM markers, SIFT-like.
fn fig10(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_base();
    let dkind = DatasetKind::SiftLike;
    let mut t = Table::new(
        "Fig.10 — latency (ms) vs memory ratio 0%→30%, SIFT-like",
        &["scheme", "0.05%", "5%", "10%", "20%", "30%"],
    );
    for kind in ALL_SCHEMES {
        let mut cells = vec![kind.name().to_string()];
        for ratio in [0.0005, 0.05, 0.1, 0.2, 0.3] {
            let budget = ctx.ratio_budget(dkind, n, ratio);
            let inst = ctx.instance(kind, dkind, n, budget)?;
            let w = ctx.workload(dkind, n);
            let cell = match op_point(ctx, &inst, &w, ctx.threads) {
                None => "OOM".into(),
                Some((_, rep)) if rep.summary.recall < TARGET_RECALL - 0.02 => "recall<0.9".into(),
                Some((_, rep)) => fmt_f(rep.summary.mean_latency_ms(), 2),
            };
            cells.push(cell);
        }
        t.row(cells);
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- tab4

/// Table 4: minimum memory to reach recall@10 = 0.9, SIFT-like.
fn tab4(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_base();
    let dkind = DatasetKind::SiftLike;
    let dataset_bytes = ctx.workload(dkind, n).base.payload_bytes();
    let mut t = Table::new(
        "Table 4 — minimum memory to reach recall@10=0.9, SIFT-like",
        &["scheme", "min_bytes", "pct_of_dataset"],
    );
    for kind in ALL_SCHEMES {
        let mut found: Option<usize> = None;
        for ratio in [0.0002, 0.0005, 0.001, 0.005, 0.01, 0.05, 0.1, 0.2, 0.3, 0.5] {
            let budget = (dataset_bytes as f64 * ratio) as usize;
            let inst = ctx.instance(kind, dkind, n, budget)?;
            let w = ctx.workload(dkind, n);
            if let Some((_, rep)) = op_point(ctx, &inst, &w, ctx.threads) {
                if rep.summary.recall >= TARGET_RECALL {
                    found = Some(budget);
                    break;
                }
            }
        }
        match found {
            Some(b) => t.row(vec![
                kind.name().into(),
                b.to_string(),
                fmt_f(b as f64 / dataset_bytes as f64 * 100.0, 3),
            ]),
            None => t.row(vec![kind.name().into(), "not reached".into(), "-".into()]),
        }
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- fig11

/// Fig. 11: PageANN latency/QPS as memory ratio × recall target vary.
fn fig11(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_base();
    let dkind = DatasetKind::SiftLike;
    let mut t = Table::new(
        "Fig.11 — PageANN latency/QPS vs memory ratio × recall, SIFT-like",
        &["ratio", "recall_target", "recall", "latency_ms", "qps"],
    );
    for ratio in [0.0005, 0.05, 0.1, 0.2, 0.3] {
        let budget = ctx.ratio_budget(dkind, n, ratio);
        let inst = ctx.instance(SchemeKind::PageAnn, dkind, n, budget)?;
        let w = ctx.workload(dkind, n);
        let SchemeInstance::Live(sys) = inst.as_ref() else { continue };
        for target in [0.85, 0.9, 0.95] {
            let (_, rep) = tune_to_recall(sys.as_ref(), &w.queries, &w.gt, 10, target, ctx.threads);
            t.row(vec![
                format!("{:.2}%", ratio * 100.0),
                fmt_f(target, 2),
                fmt_f(rep.summary.recall, 4),
                fmt_f(rep.summary.mean_latency_ms(), 2),
                fmt_f(rep.summary.qps(), 1),
            ]);
        }
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- fig12

/// Fig. 12: thread scaling 1→16 at recall 0.9, SIFT-like, 30% ratio.
fn fig12(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_base();
    let dkind = DatasetKind::SiftLike;
    let budget = ctx.ratio_budget(dkind, n, 0.3);
    let mut t = Table::new(
        "Fig.12 — QPS and latency vs query threads (recall 0.9, 30% ratio)",
        &["scheme", "threads", "qps", "latency_ms"],
    );
    for kind in ALL_SCHEMES {
        let inst = ctx.instance(kind, dkind, n, budget)?;
        let w = ctx.workload(dkind, n);
        let SchemeInstance::Live(sys) = inst.as_ref() else { continue };
        // Fix L at the single-thread op point, then sweep threads.
        let (l, _) = tune_to_recall(sys.as_ref(), &w.queries, &w.gt, 10, TARGET_RECALL, 1);
        for threads in [1usize, 2, 4, 8, 16] {
            let rep = run_workload(sys.as_ref(), &w.queries, Some(&w.gt), 10, l, threads);
            t.row(vec![
                kind.name().into(),
                threads.to_string(),
                fmt_f(rep.summary.qps(), 1),
                fmt_f(rep.summary.mean_latency_ms(), 2),
            ]);
        }
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- tab5

/// Table 5: build time (s) + query CPU utilization (%).
fn tab5(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_base();
    let mut t = Table::new(
        "Table 5 — build time (s) and query CPU utilization (%)",
        &["scheme", "dataset", "build_s", "cpu_pct"],
    );
    // Fresh timed builds (the ctx cache would hide build cost).
    for kind in [SchemeKind::DiskAnn, SchemeKind::Starling, SchemeKind::PipeAnn, SchemeKind::PageAnn] {
        for dkind in datasets() {
            let w = ctx.workload(dkind, n);
            let budget = (w.base.payload_bytes() as f64 * 0.3) as usize;
            let dir = ctx.workdir.join(format!("tab5-{}-{}", kind.name(), dkind.name()));
            let mut sw = Stopwatch::new();
            sw.start();
            let inst = instantiate_scheme(kind, &w, budget, PAGE_SIZE, &dir, ctx.sim.clone())?;
            sw.stop();
            let SchemeInstance::Live(sys) = inst else {
                t.row(vec![kind.name().into(), dkind.name().into(), "OOM".into(), "-".into()]);
                continue;
            };
            let meter = CpuMeter::start();
            let rep = run_workload(sys.as_ref(), &w.queries, Some(&w.gt), 10, 80, ctx.threads);
            let cpu = meter.utilization_pct();
            let _ = rep;
            t.row(vec![
                kind.name().into(),
                dkind.name().into(),
                fmt_f(sw.total().as_secs_f64(), 2),
                fmt_f(cpu, 0),
            ]);
        }
    }
    Ok(vec![t])
}

// ------------------------------------------------------------- ablations

/// Ablation A: neighbor-entry budget (⇒ page capacity) sweep.
fn abl_a(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_base();
    let dkind = DatasetKind::SiftLike;
    let w = ctx.workload(dkind, n);
    let mut t = Table::new(
        "Ablation A — max_nbrs (page capacity) sweep, PageANN, SIFT-like",
        &["max_nbrs", "capacity", "n_pages", "recall", "latency_ms", "mean_ios"],
    );
    for max_nbrs in [16usize, 32, 48, 64] {
        let dir = ctx.workdir.join(format!("ablA-{max_nbrs}"));
        let cfg = BuildConfig {
            page_size: PAGE_SIZE,
            max_nbrs,
            pq_m: 16,
            vamana: super::schemes::shared_vamana(0xAB1A),
            ..Default::default()
        };
        let report = IndexBuilder::new(&w.base, cfg).build(&dir)?;
        let idx = PageAnnIndex::open(
            &dir,
            OpenOptions { sim_ssd: ctx.sim.clone(), ..Default::default() },
        )?;
        let (_, rep) = tune_to_recall(&idx, &w.queries, &w.gt, 10, TARGET_RECALL, ctx.threads);
        t.row(vec![
            max_nbrs.to_string(),
            report.capacity.to_string(),
            report.n_pages.to_string(),
            fmt_f(rep.summary.recall, 4),
            fmt_f(rep.summary.mean_latency_ms(), 2),
            fmt_f(rep.summary.mean_ios(), 1),
        ]);
    }
    Ok(vec![t])
}

/// Ablation B: grouping hop bound h ∈ {1, 2, 3}.
fn abl_b(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_base();
    let dkind = DatasetKind::SiftLike;
    let w = ctx.workload(dkind, n);
    let mut t = Table::new(
        "Ablation B — grouping hop bound h, PageANN, SIFT-like",
        &["h", "recall", "latency_ms", "mean_ios", "read_amp"],
    );
    for hops in [1usize, 2, 3] {
        let dir = ctx.workdir.join(format!("ablB-{hops}"));
        let cfg = BuildConfig {
            page_size: PAGE_SIZE,
            hops,
            pq_m: 16,
            vamana: super::schemes::shared_vamana(0xAB1B),
            ..Default::default()
        };
        IndexBuilder::new(&w.base, cfg).build(&dir)?;
        let idx = PageAnnIndex::open(
            &dir,
            OpenOptions { sim_ssd: ctx.sim.clone(), ..Default::default() },
        )?;
        let (_, rep) = tune_to_recall(&idx, &w.queries, &w.gt, 10, TARGET_RECALL, ctx.threads);
        t.row(vec![
            hops.to_string(),
            fmt_f(rep.summary.recall, 4),
            fmt_f(rep.summary.mean_latency_ms(), 2),
            fmt_f(rep.summary.mean_ios(), 1),
            fmt_f(rep.summary.totals.read_amplification(), 2),
        ]);
    }
    Ok(vec![t])
}

/// Ablation C: distance backend — native scalar vs the AOT-compiled
/// Pallas/XLA artifact through PJRT.
///
/// On the CPU PJRT client the per-dispatch boundary dominates small page
/// scans, so native wins on latency; the XLA path is the structural
/// validation of the kernel artifacts (and the deploy path on real
/// accelerators). Both must return identical results.
fn abl_c(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_base();
    let dkind = DatasetKind::SiftLike; // dim 128 — matches l2_batch_d128
    let w = ctx.workload(dkind, n);
    let mut t = Table::new(
        "Ablation C — distance backend (native vs XLA/PJRT), PageANN, SIFT-like",
        &["backend", "recall", "latency_ms", "qps"],
    );
    let dir = ctx.workdir.join("ablC");
    let cfg = BuildConfig {
        page_size: PAGE_SIZE,
        pq_m: 16,
        vamana: super::schemes::shared_vamana(0xAB1C),
        ..Default::default()
    };
    IndexBuilder::new(&w.base, cfg).build(&dir)?;

    // Native backend (SIMD kernels selected by runtime dispatch).
    let native = PageAnnIndex::open(
        &dir,
        OpenOptions { sim_ssd: ctx.sim.clone(), ..Default::default() },
    )?;
    let native_isa = crate::distance::kernels().isa;
    let (l, rep_n) = tune_to_recall(&native, &w.queries, &w.gt, 10, TARGET_RECALL, ctx.threads);
    t.row(vec![
        format!("native({native_isa})"),
        fmt_f(rep_n.summary.recall, 4),
        fmt_f(rep_n.summary.mean_latency_ms(), 2),
        fmt_f(rep_n.summary.qps(), 1),
    ]);

    // Scalar-oracle *scanner*: same index, same L. Only the exact page
    // scans are pinned to scalar — LUT build and batched ADC stay on the
    // dispatched kernels, so the traversal (and hence the scanned set) is
    // identical by construction. The strict recall-identity assert below
    // is sound because this workload is SIFT-like (u8): queries decode to
    // integer-valued f32 and every subtraction/square/sum stays an exact
    // integer < 2^24, so scalar and FMA kernels agree bit-for-bit. (On an
    // f32 dataset, rounding could flip a near-tie at the k boundary — use
    // a one-flip tolerance there.) For a fully scalar pipeline, run the
    // binary with PAGEANN_SIMD=scalar instead.
    let scalar_idx = PageAnnIndex::open(
        &dir,
        OpenOptions {
            sim_ssd: ctx.sim.clone(),
            scanner: Some(Box::new(crate::distance::ScalarBatch)),
            ..Default::default()
        },
    )?;
    let rep_s = run_workload(&scalar_idx, &w.queries, Some(&w.gt), 10, l, ctx.threads);
    anyhow::ensure!(
        (rep_s.summary.recall - rep_n.summary.recall).abs() < 1e-9,
        "scalar/simd scanner recall divergence: {} vs {}",
        rep_s.summary.recall,
        rep_n.summary.recall
    );
    t.row(vec![
        "scalar-scan".into(),
        fmt_f(rep_s.summary.recall, 4),
        fmt_f(rep_s.summary.mean_latency_ms(), 2),
        fmt_f(rep_s.summary.qps(), 1),
    ]);

    // XLA backend (skipped gracefully when artifacts or PJRT are absent).
    let arts_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match crate::runtime::ArtifactSet::load(&arts_dir)
        .and_then(|arts| Ok((arts, crate::runtime::XlaRuntime::cpu()?)))
    {
        Err(e) => {
            eprintln!("[ablC] skipping xla backend: {e}");
            t.row(vec!["xla".into(), "-".into(), "-".into(), "-".into()]);
        }
        Ok((arts, rt)) => {
            // The runtime must outlive the executables; one per process is
            // fine for an experiment binary.
            // lint:allow(forbidden-forget): intentional 'static leak — the PJRT
            // runtime lives for the rest of the experiment process.
            let rt: &'static crate::runtime::XlaRuntime = Box::leak(Box::new(rt));
            let scanner = crate::distance::XlaBatch::load(rt, &arts, 128, ctx.threads)?;
            let xla_idx = PageAnnIndex::open(
                &dir,
                OpenOptions {
                    sim_ssd: ctx.sim.clone(),
                    scanner: Some(Box::new(scanner)),
                    ..Default::default()
                },
            )?;
            let rep_x = run_workload(&xla_idx, &w.queries, Some(&w.gt), 10, l, ctx.threads);
            // Same results as native (exact distances either way).
            anyhow::ensure!(
                (rep_x.summary.recall - rep_n.summary.recall).abs() < 0.02,
                "backend recall divergence: {} vs {}",
                rep_x.summary.recall,
                rep_n.summary.recall
            );
            t.row(vec![
                "xla".into(),
                fmt_f(rep_x.summary.recall, 4),
                fmt_f(rep_x.summary.mean_latency_ms(), 2),
                fmt_f(rep_x.summary.qps(), 1),
            ]);
        }
    }
    Ok(vec![t])
}

/// Ablation D: entry strategy — LSH routing vs medoid-only.
fn abl_d(ctx: &mut ExperimentCtx) -> Result<Vec<Table>> {
    let n = ctx.scale.n_base();
    let dkind = DatasetKind::SiftLike;
    let w = ctx.workload(dkind, n);
    let mut t = Table::new(
        "Ablation D — entry strategy (LSH routing vs medoid), PageANN",
        &["entry", "recall", "latency_ms", "mean_ios", "hops"],
    );
    for (name, bits) in [("lsh-routing", 32usize), ("medoid-only", 0)] {
        let dir = ctx.workdir.join(format!("ablD-{name}"));
        let cfg = BuildConfig {
            page_size: PAGE_SIZE,
            pq_m: 16,
            routing_bits: bits,
            vamana: super::schemes::shared_vamana(0xAB1D),
            ..Default::default()
        };
        IndexBuilder::new(&w.base, cfg).build(&dir)?;
        let idx = PageAnnIndex::open(
            &dir,
            OpenOptions { sim_ssd: ctx.sim.clone(), ..Default::default() },
        )?;
        let (_, rep) = tune_to_recall(&idx, &w.queries, &w.gt, 10, TARGET_RECALL, ctx.threads);
        let hops = rep.summary.totals.hops as f64 / rep.summary.queries.max(1) as f64;
        t.row(vec![
            name.into(),
            fmt_f(rep.summary.recall, 4),
            fmt_f(rep.summary.mean_latency_ms(), 2),
            fmt_f(rep.summary.mean_ios(), 1),
            fmt_f(hops, 1),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprints_bucket_budgets() {
        let spec = SynthSpec::new(DatasetKind::SiftLike, 2000).with_dim(32);
        let w = Workload::synthesize(&spec, 4, 5, 1);
        // Two large budgets with the same PQ fit share a DiskANN build.
        let a = config_fingerprint(SchemeKind::DiskAnn, &w, 2000 * 32);
        let b = config_fingerprint(SchemeKind::DiskAnn, &w, 2000 * 33);
        assert_eq!(a, b);
        // A starved budget differs.
        let c = config_fingerprint(SchemeKind::DiskAnn, &w, 2000 * 4);
        assert_ne!(a, c);
    }

    #[test]
    fn experiment_list_covers_all_paper_artifacts() {
        let ids = list_experiments();
        for required in ["fig1", "fig2", "tab1", "fig7", "fig8", "tab3", "fig9", "fig10", "tab4", "fig11", "fig12", "tab5"] {
            assert!(ids.contains(&required), "{required} missing");
        }
    }

    #[test]
    fn slug_sanitizes() {
        assert_eq!(slug("Fig.1 — latency (ms)"), "fig-1-latency-ms");
    }
}
