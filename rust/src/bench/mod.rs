//! Experiment harness: everything needed to regenerate the paper's tables
//! and figures (DESIGN.md §5 experiment index) without criterion (offline
//! build).

pub mod emit;
mod experiments;
mod schemes;
mod table;

pub use experiments::{list_experiments, run_experiment, ExperimentCtx, Scale};
pub use schemes::{instantiate_scheme, SchemeInstance, SchemeKind, ALL_SCHEMES};
pub use table::{Table, TsvSink};

use std::time::{Duration, Instant};

/// Simple measurement loop: warm up, then time `iters` runs of `f`,
/// reporting (mean, min) per-iteration wall time. The hot-path benches use
/// this in place of criterion.
pub fn time_loop<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (Duration, Duration) {
    for _ in 0..warmup {
        f();
    }
    let mut min = Duration::MAX;
    let mut total = Duration::ZERO;
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        let dt = t.elapsed();
        total += dt;
        if dt < min {
            min = dt;
        }
    }
    (total / iters.max(1) as u32, min)
}

/// ns/op convenience for the microbench printer.
pub fn ns_per_op(d: Duration, ops: usize) -> f64 {
    d.as_nanos() as f64 / ops.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_loop_measures() {
        let mut count = 0;
        let (mean, min) = time_loop(2, 5, || {
            count += 1;
            std::thread::sleep(Duration::from_micros(200));
        });
        assert_eq!(count, 7);
        assert!(mean >= Duration::from_micros(150));
        assert!(min <= mean);
    }

    #[test]
    fn ns_per_op_math() {
        assert_eq!(ns_per_op(Duration::from_micros(1), 1000), 1.0);
        assert!(ns_per_op(Duration::from_secs(1), 0) > 0.0);
    }
}
