//! PageANN CLI: build indexes, search them, and regenerate the paper's
//! experiments.
//!
//! ```text
//! pageann build  --out <dir> [--kind sift|spacev|deep] [--n 60000]
//!                [--placement onpage|hybrid:<frac>|inmem] [--page-size 4096]
//! pageann search --index <dir> [--kind sift] [--n 60000] [--k 10] [--l 64]
//!                [--queries 100] [--sim-ssd] [--io uring|aio|pread]
//!                [--trace <path>]
//! pageann experiment <id>|all [--scale xs|s|m] [--workdir target/experiments]
//! pageann serve  --index <dir> [--addr 127.0.0.1:7700] [--batch-max 8]
//!                [--gather-us <fixed>|--gather-us-max 200] [--lut-cache 0]
//!                [--sim-ssd] [--io uring|aio|pread] [--trace <path>]
//! pageann info
//! ```
//!
//! (Arg parsing is hand-rolled: the offline vendor set has no clap.)

use pageann::bench::{list_experiments, run_experiment, ExperimentCtx, Scale};
use pageann::dataset::{DatasetKind, SynthSpec, Workload};
use pageann::engine::{run_workload, AnnSystem, BatchConfig, OpenOptions, PageAnnIndex, QueryServer};
use pageann::layout::{BuildConfig, CvPlacement, IndexBuilder};
use pageann::Result;
use std::path::PathBuf;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny flag parser: `--key value` pairs + positionals.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> Self {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut it = std::env::args().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = if it.peek().map(|v| !v.starts_with("--")).unwrap_or(false) {
                    it.next().unwrap()
                } else {
                    "true".to_string()
                };
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a);
            }
        }
        Self { positional, flags }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.flags.get(key).map(|v| v.parse()).transpose()?.unwrap_or(default))
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn dataset_kind(s: &str) -> Result<DatasetKind> {
    Ok(match s {
        "sift" => DatasetKind::SiftLike,
        "spacev" => DatasetKind::SpacevLike,
        "deep" => DatasetKind::DeepLike,
        _ => anyhow::bail!("unknown dataset kind {s} (sift|spacev|deep)"),
    })
}

fn placement(s: &str) -> Result<CvPlacement> {
    Ok(match s {
        "onpage" => CvPlacement::OnPage,
        "inmem" => CvPlacement::InMemory,
        other => match other.strip_prefix("hybrid:") {
            Some(f) => CvPlacement::Hybrid { mem_frac: f.parse()? },
            None => anyhow::bail!("unknown placement {s} (onpage|hybrid:<frac>|inmem)"),
        },
    })
}

fn run() -> Result<()> {
    let args = Args::parse();
    match args.positional.first().map(|s| s.as_str()) {
        Some("build") => cmd_build(&args),
        Some("search") => cmd_search(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("serve") => cmd_serve(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!("usage: pageann <build|search|experiment|serve|info> [flags]");
            eprintln!("experiments: {}", list_experiments().join(", "));
            Ok(())
        }
    }
}

fn cmd_build(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get("out", "target/index"));
    let kind = dataset_kind(&args.get("kind", "sift"))?;
    let n = args.get_usize("n", 60_000)?;
    let cv = placement(&args.get("placement", "onpage"))?;
    let spec = SynthSpec::new(kind, n);
    eprintln!("synthesizing {} n={n}...", spec.name());
    let base = spec.generate(0xDA7A);
    let cfg = BuildConfig {
        page_size: args.get_usize("page-size", 4096)?,
        cv_placement: cv,
        pq_m: args.get_usize("pq-m", 16)?,
        pq_k: args.get_usize("pq-k", 256)?,
        ..Default::default()
    };
    eprintln!("building index into {}...", out.display());
    let report = IndexBuilder::new(&base, cfg).build(&out)?;
    println!(
        "built: {} pages × {}B, capacity {} vecs/page, avg page degree {:.1}",
        report.n_pages,
        args.get_usize("page-size", 4096)?,
        report.capacity,
        report.avg_page_degree
    );
    println!(
        "times: vamana {:.1}s, pq {:.1}s, grouping {:.1}s, write {:.1}s",
        report.vamana_secs, report.pq_secs, report.grouping_secs, report.write_secs
    );
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("index", "target/index"));
    let kind = dataset_kind(&args.get("kind", "sift"))?;
    let n = args.get_usize("n", 60_000)?;
    let k = args.get_usize("k", 10)?;
    let l = args.get_usize("l", 64)?;
    let nq = args.get_usize("queries", 100)?;
    let threads = args.get_usize("threads", 16)?;

    let spec = SynthSpec::new(kind, n);
    eprintln!("regenerating workload for ground truth...");
    let w = Workload::synthesize(&spec, nq, k, 0xDA7A);
    let opts = OpenOptions {
        sim_ssd: args.has("sim-ssd").then(Default::default),
        // I/O backend preference: --io beats PAGEANN_IO beats the
        // uring → aio → pread probe; never fails the open.
        io_backend: args.flags.get("io").cloned(),
        // Per-hop JSONL tracing: --trace beats PAGEANN_TRACE beats off.
        trace_path: args.flags.get("trace").map(PathBuf::from),
        ..Default::default()
    };
    let idx = PageAnnIndex::open(&dir, opts)?;
    eprintln!("io backend: {}", idx.io_backend());
    let rep = run_workload(&idx, &w.queries, Some(&w.gt), k, l, threads);
    println!(
        "recall@{k}={:.4}  qps={:.1}  mean={:.2}ms p50={:.2}ms p99={:.2}ms  meanIOs={:.1}  readamp={:.2}",
        rep.summary.recall,
        rep.summary.qps(),
        rep.summary.mean_latency_ms(),
        rep.summary.latency.p50_ms(),
        rep.summary.latency.p99_ms(),
        rep.summary.mean_ios(),
        rep.summary.totals.read_amplification(),
    );
    if let Some(tr) = idx.trace_sink() {
        tr.sync();
        eprintln!("trace: {} spans written, {} dropped", tr.emitted(), tr.dropped());
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let scale = Scale::parse(&args.get("scale", "s"))?;
    let workdir = PathBuf::from(args.get("workdir", "target/experiments"));
    let results = PathBuf::from(args.get("results", "results"));
    let mut ctx = ExperimentCtx::new(scale, &workdir, &results)?;
    if args.has("no-sim-ssd") {
        ctx.sim = None;
    }
    let ids: Vec<&str> = if id == "all" { list_experiments() } else { vec![id] };
    for id in ids {
        eprintln!("=== running {id} ===");
        for table in run_experiment(&mut ctx, id)? {
            println!("{}", table.render());
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("index", "target/index"));
    let addr = args.get("addr", "127.0.0.1:7700");
    let opts = OpenOptions {
        sim_ssd: args.has("sim-ssd").then(Default::default),
        io_backend: args.flags.get("io").cloned(),
        // Cross-tick LUT cache: --lut-cache beats PAGEANN_LUT_CACHE beats
        // the default (0 = off).
        lut_cache_entries: if args.has("lut-cache") {
            args.get_usize("lut-cache", 0)?
        } else {
            OpenOptions::default().lut_cache_entries
        },
        // Per-hop JSONL tracing: --trace beats PAGEANN_TRACE beats off.
        trace_path: args.flags.get("trace").map(PathBuf::from),
        ..Default::default()
    };
    let idx = PageAnnIndex::open(&dir, opts)?;
    eprintln!("io backend: {}", idx.io_backend());
    let dim = idx.meta.dim;
    // Admission-queue knobs: flags beat PAGEANN_GATHER_US[_MAX] /
    // PAGEANN_BATCH beats the defaults. `--gather-us` pins the historical
    // fixed window; otherwise the window adapts to arrival rate up to
    // `--gather-us-max`.
    let mut cfg = BatchConfig::default();
    if args.has("batch-max") {
        cfg.batch_max = args.get_usize("batch-max", cfg.batch_max)?.max(1);
    }
    if args.has("gather-us") {
        cfg.gather = pageann::engine::GatherPolicy::Fixed(std::time::Duration::from_micros(
            args.get_usize("gather-us", 200)? as u64,
        ));
    } else if args.has("gather-us-max") {
        cfg.gather = pageann::engine::GatherPolicy::Adaptive {
            max: std::time::Duration::from_micros(args.get_usize("gather-us-max", 200)? as u64),
        };
    }
    let sys: std::sync::Arc<dyn AnnSystem> = std::sync::Arc::new(idx);
    let server = QueryServer::bind(&addr, sys, dim)?.with_batching(cfg);
    let local = server.local_addr()?;
    println!("serving on {local} (batch_max={}, gather={:?})", cfg.batch_max, cfg.gather);
    // Keep the handle alive (dropping it stops the server) and park.
    let _handle = server.spawn()?;
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_info() -> Result<()> {
    println!("pageann {} — PageANN reproduction (rust + JAX + Pallas)", env!("CARGO_PKG_VERSION"));
    match pageann::runtime::XlaRuntime::cpu() {
        Ok(rt) => println!("pjrt: platform={} devices={}", rt.platform(), rt.device_count()),
        Err(e) => println!("pjrt: unavailable ({e})"),
    }
    match pageann::runtime::ArtifactSet::load(std::path::Path::new("artifacts")) {
        Ok(a) => println!("artifacts: {}", a.names().join(", ")),
        Err(e) => println!("artifacts: {e}"),
    }
    println!("host threads: {}", pageann::util::num_threads());
    Ok(())
}
